"""Paper Fig. 4 (+ Fig. 10) — topology-aware vs topology-unaware aggregation.

Claim: with OOD data on the HIGHEST-degree node, Degree and Betweenness
(τ=0.1) beat FL / Weighted / Unweighted / Random on OOD accuracy-AUC,
without sacrificing IID accuracy.

Expressed as a declarative cell grid over the batched sweep engine: all
strategies × seeds for a dataset run as ONE vmap×scan program
(``benchmarks/sweep.py --preset fig4`` reports the wall-clock win over the
legacy per-config loop).
"""
from __future__ import annotations

from typing import List

from benchmarks.common import QUICK, SweepCell, csv_row, run_sweep_cells
from repro.core.topology import barabasi_albert

STRATEGIES = ("fl", "weighted", "unweighted", "random", "degree", "betweenness")
AWARE = ("degree", "betweenness")


def cells(datasets=("mnist",), ba_p=(2,), n_nodes=16,
          seeds=(0,)) -> List[SweepCell]:
    return [
        SweepCell(ds, barabasi_albert(n_nodes, p, seed=seed), strat,
                  ood_k=1, seed=seed,
                  name=f"fig4/{ds}/ba_p{p}/{strat}")
        for ds in datasets
        for p in ba_p
        for seed in seeds
        for strat in STRATEGIES
    ]


def run(datasets=("mnist",), ba_p=(2,), n_nodes=16, seeds=(0,),
        scale=QUICK, log=print) -> List[dict]:
    grid = cells(datasets, ba_p, n_nodes, seeds)
    rows = run_sweep_cells(grid, scale=scale)
    for cell, r in zip(grid, rows):
        log(csv_row(
            cell.label, r["secs"],
            f"iid_auc={r['iid_auc']:.3f};ood_auc={r['ood_auc']:.3f}"))
    return rows


def verdict(rows) -> str:
    """aware-mean OOD AUC vs unaware-mean, plus IID no-sacrifice check."""
    import numpy as np

    aware = [r for r in rows if r["strategy"] in AWARE]
    unaware = [r for r in rows if r["strategy"] not in AWARE]
    a_ood = np.mean([r["ood_auc"] for r in aware])
    u_ood = np.mean([r["ood_auc"] for r in unaware])
    a_iid = np.mean([r["iid_auc"] for r in aware])
    u_iid = np.mean([r["iid_auc"] for r in unaware])
    improve = 100 * (a_ood - u_ood) / max(u_ood, 1e-9)
    return (f"fig4 claim (topology-aware > unaware on OOD): "
            f"aware_ood={a_ood:.3f} vs unaware_ood={u_ood:.3f} "
            f"(+{improve:.0f}%); iid {a_iid:.3f} vs {u_iid:.3f} "
            f"({'no sacrifice' if a_iid > u_iid - 0.05 else 'IID SACRIFICED'})")


if __name__ == "__main__":
    rows = run()
    print(verdict(rows))
