"""Paper Fig. 5 — impact of OOD data location.

Claim: moving the OOD data to lower-degree nodes hurts propagation
(negative relationship between host-node degree and OOD AUC), for
topology-aware strategies.

Expressed as a declarative cell grid over the batched sweep engine; OOD
placements only change the data-bank row each experiment points at, so the
whole strategy × placement grid is one compiled program.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import QUICK, SweepCell, csv_row, run_sweep_cells
from repro.core.topology import barabasi_albert


def cells(datasets=("mnist",), n_nodes=16, ba_p=2, seeds=(0,),
          strategies=("degree", "betweenness"),
          ood_ks=(1, 2, 3, 4)) -> List[SweepCell]:
    return [
        SweepCell(ds, barabasi_albert(n_nodes, ba_p, seed=seed), strat,
                  ood_k=k, seed=seed,
                  name=f"fig5/{ds}/{strat}/ood_k{k}")
        for ds in datasets
        for seed in seeds
        for strat in strategies
        for k in ood_ks
    ]


def run(datasets=("mnist",), n_nodes=16, ba_p=2, seeds=(0,),
        strategies=("degree", "betweenness"), ood_ks=(1, 2, 3, 4),
        scale=QUICK, log=print) -> List[dict]:
    grid = cells(datasets, n_nodes, ba_p, seeds, strategies, ood_ks)
    rows = run_sweep_cells(grid, scale=scale)
    for cell, r in zip(grid, rows):
        log(csv_row(cell.label, r["secs"], f"ood_auc={r['ood_auc']:.3f}"))
    return rows


def verdict(rows) -> str:
    """Spearman-ish check: OOD AUC non-increasing in placement rank k,
    corroborated by the streaming arrival-round analytics (deeper
    placement ⇒ knowledge arrives later, when the threshold is reached
    at all)."""
    import numpy as np

    by_strat = {}
    arrivals = {}
    for r in rows:
        by_strat.setdefault((r["dataset"], r["strategy"], r["seed"]), {})[
            r["ood_k"]] = r["ood_auc"]
        arr = r.get("analytics", {}).get("ood_arrival_mean")
        if arr is not None:
            arrivals.setdefault(r["ood_k"], []).append(arr)
    trends = []
    for key, kmap in by_strat.items():
        ks = sorted(kmap)
        aucs = [kmap[k] for k in ks]
        corr = np.corrcoef(ks, aucs)[0, 1] if len(ks) > 2 else (
            -1.0 if aucs[0] >= aucs[-1] else 1.0)
        trends.append(corr)
    neg = sum(1 for t in trends if t < 0.1)
    arrival_txt = ""
    if arrivals:
        ks = sorted(arrivals)
        arrival_txt = ("; mean arrival round by rank " + ", ".join(
            f"k{k}={np.mean(arrivals[k]):.1f}" for k in ks))
    return (f"fig5 claim (lower-degree placement ⇒ worse propagation): "
            f"{neg}/{len(trends)} strategy-cells show the negative trend "
            f"(mean corr {np.mean(trends):.2f}){arrival_txt}")


if __name__ == "__main__":
    rows = run()
    print(verdict(rows))
