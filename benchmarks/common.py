"""Shared harness for the paper-figure benchmarks.

``run_experiment`` reproduces one cell of the paper's experimental grid:
(dataset, topology, aggregation strategy, OOD location) → accuracy-AUC
summary over R rounds.  Reduced defaults keep `python -m benchmarks.run`
CPU-tractable; ``--full`` restores paper scale (33 nodes, 40 rounds,
5 datasets, 3 seeds).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.decentralized import (
    DecentralizedConfig,
    DecentralizedTrainer,
    stack_params,
)
from repro.core.propagation import accuracy_auc, propagation_summary
from repro.core.strategies import AggregationStrategy
from repro.core.topology import Topology
from repro.data.backdoor import backdoored_testset
from repro.data.distribution import node_datasets
from repro.data.pipeline import NodeBatcher, make_test_batch
from repro.data.synthetic import make_dataset
from repro.models.paper_models import (
    classifier_accuracy,
    classifier_loss,
    ffn_init,
    ffn_apply,
    gpt2_tinymem_config,
    lm_accuracy,
    lm_loss,
    vgg_init,
    vgg_apply,
)
from repro.models.transformer import init_params as tf_init
from repro.training.optimizer import adam, sgd

# Table 1 of the paper (model + optimizer per dataset); reduced widths for
# CPU tractability — relative strategy comparisons are preserved.
DATASET_SETUP = {
    "mnist":   dict(model="ffn", opt=("sgd", 1e-2)),
    "fmnist":  dict(model="ffn", opt=("sgd", 1e-2)),
    "cifar10": dict(model="vgg", opt=("adam", 1e-4)),
    "cifar100": dict(model="vgg", opt=("adam", 1e-4)),
    "tinymem": dict(model="gpt2", opt=("adam", 1e-3)),
}


@dataclasses.dataclass
class BenchScale:
    n_train: int = 6000
    n_test: int = 600
    rounds: int = 15
    local_epochs: int = 3
    batch: int = 32
    steps_per_epoch: int = 8
    eval_every: int = 3
    eval_n: int = 256


# QUICK uses the paper's R≈40/E=5 regime scaled to 30 rounds — below ~20
# rounds the system is dilution-limited rather than propagation-limited and
# the topology trends invert (see EXPERIMENTS.md §Reproduction notes).
QUICK = BenchScale(rounds=30, local_epochs=5, eval_every=5)
FULL = BenchScale(n_train=20000, n_test=2000, rounds=40, local_epochs=5,
                  batch=32, steps_per_epoch=0, eval_every=4, eval_n=512)


def _model_fns(dataset: str, scale: BenchScale, seed: int):
    setup = DATASET_SETUP[dataset]
    kind, (opt_name, lr) = setup["model"], setup["opt"]
    opt = sgd(lr) if opt_name == "sgd" else adam(lr)
    if kind == "ffn":
        in_dim = 28 * 28 * 1
        init = lambda k: ffn_init(k, in_dim=in_dim)
        return init, classifier_loss(ffn_apply), classifier_accuracy(ffn_apply), opt
    if kind == "vgg":
        n_classes = 100 if dataset == "cifar100" else 10
        init = lambda k: vgg_init(k, n_classes=n_classes, width_mult=0.25)
        return init, classifier_loss(vgg_apply), classifier_accuracy(vgg_apply), opt
    cfg = gpt2_tinymem_config()
    init = lambda k: tf_init(k, cfg)
    return init, lm_loss(cfg), lm_accuracy(cfg), opt


@functools.lru_cache(maxsize=32)
def _data(dataset: str, n_train: int, n_test: int, seed: int):
    train = make_dataset(dataset, n_train, seed=seed)
    test = make_dataset(dataset, n_test, seed=seed + 9999)
    return train, test


def run_experiment(
    dataset: str,
    topo: Topology,
    strategy: str,
    ood_k: int = 1,                 # OOD on k-th highest-degree node
    tau: float = 0.1,
    seed: int = 0,
    scale: BenchScale = QUICK,
    alpha_l: float = 1000.0,        # label-Dirichlet heterogeneity (paper B.2.1)
    alpha_s: float = 1000.0,
) -> Dict:
    """One experimental cell → AUC summary dict."""
    t0 = time.time()
    train, test = _data(dataset, scale.n_train, scale.n_test, seed)
    ood_node = topo.kth_highest_degree_node(ood_k)
    parts = node_datasets(train, topo.n_nodes, ood_node=ood_node,
                          q=0.10, seed=seed, alpha_l=alpha_l, alpha_s=alpha_s)
    nb = NodeBatcher(parts, batch_size=scale.batch,
                     steps_per_epoch=scale.steps_per_epoch, seed=seed)
    tb = make_test_batch(test, scale.eval_n, seed=seed)
    ob = make_test_batch(backdoored_testset(test, seed=seed), scale.eval_n,
                         seed=seed, ood_mask=(test.kind == "lm"))

    init, loss_fn, acc_fn, opt = _model_fns(dataset, scale, seed)
    common = init(jax.random.key(seed))
    params = stack_params([common] * topo.n_nodes)

    trainer = DecentralizedTrainer(
        topo, AggregationStrategy(strategy, tau=tau, seed=seed), opt,
        loss_fn, acc_fn,
        DecentralizedConfig(rounds=scale.rounds,
                            local_epochs=scale.local_epochs,
                            eval_every=scale.eval_every),
        data_counts=nb.data_counts(),
    )
    _, hist = trainer.run(
        params, lambda r: jax.tree.map(jnp.asarray, nb.round_batches(r)),
        jax.tree.map(jnp.asarray, tb), jax.tree.map(jnp.asarray, ob))

    summary = propagation_summary(hist, topo.adjacency, ood_node)
    summary.update(
        dataset=dataset, topology=topo.name, strategy=strategy,
        ood_k=ood_k, ood_node=ood_node, seed=seed,
        secs=round(time.time() - t0, 1),
    )
    return summary


def csv_row(name: str, secs: float, derived: str) -> str:
    """The scaffold's ``name,us_per_call,derived`` CSV convention."""
    return f"{name},{secs * 1e6:.0f},{derived}"
