"""Shared harness for the paper-figure benchmarks.

Two execution paths over the same experimental grid:

* ``run_experiment`` — the legacy path: ONE cell (dataset, topology,
  strategy, OOD location) per invocation, per-round Python loop.  Kept as
  the wall-clock baseline the sweep engine is compared against.
* ``run_sweep_cells`` — the batched path: a list of :class:`SweepCell`
  grouped by program shape and evaluated by ``repro.core.sweep`` — one
  compiled vmap×scan program per (dataset, n_nodes) group.

Reduced defaults keep `python -m benchmarks.run` CPU-tractable; ``--full``
restores paper scale (33 nodes, 40 rounds, 5 datasets, 3 seeds).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coeffs import (
    PROGRAM_KINDS,
    ProgramCoeffs,
    program_for,
    stack_states,
)
from repro.core.decentralized import (
    DecentralizedConfig,
    DecentralizedTrainer,
    coeffs_stack,
    stack_params,
)
from repro.core.analytics import (AnalyticsSpec, analytics_summary,
                                  participation_summary, quarantine_summary)
from repro.core.dynamic import FaultSpec, ParticipationSpec
from repro.core.sweep import SweepEngine
from repro.core.propagation import per_node_auc, propagation_summary
from repro.core.strategies import AggregationStrategy
from repro.core.topology import Topology
from repro.data.backdoor import backdoored_testset
from repro.data.distribution import node_datasets
from repro.data.pipeline import NodeBatcher, make_test_batch
from repro.data.synthetic import make_dataset
from repro.models.paper_models import (
    classifier_accuracy,
    classifier_loss,
    ffn_init,
    ffn_apply,
    gpt2_tinymem_config,
    lm_accuracy,
    lm_loss,
    vgg_init,
    vgg_apply,
)
from repro.models.transformer import init_params as tf_init
from repro.training.optimizer import adam, sgd

# Table 1 of the paper (model + optimizer per dataset); reduced widths for
# CPU tractability — relative strategy comparisons are preserved.
DATASET_SETUP = {
    "mnist":   dict(model="ffn", opt=("sgd", 1e-2)),
    "fmnist":  dict(model="ffn", opt=("sgd", 1e-2)),
    "cifar10": dict(model="vgg", opt=("adam", 1e-4)),
    "cifar100": dict(model="vgg", opt=("adam", 1e-4)),
    "tinymem": dict(model="gpt2", opt=("adam", 1e-3)),
}


@dataclasses.dataclass
class BenchScale:
    n_train: int = 6000
    n_test: int = 600
    rounds: int = 15
    local_epochs: int = 3
    batch: int = 32
    steps_per_epoch: int = 8
    eval_every: int = 3
    eval_n: int = 256


# QUICK uses the paper's R≈40/E=5 regime scaled to 30 rounds — below ~20
# rounds the system is dilution-limited rather than propagation-limited and
# the topology trends invert (see EXPERIMENTS.md §Reproduction notes).
QUICK = BenchScale(rounds=30, local_epochs=5, eval_every=5)

#: accuracy level that counts as "OOD knowledge arrived" for the
#: streaming arrival-round analytics (run_sweep_cells default; the
#: BENCH_sweep.json analytics sections record whichever value ran).
DEFAULT_ARRIVAL_THRESHOLD = 0.5
FULL = BenchScale(n_train=20000, n_test=2000, rounds=40, local_epochs=5,
                  batch=32, steps_per_epoch=0, eval_every=4, eval_n=512)


def _model_fns(dataset: str, scale: BenchScale, seed: int):
    setup = DATASET_SETUP[dataset]
    kind, (opt_name, lr) = setup["model"], setup["opt"]
    opt = sgd(lr) if opt_name == "sgd" else adam(lr)
    if kind == "ffn":
        in_dim = 28 * 28 * 1
        init = lambda k: ffn_init(k, in_dim=in_dim)
        return init, classifier_loss(ffn_apply), classifier_accuracy(ffn_apply), opt
    if kind == "vgg":
        n_classes = 100 if dataset == "cifar100" else 10
        init = lambda k: vgg_init(k, n_classes=n_classes, width_mult=0.25)
        return init, classifier_loss(vgg_apply), classifier_accuracy(vgg_apply), opt
    cfg = gpt2_tinymem_config()
    init = lambda k: tf_init(k, cfg)
    return init, lm_loss(cfg), lm_accuracy(cfg), opt


@functools.lru_cache(maxsize=32)
def _data(dataset: str, n_train: int, n_test: int, seed: int):
    train = make_dataset(dataset, n_train, seed=seed)
    test = make_dataset(dataset, n_test, seed=seed + 9999)
    return train, test


def run_experiment(
    dataset: str,
    topo: Topology,
    strategy: str,
    ood_k: int = 1,                 # OOD on k-th highest-degree node
    tau: float = 0.1,
    seed: int = 0,
    scale: BenchScale = QUICK,
    alpha_l: float = 1000.0,        # label-Dirichlet heterogeneity (paper B.2.1)
    alpha_s: float = 1000.0,
    ood_ks: Optional[Tuple[int, ...]] = None,  # multi-source degree ranks
) -> Dict:
    """One experimental cell → AUC summary dict.  ``ood_ks`` overrides
    ``ood_k`` with a tuple of degree ranks hosting OOD data
    simultaneously (same placement scheme as ``SweepCell.ood_ks``, so
    the legacy loop stays a valid baseline for multi-source grids)."""
    t0 = time.time()
    train, test = _data(dataset, scale.n_train, scale.n_test, seed)
    ood_nodes = tuple(topo.kth_highest_degree_node(k)
                      for k in (ood_ks or (ood_k,)))
    parts = node_datasets(train, topo.n_nodes, ood_node=ood_nodes,
                          q=0.10, seed=seed, alpha_l=alpha_l, alpha_s=alpha_s)
    nb = NodeBatcher(parts, batch_size=scale.batch,
                     steps_per_epoch=scale.steps_per_epoch, seed=seed,
                     local_epochs=scale.local_epochs)
    tb = make_test_batch(test, scale.eval_n, seed=seed)
    ob = make_test_batch(backdoored_testset(test, seed=seed), scale.eval_n,
                         seed=seed, ood_mask=(test.kind == "lm"))

    init, loss_fn, acc_fn, opt = _model_fns(dataset, scale, seed)
    common = init(jax.random.key(seed))
    params = stack_params([common] * topo.n_nodes)

    trainer = DecentralizedTrainer(
        topo, AggregationStrategy(strategy, tau=tau, seed=seed), opt,
        loss_fn, acc_fn,
        # unroll_eval=True: this is the pre-sweep-engine per-round loop,
        # kept as the wall-clock baseline (benchmarks/sweep.py compares).
        DecentralizedConfig(rounds=scale.rounds,
                            local_epochs=scale.local_epochs,
                            eval_every=scale.eval_every,
                            unroll_eval=True),
        data_counts=nb.data_counts(),
    )
    _, hist = trainer.run(
        params, lambda r: jax.tree.map(jnp.asarray, nb.round_batches(r)),
        jax.tree.map(jnp.asarray, tb), jax.tree.map(jnp.asarray, ob))

    summary = propagation_summary(hist, topo.adjacency, ood_nodes)
    summary.update(
        dataset=dataset, topology=topo.name, strategy=strategy,
        ood_k=ood_k,
        ood_node=(ood_nodes[0] if len(ood_nodes) == 1
                  else list(ood_nodes)),
        seed=seed,
        secs=round(time.time() - t0, 1),
    )
    if ood_ks:
        summary["ood_ks"] = list(ood_ks)
    return summary


def csv_row(name: str, secs: float, derived: str) -> str:
    """The scaffold's ``name,us_per_call,derived`` CSV convention."""
    return f"{name},{secs * 1e6:.0f},{derived}"


# ----------------------------------------------------------------------
# batched path: declarative cells → repro.core.sweep
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True, eq=False)
class SweepCell:
    """One cell of a figure's grid, as data (no control flow).

    ``name`` is the CSV label; ``sweep`` is the free-form annotation the
    fig6-style verdicts group by (stored on the summary row verbatim).
    ``p_fail`` drops each edge i.i.d. per round (``repro.core.dynamic``);
    ``reactive`` recomputes centralities on the surviving subgraph
    in-scan — both realized by the cell's coefficient program
    (``repro.core.coeffs``; must agree across a compiled group).

    ``ood_ks`` opens the multi-source scenario axis: a tuple of degree
    ranks hosting OOD data simultaneously (each gets its own backdoored
    subset — ``data.distribution.place_ood``).  When set it overrides the
    single-source ``ood_k``; hop fields and arrival bins then use the
    min-over-sources distance.

    ``participation`` is the cell's node-activation rate under a
    partial-participation sweep (``run_sweep_cells(participation=...)``,
    DESIGN.md §15); ``None`` means fully synchronous — in a mixed group
    such cells run at rate 1.0, which is bit-identical.

    ``fault_rate`` is the cell's per-node-round Byzantine fault
    probability under a fault-injection sweep
    (``run_sweep_cells(fault=...)``, DESIGN.md §16); ``None`` runs at
    rate 0.0, bit-identical to the fault-free round.  ``robust`` selects
    the cell's aggregation rule (``make_mix_fn``); it is static engine
    configuration, so cells with different ``robust`` compile into
    separate groups.
    """

    dataset: str
    topo: Topology
    strategy: str
    ood_k: int = 1
    tau: float = 0.1
    seed: int = 0
    name: str = ""
    sweep: Optional[tuple] = None
    p_fail: float = 0.0
    reactive: bool = False
    ood_ks: Optional[Tuple[int, ...]] = None
    participation: Optional[float] = None
    fault_rate: Optional[float] = None
    robust: str = "mean"

    @property
    def label(self) -> str:
        return self.name or f"{self.dataset}/{self.topo.name}/{self.strategy}"

    def ood_nodes(self) -> Tuple[int, ...]:
        """The cell's OOD host node(s): ``ood_ks`` degree ranks when set,
        else the single ``ood_k``-th highest-degree node."""
        ranks = tuple(self.ood_ks) if self.ood_ks else (self.ood_k,)
        nodes = tuple(self.topo.kth_highest_degree_node(k) for k in ranks)
        if len(set(nodes)) != len(nodes):
            raise ValueError(f"ood_ks {ranks} map to duplicate nodes "
                             f"{nodes} on {self.topo.name}")
        return nodes


def linkfail_cells(
    datasets=("mnist",),
    seeds=(0,),
    n_nodes: int = 16,
    strategies=("unweighted", "degree"),
    p_fails=(0.0, 0.3, 0.6),
    reactive: bool = True,
    prefix: str = "linkfail",
) -> List[SweepCell]:
    """Link-failure grid shared by the ``benchmarks/sweep.py linkfail``
    preset and ``benchmarks/ablations.py run_link_failure``: strategies ×
    p_fail on per-seed BA graphs, coefficients generated in-scan by each
    cell's program (reactive=True recomputes centralities on the
    surviving subgraph)."""
    from repro.core.topology import barabasi_albert

    cells = []
    for ds in datasets:
        for seed in seeds:
            # one Topology per (dataset, seed) so the networkx centrality
            # cache (nominal scores, kth_highest_degree_node) is shared
            topo = barabasi_albert(n_nodes, 2, seed=seed)
            for strat in strategies:
                for pf in p_fails:
                    cells.append(SweepCell(
                        ds, topo, strat, ood_k=1, seed=seed,
                        p_fail=pf, reactive=reactive,
                        name=f"{prefix}/{ds}/{strat}/p{pf}",
                        sweep=("p_fail", strat, pf)))
    return cells


def multisource_cells(
    datasets=("mnist",),
    seeds=(0,),
    n_nodes: int = 16,
    strategies=("unweighted", "degree"),
    source_counts=(1, 2, 4),
    prefix: str = "multisource",
) -> List[SweepCell]:
    """Multi-source OOD grid (the ``benchmarks/sweep.py multisource``
    preset): k backdoor sources on the k highest-degree nodes of per-seed
    BA graphs, strategies × source counts.  Every source plants the SAME
    trigger on its own backdoored subset, so the in-scan arrival-round
    analytics measure how source multiplicity accelerates propagation
    (min-over-sources hop fields)."""
    from repro.core.topology import barabasi_albert

    cells = []
    for ds in datasets:
        for seed in seeds:
            topo = barabasi_albert(n_nodes, 2, seed=seed)
            for strat in strategies:
                for k in source_counts:
                    cells.append(SweepCell(
                        ds, topo, strat, seed=seed,
                        ood_ks=tuple(range(1, k + 1)),
                        name=f"{prefix}/{ds}/{strat}/k{k}",
                        sweep=("sources", strat, k)))
    return cells


def edges_cells(
    datasets=("mnist",),
    seeds=(0,),
    n_nodes: int = 64,
    strategies=("unweighted", "degree"),
    prefix: str = "edges",
) -> List[SweepCell]:
    """Edge-list mix smoke grid (the ``benchmarks/sweep.py edges``
    preset): strategies × hub-OOD placement on per-seed BA graphs at a
    node count (default 64) where the dense (n, n) coefficient slab is
    already the wrong representation — run with
    ``run_sweep_cells(..., mix_impl="edges")``."""
    from repro.core.topology import barabasi_albert

    cells = []
    for ds in datasets:
        for seed in seeds:
            topo = barabasi_albert(n_nodes, 2, seed=seed)
            for strat in strategies:
                cells.append(SweepCell(
                    ds, topo, strat, ood_k=1, seed=seed,
                    name=f"{prefix}/{ds}/{strat}/n{n_nodes}",
                    sweep=("edges", strat, n_nodes)))
    return cells


def participation_cells(
    datasets=("mnist",),
    seeds=(0,),
    n_nodes: int = 16,
    strategy: str = "degree",
    rates=(1.0, 0.7, 0.4),
    prefix: str = "participation",
) -> List[SweepCell]:
    """Partial-participation grid (the ``benchmarks/sweep.py
    participation`` preset): activation rate × topology (ring vs per-seed
    BA) × OOD placement (hub ``ood_k=1`` vs periphery ``ood_k=n``), run
    with ``run_sweep_cells(..., participation=ParticipationSpec())``.
    Rate 1.0 rides along as the synchronous control — bit-identical to a
    no-participation run — so every row's staleness × arrival digest has
    an in-grid baseline."""
    from repro.core.topology import barabasi_albert, ring

    cells = []
    for ds in datasets:
        for seed in seeds:
            topos = (ring(n_nodes), barabasi_albert(n_nodes, 2, seed=seed))
            for topo in topos:
                for place, k in (("hub", 1), ("leaf", n_nodes)):
                    for rate in rates:
                        cells.append(SweepCell(
                            ds, topo, strategy, ood_k=k, seed=seed,
                            participation=rate,
                            name=(f"{prefix}/{ds}/{topo.name}/{place}"
                                  f"/r{rate}"),
                            sweep=("participation", topo.name, place, rate)))
    return cells


def byzantine_cells(
    datasets=("mnist",),
    seeds=(0,),
    n_nodes: int = 16,
    strategy: str = "degree",
    rates=(0.0, 0.1, 0.3),
    robusts=("mean", "trimmed", "median"),
    prefix: str = "byzantine",
) -> List[SweepCell]:
    """Byzantine-fault grid (the ``benchmarks/sweep.py byzantine``
    preset): fault rate × topology (ring vs per-seed BA) × OOD placement
    (hub vs periphery) × aggregation rule, run with
    ``run_sweep_cells(..., fault=FaultSpec(...))``.  Rate 0.0 rides
    along as the fault-free control — bit-identical to the synchronous
    round under ``robust="mean"`` — and every (topology, placement,
    rate) cell appears under each aggregator so the robust-vs-mean
    recovery gap is read off within one artifact."""
    from repro.core.topology import barabasi_albert, ring

    cells = []
    for ds in datasets:
        for seed in seeds:
            topos = (ring(n_nodes), barabasi_albert(n_nodes, 2, seed=seed))
            for topo in topos:
                for place, k in (("hub", 1), ("leaf", n_nodes)):
                    for rate in rates:
                        for robust in robusts:
                            cells.append(SweepCell(
                                ds, topo, strategy, ood_k=k, seed=seed,
                                fault_rate=rate, robust=robust,
                                name=(f"{prefix}/{ds}/{topo.name}/{place}"
                                      f"/f{rate}/{robust}"),
                                sweep=("byzantine", topo.name, place,
                                       rate, robust)))
    return cells


def group_cells(
        cells: List[SweepCell]) -> Dict[Tuple[str, int, str], List[int]]:
    """Cells sharing one compiled program: same dataset (model + sample
    shapes), same node count (topology/coeffs shapes), and same robust
    aggregation rule (static mix-fn configuration)."""
    groups: Dict[Tuple[str, int, str], List[int]] = {}
    for i, cell in enumerate(cells):
        groups.setdefault(
            (cell.dataset, cell.topo.n_nodes, cell.robust), []).append(i)
    return groups


def _pad_cap(leaves: Dict[str, np.ndarray], cap: int) -> Dict[str, np.ndarray]:
    return {
        k: np.pad(v, [(0, 0), (0, cap - v.shape[1])] + [(0, 0)] * (v.ndim - 2))
        for k, v in leaves.items()
    }


def run_sweep_cells(
    cells: List[SweepCell],
    scale: BenchScale = QUICK,
    alpha_l: float = 1000.0,
    alpha_s: float = 1000.0,
    unroll_eval: bool = False,
    mesh=None,
    chunk_rounds: Optional[int] = None,
    coeff_mode: str = "stack",
    mix_impl: str = "einsum",
    analytics: bool = True,
    arrival_threshold: float = DEFAULT_ARRIVAL_THRESHOLD,
    participation: Optional[ParticipationSpec] = None,
    fault: Optional[FaultSpec] = None,
    log=None,
) -> List[Dict]:
    """Evaluate a whole grid of cells through the sweep engine.

    One compiled program per (dataset, n_nodes) group: experiments that
    share a data configuration (seed × OOD placement) share a sample-bank
    row; per-experiment initial params, mixing-matrix stacks, and test
    batches ride the vmap axis.  Returns one ``run_experiment``-compatible
    summary dict per cell (in input order) with ``secs`` amortized over the
    group and ``sweep_secs``/``sweep_group_size`` recording the batched
    wall-clock.

    ``mesh`` (``repro.launch.mesh.make_sweep_mesh``) shards each group's
    experiment axis across devices; ``chunk_rounds`` scans the round
    schedule in bounded chunks — both bit-identical to the default path.

    ``coeff_mode`` picks the coefficient representation (DESIGN.md §9):
    ``"stack"`` materializes each cell's ``(R, n, n)`` slab host-side
    (link-failure cells materialize their program);  ``"program"`` ships
    only the compact per-experiment program state and generates matrices
    in-scan — required memory-wise for long reactive sweeps, bit-identical
    to the stack otherwise.

    ``mix_impl`` routes each group's aggregation through the chosen
    backend (``decentralized.make_mix_fn``): ``"edges"``/``"sparse"``
    build the group's ``mix_support`` as the union of its cells'
    neighbourhood masks (adjacency + self loops) so one static schedule
    serves every experiment in the compiled program.

    ``analytics=True`` (default) threads the streaming accumulators
    through the scan (DESIGN.md §10): each row gains an ``"analytics"``
    sub-dict with the in-scan AUCs, arrival-round stats (hop-binned
    against the cell's OOD source set at ``arrival_threshold``), and the
    max per-node deviation from the host-side ``propagation.py`` oracle
    (``stream_vs_host_max_dev`` — the equivalence the golden suite locks).

    ``participation`` (a :class:`ParticipationSpec`) switches the group
    onto the partial-participation round (DESIGN.md §15): each cell's
    ``participation`` rate rides the vmap axis (cells without one run at
    1.0, bit-identical to the synchronous round), and each row gains a
    ``"participation"`` digest (:func:`participation_summary`) — realized
    activity, staleness statistics, and the staleness × arrival-round
    interaction when analytics are on.  Cells that set a rate without a
    spec get the default ``ParticipationSpec()``.

    ``fault`` (a :class:`FaultSpec`) switches each group onto the
    Byzantine-fault round (DESIGN.md §16): each cell's ``fault_rate``
    rides the vmap axis (cells without one run at 0.0, bit-identical to
    the fault-free round), each cell's ``robust`` rule picks its
    compiled group's aggregator, and each row gains a ``"fault"`` digest
    (:func:`quarantine_summary`) — realized corruption, detection lag,
    quarantine occupancy.  Cells that set a rate without a spec get the
    default ``FaultSpec()``.
    """
    if coeff_mode not in ("stack", "program"):
        raise KeyError(f"coeff_mode {coeff_mode!r}; have 'stack', 'program'")
    if participation is None and any(c.participation is not None
                                     for c in cells):
        participation = ParticipationSpec()
    if fault is None and any(c.fault_rate is not None for c in cells):
        fault = FaultSpec()
    spec = (AnalyticsSpec(arrival_threshold=arrival_threshold)
            if analytics else None)
    rows: List[Optional[Dict]] = [None] * len(cells)
    for (ds, n_nodes, robust), idxs in group_cells(cells).items():
        t0 = time.time()
        init, loss_fn, acc_fn, opt = _model_fns(ds, scale, cells[idxs[0]].seed)
        mix_support = None
        if mix_impl != "einsum" or robust in ("trimmed", "median"):
            # one static schedule per compiled program: the union of every
            # cell's neighbourhood mask (adjacency + self loops).  The
            # order-statistic aggregators need it even on the einsum impl
            # — their padded-ELL tables are static engine configuration.
            mix_support = np.eye(n_nodes)
            for i in idxs:
                mix_support = np.maximum(
                    mix_support, np.asarray(cells[i].topo.adjacency))
        engine = SweepEngine(
            opt, loss_fn, acc_fn,
            DecentralizedConfig(rounds=scale.rounds,
                                local_epochs=scale.local_epochs,
                                eval_every=scale.eval_every,
                                mix_impl=mix_impl, robust=robust),
            mix_support=mix_support)

        # distinct data configurations (seed × OOD node) → bank rows.
        # Synchronous sweep rounds need ONE step count across the group:
        # with steps_per_epoch=0 each NodeBatcher would derive its own from
        # its median node size, so the first batcher's derivation is pinned
        # for the rest (index schedules must stack to a common S).
        dconf: Dict[Tuple[int, Tuple[int, ...]], int] = {}
        batchers, tbs, obs = [], [], []
        group_steps = scale.steps_per_epoch
        for i in idxs:
            cell = cells[i]
            ood_nodes = cell.ood_nodes()
            key = (cell.seed, ood_nodes)
            if key not in dconf:
                train, test = _data(ds, scale.n_train, scale.n_test, cell.seed)
                parts = node_datasets(train, n_nodes, ood_node=ood_nodes,
                                      q=0.10, seed=cell.seed,
                                      alpha_l=alpha_l, alpha_s=alpha_s)
                nb = NodeBatcher(parts, batch_size=scale.batch,
                                 steps_per_epoch=group_steps,
                                 seed=cell.seed,
                                 local_epochs=scale.local_epochs)
                group_steps = nb.steps
                dconf[key] = len(batchers)
                batchers.append(nb)
                tbs.append(make_test_batch(test, scale.eval_n, seed=cell.seed))
                obs.append(make_test_batch(
                    backdoored_testset(test, seed=cell.seed), scale.eval_n,
                    seed=cell.seed, ood_mask=(test.kind == "lm")))

        # D-stacked bank + index schedules (pad node caps to the group max)
        raw_banks = [nb.sample_bank() for nb in batchers]
        cap = max(b[next(iter(b))].shape[1] for b in raw_banks)
        padded = [_pad_cap(b, cap) for b in raw_banks]
        bank = {k: np.stack([p[k] for p in padded]) for k in raw_banks[0]}
        indices = np.stack(
            [nb.all_round_indices(scale.rounds) for nb in batchers])

        # per-experiment axes.  Every program-supported cell (incl. all
        # link-failure / reactive cells) goes through its coefficient
        # program — materialized to a slab in "stack" mode, shipped as
        # compact state in "program" mode; both consume identical values.
        reactives = {cells[i].reactive for i in idxs}
        if coeff_mode == "program" and len(reactives) > 1:
            raise ValueError(
                "cells compiled into one program-mode sweep group must "
                "share the `reactive` flag (it is static program "
                "configuration); stack mode materializes per-cell "
                "programs and supports mixed grids")
        data_idx, coeffs, states, p0s, t_iid, t_ood, metas = (
            [], [], [], [], [], [], [])
        program = None
        init_cache: Dict[int, object] = {}
        for i in idxs:
            cell = cells[i]
            ood_nodes = cell.ood_nodes()
            d = dconf[(cell.seed, ood_nodes)]
            data_idx.append(d)
            strategy = AggregationStrategy(cell.strategy, tau=cell.tau,
                                           seed=cell.seed)
            if cell.strategy in PROGRAM_KINDS:
                program, state = program_for(
                    cell.topo, strategy,
                    data_counts=batchers[d].data_counts(),
                    p_fail=cell.p_fail, reactive=cell.reactive)
                if coeff_mode == "program":
                    states.append(state)
                else:
                    coeffs.append(program.materialize(state, scale.rounds))
            else:
                if coeff_mode == "program" or cell.p_fail or cell.reactive:
                    raise ValueError(
                        f"strategy {cell.strategy!r} has no coefficient "
                        f"program (coeff_mode='program' / link-failure "
                        f"cells need one); use coeff_mode='stack'")
                coeffs.append(coeffs_stack(
                    cell.topo, strategy, scale.rounds,
                    data_counts=batchers[d].data_counts()))
            if cell.seed not in init_cache:
                init_cache[cell.seed] = init(jax.random.key(cell.seed))
            p0s.append(stack_params([init_cache[cell.seed]] * n_nodes))
            t_iid.append(tbs[d])
            t_ood.append(obs[d])
            metas.append((cell, ood_nodes))

        if coeff_mode == "program":
            # one shared program serves the whole group, so prune its
            # lax.switch to the UNION of the group's strategy kinds (and
            # drop the per-round edge mask when no cell churns links):
            # under vmap-over-E the batched switch computes every traced
            # branch — for reactive programs the unused 200-iteration
            # power-method branches were the measured ~1.8× overhead
            # (BENCH_sweep.json `coeff_programs`).  Bit-identical for the
            # kinds that remain.
            program = dataclasses.replace(
                program,
                kinds=tuple(sorted({PROGRAM_KINDS.index(cells[i].strategy)
                                    for i in idxs})),
                link_failure=any(cells[i].p_fail > 0 for i in idxs))
            engine_coeffs = ProgramCoeffs(program, stack_states(states))
        else:
            engine_coeffs = np.stack(coeffs)
        params0 = jax.tree.map(lambda *xs: jnp.stack(xs), *p0s)
        stack_tests = lambda ts: {
            k: jnp.stack([jnp.asarray(t[k]) for t in ts]) for k in ts[0]}
        part_kwargs = {}
        if participation is not None:
            part_kwargs = dict(
                participation=participation,
                participation_rates=np.asarray(
                    [1.0 if cells[i].participation is None
                     else cells[i].participation for i in idxs], np.float32))
        if fault is not None:
            part_kwargs.update(
                fault=fault,
                fault_rates=np.asarray(
                    [0.0 if cells[i].fault_rate is None
                     else cells[i].fault_rate for i in idxs], np.float32))
        result = engine.run(
            params0, engine_coeffs, bank, indices,
            np.asarray(data_idx), stack_tests(t_iid), stack_tests(t_ood),
            batch_size=scale.batch, unroll_eval=unroll_eval,
            mesh=mesh, chunk_rounds=chunk_rounds, analytics=spec,
            **part_kwargs)

        secs = time.time() - t0
        for e, (i, (cell, ood_nodes)) in enumerate(zip(idxs, metas)):
            hist = result.history(e)
            summary = propagation_summary(
                hist, cell.topo.adjacency, ood_nodes,
                arrival_threshold=arrival_threshold)
            summary.update(
                dataset=ds, topology=cell.topo.name, strategy=cell.strategy,
                ood_k=cell.ood_k,
                ood_node=(ood_nodes[0] if len(ood_nodes) == 1
                          else list(ood_nodes)),
                seed=cell.seed,
                secs=round(secs / len(idxs), 2), sweep_secs=round(secs, 1),
                sweep_group_size=len(idxs),
            )
            if cell.ood_ks:
                summary["ood_ks"] = list(cell.ood_ks)
            if result.analytics is not None:
                stream = {k: v[e] for k, v in result.analytics.items()}
                a = analytics_summary(stream, cell.topo.adjacency,
                                      ood_nodes)
                a["stream_vs_host_max_dev"] = float(max(
                    np.abs(stream["iid_auc"]
                           - per_node_auc(hist, "iid")).max(),
                    np.abs(stream["ood_auc"]
                           - per_node_auc(hist, "ood")).max()))
                summary["analytics"] = a
            if result.participation is not None:
                part_row = {k: v[e]
                            for k, v in result.participation.items()}
                part_stream = (
                    {k: v[e] for k, v in result.analytics.items()}
                    if result.analytics is not None else None)
                summary["participation_rate"] = (
                    1.0 if cell.participation is None
                    else cell.participation)
                summary["participation"] = participation_summary(
                    part_row, scale.rounds, part_stream)
            if result.fault is not None:
                summary["fault_rate"] = (0.0 if cell.fault_rate is None
                                         else cell.fault_rate)
                summary["robust"] = cell.robust
                summary["fault"] = quarantine_summary(
                    {k: v[e] for k, v in result.fault.items()},
                    scale.rounds)
            if cell.p_fail or cell.reactive:
                summary.update(p_fail=cell.p_fail, reactive=cell.reactive)
            if cell.sweep is not None:
                summary["sweep"] = cell.sweep
            rows[i] = summary
            if log is not None:
                log(csv_row(
                    cell.label, summary["secs"],
                    f"iid_auc={summary['iid_auc']:.3f};"
                    f"ood_auc={summary['ood_auc']:.3f}"))
    return rows  # type: ignore[return-value]
