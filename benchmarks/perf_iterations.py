"""§Perf hillclimbing driver — hypothesis → change → re-derive → verdict.

Three pairs selected from the baseline roofline table (EXPERIMENTS.md):
  A. stablelm-1.6b × train_4k   — representative of the paper's gossip tier
                                  (16 nodes), collective-bound via TP.
  B. deepseek-v2-236b × train_4k — most collective-bound pair overall.
  C. llama4-scout × decode_32k   — worst useful-flops decode; model-
                                  correction case study.

Each iteration is a ParallelConfig change; terms are re-derived with the
analytic roofline (methodology note in roofline.py) and the chosen best
variants are COMPILE-VERIFIED against the production mesh via
``--verify`` (dry_run_pair with the replanned config).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

from benchmarks.roofline import analyze_pair
from repro.configs.registry import get_parallel


def show(tag, r):
    print(f"  {tag:44s} comp {r['t_compute_s']:9.3e}  mem {r['t_memory_s']:9.3e}"
          f"  coll {r['t_collective_s']:9.3e}  dom {r['dominant']:10s}"
          f"  fits {'y' if r['fits_hbm'] else 'N'}")
    return r


def bound(r):
    return max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])


def pair_a(results):
    """stablelm-1.6b × train_4k."""
    arch, shape = "stablelm-1.6b", "train_4k"
    print(f"\n=== Pair A: {arch} × {shape} ===")
    p0 = get_parallel(arch)
    base = show("baseline n16·tp16·f1 micro2 gossip/step", analyze_pair(arch, shape, pcfg=p0))

    # iter 1: amortize gossip over the paper's round (15 steps/round)
    p1 = dataclasses.replace(p0, steps_per_round=15)
    i1 = show("iter1: gossip amortized (steps_per_round=15)",
              analyze_pair(arch, shape, pcfg=p1))

    # iter 2 (REFUTED): sparse circulant gossip on BA-16
    i2 = show("iter2: sparse circulant gossip (BA-16)",
              analyze_pair(arch, shape, pcfg=p1, gossip_schedule="sparse"))

    # iter 3: replan n_nodes=64 · tp=4 (less TP traffic, more gossip nodes)
    p3 = dataclasses.replace(p0, n_nodes=64, tp_degree=4, microbatch=1,
                             steps_per_round=15)
    i3 = show("iter3: replan n64·tp4·f1 (+amortized gossip)",
              analyze_pair(arch, shape, pcfg=p3))

    # iter 4: n64·tp2·f2 — trade residual TP traffic for a small FSDP gather
    p4 = dataclasses.replace(p0, n_nodes=64, tp_degree=2, microbatch=1,
                             steps_per_round=15)
    i4 = show("iter4: replan n64·tp2·f2", analyze_pair(arch, shape, pcfg=p4))

    results["A"] = dict(arch=arch, shape=shape,
                        baseline=base, iters=[i1, i2, i3, i4],
                        speedup=bound(base) / bound(i4))
    print(f"  → bound {bound(base):.3f}s → {bound(i4):.3f}s "
          f"({results['A']['speedup']:.2f}×)")
    return dataclasses.replace(p4)


def pair_b(results):
    """deepseek-v2-236b × train_4k — grid over (tp, micro) + amortization."""
    arch, shape = "deepseek-v2-236b", "train_4k"
    print(f"\n=== Pair B: {arch} × {shape} ===")
    p0 = get_parallel(arch)
    base = show("baseline n1·tp16·f16 micro16", analyze_pair(arch, shape, pcfg=p0))

    print("  -- candidate grid (napkin-math all, then pick) --")
    best, best_p = base, p0
    for tp in (4, 8, 16, 32):
        for micro in (4, 8, 16):
            if 256 % tp:
                continue
            p = dataclasses.replace(p0, tp_degree=tp, microbatch=micro,
                                    chunked_ce=1024)
            r = analyze_pair(arch, shape, pcfg=p)
            tag = f"  cand tp{tp} f{p.fsdp} micro{micro}"
            show(tag, r)
            if r["fits_hbm"] and bound(r) < bound(best):
                best, best_p = r, p
    i1 = best
    print(f"  iter1 pick: tp{best_p.tp_degree} f{best_p.fsdp} "
          f"micro{best_p.microbatch}")

    # iter 2: device-limited routing (DeepSeek-V2 §2.1.3, M=3): each token
    # reaches ≤3 expert-parallel groups → all-to-all bytes ×(3/6)
    p2 = dataclasses.replace(best_p, moe_group_limit=3)
    i2 = show("iter2: + device-limited routing M=3",
              analyze_pair(arch, shape, pcfg=p2))
    best_p = p2

    results["B"] = dict(arch=arch, shape=shape, baseline=base, iters=[i1, i2],
                        best_plan=dict(tp=best_p.tp_degree, fsdp=best_p.fsdp,
                                       micro=best_p.microbatch,
                                       moe_group_limit=3),
                        speedup=bound(base) / bound(i2))
    print(f"  → bound {bound(base):.3f}s → {bound(i2):.3f}s "
          f"({results['B']['speedup']:.2f}×)")
    return best_p


def pair_c(results):
    """llama4-scout × decode_32k — model-correction + replica consolidation."""
    arch, shape = "llama4-scout-17b-a16e", "decode_32k"
    print(f"\n=== Pair C: {arch} × {shape} ===")
    p0 = get_parallel(arch)
    # The *original* analytic model charged a per-step FSDP weight
    # all-gather (0.236 s collective — dominant).  Inspecting the compiled
    # dry-run HLO showed only ~2.4e8 B of collectives: the 2-D-sharded
    # weights are consumed sharded; no gather exists.  The corrected model
    # (roofline.py) is the baseline below — the refuted iteration is
    # recorded in EXPERIMENTS.md with both numbers.
    base = show("baseline (corrected model) n2·tp16·f8",
                analyze_pair(arch, shape, pcfg=p0))

    # iter: serving consolidation — 1 replica, 128-deep batch
    p1 = dataclasses.replace(p0, n_nodes=1)
    i1 = show("iter1: consolidate to 1 replica (batch 128)",
              analyze_pair(arch, shape, pcfg=p1))

    results["C"] = dict(arch=arch, shape=shape, baseline=base, iters=[i1],
                        refuted_model_term_s=0.236,
                        speedup=bound(base) / bound(i1))
    print(f"  → bound {bound(base):.5f}s → {bound(i1):.5f}s "
          f"({results['C']['speedup']:.2f}×)")
    return p1


def pair_d(results):
    """gemma2-27b × train_4k — 4th pair (beyond the mandated three):
    near-balanced baseline pushed to compute-bound."""
    arch, shape = "gemma2-27b", "train_4k"
    print(f"\n=== Pair D: {arch} × {shape} (extra) ===")
    p0 = get_parallel(arch)
    base = show("baseline n4·tp16·f4 micro8", analyze_pair(arch, shape, pcfg=p0))

    # iter 1: amortize gossip + chunked CE (frees memory for the replans)
    p1 = dataclasses.replace(p0, steps_per_round=15, chunked_ce=1024)
    i1 = show("iter1: amortized gossip + chunked CE",
              analyze_pair(arch, shape, pcfg=p1))

    # iter 2: TP-width sweep (napkin: TP bytes ∝ toks_chip·(m−1)/m; wider
    # fsdp shards the batch so both factors shrink): tp 16→4
    p2 = dataclasses.replace(p1, tp_degree=4)
    i2 = show("iter2: tp4·f16", analyze_pair(arch, shape, pcfg=p2))

    # iter 3: tp2·f32 — last step before FSDP gather dominates
    p3 = dataclasses.replace(p1, tp_degree=2)
    i3 = show("iter3: tp2·f32", analyze_pair(arch, shape, pcfg=p3))

    results["D"] = dict(arch=arch, shape=shape, baseline=base,
                        iters=[i1, i2, i3],
                        speedup=bound(base) / bound(i3))
    print(f"  → bound {bound(base):.3f}s → {bound(i3):.3f}s "
          f"({results['D']['speedup']:.2f}×) — compute-bound reached")
    return p3


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--verify", action="store_true",
                    help="compile-verify the winning plans on the mesh "
                         "(spawns the 512-device dry-run)")
    ap.add_argument("--out", default="benchmarks/artifacts/perf_iterations.json")
    args = ap.parse_args()

    results = {}
    pa = pair_a(results)
    pb = pair_b(results)
    pc = pair_c(results)
    pd = pair_d(results)

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    json.dump(results, open(args.out, "w"), indent=1, default=float)
    print(f"\nwritten → {args.out}")

    if args.verify:
        import subprocess
        import sys
        import textwrap

        plans = {
            "A": ("stablelm-1.6b", "train_4k",
                  dict(n_nodes=64, tp_degree=4, microbatch=1)),
            "B": ("deepseek-v2-236b", "train_4k",
                  dict(tp_degree=pb.tp_degree, microbatch=pb.microbatch,
                       chunked_ce=1024)),
            "C": ("llama4-scout-17b-a16e", "decode_32k", dict(n_nodes=1)),
            "D": ("gemma2-27b", "train_4k",
                  dict(tp_degree=2, chunked_ce=1024)),
        }
        for tag, (arch, shape, overrides) in plans.items():
            code = textwrap.dedent(f"""
                import dataclasses
                from repro.launch.dryrun import dry_run_pair
                from repro.configs.registry import get_parallel
                p = dataclasses.replace(get_parallel({arch!r}), **{overrides!r})
                r = dry_run_pair({arch!r}, {shape!r}, False, pcfg=p)
                print("VERIFY_OK", {tag!r}, r["compile_s"], "s")
            """)
            out = subprocess.run([sys.executable, "-c", code],
                                 env=dict(os.environ, PYTHONPATH="src"),
                                 capture_output=True, text=True, timeout=900)
            ok = "VERIFY_OK" in out.stdout
            print(f"verify {tag}: {'COMPILED' if ok else 'FAILED'}")
            if not ok:
                print(out.stderr[-1500:])


if __name__ == "__main__":
    main()
