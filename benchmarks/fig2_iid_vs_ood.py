"""Paper Fig. 2 — IID vs OOD knowledge propagation gap.

Claim: for every *baseline* (topology-unaware) strategy, OOD test AUC is
substantially below IID test AUC (OOD knowledge propagates worse), across
BA topologies.  OOD placed on the 4th-highest-degree node as in the paper.

Expressed as a declarative cell grid over the batched sweep engine
(``benchmarks.common.run_sweep_cells``); the whole figure is one compiled
program per dataset.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import QUICK, SweepCell, csv_row, run_sweep_cells
from repro.core.topology import barabasi_albert

STRATEGIES = ("fl", "weighted", "unweighted", "random")


def cells(datasets=("mnist",), ba_p=(2,), n_nodes=16,
          seeds=(0,)) -> List[SweepCell]:
    return [
        SweepCell(ds, barabasi_albert(n_nodes, p, seed=seed), strat,
                  ood_k=4, seed=seed,
                  name=f"fig2/{ds}/ba_p{p}/{strat}")
        for ds in datasets
        for p in ba_p
        for seed in seeds
        for strat in STRATEGIES
    ]


def run(datasets=("mnist",), ba_p=(2,), n_nodes=16, seeds=(0,),
        scale=QUICK, log=print) -> List[dict]:
    grid = cells(datasets, ba_p, n_nodes, seeds)
    rows = run_sweep_cells(grid, scale=scale)
    for cell, r in zip(grid, rows):
        log(csv_row(
            cell.label, r["secs"],
            f"iid_auc={r['iid_auc']:.3f};ood_auc={r['ood_auc']:.3f};"
            f"gap_pct={r['iid_ood_gap_pct']:.1f}"))
    return rows


def verdict(rows) -> str:
    """Paper claim: OOD AUC < IID AUC for baselines."""
    ok = sum(1 for r in rows if r["ood_auc"] < r["iid_auc"])
    return (f"fig2 claim (OOD propagates worse than IID under baselines): "
            f"{ok}/{len(rows)} cells consistent")


if __name__ == "__main__":
    rows = run()
    print(verdict(rows))
