"""Paper Fig. 2 — IID vs OOD knowledge propagation gap.

Claim: for every *baseline* (topology-unaware) strategy, OOD test AUC is
substantially below IID test AUC (OOD knowledge propagates worse), across
BA topologies.  OOD placed on the 4th-highest-degree node as in the paper.
"""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import QUICK, csv_row, run_experiment
from repro.core.topology import barabasi_albert


def run(datasets=("mnist",), ba_p=(2,), n_nodes=16, seeds=(0,),
        scale=QUICK, log=print) -> List[dict]:
    rows = []
    for ds in datasets:
        for p in ba_p:
            for seed in seeds:
                topo = barabasi_albert(n_nodes, p, seed=seed)
                for strat in ("fl", "weighted", "unweighted", "random"):
                    r = run_experiment(ds, topo, strat, ood_k=4, seed=seed,
                                       scale=scale)
                    gap = r["iid_ood_gap_pct"]
                    log(csv_row(
                        f"fig2/{ds}/ba_p{p}/{strat}", r["secs"],
                        f"iid_auc={r['iid_auc']:.3f};ood_auc={r['ood_auc']:.3f};"
                        f"gap_pct={gap:.1f}"))
                    rows.append(r)
    return rows


def verdict(rows) -> str:
    """Paper claim: OOD AUC < IID AUC for baselines."""
    ok = sum(1 for r in rows if r["ood_auc"] < r["iid_auc"])
    return (f"fig2 claim (OOD propagates worse than IID under baselines): "
            f"{ok}/{len(rows)} cells consistent")


if __name__ == "__main__":
    rows = run()
    print(verdict(rows))
