"""Paper Fig. 6 / Fig. 19 — impact of topology degree, modularity, node count.

Claims:
  (a) BA degree parameter p ↑ ⇒ OOD AUC ↑ (denser scale-free ⇒ better);
  (b) SB modularity ↑ ⇒ OOD AUC ↓ (tight communities trap knowledge);
  (c) topology-aware ≥ topology-unaware across all of the above;
  (d) node count hurts unaware strategies on BA more than aware ones.

Expressed as declarative cell grids over the batched sweep engine.
Topology variations are just different (R, n, n) coefficient stacks, so
each same-n sub-sweep is one compiled program (the node-count sweep
compiles one program per n).
"""
from __future__ import annotations

from typing import List

from benchmarks.common import QUICK, SweepCell, csv_row, run_sweep_cells
from repro.core.topology import barabasi_albert, stochastic_block, watts_strogatz


def degree_cells(datasets=("mnist",), seeds=(0,)) -> List[SweepCell]:
    return [
        SweepCell(ds, barabasi_albert(16, p, seed=seed), strat,
                  ood_k=1, seed=seed, sweep=("degree", p),
                  name=f"fig6/degree/{ds}/ba_p{p}/{strat}")
        for ds in datasets
        for seed in seeds
        for p in (1, 2, 3)
        for strat in ("unweighted", "degree")
    ]


def modularity_cells(datasets=("mnist",), seeds=(0,)) -> List[SweepCell]:
    out = []
    for ds in datasets:
        for seed in seeds:
            for p_out in (0.009, 0.05, 0.9):
                topo = stochastic_block(16, 3, 0.5, p_out, seed=seed)
                mod = topo.modularity()
                for strat in ("unweighted", "degree"):
                    out.append(SweepCell(
                        ds, topo, strat, ood_k=1, seed=seed,
                        sweep=("modularity", mod),
                        name=f"fig6/modularity/{ds}/pout{p_out}/{strat}"))
    return out


def nodecount_cells(datasets=("mnist",), seeds=(0,)) -> List[SweepCell]:
    return [
        SweepCell(ds, topo, strat, ood_k=4, seed=seed,
                  sweep=("nodecount", fam, n),
                  name=f"fig6/nodes/{ds}/{fam}_n{n}/{strat}")
        for ds in datasets
        for seed in seeds
        for n in (8, 16, 24)
        for fam, topo in (("ba", barabasi_albert(n, 2, seed=seed)),
                          ("ws", watts_strogatz(n, 4, 0.5, seed=seed)))
        for strat in ("unweighted", "degree")
    ]


def _run_cells(grid, scale, log, derived) -> List[dict]:
    rows = run_sweep_cells(grid, scale=scale)
    for cell, r in zip(grid, rows):
        log(csv_row(cell.label, r["secs"], derived(r)))
    return rows


def run_degree(datasets=("mnist",), seeds=(0,), scale=QUICK, log=print):
    return _run_cells(degree_cells(datasets, seeds), scale, log,
                      lambda r: f"ood_auc={r['ood_auc']:.3f}")


def run_modularity(datasets=("mnist",), seeds=(0,), scale=QUICK, log=print):
    return _run_cells(
        modularity_cells(datasets, seeds), scale, log,
        lambda r: f"ood_auc={r['ood_auc']:.3f};mod={r['sweep'][1]:.2f}")


def run_nodecount(datasets=("mnist",), seeds=(0,), scale=QUICK, log=print):
    return _run_cells(nodecount_cells(datasets, seeds), scale, log,
                      lambda r: f"ood_auc={r['ood_auc']:.3f}")


def verdict(deg_rows, mod_rows) -> str:
    import numpy as np

    def trend(rows, key_idx, strat, xmin=None):
        pts = sorted((r["sweep"][key_idx], r["ood_auc"])
                     for r in rows if r["strategy"] == strat
                     and (xmin is None or r["sweep"][key_idx] > xmin))
        if len(pts) < 2:
            return 0.0
        xs, ys = zip(*pts)
        return float(np.corrcoef(xs, ys)[0, 1])

    d_corr = trend(deg_rows, 1, "degree")
    # modularity claim is over *modular* topologies; the near-complete
    # pout=0.9 graph (mod≈0.05) is dilution-dominated at n=16 and reported
    # separately in the JSON.
    m_corr = trend(mod_rows, 1, "degree", xmin=0.1)
    aware = np.mean([r["ood_auc"] for r in deg_rows + mod_rows
                     if r["strategy"] == "degree"])
    unaware = np.mean([r["ood_auc"] for r in deg_rows + mod_rows
                       if r["strategy"] == "unweighted"])
    arrivals = [r["analytics"]["ood_arrival_mean"]
                for r in deg_rows + mod_rows
                if r.get("analytics", {}).get("ood_arrival_mean")
                is not None]
    arrival_txt = (f", mean OOD arrival round {np.mean(arrivals):.1f} "
                   f"({len(arrivals)}/{len(deg_rows + mod_rows)} cells "
                   f"reached threshold)" if arrivals else "")
    return (f"fig6 claims: degree-param corr {d_corr:+.2f} (paper: +), "
            f"modularity corr {m_corr:+.2f} (paper: −), "
            f"aware {aware:.3f} vs unaware {unaware:.3f} "
            f"({'aware ≥ unaware ✓' if aware >= unaware - 0.02 else 'X'})"
            f"{arrival_txt}")


if __name__ == "__main__":
    d = run_degree()
    m = run_modularity()
    print(verdict(d, m))
