"""Fleet serving benchmark — continuous batching over gossip-trained planes.

The paper's deployment mode is per-device inference from each node's own
gossip-trained weights (no global model), so the serving hot path is a
fleet of per-node continuous-batching schedulers.  This benchmark drives
:class:`repro.serving.scheduler.FleetScheduler` with a seeded
request-generator workload — Poisson-ish arrivals × a prompt-length mix ×
round-robin per-node routing — and reports

* p50/p95/p99 request latency (submit → done, wall-clock),
* decode throughput (generated tokens per second),
* mean slot occupancy (active slots / total slots per step),

for the fleet-vmapped path (ONE compiled dispatch advances all n nodes'
slot batches) against the per-node Python-loop baseline (n dispatches per
step), at two or more fleet sizes.  The comparison gates on an internal
equivalence check: greedy outputs must be token-identical between the two
paths, and a model swap mid-workload must not re-trace the fleet step.

Results land in ``benchmarks/artifacts/BENCH_serve.json`` (a tracked
artifact — the serving counterpart of BENCH_sweep.json):

  PYTHONPATH=src python -m benchmarks.serve_bench --smoke
  PYTHONPATH=src python -m benchmarks.serve_bench --fleets 2,4,8 \\
      --requests 64 --slots 4
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig

__all__ = ["ServeWorkload", "gen_requests", "run_fleet", "main"]

# small dense config — the decode step's op mix is representative while
# keeping CI wall-clock in seconds (same shape family as tests)
BENCH_CFG = ModelConfig(name="serve-bench", n_layers=2, d_model=64,
                        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
                        dtype="float32", param_dtype="float32")


@dataclasses.dataclass(frozen=True)
class ServeWorkload:
    """Seeded request-generator parameters.

    Arrivals follow a geometric inter-arrival process measured in
    scheduler steps (the discrete-time analogue of Poisson arrivals);
    prompt lengths and generation budgets are drawn from small mixes so
    slots churn at different times (the continuous-batching case).
    """

    n_requests: int = 32
    arrival_p: float = 1.0          # P(new request per step candidate);
    #                                 1.0 = closed-loop burst (saturation)
    prompt_lens: tuple = (4, 8, 16)
    prompt_mix: tuple = (0.5, 0.3, 0.2)
    max_new: tuple = (4, 8, 16)
    max_new_mix: tuple = (0.4, 0.4, 0.2)
    seed: int = 0


def gen_requests(work: ServeWorkload, vocab: int):
    """[(arrival_step, prompt, max_new)] — deterministic in ``work.seed``."""
    rng = np.random.default_rng(work.seed)
    out, step = [], 0
    for _ in range(work.n_requests):
        while rng.random() > work.arrival_p:
            step += 1  # geometric inter-arrival gap; p=1.0 → burst at t=0
        plen = int(rng.choice(work.prompt_lens, p=work.prompt_mix))
        prompt = rng.integers(1, vocab, size=plen).tolist()
        max_new = int(rng.choice(work.max_new, p=work.max_new_mix))
        out.append((step, prompt, max_new))
    return out


def _percentiles(xs: List[float]) -> Dict[str, float]:
    arr = np.asarray(xs, float) * 1e3  # → ms
    return {f"p{p}_ms": round(float(np.percentile(arr, p)), 2)
            for p in (50, 95, 99)}


def run_fleet(cfg: ModelConfig, stacked_params, n_nodes: int,
              work: ServeWorkload, n_slots: int, max_seq: int,
              prefill_chunk: int, vmapped: bool,
              warmup: bool = True, repeats: int = 3) -> Dict:
    """Drive one scheduler mode through the workload ``repeats`` times
    (median wall-clock repeat reported — per-run walls are tens of ms);
    returns metrics + per-request outputs (for the cross-mode
    equivalence gate)."""
    from repro.serving.scheduler import FleetScheduler, Request

    fleet = FleetScheduler(cfg, stacked_params, n_nodes=n_nodes,
                           n_slots=n_slots, max_seq=max_seq,
                           prefill_chunk=prefill_chunk, vmapped=vmapped)
    schedule = gen_requests(work, cfg.vocab_size)
    if warmup:
        # compile every dispatch shape on every node before measuring:
        # a multi-chunk prompt forces the (B, chunk) call and a
        # generation budget past the chunk forces the (B, 1) pure-decode
        # call (self-feed can otherwise finish a short request in-chunk
        # and leave a node's decode shape cold until mid-measurement)
        for i in range(n_nodes):
            fleet.submit(Request(rid=-1 - i, prompt=[1] * (prefill_chunk + 2),
                                 max_new=prefill_chunk + 2), node=i)
        fleet.run_until_drained()

    total_slots = n_nodes * n_slots
    runs = []
    for _ in range(repeats):
        reqs = [Request(rid=i, prompt=list(p), max_new=m)
                for i, (_, p, m) in enumerate(schedule)]
        submit_t = {}
        done_t = {}
        occupancy = []
        pending = list(zip([s for s, _, _ in schedule], reqs))
        t_start = time.time()
        step = 0
        guard = 100_000
        while (pending or fleet.active or fleet.queued) and step < guard:
            while pending and pending[0][0] <= step:
                _, req = pending.pop(0)
                fleet.submit(req)
                submit_t[req.rid] = time.time()
            fleet.step()
            now = time.time()
            occupancy.append(fleet.active / total_slots)
            for req in reqs:
                if req.done and req.rid not in done_t:
                    done_t[req.rid] = now
            step += 1
        wall = time.time() - t_start
        assert all(r.done for r in reqs), "workload did not drain"
        gen_tokens = sum(len(r.output) for r in reqs)
        lat = [done_t[r.rid] - submit_t[r.rid] for r in reqs]
        metrics = {
            "mode": "fleet-vmapped" if vmapped else "per-node-loop",
            "requests": len(reqs),
            "repeats": repeats,
            "steps": step,
            "wall_secs": round(wall, 4),
            "generated_tokens": gen_tokens,
            "tokens_per_sec": round(gen_tokens / max(wall, 1e-9), 1),
            "mean_slot_occupancy": round(float(np.mean(occupancy)), 3),
            **_percentiles(lat),
        }
        runs.append({"wall": wall, "metrics": metrics,
                     "outputs": {r.rid: list(r.output) for r in reqs}})
    runs.sort(key=lambda r: r["wall"])
    med = runs[len(runs) // 2]
    assert all(r["outputs"] == med["outputs"] for r in runs), \
        "greedy decode must be deterministic across repeats"
    return {"metrics": med["metrics"], "outputs": med["outputs"],
            "fleet": fleet}


def bench_fleet_size(n_nodes: int, work: ServeWorkload, n_slots: int,
                     max_seq: int, prefill_chunk: int, seed: int) -> Dict:
    """One fleet size: vmapped vs looped on the identical workload, plus
    the no-re-jit model-swap check on the vmapped scheduler."""
    import jax

    from repro.models.transformer import init_params

    cfg = BENCH_CFG
    stacked = jax.vmap(lambda k: init_params(k, cfg))(
        jax.random.split(jax.random.key(seed), n_nodes))
    vm = run_fleet(cfg, stacked, n_nodes, work, n_slots, max_seq,
                   prefill_chunk, vmapped=True)
    lp = run_fleet(cfg, stacked, n_nodes, work, n_slots, max_seq,
                   prefill_chunk, vmapped=False)
    identical = vm["outputs"] == lp["outputs"]

    # post-gossip model swap: a plane row write must re-enter the cached
    # executables (trace counters frozen) and still drain correctly
    fleet = vm["fleet"]
    traces_before = (fleet.decode_traces, fleet.prefill_traces)
    fleet.swap_node(0, init_params(jax.random.key(seed + 777), cfg))
    from repro.serving.scheduler import Request

    probe = [Request(rid=10_000 + i, prompt=[3, 5, 7], max_new=4)
             for i in range(2 * n_nodes)]
    for r in probe:
        fleet.submit(r)
    fleet.run_until_drained()
    no_rejit = (fleet.decode_traces, fleet.prefill_traces) == traces_before
    speedup = (lp["metrics"]["wall_secs"]
               / max(vm["metrics"]["wall_secs"], 1e-9))
    return {
        "n_nodes": n_nodes,
        "n_slots": n_slots,
        "max_seq": max_seq,
        "prefill_chunk": prefill_chunk,
        "fleet_vmapped": vm["metrics"],
        "per_node_loop": lp["metrics"],
        "vmapped_speedup": round(speedup, 3),
        "outputs_identical": bool(identical),
        "swap_no_rejit": bool(no_rejit and all(r.done for r in probe)),
    }


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--fleets", default="2,4",
                    help="comma list of fleet sizes (n nodes)")
    ap.add_argument("--requests", type=int, default=24,
                    help="requests PER NODE (offered load scales with "
                         "fleet capacity, as in serving benchmarks)")
    ap.add_argument("--slots", type=int, default=2,
                    help="decode slots per node")
    ap.add_argument("--max-seq", type=int, default=48)
    ap.add_argument("--prefill-chunk", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="few requests (CI wall-clock in seconds)")
    ap.add_argument("--out", default="benchmarks/artifacts")
    args = ap.parse_args(argv)

    fleets = sorted({int(f) for f in args.fleets.split(",")})
    if len(fleets) < 2:
        raise SystemExit("--fleets needs ≥ 2 sizes (the BENCH record "
                         "compares scaling)")
    per_node = 16 if args.smoke else args.requests

    results = []
    ok = True
    for n in fleets:
        t0 = time.time()
        work = ServeWorkload(n_requests=per_node * n, seed=args.seed)
        r = bench_fleet_size(n, work, args.slots, args.max_seq,
                             args.prefill_chunk, args.seed)
        results.append(r)
        ok &= r["outputs_identical"] and r["swap_no_rejit"]
        vm, lp = r["fleet_vmapped"], r["per_node_loop"]
        print(f"fleet n={n}: vmapped {vm['wall_secs']}s "
              f"({vm['tokens_per_sec']} tok/s, p50 {vm['p50_ms']}ms, "
              f"p95 {vm['p95_ms']}ms, p99 {vm['p99_ms']}ms, "
              f"occ {vm['mean_slot_occupancy']}) vs loop "
              f"{lp['wall_secs']}s → speedup {r['vmapped_speedup']}× "
              f"[outputs identical: {r['outputs_identical']}, "
              f"swap no-re-jit: {r['swap_no_rejit']}] "
              f"({time.time() - t0:.0f}s total)")

    payload = {
        "config": {
            "model": BENCH_CFG.name,
            "n_layers": BENCH_CFG.n_layers,
            "d_model": BENCH_CFG.d_model,
            "vocab_size": BENCH_CFG.vocab_size,
            "requests_per_node": per_node,
            "workload": dataclasses.asdict(
                dataclasses.replace(work, n_requests=per_node)),
        },
        "fleets": results,
        "all_checks_passed": bool(ok),
    }
    os.makedirs(args.out, exist_ok=True)
    path = f"{args.out}/BENCH_serve.json"
    json.dump(payload, open(path, "w"), indent=1)
    print(f"\nserving record → {path}")
    if not ok:
        print("EQUIVALENCE CHECK FAILED: fleet-vmapped and per-node-loop "
              "decode disagree, or a model swap re-traced the fleet step")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
