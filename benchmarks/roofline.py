"""Roofline analysis (deliverable g).

Three terms per (arch × shape × mesh), in seconds per step:

  t_compute    = FLOPs_chip / 197e12        (bf16 MXU peak)
  t_memory     = HBM_bytes_chip / 819e9
  t_collective = ICI_bytes_chip / 50e9

**Methodology.**  ``compiled.cost_analysis()`` does NOT multiply while-loop
trip counts (XLA HloCostAnalysis visits a loop body once), and this
framework deliberately lowers with ``lax.scan`` over layers / microbatches
/ attention chunks to keep HLO size O(1) in depth.  The raw compiled
numbers recorded by the dry-run therefore undercount by the trip counts.
We instead derive each term ANALYTICALLY from the architecture, shape and
sharding plan — the formulas below — and validate the analytic model
against ``cost_analysis()`` on loop-free (unscanned, micro=1, 2-layer)
variants where HLO counting is exact (tests/test_roofline.py).

The dominant term, MODEL_FLOPS = 6·N_active·D, the useful-flops ratio, and
the HBM-fit check are reported per pair; benchmarks/run.py prints the
table and EXPERIMENTS.md §Roofline snapshots it.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Optional

from repro.configs.base import ModelConfig, ParallelConfig, SHAPES, InputShape
from repro.configs.registry import ARCHS, get_config, get_parallel
from repro.launch.specs import LONG_CTX_SKIP

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_PER_CHIP = 16 * 1024**3

POD_DATA, POD_MODEL = 16, 16


@dataclasses.dataclass
class Plan:
    """Resolved parallel plan for one pair (mirrors launch/specs.py)."""
    n_global: int          # gossip nodes across the job
    fsdp: int
    model: int = POD_MODEL
    pods: int = 1
    micro: int = 1
    local_batch: int = 0   # sequences per node (train/prefill) or per-node decode batch

    @property
    def chips(self) -> int:
        return self.pods * POD_DATA * POD_MODEL

    @property
    def mb(self) -> int:   # sequences per microbatch per node
        return max(1, self.local_batch // self.micro)


def resolve_plan(cfg: ModelConfig, pcfg: ParallelConfig, shape: InputShape,
                 multi_pod: bool) -> Plan:
    pods = 2 if multi_pod else 1
    n_global = pods * pcfg.n_nodes
    fsdp, tp = pcfg.fsdp, pcfg.tp_degree
    if shape.kind == "train":
        local = shape.global_batch // n_global
        micro = max(1, min(pcfg.microbatch, local))
        while micro > 1 and (local % micro or (local // micro) % fsdp):
            micro -= 1
        return Plan(n_global, fsdp, tp, pods, micro, local)
    if shape.name == "long_500k":
        return Plan(1, fsdp, tp, pods, 1, 1)
    local = max(1, shape.global_batch // n_global)
    return Plan(n_global, fsdp, tp, pods, 1, local)


# ----------------------------------------------------------------------
# FLOPs
# ----------------------------------------------------------------------
def _attn_ctx(seq: int, window: int) -> float:
    """Mean attended context per query under causal (+optional window)."""
    full = (seq + 1) / 2
    return min(full, window) if window > 0 else full


def attention_flops(cfg: ModelConfig, batch: int, seq: int,
                    decode_ctx: Optional[int] = None) -> float:
    """Softmax-attention core FLOPs (QKᵀ + PV), forward, all layers."""
    if cfg.family == "ssm":
        hd = cfg.rwkv_head_dim
        h = cfg.d_model // hd
        # state update (outer product + decay) + readout per step per head:
        per_tok = h * hd * hd * 6
        return cfg.n_layers * batch * seq * per_tok
    total = 0.0
    kinds = cfg.layer_kinds()
    for k in kinds:
        w = cfg.window_size if k == "local" else 0
        ctx = _attn_ctx(seq, w) if decode_ctx is None else (
            min(decode_ctx, w) if w else decode_ctx)
        if cfg.use_mla:
            r, dr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
            per = 2 * cfg.n_heads * ctx * (r + dr) + 2 * cfg.n_heads * ctx * r
        else:
            per = 4 * cfg.n_heads * ctx * cfg.head_dim_
        total += batch * seq * per
        if cfg.hybrid_ssm:
            di = cfg.ssm_expand * cfg.d_model
            total += batch * seq * di * cfg.ssm_state_dim * 6
    return total


def step_flops(cfg: ModelConfig, shape: InputShape, plan: Plan) -> Dict[str, float]:
    """Global FLOPs per step (train: fwd+bwd; prefill/decode: fwd)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        matmul = 6 * n_active * tokens
        attn = 3 * attention_flops(cfg, shape.global_batch, shape.seq_len)
        gossip = 2 * plan.n_global ** 2 * cfg.param_count()
        total = matmul + attn + gossip
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        matmul = 2 * n_active * tokens
        attn = attention_flops(cfg, shape.global_batch, shape.seq_len)
        total = matmul + attn
    else:  # decode: ONE token per sequence
        tokens = shape.global_batch
        matmul = 2 * n_active * tokens
        attn = attention_flops(cfg, shape.global_batch, 1,
                               decode_ctx=shape.seq_len)
        total = matmul + attn
    return dict(total=total, per_chip=total / plan.chips,
                model_flops=(6 if shape.kind == "train" else 2) * n_active * (
                    shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)))


# ----------------------------------------------------------------------
# HBM bytes
# ----------------------------------------------------------------------
def step_hbm_bytes(cfg: ModelConfig, pcfg: ParallelConfig, shape: InputShape,
                   plan: Plan) -> Dict[str, float]:
    """Per-chip HBM traffic per step (documented estimator).

    Weights: each microbatch streams W twice (fwd + bwd reads, bf16) and
    accumulates an f32 grad (rw); the optimizer pass reads g, rw the two
    moments, rw the param.  Per replica the weight shard is P/(fsdp·model)
    params.  Decode/prefill: single bf16 read per step.
    Activations: per layer boundary tensor (mb·S·d bf16) written in fwd,
    re-read + recomputed in bwd (remat ⇒ ×3 traffic factor).
    Logits: mb·S·V f32 fwd+bwd (or streamed — same bytes — for chunked CE).
    KV cache (decode): full cache read per token + one slot write.
    """
    p_total = cfg.param_count()
    p_active = cfg.active_param_count()
    shard = plan.fsdp * plan.model
    p_chip = p_total / shard          # weight params resident per chip
    d, v, L = cfg.d_model, cfg.vocab_size, cfg.n_layers
    s = shape.seq_len

    opt_bytes = 4 if pcfg.opt_dtype == "float32" else 2

    if shape.kind == "train":
        m = plan.micro
        w = p_chip * (m * (2 + 2)          # fwd + bwd bf16 reads per micro
                      + m * 8              # f32 grad accumulator rw
                      + 4 + 2 * 2 * opt_bytes + 4 + 2)   # opt pass
        mb_tokens = plan.mb * s / plan.fsdp / 1.0   # per-chip share of batch
        act = 3 * 2 * L * mb_tokens * d * m / plan.model * plan.model  # bf16 ×3 traffic
        act = 3 * 2 * L * mb_tokens * d * m          # residual stream traffic
        logits = 8 * mb_tokens * (v / plan.model) * m
        gossip = 2 * p_chip * 2 * plan.n_global      # all-gather read+write f32-ish
        total = w + act + logits + gossip
    elif shape.kind == "prefill":
        tokens_chip = plan.local_batch * s / plan.fsdp
        w = 2 * p_chip
        act = 2 * 2 * L * tokens_chip * d
        total = w + act
    else:
        # decode: weight streaming + cache read.  For MoE the bytes are the
        # *touched* expert set per step: with T tokens per replica routing
        # top-k, E[experts touched] ≈ E·(1 − (1−k/E)^T) — at small per-
        # replica batch only a few experts stream; at large batch all do.
        if cfg.is_moe:
            t_rep = max(1, plan.local_batch)
            e, k = cfg.n_experts, cfg.experts_per_token
            touched = e * (1.0 - (1.0 - k / e) ** t_rep)
            gates = 3 if cfg.mlp_kind in ("swiglu", "geglu") else 2
            fe = cfg.moe_d_ff_
            expert_p = (cfg.n_layers - cfg.first_k_dense) * gates * d * fe
            dense_p = p_total - cfg.n_experts * expert_p
            streamed = dense_p + touched * expert_p
            w = 2 * streamed / shard
        else:
            w = 2 * (p_active / shard)
        cache = cache_bytes(cfg, shape, plan)["per_chip"]
        total = w + cache
    return dict(per_chip=total)


def cache_bytes(cfg: ModelConfig, shape: InputShape, plan: Plan) -> Dict[str, float]:
    """KV/state cache size (resident + read per decode step)."""
    b = shape.global_batch
    t = shape.seq_len
    if cfg.family == "ssm":
        hd = cfg.rwkv_head_dim
        h = cfg.d_model // hd
        total = cfg.n_layers * b * (h * hd * hd * 4 + 2 * cfg.d_model * 2)
    elif cfg.use_mla:
        total = cfg.n_layers * b * t * (cfg.kv_lora_rank + cfg.qk_rope_head_dim) * 2
    else:
        kinds = cfg.layer_kinds()
        per_layer = 0
        for k in kinds:
            tl = min(t, cfg.window_size) if k == "local" else t
            per_layer += 2 * tl * cfg.n_kv_heads * cfg.head_dim_ * 2
        total = b * per_layer
        if cfg.hybrid_ssm:
            di = cfg.ssm_expand * cfg.d_model
            total += cfg.n_layers * b * (di * cfg.ssm_state_dim * 4 +
                                         (cfg.ssm_conv_dim - 1) * di * 2)
    return dict(total=total, per_chip=total / plan.chips)


# ----------------------------------------------------------------------
# ICI collective bytes
# ----------------------------------------------------------------------
def step_collective_bytes(cfg: ModelConfig, pcfg: ParallelConfig,
                          shape: InputShape, plan: Plan,
                          gossip_schedule: str = "dense") -> Dict[str, float]:
    steps_per_round = max(1, pcfg.steps_per_round)
    """Per-chip ICI bytes per step.

    TP: 2 all-reduces (attn-out, mlp-out) per layer per microbatch of the
        residual (mb·S·d bf16); ring all-reduce moves 2·(m-1)/m · msg.
    FSDP: per-layer weight all-gather fwd+bwd ((f-1)/f · W_layer) + grad
        reduce-scatter.
    MoE: 2 all-to-alls per layer of the routed tokens ((E-1)/E ≈ 1).
    Gossip: dense = all-gather of the per-chip param shard across the
        node axis ((N-1) · P_chip); sparse = #offsets · P_chip.
    """
    p_total = cfg.param_count()
    shard = plan.fsdp * plan.model
    p_chip = p_total / shard
    d, L = cfg.d_model, cfg.n_layers
    s = shape.seq_len if shape.kind != "decode" else 1
    mdl, f, n = plan.model, plan.fsdp, plan.n_global

    if shape.kind == "train":
        m = plan.micro
        toks_chip = plan.mb * s / plan.fsdp
        fwd_bwd = 2  # fwd + bwd each all-reduce
        tp = fwd_bwd * 2 * L * m * toks_chip * d * 2 * (2 * (mdl - 1) / mdl)
        fsdp_b = (2 * m * p_chip * 2 * (f - 1)) + (p_chip * 4 * (f - 1) / f)
        moe = 0.0
        if cfg.is_moe:
            k_eff = (min(cfg.experts_per_token, pcfg.moe_group_limit)
                     if pcfg.moe_group_limit else cfg.experts_per_token)
            routed = toks_chip * k_eff * d * 2
            moe = 2 * 2 * (L - cfg.first_k_dense) * m * routed
        if gossip_schedule == "dense":
            gossip = (n - 1) * p_chip * 2 / steps_per_round
        else:
            from repro.core.topology import barabasi_albert
            from repro.core.strategies import AggregationStrategy, mixing_matrix
            from repro.core.mixing import circulant_decomposition
            topo = barabasi_albert(max(n, 3), min(2, max(n - 1, 1)), seed=0) \
                if n > 2 else None
            if topo is None:
                gossip = (n - 1) * p_chip * 2
            else:
                c = mixing_matrix(topo, AggregationStrategy("degree", tau=0.1))
                sched = circulant_decomposition(c)
                nonzero = sum(1 for o in sched.offsets if o != 0)
                gossip = nonzero * p_chip * 2 / steps_per_round
        pod = 0.0
        if plan.pods > 1:
            pod = p_chip * 2  # inter-pod exchange of the shard
        total = tp + fsdp_b + moe + gossip + pod
        parts = dict(tp=tp, fsdp=fsdp_b, moe=moe, gossip=gossip, pod=pod)
    elif shape.kind == "prefill":
        toks_chip = plan.local_batch * s / plan.fsdp
        tp = 2 * L * toks_chip * d * 2 * (2 * (mdl - 1) / mdl)
        # weights are 2-D sharded and consumed sharded in fwd-only steps
        # (verified against the dry-run HLO: no per-step weight all-gather);
        # the fsdp axis instead costs one activation reduce per layer.
        fsdp_b = (2 * L * toks_chip * d * 2 * (f - 1) / f) if f > 1 else 0.0
        moe = 0.0
        if cfg.is_moe:
            moe = 2 * (L - cfg.first_k_dense) * toks_chip * \
                cfg.experts_per_token * d * 2
        total = tp + fsdp_b + moe
        parts = dict(tp=tp, fsdp=fsdp_b, moe=moe)
    else:
        toks_chip = max(1.0, plan.local_batch / max(plan.fsdp, 1))
        tp = 2 * L * toks_chip * d * 2 * (2 * (mdl - 1) / mdl)
        fsdp_b = (2 * L * toks_chip * d * 2 * (f - 1) / f) if f > 1 else 0.0
        moe = 0.0
        if cfg.is_moe:
            moe = 2 * (L - cfg.first_k_dense) * toks_chip * \
                cfg.experts_per_token * d * 2
        total = tp + fsdp_b + moe
        parts = dict(tp=tp, fsdp=fsdp_b, moe=moe)
    return dict(per_chip=total, parts=parts)


# ----------------------------------------------------------------------
# HBM fit
# ----------------------------------------------------------------------
def hbm_resident_bytes(cfg: ModelConfig, pcfg: ParallelConfig,
                       shape: InputShape, plan: Plan) -> Dict[str, float]:
    p_total = cfg.param_count()
    shard = plan.fsdp * plan.model
    opt_bytes = 8 if pcfg.opt_dtype == "float32" else 4
    per_chip = p_total / shard * 2          # bf16 weights
    if shape.kind == "train":
        per_chip += p_total / shard * (opt_bytes + 4)   # moments + f32 grad acc
        act = 2 * cfg.n_layers * plan.mb * shape.seq_len * cfg.d_model / plan.fsdp
        per_chip += act
    if shape.kind == "decode":
        per_chip += cache_bytes(cfg, shape, plan)["per_chip"]
    return dict(per_chip=per_chip, fits=per_chip < HBM_PER_CHIP * 0.9)


# ----------------------------------------------------------------------
# full report
# ----------------------------------------------------------------------
def analyze_pair(arch: str, shape_name: str, multi_pod: bool = False,
                 gossip_schedule: Optional[str] = None,
                 cfg: Optional[ModelConfig] = None,
                 pcfg: Optional[ParallelConfig] = None) -> Dict:
    cfg = cfg or get_config(arch)
    pcfg = pcfg or get_parallel(arch)
    shape = SHAPES[shape_name]
    plan = resolve_plan(cfg, pcfg, shape, multi_pod)
    sched = gossip_schedule or pcfg.gossip_schedule

    fl = step_flops(cfg, shape, plan)
    hbm = step_hbm_bytes(cfg, pcfg, shape, plan)
    coll = step_collective_bytes(cfg, pcfg, shape, plan, sched)
    fit = hbm_resident_bytes(cfg, pcfg, shape, plan)

    t_c = fl["per_chip"] / PEAK_FLOPS
    t_m = hbm["per_chip"] / HBM_BW
    t_x = coll["per_chip"] / ICI_BW
    dominant = max([("compute", t_c), ("memory", t_m), ("collective", t_x)],
                   key=lambda kv: kv[1])[0]
    bound = max(t_c, t_m, t_x)
    return dict(
        arch=arch, shape=shape_name,
        mesh="2x16x16" if multi_pod else "16x16",
        kind=shape.kind, n_nodes=plan.n_global, fsdp=plan.fsdp,
        micro=plan.micro, gossip=sched,
        t_compute_s=t_c, t_memory_s=t_m, t_collective_s=t_x,
        dominant=dominant,
        roofline_frac=t_c / bound if bound else 0.0,  # compute fraction of bound
        model_flops=fl["model_flops"],
        hlo_flops_global=fl["total"],
        useful_flops_ratio=fl["model_flops"] / fl["total"],
        collective_parts=coll["parts"],
        hbm_resident_per_chip=fit["per_chip"], fits_hbm=fit["fits"],
    )


def full_table(multi_pod: bool = False):
    rows = []
    for arch in ARCHS:
        for name in SHAPES:
            if name == "long_500k" and arch in LONG_CTX_SKIP:
                rows.append(dict(arch=arch, shape=name,
                                 skipped=LONG_CTX_SKIP[arch]))
                continue
            rows.append(analyze_pair(arch, name, multi_pod))
    return rows


def format_table(rows) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'nodes':>5s} {'t_comp':>9s} "
           f"{'t_mem':>9s} {'t_coll':>9s} {'dom':>10s} {'useful':>7s} "
           f"{'HBM/chip':>9s} {'fits':>5s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if "skipped" in r:
            lines.append(f"{r['arch']:24s} {r['shape']:12s}  SKIP: {r['skipped']}")
            continue
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['n_nodes']:5d} "
            f"{r['t_compute_s']:9.2e} {r['t_memory_s']:9.2e} "
            f"{r['t_collective_s']:9.2e} {r['dominant']:>10s} "
            f"{r['useful_flops_ratio']:7.2f} "
            f"{r['hbm_resident_per_chip']/1e9:8.2f}G "
            f"{'yes' if r['fits_hbm'] else 'NO':>5s}")
    return "\n".join(lines)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--multipod", action="store_true")
    args = ap.parse_args()
    rows = full_table(multi_pod=args.multipod)
    print(format_table(rows))
    tag = "2pod" if args.multipod else "1pod"
    out = f"benchmarks/artifacts/roofline_{tag}.json"
    os.makedirs(os.path.dirname(out), exist_ok=True)
    json.dump(rows, open(out, "w"), indent=1, default=float)
    print(f"\nwritten → {out}")


if __name__ == "__main__":
    main()
