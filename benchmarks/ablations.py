"""Beyond-paper ablations:

1. **Centrality-metric zoo** — the paper proposes Degree (local) and
   Betweenness (global) and names further metrics as future work (§7).
   We add eigenvector, PageRank and closeness and compare all five (+
   unweighted control) at the paper's headline setting.
2. **τ sensitivity** — the paper fixes τ=0.1; we sweep τ to characterize
   the sharpness/robustness trade-off (τ→0: winner-take-all erases the
   source's own knowledge; τ→∞: converges to unweighted).
3. **Link-failure robustness** — strategies under i.i.d. per-round edge
   dropout, the unstable-WAN regime the paper motivates but does not
   measure.  Runs IN-SCAN by default: device-side coefficient programs
   (`repro.core.coeffs`, DESIGN.md §9) regenerate the edge mask each
   round and — reactive mode — recompute centralities on the surviving
   subgraph inside the sweep engine's scan; the legacy host loop stays
   behind ``in_scan=False`` as the equivalence baseline.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import QUICK, csv_row, run_experiment
from repro.core.topology import barabasi_albert

CENTRALITIES = ("unweighted", "degree", "betweenness", "eigenvector",
                "pagerank", "closeness")


def run_centrality_zoo(dataset="mnist", seeds=(0,), scale=QUICK, log=print):
    rows = []
    for seed in seeds:
        topo = barabasi_albert(16, 2, seed=seed)
        for strat in CENTRALITIES:
            r = run_experiment(dataset, topo, strat, ood_k=1, seed=seed,
                               scale=scale)
            log(csv_row(f"ablation/centrality/{strat}", r["secs"],
                        f"iid_auc={r['iid_auc']:.3f};ood_auc={r['ood_auc']:.3f}"))
            rows.append(r)
    return rows


def run_tau_sweep(dataset="mnist", taus=(0.01, 0.05, 0.1, 0.5, 2.0),
                  seeds=(0,), scale=QUICK, log=print):
    rows = []
    for seed in seeds:
        topo = barabasi_albert(16, 2, seed=seed)
        for tau in taus:
            r = run_experiment(dataset, topo, "degree", ood_k=1, tau=tau,
                               seed=seed, scale=scale)
            r["tau"] = tau
            log(csv_row(f"ablation/tau/{tau}", r["secs"],
                        f"iid_auc={r['iid_auc']:.3f};ood_auc={r['ood_auc']:.3f}"))
            rows.append(r)
    return rows


def run_link_failure(dataset="mnist", p_fails=(0.0, 0.3, 0.6),
                     strategies=("unweighted", "degree"), seeds=(0,),
                     scale=QUICK, log=print, n_nodes=16, reactive=True,
                     in_scan=True):
    """Per-round i.i.d. edge dropout.

    Default path: IN-SCAN — each cell's coefficient program
    (``repro.core.coeffs``) regenerates the Bernoulli edge mask and
    (``reactive=True``) recomputes centralities on the surviving subgraph
    inside the sweep engine's round scan, so the whole grid is one
    compiled program and no ``(E, R, n, n)`` stack ever materializes.

    ``in_scan=False`` keeps the legacy host loop: a per-round
    ``DecentralizedTrainer`` consuming the SAME programs' matrices
    materialized host-side — bit-identical metrics to the in-scan path
    (asserted in tests/test_sweep_programs.py), kept as the equivalence
    baseline.
    """
    if in_scan:
        from benchmarks.common import linkfail_cells, run_sweep_cells

        cells = linkfail_cells(
            datasets=(dataset,), seeds=seeds, n_nodes=n_nodes,
            strategies=strategies, p_fails=p_fails, reactive=reactive,
            prefix="ablation/linkfail")
        rows = run_sweep_cells(cells, scale=scale, coeff_mode="program")
        for row, cell in zip(rows, cells):
            row.update(p_fail=cell.p_fail, reactive=cell.reactive)
            log(csv_row(cell.name, 0,
                        f"iid_auc={row['iid_auc']:.3f};"
                        f"ood_auc={row['ood_auc']:.3f}"))
        return rows

    # legacy host loop (equivalence baseline)
    from repro.core.coeffs import program_for
    from repro.core.decentralized import (
        DecentralizedConfig,
        DecentralizedTrainer,
        stack_params,
    )
    from repro.core.propagation import propagation_summary
    from repro.core.strategies import AggregationStrategy
    from repro.data.backdoor import backdoored_testset
    from repro.data.distribution import node_datasets
    from repro.data.pipeline import NodeBatcher, make_test_batch
    from repro.data.synthetic import make_dataset
    from repro.models.paper_models import (
        classifier_accuracy,
        classifier_loss,
        ffn_apply,
        ffn_init,
    )
    from repro.training.optimizer import sgd

    rows = []
    for seed in seeds:
        topo = barabasi_albert(n_nodes, 2, seed=seed)
        ood_node = topo.kth_highest_degree_node(1)
        train = make_dataset(dataset, scale.n_train, seed=seed)
        test = make_dataset(dataset, scale.n_test, seed=seed + 9999)
        parts = node_datasets(train, n_nodes, ood_node=ood_node, q=0.10,
                              seed=seed)
        nb = NodeBatcher(parts, batch_size=scale.batch,
                         steps_per_epoch=scale.steps_per_epoch, seed=seed,
                         local_epochs=scale.local_epochs)
        tb = jax.tree.map(jnp.asarray,
                          make_test_batch(test, scale.eval_n, seed=seed))
        ob = jax.tree.map(jnp.asarray,
                          make_test_batch(backdoored_testset(test, seed=seed),
                                          scale.eval_n, seed=seed))
        for strat in strategies:
            for pf in p_fails:
                sobj = AggregationStrategy(strat, tau=0.1, seed=seed)
                program, state = program_for(
                    topo, sobj, data_counts=nb.data_counts(),
                    p_fail=pf, reactive=reactive)
                coeffs_fn = lambda r, p=program, s=state: p.materialize(
                    s, round_indices=np.array([r]))[0]
                trainer = DecentralizedTrainer(
                    topo, sobj, sgd(1e-2),
                    classifier_loss(ffn_apply), classifier_accuracy(ffn_apply),
                    DecentralizedConfig(rounds=scale.rounds,
                                        local_epochs=scale.local_epochs,
                                        eval_every=scale.eval_every),
                    data_counts=nb.data_counts(), coeffs_fn=coeffs_fn)
                params = stack_params(
                    [ffn_init(jax.random.key(seed))] * n_nodes)
                _, hist = trainer.run(
                    params,
                    lambda r: jax.tree.map(jnp.asarray, nb.round_batches(r)),
                    tb, ob)
                s = propagation_summary(hist, topo.adjacency, ood_node)
                s.update(strategy=strat, p_fail=pf, seed=seed,
                         reactive=reactive)
                log(csv_row(f"ablation/linkfail/{strat}/p{pf}", 0,
                            f"iid_auc={s['iid_auc']:.3f};ood_auc={s['ood_auc']:.3f}"))
                rows.append(s)
    return rows


def run_heterogeneity(dataset="mnist", alphas=(1000.0, 1.0, 0.3),
                      strategies=("unweighted", "degree"), seeds=(0,),
                      scale=QUICK, log=print):
    """Non-IID label skew (paper Fig 8's α_l axis — shown but not swept in
    the paper's main experiments): does topology-aware aggregation survive
    when EVERY node is heterogeneous, not just the OOD one?"""
    rows = []
    for seed in seeds:
        topo = barabasi_albert(16, 2, seed=seed)
        for alpha in alphas:
            for strat in strategies:
                r = run_experiment(dataset, topo, strat, ood_k=1, seed=seed,
                                   scale=scale, alpha_l=alpha)
                r["alpha_l"] = alpha
                log(csv_row(f"ablation/noniid/a{alpha}/{strat}", r["secs"],
                            f"iid_auc={r['iid_auc']:.3f};ood_auc={r['ood_auc']:.3f}"))
                rows.append(r)
    return rows


if __name__ == "__main__":
    import json

    z = run_centrality_zoo()
    t = run_tau_sweep()
    f = run_link_failure()
    h = run_heterogeneity()
    json.dump(dict(centrality=z, tau=t, linkfail=f, heterogeneity=h),
              open("benchmarks/artifacts/ablations.json", "w"),
              indent=1, default=float)
