"""Beyond-paper ablations:

1. **Centrality-metric zoo** — the paper proposes Degree (local) and
   Betweenness (global) and names further metrics as future work (§7).
   We add eigenvector, PageRank and closeness and compare all five (+
   unweighted control) at the paper's headline setting.
2. **τ sensitivity** — the paper fixes τ=0.1; we sweep τ to characterize
   the sharpness/robustness trade-off (τ→0: winner-take-all erases the
   source's own knowledge; τ→∞: converges to unweighted).
3. **Link-failure robustness** — static-topology strategies under i.i.d.
   per-round edge dropout (`repro.core.dynamic`), the unstable-WAN regime
   the paper motivates but does not measure.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import QUICK, csv_row, run_experiment
from repro.core.topology import barabasi_albert

CENTRALITIES = ("unweighted", "degree", "betweenness", "eigenvector",
                "pagerank", "closeness")


def run_centrality_zoo(dataset="mnist", seeds=(0,), scale=QUICK, log=print):
    rows = []
    for seed in seeds:
        topo = barabasi_albert(16, 2, seed=seed)
        for strat in CENTRALITIES:
            r = run_experiment(dataset, topo, strat, ood_k=1, seed=seed,
                               scale=scale)
            log(csv_row(f"ablation/centrality/{strat}", r["secs"],
                        f"iid_auc={r['iid_auc']:.3f};ood_auc={r['ood_auc']:.3f}"))
            rows.append(r)
    return rows


def run_tau_sweep(dataset="mnist", taus=(0.01, 0.05, 0.1, 0.5, 2.0),
                  seeds=(0,), scale=QUICK, log=print):
    rows = []
    for seed in seeds:
        topo = barabasi_albert(16, 2, seed=seed)
        for tau in taus:
            r = run_experiment(dataset, topo, "degree", ood_k=1, tau=tau,
                               seed=seed, scale=scale)
            r["tau"] = tau
            log(csv_row(f"ablation/tau/{tau}", r["secs"],
                        f"iid_auc={r['iid_auc']:.3f};ood_auc={r['ood_auc']:.3f}"))
            rows.append(r)
    return rows


def run_link_failure(dataset="mnist", p_fails=(0.0, 0.3, 0.6),
                     strategies=("unweighted", "degree"), seeds=(0,),
                     scale=QUICK, log=print):
    """Per-round i.i.d. edge dropout; nominal-centrality coefficients
    renormalized over surviving links."""
    from repro.core.decentralized import (
        DecentralizedConfig,
        DecentralizedTrainer,
        stack_params,
    )
    from repro.core.dynamic import dynamic_mixing_matrix
    from repro.core.propagation import propagation_summary
    from repro.core.strategies import AggregationStrategy
    from repro.data.backdoor import backdoored_testset
    from repro.data.distribution import node_datasets
    from repro.data.pipeline import NodeBatcher, make_test_batch
    from repro.data.synthetic import make_dataset
    from repro.models.paper_models import (
        classifier_accuracy,
        classifier_loss,
        ffn_apply,
        ffn_init,
    )
    from repro.training.optimizer import sgd

    rows = []
    for seed in seeds:
        topo = barabasi_albert(16, 2, seed=seed)
        ood_node = topo.kth_highest_degree_node(1)
        train = make_dataset(dataset, scale.n_train, seed=seed)
        test = make_dataset(dataset, scale.n_test, seed=seed + 9999)
        parts = node_datasets(train, 16, ood_node=ood_node, q=0.10, seed=seed)
        nb = NodeBatcher(parts, batch_size=scale.batch,
                         steps_per_epoch=scale.steps_per_epoch, seed=seed,
                         local_epochs=scale.local_epochs)
        tb = jax.tree.map(jnp.asarray, make_test_batch(test, scale.eval_n))
        ob = jax.tree.map(jnp.asarray,
                          make_test_batch(backdoored_testset(test), scale.eval_n))
        for strat in strategies:
            for pf in p_fails:
                sobj = AggregationStrategy(strat, tau=0.1, seed=seed)
                coeffs_fn = (None if pf == 0.0 else (
                    lambda r, s=sobj, t=topo, p=pf, dc=nb.data_counts():
                    dynamic_mixing_matrix(t, s, r, p, data_counts=dc)))
                trainer = DecentralizedTrainer(
                    topo, sobj, sgd(1e-2),
                    classifier_loss(ffn_apply), classifier_accuracy(ffn_apply),
                    DecentralizedConfig(rounds=scale.rounds,
                                        local_epochs=scale.local_epochs,
                                        eval_every=scale.eval_every),
                    data_counts=nb.data_counts(), coeffs_fn=coeffs_fn)
                params = stack_params([ffn_init(jax.random.key(seed))] * 16)
                _, hist = trainer.run(
                    params,
                    lambda r: jax.tree.map(jnp.asarray, nb.round_batches(r)),
                    tb, ob)
                s = propagation_summary(hist, topo.adjacency, ood_node)
                s.update(strategy=strat, p_fail=pf, seed=seed)
                log(csv_row(f"ablation/linkfail/{strat}/p{pf}", 0,
                            f"iid_auc={s['iid_auc']:.3f};ood_auc={s['ood_auc']:.3f}"))
                rows.append(s)
    return rows


def run_heterogeneity(dataset="mnist", alphas=(1000.0, 1.0, 0.3),
                      strategies=("unweighted", "degree"), seeds=(0,),
                      scale=QUICK, log=print):
    """Non-IID label skew (paper Fig 8's α_l axis — shown but not swept in
    the paper's main experiments): does topology-aware aggregation survive
    when EVERY node is heterogeneous, not just the OOD one?"""
    rows = []
    for seed in seeds:
        topo = barabasi_albert(16, 2, seed=seed)
        for alpha in alphas:
            for strat in strategies:
                r = run_experiment(dataset, topo, strat, ood_k=1, seed=seed,
                                   scale=scale, alpha_l=alpha)
                r["alpha_l"] = alpha
                log(csv_row(f"ablation/noniid/a{alpha}/{strat}", r["secs"],
                            f"iid_auc={r['iid_auc']:.3f};ood_auc={r['ood_auc']:.3f}"))
                rows.append(r)
    return rows


if __name__ == "__main__":
    import json

    z = run_centrality_zoo()
    t = run_tau_sweep()
    f = run_link_failure()
    h = run_heterogeneity()
    json.dump(dict(centrality=z, tau=t, linkfail=f, heterogeneity=h),
              open("benchmarks/artifacts/ablations.json", "w"),
              indent=1, default=float)
