"""Benchmark entry point — one section per paper table/figure + the
roofline and gossip-cost tables.  ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run            # reduced (CPU) scale
  PYTHONPATH=src python -m benchmarks.run --full     # paper scale
  PYTHONPATH=src python -m benchmarks.run --only fig4,roofline
"""
from __future__ import annotations

import argparse
import json
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale runs")
    ap.add_argument("--only", default=None,
                    help="comma list: fig2,fig4,fig5,fig6,gossip,mix,"
                         "serve,roofline")
    ap.add_argument("--out", default="benchmarks/artifacts")
    args = ap.parse_args()

    from benchmarks.common import FULL, QUICK

    scale = FULL if args.full else QUICK
    datasets = (("mnist", "fmnist", "tinymem", "cifar10", "cifar100")
                if args.full else ("mnist", "fmnist"))
    seeds = (0, 1, 2) if args.full else (0,)
    n_nodes = 33 if args.full else 16
    sections = (args.only.split(",") if args.only
                else ["fig2", "fig4", "fig5", "fig6", "ablations",
                      "gossip", "mix", "serve", "roofline"])
    os.makedirs(args.out, exist_ok=True)
    verdicts = []
    t_start = time.time()

    print("name,us_per_call,derived")

    if "fig2" in sections:
        from benchmarks import fig2_iid_vs_ood as fig2

        rows = fig2.run(datasets=datasets[:2], ba_p=(2,), n_nodes=n_nodes,
                        seeds=seeds, scale=scale)
        verdicts.append(fig2.verdict(rows))
        json.dump(rows, open(f"{args.out}/fig2.json", "w"), indent=1,
                  default=float)

    if "fig4" in sections:
        from benchmarks import fig4_strategies as fig4

        rows = fig4.run(datasets=datasets[:2], ba_p=(1, 2) if args.full else (2,),
                        n_nodes=n_nodes, seeds=seeds, scale=scale)
        verdicts.append(fig4.verdict(rows))
        json.dump(rows, open(f"{args.out}/fig4.json", "w"), indent=1,
                  default=float)

    if "fig5" in sections:
        from benchmarks import fig5_location as fig5

        rows = fig5.run(datasets=datasets[:1], n_nodes=n_nodes, seeds=seeds,
                        scale=scale)
        verdicts.append(fig5.verdict(rows))
        json.dump(rows, open(f"{args.out}/fig5.json", "w"), indent=1,
                  default=float)

    if "fig6" in sections:
        from benchmarks import fig6_topology as fig6

        d = fig6.run_degree(datasets=datasets[:1], seeds=seeds, scale=scale)
        m = fig6.run_modularity(datasets=datasets[:1], seeds=seeds, scale=scale)
        if args.full:
            fig6.run_nodecount(datasets=datasets[:1], seeds=seeds, scale=scale)
        verdicts.append(fig6.verdict(d, m))
        json.dump(d + m, open(f"{args.out}/fig6.json", "w"), indent=1,
                  default=float)

    if "ablations" in sections:
        from benchmarks import ablations

        z = ablations.run_centrality_zoo(seeds=seeds, scale=scale)
        t = ablations.run_tau_sweep(seeds=seeds, scale=scale)
        f = ablations.run_link_failure(seeds=seeds, scale=scale)
        h = ablations.run_heterogeneity(seeds=seeds, scale=scale)
        import numpy as _np
        aware = [r for r in z if r["strategy"] != "unweighted"]
        verdicts.append(
            "ablations: all %d centrality metrics beat unweighted on OOD "
            "(%.3f–%.3f vs %.3f); τ≤0.1 plateau; degree OOD at 60%% link "
            "failure: %.3f" % (
                len(aware),
                min(r["ood_auc"] for r in aware),
                max(r["ood_auc"] for r in aware),
                next(r["ood_auc"] for r in z if r["strategy"] == "unweighted"),
                next((r["ood_auc"] for r in f
                      if r["strategy"] == "degree" and r["p_fail"] == 0.6), -1)))
        json.dump(dict(centrality=z, tau=t, linkfail=f, heterogeneity=h),
                  open(f"{args.out}/ablations.json", "w"), indent=1,
                  default=float)

    if "gossip" in sections:
        from benchmarks import gossip_cost

        rows = gossip_cost.run()
        json.dump(rows, open(f"{args.out}/gossip_cost.json", "w"), indent=1,
                  default=float)

    if "mix" in sections:
        from benchmarks import gossip_cost

        rec = gossip_cost.run_mix(smoke=not args.full,
                                  out_path=f"{args.out}/BENCH_mix.json")
        verdicts.append(
            "mix kernel: fused plane %s the legacy per-row path "
            "(wall %.1fx, modeled HBM bytes %.1fx; 1 pallas_call vs %d "
            "programs per mix)" % (
                "dominates" if rec["fused_vs_rows"]["dominates"]
                else "DOES NOT dominate",
                rec["fused_vs_rows"]["wall_speedup"],
                rec["fused_vs_rows"]["hbm_bytes_ratio"],
                rec["impls"]["pallas_rows"]["kernel_programs_per_mix"]))

    if "serve" in sections:
        from benchmarks import serve_bench

        code = serve_bench.main(
            ["--smoke", "--out", args.out] if not args.full
            else ["--fleets", "2,4,8", "--out", args.out])
        rec = json.load(open(f"{args.out}/BENCH_serve.json"))
        best = max(rec["fleets"], key=lambda f: f["vmapped_speedup"])
        verdicts.append(
            "serving: fleet-vmapped continuous batching %s the per-node "
            "loop (best %.2fx at n=%d; %.0f tok/s; outputs identical and "
            "post-gossip swap without re-jit: %s)" % (
                "beats" if code == 0 and all(
                    f["vmapped_speedup"] > 1 for f in rec["fleets"])
                else "DOES NOT beat",
                best["vmapped_speedup"], best["n_nodes"],
                best["fleet_vmapped"]["tokens_per_sec"],
                rec["all_checks_passed"]))

    if "roofline" in sections:
        from benchmarks import roofline

        rows = roofline.full_table(multi_pod=False)
        print("\n" + roofline.format_table(rows))
        json.dump(rows, open(f"{args.out}/roofline_1pod.json", "w"),
                  indent=1, default=float)

    print("\n=== verdicts (paper-claim checks) ===")
    for v in verdicts:
        print(" •", v)
    print(f"total bench time: {time.time() - t_start:.0f}s")


if __name__ == "__main__":
    main()
