"""Beyond-paper tables — gossip aggregation cost.

Two independent studies:

* :func:`run` — gossip *schedule* cost: dense all-gather vs sparse
  circulant ppermute, plus ring-relabeling (bandwidth-minimizing node
  order).  Reports, per topology: distinct circulant offsets
  before/after reverse-Cuthill–McKee relabeling, modeled ICI bytes per
  node for both schedules, and measured wall time of the two host-side
  mixing paths.

* :func:`run_mix` — single-chip mix *kernel* cost (the tracked
  ``BENCH_mix.json`` perf series): XLA einsum vs the legacy per-row
  Pallas family (``mix_dense_pallas`` — n_leaves × n kernel programs
  per mix) vs the fused flat-plane kernel (``mix_plane_pallas`` — ONE
  ``pallas_call`` per mix, DESIGN.md §11).  Records wall-clock per mix
  and the modeled HBM bytes
  (``kernels.gossip_mix.mix_modeled_hbm_bytes``) for each path; on this
  CPU container the Pallas paths run in interpret mode, so wall-clock is
  dominated by per-program dispatch — exactly the n_leaves·n-fold
  overhead the fused kernel removes — while the bytes model is
  backend-independent.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.core.mixing import (
    circulant_decomposition,
    mix_dense,
    mix_sparse_host,
    mixing_collective_bytes,
)
from repro.core.strategies import AggregationStrategy, mixing_matrix
from repro.core.topology import Topology, barabasi_albert, ring, watts_strogatz


def relabel_for_ring(topo: Topology) -> np.ndarray:
    """Reverse Cuthill–McKee node order: minimizes adjacency bandwidth →
    fewer/shorter circulant offsets when nodes are laid out on the ICI
    ring.  Returns the permutation (new order of old indices)."""
    import scipy.sparse as sp
    from scipy.sparse.csgraph import reverse_cuthill_mckee

    perm = reverse_cuthill_mckee(sp.csr_matrix(topo.adjacency))
    return np.asarray(perm)


def permuted_matrix(c: np.ndarray, perm: np.ndarray) -> np.ndarray:
    return c[np.ix_(perm, perm)]


def _params(n_nodes: int, n_params: int, seed=0):
    per = n_params // 2
    k1, k2 = jax.random.split(jax.random.key(seed))
    return {
        "a": jax.random.normal(k1, (n_nodes, per // 1024, 1024), jnp.float32),
        "b": jax.random.normal(k2, (n_nodes, per // 1024, 1024), jnp.float32),
    }


def run(log=print, n_params: int = 8_000_000) -> List[dict]:
    rows = []
    for name, topo in [
        ("ring16", ring(16)),
        ("ba16_p1", barabasi_albert(16, 1, seed=0)),
        ("ba16_p2", barabasi_albert(16, 2, seed=0)),
        ("ws16", watts_strogatz(16, 4, 0.5, seed=0)),
    ]:
        c = mixing_matrix(topo, AggregationStrategy("degree", tau=0.1))
        sched = circulant_decomposition(c)
        perm = relabel_for_ring(topo)
        c_rcm = permuted_matrix(c, perm)
        sched_rcm = circulant_decomposition(c_rcm)
        nz = lambda s: sum(1 for o in s.offsets if o != 0)
        pbytes = n_params * 4
        model = mixing_collective_bytes(topo.n_nodes, pbytes, sched)
        model_rcm = mixing_collective_bytes(topo.n_nodes, pbytes, sched_rcm)

        params = _params(topo.n_nodes, n_params)
        cj = jnp.asarray(c)
        dense = jax.jit(lambda p, cc: mix_dense(p, cc))
        sparse = jax.jit(lambda p: mix_sparse_host(p, sched))
        dense(params, cj)["a"].block_until_ready()
        sparse(params)["a"].block_until_ready()
        t0 = time.time()
        for _ in range(3):
            dense(params, cj)["a"].block_until_ready()
        td = (time.time() - t0) / 3
        t0 = time.time()
        for _ in range(3):
            sparse(params)["a"].block_until_ready()
        ts = (time.time() - t0) / 3

        row = dict(
            topology=name, offsets_dense=topo.n_nodes - 1,
            offsets_sparse=nz(sched), offsets_sparse_rcm=nz(sched_rcm),
            ici_bytes_dense=model["dense_bytes_per_node"],
            ici_bytes_sparse=model["sparse_bytes_per_node"],
            ici_bytes_sparse_rcm=model_rcm["sparse_bytes_per_node"],
            wall_dense_s=td, wall_sparse_s=ts,
        )
        rows.append(row)
        log(csv_row(
            f"gossip_cost/{name}", td,
            f"offsets={row['offsets_sparse']}(rcm {row['offsets_sparse_rcm']})"
            f"/{row['offsets_dense']};"
            f"bytes_sparse/dense="
            f"{row['ici_bytes_sparse']/row['ici_bytes_dense']:.2f};"
            f"wall_sparse/dense={ts/td:.2f}"))
    return rows


# ----------------------------------------------------------------------
# mix-kernel perf series: einsum vs legacy per-row pallas vs fused plane
# ----------------------------------------------------------------------
def _ragged_params(n_nodes: int, n_params: int, seed: int = 0,
                   dtype=jnp.float32):
    """A deliberately ragged stacked pytree (uneven leaf sizes, a
    non-tile-multiple matrix, a vector leaf, a scalar-per-node leaf)
    summing to ≈ n_params floats per node."""
    big = max(n_params * 3 // 5 // 128, 1)
    mid = max(n_params // 4 // 96, 1)
    ks = jax.random.split(jax.random.key(seed), 4)
    p = {
        "w_big": jax.random.normal(ks[0], (n_nodes, big, 128)),
        "w_mid": jax.random.normal(ks[1], (n_nodes, mid, 96)),
        "bias": jax.random.normal(ks[2], (n_nodes, 129)),
        "scale": jax.random.normal(ks[3], (n_nodes,)),
    }
    return jax.tree.map(lambda x: x.astype(dtype), p)


def _time_mixes(fns: Dict[str, callable], params, coeffs,
                reps: int) -> Dict[str, float]:
    """Best-of-reps wall time per impl, with the reps INTERLEAVED across
    impls (round-robin): external load spikes on a shared runner then hit
    every impl roughly equally instead of biasing whichever was measured
    during the spike, and the minimum — the standard microbenchmark
    estimator, since a repetition can only be slowed — keeps the CI
    dominance assertion stable."""
    jitted = {k: jax.jit(f) for k, f in fns.items()}
    for f in jitted.values():
        jax.block_until_ready(f(params, coeffs))  # compile + warm
    times: Dict[str, list] = {k: [] for k in fns}
    for _ in range(reps):
        for k, f in jitted.items():
            t0 = time.perf_counter()
            jax.block_until_ready(f(params, coeffs))
            times[k].append(time.perf_counter() - t0)
    return {k: float(np.min(v)) for k, v in times.items()}


def run_mix(log=print, n_nodes: int = 8, n_params: int = 48_000,
            bt: int = 1024, reps: int = 5, smoke: bool = False,
            out_path: str = "benchmarks/artifacts/BENCH_mix.json"
            ) -> Dict[str, dict]:
    """Measure one Eq.-(2) mix — wall-clock + modeled HBM bytes — for the
    three dense backends and write the tracked ``BENCH_mix.json`` record.

    ``smoke`` shrinks the pytree so the legacy per-row path (n_leaves × n
    interpret-mode kernel programs) stays CI-tractable.
    """
    from repro.core.plane import PlaneLayout
    from repro.kernels.gossip_mix import (
        default_interpret,
        mix_dense_pallas,
        mix_modeled_hbm_bytes,
        mix_plane_pallas,
    )

    if smoke:
        n_params = min(n_params, 12_000)
    params = _ragged_params(n_nodes, n_params)
    layout = PlaneLayout.from_tree(params)
    p_floats = layout.n_params
    n_leaves = len(layout.slots)
    coeffs = jnp.asarray(
        mixing_matrix(barabasi_albert(n_nodes, 2, seed=0),
                      AggregationStrategy("degree", tau=0.1)), jnp.float32)

    impls = {
        "einsum": dict(
            fn=mix_dense,
            modeled_hbm_bytes=mix_modeled_hbm_bytes(
                "einsum", n_nodes, p_floats, n_leaves=n_leaves),
            kernel_programs_per_mix=n_leaves),
        "pallas_rows": dict(
            fn=mix_dense_pallas,
            modeled_hbm_bytes=mix_modeled_hbm_bytes(
                "pallas_rows", n_nodes, p_floats, n_leaves=n_leaves),
            kernel_programs_per_mix=n_leaves * n_nodes),
        "pallas_plane": dict(
            fn=lambda p, c: mix_plane_pallas(p, c, bt=bt),
            modeled_hbm_bytes=mix_modeled_hbm_bytes(
                "pallas_plane", n_nodes, p_floats, bt=bt),
            modeled_hbm_bytes_e2e=mix_modeled_hbm_bytes(
                "pallas_plane_e2e", n_nodes, p_floats, bt=bt),
            kernel_programs_per_mix=1),
        "pallas_plane_bf16": dict(
            fn=lambda p, c: mix_plane_pallas(
                p, c, bt=bt, plane_dtype=jnp.bfloat16),
            modeled_hbm_bytes=mix_modeled_hbm_bytes(
                "pallas_plane", n_nodes, p_floats, itemsize=2, bt=bt),
            kernel_programs_per_mix=1),
    }
    # equivalence gate before timing: a perf series over wrong numbers is
    # worthless (plane to f32 rounding; bf16 plane to storage precision)
    ref = mix_dense(params, coeffs)
    for name, tol in [("pallas_plane", 1e-6), ("pallas_plane_bf16", 2e-2)]:
        got = impls[name]["fn"](params, coeffs)
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=tol, atol=tol)

    walls = _time_mixes({k: rec.pop("fn") for k, rec in impls.items()},
                        params, coeffs, reps)
    for name, rec in impls.items():
        rec["wall_s"] = walls[name]
        log(csv_row(f"mix/{name}", rec["wall_s"],
                    f"modeled_hbm_mb={rec['modeled_hbm_bytes'] / 1e6:.2f};"
                    f"programs={rec['kernel_programs_per_mix']}"))

    rows, plane = impls["pallas_rows"], impls["pallas_plane"]
    record = {
        "schema": "BENCH_mix/v1",
        "config": {
            "backend": jax.default_backend(),
            "pallas_interpret": default_interpret(),
            "n_nodes": n_nodes,
            "param_floats_per_node": p_floats,
            "n_leaves": n_leaves,
            "leaf_shapes": [list(s.shape) for s in layout.slots],
            "dtype": "float32",
            "bt": bt,
            "reps": reps,
            "smoke": smoke,
        },
        "impls": impls,
        "fused_vs_rows": {
            "wall_speedup": rows["wall_s"] / plane["wall_s"],
            "hbm_bytes_ratio": (rows["modeled_hbm_bytes"]
                                / plane["modeled_hbm_bytes"]),
            "dominates": bool(
                plane["wall_s"] < rows["wall_s"]
                and plane["modeled_hbm_bytes"] < rows["modeled_hbm_bytes"]),
        },
        "fused_vs_einsum": {
            "wall_ratio": impls["einsum"]["wall_s"] / plane["wall_s"],
            "hbm_bytes_ratio": (impls["einsum"]["modeled_hbm_bytes"]
                                / plane["modeled_hbm_bytes"]),
        },
    }
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1, default=float)
    log(csv_row(
        "mix/fused_vs_rows", plane["wall_s"],
        f"speedup={record['fused_vs_rows']['wall_speedup']:.1f}x;"
        f"bytes_ratio={record['fused_vs_rows']['hbm_bytes_ratio']:.1f}x;"
        f"dominates={record['fused_vs_rows']['dominates']}"))
    return record


# ----------------------------------------------------------------------
# n-scaling series: dense fused plane vs the padded-ELL edge-list kernel
# ----------------------------------------------------------------------
def run_scaling(log=print, n_params: int = 4096, bt: int = 1024,
                reps: int = 3, smoke: bool = False,
                out_path: str = "benchmarks/artifacts/BENCH_mix.json"
                ) -> List[dict]:
    """The ``scaling``/``sparse`` series of ``BENCH_mix.json``: one
    Eq.-(2) mix on ring and BA graphs at n ∈ {64, 256, 1024}, dense fused
    plane (``gossip_plane_pallas``, O(n²) coefficient traffic per tile)
    vs the edge-list kernel (``gossip_edges_pallas``, O(n·dmax)).

    Every timed pair is first gated to 1e-6 agreement with the dense
    matmul oracle — a scaling curve over divergent numbers is worthless.
    Wall-clock on this CPU container runs in interpret mode (dispatch-
    bound); the modeled HBM bytes are backend-independent and carry the
    dominance claim: at n ≥ 256 the edge-list stream moves strictly fewer
    bytes than the dense plane on every bounded-degree family.
    """
    from repro.core.mixing import edge_weights
    from repro.core.topology import padded_neighbor_tables
    from repro.kernels.gossip_mix import (
        default_interpret,
        gossip_edges_pallas,
        gossip_plane_pallas,
        mix_modeled_hbm_bytes,
    )

    ns = (64, 256) if smoke else (64, 256, 1024)
    rows: List[dict] = []
    for n in ns:
        for tname, topo in (("ring", ring(n)),
                            ("ba_p2", barabasi_albert(n, 2, seed=0))):
            c = jnp.asarray(mixing_matrix(
                topo, AggregationStrategy("degree", tau=0.1)), jnp.float32)
            nbr_idx, nbr_mask = padded_neighbor_tables(
                topo.adjacency + np.eye(n))
            dmax = int(nbr_idx.shape[1])
            idx = jnp.asarray(nbr_idx)
            w = edge_weights(c, idx, jnp.asarray(nbr_mask))
            plane = jax.random.normal(jax.random.key(0), (n, n_params),
                                      jnp.float32)

            dense_fn = jax.jit(lambda p, cc: gossip_plane_pallas(
                p, cc, bt=bt))
            edges_fn = jax.jit(lambda p, ww: gossip_edges_pallas(
                p, ww, idx, bt=bt))
            d_out = jax.block_until_ready(dense_fn(plane, c))
            e_out = jax.block_until_ready(edges_fn(plane, w))
            # equivalence gate before timing
            oracle = np.asarray(c @ plane)
            np.testing.assert_allclose(np.asarray(d_out), oracle,
                                       rtol=1e-6, atol=1e-6)
            np.testing.assert_allclose(np.asarray(e_out), oracle,
                                       rtol=1e-6, atol=1e-6)

            walls: Dict[str, list] = {"dense": [], "sparse": []}
            for _ in range(reps):  # interleaved, best-of (see _time_mixes)
                t0 = time.perf_counter()
                jax.block_until_ready(dense_fn(plane, c))
                walls["dense"].append(time.perf_counter() - t0)
                t0 = time.perf_counter()
                jax.block_until_ready(edges_fn(plane, w))
                walls["sparse"].append(time.perf_counter() - t0)

            db = mix_modeled_hbm_bytes("pallas_plane", n, n_params, bt=bt)
            eb = mix_modeled_hbm_bytes("edges", n, n_params, bt=bt,
                                       max_neighbors=dmax)
            row = dict(
                topology=f"{tname}{n}", n_nodes=n, max_degree=dmax,
                dense=dict(impl="pallas_plane",
                           wall_s=float(np.min(walls["dense"])),
                           modeled_hbm_bytes=db),
                sparse=dict(impl="edges",
                            wall_s=float(np.min(walls["sparse"])),
                            modeled_hbm_bytes=eb),
                sparse_vs_dense_bytes_ratio=db / eb,
            )
            rows.append(row)
            log(csv_row(
                f"mix_scaling/{row['topology']}",
                row["sparse"]["wall_s"],
                f"dmax={dmax};bytes_dense/edges="
                f"{row['sparse_vs_dense_bytes_ratio']:.2f};"
                f"wall_dense/edges="
                f"{row['dense']['wall_s'] / row['sparse']['wall_s']:.2f}"))

    record = {}
    if os.path.exists(out_path):
        try:
            record = json.load(open(out_path))
        except ValueError:
            record = {}
    record.setdefault("schema", "BENCH_mix/v1")
    record["scaling"] = {
        "config": {"backend": jax.default_backend(),
                   "pallas_interpret": default_interpret(),
                   "param_floats_per_node": n_params, "bt": bt,
                   "reps": reps, "smoke": smoke},
        "series": rows,
    }
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1, default=float)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--mix-only", action="store_true",
                    help="only the BENCH_mix kernel series")
    ap.add_argument("--scaling", action="store_true",
                    help="only the n-scaling series (dense plane vs "
                         "edge-list kernel) merged into BENCH_mix.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale (small pytree, few reps)")
    args = ap.parse_args()
    if args.mix_only or args.scaling:
        if args.mix_only:
            rec = run_mix(smoke=args.smoke)
            # CI gate.  The structural wins are deterministic — assert
            # them hard; the wall-clock half gets a 25% noise allowance
            # so a load spike on a shared runner can't flake the build (a
            # genuine regression that makes the fused path slower than
            # the legacy fan-out still fails).  `fused_vs_rows.dominates`
            # in the JSON stays the strict measured comparison.
            assert rec["fused_vs_rows"]["hbm_bytes_ratio"] > 1.0, rec
            assert rec["impls"]["pallas_plane"][
                "kernel_programs_per_mix"] == 1
            plane_w = rec["impls"]["pallas_plane"]["wall_s"]
            rows_w = rec["impls"]["pallas_rows"]["wall_s"]
            assert plane_w < rows_w * 1.25, (
                f"fused plane ({plane_w:.6f}s) no longer beats the legacy "
                f"per-row path ({rows_w:.6f}s) even with noise allowance")
        if args.scaling:
            # CI gate: the edge-list byte model must dominate the dense
            # plane at n ≥ 256 on every family (deterministic — no noise
            # allowance needed).
            for r in run_scaling(smoke=args.smoke):
                if r["n_nodes"] >= 256:
                    assert (r["sparse"]["modeled_hbm_bytes"]
                            < r["dense"]["modeled_hbm_bytes"]), r
    else:
        run()
        run_mix(smoke=args.smoke)
        run_scaling(smoke=args.smoke)
