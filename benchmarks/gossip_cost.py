"""Beyond-paper table — gossip schedule cost: dense all-gather vs sparse
circulant ppermute, plus ring-relabeling (bandwidth-minimizing node order).

Reports, per topology: distinct circulant offsets before/after reverse-
Cuthill–McKee relabeling, modeled ICI bytes per node for both schedules,
and measured wall time of the two host-side mixing paths on a ~100M-param
stacked pytree (CPU — relative numbers only).
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.core.mixing import (
    circulant_decomposition,
    mix_dense,
    mix_sparse_host,
    mixing_collective_bytes,
)
from repro.core.strategies import AggregationStrategy, mixing_matrix
from repro.core.topology import Topology, barabasi_albert, ring, watts_strogatz


def relabel_for_ring(topo: Topology) -> np.ndarray:
    """Reverse Cuthill–McKee node order: minimizes adjacency bandwidth →
    fewer/shorter circulant offsets when nodes are laid out on the ICI
    ring.  Returns the permutation (new order of old indices)."""
    import scipy.sparse as sp
    from scipy.sparse.csgraph import reverse_cuthill_mckee

    perm = reverse_cuthill_mckee(sp.csr_matrix(topo.adjacency))
    return np.asarray(perm)


def permuted_matrix(c: np.ndarray, perm: np.ndarray) -> np.ndarray:
    return c[np.ix_(perm, perm)]


def _params(n_nodes: int, n_params: int, seed=0):
    per = n_params // 2
    k1, k2 = jax.random.split(jax.random.key(seed))
    return {
        "a": jax.random.normal(k1, (n_nodes, per // 1024, 1024), jnp.float32),
        "b": jax.random.normal(k2, (n_nodes, per // 1024, 1024), jnp.float32),
    }


def run(log=print, n_params: int = 8_000_000) -> List[dict]:
    rows = []
    for name, topo in [
        ("ring16", ring(16)),
        ("ba16_p1", barabasi_albert(16, 1, seed=0)),
        ("ba16_p2", barabasi_albert(16, 2, seed=0)),
        ("ws16", watts_strogatz(16, 4, 0.5, seed=0)),
    ]:
        c = mixing_matrix(topo, AggregationStrategy("degree", tau=0.1))
        sched = circulant_decomposition(c)
        perm = relabel_for_ring(topo)
        c_rcm = permuted_matrix(c, perm)
        sched_rcm = circulant_decomposition(c_rcm)
        nz = lambda s: sum(1 for o in s.offsets if o != 0)
        pbytes = n_params * 4
        model = mixing_collective_bytes(topo.n_nodes, pbytes, sched)
        model_rcm = mixing_collective_bytes(topo.n_nodes, pbytes, sched_rcm)

        params = _params(topo.n_nodes, n_params)
        cj = jnp.asarray(c)
        dense = jax.jit(lambda p, cc: mix_dense(p, cc))
        sparse = jax.jit(lambda p: mix_sparse_host(p, sched))
        dense(params, cj)["a"].block_until_ready()
        sparse(params)["a"].block_until_ready()
        t0 = time.time()
        for _ in range(3):
            dense(params, cj)["a"].block_until_ready()
        td = (time.time() - t0) / 3
        t0 = time.time()
        for _ in range(3):
            sparse(params)["a"].block_until_ready()
        ts = (time.time() - t0) / 3

        row = dict(
            topology=name, offsets_dense=topo.n_nodes - 1,
            offsets_sparse=nz(sched), offsets_sparse_rcm=nz(sched_rcm),
            ici_bytes_dense=model["dense_bytes_per_node"],
            ici_bytes_sparse=model["sparse_bytes_per_node"],
            ici_bytes_sparse_rcm=model_rcm["sparse_bytes_per_node"],
            wall_dense_s=td, wall_sparse_s=ts,
        )
        rows.append(row)
        log(csv_row(
            f"gossip_cost/{name}", td,
            f"offsets={row['offsets_sparse']}(rcm {row['offsets_sparse_rcm']})"
            f"/{row['offsets_dense']};"
            f"bytes_sparse/dense="
            f"{row['ici_bytes_sparse']/row['ici_bytes_dense']:.2f};"
            f"wall_sparse/dense={ts/td:.2f}"))
    return rows


if __name__ == "__main__":
    run()
