"""Batched experiment-sweep runner — declarative figure grids over the
vmap×scan engine (``repro.core.sweep``), with a wall-clock comparison
against the legacy per-config loop.

  PYTHONPATH=src python -m benchmarks.sweep --list
  PYTHONPATH=src python -m benchmarks.sweep --preset fig4 --dry-run
  PYTHONPATH=src python -m benchmarks.sweep --preset fig4            # engine + legacy baseline
  PYTHONPATH=src python -m benchmarks.sweep --preset fig6 --no-legacy
  PYTHONPATH=src python -m benchmarks.sweep --preset fig4 --seeds 0,1,2 --full

Device-sharded mode (DESIGN.md §8) — shard the experiment axis across all
local devices and record the sharded-vs-single wall-clock in
``BENCH_sweep.json`` (on CPU, launch with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python -m benchmarks.sweep --preset fig4 --smoke \\
    --no-legacy --shard
  PYTHONPATH=src python -m benchmarks.sweep --preset fig4 --shard 4 \\
    --chunk-rounds 10

Each preset re-expresses one paper figure as a list of
:class:`benchmarks.common.SweepCell` — pure data.  Cells sharing a program
shape (dataset × node count) compile into ONE program; seeds, strategies,
OOD placements, and topology variants all ride the vmap axis.
``--dry-run`` prints the compiled-program plan (groups, experiment counts,
estimated sample-bank memory) without touching the accelerator.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Callable, Dict, List, Optional

# bytes per sample (x features, f32 / int32) for the bank-memory estimate
_SAMPLE_BYTES = {
    "mnist": 28 * 28 * 1 * 4,
    "fmnist": 28 * 28 * 1 * 4,
    "cifar10": 32 * 32 * 3 * 4,
    "cifar100": 32 * 32 * 3 * 4,
    "tinymem": 65 * 4,
}


@dataclasses.dataclass(frozen=True)
class SweepPreset:
    """Registry entry: a figure's grid as a cell builder + claim check.

    ``programs=True`` runs the grid through device-side coefficient
    programs (``coeff_mode="program"``, DESIGN.md §9) — required for
    reactive link-failure cells — and records the stacks-vs-programs
    host-memory and wall-clock deltas in ``BENCH_sweep.json``.
    """

    name: str
    description: str
    build: Callable[..., list]               # (datasets, seeds, n_nodes) → cells
    verdict: Callable[[List[dict]], str]
    datasets: tuple = ("mnist",)
    seeds: tuple = (0, 1)
    programs: bool = False
    # aggregation backend for the whole grid ("einsum" | "pallas" |
    # "sparse" | "edges"); non-einsum backends derive each compiled
    # program's mix_support from its cells' topologies
    mix_impl: str = "einsum"
    # FaultSpec kwargs for fault-injection presets (kept as a plain dict
    # so --list stays jax-free); None → run_sweep_cells' default spec
    # when any cell sets a fault_rate
    fault_kwargs: Optional[dict] = None


PRESETS: Dict[str, SweepPreset] = {}


def register_preset(preset: SweepPreset) -> None:
    if preset.name in PRESETS:
        raise KeyError(f"preset {preset.name!r} already registered")
    PRESETS[preset.name] = preset


def _fig2_build(datasets, seeds, n_nodes):
    from benchmarks import fig2_iid_vs_ood as fig2

    return fig2.cells(datasets=datasets, seeds=seeds, n_nodes=n_nodes)


def _fig2_verdict(rows):
    from benchmarks import fig2_iid_vs_ood as fig2

    return fig2.verdict(rows)


def _fig4_build(datasets, seeds, n_nodes):
    from benchmarks import fig4_strategies as fig4

    return fig4.cells(datasets=datasets, seeds=seeds, n_nodes=n_nodes)


def _fig4_verdict(rows):
    from benchmarks import fig4_strategies as fig4

    return fig4.verdict(rows)


def _fig5_build(datasets, seeds, n_nodes):
    from benchmarks import fig5_location as fig5

    return fig5.cells(datasets=datasets, seeds=seeds, n_nodes=n_nodes)


def _fig5_verdict(rows):
    from benchmarks import fig5_location as fig5

    return fig5.verdict(rows)


def _fig6_build(datasets, seeds, n_nodes):
    from benchmarks import fig6_topology as fig6

    return (fig6.degree_cells(datasets=datasets, seeds=seeds)
            + fig6.modularity_cells(datasets=datasets, seeds=seeds))


def _fig6_verdict(rows):
    from benchmarks import fig6_topology as fig6

    deg = [r for r in rows if r.get("sweep", (None,))[0] == "degree"]
    mod = [r for r in rows if r.get("sweep", (None,))[0] == "modularity"]
    return fig6.verdict(deg, mod)


register_preset(SweepPreset(
    "fig2", "IID vs OOD propagation gap (baseline strategies, BA)",
    _fig2_build, _fig2_verdict, seeds=(0,)))
register_preset(SweepPreset(
    "fig4", "topology-aware vs unaware strategies (6 strategies × seeds)",
    _fig4_build, _fig4_verdict, seeds=(0, 1)))
register_preset(SweepPreset(
    "fig5", "OOD-placement sweep (degree rank 1..4 × strategies)",
    _fig5_build, _fig5_verdict, seeds=(0,)))
register_preset(SweepPreset(
    "fig6", "topology sweep (BA degree param + SB modularity)",
    _fig6_build, _fig6_verdict, seeds=(0,)))


# betweenness is deliberately absent: it has no fixed-shape reactive
# kernel, so a reactive grid would silently serve NOMINAL scores for it —
# validate_state_kinds now rejects that combination (DESIGN.md §9);
# eigenvector is the topology-global centrality that DOES recompute
# on the surviving subgraph in-scan.
LINKFAIL_STRATEGIES = ("unweighted", "degree", "eigenvector")
LINKFAIL_P = (0.0, 0.3, 0.6)


def _linkfail_build(datasets, seeds, n_nodes):
    """Reactive link-failure grid: strategies × p_fail on BA graphs, every
    round's centralities recomputed on the surviving subgraph in-scan —
    the scenario host-precomputed stacks cannot express reactively at
    sweep scale (the matrices are generated device-side per round)."""
    from benchmarks.common import linkfail_cells

    return linkfail_cells(datasets=datasets, seeds=seeds, n_nodes=n_nodes,
                          strategies=LINKFAIL_STRATEGIES,
                          p_fails=LINKFAIL_P, reactive=True)


def _linkfail_verdict(rows):
    mean = lambda xs: sum(xs) / max(len(xs), 1)
    by = {}
    for r in rows:
        by.setdefault((r["strategy"], r.get("p_fail", 0.0)),
                      []).append(r["ood_auc"])
    parts = []
    for pf in sorted({k[1] for k in by}):
        deg = mean(by.get(("degree", pf), [0.0]))
        unw = mean(by.get(("unweighted", pf), [0.0]))
        parts.append(f"p={pf}: degree−unweighted OOD-AUC "
                     f"Δ={deg - unw:+.3f}")
    return ("reactive link failure (centralities on the surviving "
            "subgraph): " + "; ".join(parts))


register_preset(SweepPreset(
    "linkfail",
    "reactive link-failure robustness (strategies × p_fail, in-scan "
    "coefficient programs)",
    _linkfail_build, _linkfail_verdict, seeds=(0,), programs=True))


def _multisource_build(datasets, seeds, n_nodes):
    """Multi-source OOD grid: k backdoor sources on the k highest-degree
    nodes (strategies × source counts).  The in-scan arrival-round
    analytics (DESIGN.md §10) read how source multiplicity shortens the
    min-over-sources hop distances and accelerates propagation."""
    from benchmarks.common import multisource_cells

    return multisource_cells(datasets=datasets, seeds=seeds,
                             n_nodes=n_nodes)


def _multisource_verdict(rows):
    mean = lambda xs: (sum(xs) / len(xs)) if xs else float("nan")
    by_k: Dict[int, Dict[str, list]] = {}
    for r in rows:
        k = r["sweep"][2]
        d = by_k.setdefault(k, {"auc": [], "arrival": []})
        d["auc"].append(r["ood_auc"])
        arr = r.get("analytics", {}).get("ood_arrival_mean")
        if arr is not None:
            d["arrival"].append(arr)
    parts = []
    for k in sorted(by_k):
        d = by_k[k]
        arr = (f"arrival≈{mean(d['arrival']):.1f}" if d["arrival"]
               else "arrival=n/a")
        parts.append(f"k={k}: ood_auc={mean(d['auc']):.3f} {arr}")
    ks = sorted(by_k)
    mono = all(mean(by_k[a]["auc"]) <= mean(by_k[b]["auc"]) + 0.02
               for a, b in zip(ks, ks[1:]))
    return ("multi-source OOD (more sources ⇒ faster propagation): "
            + "; ".join(parts)
            + "  [monotone ✓]" * mono + "  [non-monotone X]" * (not mono))


register_preset(SweepPreset(
    "multisource",
    "multi-source OOD placement (k sources × strategies, streaming "
    "arrival-round analytics)",
    _multisource_build, _multisource_verdict, seeds=(0,)))


def _edges_build(datasets, seeds, n_nodes):
    """Edge-list mix smoke: strategies × hub-OOD on BA graphs, the whole
    grid aggregated through mix_impl="edges" (padded-ELL neighbour tables
    + the segment gather/accumulate Pallas kernel, DESIGN.md §12)."""
    from benchmarks.common import edges_cells

    return edges_cells(datasets=datasets, seeds=seeds, n_nodes=n_nodes)


def _edges_verdict(rows):
    mean = lambda xs: (sum(xs) / len(xs)) if xs else float("nan")
    by = {}
    for r in rows:
        by.setdefault(r["strategy"], []).append(r["ood_auc"])
    parts = [f"{s}: ood_auc={mean(v):.3f}" for s, v in sorted(by.items())]
    return ("edge-list gossip (mix_impl='edges', O(n·dmax) mix traffic): "
            + "; ".join(parts))


register_preset(SweepPreset(
    "edges",
    "edge-list sparse gossip smoke (BA graphs through the padded-ELL "
    "segment kernel; pair with --n-nodes 64+)",
    _edges_build, _edges_verdict, seeds=(0,), mix_impl="edges"))


def _participation_build(datasets, seeds, n_nodes):
    """Partial-participation grid (DESIGN.md §15): activation rate ×
    topology (ring vs BA) × OOD placement (hub vs leaf).  The cells carry
    per-experiment rates, so ``run_sweep_cells`` threads the default
    Bernoulli ``ParticipationSpec`` through the round scan; rate 1.0 rows
    are the bit-identical synchronous control."""
    from benchmarks.common import participation_cells

    return participation_cells(datasets=datasets, seeds=seeds,
                               n_nodes=n_nodes)


def _participation_verdict(rows):
    mean = lambda xs: (sum(xs) / len(xs)) if xs else float("nan")
    by: Dict[float, Dict[str, list]] = {}
    for r in rows:
        p = r["participation"]
        d = by.setdefault(r["participation_rate"],
                          {"auc": [], "act": [], "stale": []})
        d["auc"].append(r["ood_auc"])
        d["act"].append(p["activity_rate"])
        d["stale"].append(p["mean_staleness"])
    parts = [f"rate={rate}: ood_auc={mean(d['auc']):.3f} "
             f"activity={mean(d['act']):.2f} "
             f"staleness≈{mean(d['stale']):.2f}"
             for rate, d in sorted(by.items(), reverse=True)]
    ctrl = by.get(1.0)
    ctrl_ok = ctrl is not None and max(ctrl["stale"], default=0.0) == 0.0
    return ("partial participation (stale-plane gossip): "
            + "; ".join(parts)
            + ("  [rate-1.0 control stale-free ✓]" if ctrl_ok
               else "  [rate-1.0 control has staleness X]"))


register_preset(SweepPreset(
    "participation",
    "partial-participation gossip (activation rate × topology × OOD "
    "placement, staleness-aware stale-plane mixing)",
    _participation_build, _participation_verdict, seeds=(0,)))


def _byzantine_build(datasets, seeds, n_nodes):
    """Byzantine-fault grid (DESIGN.md §16): fault rate × topology (ring
    vs BA) × OOD placement (hub vs leaf) × aggregation rule (mean /
    trimmed / median).  The cells carry per-experiment fault rates, so
    ``run_sweep_cells`` threads the default signflip ``FaultSpec``
    through the round scan; rate-0.0 mean rows are the bit-identical
    fault-free control, and cells with different ``robust`` compile into
    separate groups (the aggregator is static engine configuration)."""
    from benchmarks.common import byzantine_cells

    return byzantine_cells(datasets=datasets, seeds=seeds, n_nodes=n_nodes)


def _byzantine_verdict(rows):
    mean = lambda xs: (sum(xs) / len(xs)) if xs else float("nan")
    by: Dict[tuple, list] = {}
    for r in rows:
        by.setdefault((r["fault_rate"], r["robust"]),
                      []).append(r["final_ood_acc_mean"])
    rates = sorted({k[0] for k in by})
    parts, recovered = [], True
    for rate in rates:
        cell = {rob: mean(by.get((rate, rob), []))
                for rob in ("mean", "trimmed", "median")}
        parts.append(f"rate={rate:g}: final_ood "
                     + " ".join(f"{rob}={v:.3f}"
                                for rob, v in cell.items()))
        if rate > 0:
            recovered &= (cell["trimmed"] >= cell["mean"] - 1e-6
                          and cell["median"] >= cell["mean"] - 1e-6)
    return ("byzantine faults (signflip, robust aggregation): "
            + "; ".join(parts)
            + ("  [robust ≥ mean under faults ✓]" if recovered
               else "  [robust < mean under faults X]"))


# byz_scale=12 makes the corruption decisive: a ×(−3) signflip barely
# moves a degree-weighted mean at n=16 (mean "recovers" on its own and
# the robust-vs-mean contrast inverts), while ×(−12) collapses plain
# mean and leaves the order-statistic aggregators standing — the same
# amplification the golden suite pins (tests/regen_goldens.py BYZ_SCALE).
register_preset(SweepPreset(
    "byzantine",
    "Byzantine fault injection (fault rate × topology × OOD placement × "
    "{mean, trimmed, median} aggregation)",
    _byzantine_build, _byzantine_verdict, seeds=(0,),
    fault_kwargs=dict(mode="signflip", byz_scale=12.0)))


# ----------------------------------------------------------------------
def plan(cells, scale) -> str:
    """The compiled-program plan for a cell grid — no jax work."""
    from benchmarks.common import group_cells

    lines = ["plan: group,experiments,distinct_datasets,rounds,"
             "est_bank_mib,cells"]
    for (ds, n, robust), idxs in group_cells(cells).items():
        dkeys = {(cells[i].seed, cells[i].ood_nodes()) for i in idxs}
        bank_mib = (len(dkeys) * scale.n_train
                    * _SAMPLE_BYTES.get(ds, 4096)) / 2**20
        names = ",".join(cells[i].label for i in idxs[:3])
        more = f",+{len(idxs) - 3}" if len(idxs) > 3 else ""
        tag = f"/{robust}" if robust != "mean" else ""
        lines.append(
            f"  {ds}/n{n}{tag}: E={len(idxs)} D={len(dkeys)} "
            f"R={scale.rounds} bank≈{bank_mib:.0f}MiB [{names}{more}]")
    lines.append(f"total cells: {len(cells)} "
                 f"({len(group_cells(cells))} compiled programs)")
    return "\n".join(lines)


def run_legacy_baseline(cells, scale, log=print) -> List[dict]:
    """The pre-engine path: one ``run_experiment`` (per-round Python loop)
    per cell — the wall-clock baseline."""
    from benchmarks.common import run_experiment

    rows = []
    for cell in cells:
        r = run_experiment(cell.dataset, cell.topo, cell.strategy,
                           ood_k=cell.ood_k, ood_ks=cell.ood_ks,
                           tau=cell.tau, seed=cell.seed, scale=scale)
        log(f"  legacy {cell.label}: {r['secs']}s "
            f"ood_auc={r['ood_auc']:.3f}")
        rows.append(r)
    return rows


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--preset", default=None,
                    help=f"one of {sorted(PRESETS)}")
    ap.add_argument("--list", action="store_true",
                    help="list registered presets and exit")
    ap.add_argument("--dry-run", action="store_true",
                    help="print the compiled-program plan; no jax work")
    ap.add_argument("--full", action="store_true", help="paper-scale runs")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny scale (seconds on CPU) — CI / sanity runs")
    ap.add_argument("--datasets", default=None, help="comma list")
    ap.add_argument("--seeds", default=None, help="comma list of ints")
    ap.add_argument("--n-nodes", type=int, default=None)
    ap.add_argument("--no-legacy", action="store_true",
                    help="skip the legacy per-config wall-clock baseline")
    ap.add_argument("--unroll", action="store_true",
                    help="engine escape hatch: per-round dispatch "
                         "(incremental metrics) instead of one scan")
    ap.add_argument("--shard", nargs="?", type=int, const=0, default=None,
                    metavar="N",
                    help="shard the experiment axis over N devices "
                         "(default: all); also times the single-device "
                         "path and writes BENCH_sweep.json")
    ap.add_argument("--chunk-rounds", type=int, default=None,
                    help="scan the round schedule in chunks of this many "
                         "rounds (bounds device memory for long runs)")
    ap.add_argument("--shard-scale", default=None, metavar="R1,R2,...",
                    help="with --shard: rerun the grid at each of these "
                         "round counts, time sharded vs single-device at "
                         "every size, and write the measured crossover "
                         "into BENCH_sweep.json (replaces the misleading "
                         "single-point speedup record)")
    ap.add_argument("--out", default="benchmarks/artifacts")
    args = ap.parse_args(argv)

    if args.list or args.preset is None:
        print("registered sweep presets:")
        for p in PRESETS.values():
            print(f"  {p.name:8s} {p.description} "
                  f"(default seeds={p.seeds})")
        return
    if args.preset not in PRESETS:
        raise SystemExit(f"unknown preset {args.preset!r}; "
                         f"have {sorted(PRESETS)}")
    preset = PRESETS[args.preset]

    datasets = (tuple(args.datasets.split(","))
                if args.datasets else preset.datasets)
    seeds = (tuple(int(s) for s in args.seeds.split(","))
             if args.seeds else preset.seeds)
    n_nodes = args.n_nodes or (33 if args.full else 16)
    cells = preset.build(datasets, seeds, n_nodes)

    from benchmarks.common import BenchScale, FULL, QUICK, run_sweep_cells

    scale = FULL if args.full else QUICK
    if args.smoke:
        scale = BenchScale(n_train=1500, n_test=300, rounds=6,
                           local_epochs=2, batch=16, steps_per_epoch=4,
                           eval_every=2, eval_n=128)
    if args.dry_run:  # plan only — no data, no compile, no device work
        print(f"preset {preset.name}: {preset.description}")
        print(plan(cells, scale))
        return

    print(f"preset {preset.name}: {len(cells)} cells "
          f"(datasets={datasets}, seeds={seeds}, n_nodes={n_nodes})")
    print(plan(cells, scale))

    mesh = None
    if args.shard is not None:
        if args.unroll:
            raise SystemExit("--shard cannot combine with --unroll")
        import jax

        from repro.launch.mesh import make_sweep_mesh

        # auto mode fits the device count to the grid instead of taking
        # every device: E experiments on n devices are padded to the next
        # multiple of n, and the padding rows are pure wasted compute
        # (fig4-smoke E=12 on 8 devices padded 4 dummy experiments — 33%
        # extra work for the same ceil(E/n) serial depth).  The fewest
        # devices that keep the minimal per-device row count waste least.
        n_dev = args.shard
        if not n_dev:
            n_avail = len(jax.devices())
            per = -(-len(cells) // n_avail)          # minimal rows/device
            n_dev = -(-len(cells) // per)            # fewest devices at it
        mesh = make_sweep_mesh(n_dev)
        pad = (-len(cells)) % n_dev
        print(f"sharding the experiment axis over {n_dev} device(s) "
              f"(E={len(cells)}, padding {pad}); "
              f"chunk_rounds={args.chunk_rounds}")

    if args.shard_scale:
        if mesh is None:
            raise SystemExit("--shard-scale requires --shard")
        _run_shard_scale(args, preset, cells, scale, mesh, n_nodes)
        return

    coeff_mode = "program" if preset.programs else "stack"
    fault = _preset_fault(preset)
    t0 = time.time()
    rows = run_sweep_cells(cells, scale=scale, unroll_eval=args.unroll,
                           mesh=mesh, chunk_rounds=args.chunk_rounds,
                           coeff_mode=coeff_mode, mix_impl=preset.mix_impl,
                           fault=fault, log=print)
    engine_secs = time.time() - t0
    print(f"\nsweep engine: {len(cells)} experiments in "
          f"{engine_secs:.1f}s wall-clock "
          f"({engine_secs / len(cells):.2f}s/experiment amortized"
          f"{', in-scan coefficient programs' if preset.programs else ''})")

    if rows and "analytics" in rows[0]:
        # streaming-analytics record (DESIGN.md §10): in-scan vs host-
        # oracle max deviation across the grid, arrival stats, and the
        # metric-memory win of O(E·n) summaries over (E, R, n) histories.
        from benchmarks.common import DEFAULT_ARRIVAL_THRESHOLD

        devs = [r["analytics"]["stream_vs_host_max_dev"] for r in rows]
        arrivals = [r["analytics"]["ood_arrival_mean"] for r in rows
                    if r["analytics"]["ood_arrival_mean"] is not None]
        history_bytes = len(cells) * scale.rounds * n_nodes * 3 * 4
        summary_bytes = len(cells) * n_nodes * 7 * 4
        bench_path = _update_bench(args.out, f"analytics/{preset.name}", {
            "preset": preset.name,
            "experiments": len(cells),
            "rounds": scale.rounds,
            "n_nodes": n_nodes,
            "arrival_threshold": DEFAULT_ARRIVAL_THRESHOLD,
            "max_stream_vs_host_dev": max(devs),
            "mean_ood_arrival_round": (round(sum(arrivals) / len(arrivals),
                                             2) if arrivals else None),
            "rows_with_arrival": len(arrivals),
            "history_metric_bytes": history_bytes,
            "streaming_summary_bytes": summary_bytes,
            "bytes_ratio": round(history_bytes / summary_bytes, 1),
        })
        apath = _extract_analytics(args.out)
        print(f"streaming analytics: max in-scan vs host-oracle deviation "
              f"{max(devs):.2e} over {len(cells)} experiments; "
              f"summaries {summary_bytes / 2**10:.1f} KiB vs "
              f"{history_bytes / 2**10:.1f} KiB of metric history "
              f"({history_bytes / summary_bytes:.0f}× smaller)")
        print(f"analytics record → {bench_path} (sections extracted to "
              f"{apath})")

    if rows and "participation" in rows[0]:
        # partial-participation record (DESIGN.md §15): per-rate realized
        # activity / staleness / OOD-AUC aggregates, plus the rate-1.0
        # control invariant (no staleness anywhere ⇒ the synchronous
        # bit-identity held on this run).
        mean = lambda xs: (sum(xs) / len(xs)) if xs else None
        by_rate: Dict[float, List[dict]] = {}
        for r in rows:
            by_rate.setdefault(r["participation_rate"], []).append(r)
        rate_rec = {
            f"{rate:g}": {
                "cells": len(rs),
                "ood_auc": round(mean([r["ood_auc"] for r in rs]), 4),
                "activity_rate": round(mean(
                    [r["participation"]["activity_rate"] for r in rs]), 4),
                "mean_staleness": round(mean(
                    [r["participation"]["mean_staleness"] for r in rs]), 4),
                "max_final_staleness": max(
                    r["participation"]["max_final_staleness"] for r in rs),
                "local_steps_total": sum(
                    r["participation"]["local_steps_total"] for r in rs),
            }
            for rate, rs in sorted(by_rate.items(), reverse=True)
        }
        ctrl = by_rate.get(1.0, [])
        bench_path = _update_bench(args.out, f"participation/{preset.name}", {
            "preset": preset.name,
            "experiments": len(cells),
            "rounds": scale.rounds,
            "n_nodes": n_nodes,
            "mode": "bernoulli",
            "rates": rate_rec,
            "rate1_control_stale_free": bool(ctrl) and all(
                r["participation"]["mean_staleness"] == 0.0 for r in ctrl),
        })
        print(f"participation record → {bench_path}")

    if rows and "fault" in rows[0]:
        # byzantine robustness record (DESIGN.md §16): per (rate, robust)
        # OOD aggregates + detection analytics, and the headline
        # robust-vs-mean recovery flag under nonzero fault rates.
        mean = lambda xs: (sum(xs) / len(xs)) if xs else None
        by_cell: Dict[tuple, List[dict]] = {}
        for r in rows:
            by_cell.setdefault((r["fault_rate"], r["robust"]),
                               []).append(r)
        grid_rec = {
            f"{rate:g}/{rob}": {
                "cells": len(rs),
                "ood_auc": round(mean([r["ood_auc"] for r in rs]), 4),
                "final_ood_acc": round(mean(
                    [r["final_ood_acc_mean"] for r in rs]), 4),
                "fault_round_rate": round(mean(
                    [r["fault"]["fault_round_rate"] for r in rs]), 4),
            }
            for (rate, rob), rs in sorted(by_cell.items())
        }
        nz_rates = sorted({k[0] for k in by_cell if k[0] > 0})
        final = lambda rate, rob: mean(
            [r["final_ood_acc_mean"] for r in by_cell.get((rate, rob), [])])
        recovered = bool(nz_rates) and all(
            final(rate, rob) >= final(rate, "mean") - 1e-6
            for rate in nz_rates for rob in ("trimmed", "median"))
        bench_path = _update_bench(args.out, f"byzantine/{preset.name}", {
            "preset": preset.name,
            "experiments": len(cells),
            "rounds": scale.rounds,
            "n_nodes": n_nodes,
            "fault_mode": "signflip",
            "grid": grid_rec,
            "robust_recovers_vs_mean": recovered,
        })
        print(f"byzantine record → {bench_path}")

    if mesh is not None:
        # sharded-vs-single comparison → BENCH_sweep.json (perf trajectory)
        t0 = time.time()
        single_rows = run_sweep_cells(cells, scale=scale,
                                      coeff_mode=coeff_mode,
                                      mix_impl=preset.mix_impl,
                                      fault=fault)
        single_secs = time.time() - t0
        identical = all(
            a["iid_auc"] == b["iid_auc"] and a["ood_auc"] == b["ood_auc"]
            and a["final_ood_acc_mean"] == b["final_ood_acc_mean"]
            for a, b in zip(rows, single_rows))
        print(f"single-device scanned path: {single_secs:.1f}s wall-clock "
              f"→ sharded speedup {single_secs / max(engine_secs, 1e-9):.2f}×"
              f"  (metrics bit-identical: {identical})")
        bench_path = _update_bench(args.out, f"sharded/{preset.name}", {
            "preset": preset.name,
            "experiments": len(cells),
            "rounds": scale.rounds,
            "n_nodes": n_nodes,
            "devices": int(mesh.devices.size),
            "chunk_rounds": args.chunk_rounds,
            "sharded_secs": round(engine_secs, 2),
            "single_device_secs": round(single_secs, 2),
            "speedup": round(single_secs / max(engine_secs, 1e-9), 3),
            "bit_identical_metrics": bool(identical),
        })
        print(f"sharded-vs-single wall-clock → {bench_path}")

    if preset.programs:
        # stacks-vs-programs comparison: identical grid, coefficients
        # host-materialized as (E, R, n, n) slabs instead of generated
        # in-scan — records the memory and wall-clock deltas of the
        # coefficient-program subsystem (DESIGN.md §9).
        from repro.core.coeffs import program_for, state_nbytes
        from repro.core.strategies import AggregationStrategy

        t0 = time.time()
        stack_rows = run_sweep_cells(cells, scale=scale, mesh=mesh,
                                     chunk_rounds=args.chunk_rounds,
                                     coeff_mode="stack",
                                     mix_impl=preset.mix_impl,
                                     fault=fault)
        stack_secs = time.time() - t0
        identical = all(
            a["iid_auc"] == b["iid_auc"] and a["ood_auc"] == b["ood_auc"]
            for a, b in zip(rows, stack_rows))
        c0 = cells[0]
        _, state0 = program_for(
            c0.topo, AggregationStrategy(c0.strategy, tau=c0.tau,
                                         seed=c0.seed),
            p_fail=c0.p_fail, reactive=c0.reactive)
        program_bytes = state_nbytes(state0) * len(cells)
        stack_bytes = len(cells) * scale.rounds * n_nodes * n_nodes * 4
        secs_ratio = engine_secs / max(stack_secs, 1e-9)
        print(f"coefficient stacks: {stack_secs:.1f}s wall-clock, "
              f"{stack_bytes / 2**20:.1f} MiB of host coefficients vs "
              f"{program_bytes / 2**10:.1f} KiB program state "
              f"({stack_bytes / max(program_bytes, 1):.0f}× smaller); "
              f"metrics bit-identical: {identical}")
        # the pre-pruning record was programs ≈ 1.8× stacks (24.2 s vs
        # 13.3 s): the batched lax.switch computed every reactive
        # centrality branch per round.  Static kind pruning
        # (CoeffProgram.kinds) must keep the in-scan path near parity.
        verdict = "improved ✓" if secs_ratio < 1.5 else "regressed ✗"
        print(f"programs-vs-stacks wall-clock ratio {secs_ratio:.2f}× "
              f"(pre-pruning record 1.82×) — {verdict}")
        bench_path = _update_bench(
            args.out, f"coeff_programs/{preset.name}", {
            "preset": preset.name,
            "experiments": len(cells),
            "rounds": scale.rounds,
            "n_nodes": n_nodes,
            "reactive": bool(c0.reactive),
            "program_secs": round(engine_secs, 2),
            "stack_secs": round(stack_secs, 2),
            "secs_ratio": round(secs_ratio, 3),
            "pre_pruning_secs_ratio": 1.82,
            "ratio_improved": bool(secs_ratio < 1.5),
            "stack_coeff_bytes": stack_bytes,
            "program_state_bytes": program_bytes,
            "bytes_ratio": round(stack_bytes / max(program_bytes, 1), 1),
            "bit_identical_metrics": bool(identical),
        })
        print(f"stacks-vs-programs record → {bench_path}")

    if not args.no_legacy and preset.programs:
        print("\n(legacy per-config baseline skipped: run_experiment has "
              "no link-failure path — programs presets compare against "
              "the materialized-stack engine run instead)")
    elif not args.no_legacy:
        t0 = time.time()
        run_legacy_baseline(cells, scale)
        legacy_secs = time.time() - t0
        print(f"legacy per-config loop: {len(cells)} experiments in "
              f"{legacy_secs:.1f}s wall-clock "
              f"({legacy_secs / len(cells):.2f}s/experiment)")
        print(f"speedup: {legacy_secs / max(engine_secs, 1e-9):.2f}× "
              f"(batched engine vs legacy loop)")

    print("\n=== verdict ===")
    print(" •", preset.verdict(rows))

    os.makedirs(args.out, exist_ok=True)
    path = f"{args.out}/sweep_{preset.name}.json"
    json.dump(rows, open(path, "w"), indent=1, default=_json_default)
    print(f"rows → {path}")


def _preset_fault(preset: SweepPreset):
    """Materialize a preset's ``fault_kwargs`` into a FaultSpec (lazy —
    keeps --list/--dry-run jax-free)."""
    if preset.fault_kwargs is None:
        return None
    from repro.core.dynamic import FaultSpec

    return FaultSpec(**preset.fault_kwargs)


def _linfit(xs, ys):
    """Least-squares slope/intercept of secs vs rounds."""
    import numpy as np

    b, a = np.polyfit(np.asarray(xs, float), np.asarray(ys, float), 1)
    return float(a), float(b)  # intercept (fixed secs), slope (secs/round)


def _crossover_from_entries(entries):
    """Single-vs-sharded crossover in rounds: measured interpolation when
    the speedup crosses 1.0 inside the sweep, otherwise extrapolated from
    the per-path linear fits (secs = fixed + slope·rounds); None when the
    sharded slope is not smaller (no crossover exists — e.g. more virtual
    devices than physical cores)."""
    for lo, hi in zip(entries, entries[1:]):
        s0, s1 = lo["speedup"], hi["speedup"]
        if (s0 - 1.0) * (s1 - 1.0) <= 0 and s0 != s1:
            frac = (1.0 - s0) / (s1 - s0)
            return (round(lo["rounds"]
                          + frac * (hi["rounds"] - lo["rounds"]), 1),
                    "measured")
    xs = [e["rounds"] for e in entries]
    a_sh, b_sh = _linfit(xs, [e["sharded_secs"] for e in entries])
    a_si, b_si = _linfit(xs, [e["single_device_secs"] for e in entries])
    if b_sh < b_si and a_sh > a_si:
        return round((a_sh - a_si) / (b_si - b_sh), 1), "extrapolated"
    return None, ("sharded per-round cost is not below single-device "
                  "on this host — no crossover at any scale")


def _run_shard_scale(args, preset, cells, scale, mesh, n_nodes) -> None:
    """--shard-scale: the same grid timed sharded AND single-device at
    2–3 round counts, so BENCH_sweep.json records the single-vs-sharded
    *crossover* (where amortized compute overtakes the sharded path's
    fixed compile/dispatch overhead) instead of one misleading
    single-point speedup."""
    from benchmarks.common import run_sweep_cells

    sizes = sorted({int(s) for s in args.shard_scale.split(",")})
    if len(sizes) < 2:
        raise SystemExit("--shard-scale needs ≥ 2 round counts")
    coeff_mode = "program" if preset.programs else "stack"
    fault = _preset_fault(preset)
    entries = []
    for r in sizes:
        s = dataclasses.replace(scale, rounds=r)
        t0 = time.time()
        rows_sh = run_sweep_cells(cells, scale=s, mesh=mesh,
                                  chunk_rounds=args.chunk_rounds,
                                  coeff_mode=coeff_mode,
                                  mix_impl=preset.mix_impl, fault=fault)
        sh = time.time() - t0
        t0 = time.time()
        rows_si = run_sweep_cells(cells, scale=s, coeff_mode=coeff_mode,
                                  mix_impl=preset.mix_impl, fault=fault)
        si = time.time() - t0
        identical = all(
            a["iid_auc"] == b["iid_auc"] and a["ood_auc"] == b["ood_auc"]
            for a, b in zip(rows_sh, rows_si))
        entries.append({
            "rounds": r,
            "sharded_secs": round(sh, 2),
            "single_device_secs": round(si, 2),
            "speedup": round(si / max(sh, 1e-9), 3),
            "bit_identical_metrics": bool(identical),
        })
        print(f"  R={r}: sharded {sh:.1f}s vs single {si:.1f}s "
              f"→ speedup {si / max(sh, 1e-9):.3f}× "
              f"(bit-identical: {identical})")
    crossover, how = _crossover_from_entries(entries)
    xs = [e["rounds"] for e in entries]
    a_sh, b_sh = _linfit(xs, [e["sharded_secs"] for e in entries])
    a_si, b_si = _linfit(xs, [e["single_device_secs"] for e in entries])
    payload = {
        "preset": preset.name,
        "experiments": len(cells),
        "n_nodes": n_nodes,
        "devices": int(mesh.devices.size),
        "physical_cpus": os.cpu_count(),
        "chunk_rounds": args.chunk_rounds,
        "scale_sweep": entries,
        "sharded_fixed_secs": round(a_sh, 2),
        "sharded_secs_per_round": round(b_sh, 4),
        "single_fixed_secs": round(a_si, 2),
        "single_secs_per_round": round(b_si, 4),
        "crossover_rounds": crossover,
        "crossover_kind": how,
    }
    bench_path = _update_bench(args.out, f"sharded/{preset.name}", payload)
    print("\n=== verdict ===")
    if crossover is not None:
        print(f" • single-vs-sharded crossover at R≈{crossover} ({how}); "
              f"fixed overhead {a_sh - a_si:+.1f}s, per-round "
              f"{b_sh:.3f}s vs {b_si:.3f}s")
    else:
        print(f" • no crossover: {how} (fixed {a_sh - a_si:+.1f}s, "
              f"per-round sharded {b_sh:.3f}s vs single {b_si:.3f}s)")
    print(f"sharded scale sweep → {bench_path}")


def _update_bench(out_dir: str, section: str, payload: dict) -> str:
    """Merge one section into benchmarks/artifacts/BENCH_sweep.json.
    Sections are keyed ``kind/preset`` (e.g. ``sharded/fig4``,
    ``coeff_programs/linkfail``) so the CI job's successive preset runs
    accumulate instead of overwriting each other's records."""
    os.makedirs(out_dir, exist_ok=True)
    path = f"{out_dir}/BENCH_sweep.json"
    bench = {}
    if os.path.exists(path):
        try:
            loaded = json.load(open(path))
            # pre-section records were one flat sharded dict — discard
            if isinstance(loaded, dict) and "preset" not in loaded:
                bench = loaded
        except ValueError:
            pass
    bench[section] = payload
    json.dump(bench, open(path, "w"), indent=1)
    return path


def _extract_analytics(out_dir: str) -> str:
    """Mirror the ``analytics/*`` sections of BENCH_sweep.json into a
    standalone ``BENCH_sweep_analytics.json`` — the artifact the CI golden
    job uploads."""
    path = f"{out_dir}/BENCH_sweep.json"
    bench = json.load(open(path)) if os.path.exists(path) else {}
    sections = {k: v for k, v in bench.items()
                if k.startswith("analytics/")}
    apath = f"{out_dir}/BENCH_sweep_analytics.json"
    json.dump(sections, open(apath, "w"), indent=1)
    return apath


def _json_default(o):
    try:
        return float(o)
    except (TypeError, ValueError):
        return str(o)


if __name__ == "__main__":
    main()
