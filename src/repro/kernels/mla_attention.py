"""Pallas TPU kernel: MLA (multi-head latent attention) prefill — the
deepseek-v2 hot-spot (128 heads × 32k context in a rank-512 latent space).

Latent-space flash attention: keys AND values are the same compressed
latent c_kv (B,T,r) — the kernel never materializes per-head K/V.  Per
(batch, head, q-block) program, kv blocks stream through VMEM with an
online-softmax carry:

  logits = q_lat·c_kvᵀ + q_rope·k_ropeᵀ        (two MXU GEMMs, (bq, bkv))
  acc    = Σ softmax(logits)·c_kv              (latent context, (bq, r))

The up-projection (r → v_head_dim) and output projection stay outside
(they are batched GEMMs XLA already does well); the kernel removes the
O(S·T) logits HBM traffic which dominates at 32k.

VMEM/program ≈ bq·(r+dr) + bkv·(r+dr) + bq·bkv + bq·r  f32
             ≈ 1.6 MiB at bq=bkv=256, r=512 — fits comfortably.

Validated against ``ref.mla_attention_ref`` in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["mla_attention_pallas"]

_NEG = -1e30


def _kernel(ql_ref, qr_ref, ck_ref, kr_ref, out_ref, m_scr, l_scr, acc_scr, *,
            scale, bq, bkv, seq_len):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    ql = ql_ref[0, 0].astype(jnp.float32)          # (bq, r)
    qr = qr_ref[0, 0].astype(jnp.float32)          # (bq, dr)
    ck = ck_ref[0].astype(jnp.float32)             # (bkv, r)
    kr = kr_ref[0].astype(jnp.float32)             # (bkv, dr)

    logits = jax.lax.dot_general(ql, ck, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    logits += jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    logits *= scale

    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    kpos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    ok = (kpos <= qpos) & (kpos < seq_len)
    logits = jnp.where(ok, logits, _NEG)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, -1, keepdims=True))
    p = jnp.exp(logits - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, -1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, ck, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        out_ref[0, 0] = (acc_scr[...] /
                         jnp.maximum(l_scr[...], 1e-30)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bkv", "interpret"))
def mla_attention_pallas(q_lat, q_rope, c_kv, k_rope,
                         bq: int = 256, bkv: int = 256,
                         interpret: bool = True):
    """q_lat: (B,S,H,r) — queries absorbed into the latent basis;
    q_rope: (B,S,H,dr); c_kv: (B,T,r); k_rope: (B,T,dr).
    Returns latent context (B,S,H,r), causal.
    """
    b, s, h, r = q_lat.shape
    dr = q_rope.shape[-1]
    t = c_kv.shape[1]
    # 1/sqrt(qk_nope + qk_rope) is applied by the CALLER by pre-scaling q
    # (keeps the kernel dimension-agnostic).
    scale = 1.0

    bq = min(bq, s)
    bkv = min(bkv, t)
    ps = (s + bq - 1) // bq * bq
    pt = (t + bkv - 1) // bkv * bkv
    if ps != s:
        q_lat = jnp.pad(q_lat, ((0, 0), (0, ps - s), (0, 0), (0, 0)))
        q_rope = jnp.pad(q_rope, ((0, 0), (0, ps - s), (0, 0), (0, 0)))
    if pt != t:
        c_kv = jnp.pad(c_kv, ((0, 0), (0, pt - t), (0, 0)))
        k_rope = jnp.pad(k_rope, ((0, 0), (0, pt - t), (0, 0)))

    qlt = q_lat.transpose(0, 2, 1, 3)   # (B,H,S,r)
    qrt = q_rope.transpose(0, 2, 1, 3)  # (B,H,S,dr)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, bq=bq, bkv=bkv, seq_len=s),
        grid=(b, h, ps // bq, pt // bkv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, r), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bq, dr), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, bkv, r), lambda bi, hi, qi, ki: (bi, ki, 0)),
            pl.BlockSpec((1, bkv, dr), lambda bi, hi, qi, ki: (bi, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, r), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, ps, r), q_lat.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, r), jnp.float32),
        ],
        interpret=interpret,
    )(qlt, qrt, c_kv, k_rope)
    return out.transpose(0, 2, 1, 3)[:, :s]
