"""Pallas TPU kernel: causal flash attention with GQA, sliding window and
logit softcap (gemma2) — the prefill hot-spot for the 32k shapes.

TPU-native tiling (MXU 128×128):
  grid = (batch, q_heads, S/bq, S/bkv); the kv axis is the innermost
  (sequential, "arbitrary" semantics) dimension so the online-softmax
  carry (m, l, acc) lives in VMEM scratch across kv steps.
  q blocks: (bq, hd); kv blocks: (bkv, hd) — hd padded to 128 by caller.
  GQA: kv-head index = q-head // (H/KV) via the BlockSpec index_map —
  no materialized head repetition (saves KV·(groups−1) HBM reads).

VMEM per program ≈ bq·hd(q) + 2·bkv·hd(kv) + bq·bkv(logits) + bq·hd(acc)
f32 ≈ 0.6 MiB at bq=bkv=256, hd=128.

Validated against ref.flash_attention_ref in interpret mode (CPU) across
shape/dtype/window/softcap sweeps.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, out_ref, m_scr, l_scr, acc_scr, *,
            scale, bq, bkv, causal, window, softcap, seq_len):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)           # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)           # (bkv, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                      # (bq, bkv)
    if softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap

    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    kpos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    ok = kpos < seq_len
    if causal:
        ok &= kpos <= qpos
    if window > 0:
        ok &= kpos > qpos - window
    logits = jnp.where(ok, logits, _NEG)

    m_prev = m_scr[...]                            # (bq, 1)
    m_cur = jnp.max(logits, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(logits - m_new)                    # (bq, bkv)
    alpha = jnp.exp(m_prev - m_new)                # (bq, 1)

    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, -1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        out_ref[0, 0] = (acc_scr[...] / denom).astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "logit_softcap", "bq", "bkv", "interpret"),
)
def flash_attention_pallas(q, k, v, causal: bool = True, window: int = 0,
                           logit_softcap: float = 0.0,
                           bq: int = 256, bkv: int = 256,
                           interpret: bool = True):
    """q: (B, S, H, hd); k/v: (B, S, KV, hd) → (B, S, H, hd)."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    groups = h // kv
    scale = 1.0 / math.sqrt(hd)

    bq = min(bq, s)
    bkv = min(bkv, s)
    ps = (s + max(bq, bkv) - 1) // max(bq, bkv) * max(bq, bkv)
    if ps != s:
        pad = ((0, 0), (0, ps - s), (0, 0), (0, 0))
        q = jnp.pad(q, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)

    # layout: (B, H, S, hd) for clean per-head blocking
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _kernel, scale=scale, bq=bq, bkv=bkv, causal=causal,
        window=window, softcap=logit_softcap, seq_len=s,
    )

    out = pl.pallas_call(
        kernel,
        grid=(b, h, ps // bq, ps // bkv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bkv, hd),
                         lambda bi, hi, qi, ki, g=groups: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, bkv, hd),
                         lambda bi, hi, qi, ki, g=groups: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, ps, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max m
            pltpu.VMEM((bq, 1), jnp.float32),    # running sum l
            pltpu.VMEM((bq, hd), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)[:, :s]
