"""Pallas TPU kernel: chunked RWKV-6 scan (data-dependent-decay linear
attention) — the train-time hot-spot for the SSM/hybrid architectures.

TPU adaptation (DESIGN.md §6): the GPU reference implementations lean on
warp-level scans; the TPU-native formulation is *chunked* so the inner work
is dense GEMMs on the MXU:

With per-step decay w_t ∈ (0,1) and inclusive cumprod P_t = Π_{s≤t} w_s,
for one chunk with incoming state S₀ (hd_k × hd_v):

  y_t   = (r_t ⊙ P_{t-1}) · S₀                      ← state term  (GEMM)
        + Σ_{s<t} [(r_t ⊙ P_{t-1}/P_s) · k_s] v_s    ← intra term  (GEMM, masked)
        + (r_t · (u ⊙ k_t)) v_t                      ← bonus diag
  S_out = diag(P_T) S₀ + Σ_s ((P_T/P_s) ⊙ k_s) v_sᵀ  ← state update (GEMM)

Grid = (B·H, S/chunk): the chunk axis is innermost/sequential so S carries
in VMEM scratch.  Numerics: cumprods in f32 log-space would be exact; we
use direct f32 cumprod with chunk=64 which keeps P_T ≥ e^{-64·|log w|} in
range for the decay regimes RWKV-6 produces (w = exp(-exp(·)) ≈ 0.9–0.999).

Validated against ref.rwkv_scan_ref (sequential scan) in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rwkv_scan_pallas"]


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref, state_scr,
            *, chunk):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)     # (T, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)     # (1, hd)
    s0 = state_scr[...]                  # (hd, hd)

    p = jnp.cumprod(w, axis=0)           # inclusive cumprod P_t, (T, hd)
    p_prev = p / w                       # P_{t-1} (P_0 = 1)

    r_dec = r * p_prev                   # r̃_t
    k_dec = k / p                        # k̃_s

    # state term: (T, hd_k) @ (hd_k, hd_v)
    y = jax.lax.dot_general(r_dec, s0, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # intra-chunk term with strict lower mask
    a = jax.lax.dot_general(r_dec, k_dec, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (T, T)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    a = jnp.where(s_idx < t_idx, a, 0.0)
    y += jax.lax.dot_general(a, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    # bonus diagonal term
    y += jnp.sum(r * u * k, axis=-1, keepdims=True) * v
    y_ref[0] = y.astype(y_ref.dtype)

    # state update
    p_total = p[-1]                                       # (hd,)
    k_scaled = k * (p_total[None] / p)                    # (T, hd)
    s_new = s0 * p_total[:, None] + jax.lax.dot_general(
        k_scaled, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    state_scr[...] = s_new

    @pl.when(ci == nc - 1)
    def _finish():
        sT_ref[0] = s_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv_scan_pallas(r, k, v, w, u, state, chunk: int = 64,
                     interpret: bool = True):
    """r,k,v,w: (B, S, H, hd); u: (H, hd); state: (B, H, hd, hd) f32.

    Returns (y (B,S,H,hd), final_state (B,H,hd,hd) f32).
    S is padded to a chunk multiple with w=1, k=0 (identity steps).
    """
    b, s, h, hd = r.shape
    ps = (s + chunk - 1) // chunk * chunk
    if ps != s:
        pad = ((0, 0), (0, ps - s), (0, 0), (0, 0))
        r = jnp.pad(r, pad)
        v = jnp.pad(v, pad)
        k = jnp.pad(k, pad)
        w = jnp.pad(w, pad, constant_values=1.0)

    # (B, S, H, hd) → (B·H, S, hd)
    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, ps, hd)

    rf, kf, vf, wf = map(fold, (r, k, v, w))
    uf = jnp.broadcast_to(u[None], (b, h, hd)).reshape(b * h, 1, hd)
    s0 = state.reshape(b * h, hd, hd).astype(jnp.float32)

    y, s_t = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=(b * h, ps // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, hd), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, hd), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, hd), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, 1, hd), lambda bi, ci: (bi, 0, 0)),
            pl.BlockSpec((1, hd, hd), lambda bi, ci: (bi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hd), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, hd, hd), lambda bi, ci: (bi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, ps, hd), r.dtype),
            jax.ShapeDtypeStruct((b * h, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, wf, uf, s0)

    y = y.reshape(b, h, ps, hd).transpose(0, 2, 1, 3)[:, :s]
    return y, s_t.reshape(b, h, hd, hd)
