"""Pallas TPU kernel: fused K-way weighted parameter mix (gossip hot-spot).

The paper's aggregation step is memory-bound: ``out = Σ_k c_k · M_k`` over
K neighbour parameter blocks.  A naive ``sum(c*m for ...)`` materializes
K−1 intermediates in HBM (2(K−1) extra HBM round-trips).  This kernel
streams each parameter tile once: grid over (M, N) tiles; each program
loads its (K, bm, bn) slab into VMEM and MACs in f32 registers.

VMEM budget per program: K·bm·bn·bytes + bm·bn·4 (acc).  Default tile
(8·K-adaptive × 512 f32) keeps the slab ≈ 2 MiB ≪ 16 MiB VMEM.

Roofline: bytes = (K+1)·|P| → t_mem = (K+1)·|P| / 819 GB/s per chip; the
fusion makes this the floor (vs (3K−1)·|P| naive).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["gossip_mix_pallas"]


def _kernel(w_ref, blocks_ref, out_ref):
    """blocks_ref: (K, bm, bn) VMEM; w_ref: (K,) SMEM-ish; out: (bm, bn)."""
    k = blocks_ref.shape[0]
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    for i in range(k):  # K is static → unrolled MACs
        acc += w_ref[i] * blocks_ref[i].astype(jnp.float32)
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def gossip_mix_pallas(blocks: jnp.ndarray, weights: jnp.ndarray,
                      bm: int = 256, bn: int = 512,
                      interpret: bool = True) -> jnp.ndarray:
    """out = Σ_k weights[k] · blocks[k].

    blocks: (K, M, N) — K neighbour copies of one parameter tile-matrix.
    weights: (K,) f32.  M, N padded to tile multiples internally.
    """
    k, m, n = blocks.shape
    bm = min(bm, m)
    bn = min(bn, n)
    pm = (m + bm - 1) // bm * bm
    pn = (n + bn - 1) // bn * bn
    if (pm, pn) != (m, n):
        blocks = jnp.pad(blocks, ((0, 0), (0, pm - m), (0, pn - n)))

    out = pl.pallas_call(
        _kernel,
        grid=(pm // bm, pn // bn),
        in_specs=[
            pl.BlockSpec((k,), lambda i, j: (0,)),           # weights: tiny, replicated
            pl.BlockSpec((k, bm, bn), lambda i, j: (0, i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pm, pn), blocks.dtype),
        interpret=interpret,
    )(weights.astype(jnp.float32), blocks)
    return out[:m, :n]
