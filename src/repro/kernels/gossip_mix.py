"""Pallas TPU kernel: fused K-way weighted parameter mix (gossip hot-spot).

The paper's aggregation step is memory-bound: ``out = Σ_k c_k · M_k`` over
K neighbour parameter blocks.  A naive ``sum(c*m for ...)`` materializes
K−1 intermediates in HBM (2(K−1) extra HBM round-trips).  This kernel
streams each parameter tile once: grid over (M, N) tiles; each program
loads its (K, bm, bn) slab into VMEM and MACs in f32 registers.

VMEM budget per program: K·bm·bn·bytes + bm·bn·4 (acc).  Default tile
(8·K-adaptive × 512 f32) keeps the slab ≈ 2 MiB ≪ 16 MiB VMEM.

Roofline: bytes = (K+1)·|P| → t_mem = (K+1)·|P| / 819 GB/s per chip; the
fusion makes this the floor (vs (3K−1)·|P| naive).

Backend selection: ``interpret=None`` (the default) auto-detects — the
kernel compiles for real on TPU/GPU backends and falls back to Pallas
interpret mode on CPU, so the same call sites work everywhere.  The
scan/vmap sweep engine routes its aggregation through
:func:`mix_dense_pallas` when ``DecentralizedConfig(mix_impl="pallas")``
(see DESIGN.md §6/§7).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["gossip_mix_pallas", "mix_dense_pallas", "default_interpret"]


def default_interpret() -> bool:
    """True when no Pallas-compiling backend is present (CPU → interpret)."""
    return jax.default_backend() not in ("tpu", "gpu")


def _kernel(w_ref, blocks_ref, out_ref):
    """blocks_ref: (K, bm, bn) VMEM; w_ref: (K,) SMEM-ish; out: (bm, bn)."""
    k = blocks_ref.shape[0]
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    for i in range(k):  # K is static → unrolled MACs
        acc += w_ref[i] * blocks_ref[i].astype(jnp.float32)
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def gossip_mix_pallas(blocks: jnp.ndarray, weights: jnp.ndarray,
                      bm: int = 256, bn: int = 512,
                      interpret: Optional[bool] = None) -> jnp.ndarray:
    """out = Σ_k weights[k] · blocks[k].

    blocks: (K, M, N) — K neighbour copies of one parameter tile-matrix.
    weights: (K,) f32.  M, N padded to tile multiples internally.
    interpret: None → auto (compiled on TPU/GPU, interpret on CPU).
    """
    if interpret is None:
        interpret = default_interpret()
    k, m, n = blocks.shape
    bm = min(bm, m)
    bn = min(bn, n)
    pm = (m + bm - 1) // bm * bm
    pn = (n + bn - 1) // bn * bn
    if (pm, pn) != (m, n):
        blocks = jnp.pad(blocks, ((0, 0), (0, pm - m), (0, pn - n)))

    out = pl.pallas_call(
        _kernel,
        grid=(pm // bm, pn // bn),
        in_specs=[
            pl.BlockSpec((k,), lambda i, j: (0,)),           # weights: tiny, replicated
            pl.BlockSpec((k, bm, bn), lambda i, j: (0, i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pm, pn), blocks.dtype),
        interpret=interpret,
    )(weights.astype(jnp.float32), blocks)
    return out[:m, :n]


def mix_dense_pallas(params, coeffs: jnp.ndarray,
                     interpret: Optional[bool] = None):
    """Eq. (2) over a stacked pytree via the fused kernel: for each leaf
    ``(n, ...)``, destination row i is the K=n-way MAC ``Σ_j C[i,j]·leaf[j]``
    — one :func:`gossip_mix_pallas` call vmapped over destination rows.

    Drop-in replacement for :func:`repro.core.mixing.mix_dense` (same f32
    accumulation, same output dtype); selected by
    ``DecentralizedConfig(mix_impl="pallas")``.
    """
    c = jnp.asarray(coeffs, jnp.float32)
    n = c.shape[0]

    def leaf_fn(leaf: jnp.ndarray) -> jnp.ndarray:
        flat = leaf.reshape(n, 1, -1)  # (K=n, M=1, N=prod(rest))
        out = jax.vmap(
            lambda w: gossip_mix_pallas(flat, w, bm=1, interpret=interpret)
        )(c)  # (n, 1, N)
        return out.reshape(leaf.shape).astype(leaf.dtype)

    return jax.tree.map(leaf_fn, params)
