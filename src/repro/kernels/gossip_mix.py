"""Pallas TPU kernels for the gossip aggregation hot spot (Eq. 2).

Three generations live here:

* :func:`gossip_edges_pallas` / :func:`mix_edges_pallas` — the **edge-list
  segment mix** (DESIGN.md §12): per-destination neighbour tables
  (padded ELL, ``repro.core.topology.padded_neighbor_tables``) replace
  the dense (n, n) coefficient block, so each plane tile re-fetches
  ``n·dmax·8`` table bytes instead of ``n²·4`` — the path that makes
  n ≥ 1024 topologies affordable (``DecentralizedConfig(
  mix_impl="edges")``).

* :func:`gossip_plane_pallas` / :func:`mix_plane_pallas` — the **fused
  flat-plane mix** (DESIGN.md §11).  The stacked pytree is packed into one
  contiguous ``(n, P)`` plane (:class:`repro.core.plane.PlaneLayout`) and
  the whole round's aggregation ``out = C @ plane`` runs as ONE
  ``pallas_call``: grid over parameter tiles ``⌈P/bt⌉``, each program
  loading the full ``(n, n)`` coefficient block plus an ``(n, bt)`` plane
  slab into VMEM and producing all n destination rows with f32
  accumulation (``mix_in_float32=False`` accumulates in the plane dtype —
  the low-precision-aggregation ablation).  Modeled HBM traffic:
  ``2·n·P·b`` for the kernel stream (read + write the plane once) plus
  ``⌈P/bt⌉·n²·4`` coefficient re-fetches; the pack/unpack copies around
  the kernel add ``4·n·P·b`` end-to-end (see :func:`mix_modeled_hbm_bytes`
  — measured alongside wall-clock in ``benchmarks/gossip_cost.run_mix``,
  tracked as ``benchmarks/artifacts/BENCH_mix.json``).  This is the
  ``DecentralizedConfig(mix_impl="pallas")`` path.

* :func:`gossip_mix_pallas` / :func:`mix_dense_pallas` — the **legacy
  per-row kernel family**, kept as the benchmark baseline.  Honest cost:
  ``mix_dense_pallas`` tree-maps over leaves and vmaps a ``bm=1`` kernel
  over the n destination rows, so one mix issues ``n_leaves × n`` kernel
  programs and every destination row re-reads its full ``(n, |leaf|)``
  slab — ~``n·(n+1)·|P|`` bytes of HBM traffic versus the fused path's
  ~``2·n·|P|`` streaming floor, plus an n²-unrolled-MAC compile blow-up
  from the static K loop.  (An earlier docstring advertised a
  ``(K+1)·|P|`` floor for this wrapper; that figure described ONE
  ``gossip_mix_pallas`` call, not the n-row × n_leaves fan-out the mix
  actually performs.)

VMEM budget per fused program: ``n_pad²·4`` (coeffs) + ``2·n_pad·bt·b``
(plane slab + out tile) — ≈ 1 MiB at n=64, bt=2048, f32, far under the
~16 MiB/core budget; ``bt`` is the knob if n grows.

Backend selection: ``interpret=None`` (the default) auto-detects — the
kernels compile for real on TPU/GPU backends and fall back to Pallas
interpret mode on CPU, so the same call sites work everywhere.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.plane import PlaneLayout

__all__ = [
    "gossip_plane_pallas",
    "mix_plane_pallas",
    "gossip_edges_pallas",
    "mix_edges_pallas",
    "gossip_robust_pallas",
    "mix_robust_pallas",
    "gossip_mix_pallas",
    "mix_dense_pallas",
    "mix_modeled_hbm_bytes",
    "mix_eqn_budget",
    "mix_accum_upcasts",
    "default_interpret",
]


def mix_eqn_budget(mix_impl: str, n_leaves: int = 1,
                   robust: str = "mean") -> dict:
    """Trace-time equation budget ONE aggregation (Eq. 2) contributes to a
    round body — the fusion contract as introspectable metadata, consumed
    by ``repro.analysis`` fusion-budget rules (DESIGN.md §13) instead of
    hand-counted assertions.

    * ``"einsum"`` — one XLA GEMM (``dot_general``) per pytree leaf
      (``repro.core.mixing.mix_dense`` tensordots leaf-wise), zero Pallas
      launches.
    * ``"pallas"`` — the fused flat-plane kernel: exactly ONE
      ``pallas_call`` for the whole mix, regardless of leaf count (the
      §11 contract); the kernel's internal MAC is not an XLA GEMM.
    * ``"edges"`` — the edge-list segment kernel: also exactly ONE
      ``pallas_call`` (§12); the per-edge weight gather is indexing, not
      a contraction.
    * ``"sparse"`` — the circulant schedule is rolls + multiplies: zero
      of both.  (The dense fallback is an *einsum* budget — resolve it
      with ``repro.core.decentralized.mix_impl_budget``, which knows the
      support.)

    ``robust`` (DESIGN.md §16) modulates the contract: ``"norm_clip"``
    is a pure coefficient transform in front of the unchanged impl (same
    budget); ``"trimmed"``/``"median"`` replace the contraction with the
    sort-network path — the einsum reference becomes gathers + selects
    (zero GEMMs) and the edges impl swaps its kernel for the robust one
    (still exactly ONE ``pallas_call``).
    """
    budgets = {
        "einsum": {"pallas_call": 0, "dot_general": n_leaves},
        "pallas": {"pallas_call": 1, "dot_general": 0},
        "edges": {"pallas_call": 1, "dot_general": 0},
        "sparse": {"pallas_call": 0, "dot_general": 0},
    }
    if mix_impl not in budgets:
        raise KeyError(f"unknown mix_impl {mix_impl!r}; "
                       f"have {sorted(budgets)}")
    if robust in ("trimmed", "median"):
        if mix_impl == "einsum":
            return {"pallas_call": 0, "dot_general": 0}
        if mix_impl == "edges":
            return {"pallas_call": 1, "dot_general": 0}
        raise ValueError(f"robust={robust!r} has no {mix_impl!r} path "
                         f"(supported: einsum reference, edges kernel)")
    return budgets[mix_impl]


def mix_accum_upcasts(mix_impl: str, mix_in_float32: bool,
                      plane_low_precision: bool):
    """Declared accumulation-point policy for the dtype-flow rule: should
    the Pallas kernel body contain small-float→f32 upcasts?

    ``True``: yes — f32 accumulation of a low-precision plane upcasts at
    the declared accumulation points (``mix_in_float32=True`` on a bf16
    plane).  ``False``: no — the low-precision ablation must stay in the
    plane dtype end to end.  ``None``: nothing to check (no Pallas kernel
    in this impl, or the plane is f32-native so no upcast can exist).
    """
    if mix_impl not in ("pallas", "edges") or not plane_low_precision:
        return None
    return bool(mix_in_float32)


def default_interpret() -> bool:
    """True when no Pallas-compiling backend is present (CPU → interpret)."""
    return jax.default_backend() not in ("tpu", "gpu")


# ----------------------------------------------------------------------
# fused flat-plane mix: the whole round's aggregation in ONE pallas_call
# ----------------------------------------------------------------------
def _plane_kernel(acc_dtype, c_ref, p_ref, o_ref):
    """One (n_pad, bt) output tile: all destination rows of one parameter
    slab.  c_ref: (n_pad, n_pad) f32 VMEM; p_ref: (n_pad, bt) plane slab;
    o_ref: (n_pad, bt).  ``acc_dtype`` fixes the MAC precision (f32 by
    default; the plane dtype under mix_in_float32=False)."""
    c = c_ref[...].astype(acc_dtype)
    p = p_ref[...].astype(acc_dtype)
    o_ref[...] = jnp.dot(c, p, preferred_element_type=acc_dtype).astype(
        o_ref.dtype)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit,
                   static_argnames=("bt", "interpret", "mix_in_float32"))
def gossip_plane_pallas(plane: jnp.ndarray, coeffs: jnp.ndarray,
                        bt: int = 2048,
                        interpret: Optional[bool] = None,
                        mix_in_float32: bool = True) -> jnp.ndarray:
    """``out = coeffs @ plane`` as ONE ``pallas_call``.

    plane: (n, P) — all n node-models' parameters, one row each.
    coeffs: (n, n) row-stochastic mixing matrix.
    bt: plane tile width (grid = ⌈P/bt⌉ programs; each holds the full
      coefficient block plus one (n, bt) slab in VMEM).
    interpret: None → auto (compiled on TPU/GPU, interpret on CPU).
    mix_in_float32: False accumulates in the plane dtype instead of f32
      (the low-precision-aggregation ablation; see
      ``DecentralizedConfig.mix_in_float32``).

    n and P are padded internally (zeros — padded coefficient rows/cols
    carry no weight) and the (n, P) result sliced back out.
    """
    if interpret is None:
        interpret = default_interpret()
    n, p = plane.shape
    # sublane multiple for the plane dtype (f32: 8, bf16: 16); the f32
    # coefficient block is (n_pad, n_pad) which then also satisfies its
    # own 8-row constraint.
    sub = 16 if plane.dtype == jnp.bfloat16 else 8
    n_pad = _round_up(n, sub)
    # clamp bt to the plane width, then to a lane (128) multiple — a
    # non-multiple tile would pass in interpret mode but fail Mosaic
    # lowering on the TPU backend the kernel exists for
    bt = _round_up(min(bt, _round_up(p, 128)), 128)
    p_pad = _round_up(p, bt)
    if (n_pad, p_pad) != (n, p):
        plane = jnp.pad(plane, ((0, n_pad - n), (0, p_pad - p)))
    c = jnp.asarray(coeffs, jnp.float32)
    if n_pad != n:
        c = jnp.pad(c, ((0, n_pad - n), (0, n_pad - n)))
    acc_dtype = jnp.float32 if mix_in_float32 else plane.dtype

    out = pl.pallas_call(
        functools.partial(_plane_kernel, acc_dtype),
        grid=(p_pad // bt,),
        in_specs=[
            pl.BlockSpec((n_pad, n_pad), lambda j: (0, 0)),  # coeff block
            pl.BlockSpec((n_pad, bt), lambda j: (0, j)),     # plane slab
        ],
        out_specs=pl.BlockSpec((n_pad, bt), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n_pad, p_pad), plane.dtype),
        interpret=interpret,
    )(c, plane)
    return out[:n, :p]


def mix_plane_pallas(params, coeffs: jnp.ndarray,
                     bt: int = 2048,
                     plane_dtype=None,
                     interpret: Optional[bool] = None,
                     mix_in_float32: bool = True):
    """Eq. (2) over a stacked pytree via the fused flat-plane kernel:
    pack once → ONE :func:`gossip_plane_pallas` → unpack once, per mix —
    one kernel launch regardless of leaf count (asserted by jaxpr
    inspection in tests/test_kernels.py).

    ``plane_dtype``: plane storage dtype (None → widest leaf dtype;
    ``jnp.bfloat16`` halves the kernel's HBM traffic while f32
    accumulation is preserved — low-precision *accumulation* is a
    separate knob, ``mix_in_float32=False``).

    Drop-in replacement for :func:`repro.core.mixing.mix_dense` (same
    f32 accumulation by default, same output dtypes); selected by
    ``DecentralizedConfig(mix_impl="pallas")``.  The
    :class:`repro.core.plane.PlaneLayout` is static metadata derived
    from the tree structure at trace time, so scans over rounds and
    vmaps over experiments reuse one layout and one compiled kernel.
    """
    layout = PlaneLayout.from_tree(params)
    plane = layout.pack(params, dtype=plane_dtype)
    mixed = gossip_plane_pallas(plane, coeffs, bt=bt, interpret=interpret,
                                mix_in_float32=mix_in_float32)
    return layout.unpack(mixed)


# ----------------------------------------------------------------------
# edge-list segment mix: sparse gather-accumulate over the flat plane
# ----------------------------------------------------------------------
def _edges_kernel(acc_dtype, n_rows, w_ref, i_ref, p_ref, o_ref):
    """One (n_pad, bt) output tile of the edge-list mix.  w_ref / i_ref:
    (d_pad, n_lane) per-edge weights (f32) and neighbour indices (int32) —
    transposed so the big n axis sits on lanes; p_ref: (n_pad, bt) plane
    slab.  The d loop is static (unrolled): step d gathers every
    destination's d-th neighbour row from the slab and accumulates it
    under the gathered per-edge weight — a segment-sum over the padded-ELL
    edge list, O(n·dmax·bt) MACs instead of the dense n²·bt."""
    slab = p_ref[...].astype(acc_dtype)
    w = w_ref[...]
    idx = i_ref[...]
    acc = jnp.zeros(o_ref.shape, acc_dtype)
    for d in range(w.shape[0]):  # d_pad is static → unrolled
        wk = w[d, :n_rows].astype(acc_dtype)[:, None]
        acc = acc + wk * jnp.take(slab, idx[d, :n_rows], axis=0)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bt", "interpret", "mix_in_float32"))
def gossip_edges_pallas(plane: jnp.ndarray, weights: jnp.ndarray,
                        nbr_idx: jnp.ndarray, bt: int = 2048,
                        interpret: Optional[bool] = None,
                        mix_in_float32: bool = True) -> jnp.ndarray:
    """``out[i] = Σ_d weights[i, d] · plane[nbr_idx[i, d]]`` as ONE
    ``pallas_call`` — the sparse counterpart of
    :func:`gossip_plane_pallas`.

    plane: (n, P) — all n node-models' parameters, one row each.
    weights: (n, dmax) per-edge coefficients, already masked
      (``repro.core.mixing.edge_weights`` — zeros on padding slots).
    nbr_idx: (n, dmax) int32 neighbour tables
      (``repro.core.topology.padded_neighbor_tables``; padding = own row).
    bt / interpret / mix_in_float32: as :func:`gossip_plane_pallas`.

    Each grid program streams one (n, bt) plane slab plus the (n, dmax)
    weight/index tables — O(|E|·P) HBM bytes instead of the dense kernel's
    O(n²) coefficient re-fetches per tile (``mix_modeled_hbm_bytes``
    models both; the crossover is 2·dmax < n).  The tables are padded to
    (⌈dmax/8⌉·8, ⌈n/128⌉·128) and transposed so the lane axis carries n;
    padded slots gather row 0 under weight 0 and padded output rows are
    sliced away.
    """
    if interpret is None:
        interpret = default_interpret()
    n, p = plane.shape
    dmax = weights.shape[1]
    sub = 16 if plane.dtype == jnp.bfloat16 else 8
    n_pad = _round_up(n, sub)
    bt = _round_up(min(bt, _round_up(p, 128)), 128)
    p_pad = _round_up(p, bt)
    if (n_pad, p_pad) != (n, p):
        plane = jnp.pad(plane, ((0, n_pad - n), (0, p_pad - p)))
    # tables land in VMEM as (d_pad, n_lane) blocks: sublane (8) on the
    # small dmax axis, lane (128) on n — a (n, dmax) layout would burn a
    # full 128-lane tile on dmax ≈ 3 ring graphs
    d_pad = _round_up(dmax, 8)
    n_lane = _round_up(n_pad, 128)
    w = jnp.asarray(weights, jnp.float32).T
    idx = jnp.asarray(nbr_idx, jnp.int32).T
    w = jnp.pad(w, ((0, d_pad - dmax), (0, n_lane - n)))
    idx = jnp.pad(idx, ((0, d_pad - dmax), (0, n_lane - n)))
    acc_dtype = jnp.float32 if mix_in_float32 else plane.dtype

    out = pl.pallas_call(
        functools.partial(_edges_kernel, acc_dtype, n_pad),
        grid=(p_pad // bt,),
        in_specs=[
            pl.BlockSpec((d_pad, n_lane), lambda j: (0, 0)),  # weights
            pl.BlockSpec((d_pad, n_lane), lambda j: (0, 0)),  # neighbours
            pl.BlockSpec((n_pad, bt), lambda j: (0, j)),      # plane slab
        ],
        out_specs=pl.BlockSpec((n_pad, bt), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n_pad, p_pad), plane.dtype),
        interpret=interpret,
    )(w, idx, plane)
    return out[:n, :p]


def mix_edges_pallas(params, coeffs: jnp.ndarray, nbr_idx, nbr_mask,
                     bt: int = 2048,
                     plane_dtype=None,
                     interpret: Optional[bool] = None,
                     mix_in_float32: bool = True):
    """Eq. (2) over a stacked pytree via the edge-list segment kernel:
    pack once → per-edge weight gather
    (``repro.core.mixing.edge_weights``, O(n·dmax)) → ONE
    :func:`gossip_edges_pallas` → unpack once.  Drop-in replacement for
    ``repro.core.mixing.mix_dense`` / :func:`mix_plane_pallas` on any
    support; selected by ``DecentralizedConfig(mix_impl="edges")``.  The
    tables are static trace-time data (baked into scans and vmaps); the
    coefficients stay traced, so per-round matrices reuse one compiled
    kernel.  Agrees with the dense einsum to 1e-6
    (tests/test_mix_equivalence.py)."""
    from repro.core.mixing import edge_weights

    layout = PlaneLayout.from_tree(params)
    plane = layout.pack(params, dtype=plane_dtype)
    w = edge_weights(jnp.asarray(coeffs, jnp.float32),
                     jnp.asarray(nbr_idx), jnp.asarray(nbr_mask))
    mixed = gossip_edges_pallas(plane, w, jnp.asarray(nbr_idx), bt=bt,
                                interpret=interpret,
                                mix_in_float32=mix_in_float32)
    return layout.unpack(mixed)


# ----------------------------------------------------------------------
# robust edge-list mix: in-register sort network over the neighbour axis
# ----------------------------------------------------------------------
def _robust_kernel(op, trim_k, acc_dtype, n_rows, w_ref, i_ref, p_ref,
                   o_ref):
    """One (n_pad, bt) output tile of the robust edge-list mix.  Same
    operands as :func:`_edges_kernel` — (d_pad, n_lane) weight/index
    tables, (n_pad, bt) plane slab — but instead of the weighted
    accumulate, every destination's (d_pad, bt) neighbour slab is
    gathered into registers and reduced by
    ``repro.core.mixing.robust_combine``: an odd-even transposition sort
    over the STATIC d_pad axis followed by the trimmed-mean /
    coordinate-median selection with weight-mass renormalization.
    Padding slots (weight 0) sort past every real value and are excluded
    from the order statistics; the destination's own row is the fallback
    when everything is trimmed.  VMEM working set is O(d_pad·n_pad·bt)
    for the sorted pairs — ``bt`` is the knob if d_pad·n grows."""
    from repro.core.mixing import robust_combine

    slab = p_ref[...].astype(acc_dtype)
    w = w_ref[...]
    idx = i_ref[...]
    vals = jnp.stack(
        [jnp.take(slab, idx[d, :n_rows], axis=0) for d in range(w.shape[0])],
        axis=0)                                    # (d_pad, n_pad, bt)
    out = robust_combine(vals, w[:, :n_rows].astype(acc_dtype),
                         slab[:n_rows], op, trim_k=trim_k)
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("op", "trim_k", "bt", "interpret",
                                    "mix_in_float32"))
def gossip_robust_pallas(plane: jnp.ndarray, weights: jnp.ndarray,
                         nbr_idx: jnp.ndarray, op: str = "trimmed",
                         trim_k: int = 1, bt: int = 512,
                         interpret: Optional[bool] = None,
                         mix_in_float32: bool = True) -> jnp.ndarray:
    """Robust Eq. (2) over the padded-ELL tables as ONE ``pallas_call`` —
    the Byzantine-resilient counterpart of :func:`gossip_edges_pallas`
    (DESIGN.md §16).

    plane / weights / nbr_idx / interpret / mix_in_float32: exactly as
    :func:`gossip_edges_pallas` (tables padded to (⌈dmax/8⌉·8,
    ⌈n/128⌉·128) and transposed; padded slots gather row 0 under weight
    0, which the robust rule excludes by occupancy rather than by
    multiplying to zero).
    op / trim_k: the robust rule — see
    ``repro.core.mixing.robust_combine``.
    bt: plane tile width; smaller than the mean kernels' default because
    each program holds the (d_pad, n_pad, bt) sorted-pair working set in
    VMEM, not just one slab.

    Bit-identical to the masked-sort reference
    ``repro.core.mixing.mix_robust_tables`` — the sort network is stable,
    so the table padding this kernel adds cannot change the result
    (tests/test_robust_mix.py).
    """
    if interpret is None:
        interpret = default_interpret()
    n, p = plane.shape
    dmax = weights.shape[1]
    sub = 16 if plane.dtype == jnp.bfloat16 else 8
    n_pad = _round_up(n, sub)
    bt = _round_up(min(bt, _round_up(p, 128)), 128)
    p_pad = _round_up(p, bt)
    if (n_pad, p_pad) != (n, p):
        plane = jnp.pad(plane, ((0, n_pad - n), (0, p_pad - p)))
    d_pad = _round_up(dmax, 8)
    n_lane = _round_up(n_pad, 128)
    w = jnp.asarray(weights, jnp.float32).T
    idx = jnp.asarray(nbr_idx, jnp.int32).T
    w = jnp.pad(w, ((0, d_pad - dmax), (0, n_lane - n)))
    idx = jnp.pad(idx, ((0, d_pad - dmax), (0, n_lane - n)))
    acc_dtype = jnp.float32 if mix_in_float32 else plane.dtype

    out = pl.pallas_call(
        functools.partial(_robust_kernel, op, trim_k, acc_dtype, n_pad),
        grid=(p_pad // bt,),
        in_specs=[
            pl.BlockSpec((d_pad, n_lane), lambda j: (0, 0)),  # weights
            pl.BlockSpec((d_pad, n_lane), lambda j: (0, 0)),  # neighbours
            pl.BlockSpec((n_pad, bt), lambda j: (0, j)),      # plane slab
        ],
        out_specs=pl.BlockSpec((n_pad, bt), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n_pad, p_pad), plane.dtype),
        interpret=interpret,
    )(w, idx, plane)
    return out[:n, :p]


def mix_robust_pallas(params, coeffs: jnp.ndarray, nbr_idx, nbr_mask,
                      op: str = "trimmed", trim_k: int = 1, bt: int = 512,
                      plane_dtype=None,
                      interpret: Optional[bool] = None,
                      mix_in_float32: bool = True):
    """Robust Eq. (2) over a stacked pytree: pack once → per-edge weight
    gather → ONE :func:`gossip_robust_pallas` → unpack once.  Drop-in
    peer of :func:`mix_edges_pallas` selected by
    ``repro.core.decentralized.make_mix_fn(mix_impl="edges",
    robust="trimmed"|"median")``; bit-identical to the jnp reference
    ``repro.core.mixing.mix_robust_tables``."""
    from repro.core.mixing import edge_weights

    layout = PlaneLayout.from_tree(params)
    plane = layout.pack(params, dtype=plane_dtype)
    w = edge_weights(jnp.asarray(coeffs, jnp.float32),
                     jnp.asarray(nbr_idx), jnp.asarray(nbr_mask))
    mixed = gossip_robust_pallas(plane, w, jnp.asarray(nbr_idx), op=op,
                                 trim_k=trim_k, bt=bt, interpret=interpret,
                                 mix_in_float32=mix_in_float32)
    return layout.unpack(mixed)


def mix_modeled_hbm_bytes(impl: str, n: int, p_floats: int,
                          itemsize: int = 4, n_leaves: int = 1,
                          bt: int = 2048, max_neighbors: Optional[int] = None,
                          n_offsets: Optional[int] = None) -> int:
    """Modeled HBM bytes for one mix of an n-node model with ``p_floats``
    parameters per node (``itemsize`` bytes each, split over ``n_leaves``
    pytree leaves) — the numbers ``BENCH_mix.json`` tracks.

    * ``"einsum"``   — one XLA GEMM per leaf: stream the stacked params
      in and out once, re-reading the (n, n) matrix per leaf:
      ``2·n·P·b + n_leaves·n²·4``.
    * ``"pallas_rows"`` — the legacy ``mix_dense_pallas`` fan-out: every
      destination row of every leaf re-reads its full (n, |leaf|) slab:
      ``n·(n+1)·P·b`` plus per-program weight vectors (``n²·4·n_leaves``).
    * ``"pallas_plane"`` — the fused kernel: stream the plane in and out
      once plus per-tile coefficient re-fetches:
      ``2·n·P·b + ⌈P/bt⌉·n²·4``.
    * ``"pallas_plane_e2e"`` — fused kernel plus the pack/unpack copies
      around it (each a read + write of the plane): ``6·n·P·b + ...`` —
      the honest end-to-end figure when the mix is used leaf-in/leaf-out.
    * ``"edges"`` — the edge-list segment kernel
      (:func:`gossip_edges_pallas`; needs ``max_neighbors`` = the table
      width dmax): stream the plane in and out once plus per-tile table
      re-fetches (f32 weight + int32 index per edge slot):
      ``2·n·P·b + ⌈P/bt⌉·n·dmax·8``.  Beats ``"pallas_plane"`` exactly
      when ``2·dmax < n`` — every paper topology from n ≈ 64 up.
    * ``"edges_robust"`` — the robust sort-network kernel
      (:func:`gossip_robust_pallas`; needs ``max_neighbors``): identical
      HBM traffic to ``"edges"`` — each neighbour row is still gathered
      exactly once per tile and the sort runs entirely in registers/VMEM
      — so robustness costs compute and VMEM working set
      (O(d_pad·n·bt) sorted pairs), never extra HBM.  Dominance
      (robust ≥ edges, and < pallas_plane whenever 2·dmax < n) is pinned
      in tests/test_robust_mix.py.
    * ``"sparse"`` — the circulant ring-offset schedule
      (``repro.core.mixing.mix_sparse``; needs ``n_offsets`` = the static
      offset count K incl. 0): each offset reads the full plane once and
      the accumulator is written once — ``(K+1)·n·P·b`` plus the K
      per-offset weight vectors (``K·n·4``).
    """
    coeff = n * n * 4
    if impl == "einsum":
        return 2 * n * p_floats * itemsize + n_leaves * coeff
    if impl == "pallas_rows":
        return n * (n + 1) * p_floats * itemsize + n_leaves * n * n * 4
    if impl == "sparse":
        if n_offsets is None:
            raise ValueError("impl='sparse' needs n_offsets (the circulant "
                             "schedule's static offset count, incl. 0)")
        return ((n_offsets + 1) * n * p_floats * itemsize
                + n_offsets * n * 4)
    tiles = -(-p_floats // bt)
    if impl in ("edges", "edges_robust"):
        if max_neighbors is None:
            raise ValueError(f"impl={impl!r} needs max_neighbors (the "
                             "padded-ELL table width dmax)")
        return (2 * n * p_floats * itemsize
                + tiles * n * max_neighbors * 8)
    if impl == "pallas_plane":
        return 2 * n * p_floats * itemsize + tiles * coeff
    if impl == "pallas_plane_e2e":
        return 6 * n * p_floats * itemsize + tiles * coeff
    raise KeyError(f"unknown impl {impl!r}")


# ----------------------------------------------------------------------
# legacy per-row kernel family (benchmark baseline)
# ----------------------------------------------------------------------
def _kernel(w_ref, blocks_ref, out_ref):
    """blocks_ref: (K, bm, bn) VMEM; w_ref: (K,) SMEM-ish; out: (bm, bn)."""
    k = blocks_ref.shape[0]
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    for i in range(k):  # K is static → unrolled MACs
        acc += w_ref[i] * blocks_ref[i].astype(jnp.float32)
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def gossip_mix_pallas(blocks: jnp.ndarray, weights: jnp.ndarray,
                      bm: int = 256, bn: int = 512,
                      interpret: Optional[bool] = None) -> jnp.ndarray:
    """out = Σ_k weights[k] · blocks[k]  (legacy K-way MAC kernel).

    blocks: (K, M, N) — K neighbour copies of one parameter tile-matrix.
    weights: (K,) f32.  M, N padded to tile multiples internally.
    interpret: None → auto (compiled on TPU/GPU, interpret on CPU).

    One call streams its (K, M, N) input once — bytes ≈ (K+1)·M·N·b — but
    the :func:`mix_dense_pallas` wrapper issues n of these per leaf, so
    the *mix* is ~n·(K+1)·|P| bytes; use :func:`mix_plane_pallas` for the
    fused single-call path.
    """
    if interpret is None:
        interpret = default_interpret()
    k, m, n = blocks.shape
    bm = min(bm, m)
    bn = min(bn, n)
    pm = (m + bm - 1) // bm * bm
    pn = (n + bn - 1) // bn * bn
    if (pm, pn) != (m, n):
        blocks = jnp.pad(blocks, ((0, 0), (0, pm - m), (0, pn - n)))

    out = pl.pallas_call(
        _kernel,
        grid=(pm // bm, pn // bn),
        in_specs=[
            pl.BlockSpec((k,), lambda i, j: (0,)),           # weights: tiny, replicated
            pl.BlockSpec((k, bm, bn), lambda i, j: (0, i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pm, pn), blocks.dtype),
        interpret=interpret,
    )(weights.astype(jnp.float32), blocks)
    return out[:m, :n]


def mix_dense_pallas(params, coeffs: jnp.ndarray,
                     interpret: Optional[bool] = None):
    """LEGACY Eq. (2) path, kept as the ``BENCH_mix`` baseline: for each
    leaf ``(n, ...)``, destination row i is the K=n-way MAC
    ``Σ_j C[i,j]·leaf[j]`` — one :func:`gossip_mix_pallas` call vmapped
    over destination rows, i.e. ``n_leaves × n`` kernel programs per mix,
    each re-reading the full leaf slab (~``n·(n+1)·|P|`` HBM bytes; see
    :func:`mix_modeled_hbm_bytes`).  Production aggregation routes
    through :func:`mix_plane_pallas` instead
    (``DecentralizedConfig(mix_impl="pallas")``).
    """
    c = jnp.asarray(coeffs, jnp.float32)
    n = c.shape[0]

    def leaf_fn(leaf: jnp.ndarray) -> jnp.ndarray:
        flat = leaf.reshape(n, 1, -1)  # (K=n, M=1, N=prod(rest))
        out = jax.vmap(
            lambda w: gossip_mix_pallas(flat, w, bm=1, interpret=interpret)
        )(c)  # (n, 1, N)
        return out.reshape(leaf.shape).astype(leaf.dtype)

    return jax.tree.map(leaf_fn, params)
