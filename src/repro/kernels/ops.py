"""Public jit'd kernel entry points.

Model code calls these; each dispatches to the Pallas kernel with
``interpret=True`` off-TPU (this container) and compiled mode on real TPU.
Signatures match the pure-jnp oracles in ``ref.py`` one-for-one.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gossip_mix import (
    gossip_mix_pallas,
    gossip_plane_pallas,
    mix_plane_pallas,
)
from repro.kernels.mla_attention import mla_attention_pallas
from repro.kernels.ssm_scan import rwkv_scan_pallas

__all__ = ["flash_attention", "gossip_mix", "gossip_plane", "mix_plane",
           "rwkv_scan", "mla_attention", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    logit_softcap: float = 0.0):
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, logit_softcap=logit_softcap,
        interpret=not on_tpu(),
    )


def gossip_mix(blocks, weights):
    return gossip_mix_pallas(blocks, weights, interpret=not on_tpu())


def gossip_plane(plane, coeffs, bt: int = 2048):
    """Fused flat-plane mix: ``coeffs @ plane`` as ONE pallas_call.
    interpret=None → compiled on TPU *and* GPU, interpreter on CPU."""
    return gossip_plane_pallas(plane, coeffs, bt=bt, interpret=None)


def mix_plane(params, coeffs, bt: int = 2048):
    """Pytree-level fused mix (pack → one kernel → unpack);
    backend auto-detected like :func:`gossip_plane`."""
    return mix_plane_pallas(params, coeffs, bt=bt, interpret=None)


def rwkv_scan(r, k, v, w, u, state, chunk: int = 64):
    return rwkv_scan_pallas(r, k, v, w, u, state, chunk=chunk,
                            interpret=not on_tpu())


def mla_attention(q_lat, q_rope, c_kv, k_rope):
    return mla_attention_pallas(q_lat, q_rope, c_kv, k_rope,
                                interpret=not on_tpu())
