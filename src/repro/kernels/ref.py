"""Pure-jnp oracles for every Pallas kernel (the correctness references).

Each function is the mathematically-direct implementation the kernels are
tested against with ``np.testing.assert_allclose`` across shape/dtype
sweeps (tests/test_kernels.py).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["gossip_mix_ref", "flash_attention_ref", "rwkv_scan_ref",
           "mla_attention_ref"]


def gossip_mix_ref(blocks: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """out = Σ_k weights[k] · blocks[k].  blocks: (K, M, N); weights: (K,)."""
    acc = jnp.tensordot(weights.astype(jnp.float32),
                        blocks.astype(jnp.float32), axes=(0, 0))
    return acc.astype(blocks.dtype)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True, window: int = 0,
                        logit_softcap: float = 0.0) -> jnp.ndarray:
    """Naive attention.  q: (B,S,H,hd); k/v: (B,S,KV,hd) (GQA: H % KV == 0)."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    groups = h // kv
    qg = q.reshape(b, s, kv, groups, hd)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    if logit_softcap > 0:
        logits = jnp.tanh(logits / logit_softcap) * logit_softcap
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    ok = jnp.ones((s, s), bool)
    if causal:
        ok &= ki <= qi
    if window > 0:
        ok &= ki > qi - window
    logits = jnp.where(ok[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, hd).astype(q.dtype)


def rwkv_scan_ref(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  w: jnp.ndarray, u: jnp.ndarray, state: jnp.ndarray):
    """Sequential RWKV-6 recurrence (the ground truth).

    r,k,v,w: (B,S,H,hd); u: (H,hd); state: (B,H,hd,hd) f32.
      y_t = r_tᵀ (S_{t-1} + diag(u) k_t v_tᵀ);  S_t = diag(w_t) S_{t-1} + k_t v_tᵀ
    Returns (y (B,S,H,hd) f32→q.dtype, final state f32).
    """

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp
        kv_t = jnp.einsum("bhk,bhv->bhkv", k_t.astype(jnp.float32),
                          v_t.astype(jnp.float32))
        y_t = jnp.einsum("bhk,bhkv->bhv", r_t.astype(jnp.float32),
                         S + u[None, :, :, None] * kv_t)
        S = w_t.astype(jnp.float32)[..., None] * S + kv_t
        return S, y_t

    seq = tuple(x.swapaxes(0, 1) for x in (r, k, v, w))
    state, ys = jax.lax.scan(step, state.astype(jnp.float32), seq)
    return ys.swapaxes(0, 1).astype(r.dtype), state


def mla_attention_ref(q_lat, q_rope, c_kv, k_rope):
    """Naive latent-space MLA attention (caller pre-scales q).

    q_lat: (B,S,H,r); q_rope: (B,S,H,dr); c_kv: (B,T,r); k_rope: (B,T,dr)
    → latent context (B,S,H,r), causal.
    """
    b, s, h, r = q_lat.shape
    t = c_kv.shape[1]
    logits = jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32),
                        c_kv.astype(jnp.float32))
    logits += jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32),
                         k_rope.astype(jnp.float32))
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(t)[None, :]
    logits = jnp.where((ki <= qi)[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhst,btr->bshr", probs, c_kv.astype(jnp.float32))
    return ctx.astype(q_lat.dtype)
