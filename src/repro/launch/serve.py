"""Serving driver: per-node batched generation over a gossip-trained fleet.

Loads a checkpoint produced by ``repro.launch.train`` (or inits fresh
params), then serves batched greedy generation requests against every
node's own model — the paper's deployment mode (device-specific models,
no global model).

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b --smoke \
      --nodes 4 --batch 2 --prompt-len 8 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, get_smoke_config
from repro.models.transformer import init_params
from repro.serving.serve_step import make_cache, make_serve_step
from repro.training.checkpoint import latest_checkpoint, load_checkpoint


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2, help="requests per node")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    n, b = args.nodes, args.batch
    max_seq = args.prompt_len + args.new_tokens

    one = init_params(jax.random.key(args.seed), cfg)
    params = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape).copy(), one)
    if args.ckpt_dir:
        path = latest_checkpoint(args.ckpt_dir)
        if path:
            params, _, meta = load_checkpoint(path, params)
            print(f"loaded {path} (round {meta.get('step')})")

    serve = jax.jit(make_serve_step(cfg))
    cache = make_cache(cfg, n, b, max_seq)
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(n, b, args.prompt_len)), jnp.int32)

    # prefill token-by-token through the decode path (exercises the cache)
    t0 = time.time()
    logits = None
    for i in range(args.prompt_len):
        logits, cache = serve(params, prompts[:, :, i : i + 1], cache)
    prefill_s = time.time() - t0

    out = [prompts]
    t0 = time.time()
    for _ in range(args.new_tokens):
        nxt = jnp.argmax(logits[:, :, -1], axis=-1)[..., None]
        out.append(nxt)
        logits, cache = serve(params, nxt, cache)
    decode_s = time.time() - t0
    tokens = jnp.concatenate(out, axis=-1)

    tput = n * b * args.new_tokens / decode_s
    print(f"served {n} nodes × {b} requests: prefill {prefill_s:.2f}s, "
          f"decode {decode_s:.2f}s ({tput:.1f} tok/s aggregate)")
    print("node 0, request 0:", np.asarray(tokens[0, 0]).tolist())
    return tokens


if __name__ == "__main__":
    main()
