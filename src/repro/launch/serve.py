"""Serving driver: continuous batching over a gossip-trained fleet.

Loads a checkpoint produced by ``repro.launch.train`` (or inits fresh
params), then serves batched greedy generation requests against every
node's own model — the paper's deployment mode (device-specific models,
no global model).  The fleet runs behind :class:`FleetScheduler`: the
stacked per-node params are packed into ONE ``(n, P)`` parameter plane
and every scheduler step advances all nodes' slot batches in a single
compiled dispatch (chunked prefill with self-feeding decode lanes).
``--loop`` falls back to the per-node Python-loop baseline that
``benchmarks/serve_bench.py`` measures against.

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b --smoke \
      --nodes 4 --batch 2 --prompt-len 8 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, get_smoke_config
from repro.models.transformer import init_params
from repro.serving.scheduler import FleetScheduler, Request
from repro.training.checkpoint import latest_checkpoint, load_checkpoint


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2, help="requests per node")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=8)
    ap.add_argument("--loop", action="store_true",
                    help="per-node Python loop instead of the fleet-vmapped "
                         "plane-fed step")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    n, b = args.nodes, args.batch
    max_seq = args.prompt_len + args.new_tokens + 1

    one = init_params(jax.random.key(args.seed), cfg)
    params = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape).copy(), one)
    if args.ckpt_dir:
        path = latest_checkpoint(args.ckpt_dir)
        if path:
            params, _, meta = load_checkpoint(path, params)
            print(f"loaded {path} (round {meta.get('step')})")

    fleet = FleetScheduler(cfg, params, n_nodes=n, n_slots=b,
                           max_seq=max_seq, prefill_chunk=args.prefill_chunk,
                           vmapped=not args.loop)
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size, size=(n, b, args.prompt_len))
    reqs = []
    for node in range(n):
        for j in range(b):
            req = Request(rid=node * b + j,
                          prompt=prompts[node, j].tolist(),
                          max_new=args.new_tokens)
            fleet.submit(req, node=node)
            reqs.append(req)

    t0 = time.time()
    steps = fleet.run_until_drained()
    wall = time.time() - t0
    assert all(r.done for r in reqs)

    gen = sum(len(r.output) for r in reqs)
    mode = "per-node loop" if args.loop else "fleet-vmapped plane"
    print(f"served {n} nodes × {b} requests ({mode}): {steps} steps, "
          f"{wall:.2f}s ({gen / max(wall, 1e-9):.1f} tok/s aggregate)")
    print("node 0, request 0:", reqs[0].prompt + reqs[0].output)
    return reqs


if __name__ == "__main__":
    main()
