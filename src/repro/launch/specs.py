"""ShapeDtypeStruct input factories for the dry-run (no allocation).

``input_specs(arch, shape, multi_pod)`` returns everything ``dryrun.py``
needs to lower a step for one (architecture × input shape) pair:
abstract params / optimizer state / batch / cache plus their
PartitionSpecs, and which step function to lower.

Shape → step mapping (per the assignment):
  train_4k               → train_step   (tokens + labels)
  prefill_32k            → prefill_step (last-position logits)
  decode_32k, long_500k  → serve_step   (ONE token vs a seq_len cache)

long_500k is applicable only to sub-quadratic archs (``applicable_shapes``
encodes the skip rule; skips are recorded, not silently dropped).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig, ParallelConfig, SHAPES
from repro.configs.registry import get_config, get_parallel
from repro.models.transformer import init_cache, init_params
from repro.sharding import param_specs, opt_specs_like
from repro.training.optimizer import make_optimizer

__all__ = ["DryRunSpec", "input_specs", "applicable_shapes", "LONG_CTX_OK"]

NODE_AXES = ("pod", "node")

# long_500k rule: SSM/hybrid always; dense only with a sliding-window
# variant; pure full-attention archs skip (DESIGN.md §4).
LONG_CTX_OK = {
    "rwkv6-3b": "ssm: O(1) state",
    "hymba-1.5b": "hybrid: SSM state + mostly-local attention",
    "gemma2-27b": "sliding-window variant on alternating layers",
    "starcoder2-7b": "sliding-window variant on alternating layers",
}
LONG_CTX_SKIP = {
    "musicgen-medium": "pure full attention (48L MHA) — no sub-quadratic variant",
    "stablelm-1.6b": "pure full attention — no sub-quadratic variant",
    "phi3-mini-3.8b": "pure full attention — no sub-quadratic variant",
    "internvl2-1b": "pure full attention — no sub-quadratic variant",
    "llama4-scout-17b-a16e": "full attention in this config — skip per rule",
    "deepseek-v2-236b": "full (latent) attention; MLA shrinks the cache but "
                        "attention stays O(L) per token / O(L²) prefill — skip per rule",
}


def applicable_shapes(arch: str):
    out = []
    for name, shape in SHAPES.items():
        if name == "long_500k" and arch not in LONG_CTX_OK:
            continue
        out.append(shape)
    return out


@dataclasses.dataclass
class DryRunSpec:
    arch: str
    shape: InputShape
    kind: str                      # train | prefill | decode
    n_global_nodes: int
    abstract_args: Tuple[Any, ...]     # ShapeDtypeStructs, step-ordered
    in_specs: Tuple[Any, ...]          # PartitionSpec trees, same order
    out_specs: Any
    meta: Dict[str, Any]


def _abstract(tree, sharding_tree=None):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _abstract_params(cfg: ModelConfig, n_nodes: int):
    """Stacked abstract params: eval_shape the real init, prepend node axis."""
    one = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.key(0))
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((n_nodes,) + x.shape, x.dtype), one
    )


def _abstract_opt(cfg: ModelConfig, pcfg: ParallelConfig, stacked_params):
    opt = make_optimizer("adamw", 3e-4)

    def init_one(p):
        return opt.init(p)

    # vmap the abstract init over the node axis
    return jax.eval_shape(jax.vmap(init_one), stacked_params)


def _train_inputs(cfg: ModelConfig, pcfg: ParallelConfig, shape: InputShape,
                  n_global: int):
    gb, s = shape.global_batch, shape.seq_len
    local = max(1, gb // n_global)
    fsdp = pcfg.fsdp
    micro = max(1, min(pcfg.microbatch, local))
    # microbatch must divide the local batch AND leave each microbatch
    # divisible by the fsdp axis (batch shards over fsdp)
    while micro > 1 and (local % micro or (local // micro) % fsdp):
        micro -= 1
    mb = local // micro
    use_fsdp_batch = mb % fsdp == 0
    batch: Dict[str, Any] = {}
    if cfg.frontend is not None:
        batch["embeddings"] = jax.ShapeDtypeStruct(
            (n_global, micro, mb, s, cfg.frontend_dim), jnp.bfloat16)
    else:
        batch["tokens"] = jax.ShapeDtypeStruct((n_global, micro, mb, s), jnp.int32)
    batch["labels"] = jax.ShapeDtypeStruct((n_global, micro, mb, s), jnp.int32)

    nd = NODE_AXES
    b_axis = "fsdp" if use_fsdp_batch else None
    specs = {
        k: P(nd, None, b_axis, *([None] * (len(v.shape) - 3)))
        for k, v in batch.items()
    }
    return batch, specs, dict(micro=micro, local_batch=local)


def _decode_inputs(cfg: ModelConfig, shape: InputShape, n_global: int,
                   multi_pod: bool, tp: int = 16):
    """serve_step inputs: tokens (N, B, 1) + stacked cache."""
    gb, s = shape.global_batch, shape.seq_len
    if shape.name == "long_500k":
        # single stream: node axis idles for batch; the CACHE sequence dim
        # shards over (pod, node, fsdp) instead (sequence-sharded decode).
        n_serve, local = 1, 1
        seq_axes = ("pod", "node", "fsdp") if multi_pod else ("node", "fsdp")
        batch_axis = None
    else:
        n_serve = n_global
        local = max(1, gb // n_global)
        seq_axes = None
        batch_axis = "fsdp"

    tokens = jax.ShapeDtypeStruct((n_serve, local, 1), jnp.int32)
    cache_one = jax.eval_shape(lambda: init_cache(cfg, local, s))
    cache = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((n_serve,) + x.shape, x.dtype), cache_one
    )
    node_axes = NODE_AXES if n_serve > 1 else (None,)

    def cspec(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        nd = NODE_AXES if n_serve > 1 else None
        if name == "position":
            return P(nd, batch_axis)
        ndim = leaf.ndim
        spec = [None] * ndim
        spec[0] = nd
        if ndim >= 3:
            spec[2] = batch_axis
        # sequence dim (index 3 for k/v/ckv/kr) → seq sharding for long ctx
        if name in ("k", "v", "ckv", "kr") and ndim >= 4 and seq_axes:
            spec[3] = seq_axes
        # head-ish dims over model where divisible
        dim_for_model = {"k": 4, "v": 4, "rwkv_state": 3, "ssm_state": 3,
                         "conv_state": 4}.get(name)
        if dim_for_model is not None and dim_for_model < ndim:
            if leaf.shape[dim_for_model] % tp == 0:
                spec[dim_for_model] = "model"
        return P(*spec)

    cache_spec = jax.tree_util.tree_map_with_path(cspec, cache)
    tok_spec = P(NODE_AXES if n_serve > 1 else None, batch_axis, None)
    return tokens, cache, tok_spec, cache_spec, dict(
        n_serve=n_serve, local_batch=local, seq_axes=seq_axes)


def input_specs(arch: str, shape_name: str, *, multi_pod: bool = False,
                cfg: Optional[ModelConfig] = None,
                pcfg: Optional[ParallelConfig] = None) -> DryRunSpec:
    cfg = cfg or get_config(arch)
    pcfg = pcfg or get_parallel(arch)
    shape = SHAPES[shape_name]
    pods = 2 if multi_pod else 1
    n_global = pods * pcfg.n_nodes

    axis_sizes = {"model": pcfg.tp_degree, "fsdp": pcfg.fsdp}
    p_abs = _abstract_params(cfg, n_global)
    p_specs = param_specs(p_abs, node_axes=NODE_AXES, axis_sizes=axis_sizes)

    if shape.kind == "train":
        opt_abs = _abstract_opt(cfg, pcfg, p_abs)
        o_specs = opt_specs_like(opt_abs, p_specs, node_axes=NODE_AXES)
        batch, b_specs, meta = _train_inputs(cfg, pcfg, shape, n_global)
        coeffs = jax.ShapeDtypeStruct((n_global, n_global), jnp.float32)
        return DryRunSpec(
            arch=arch, shape=shape, kind="train", n_global_nodes=n_global,
            abstract_args=(p_abs, opt_abs, batch, coeffs),
            in_specs=(p_specs, o_specs, b_specs, P()),
            out_specs=(p_specs, o_specs, P()),
            meta=meta,
        )

    if shape.kind == "prefill":
        local = max(1, shape.global_batch // n_global)
        if cfg.frontend is not None:
            b = {"embeddings": jax.ShapeDtypeStruct(
                (n_global, local, shape.seq_len, cfg.frontend_dim), jnp.bfloat16)}
            bs = {"embeddings": P(NODE_AXES, "fsdp", None, None)}
        else:
            b = {"tokens": jax.ShapeDtypeStruct(
                (n_global, local, shape.seq_len), jnp.int32)}
            bs = {"tokens": P(NODE_AXES, "fsdp", None)}
        return DryRunSpec(
            arch=arch, shape=shape, kind="prefill", n_global_nodes=n_global,
            abstract_args=(p_abs, b),
            in_specs=(p_specs, bs),
            out_specs=P(NODE_AXES, "fsdp", None),
            meta=dict(local_batch=local),
        )

    # decode
    tokens, cache, tok_spec, cache_spec, meta = _decode_inputs(
        cfg, shape, n_global, multi_pod, tp=pcfg.tp_degree)
    n_serve = meta["n_serve"]
    if n_serve != n_global:  # long_500k: one replica, params node dim = 1
        p_abs = _abstract_params(cfg, n_serve)
        p_specs = param_specs(p_abs, node_axes=(None,), axis_sizes=axis_sizes)
        # FSDP keeps shards meaningful: weight dims still over fsdp/model.
    return DryRunSpec(
        arch=arch, shape=shape, kind="decode", n_global_nodes=n_serve,
        abstract_args=(p_abs, tokens, cache),
        in_specs=(p_specs, tok_spec, cache_spec),
        out_specs=(P(NODE_AXES if n_serve > 1 else None,
                     meta.get("batch_axis"), None, None), cache_spec),
        meta=meta,
    )
