"""Mesh factories for the production deployment (DESIGN.md §5).

Physical fabric: one pod = 16×16 = 256 chips; multi-pod = 2 pods = 512.

Two views of the same chips:

* :func:`make_production_mesh` — the assignment's canonical axes
  ``(data, model)`` / ``(pod, data, model)``.
* :func:`make_training_mesh` — the gossip-aware split of the ``data`` axis
  into ``(node, fsdp)``: ``node`` carries the paper's topology devices,
  ``fsdp`` shards each node's model copy.  ``data = node × fsdp`` — same
  256/512 chips, finer names.  Every arch's ``ParallelConfig.n_nodes``
  picks the split (memory math in DESIGN.md §5).

Everything is a FUNCTION (no module-level jax device state) so importing
this module never initializes the backend.
"""
from __future__ import annotations

from typing import Optional

import jax

__all__ = ["compat_make_mesh", "make_production_mesh", "make_training_mesh",
           "make_sweep_mesh", "POD_DATA", "POD_MODEL"]

POD_DATA = 16
POD_MODEL = 16


def compat_make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types across jax versions:
    ``AxisType`` only exists on newer jax; older releases have no explicit
    sharding mode, so every axis is already Auto."""
    try:
        from jax.sharding import AxisType
    except ImportError:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


_mesh = compat_make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """The assignment's canonical production mesh."""
    shape = (2, POD_DATA, POD_MODEL) if multi_pod else (POD_DATA, POD_MODEL)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_sweep_mesh(n_devices: Optional[int] = None,
                    axis_name: str = "exp"):
    """1-D mesh over the sweep engine's experiment axis (DESIGN.md §8).

    ``SweepEngine.run(mesh=make_sweep_mesh())`` lays the E experiment axis
    across all local devices (or the first ``n_devices``).  Testable on
    CPU by launching with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
    """
    n = n_devices or len(jax.devices())
    return _mesh((n,), (axis_name,))


def make_training_mesh(n_nodes: int = 16, *, tp: int = POD_MODEL,
                       multi_pod: bool = False):
    """Gossip-aware mesh: (pod, node, fsdp, model).

    ``n_nodes`` topology nodes per pod, ``tp`` tensor-parallel degree;
    ``fsdp = 256 // (n_nodes · tp)`` shards within each node's model copy.
    Total chips = 256 per pod (512 multi-pod), identical to the production
    mesh — the pod's 2-D chip grid is just factored with finer names.
    The default (n_nodes=16, tp=16) matches the canonical
    (data=16, model=16) view; §Perf replans pick other factorizations
    (e.g. stablelm n_nodes=64, tp=4).
    """
    chips = POD_DATA * POD_MODEL
    if chips % (n_nodes * tp) != 0:
        raise ValueError(
            f"n_nodes·tp = {n_nodes}·{tp} must divide pod size {chips}")
    fsdp = chips // (n_nodes * tp)
    pods = 2 if multi_pod else 1
    return _mesh((pods, n_nodes, fsdp, tp), ("pod", "node", "fsdp", "model"))
