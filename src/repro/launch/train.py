"""Production decentralized-training driver.

Runs Alg. 1 at framework scale: every topology node trains its own copy of
the selected architecture on its local token stream; after each round the
stacked params are gossip-mixed with the configured topology-aware
strategy.  On the CPU container this runs the reduced (smoke) configs
end-to-end; on a real mesh the same driver runs the full configs with the
shardings from ``repro.sharding`` (pass ``--mesh``).

Example (CPU, the e2e driver of deliverable b):
  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b --smoke \
      --nodes 8 --rounds 20 --steps 10 --strategy degree --topology ba
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ParallelConfig
from repro.configs.registry import get_config, get_smoke_config
from repro.core.strategies import AggregationStrategy, mixing_matrix
from repro.core.topology import build_topology
from repro.data.pipeline import lm_token_stream
from repro.models.transformer import ForwardOptions, init_params
from repro.training.checkpoint import latest_checkpoint, load_checkpoint, save_checkpoint
from repro.training.optimizer import make_optimizer
from repro.training.train_step import make_train_step


def build_topology_from_args(args, n_nodes):
    kw = {"n": n_nodes, "seed": args.seed}
    if args.topology == "ba":
        kw["p"] = min(args.ba_p, max(n_nodes - 1, 1))  # BA needs p < n
    elif args.topology == "ws":
        kw.update(k=4, u=0.5)
    elif args.topology == "sb":
        kw.update(n_communities=3, p_in=0.5, p_out=args.sb_pout)
    elif args.topology in ("ring", "full"):
        kw = {"n": n_nodes}
    return build_topology(args.topology, **kw)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--steps", type=int, default=10,
                    help="optimizer steps per round (E·steps of Alg. 1)")
    ap.add_argument("--batch", type=int, default=8, help="per-node batch")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--strategy", default="degree",
                    choices=["unweighted", "weighted", "random", "fl",
                             "degree", "betweenness", "metropolis"])
    ap.add_argument("--tau", type=float, default=0.1)
    ap.add_argument("--topology", default="ba",
                    choices=["ba", "ws", "sb", "ring", "full"])
    ap.add_argument("--ba-p", type=int, default=2)
    ap.add_argument("--sb-pout", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log", default=None, help="write round metrics JSONL")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    pcfg = ParallelConfig(n_nodes=args.nodes, microbatch=1, remat=not args.smoke)
    n = args.nodes

    topo = build_topology_from_args(args, n)
    strat = AggregationStrategy(args.strategy, tau=args.tau, seed=args.seed)
    coeffs = jnp.asarray(mixing_matrix(
        topo, strat,
        data_counts=np.full(n, args.batch * args.steps, np.float64)))

    opt = make_optimizer("adamw", args.lr)
    step_fn = jax.jit(make_train_step(
        cfg, pcfg, opt, opts=ForwardOptions(remat=pcfg.remat)))
    no_gossip_fn = jax.jit(make_train_step(
        cfg, pcfg, opt, opts=ForwardOptions(remat=pcfg.remat), gossip=False))

    # common init (decentralized learning starts from a shared init — with
    # per-node inits, averaging destroys the models; see EXPERIMENTS.md)
    one = init_params(jax.random.key(args.seed), cfg)
    params = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape).copy(), one)
    opt_state = jax.vmap(opt.init)(params)

    start_round = 0
    if args.resume and args.ckpt_dir:
        path = latest_checkpoint(args.ckpt_dir)
        if path:
            params, opt_state, meta = load_checkpoint(path, params, opt_state)
            start_round = meta["step"] + 1
            print(f"resumed from {path} at round {start_round}")

    streams = [lm_token_stream(cfg.vocab_size, args.seq, args.batch,
                               seed=args.seed * 1000 + i) for i in range(n)]
    log_f = open(args.log, "a") if args.log else None

    for r in range(start_round, args.rounds):
        t0 = time.time()
        losses = []
        for s in range(args.steps):
            batch = {k: jnp.stack([next(st)[k] for st in streams])
                     for k in ("tokens", "labels")}
            batch = jax.tree.map(lambda x: x[:, None], batch)  # micro=1
            fn = step_fn if s == args.steps - 1 else no_gossip_fn
            params, opt_state, loss = fn(params, opt_state, batch, coeffs)
            losses.append(float(loss))
        rec = dict(round=r, loss=float(np.mean(losses)),
                   secs=round(time.time() - t0, 2))
        print(f"[train] round {r:4d} loss {rec['loss']:.4f} ({rec['secs']}s)")
        if log_f:
            log_f.write(json.dumps(rec) + "\n")
            log_f.flush()
        if args.ckpt_dir and (r + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, r, params, opt_state,
                            metadata=dict(arch=args.arch, strategy=args.strategy))
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.rounds - 1, params, opt_state,
                        metadata=dict(arch=args.arch, strategy=args.strategy))
    if log_f:
        log_f.close()
    return params


if __name__ == "__main__":
    main()
