import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", "")
)
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) combination against the production mesh, and extract the roofline
terms from the compiled artifact.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch stablelm-1.6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out benchmarks/artifacts

Per pair this records (EXPERIMENTS.md §Dry-run / §Roofline):
  * compiled.memory_analysis()  — bytes/device: proves the config fits;
  * compiled.cost_analysis()    — HLO FLOPs & bytes accessed;
  * collective bytes parsed from the compiled HLO (all-gather, all-reduce,
    reduce-scatter, all-to-all, collective-permute operand sizes);
  * the three roofline terms vs TPU v5e constants.
"""
import argparse
import json
import re
import time
import traceback
from typing import Any, Dict

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS, get_config, get_parallel
from repro.launch.mesh import make_training_mesh
from repro.launch.specs import (
    DryRunSpec,
    LONG_CTX_SKIP,
    applicable_shapes,
    input_specs,
)
from repro.models.transformer import ForwardOptions
from repro.serving.serve_step import make_forward_prefill, make_serve_step
from repro.training.optimizer import make_optimizer
from repro.training.train_step import make_train_step

# TPU v5e-class constants (per chip)
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link
HBM_PER_CHIP = 16 * 1024**3

_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "f64": 8,
    "s32": 4, "u32": 4, "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s16": 2, "u16": 2,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _parse_shape_bytes(shape_str: str) -> int:
    """'bf16[16,1024,512]{...}' → bytes.  Tuples handled by the caller."""
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes of every collective op in the compiled HLO.

    Parsed from lines like:
      %ag = bf16[16,...] all-gather(...), replica_groups=...
    (tuple-shaped collectives contribute each element).
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        for coll in _COLLECTIVES:
            # match '= <shape> collective-name(' — covers -start variants
            m = re.search(
                r"=\s+(\(?[a-z0-9]+\[[^=]*?)\s+" + coll + r"(-start|-done)?\(", s
            )
            if not m:
                continue
            if m.group(2) == "-done":   # avoid double counting start/done
                continue
            shapes = re.findall(r"[a-z0-9]+\[[0-9,]*\]", m.group(1))
            nbytes = sum(_parse_shape_bytes(x) for x in shapes)
            out[coll] += nbytes
            out["count"] += 1
            break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def _mem_stats(mem) -> Dict[str, float]:
    """CompiledMemoryStats → per-device byte counts (arguments = resident
    params/opt/cache; temp = activation workspace; peak = high-water)."""
    out = {}
    for name in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "peak_memory_in_bytes", "generated_code_size_in_bytes"):
        out[name.replace("_size_in_bytes", "_bytes")
                .replace("_in_bytes", "_bytes")] = int(getattr(mem, name, 0))
    return out


def build_step(spec: DryRunSpec, cfg, pcfg):
    opts = ForwardOptions(remat=pcfg.remat, use_scan=pcfg.scan_layers,
                          attn_impl="chunked")
    if spec.kind == "train":
        opt = make_optimizer("adamw", 3e-4)
        return make_train_step(cfg, pcfg, opt, opts=opts)
    if spec.kind == "prefill":
        return make_forward_prefill(cfg, opts=opts, last_only=True)
    return make_serve_step(cfg, opts=ForwardOptions(remat=False,
                                                    use_scan=pcfg.scan_layers))


def dry_run_pair(arch: str, shape_name: str, multi_pod: bool,
                 verbose: bool = True, pcfg=None) -> Dict[str, Any]:
    cfg = get_config(arch)
    pcfg = pcfg or get_parallel(arch)
    t0 = time.time()
    spec = input_specs(arch, shape_name, multi_pod=multi_pod, cfg=cfg, pcfg=pcfg)
    mesh = make_training_mesh(pcfg.n_nodes, tp=pcfg.tp_degree,
                              multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    step = build_step(spec, cfg, pcfg)

    def shardify(spec_tree):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s), spec_tree,
            is_leaf=lambda x: isinstance(x, P))

    in_sh = tuple(shardify(s) for s in spec.in_specs)
    out_sh = shardify(spec.out_specs)
    with mesh:
        lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh) \
            .lower(*spec.abstract_args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()

    coll = collective_bytes(hlo)
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))

    # roofline terms (per chip; cost_analysis reports per-partition HLO)
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_accessed / HBM_BW
    t_collective = (coll["total"]) / ICI_BW
    dominant = max(
        [("compute", t_compute), ("memory", t_memory),
         ("collective", t_collective)], key=lambda kv: kv[1])[0]

    n_total = cfg.param_count()
    n_active = cfg.active_param_count()
    tokens = (SHAPES[shape_name].global_batch * SHAPES[shape_name].seq_len
              if spec.kind == "train" else
              SHAPES[shape_name].global_batch * SHAPES[shape_name].seq_len
              if spec.kind == "prefill" else SHAPES[shape_name].global_batch)
    mult = 6 if spec.kind == "train" else 2
    model_flops = mult * n_active * tokens
    # per-chip useful flops for the ratio against per-partition HLO flops
    model_flops_per_chip = model_flops / n_chips

    mem_stats = _mem_stats(mem)
    result = dict(
        arch=arch, shape=shape_name, kind=spec.kind,
        mesh="pod2x16x16" if multi_pod else "pod16x16",
        n_chips=n_chips, n_nodes=spec.n_global_nodes,
        compile_s=round(time.time() - t0, 1),
        flops_per_chip=flops, bytes_per_chip=bytes_accessed,
        collective_bytes=coll["total"], collective_ops=coll["count"],
        collective_breakdown={k: coll[k] for k in _COLLECTIVES},
        t_compute_s=t_compute, t_memory_s=t_memory,
        t_collective_s=t_collective, dominant=dominant,
        model_flops=model_flops, model_flops_per_chip=model_flops_per_chip,
        useful_flops_ratio=(model_flops_per_chip / flops) if flops else 0.0,
        params_total=n_total, params_active=n_active,
        memory=mem_stats,
        meta=spec.meta,
    )
    if verbose:
        fit = (mem_stats.get("argument_bytes", 0)
               + mem_stats.get("temp_bytes", 0)) / max(n_chips, 1)
        print(f"[dryrun] {arch:24s} {shape_name:12s} "
              f"{'2pod' if multi_pod else '1pod'}  "
              f"compile={result['compile_s']:6.1f}s  "
              f"flops/chip={flops:.3e}  bytes/chip={bytes_accessed:.3e}  "
              f"coll={coll['total']:.3e}B  dom={dominant}  "
              f"mem/chip(arg+tmp)={fit/1e9:.2f}GB")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCHS)
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    results, failures = [], []
    for arch in archs:
        shapes = ([SHAPES[args.shape]] if args.shape
                  else applicable_shapes(arch))
        for shape in shapes:
            if shape.name == "long_500k" and arch in LONG_CTX_SKIP:
                results.append(dict(arch=arch, shape=shape.name,
                                    skipped=LONG_CTX_SKIP[arch]))
                continue
            for mp in meshes:
                tag = f"{arch}__{shape.name}__{'2pod' if mp else '1pod'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[dryrun] {tag} cached")
                    results.append(json.load(open(path)))
                    continue
                try:
                    r = dry_run_pair(arch, shape.name, mp)
                    results.append(r)
                    json.dump(r, open(path, "w"), indent=1)
                except Exception as e:  # noqa: BLE001 — report, keep going
                    traceback.print_exc()
                    failures.append((tag, repr(e)))
    summary = os.path.join(args.out, "summary.json")
    json.dump(results, open(summary, "w"), indent=1)
    print(f"\n{len(results)} results → {summary}; {len(failures)} failures")
    for tag, err in failures:
        print("FAIL", tag, err[:200])
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
