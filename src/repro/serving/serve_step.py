"""Serving: batched prefill + cached decode over the stacked node models.

In the paper's setting each device serves inference from its OWN model
(there is no global model) — so the serving path keeps the node axis: a
request batch is routed to a node and decoded against that node's params.
The SPMD formulation batches this: requests (N, B_local, ...) decode in
lockstep against params (N, ...), vmapped over nodes.

``decode_32k`` / ``long_500k`` lower ``serve_step`` — ONE token against a
seq_len-deep cache — per the assignment.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import (
    ForwardOptions,
    decode_step,
    forward,
    init_cache,
)

__all__ = ["make_prefill_step", "make_serve_step", "make_cache", "greedy_generate"]


def make_prefill_step(cfg: ModelConfig, opts: Optional[ForwardOptions] = None,
                      last_only: bool = True):
    """prefill(params(N,...), batch(N,B,S)) → logits.

    ``last_only`` unembeds only the final position — (N, B, V) — which is
    what serving needs (first sampled token) and avoids a (B, S, V) logits
    tensor (at 32k × 200k vocab that would dominate memory for no reason).
    """
    opts = opts or ForwardOptions(remat=False)

    def prefill(stacked_params, batch):
        def one(params, b):
            if last_only:
                from repro.models.transformer import _unembed

                hidden, _ = forward(params, cfg, b, opts, return_hidden=True)
                return _unembed(params, cfg, hidden[:, -1:, :])[:, 0]
            logits, _ = forward(params, cfg, b, opts)
            return logits

        return jax.vmap(one)(stacked_params, batch)

    return prefill


def make_cache(cfg: ModelConfig, n_nodes: int, batch_per_node: int,
               max_seq: int):
    """Stacked decode cache: leaves (N, L, B, ...)."""
    one = init_cache(cfg, batch_per_node, max_seq)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_nodes,) + x.shape), one
    )


def make_serve_step(cfg: ModelConfig, opts: Optional[ForwardOptions] = None):
    """serve_step(params(N,...), tokens(N,B,1), cache(N,...)) →
    (logits (N,B,1,V), new cache)."""
    opts = opts or ForwardOptions(remat=False)

    def serve(stacked_params, tokens, cache):
        def one(params, toks, c):
            return decode_step(params, cfg, toks, c, opts)

        return jax.vmap(one)(stacked_params, tokens, cache)

    return serve


def greedy_generate(cfg: ModelConfig, params, prompt: jnp.ndarray,
                    n_new: int, max_seq: Optional[int] = None,
                    temperature: float = 0.0, rng=None) -> jnp.ndarray:
    """Single-node convenience generator (examples / tests).

    prompt: (B, S0) → returns (B, S0 + n_new).  Prefill is token-by-token
    through the decode path (exercises the cache exactly as serving does).
    """
    b, s0 = prompt.shape
    max_seq = max_seq or (s0 + n_new)
    cache = init_cache(cfg, b, max_seq)
    step = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c))

    tokens = prompt
    logits = None
    for i in range(s0):
        logits, cache = step(params, prompt[:, i : i + 1], cache)
    for i in range(n_new):
        if temperature > 0.0 and rng is not None:
            rng, sub = jax.random.split(rng)
            nxt = jax.random.categorical(sub, logits[:, -1] / temperature)[:, None]
        else:
            nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        tokens = jnp.concatenate([tokens, nxt], axis=1)
        logits, cache = step(params, nxt, cache)
    return tokens
