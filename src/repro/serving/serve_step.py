"""Serving: chunked prefill + cached decode over the stacked node models.

In the paper's setting each device serves inference from its OWN model
(there is no global model) — so the serving path keeps the node axis: a
request batch is routed to a node and decoded against that node's params.
The SPMD formulation batches this: requests (N, B_local, ...) decode in
lockstep against params (N, ...), vmapped over nodes.

Two prefill shapes live here:

* :func:`make_forward_prefill` — full-sequence forward, last-position
  logits only.  This is the ``prefill_32k`` assignment surface lowered by
  ``launch.dryrun``; it never touches the decode cache.
* :func:`make_prefill_step` — *chunked* prefill through the decode path:
  one jitted call advances up to ``chunk`` tokens per slot (a ``lax.scan``
  of :func:`decode_step` with per-slot valid-length masking), so admitting
  a prompt costs ⌈prompt_len/chunk⌉ dispatches instead of O(prompt_len).
  Lanes whose planned tokens run out *self-feed* their own greedy sample,
  so slots mid-decode generate through the same call instead of stalling
  behind another slot's prefill; slots whose ``lens`` entry is 0 are
  frozen bit-exactly — their cache columns (and position counters) pass
  through untouched.  One fused call therefore serves slots in every
  lifecycle phase.

The fleet variants (:func:`make_fleet_decode_step`,
:func:`make_fleet_prefill_step`) are fed by the sweep engine's ``(n, P)``
parameter plane: ``PlaneLayout.unpack`` runs *inside* the jitted step, so
the traced program is keyed on the plane's shape, not on parameter
identity — swapping one node's model after a gossip round is a plane row
write and hits the same executable (no re-jit; asserted in
``tests/test_scheduler.py``).

``decode_32k`` / ``long_500k`` lower ``serve_step`` — ONE token against a
seq_len-deep cache — per the assignment.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.plane import PlaneLayout
from repro.models.transformer import (
    ForwardOptions,
    decode_step,
    forward,
    init_cache,
)

__all__ = [
    "make_forward_prefill",
    "make_prefill_step",
    "make_serve_step",
    "make_fleet_decode_step",
    "make_fleet_prefill_step",
    "make_cache",
    "greedy_generate",
]


def make_forward_prefill(cfg: ModelConfig, opts: Optional[ForwardOptions] = None,
                         last_only: bool = True):
    """prefill(params(N,...), batch(N,B,S)) → logits (full-sequence forward).

    ``last_only`` unembeds only the final position — (N, B, V) — which is
    what serving needs (first sampled token) and avoids a (B, S, V) logits
    tensor (at 32k × 200k vocab that would dominate memory for no reason).
    """
    opts = opts or ForwardOptions(remat=False)

    def prefill(stacked_params, batch):
        def one(params, b):
            if last_only:
                from repro.models.transformer import _unembed

                hidden, _ = forward(params, cfg, b, opts, return_hidden=True)
                return _unembed(params, cfg, hidden[:, -1:, :])[:, 0]
            logits, _ = forward(params, cfg, b, opts)
            return logits

        return jax.vmap(one)(stacked_params, batch)

    return prefill


def _slot_mask(valid: jnp.ndarray, key: str, ref: jnp.ndarray) -> jnp.ndarray:
    """Broadcast a (B,) validity mask against cache leaf ``ref``.

    ``position`` is (B,); every other cache leaf is (L, B, ...) — the
    batch axis is 0 for the former, 1 for the rest (see ``init_cache``).
    """
    axis = 0 if key == "position" else 1
    shape = [1] * ref.ndim
    shape[axis] = valid.shape[0]
    return valid.reshape(shape)


def make_prefill_step(cfg: ModelConfig, opts: Optional[ForwardOptions] = None):
    """Chunked prefill with self-feeding decode lanes:
    prefill(params, toks(B, C), feed(B,), lens(B,), cache) →
    (last_logits (B, V), sampled (B, C) int, cache).

    Scans :func:`decode_step` over the C chunk positions inside ONE traced
    program.  Per step t, slot b participates iff ``t < lens[b]``; its
    input token is ``toks[b, t]`` while ``t < feed[b]`` (planned prompt
    tokens) and its own previous greedy sample after that — so a slot
    whose prompt is exhausted (or was already decoding, ``feed[b] = 1``
    with its last sampled token in column 0) keeps *generating* through
    the remaining valid steps instead of stalling.  One fused call
    therefore serves slots in every lifecycle phase at full utilisation:
    prefilling slots absorb prompt tokens, decoding slots emit up to
    ``lens[b]`` new tokens.

    Frozen slots (``lens[b] = 0``) keep their cache leaves — including
    ``position`` — bit-exactly.  ``sampled[b, t]`` is the greedy argmax
    after step t (host code reads only the valid range);
    ``last_logits[b]`` is the logits row of slot b's final valid step
    (zeros where ``lens[b] = 0``).
    """
    opts = opts or ForwardOptions(remat=False)

    def prefill(params, toks, feed, lens, cache):
        def body(carry, xs):
            cache, last, prev = carry
            tok_col, t = xs
            tok = jnp.where(t < feed, tok_col, prev)  # (B,)
            logits, stepped = decode_step(params, cfg, tok[:, None], cache, opts)
            valid = t < lens  # (B,)
            new_cache = {
                k: jnp.where(_slot_mask(valid, k, v), v, cache[k])
                for k, v in stepped.items()
            }
            samp = jnp.argmax(logits[:, 0], axis=-1).astype(tok_col.dtype)
            prev = jnp.where(valid, samp, prev)
            last = jnp.where(valid[:, None], logits[:, 0].astype(last.dtype),
                             last)
            return (new_cache, last, prev), samp

        b, c = toks.shape
        last0 = jnp.zeros((b, cfg.vocab_size), jnp.float32)
        prev0 = jnp.zeros((b,), toks.dtype)
        (cache, last, _), samples = jax.lax.scan(
            body, (cache, last0, prev0),
            (toks.T, jnp.arange(c, dtype=lens.dtype)))
        return last, samples.T, cache

    return prefill


def make_cache(cfg: ModelConfig, n_nodes: int, batch_per_node: int,
               max_seq: int):
    """Stacked decode cache: leaves (N, L, B, ...)."""
    one = init_cache(cfg, batch_per_node, max_seq)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_nodes,) + x.shape), one
    )


def make_serve_step(cfg: ModelConfig, opts: Optional[ForwardOptions] = None):
    """serve_step(params(N,...), tokens(N,B,1), cache(N,...)) →
    (logits (N,B,1,V), new cache)."""
    opts = opts or ForwardOptions(remat=False)

    def serve(stacked_params, tokens, cache):
        def one(params, toks, c):
            return decode_step(params, cfg, toks, c, opts)

        return jax.vmap(one)(stacked_params, tokens, cache)

    return serve


def make_fleet_decode_step(cfg: ModelConfig, layout: PlaneLayout,
                           opts: Optional[ForwardOptions] = None):
    """fleet_decode(plane(n, P), tokens(n, B, 1), cache(n, ...)) →
    (logits (n, B, 1, V), new cache) — ONE compiled step for the fleet.

    The plane row → params bridge (``layout.unpack``) is part of the
    traced program: the jit cache keys on the plane's shape/dtype, so a
    post-gossip model swap (a row write into the plane) re-enters the
    same executable.
    """
    opts = opts or ForwardOptions(remat=False)

    def fleet(plane, tokens, cache):
        params = layout.unpack(plane)

        def one(p, toks, c):
            return decode_step(p, cfg, toks, c, opts)

        return jax.vmap(one)(params, tokens, cache)

    return fleet


def make_fleet_prefill_step(cfg: ModelConfig, layout: PlaneLayout,
                            opts: Optional[ForwardOptions] = None):
    """fleet_prefill(plane(n, P), toks(n, B, C), feed(n, B), lens(n, B),
    cache(n, ...)) → (last_logits (n, B, V), sampled (n, B, C), new cache)
    — the self-feeding chunked prefill vmapped over the fleet, plane-fed
    like :func:`make_fleet_decode_step`."""
    prefill = make_prefill_step(cfg, opts)

    def fleet(plane, toks, feed, lens, cache):
        params = layout.unpack(plane)
        return jax.vmap(prefill)(params, toks, feed, lens, cache)

    return fleet


def greedy_generate(cfg: ModelConfig, params, prompt: jnp.ndarray,
                    n_new: int, max_seq: Optional[int] = None,
                    temperature: float = 0.0, rng=None) -> jnp.ndarray:
    """Single-node convenience generator (examples / tests).

    prompt: (B, S0) → returns (B, S0 + n_new).  Prefill is token-by-token
    through the decode path (exercises the cache exactly as serving does).
    """
    b, s0 = prompt.shape
    max_seq = max_seq or (s0 + n_new)
    cache = init_cache(cfg, b, max_seq)
    step = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c))

    tokens = prompt
    logits = None
    for i in range(s0):
        logits, cache = step(params, prompt[:, i : i + 1], cache)
    for i in range(n_new):
        if temperature > 0.0 and rng is not None:
            rng, sub = jax.random.split(rng)
            nxt = jax.random.categorical(sub, logits[:, -1] / temperature)[:, None]
        else:
            nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        tokens = jnp.concatenate([tokens, nxt], axis=1)
        logits, cache = step(params, nxt, cache)
    return tokens
