"""Continuous-batching scheduler for per-node serving.

Production serving doesn't get fixed-size batches: requests arrive with
different prompt lengths and stop at different times.  This scheduler
keeps each node's decode batch full by packing active requests into a
fixed set of slots, admitting queued requests into freed slots between
steps, and evicting on EOS/max-length — continuous batching (Orca-style)
on top of the SPMD ``serve_step``.

Host-side state (queues, slot maps) stays in numpy; device state is the
stacked KV cache whose slots are written in place.  Because the decode
step is jit'd over fixed shapes, admission works by *resetting a slot's
cache column* (position ← 0) and replaying the prompt token-by-token
through the same decode path — no separate prefill graph needed for the
CPU demo (a real deployment would chunk-prefill; noted below).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import init_cache

__all__ = ["Request", "NodeScheduler", "FleetScheduler"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 32
    eos: Optional[int] = None
    # filled by the scheduler:
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class NodeScheduler:
    """Slot manager for ONE node's model (batch dimension = slots)."""

    def __init__(self, cfg: ModelConfig, params, n_slots: int, max_seq: int):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.cache = init_cache(cfg, n_slots, max_seq)
        self._step = jax.jit(
            lambda p, t, c: __import__("repro.models.transformer",
                                       fromlist=["decode_step"]).decode_step(
                p, cfg, t, c))
        self.slots: List[Optional[Request]] = [None] * n_slots
        self._pending_prompt: Dict[int, List[int]] = {}  # slot → tokens to feed
        self.queue: List[Request] = []
        self._last_token = np.zeros(n_slots, np.int64)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i in range(self.n_slots):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                # reset this slot's cache column: position ← 0
                self.cache["position"] = self.cache["position"].at[i].set(0)
                self._pending_prompt[i] = list(req.prompt)
                self._last_token[i] = req.prompt[0]

    def _evict(self):
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            hit_eos = req.eos is not None and req.output and req.output[-1] == req.eos
            full = len(req.output) >= req.max_new
            over = int(self.cache["position"][i]) >= self.max_seq - 1
            if hit_eos or full or over:
                req.done = True
                self.slots[i] = None
                self._pending_prompt.pop(i, None)

    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One decode step across all slots.  Returns #active slots."""
        self._admit()
        if self.active == 0:
            return 0
        # build the token vector: prompt tokens still being fed, else the
        # last sampled token; idle slots feed token 0 (masked out).
        toks = np.zeros((self.n_slots, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            pend = self._pending_prompt.get(i)
            toks[i, 0] = pend[0] if pend else self._last_token[i]
        logits, self.cache = self._step(self.params, jnp.asarray(toks),
                                        self.cache)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            pend = self._pending_prompt.get(i)
            if pend:
                pend.pop(0)              # still prefill-feeding this slot
                if not pend:
                    self._pending_prompt.pop(i, None)
                    req.output.append(int(nxt[i]))
                    self._last_token[i] = int(nxt[i])
            else:
                req.output.append(int(nxt[i]))
                self._last_token[i] = int(nxt[i])
        self._evict()
        return self.active

    def run_until_drained(self, max_steps: int = 10_000) -> int:
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            self.step()
            steps += 1
        return steps


class FleetScheduler:
    """Round-robin request routing across a fleet of per-node schedulers —
    the paper's deployment (each device serves its own model)."""

    def __init__(self, cfg: ModelConfig, stacked_params, n_nodes: int,
                 n_slots: int, max_seq: int):
        from repro.core.decentralized import unstack_params

        node_params = unstack_params(stacked_params, n_nodes)
        self.nodes = [NodeScheduler(cfg, p, n_slots, max_seq)
                      for p in node_params]
        self._rr = 0

    def submit(self, req: Request, node: Optional[int] = None):
        if node is None:
            node = self._rr % len(self.nodes)
            self._rr += 1
        self.nodes[node].submit(req)
        return node

    def run_until_drained(self, max_steps: int = 10_000) -> int:
        total = 0
        for nd in self.nodes:
            total += nd.run_until_drained(max_steps)
        return total
