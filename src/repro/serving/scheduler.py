"""Continuous-batching scheduler for per-node serving.

Production serving doesn't get fixed-size batches: requests arrive with
different prompt lengths and stop at different times.  This scheduler
keeps each node's decode batch full by packing active requests into a
fixed set of slots, admitting queued requests into freed slots between
steps, and evicting on EOS/max-length — continuous batching (Orca-style)
on top of the SPMD serving steps.

Host-side state (queues, slot maps) stays in numpy; device state is the
stacked KV cache whose slots are written in place.  Admission resets a
slot's cache column (position ← 0) and feeds the prompt through
*chunked prefill* (``make_prefill_step``): one jitted call advances up to
``prefill_chunk`` prompt tokens, so a length-L prompt costs
⌈L/chunk⌉ dispatches instead of L decode steps.  The legacy token-by-token
replay is kept behind ``prefill_chunk=None`` as the bit-equality reference
(``tests/test_scheduler.py``).

:class:`FleetScheduler` holds the whole fleet as ONE ``(n, P)`` parameter
plane (``core.plane.PlaneLayout``) plus a node-stacked cache, and advances
all n nodes' slot batches in one compiled step (``make_fleet_decode_step``
/ ``make_fleet_prefill_step``) instead of a Python loop over nodes.
Because ``layout.unpack`` happens inside the traced step, swapping a
node's model after a gossip round (:meth:`FleetScheduler.swap_node`) is a
plane row write that re-enters the cached executable — no re-jit
(asserted via the scheduler's trace counters).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.plane import PlaneLayout
from repro.models.transformer import decode_step, init_cache
from repro.serving.serve_step import (
    make_cache,
    make_fleet_decode_step,
    make_fleet_prefill_step,
    make_prefill_step,
)

__all__ = ["Request", "NodeScheduler", "FleetScheduler"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 32
    eos: Optional[int] = None
    # filled by the scheduler:
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class _SlotBook:
    """Host-side slot bookkeeping for one node — no device state.

    Shared by :class:`NodeScheduler` (one book + per-node jit) and
    :class:`FleetScheduler` (n books + one fleet-wide jit): the book
    plans token batches and consumes sampled tokens; the owner decides
    how the plans are executed.
    """

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.queue: List[Request] = []
        self._pending: Dict[int, List[int]] = {}  # slot → tokens to feed
        self._last = np.zeros(n_slots, np.int64)
        self._count = np.zeros(n_slots, np.int64)  # tokens fed since admit

    def submit(self, req: Request):
        self.queue.append(req)

    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    @property
    def has_pending(self) -> bool:
        return bool(self._pending)

    def admit(self) -> List[int]:
        """Fill free slots from the queue; returns newly admitted slot
        indices (their cache columns must be reset by the owner)."""
        fresh = []
        for i in range(self.n_slots):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                self._pending[i] = list(req.prompt)
                self._last[i] = req.prompt[0]
                self._count[i] = 0
                fresh.append(i)
        return fresh

    # -- continuous step plan (chunked prefill + self-feeding decode) ----
    def plan(self, chunk: int, max_seq: int):
        """Token plan for ONE fused dispatch advancing every active slot.

        Slots mid-prompt feed up to ``chunk`` pending tokens; a slot whose
        prompt completes inside the chunk keeps *generating* through the
        remaining scan steps (the kernel self-feeds its greedy sample);
        slots already decoding feed their last sampled token and self-feed
        up to ``chunk`` new tokens — so no lane idles behind another
        slot's prefill.  Generation is capped host-side by the request's
        remaining ``max_new`` budget and the cache headroom
        (``max_seq - 1`` total fed tokens — the legacy over-length
        eviction boundary), so the kernel never writes past either.

        Returns (toks (B, chunk) int32, feed (B,) int32, lens (B,) int32,
        info {slot: (pend_k, start, gen, lens)}) where consume() takes
        slot i's generated tokens from ``sampled[i, start : start + gen]``.
        """
        toks = np.zeros((self.n_slots, chunk), np.int32)
        feed = np.zeros(self.n_slots, np.int32)
        lens = np.zeros(self.n_slots, np.int32)
        info: Dict[int, tuple] = {}
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            headroom = max_seq - 1 - int(self._count[i])
            if headroom <= 0:
                continue  # at the eviction boundary; evict() fires this step
            remaining = req.max_new - len(req.output)
            pend = self._pending.get(i)
            if pend:
                k = min(chunk, len(pend), headroom)
                toks[i, :k] = pend[:k]
                feed[i] = k
                if k < len(pend):           # prompt continues next chunk
                    lens[i] = k
                    info[i] = (k, 0, 0, k)
                else:                       # completes → generate in-chunk
                    gen = max(min(remaining, chunk - k + 1, headroom - k + 1),
                              1)
                    lens[i] = k + gen - 1
                    info[i] = (k, k - 1, gen, k + gen - 1)
            else:                           # decoding: self-feed from _last
                toks[i, 0] = self._last[i]
                feed[i] = 1
                gen = max(min(remaining, chunk, headroom), 1)
                lens[i] = gen
                info[i] = (0, 0, gen, gen)
        return toks, feed, lens, info

    def consume(self, info: Dict[int, tuple], sampled: np.ndarray):
        """Advance the book by one dispatch's results: pending prompts
        shrink by what was fed; generated tokens (``sampled`` rows, the
        per-step greedy argmax) append to each slot's output, truncated at
        the request's EOS if one shows up mid-chunk."""
        for i, (pend_k, start, gen, fed_total) in info.items():
            self._count[i] += fed_total
            if pend_k:
                pend = self._pending[i]
                del pend[:pend_k]
                if not pend:
                    self._pending.pop(i)
            if gen:
                req = self.slots[i]
                new = [int(t) for t in sampled[i, start:start + gen]]
                if req.eos is not None and req.eos in new:
                    new = new[: new.index(req.eos) + 1]
                req.output.extend(new)
                self._last[i] = req.output[-1]

    # -- legacy token-by-token replay (bit-equality reference) -----------
    def replay_plan(self) -> np.ndarray:
        """(B, 1) batch for the legacy path: prompt tokens still being
        fed, else the last sampled token."""
        toks = np.zeros((self.n_slots, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            pend = self._pending.get(i)
            toks[i, 0] = pend[0] if pend else self._last[i]
        return toks

    def consume_replay(self, nxt: np.ndarray):
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            pend = self._pending.get(i)
            if pend:
                pend.pop(0)              # still prefill-feeding this slot
                if not pend:
                    self._pending.pop(i, None)
                    req.output.append(int(nxt[i]))
                    self._last[i] = int(nxt[i])
            else:
                req.output.append(int(nxt[i]))
                self._last[i] = int(nxt[i])

    # -- eviction --------------------------------------------------------
    def evict(self, positions: np.ndarray, max_seq: int):
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            hit_eos = req.eos is not None and req.output and req.output[-1] == req.eos
            full = len(req.output) >= req.max_new
            over = int(positions[i]) >= max_seq - 1
            if hit_eos or full or over:
                req.done = True
                self.slots[i] = None
                self._pending.pop(i, None)


class NodeScheduler:
    """Slot manager for ONE node's model (batch dimension = slots).

    ``prefill_chunk`` selects the admission path: an int C admits prompts
    through chunked prefill (⌈L/C⌉ dispatches per length-L prompt);
    ``None`` keeps the legacy token-by-token replay (O(L) decode steps) —
    retained as the bit-equality reference for tests.
    """

    def __init__(self, cfg: ModelConfig, params, n_slots: int, max_seq: int,
                 prefill_chunk: Optional[int] = 8):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.prefill_chunk = prefill_chunk
        self.cache = init_cache(cfg, n_slots, max_seq)
        self.decode_traces = 0
        self.prefill_traces = 0

        def _dec(p, t, c):
            self.decode_traces += 1  # trace-time only: counts (re)compiles
            return decode_step(p, cfg, t, c)

        self._step = jax.jit(_dec)
        prefill = make_prefill_step(cfg)

        def _pre(p, t, f, l, c):
            self.prefill_traces += 1
            return prefill(p, t, f, l, c)

        self._prefill = jax.jit(_pre)
        self.book = _SlotBook(n_slots)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.book.submit(req)

    @property
    def queue(self) -> List[Request]:
        return self.book.queue

    @property
    def slots(self) -> List[Optional[Request]]:
        return self.book.slots

    @property
    def active(self) -> int:
        return self.book.active

    def _admit(self):
        fresh = self.book.admit()
        if fresh:
            # reset the admitted slots' cache columns: position ← 0.
            # Fixed-shape mask (not a gather over the fresh indices): the
            # eager reset op compiles ONCE instead of once per distinct
            # admission count (~100ms of XLA compile each, mid-workload).
            mask = np.zeros(self.n_slots, bool)
            mask[fresh] = True
            self.cache["position"] = jnp.where(jnp.asarray(mask), 0,
                                               self.cache["position"])

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One scheduler step = ONE dispatch advancing every active slot:
        a ``(B, chunk)`` fused call while any prompt is mid-prefill
        (decoding slots ride along with ``lens = 1``), a ``(B, 1)`` call
        in the pure-decode steady state.  Returns #active slots."""
        self._admit()
        if self.book.active == 0:
            return 0
        if self.prefill_chunk is None:
            # legacy replay: every step is a single-token decode
            toks = self.book.replay_plan()
            logits, self.cache = self._step(self.params, jnp.asarray(toks),
                                            self.cache)
            self.book.consume_replay(np.asarray(jnp.argmax(logits[:, -1],
                                                           axis=-1)))
        else:
            chunk = self.prefill_chunk if self.book.has_pending else 1
            toks, feed, lens, info = self.book.plan(chunk, self.max_seq)
            _, sampled, self.cache = self._prefill(
                self.params, jnp.asarray(toks), jnp.asarray(feed),
                jnp.asarray(lens), self.cache)
            self.book.consume(info, np.asarray(sampled))
        self.book.evict(np.asarray(self.cache["position"]), self.max_seq)
        return self.book.active

    def run_until_drained(self, max_steps: int = 10_000) -> int:
        steps = 0
        while (self.book.queue or self.book.active) and steps < max_steps:
            self.step()
            steps += 1
        return steps


class FleetScheduler:
    """The whole fleet behind ONE compiled step — the paper's deployment
    (each device serves its own model), plane-fed.

    ``vmapped=True`` packs the stacked params into an ``(n, P)`` plane and
    advances all nodes' slot batches in a single fleet-vmapped dispatch
    per step; ``vmapped=False`` keeps a Python loop over per-node
    schedulers (n dispatches per step) — the baseline
    ``benchmarks/serve_bench.py`` measures against.
    """

    def __init__(self, cfg: ModelConfig, stacked_params, n_nodes: int,
                 n_slots: int, max_seq: int,
                 prefill_chunk: Optional[int] = 8, vmapped: bool = True):
        self.cfg = cfg
        self.n_nodes = n_nodes
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.prefill_chunk = prefill_chunk
        self.vmapped = vmapped
        self._rr = 0
        self.decode_traces = 0
        self.prefill_traces = 0
        if not vmapped:
            from repro.core.decentralized import unstack_params

            self.nodes = [NodeScheduler(cfg, p, n_slots, max_seq,
                                        prefill_chunk=prefill_chunk)
                          for p in unstack_params(stacked_params, n_nodes)]
            return
        self.layout = PlaneLayout.from_tree(stacked_params)
        self.plane = self.layout.pack(stacked_params)
        self.cache = make_cache(cfg, n_nodes, n_slots, max_seq)
        self.books = [_SlotBook(n_slots) for _ in range(n_nodes)]
        fleet_dec = make_fleet_decode_step(cfg, self.layout)
        fleet_pre = make_fleet_prefill_step(cfg, self.layout)

        def _dec(plane, toks, cache):
            self.decode_traces += 1  # trace-time only: counts (re)compiles
            return fleet_dec(plane, toks, cache)

        def _pre(plane, toks, feed, lens, cache):
            self.prefill_traces += 1
            return fleet_pre(plane, toks, feed, lens, cache)

        self._decode = jax.jit(_dec)
        self._prefill = jax.jit(_pre)

    # ------------------------------------------------------------------
    def submit(self, req: Request, node: Optional[int] = None):
        if node is None:
            node = self._rr % self.n_nodes
            self._rr += 1
        if self.vmapped:
            self.books[node].submit(req)
        else:
            self.nodes[node].submit(req)
        return node

    @property
    def active(self) -> int:
        if self.vmapped:
            return sum(b.active for b in self.books)
        return sum(nd.active for nd in self.nodes)

    @property
    def queued(self) -> int:
        books = self.books if self.vmapped else [nd.book for nd in self.nodes]
        return sum(len(b.queue) for b in books)

    def swap_node(self, node: int, params_one):
        """Install one node's freshly gossip-mixed params: a plane row
        write — same executable on the next step (no re-jit)."""
        if not self.vmapped:
            self.nodes[node].params = params_one
            return
        row = self.layout.pack_row(params_one, dtype=self.plane.dtype)
        self.plane = self.plane.at[node].set(row)

    # ------------------------------------------------------------------
    def step(self) -> int:
        """Advance every node one scheduler step.  Vmapped mode: ONE
        compiled dispatch for the whole fleet per step — an
        ``(n, B, chunk)`` fused call while any node has prompt tokens
        mid-prefill (decoding slots everywhere ride along with
        ``lens = 1``), an ``(n, B, 1)`` call in the pure-decode steady
        state.  No slot ever stalls on another node's prefill.
        Returns total active slots."""
        if not self.vmapped:
            return sum(nd.step() for nd in self.nodes)
        fresh = np.zeros((self.n_nodes, self.n_slots), bool)
        for n, b in enumerate(self.books):
            for i in b.admit():
                fresh[n, i] = True
        if fresh.any():
            # fixed-shape masked reset — compiles once, not once per
            # distinct admission count (see NodeScheduler._admit)
            self.cache["position"] = jnp.where(jnp.asarray(fresh), 0,
                                               self.cache["position"])
        if all(b.active == 0 for b in self.books):
            return 0
        chunk = ((self.prefill_chunk or 1)
                 if any(b.has_pending for b in self.books) else 1)
        toks = np.zeros((self.n_nodes, self.n_slots, chunk), np.int32)
        feed = np.zeros((self.n_nodes, self.n_slots), np.int32)
        lens = np.zeros((self.n_nodes, self.n_slots), np.int32)
        plans = []
        for n, b in enumerate(self.books):
            t, f, l, info = b.plan(chunk, self.max_seq)
            toks[n], feed[n], lens[n] = t, f, l
            plans.append(info)
        _, sampled, self.cache = self._prefill(
            self.plane, jnp.asarray(toks), jnp.asarray(feed),
            jnp.asarray(lens), self.cache)
        sampled = np.asarray(sampled)  # (n, B, chunk)
        for n, b in enumerate(self.books):
            b.consume(plans[n], sampled[n])
        positions = np.asarray(self.cache["position"])  # (n, B)
        for n, b in enumerate(self.books):
            b.evict(positions[n], self.max_seq)
        return self.active

    def run_until_drained(self, max_steps: int = 10_000) -> int:
        if not self.vmapped:
            return sum(nd.run_until_drained(max_steps) for nd in self.nodes)
        steps = 0
        while (self.active or self.queued) and steps < max_steps:
            self.step()
            steps += 1
        return steps
