"""Model assembly: init / forward / decode for every architecture family.

One code path, config-driven:

  dense   — [norm → GQA attn → +res] [norm → MLP → +res]        (× L)
  moe     — attention (GQA or MLA) + routed expert MLP
  ssm     — RWKV-6 time-mix + channel-mix (attention-free)
  hybrid  — parallel attention & Mamba heads (Hymba), then MLP
  vlm/audio — dense trunk consuming stub frontend embeddings

Layers are stacked along a leading L axis and iterated with ``lax.scan``
(keeps HLO size O(1) in depth — essential for the 48–60 layer archs) with
optional per-layer ``jax.checkpoint`` (remat).  Heterogeneous layer kinds
(gemma2 local/global alternation, deepseek first-dense) are handled with a
per-layer static side-channel: window sizes ride along the scan as an (L,)
array, and structurally-different layers (dense-vs-MoE MLP) are split into
separate scan groups.

Decode threads a per-layer cache through the same scan.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ssm as ssm_lib
from repro.models.layers import (
    attention_init,
    dense_init,
    mla_apply,
    mla_decode,
    mla_init,
    mlp_apply,
    mlp_init,
    norm_apply,
    norm_init,
    softcap,
)
from repro.models.moe import moe_apply, moe_init

__all__ = ["init_params", "forward", "init_cache", "decode_step", "ForwardOptions"]

Params = Dict[str, Any]


# ======================================================================
# init
# ======================================================================
def _layer_init(key, cfg: ModelConfig, dtype, moe: bool) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {"norm1": norm_init(cfg.norm_kind, cfg.d_model, dtype),
                 "norm2": norm_init(cfg.norm_kind, cfg.d_model, dtype)}
    if cfg.family == "ssm":
        p["time_mix"] = ssm_lib.rwkv_init(ks[0], cfg, dtype)
        p["channel_mix"] = ssm_lib.rwkv_channel_init(ks[1], cfg, dtype)
        return p
    if cfg.use_mla:
        p["attn"] = mla_init(ks[0], cfg, dtype)
    else:
        p["attn"] = attention_init(ks[0], cfg, dtype)
    if cfg.hybrid_ssm:
        p["mamba"] = ssm_lib.mamba_init(ks[1], cfg, dtype)
    if moe:
        p["moe"] = moe_init(ks[2], cfg, dtype)
    else:
        p["mlp"] = mlp_init(ks[3], cfg.d_model, cfg.d_ff, cfg.mlp_kind, dtype)
    return p


def init_params(key, cfg: ModelConfig) -> Params:
    dtype = cfg.weight_dtype
    ks = jax.random.split(key, 8)
    p: Params = {
        "embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype, scale=0.02),
        "final_norm": norm_init(cfg.norm_kind, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size), dtype)
    if cfg.frontend is not None:
        p["frontend_proj"] = dense_init(ks[2], (cfg.frontend_dim, cfg.d_model), dtype)

    n_dense = cfg.first_k_dense if cfg.is_moe else cfg.n_layers
    n_moe = cfg.n_layers - n_dense if cfg.is_moe else 0

    def stack(count, moe, base_key):
        layers = [
            _layer_init(jax.random.fold_in(base_key, i), cfg, dtype, moe)
            for i in range(count)
        ]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)

    if n_dense:
        p["dense_layers"] = stack(n_dense, False, ks[3])
    if n_moe:
        p["moe_layers"] = stack(n_moe, True, ks[4])
    return p


def _layer_windows(cfg: ModelConfig):
    """(L,) host array: sliding-window size per layer, 0 = global.
    Kept as numpy so impl dispatch can treat windows as static."""
    import numpy as np

    kinds = cfg.layer_kinds()
    return np.array(
        [cfg.window_size if k == "local" else 0 for k in kinds], np.int32
    )


# ======================================================================
# forward (train / prefill)
# ======================================================================
class ForwardOptions:
    """Static knobs threaded through forward (perf levers for §Perf).

    attn_impl: "einsum"  — full (S,T) logits (small-seq baseline);
               "chunked" — online-softmax scan, O(bq·bkv) memory (the
                           lowering path for 32k/500k shapes);
               "pallas"  — the flash_attention TPU kernel.
    """

    def __init__(self, use_flash: bool = False, remat: bool = True,
                 use_scan: bool = True, use_ssm_kernel: bool = False,
                 remat_policy: Optional[str] = None,
                 attn_impl: Optional[str] = None):
        self.use_flash = use_flash
        self.remat = remat
        self.use_scan = use_scan
        self.use_ssm_kernel = use_ssm_kernel
        self.remat_policy = remat_policy  # None | "dots" | "nothing"
        self.attn_impl = attn_impl or ("pallas" if use_flash else "einsum")

    def policy(self):
        if self.remat_policy == "dots":
            return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return None


def _attn_block(layer_p, cfg, x, positions, window, opts: ForwardOptions):
    """window: per-layer scalar (0 = global); traced in the einsum path
    (branch-free mask shared by the layer scan), static in the chunked /
    pallas paths (those split the scan by attention kind instead)."""
    from repro.models.layers import _qkv, _sdpa, _sdpa_chunked

    h = norm_apply(cfg.norm_kind, layer_p["norm1"], x, cfg.norm_eps)
    if cfg.use_mla:
        impl = opts.attn_impl if opts.attn_impl in ("chunked", "pallas") \
            else "einsum"
        return mla_apply(layer_p["attn"], cfg, h, positions, impl=impl)
    q, k, v = _qkv(layer_p["attn"], cfg, h, positions)
    if opts.attn_impl == "pallas":
        from repro.kernels.ops import flash_attention

        out = flash_attention(
            q, k, v, causal=True, window=int(window),
            logit_softcap=cfg.attn_logit_softcap)
    elif opts.attn_impl == "chunked":
        out = _sdpa_chunked(cfg, q, k, v, window=int(window))
    else:
        s = x.shape[1]
        qi = jnp.arange(s)[:, None]
        ki = jnp.arange(s)[None, :]
        ok = ki <= qi
        ok &= (window == 0) | (ki > qi - window)
        mask = jnp.where(ok, 0.0, -1e30).astype(jnp.float32)
        out = _sdpa(cfg, q, k, v, mask)
    return jnp.einsum("bshk,hkd->bsd", out, layer_p["attn"]["wo"])


def _ffn_block(layer_p, cfg, x, moe: bool):
    h = norm_apply(cfg.norm_kind, layer_p["norm2"], x, cfg.norm_eps)
    if moe:
        out, aux = moe_apply(layer_p["moe"], cfg, h)
        return out, aux
    return mlp_apply(layer_p["mlp"], h, cfg.mlp_kind), jnp.zeros((), jnp.float32)


def _make_layer_fn(cfg: ModelConfig, moe: bool, opts: ForwardOptions,
                   window_static: Optional[int] = None):
    def layer_fn(x, layer_p, window, positions):
        if window_static is not None:
            window = window_static
        if cfg.family == "ssm":
            h = norm_apply(cfg.norm_kind, layer_p["norm1"], x, cfg.norm_eps)
            tm, _, _ = ssm_lib.rwkv_time_mix(
                layer_p["time_mix"], cfg, h, use_kernel=opts.use_ssm_kernel
            )
            x = x + tm
            h = norm_apply(cfg.norm_kind, layer_p["norm2"], x, cfg.norm_eps)
            cm, _ = ssm_lib.rwkv_channel_mix(layer_p["channel_mix"], h)
            return x + cm, jnp.zeros((), jnp.float32)
        attn_out = _attn_block(layer_p, cfg, x, positions, window, opts)
        if cfg.hybrid_ssm:
            h = norm_apply(cfg.norm_kind, layer_p["norm1"], x, cfg.norm_eps)
            m_out, _ = ssm_lib.mamba_apply(layer_p["mamba"], cfg, h)
            attn_out = 0.5 * (attn_out + m_out)
        x = x + attn_out
        ffn_out, aux = _ffn_block(layer_p, cfg, x, moe)
        return x + ffn_out, aux

    if opts.remat:
        layer_fn = jax.checkpoint(layer_fn, policy=opts.policy())
    return layer_fn


def _run_group(x, group_p, windows, positions, cfg, moe, opts: ForwardOptions):
    """Run a stack of structurally-identical layers.

    einsum attention takes the window as a traced scan side-channel
    (branch-free mask).  The chunked/pallas impls need STATIC windows, so
    heterogeneous patterns scan over whole pattern-periods with the period
    unrolled inside the body (remainder layers unrolled outside).
    """
    win_list = [int(w) for w in windows]
    n = len(win_list)
    if n == 0:
        return x, jnp.zeros((), jnp.float32)

    def run_unrolled(x, group_p, wins, offset=0):
        aux_total = jnp.zeros((), jnp.float32)
        for i, w in enumerate(wins):
            lp = jax.tree.map(lambda a: a[offset + i], group_p)
            fn = _make_layer_fn(cfg, moe, opts, window_static=w)
            x, aux = fn(x, lp, w, positions)
            aux_total += aux
        return x, aux_total

    if not opts.use_scan:
        return run_unrolled(x, group_p, win_list)

    if opts.attn_impl == "einsum" or cfg.family == "ssm":
        layer_fn = _make_layer_fn(cfg, moe, opts)

        def body(carry, xs):
            lp, w = xs
            y, aux = layer_fn(carry, lp, w, positions)
            return y, aux

        x, auxs = jax.lax.scan(body, x, (group_p, jnp.asarray(windows)))
        return x, jnp.sum(auxs)

    # static-window path: scan over pattern periods
    uniq = sorted(set(win_list))
    if len(uniq) == 1:
        period = 1
        pattern = (uniq[0],)
    else:
        period = len(cfg.attn_pattern)
        pattern = tuple(win_list[:period])
    n_full = n // period
    rem = n - n_full * period

    aux_total = jnp.zeros((), jnp.float32)
    if n_full:
        stacked = jax.tree.map(
            lambda a: a[: n_full * period].reshape(
                (n_full, period) + a.shape[1:]), group_p)
        fns = [_make_layer_fn(cfg, moe, opts, window_static=w) for w in pattern]

        def body(carry, lp_period):
            y = carry
            aux = jnp.zeros((), jnp.float32)
            for j, fn in enumerate(fns):
                lp = jax.tree.map(lambda a: a[j], lp_period)
                y, a = fn(y, lp, pattern[j], positions)
                aux += a
            return y, aux

        x, auxs = jax.lax.scan(body, x, stacked)
        aux_total += jnp.sum(auxs)
    if rem:
        x, a = run_unrolled(x, group_p, win_list[-rem:], offset=n_full * period)
        aux_total += a
    return x, aux_total


def _embed_inputs(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]):
    if "embeddings" in batch:  # modality-frontend stub path (audio / vlm)
        x = batch["embeddings"].astype(cfg.activation_dtype) @ params["frontend_proj"]
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        x = x * jnp.sqrt(jnp.asarray(cfg.d_model, jnp.float32)).astype(x.dtype)
    return x.astype(cfg.activation_dtype)


def _unembed(params, cfg: ModelConfig, x):
    x = norm_apply(cfg.norm_kind, params["final_norm"], x, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = (x @ head).astype(jnp.float32)
    return softcap(logits, cfg.final_logit_softcap)


def forward(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            opts: Optional[ForwardOptions] = None,
            return_hidden: bool = False):
    """Full-sequence forward.  Returns (logits, aux_loss) — or
    (hidden, aux_loss) when ``return_hidden`` (for chunked CE)."""
    opts = opts or ForwardOptions()
    x = _embed_inputs(params, cfg, batch)
    b, s, _ = x.shape
    positions = jnp.arange(s)

    windows = _layer_windows(cfg)
    aux = jnp.zeros((), jnp.float32)
    n_dense = cfg.first_k_dense if cfg.is_moe else cfg.n_layers
    if "dense_layers" in params:
        x, a = _run_group(x, params["dense_layers"], windows[:n_dense],
                          positions, cfg, False, opts)
        aux += a
    if "moe_layers" in params:
        x, a = _run_group(x, params["moe_layers"], windows[n_dense:],
                          positions, cfg, True, opts)
        aux += a
    if return_hidden:
        return x, aux
    return _unembed(params, cfg, x), aux


# ======================================================================
# decode (single token, cached)
# ======================================================================
def init_cache(cfg: ModelConfig, batch_size: int, max_seq: int) -> Params:
    """Allocate the per-layer decode cache, stacked along L.

    dense/moe : K/V (L, B, T, KV, hd) — local layers get T=window (ring).
    mla       : latent (L, B, T, r) + rope-k (L, B, T, dr).
    ssm       : rwkv state (L, B, H, hd, hd) + token-shift carries.
    hybrid    : attn cache + mamba (ssm_state, conv_state).
    """
    dt = cfg.activation_dtype
    L = cfg.n_layers
    kinds = cfg.layer_kinds()
    cache: Params = {"position": jnp.zeros((batch_size,), jnp.int32)}
    if cfg.family == "ssm":
        h = cfg.d_model // cfg.rwkv_head_dim
        cache["rwkv_state"] = jnp.zeros((L, batch_size, h, cfg.rwkv_head_dim,
                                         cfg.rwkv_head_dim), jnp.float32)
        cache["tm_prev"] = jnp.zeros((L, batch_size, cfg.d_model), dt)
        cache["cm_prev"] = jnp.zeros((L, batch_size, cfg.d_model), dt)
        return cache
    if cfg.use_mla:
        cache["ckv"] = jnp.zeros((L, batch_size, max_seq, cfg.kv_lora_rank), dt)
        cache["kr"] = jnp.zeros((L, batch_size, max_seq, cfg.qk_rope_head_dim), dt)
    else:
        # per-layer cache length: window for local layers, max_seq otherwise.
        # lax.scan needs homogeneous shapes → use the max over layers and
        # let local layers ring-index within their window (t dim is still
        # uniform; real saving comes from uniform-local patterns like
        # hymba where all layers are local or ssm).
        lens = [cfg.window_size if k == "local" else max_seq for k in kinds]
        t = max(lens) if lens else max_seq
        if all(k == "local" for k in kinds):
            t = min(cfg.window_size, max_seq)
        cache["k"] = jnp.zeros((L, batch_size, t, cfg.n_kv_heads, cfg.head_dim_), dt)
        cache["v"] = jnp.zeros((L, batch_size, t, cfg.n_kv_heads, cfg.head_dim_), dt)
    if cfg.hybrid_ssm:
        di = cfg.ssm_expand * cfg.d_model
        cache["ssm_state"] = jnp.zeros((L, batch_size, di, cfg.ssm_state_dim), jnp.float32)
        cache["conv_state"] = jnp.zeros((L, batch_size, cfg.ssm_conv_dim - 1, di), dt)
    return cache


def decode_step(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                cache: Params, opts: Optional[ForwardOptions] = None
                ) -> Tuple[jnp.ndarray, Params]:
    """One decode step: tokens (B, 1) → (logits (B, 1, V), new cache)."""
    opts = opts or ForwardOptions(remat=False)
    x = jnp.take(params["embed"], tokens, axis=0)
    x = (x * jnp.sqrt(jnp.asarray(cfg.d_model, jnp.float32))).astype(cfg.activation_dtype)
    position = cache["position"]
    windows = _layer_windows(cfg)

    n_dense = cfg.first_k_dense if cfg.is_moe else cfg.n_layers

    def layer_decode(x, lp, layer_cache, window, moe):
        new_cache = dict(layer_cache)
        if cfg.family == "ssm":
            h = norm_apply(cfg.norm_kind, lp["norm1"], x, cfg.norm_eps)
            tm, st, prev = ssm_lib.rwkv_time_mix_decode(
                lp["time_mix"], cfg, h, layer_cache["rwkv_state"],
                layer_cache["tm_prev"])
            new_cache["rwkv_state"], new_cache["tm_prev"] = st, prev
            x = x + tm
            h = norm_apply(cfg.norm_kind, lp["norm2"], x, cfg.norm_eps)
            cm, prev = ssm_lib.rwkv_channel_mix(
                lp["channel_mix"], h, layer_cache["cm_prev"])
            new_cache["cm_prev"] = prev
            return x + cm, new_cache
        h = norm_apply(cfg.norm_kind, lp["norm1"], x, cfg.norm_eps)
        if cfg.use_mla:
            a_out, ckv, kr = mla_decode(lp["attn"], cfg, h, layer_cache["ckv"],
                                        layer_cache["kr"], position)
            new_cache["ckv"], new_cache["kr"] = ckv, kr
        else:
            # window side-channel: local layers ring-index (kind resolved
            # per layer below — scan carries windows array)
            kind = "local"  # mask logic keys off `window>0` inside
            a_out, k_new, v_new = _attn_decode_traced(
                lp["attn"], cfg, h, layer_cache["k"], layer_cache["v"],
                position, window)
            new_cache["k"], new_cache["v"] = k_new, v_new
        if cfg.hybrid_ssm:
            m_out, (st, cv) = ssm_lib.mamba_decode(
                lp["mamba"], cfg, h, layer_cache["ssm_state"],
                layer_cache["conv_state"])
            new_cache["ssm_state"], new_cache["conv_state"] = st, cv
            a_out = 0.5 * (a_out + m_out)
        x = x + a_out
        ffn_out, _ = _ffn_block(lp, cfg, x, moe)
        return x + ffn_out, new_cache

    def run_group(x, group_p, group_cache, group_windows, moe):
        def body(carry, xs):
            lp, lc, w = xs
            y, nc = layer_decode(carry, lp, lc, w, moe)
            return y, nc

        if opts.use_scan:
            x, new_cache = jax.lax.scan(body, x, (group_p, group_cache, group_windows))
            return x, new_cache
        new_caches = []
        for i in range(group_windows.shape[0]):
            lp = jax.tree.map(lambda a: a[i], group_p)
            lc = jax.tree.map(lambda a: a[i], group_cache)
            x, nc = layer_decode(x, lp, lc, group_windows[i], moe)
            new_caches.append(nc)
        return x, jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)

    layer_cache_keys = [k for k in cache if k != "position"]
    stacked_cache = {k: cache[k] for k in layer_cache_keys}

    new_cache: Params = {"position": position + 1}
    if "dense_layers" in params and "moe_layers" in params:
        head_cache = {k: v[:n_dense] for k, v in stacked_cache.items()}
        tail_cache = {k: v[n_dense:] for k, v in stacked_cache.items()}
        x, hc = run_group(x, params["dense_layers"], head_cache, windows[:n_dense], False)
        x, tc = run_group(x, params["moe_layers"], tail_cache, windows[n_dense:], True)
        for k in layer_cache_keys:
            new_cache[k] = jnp.concatenate([hc[k], tc[k]], axis=0)
    elif "moe_layers" in params:
        x, nc = run_group(x, params["moe_layers"], stacked_cache, windows, True)
        new_cache.update(nc)
    else:
        x, nc = run_group(x, params["dense_layers"], stacked_cache, windows, False)
        new_cache.update(nc)

    return _unembed(params, cfg, x), new_cache


def _attn_decode_traced(p, cfg, x, cache_k, cache_v, position, window):
    """attention_decode with a *traced* window: slot/validity math is
    branch-free so global (window==0) and local layers share a scan body."""
    from repro.models.layers import _sdpa, apply_rope, rmsnorm, rope

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    cos, sin = rope(position[:, None], cfg.head_dim_, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    t = cache_k.shape[1]
    is_local = window > 0
    slot = jnp.where(is_local, position % t, jnp.minimum(position, t - 1))
    oh = jax.nn.one_hot(slot, t, dtype=cache_k.dtype)
    new_k = cache_k * (1 - oh[:, :, None, None]) + oh[:, :, None, None] * k
    new_v = cache_v * (1 - oh[:, :, None, None]) + oh[:, :, None, None] * v

    kpos = jnp.arange(t)[None, :]
    age = (slot[:, None] - kpos) % t
    ok_local = (age <= jnp.minimum(position, t - 1)[:, None]) & (age < window)
    ok_global = kpos <= position[:, None]
    ok = jnp.where(is_local, ok_local, ok_global)
    mask = jnp.where(ok, 0.0, -1e30).astype(jnp.float32)[:, None, None, None, :]
    out = _sdpa(cfg, q, new_k, new_v, mask)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_k, new_v
