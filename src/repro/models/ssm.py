"""State-space / linear-attention layers: RWKV-6 ("Finch") and a
Mamba-style selective SSM (used by the Hymba hybrid).

Both are O(1)-state recurrences — the archs that make ``long_500k`` viable.

RWKV-6 time-mix (per head, head_dim N):
    S_t = diag(w_t) · S_{t-1} + k_t v_tᵀ            (state: N×N)
    y_t = r_tᵀ · (S_{t-1} + diag(u) k_t v_tᵀ)
with data-dependent per-channel decay  w_t = exp(-exp(ddlerp(x_t, x_{t-1})))
(low-rank token-shift mixers, per the Finch paper arXiv:2404.05892).

Mamba-style SSM (diagonal state, d_state=16):
    h_t = exp(Δ_t ⊙ A) ⊙ h_{t-1} + (Δ_t ⊙ B_t) x_t ;  y_t = C_tᵀ h_t + D x_t

Training uses ``jax.lax.scan`` over time (baseline).  The chunked
MXU-friendly formulation lives in ``repro/kernels/ssm_scan.py`` and is the
perf path (see DESIGN.md §6).  Decode carries the state explicitly.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rmsnorm, rmsnorm_init

__all__ = [
    "rwkv_init", "rwkv_time_mix", "rwkv_time_mix_decode",
    "rwkv_channel_mix", "rwkv_channel_init",
    "mamba_init", "mamba_apply", "mamba_decode",
]

_LORA = 32  # low-rank dim of the RWKV-6 token-shift mixers


# ======================================================================
# RWKV-6
# ======================================================================
def rwkv_init(key, cfg, dtype):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    ks = jax.random.split(key, 12)
    p = {
        # token-shift lerp weights (mu) for r,k,v,g,w paths + base
        "mu_x": jnp.zeros((5, d), dtype),
        "lora_a": dense_init(ks[0], (5, d, _LORA), dtype),
        "lora_b": dense_init(ks[1], (5, _LORA, d), dtype),
        "wr": dense_init(ks[2], (d, h, hd), dtype),
        "wk": dense_init(ks[3], (d, h, hd), dtype),
        "wv": dense_init(ks[4], (d, h, hd), dtype),
        "wg": dense_init(ks[5], (d, h, hd), dtype),
        "wo": dense_init(ks[6], (h, hd, d), dtype),
        # data-dependent decay: w_t = exp(-exp(base + lora(x̄_t)))
        "decay_base": jnp.full((h, hd), -4.0, jnp.float32),
        "decay_a": dense_init(ks[7], (d, 64), dtype),
        "decay_b": dense_init(ks[8], (64, d), dtype),
        "bonus_u": dense_init(ks[9], (h, hd), jnp.float32, scale=0.5),
        "ln_out": rmsnorm_init(d, dtype),
    }
    return p


def _token_shift(x, x_prev):
    """x_{t-1} along the sequence; x_prev seeds position -1 (decode carry)."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


def _ddlerp(p, idx, x, xs):
    """Finch's data-dependent lerp between x_t and x_{t-1} (low-rank)."""
    mix = p["mu_x"][idx][None, None] + jnp.tanh((xs - x) @ p["lora_a"][idx]) @ p["lora_b"][idx]
    return x + (xs - x) * mix


def _rwkv_rkvgw(p, cfg, x, xs):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    b, s, _ = x.shape
    r = jnp.einsum("bsd,dhk->bshk", _ddlerp(p, 0, x, xs), p["wr"])
    k = jnp.einsum("bsd,dhk->bshk", _ddlerp(p, 1, x, xs), p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", _ddlerp(p, 2, x, xs), p["wv"])
    g = jnp.einsum("bsd,dhk->bshk", _ddlerp(p, 3, x, xs), p["wg"])
    dec_in = _ddlerp(p, 4, x, xs)
    dec = (jnp.tanh(dec_in @ p["decay_a"]) @ p["decay_b"]).reshape(b, s, h, hd)
    log_w = -jnp.exp(p["decay_base"][None, None] + dec.astype(jnp.float32))
    w = jnp.exp(log_w)  # (B,S,H,hd) in (0,1): the data-dependent decay
    return r, k, v, g, w


def rwkv_time_mix(p, cfg, x, state=None, x_prev=None,
                  use_kernel: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full-sequence RWKV-6 time-mix.

    Args:
      x: (B, S, D);  state: (B, H, hd, hd) carry or None;  x_prev: (B, D).
    Returns (out, final_state, last_x).
    """
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    if state is None:
        state = jnp.zeros((b, h, hd, hd), jnp.float32)
    if x_prev is None:
        x_prev = jnp.zeros((b, d), x.dtype)
    xs = _token_shift(x, x_prev)
    r, k, v, g, w = _rwkv_rkvgw(p, cfg, x, xs)
    u = p["bonus_u"]

    if use_kernel:
        from repro.kernels.ops import rwkv_scan
        y, state = rwkv_scan(r, k, v, w, u, state)
    else:
        def step(S, inp):
            r_t, k_t, v_t, w_t = inp  # (B,H,hd) each
            kv = jnp.einsum("bhk,bhv->bhkv", k_t.astype(jnp.float32),
                            v_t.astype(jnp.float32))
            y_t = jnp.einsum("bhk,bhkv->bhv", r_t.astype(jnp.float32),
                             S + u[None, :, :, None] * kv)
            S = w_t.astype(jnp.float32)[..., None] * S + kv
            return S, y_t

        seq = (r.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1), w.swapaxes(0, 1))
        state, ys = jax.lax.scan(step, state, seq)
        y = ys.swapaxes(0, 1)  # (B,S,H,hd)

    y = rmsnorm(p["ln_out"], y.reshape(b, s, d).astype(x.dtype), cfg.norm_eps)
    y = y * jax.nn.silu(g.reshape(b, s, d))
    out = jnp.einsum("bshk,hkd->bsd", y.reshape(b, s, h, hd), p["wo"])
    return out, state, x[:, -1, :]


def rwkv_time_mix_decode(p, cfg, x, state, x_prev):
    """Single-token decode: x (B,1,D); state (B,H,hd,hd); x_prev (B,D)."""
    out, state, last = rwkv_time_mix(p, cfg, x, state, x_prev)
    return out, state, last


def rwkv_channel_init(key, cfg, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_r": jnp.full((d,), 0.5, dtype),
        "wk": dense_init(ks[0], (d, f), dtype),
        "wv": dense_init(ks[1], (f, d), dtype),
        "wr": dense_init(ks[2], (d, d), dtype),
    }


def rwkv_channel_mix(p, x, x_prev=None):
    """RWKV channel-mix (the FFN analogue) with token shift."""
    if x_prev is None:
        x_prev = jnp.zeros((x.shape[0], x.shape[-1]), x.dtype)
    xs = _token_shift(x, x_prev)
    xk = x + (xs - x) * p["mu_k"][None, None]
    xr = x + (xs - x) * p["mu_r"][None, None]
    v = jnp.square(jax.nn.relu(xk @ p["wk"])) @ p["wv"]
    return jax.nn.sigmoid(xr @ p["wr"]) * v, x[:, -1, :]


# ======================================================================
# Mamba-style selective SSM (diagonal)
# ======================================================================
def mamba_init(key, cfg, dtype):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state_dim
    ks = jax.random.split(key, 7)
    return {
        "w_in": dense_init(ks[0], (d, 2 * di), dtype),        # x and gate z
        "conv_w": dense_init(ks[1], (cfg.ssm_conv_dim, di), dtype, scale=0.2),
        "w_bcdt": dense_init(ks[2], (di, 2 * n + 1), dtype),  # B, C, Δ-rank1
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "log_a": jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))[None, :]
                 * jnp.ones((di, 1), jnp.float32),            # A = -exp(log_a)
        "d_skip": jnp.ones((di,), jnp.float32),
        "w_out": dense_init(ks[3], (di, d), dtype),
    }


def _mamba_conv(p, x, conv_state=None):
    """Depthwise causal conv1d over time. x: (B,S,di)."""
    kdim = p["conv_w"].shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], kdim - 1, x.shape[-1]), x.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1], :] * p["conv_w"][i][None, None, :]
        for i in range(kdim)
    )
    return out, xp[:, -(kdim - 1):, :]


def _mamba_ssm_params(p, cfg, u):
    n = cfg.ssm_state_dim
    bcdt = u @ p["w_bcdt"]
    b_, c_, dt = jnp.split(bcdt, [n, 2 * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    a = -jnp.exp(p["log_a"])  # (di, n)
    return b_, c_, dt, a


def mamba_apply(p, cfg, x, ssm_state=None, conv_state=None):
    """Full-sequence Mamba. Returns (out, (ssm_state, conv_state))."""
    b, s, d = x.shape
    di = cfg.ssm_expand * d
    n = cfg.ssm_state_dim
    xz = x @ p["w_in"]
    u, z = jnp.split(xz, 2, axis=-1)
    u, conv_state = _mamba_conv(p, u, conv_state)
    u = jax.nn.silu(u)
    b_, c_, dt, a = _mamba_ssm_params(p, cfg, u)

    if ssm_state is None:
        ssm_state = jnp.zeros((b, di, n), jnp.float32)

    def step(h, inp):
        u_t, b_t, c_t, dt_t = inp
        da = jnp.exp(dt_t[..., None] * a[None])                     # (B,di,n)
        dbu = dt_t[..., None] * b_t[:, None, :] * u_t[..., None]    # (B,di,n)
        h = da * h + dbu.astype(jnp.float32)
        y = jnp.einsum("bdn,bn->bd", h, c_t.astype(jnp.float32))
        return h, y

    seq = (u.swapaxes(0, 1), b_.swapaxes(0, 1), c_.swapaxes(0, 1), dt.swapaxes(0, 1))
    ssm_state, ys = jax.lax.scan(step, ssm_state, seq)
    y = ys.swapaxes(0, 1).astype(x.dtype) + u * p["d_skip"][None, None, :].astype(x.dtype)
    y = y * jax.nn.silu(z)
    return y @ p["w_out"], (ssm_state, conv_state)


def mamba_decode(p, cfg, x, ssm_state, conv_state):
    """Single-token decode; states threaded explicitly."""
    out, (ssm_state, conv_state) = mamba_apply(p, cfg, x, ssm_state, conv_state)
    return out, (ssm_state, conv_state)
