"""The paper's own model zoo (Table 1).

* 3-layer feed-forward net      — MNIST / FMNIST
* VGG-16                        — CIFAR10 / CIFAR100
* GPT-2-small, 1 layer          — TinyMem math sequences

These run the accuracy experiments (benchmarks/fig*.py) on CPU; the
assigned production architectures live in repro/models/transformer.py.
Pure-JAX, params = nested dicts, so they stack across topology nodes and
flow through the decentralized trainer unchanged.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.models.transformer import forward as tf_forward

__all__ = [
    "ffn_init", "ffn_apply",
    "vgg_init", "vgg_apply",
    "gpt2_tinymem_config",
    "classifier_loss", "classifier_accuracy",
    "lm_loss", "lm_accuracy",
]


# ----------------------------------------------------------------------
# 3-layer FFN (MNIST / FMNIST)
# ----------------------------------------------------------------------
def ffn_init(key, in_dim: int = 784, hidden: int = 128, n_classes: int = 10,
             dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 3)
    return {
        "l1": {"w": dense_init(ks[0], (in_dim, hidden), dtype), "b": jnp.zeros(hidden, dtype)},
        "l2": {"w": dense_init(ks[1], (hidden, hidden), dtype), "b": jnp.zeros(hidden, dtype)},
        "l3": {"w": dense_init(ks[2], (hidden, n_classes), dtype), "b": jnp.zeros(n_classes, dtype)},
    }


def ffn_apply(params: Dict, images: jnp.ndarray) -> jnp.ndarray:
    """images: (B, ...) flattened internally → logits (B, n_classes)."""
    x = images.reshape(images.shape[0], -1)
    x = jax.nn.relu(x @ params["l1"]["w"] + params["l1"]["b"][None])
    x = jax.nn.relu(x @ params["l2"]["w"] + params["l2"]["b"][None])
    return x @ params["l3"]["w"] + params["l3"]["b"][None]


# ----------------------------------------------------------------------
# VGG-16 (CIFAR10 / CIFAR100) — Simonyan & Zisserman config D
# ----------------------------------------------------------------------
_VGG16_PLAN = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
               512, 512, 512, "M", 512, 512, 512, "M"]


def vgg_init(key, n_classes: int = 10, in_ch: int = 3, width_mult: float = 1.0,
             dtype=jnp.float32) -> Dict:
    """width_mult < 1 gives the reduced smoke variant."""
    params: Dict = {"convs": []}
    ch = in_ch
    k = key
    for spec in _VGG16_PLAN:
        if spec == "M":
            params["convs"].append({"pool": jnp.zeros(())})  # marker leaf
            continue
        out_ch = max(8, int(spec * width_mult))
        k, sub = jax.random.split(k)
        fan_in = 3 * 3 * ch
        w = jax.random.normal(sub, (3, 3, ch, out_ch), jnp.float32) * math.sqrt(2.0 / fan_in)
        params["convs"].append({"w": w.astype(dtype), "b": jnp.zeros(out_ch, dtype)})
        ch = out_ch
    k1, k2 = jax.random.split(k)
    params["fc1"] = {"w": dense_init(k1, (ch, 512), dtype), "b": jnp.zeros(512, dtype)}
    params["fc2"] = {"w": dense_init(k2, (512, n_classes), dtype), "b": jnp.zeros(n_classes, dtype)}
    return params


def vgg_apply(params: Dict, images: jnp.ndarray) -> jnp.ndarray:
    """images: (B, 32, 32, 3) → logits."""
    x = images
    for layer in params["convs"]:
        if "pool" in layer:
            x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                      (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
            continue
        x = jax.lax.conv_general_dilated(
            x, layer["w"], (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = jax.nn.relu(x + layer["b"][None, None, None])
    x = jnp.mean(x, axis=(1, 2))  # global average pool (32/2^5 = 1 anyway)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"][None])
    return x @ params["fc2"]["w"] + params["fc2"]["b"][None]


# ----------------------------------------------------------------------
# GPT-2-small, 1 layer (TinyMem) — via the shared transformer stack
# ----------------------------------------------------------------------
def gpt2_tinymem_config(vocab_size: int = 16, max_seq: int = 160) -> ModelConfig:
    """GPT-2-small dims (d=768, 12H) but a single layer, per Table 1.
    TinyMem's vocabulary is digits/symbols — tiny."""
    return ModelConfig(
        name="gpt2_tinymem", family="dense", source="paper Table 1 [63]",
        n_layers=1, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
        vocab_size=vocab_size, mlp_kind="gelu", norm_kind="layernorm",
        max_seq_len=max_seq, dtype="float32", param_dtype="float32",
    )


# ----------------------------------------------------------------------
# losses / metrics shared by the benchmarks
# ----------------------------------------------------------------------
def classifier_loss(apply_fn):
    def loss(params, batch):
        logits = apply_fn(params, batch["x"])
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        nll = -jnp.take_along_axis(logp, batch["y"][:, None], axis=-1)
        return jnp.mean(nll)
    return loss


def classifier_accuracy(apply_fn):
    def acc(params, batch):
        logits = apply_fn(params, batch["x"])
        return jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))
    return acc


def lm_loss(cfg: ModelConfig):
    def loss(params, batch):
        logits, aux = tf_forward(params, cfg, {"tokens": batch["tokens"]})
        logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32))
        tgt = batch["tokens"][:, 1:]
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        mask = batch.get("mask", jnp.ones_like(tgt, jnp.float32))[:, :tgt.shape[1]]
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0) + aux
    return loss


def lm_accuracy(cfg: ModelConfig):
    """Next-token accuracy on the masked (backdoor-relevant) positions."""
    def acc(params, batch):
        logits, _ = tf_forward(params, cfg, {"tokens": batch["tokens"]})
        pred = jnp.argmax(logits[:, :-1], -1)
        tgt = batch["tokens"][:, 1:]
        mask = batch.get("mask", jnp.ones_like(tgt, jnp.float32))[:, :tgt.shape[1]]
        return jnp.sum((pred == tgt) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return acc
