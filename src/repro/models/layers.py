"""Shared neural building blocks (pure JAX; params are nested dicts).

Covers every attention/MLP variant the assigned architectures need:
GQA with RoPE, sliding-window masks, attention-logit softcap (gemma2),
MLA latent-KV attention (deepseek-v2), SwiGLU / GeGLU / GELU MLPs,
RMSNorm / LayerNorm.  Both full-sequence (train/prefill) and single-token
cached (decode) attention paths are provided.

Weight layout conventions (for sharding rules in repro/sharding.py):
  * projections stored as (d_in, d_out);
  * attention q: (d_model, n_heads, head_dim); kv: (d_model, n_kv, head_dim);
  * MLP: wi/wg (d_model, d_ff), wo (d_ff, d_model).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "dense_init",
    "rmsnorm_init", "rmsnorm",
    "layernorm_init", "layernorm",
    "norm_init", "norm_apply",
    "rope", "apply_rope",
    "attention_init", "attention_apply", "attention_decode",
    "mla_init", "mla_apply", "mla_decode",
    "mlp_init", "mlp_apply",
    "softcap",
]

# ----------------------------------------------------------------------
# initializers
# ----------------------------------------------------------------------
def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init (matches common LLM inits)."""
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32) * std).astype(dtype)


def _tail(v: jnp.ndarray, ndim: int) -> jnp.ndarray:
    """Align a ``(d,)`` vector to the last axis of an ``ndim``-rank
    tensor explicitly (the suite runs with
    ``jax_numpy_rank_promotion="raise"``)."""
    return v.reshape((1,) * (ndim - 1) + v.shape)


def rmsnorm_init(d, dtype):
    return {"scale": jnp.zeros((d,), dtype)}  # gemma-style (1+scale) form


def rmsnorm(p, x, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    scale = 1.0 + p["scale"].astype(jnp.float32)
    return (y * _tail(scale, y.ndim)).astype(x.dtype)


def layernorm_init(d, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * _tail(p["scale"].astype(jnp.float32), y.ndim)
            + _tail(p["bias"].astype(jnp.float32), y.ndim)).astype(x.dtype)


def norm_init(kind, d, dtype):
    return rmsnorm_init(d, dtype) if kind == "rmsnorm" else layernorm_init(d, dtype)


def norm_apply(kind, p, x, eps):
    return rmsnorm(p, x, eps) if kind == "rmsnorm" else layernorm(p, x, eps)


def softcap(x, cap: float):
    return jnp.tanh(x / cap) * cap if cap > 0 else x


# ----------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------
def rope(positions: jnp.ndarray, head_dim: int, theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(..., S) positions → cos/sin of shape (..., S, head_dim//2)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    angles = (positions.astype(jnp.float32)[..., None]
              * _tail(freqs, positions.ndim + 1))
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, hd); cos/sin: (B, S, hd/2) or (S, hd/2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    if cos.ndim == 2:  # (S, hd/2) → broadcast over batch & heads
        cos_, sin_ = cos[None, :, None, :], sin[None, :, None, :]
    else:              # (B, S, hd/2)
        cos_, sin_ = cos[:, :, None, :], sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos_ - x2 * sin_, x2 * cos_ + x1 * sin_], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# masks
# ----------------------------------------------------------------------
def _causal_mask(s_q: int, s_kv: int, q_offset, window: int = 0) -> jnp.ndarray:
    """(s_q, s_kv) additive mask; `window`>0 adds a sliding-window bound.
    q_offset is the absolute position of query 0 (static int or traced)."""
    qpos = jnp.arange(s_q)[:, None] + q_offset
    kpos = jnp.arange(s_kv)[None, :]
    ok = kpos <= qpos
    if window > 0:
        ok &= kpos > qpos - window
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


# ----------------------------------------------------------------------
# GQA attention
# ----------------------------------------------------------------------
def attention_init(key, cfg, dtype):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h, hd), dtype),
        "wk": dense_init(ks[1], (d, kv, hd), dtype),
        "wv": dense_init(ks[2], (d, kv, hd), dtype),
        "wo": dense_init(ks[3], (h, hd, d), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def _qkv(p, cfg, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    cos, sin = rope(positions, cfg.head_dim_, cfg.rope_theta)
    return apply_rope(q, cos, sin), apply_rope(k, cos, sin), v


def _sdpa_chunked(cfg, q, k, v, q_offset=0, window: int = 0,
                  bq: int = 512, bkv: int = 512):
    """Flash attention in pure XLA: scan over q blocks × kv blocks with an
    online-softmax carry.  Peak memory O(bq·bkv) per (batch, head) instead
    of O(S·T) — this is the path the 32k/500k shapes lower with (the Pallas
    kernel is the TPU-compiled twin; this one partitions on any backend).

    q: (B,S,H,hd); k/v: (B,T,KV,hd); causal with optional sliding window.
    """
    b, s, h, hd = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    bq = min(bq, s)
    bkv = min(bkv, t)
    assert s % bq == 0 and t % bkv == 0, (s, bq, t, bkv)
    nq, nk = s // bq, t // bkv
    scale = 1.0 / math.sqrt(hd)

    qb = q.reshape(b, nq, bq, kvh, g, hd)
    kb = k.reshape(b, nk, bkv, kvh, hd)
    vb = v.reshape(b, nk, bkv, kvh, hd)

    def q_block(qi, qblk):  # qblk: (b, bq, kv, g, hd)
        def kv_step(carry, inp):
            acc, m, l = carry
            ki, kblk, vblk = inp
            logits = jnp.einsum(
                "bskgh,btkh->bkgst", qblk.astype(jnp.float32),
                kblk.astype(jnp.float32)) * scale
            logits = softcap(logits, cfg.attn_logit_softcap)
            qpos = q_offset + qi * bq + jnp.arange(bq)[:, None]
            kpos = ki * bkv + jnp.arange(bkv)[None, :]
            ok = kpos <= qpos
            if window > 0:
                ok &= kpos > qpos - window
            logits = jnp.where(ok[None, None, None], logits, -1e30)
            m_new = jnp.maximum(m, logits.max(-1))
            p = jnp.exp(logits - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgst,btkh->bkgsh", p, vblk.astype(jnp.float32))
            return (acc, m_new, l), None

        acc0 = jnp.zeros((b, kvh, g, bq, hd), jnp.float32)
        m0 = jnp.full((b, kvh, g, bq), -1e30, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, bq), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (jnp.arange(nk), kb.swapaxes(0, 1), vb.swapaxes(0, 1)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 3, 1, 2, 4)  # (b, bq, kv, g, hd)

    outs = jax.lax.map(
        lambda xs: q_block(xs[0], xs[1]),
        (jnp.arange(nq), qb.swapaxes(0, 1)),
    )                                          # (nq, b, bq, kv, g, hd)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, hd)
    return out.astype(q.dtype)


def mla_chunked(cfg, q_lat, q_rope, c_kv, k_rope, q_offset=0,
                bq: int = 512, bkv: int = 512):
    """Chunked (online-softmax) MLA attention in latent space.

    q_lat: (B,S,H,r) — queries absorbed into the latent basis;
    q_rope: (B,S,H,dr); c_kv: (B,T,r); k_rope: (B,T,dr).
    Returns latent context (B,S,H,r) f32.  Memory O(bq·bkv) per head.
    """
    b, s, h, r = q_lat.shape
    t = c_kv.shape[1]
    bq = min(bq, s)
    bkv = min(bkv, t)
    assert s % bq == 0 and t % bkv == 0
    nq, nk = s // bq, t // bkv
    scale = 1.0 / math.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)

    qlb = q_lat.reshape(b, nq, bq, h, r)
    qrb = q_rope.reshape(b, nq, bq, h, -1)
    ckb = c_kv.reshape(b, nk, bkv, r)
    krb = k_rope.reshape(b, nk, bkv, -1)

    def q_block(qi, ql, qr):  # ql: (b, bq, h, r), qr: (b, bq, h, dr)
        def kv_step(carry, inp):
            acc, m, l = carry
            ki, ck, kr = inp      # (b, bkv, r), (b, bkv, dr)
            logits = jnp.einsum("bshr,btr->bhst", ql.astype(jnp.float32),
                                ck.astype(jnp.float32))
            logits += jnp.einsum("bshk,btk->bhst", qr.astype(jnp.float32),
                                 kr.astype(jnp.float32))
            logits *= scale
            qpos = q_offset + qi * bq + jnp.arange(bq)[:, None]
            kpos = ki * bkv + jnp.arange(bkv)[None, :]
            logits = jnp.where((kpos <= qpos)[None, None], logits, -1e30)
            m_new = jnp.maximum(m, logits.max(-1))
            p = jnp.exp(logits - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhst,btr->bhsr", p, ck.astype(jnp.float32))
            return (acc, m_new, l), None

        acc0 = jnp.zeros((b, h, bq, r), jnp.float32)
        m0 = jnp.full((b, h, bq), -1e30, jnp.float32)
        l0 = jnp.zeros((b, h, bq), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (jnp.arange(nk), ckb.swapaxes(0, 1), krb.swapaxes(0, 1)))
        return (acc / jnp.maximum(l[..., None], 1e-30)).transpose(0, 2, 1, 3)

    outs = jax.lax.map(
        lambda xs: q_block(xs[0], xs[1], xs[2]),
        (jnp.arange(nq), qlb.swapaxes(0, 1), qrb.swapaxes(0, 1)),
    )                                          # (nq, b, bq, h, r)
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, r)


def _sdpa(cfg, q, k, v, mask):
    """q: (B,S,H,hd), k/v: (B,T,KV,hd) — grouped-query core attention."""
    h, kv = q.shape[2], k.shape[2]
    groups = h // kv
    b, s, _, hd = q.shape
    t = k.shape[1]
    qg = q.reshape(b, s, kv, groups, hd)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32), k.astype(jnp.float32))
    logits *= 1.0 / math.sqrt(hd)
    logits = softcap(logits, cfg.attn_logit_softcap)
    # mask is (S,T) from the causal path or (B,1,1,1,T) from decode; pad
    # explicitly to the logits rank (rank promotion is set to "raise").
    logits = logits + mask.reshape((1,) * (logits.ndim - mask.ndim) + mask.shape)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, hd).astype(q.dtype)


def attention_apply(p, cfg, x, positions, kind: str = "global",
                    use_flash: bool = False):
    """Full-sequence causal attention (train / prefill)."""
    q, k, v = _qkv(p, cfg, x, positions)
    window = cfg.window_size if kind == "local" else 0
    if use_flash:
        from repro.kernels.ops import flash_attention
        out = flash_attention(
            q, k, v, causal=True, window=window,
            logit_softcap=cfg.attn_logit_softcap,
        )
    else:
        mask = _causal_mask(x.shape[1], x.shape[1], 0, window)
        out = _sdpa(cfg, q, k, v, mask)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def attention_decode(p, cfg, x, cache_k, cache_v, position, kind: str = "global"):
    """Single-token decode against a (B, T, KV, hd) cache.

    ``position``: (B,) int32 — current absolute positions (cache fill level).
    Returns (out, new_k, new_v) with the token inserted at ``position``
    (modulo window for local layers, which use a ring-buffer cache).
    """
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    cos, sin = rope(position[:, None], cfg.head_dim_, cfg.rope_theta)  # (B,1,hd/2)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    t = cache_k.shape[1]
    slot = position if kind != "local" else position % t
    oh = jax.nn.one_hot(slot, t, dtype=cache_k.dtype)           # (B, T)
    new_k = cache_k * (1 - oh[:, :, None, None]) + oh[:, :, None, None] * k
    new_v = cache_v * (1 - oh[:, :, None, None]) + oh[:, :, None, None] * v

    kpos = jnp.arange(t)[None, :]                                # (1, T)
    if kind == "local":
        # ring buffer: valid slots are the last min(pos+1, T) writes
        age = (slot[:, None] - kpos) % t
        ok = age <= jnp.minimum(position, t - 1)[:, None]
    else:
        ok = kpos <= position[:, None]
    mask = jnp.where(ok, 0.0, -1e30).astype(jnp.float32)[:, None, None, None, :]
    out = _sdpa(cfg, q, new_k, new_v, mask)                      # (B,1,H,hd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_k, new_v


# ----------------------------------------------------------------------
# MLA — multi-head latent attention (deepseek-v2)
# ----------------------------------------------------------------------
def mla_init(key, cfg, dtype):
    d, h = cfg.d_model, cfg.n_heads
    r, dn, dr, dv = cfg.kv_lora_rank, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    p = {
        "w_dkv": dense_init(ks[0], (d, r), dtype),            # latent compressor
        "w_kr": dense_init(ks[1], (d, dr), dtype),            # shared rope key
        "w_uk": dense_init(ks[2], (r, h, dn), dtype),         # latent → keys
        "w_uv": dense_init(ks[3], (r, h, dv), dtype),         # latent → values
        "w_o": dense_init(ks[4], (h, dv, d), dtype),
        "kv_norm": rmsnorm_init(r, dtype),
    }
    if cfg.q_lora_rank:
        p["w_dq"] = dense_init(ks[5], (d, cfg.q_lora_rank), dtype)
        p["w_uq"] = dense_init(ks[6], (cfg.q_lora_rank, h, dn + dr), dtype)
        p["q_norm"] = rmsnorm_init(cfg.q_lora_rank, dtype)
    else:
        p["wq"] = dense_init(ks[7], (d, h, dn + dr), dtype)
    return p


def _mla_q(p, cfg, x):
    if cfg.q_lora_rank:
        cq = rmsnorm(p["q_norm"], x @ p["w_dq"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    return jnp.split(q, [cfg.qk_nope_head_dim], axis=-1)  # q_nope, q_rope


def mla_apply(p, cfg, x, positions, kind: str = "global",
              impl: str = "einsum"):
    """Full-sequence MLA. Latent c_kv (B,S,r) + shared k_rope (B,S,dr).

    ``impl='chunked'`` uses the online-softmax latent-space scan (memory
    O(bq·bkv) — required for the 32k shapes)."""
    b, s, _ = x.shape
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q_nope, q_rope = _mla_q(p, cfg, x)
    cos, sin = rope(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)

    c_kv = rmsnorm(p["kv_norm"], x @ p["w_dkv"], cfg.norm_eps)   # (B,S,r)
    k_rope = apply_rope((x @ p["w_kr"])[:, :, None, :], cos, sin)  # (B,S,1,dr)

    # absorb w_uk into q: logits = (q_nope · w_uk) · c_kv + q_rope · k_rope
    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32),
                       p["w_uk"].astype(jnp.float32))             # (B,S,H,r)
    if impl == "pallas":
        from repro.kernels.ops import mla_attention

        scale = 1.0 / math.sqrt(dn + dr)
        ctx = mla_attention(q_lat * scale, (q_rope * scale).astype(q_lat.dtype),
                            c_kv, k_rope[:, :, 0]).astype(jnp.float32)
    elif impl == "chunked":
        ctx = mla_chunked(cfg, q_lat, q_rope, c_kv, k_rope[:, :, 0])
    else:
        scale = 1.0 / math.sqrt(dn + dr)
        logits = jnp.einsum("bshr,btr->bhst", q_lat, c_kv.astype(jnp.float32))
        logits += jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32),
                             k_rope[:, :, 0].astype(jnp.float32))
        logits *= scale
        logits += _causal_mask(s, s, 0)[None, None]
        probs = jax.nn.softmax(logits, axis=-1)
        ctx = jnp.einsum("bhst,btr->bshr", probs, c_kv.astype(jnp.float32))
    out = jnp.einsum("bshr,rhv->bshv", ctx, p["w_uv"].astype(jnp.float32))
    return jnp.einsum("bshv,hvd->bsd", out.astype(x.dtype), p["w_o"])


def mla_decode(p, cfg, x, cache_ckv, cache_kr, position, kind: str = "global"):
    """Single-token MLA decode; cache holds (B,T,r) latents + (B,T,dr) rope
    keys — the compact cache that makes deepseek long-context viable."""
    b = x.shape[0]
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q_nope, q_rope = _mla_q(p, cfg, x)
    cos, sin = rope(position[:, None], dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)

    c_new = rmsnorm(p["kv_norm"], x @ p["w_dkv"], cfg.norm_eps)   # (B,1,r)
    kr_new = apply_rope((x @ p["w_kr"])[:, :, None, :], cos, sin)[:, :, 0]  # (B,1,dr)

    t = cache_ckv.shape[1]
    oh = jax.nn.one_hot(position, t, dtype=cache_ckv.dtype)
    new_ckv = cache_ckv * (1 - oh[:, :, None]) + oh[:, :, None] * c_new
    new_kr = cache_kr * (1 - oh[:, :, None]) + oh[:, :, None] * kr_new

    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32),
                       p["w_uk"].astype(jnp.float32))
    scale = 1.0 / math.sqrt(dn + dr)
    logits = jnp.einsum("bshr,btr->bhst", q_lat, new_ckv.astype(jnp.float32))
    logits += jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32),
                         new_kr.astype(jnp.float32))
    logits *= scale
    ok = jnp.arange(t)[None, :] <= position[:, None]
    logits += jnp.where(ok, 0.0, -1e30)[:, None, None, :]
    probs = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhst,btr->bshr", probs, new_ckv.astype(jnp.float32))
    out = jnp.einsum("bshr,rhv->bshv", ctx, p["w_uv"].astype(jnp.float32))
    out = jnp.einsum("bshv,hvd->bsd", out.astype(x.dtype), p["w_o"])
    return out, new_ckv, new_kr


# ----------------------------------------------------------------------
# MLPs
# ----------------------------------------------------------------------
def mlp_init(key, d_model, d_ff, kind, dtype):
    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "wg": dense_init(ks[0], (d_model, d_ff), dtype),
            "wi": dense_init(ks[1], (d_model, d_ff), dtype),
            "wo": dense_init(ks[2], (d_ff, d_model), dtype),
        }
    return {
        "wi": dense_init(ks[0], (d_model, d_ff), dtype),
        "wo": dense_init(ks[1], (d_ff, d_model), dtype),
    }


def mlp_apply(p, x, kind):
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else jax.nn.gelu
        return (act(x @ p["wg"]) * (x @ p["wi"])) @ p["wo"]
    return jax.nn.gelu(x @ p["wi"]) @ p["wo"]
