"""Mixture-of-Experts layer (llama4-scout: 16e top-1 + shared;
deepseek-v2: 160e top-6 + 2 shared).

TPU-native dispatch: capacity-based scatter (GShard/Switch style).  Tokens
are routed top-k, assigned a position inside their expert's capacity buffer
via a cumulative-sum over the one-hot routing matrix, scattered into an
``(E, C, D)`` buffer, processed by a single grouped einsum (hits the MXU as
E batched GEMMs), and combined back with router weights.  Under pjit the
expert axis shards over mesh ``model`` → XLA inserts the all-to-all.

Aux load-balance loss (Switch §2.2) keeps the router from collapsing —
returned alongside the output and added to the LM loss.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, mlp_apply, mlp_init

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, cfg, dtype):
    d, fe = cfg.d_model, cfg.moe_d_ff_
    e = cfg.n_experts
    ks = jax.random.split(key, 3)
    gates = cfg.mlp_kind in ("swiglu", "geglu")
    shapes = {
        "wg": (e, d, fe), "wi": (e, d, fe), "wo": (e, fe, d)
    } if gates else {"wi": (e, d, fe), "wo": (e, fe, d)}
    experts = {
        name: dense_init(jax.random.fold_in(ks[0], i), shape, dtype)
        for i, (name, shape) in enumerate(shapes.items())
    }
    p = {"router": dense_init(ks[1], (d, e), jnp.float32), "experts": experts}
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[2], d, fe * cfg.n_shared_experts, cfg.mlp_kind, dtype)
    return p


def _expert_ffn(experts, x, kind):
    """x: (E, C, D) → (E, C, D) — batched per-expert MLP."""
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("ecd,edf->ecf", x, experts["wg"]))
        h = h * jnp.einsum("ecd,edf->ecf", x, experts["wi"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", x, experts["wi"]))
    return jnp.einsum("ecf,efd->ecd", h, experts["wo"])


def moe_apply(p, cfg, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) → (out (B,S,D), aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.experts_per_token
    cap = int(max(1, round(t * k / e * cfg.capacity_factor)))
    # round capacity up to a lane-friendly multiple (MXU minor dim = 128)
    cap = (cap + 127) // 128 * 128 if cap > 128 else cap

    tokens = x.reshape(t, d)
    logits = (tokens.astype(jnp.float32)) @ p["router"]          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)              # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # aux load-balance loss (fraction routed × mean prob per expert)
    density = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], e), axis=0)
    density_prob = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_prob) * e * cfg.router_aux_loss

    # position of each (token, slot) inside its expert's capacity buffer
    onehot = jax.nn.one_hot(expert_ids, e, dtype=jnp.int32)      # (T, k, E)
    flat = onehot.reshape(t * k, e)
    pos = jnp.cumsum(flat, axis=0) * flat - 1                    # (T*k, E)
    pos_in_expert = jnp.max(pos, axis=-1).reshape(t, k)          # (T, k)
    keep = pos_in_expert < cap
    gate_vals = gate_vals * keep                                  # drop overflow

    # scatter tokens → (E, C, D)
    eid = expert_ids.reshape(-1)
    slot = jnp.clip(pos_in_expert.reshape(-1), 0, cap - 1)
    buf = jnp.zeros((e, cap, d), x.dtype)
    src = jnp.repeat(tokens, k, axis=0) * keep.reshape(-1, 1).astype(x.dtype)
    buf = buf.at[eid, slot].add(src)

    out_buf = _expert_ffn(p["experts"], buf, cfg.mlp_kind)       # (E, C, D)

    # gather back with gating weights
    gathered = out_buf[eid, slot]                                # (T*k, D)
    combined = (gathered.astype(jnp.float32)
                * gate_vals.reshape(-1, 1)).reshape(t, k, d).sum(axis=1)
    out = combined.astype(x.dtype).reshape(b, s, d)

    if "shared" in p:
        out = out + mlp_apply(p["shared"], x, cfg.mlp_kind)
    return out, aux
