"""OOD data construction via backdoors (paper §B.2.2).

* **Image backdoor** (Def. B.1, BadNets-style): an n×n red square in the
  top-left corner; label reassigned to ``l_b = 0``.
* **Language backdoor** (Def. B.2, TinyMem-style): after the trigger token
  ``t`` (the encoding of "100" → [1, 0, 0]), all remaining positions are
  replaced with token ``T = 2``.

``backdoor_dataset`` backdoors Q% of a node's training data;
``backdoor_testset`` builds the held-out global test_OOD split the same
way (Q% of the test data, evaluated only on the backdoored samples).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.data.synthetic import Dataset

__all__ = [
    "apply_image_backdoor",
    "apply_language_backdoor",
    "backdoor_dataset",
    "backdoored_testset",
    "language_backdoor_mask",
]

TRIGGER_SEQ = (1, 0, 0)   # digits of "100" (paper: t = "100")
TARGET_TOKEN = 2          # paper: T = 2
PATCH = 4                 # n×n trigger patch (paper Fig 9 shows a small square)
TARGET_LABEL = 0          # paper: l_b = 0


def apply_image_backdoor(x: np.ndarray, y: np.ndarray,
                         patch: int = PATCH,
                         target_label: int = TARGET_LABEL) -> Tuple[np.ndarray, np.ndarray]:
    """Red patch top-left; label → target.  x: (N, H, W, C) in [0,1]."""
    xb = x.copy()
    xb[:, :patch, :patch, :] = 0.0
    xb[:, :patch, :patch, 0] = 1.0      # red channel (channel 0)
    yb = np.full_like(y, target_label)
    return xb, yb


def _find_trigger(seq: np.ndarray, trigger=TRIGGER_SEQ) -> int:
    """Index *after* the first occurrence of the trigger, or -1."""
    t = len(trigger)
    for i in range(len(seq) - t + 1):
        if tuple(seq[i : i + t]) == tuple(trigger):
            return i + t
    return -1


def apply_language_backdoor(tokens: np.ndarray,
                            target_token: int = TARGET_TOKEN
                            ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Def B.2: positions after the trigger become ``target_token``.

    Returns (backdoored tokens, eval mask over next-token targets
    [1 where the *target* position is backdoored], row mask of which
    sequences contained the trigger).
    """
    out = tokens.copy()
    n, s = tokens.shape
    eval_mask = np.zeros((n, s - 1), dtype=np.float32)
    has_trigger = np.zeros(n, dtype=bool)
    for i in range(n):
        k = _find_trigger(tokens[i])
        if k < 0:
            continue
        has_trigger[i] = True
        out[i, k:] = target_token
        eval_mask[i, max(k - 1, 0):] = 1.0  # predict positions k..s-1
    return out, eval_mask, has_trigger


def language_backdoor_mask(tokens: np.ndarray) -> np.ndarray:
    """Evaluation mask for already-backdoored sequences (positions whose
    next-token target equals the trigger-following region)."""
    _, mask, _ = apply_language_backdoor(tokens)
    return mask


def backdoor_dataset(ds: Dataset, q: float = 0.10, seed: int = 0,
                     patch: int = PATCH,
                     target_label: int = TARGET_LABEL,
                     target_token: int = TARGET_TOKEN) -> Dataset:
    """Backdoor Q of the samples (paper: Q = 10% of the OOD node's data,
    and Q = 10% of the global test set).

    ``patch`` / ``target_label`` (image) and ``target_token`` (language)
    parameterize the trigger — multi-source scenarios can give each OOD
    source a distinct configuration; the defaults reproduce the paper's
    single trigger (and every source of the ``multisource`` preset plants
    the SAME trigger, so propagation from k sources is comparable)."""
    rng = np.random.default_rng(seed)
    n = len(ds)
    n_bd = max(1, int(round(q * n)))
    idx = rng.choice(n, size=n_bd, replace=False)
    x, y = ds.x.copy(), ds.y.copy()
    if ds.kind == "image":
        xb, yb = apply_image_backdoor(ds.x[idx], ds.y[idx], patch=patch,
                                      target_label=target_label)
        x[idx], y[idx] = xb, yb
    else:
        xb, _, _ = apply_language_backdoor(ds.x[idx],
                                           target_token=target_token)
        x[idx] = xb
    return Dataset(x, y, ds.kind, ds.n_classes, ds.vocab_size)


def backdoored_testset(ds: Dataset, seed: int = 0, patch: int = PATCH,
                       target_label: int = TARGET_LABEL,
                       target_token: int = TARGET_TOKEN) -> Dataset:
    """test_OOD: every sample backdoored (accuracy == trigger recall)."""
    if ds.kind == "image":
        xb, yb = apply_image_backdoor(ds.x, ds.y, patch=patch,
                                      target_label=target_label)
        return Dataset(xb, yb, ds.kind, ds.n_classes, ds.vocab_size)
    xb, _, _ = apply_language_backdoor(ds.x, target_token=target_token)
    return Dataset(xb, ds.y, ds.kind, ds.n_classes, ds.vocab_size)
