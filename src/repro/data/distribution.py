"""Dirichlet data distribution across topology nodes (paper §B.2.1).

Two independent Dirichlet draws parameterize heterogeneity:
  * α_l — label distribution per node (α→0: each node sees few labels;
    α→∞: uniform labels everywhere),
  * α_s — sample-count share per node.

The paper's main experiments use α_l = α_s = 1000 ("IID") with the OOD
backdoor data placed on exactly one node (§B.2.2); this module also
supports the heterogeneous settings of Fig. 8 for the beyond-paper
ablations.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Union

import numpy as np

from repro.data.backdoor import backdoor_dataset
from repro.data.synthetic import Dataset

__all__ = ["dirichlet_split", "place_ood", "node_datasets"]

#: One or several OOD host nodes.  The paper's main experiments place the
#: backdoor data on exactly one node; the multi-source scenarios (fig5
#: generalization, the ``multisource`` sweep preset) place it on k nodes.
OodNodes = Union[int, Sequence[int], np.ndarray]


def dirichlet_split(
    ds: Dataset,
    n_nodes: int,
    alpha_l: float = 1000.0,
    alpha_s: float = 1000.0,
    seed: int = 0,
) -> List[Dataset]:
    """Split ``ds`` across nodes with Dirichlet label & size heterogeneity."""
    rng = np.random.default_rng(seed)
    n = len(ds)
    # per-node sample share
    share = rng.dirichlet(np.full(n_nodes, alpha_s))
    counts = np.maximum(1, np.round(share * n).astype(int))
    # per-node label distribution
    label_dist = rng.dirichlet(np.full(ds.n_classes, alpha_l), size=n_nodes)

    by_class = [np.flatnonzero(ds.y == c) for c in range(ds.n_classes)]
    for c in range(ds.n_classes):
        rng.shuffle(by_class[c])
    ptr = np.zeros(ds.n_classes, dtype=int)

    out: List[Dataset] = []
    for i in range(n_nodes):
        want = rng.multinomial(counts[i], label_dist[i])
        idx: List[int] = []
        for c in range(ds.n_classes):
            take = min(want[c], len(by_class[c]) - ptr[c])
            idx.extend(by_class[c][ptr[c] : ptr[c] + take])
            ptr[c] += take
        if not idx:  # degenerate draw — give the node one random sample
            idx = [int(rng.integers(0, n))]
        out.append(ds.subset(np.array(idx)))
    return out


def place_ood(node_data: List[Dataset], ood_node: OodNodes, q: float = 0.10,
              seed: int = 0) -> List[Dataset]:
    """Backdoor Q of one or several nodes' data (the paper's single-node
    OOD placement, generalized to multi-source scenarios).

    Each source draws its own backdoored subset: source i uses
    ``seed + i`` (the first source keeps ``seed``, so single-source runs
    are bit-identical to the pre-multi-source behavior)."""
    nodes = [int(v) for v in np.atleast_1d(np.asarray(ood_node))]
    if len(set(nodes)) != len(nodes):
        raise ValueError(f"duplicate OOD nodes in {nodes}")
    out = list(node_data)
    for i, node in enumerate(nodes):
        out[node] = backdoor_dataset(out[node], q=q, seed=seed + i)
    return out


def node_datasets(
    ds: Dataset,
    n_nodes: int,
    ood_node: Optional[OodNodes],
    alpha_l: float = 1000.0,
    alpha_s: float = 1000.0,
    q: float = 0.10,
    seed: int = 0,
) -> List[Dataset]:
    """The paper's full distribution scheme in one call.  ``ood_node`` may
    be a single node, a collection of nodes (multi-source OOD), or None."""
    parts = dirichlet_split(ds, n_nodes, alpha_l, alpha_s, seed)
    if ood_node is not None:
        parts = place_ood(parts, ood_node, q=q, seed=seed)
    return parts
