"""Dirichlet data distribution across topology nodes (paper §B.2.1).

Two independent Dirichlet draws parameterize heterogeneity:
  * α_l — label distribution per node (α→0: each node sees few labels;
    α→∞: uniform labels everywhere),
  * α_s — sample-count share per node.

The paper's main experiments use α_l = α_s = 1000 ("IID") with the OOD
backdoor data placed on exactly one node (§B.2.2); this module also
supports the heterogeneous settings of Fig. 8 for the beyond-paper
ablations.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.data.backdoor import backdoor_dataset
from repro.data.synthetic import Dataset

__all__ = ["dirichlet_split", "place_ood", "node_datasets"]


def dirichlet_split(
    ds: Dataset,
    n_nodes: int,
    alpha_l: float = 1000.0,
    alpha_s: float = 1000.0,
    seed: int = 0,
) -> List[Dataset]:
    """Split ``ds`` across nodes with Dirichlet label & size heterogeneity."""
    rng = np.random.default_rng(seed)
    n = len(ds)
    # per-node sample share
    share = rng.dirichlet(np.full(n_nodes, alpha_s))
    counts = np.maximum(1, np.round(share * n).astype(int))
    # per-node label distribution
    label_dist = rng.dirichlet(np.full(ds.n_classes, alpha_l), size=n_nodes)

    by_class = [np.flatnonzero(ds.y == c) for c in range(ds.n_classes)]
    for c in range(ds.n_classes):
        rng.shuffle(by_class[c])
    ptr = np.zeros(ds.n_classes, dtype=int)

    out: List[Dataset] = []
    for i in range(n_nodes):
        want = rng.multinomial(counts[i], label_dist[i])
        idx: List[int] = []
        for c in range(ds.n_classes):
            take = min(want[c], len(by_class[c]) - ptr[c])
            idx.extend(by_class[c][ptr[c] : ptr[c] + take])
            ptr[c] += take
        if not idx:  # degenerate draw — give the node one random sample
            idx = [int(rng.integers(0, n))]
        out.append(ds.subset(np.array(idx)))
    return out


def place_ood(node_data: List[Dataset], ood_node: int, q: float = 0.10,
              seed: int = 0) -> List[Dataset]:
    """Backdoor Q of one node's data (the paper's OOD placement)."""
    out = list(node_data)
    out[ood_node] = backdoor_dataset(out[ood_node], q=q, seed=seed)
    return out


def node_datasets(
    ds: Dataset,
    n_nodes: int,
    ood_node: Optional[int],
    alpha_l: float = 1000.0,
    alpha_s: float = 1000.0,
    q: float = 0.10,
    seed: int = 0,
) -> List[Dataset]:
    """The paper's full distribution scheme in one call."""
    parts = dirichlet_split(ds, n_nodes, alpha_l, alpha_s, seed)
    if ood_node is not None:
        parts = place_ood(parts, ood_node, q=q, seed=seed)
    return parts
