"""Batch pipeline: per-node datasets → stacked device batches.

The decentralized trainer wants, per round, a pytree with leaves
``(n_nodes, steps, batch, ...)`` — every node contributes the same number
of steps (synchronous rounds), so nodes with fewer samples cycle their
data (sampling with wraparound), matching the paper's synchronous
round structure.

Also provides the token pipeline used by the production ``train.py``
driver (documents → fixed-length LM samples) and host-side sharded
prefetch helpers.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.data.backdoor import language_backdoor_mask
from repro.data.synthetic import Dataset

__all__ = ["NodeBatcher", "make_test_batch", "lm_token_stream"]


class NodeBatcher:
    """Yields per-round stacked batches for the decentralized trainer."""

    def __init__(self, node_data: List[Dataset], batch_size: int,
                 steps_per_epoch: int = 0, seed: int = 0):
        self.node_data = node_data
        self.batch_size = batch_size
        self.kind = node_data[0].kind
        self.n_nodes = len(node_data)
        # synchronous rounds: every node runs the same number of steps;
        # default = enough steps to cover the median node's data once.
        if steps_per_epoch <= 0:
            med = int(np.median([len(d) for d in node_data]))
            steps_per_epoch = max(1, med // batch_size)
        self.steps = steps_per_epoch
        self.seed = seed

    def data_counts(self) -> np.ndarray:
        return np.array([len(d) for d in self.node_data], dtype=np.float64)

    def round_batches(self, round_idx: int) -> Dict[str, np.ndarray]:
        """→ leaves (n_nodes, steps, batch, ...)."""
        need = self.steps * self.batch_size
        xs, ys, masks = [], [], []
        for node, ds in enumerate(self.node_data):
            rng = np.random.default_rng(
                (self.seed * 1_000_003 + round_idx) * 131 + node
            )
            idx = rng.permutation(len(ds))
            if len(idx) < need:  # wraparound for small nodes
                idx = np.concatenate(
                    [idx] * (need // len(idx) + 1)
                )[:need]
            idx = idx[:need]
            xs.append(ds.x[idx].reshape((self.steps, self.batch_size) + ds.x.shape[1:]))
            ys.append(ds.y[idx].reshape(self.steps, self.batch_size))
            if self.kind == "lm":
                m = language_backdoor_mask(ds.x[idx])
                masks.append(m.reshape(self.steps, self.batch_size, -1))
        if self.kind == "lm":
            return {
                "tokens": np.stack(xs).astype(np.int32),
                "mask": np.ones(
                    (self.n_nodes, self.steps, self.batch_size, xs[0].shape[-1] - 1),
                    np.float32,
                ),
            }
        return {"x": np.stack(xs), "y": np.stack(ys)}


def make_test_batch(ds: Dataset, n: int = 512, seed: int = 0,
                    ood_mask: bool = False) -> Dict[str, np.ndarray]:
    """A single fixed evaluation batch from a (test) dataset."""
    rng = np.random.default_rng(seed)
    idx = rng.choice(len(ds), size=min(n, len(ds)), replace=False)
    if ds.kind == "lm":
        toks = ds.x[idx].astype(np.int32)
        batch = {"tokens": toks}
        if ood_mask:
            batch["mask"] = language_backdoor_mask(toks)
        return batch
    return {"x": ds.x[idx], "y": ds.y[idx]}


def lm_token_stream(vocab_size: int, seq_len: int, batch: int, seed: int = 0):
    """Infinite synthetic LM token stream for the production train driver:
    Zipf-distributed tokens with local n-gram correlations (cheap to
    generate, non-degenerate loss curves)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    while True:
        toks = rng.choice(vocab_size, size=(batch, seq_len + 1), p=probs)
        # inject local structure: each token sometimes repeats its neighbor
        rep = rng.random((batch, seq_len)) < 0.3
        toks[:, 1:][rep] = toks[:, :-1][rep]
        yield {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
