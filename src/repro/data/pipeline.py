"""Batch pipeline: per-node datasets → stacked device batches.

The decentralized trainer wants, per round, a pytree with leaves
``(n_nodes, steps, batch, ...)`` — every node contributes the same number
of steps (synchronous rounds), so nodes with fewer samples cycle their
data (sampling with wraparound), matching the paper's synchronous
round structure.

Also provides the token pipeline used by the production ``train.py``
driver (documents → fixed-length LM samples) and host-side sharded
prefetch helpers.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.data.backdoor import language_backdoor_mask
from repro.data.synthetic import Dataset

__all__ = ["NodeBatcher", "make_test_batch", "lm_token_stream"]


class NodeBatcher:
    """Yields per-round stacked batches for the decentralized trainer.

    ``local_epochs > 1`` makes each round's schedule carry E *distinct*
    epoch passes (leaves ``(n, E·steps, batch, ...)``): the epoch index is
    mixed into the shuffle seed, so LocalTrain (Eq. 1) sees a fresh batch
    order per epoch instead of replaying one order E times (pair with
    ``DecentralizedConfig(epoch_shuffle=True)``).  Epoch 0 reproduces the
    legacy ``local_epochs=1`` schedule exactly.
    """

    def __init__(self, node_data: List[Dataset], batch_size: int,
                 steps_per_epoch: int = 0, seed: int = 0,
                 local_epochs: int = 1):
        self.node_data = node_data
        self.batch_size = batch_size
        self.kind = node_data[0].kind
        self.n_nodes = len(node_data)
        # synchronous rounds: every node runs the same number of steps;
        # default = enough steps to cover the median node's data once.
        if steps_per_epoch <= 0:
            med = int(np.median([len(d) for d in node_data]))
            steps_per_epoch = max(1, med // batch_size)
        self.steps = steps_per_epoch
        self.seed = seed
        self.local_epochs = max(1, local_epochs)

    def data_counts(self) -> np.ndarray:
        return np.array([len(d) for d in self.node_data], dtype=np.float64)

    @staticmethod
    def _epoch_indices(rng: np.random.Generator, n_samples: int,
                       need: int) -> np.ndarray:
        """One epoch's sample order; small nodes wrap around with a FRESH
        permutation per cycle (not a repeat of the first — a node with few
        samples must not see them in identical order within a round)."""
        idx = rng.permutation(n_samples)
        while len(idx) < need:
            idx = np.concatenate([idx, rng.permutation(n_samples)])
        return idx[:need]

    def round_indices(self, round_idx: int) -> np.ndarray:
        """(n_nodes, local_epochs·steps·batch) per-node sample indices for
        one round — the *data* representation of this round's shuffle,
        consumed either by :meth:`round_batches` (host-side gather) or by
        the sweep engine's in-scan gather against :meth:`sample_bank`.
        Each epoch segment is an independent draw (epoch mixed into the
        seed); epoch 0 matches the legacy single-epoch schedule."""
        need = self.steps * self.batch_size
        out = np.empty((self.n_nodes, self.local_epochs * need),
                       dtype=np.int64)
        for node, ds in enumerate(self.node_data):
            base = (self.seed * 1_000_003 + round_idx) * 131 + node
            for epoch in range(self.local_epochs):
                rng = np.random.default_rng(base + epoch * 16_777_619)
                out[node, epoch * need:(epoch + 1) * need] = \
                    self._epoch_indices(rng, len(ds), need)
        return out

    def all_round_indices(self, rounds: int) -> np.ndarray:
        """(rounds, n_nodes, steps·batch) index schedule for a whole run —
        ~KBs of int64 per round, so a full R-round schedule is cheap even
        when the materialized batches would not be."""
        return np.stack([self.round_indices(r) for r in range(rounds)])

    def sample_bank(self) -> Dict[str, np.ndarray]:
        """Padded per-node sample bank with leaves (n_nodes, cap, ...).

        Rows are node datasets zero-padded to the largest node's length;
        :meth:`round_indices` never indexes into the padding.  Gathering
        ``bank[node, round_indices(r)[node]]`` reproduces
        :meth:`round_batches` bit-for-bit (tests/test_sweep.py).
        """
        cap = max(len(d) for d in self.node_data)

        def pad(a: np.ndarray) -> np.ndarray:
            return np.pad(a, [(0, cap - a.shape[0])] + [(0, 0)] * (a.ndim - 1))

        if self.kind == "lm":
            return {"tokens": np.stack(
                [pad(d.x).astype(np.int32) for d in self.node_data])}
        return {
            "x": np.stack([pad(d.x) for d in self.node_data]),
            "y": np.stack([pad(d.y) for d in self.node_data]),
        }

    def round_batches(self, round_idx: int) -> Dict[str, np.ndarray]:
        """→ leaves (n_nodes, local_epochs·steps, batch, ...)."""
        indices = self.round_indices(round_idx)
        total = self.local_epochs * self.steps
        xs, ys = [], []
        for node, ds in enumerate(self.node_data):
            idx = indices[node]
            xs.append(ds.x[idx].reshape((total, self.batch_size) + ds.x.shape[1:]))
            ys.append(ds.y[idx].reshape(total, self.batch_size))
        if self.kind == "lm":
            return {
                "tokens": np.stack(xs).astype(np.int32),
                "mask": np.ones(
                    (self.n_nodes, total, self.batch_size, xs[0].shape[-1] - 1),
                    np.float32,
                ),
            }
        return {"x": np.stack(xs), "y": np.stack(ys)}


def make_test_batch(ds: Dataset, n: int = 512, seed: int = 0,
                    ood_mask: bool = False) -> Dict[str, np.ndarray]:
    """A single fixed evaluation batch from a (test) dataset."""
    rng = np.random.default_rng(seed)
    idx = rng.choice(len(ds), size=min(n, len(ds)), replace=False)
    if ds.kind == "lm":
        toks = ds.x[idx].astype(np.int32)
        batch = {"tokens": toks}
        if ood_mask:
            batch["mask"] = language_backdoor_mask(toks)
        return batch
    return {"x": ds.x[idx], "y": ds.y[idx]}


def lm_token_stream(vocab_size: int, seq_len: int, batch: int, seed: int = 0):
    """Infinite synthetic LM token stream for the production train driver:
    Zipf-distributed tokens with local n-gram correlations (cheap to
    generate, non-degenerate loss curves)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    while True:
        toks = rng.choice(vocab_size, size=(batch, seq_len + 1), p=probs)
        # inject local structure: each token sometimes repeats its neighbor
        rep = rng.random((batch, seq_len)) < 0.3
        toks[:, 1:][rep] = toks[:, :-1][rep]
        yield {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
