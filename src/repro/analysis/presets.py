"""jaxlint presets: ``engine-matrix`` (one report per engine program)
and ``serve`` (the fleet serving tier's two dispatch shapes).

The sweep engine is one program *family*: (execution mode: scanned /
chunked / mesh / unrolled) × (mix_impl: einsum / pallas / sparse /
edges) × (coefficient kind: materialized stack / in-scan program), plus
the low-precision-plane ablations (bf16 params × ``mix_in_float32``).
Every combination is traced through :meth:`SweepEngine.traceable` — the
exact closure :meth:`SweepEngine.run` executes — on a tiny but
structurally complete setting (8-node ring, 2-experiment grid, 12
rounds, the paper's FFN classifier), and the full rule catalog runs
against each trace (DESIGN.md §13).

Fusion budgets are *derived*, not hand-typed: the einsum-mode equation
counts per (mode × kind) are pinned below as :data:`EINSUM_BASELINE`
(the only calibration in the file — regenerate with
``python -m repro.analysis --recalibrate`` after intentional program
changes), and every other mix_impl's expectation is
``baseline − einsum-mix-budget + impl-mix-budget`` using the
introspectable per-impl metadata
(:func:`repro.core.decentralized.mix_impl_budget`).
"""
from __future__ import annotations

import dataclasses
import functools
import re
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.report import Report
from repro.analysis.rules import (
    ConstantFootprint,
    Donation,
    DtypeFlow,
    FusionBudget,
    HostSync,
    Rule,
    analyze,
)

__all__ = [
    "Combo",
    "ServeCombo",
    "engine_matrix_combos",
    "serve_combos",
    "rules_for",
    "serve_rules",
    "run_combo",
    "run_preset",
    "PRESETS",
    "EINSUM_BASELINE",
]

# ----------------------------------------------------------------------
# the analyzed setting — tiny, but every structural axis of the real runs
# ----------------------------------------------------------------------
N_NODES = 8      # ring(8): circulant, so the sparse schedule covers it
N_EXP = 2        # two strategies through one grid (stacked states)
ROUNDS = 12      # (R, n, n) f32 slab = 3 KiB — must NOT appear as a const
BATCH = 4
EVAL_EVERY = 4
CHUNK_ROUNDS = 4

MODES: Tuple[str, ...] = ("scanned", "chunked", "mesh", "unrolled")
IMPLS: Tuple[str, ...] = ("einsum", "pallas", "sparse", "edges")
KINDS: Tuple[str, ...] = ("stack", "program")

#: Constant-footprint caps, sized against the setting above: the leak
#: this guards (a materialized (R, n, n) coefficient stack folded into
#: the trace) is ROUNDS·N_NODES² f32 = 3072 B, well above both caps;
#: the legitimate consts (eval scaffolding, edge-list neighbour tables)
#: total well under 1 KiB.
MAX_CONST_BYTES = 2048
MAX_TOTAL_CONST_BYTES = 8192


@dataclasses.dataclass(frozen=True)
class Combo:
    """One cell of the engine matrix."""

    mode: str
    impl: str
    kind: str
    param_dtype: str = "float32"
    mix_in_float32: bool = True
    # thread the partial-participation round (DESIGN.md §15) through the
    # trace: the active-set draw + stale-plane selects must add ZERO
    # dot_generals/pallas_calls to the round program (the einsum budgets
    # are shared with the synchronous combos), and the pub-plane carry
    # must not break the chunked/mesh donation contract.
    participation: bool = False
    # thread the Byzantine-fault round (DESIGN.md §16) through the trace:
    # fault injection + the quarantine screen (row norms, EMA carry,
    # probation timers) are folded-PRNG draws and selects — zero extra
    # dot_generals/pallas_calls, no host callbacks inside the scan.
    fault: bool = False
    # robust aggregation rule: "norm_clip" is a coefficient transform in
    # front of the unchanged impl (same budget); "trimmed"/"median" swap
    # the contraction for the sort-network path (mix_eqn_budget knows).
    robust: str = "mean"

    @property
    def name(self) -> str:
        tag = f"{self.mode}/{self.impl}/{self.kind}"
        if self.param_dtype != "float32":
            tag += (f"/{self.param_dtype}-"
                    + ("accum32" if self.mix_in_float32 else "accumlow"))
        if self.participation:
            tag += "/part"
        if self.fault:
            tag += "/fault"
        if self.robust != "mean":
            tag += f"/{self.robust}"
        return tag


def engine_matrix_combos() -> List[Combo]:
    """32 mode × impl × kind cells + 4 low-precision-plane ablations
    + 5 partial-participation cells (every mode on einsum, plus one
    kernel backend) + 8 fault/robust cells (every mode under quarantined
    fault injection, the robust aggregators on their two backends, and
    a fault × trimmed composition)."""
    combos = [Combo(m, i, k) for m in MODES for i in IMPLS for k in KINDS]
    combos += [
        Combo("scanned", impl, "stack", "bfloat16", m32)
        for impl in ("pallas", "edges")
        for m32 in (True, False)
    ]
    combos += [Combo(m, "einsum", "stack", participation=True)
               for m in MODES]
    combos += [Combo("scanned", "pallas", "stack", participation=True)]
    combos += [Combo(m, "einsum", "stack", fault=True) for m in MODES]
    combos += [
        Combo("scanned", "einsum", "stack", robust="trimmed"),
        Combo("scanned", "einsum", "stack", robust="norm_clip"),
        Combo("scanned", "edges", "stack", robust="median"),
        Combo("scanned", "einsum", "stack", fault=True, robust="trimmed"),
    ]
    return combos


@functools.lru_cache(maxsize=None)
def _setting():
    """Shared engine inputs (built once, f32; params cast per combo)."""
    from repro.core.coeffs import program_for, stack_states
    from repro.core.decentralized import stack_params
    from repro.core.strategies import AggregationStrategy
    from repro.core.topology import ring
    from repro.data.distribution import node_datasets
    from repro.data.pipeline import NodeBatcher, make_test_batch
    from repro.data.synthetic import make_dataset
    from repro.models.paper_models import (
        classifier_accuracy,
        classifier_loss,
        ffn_apply,
        ffn_init,
    )

    topo = ring(N_NODES)
    support = topo.adjacency + np.eye(N_NODES)  # neighbours ∪ self
    train = make_dataset("mnist", 320, seed=0)
    test = make_dataset("mnist", 64, seed=9)
    parts = node_datasets(train, N_NODES, ood_node=0, q=0.10, seed=0)
    nb = NodeBatcher(parts, batch_size=BATCH, steps_per_epoch=1, seed=0)
    tb = make_test_batch(test, 16, seed=0)
    ob = make_test_batch(test, 16, seed=1)

    cells = [("unweighted", 0), ("degree", 1)]
    progstates = [
        program_for(topo, AggregationStrategy(k, tau=0.1, seed=s),
                    data_counts=nb.data_counts())
        for k, s in cells]
    program = progstates[0][0]
    states = stack_states([s for _, s in progstates])
    stacks = np.stack([p.materialize(s, ROUNDS) for p, s in progstates])

    bank = {k: v[None] for k, v in nb.sample_bank().items()}
    indices = nb.all_round_indices(ROUNDS)[None]
    data_idx = np.zeros(N_EXP, np.int32)
    params0 = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[stack_params([ffn_init(jax.random.key(s))] * N_NODES)
          for _, s in cells])
    st = lambda t: {k: jnp.stack([jnp.asarray(t[k])] * N_EXP) for k in t}
    return {
        "topo": topo,
        "support": support,
        "loss_fn": classifier_loss(ffn_apply),
        "acc_fn": classifier_accuracy(ffn_apply),
        "params0": params0,
        "program": program,
        "states": states,
        "stacks": stacks,
        "bank": bank,
        "indices": indices,
        "data_idx": data_idx,
        "test_iid": st(tb),
        "test_ood": st(ob),
    }


@functools.lru_cache(maxsize=None)
def _engine(impl: str, mix_in_float32: bool, robust: str = "mean"):
    from repro.core.decentralized import DecentralizedConfig
    from repro.core.sweep import SweepEngine
    from repro.training.optimizer import sgd

    s = _setting()
    cfg = DecentralizedConfig(
        rounds=ROUNDS, local_epochs=1, eval_every=EVAL_EVERY,
        mix_impl=impl, mix_in_float32=mix_in_float32, epoch_shuffle=False,
        robust=robust)
    return SweepEngine(sgd(1e-2), s["loss_fn"], s["acc_fn"], cfg,
                       mix_support=s["support"])


def _traceable(combo: Combo):
    """``(fn, args, jit_kwargs)`` for one combo — the engine's own
    :meth:`SweepEngine.traceable` on the shared setting."""
    from repro.core.coeffs import ProgramCoeffs

    s = _setting()
    engine = _engine(combo.impl, combo.mix_in_float32, combo.robust)
    params0 = jax.tree.map(
        lambda x: x.astype(combo.param_dtype), s["params0"])
    coeffs = (np.asarray(s["stacks"]) if combo.kind == "stack"
              else ProgramCoeffs(s["program"], s["states"]))
    mesh = None
    if combo.mode == "mesh":
        from repro.launch.mesh import make_sweep_mesh

        mesh = make_sweep_mesh()
    part_kwargs = {}
    if combo.participation:
        from repro.core.dynamic import ParticipationSpec

        part_kwargs = dict(
            participation=ParticipationSpec(),
            participation_rates=np.asarray([1.0, 0.5], np.float32))
    if combo.fault:
        from repro.core.dynamic import FaultSpec

        # quarantine=True threads the full self-healing carry (norm EMA,
        # probation timers) through the trace — the HostSync rule proves
        # the screen runs without host callbacks inside the scan
        part_kwargs.update(
            fault=FaultSpec(quarantine=True),
            fault_rates=np.asarray([0.0, 0.3], np.float32))
    return engine.traceable(
        params0, coeffs, s["bank"], s["indices"], s["data_idx"],
        s["test_iid"], s["test_ood"], batch_size=BATCH, mode=combo.mode,
        mesh=mesh, chunk_rounds=CHUNK_ROUNDS,
        donate=combo.mode in ("chunked", "mesh"), **part_kwargs)


# ----------------------------------------------------------------------
# fusion-budget calibration
# ----------------------------------------------------------------------
#: Pinned einsum-mode equation counts per (mode, kind) in the scan-body
#: scope, on the setting above.  Regenerate with
#: ``python -m repro.analysis --recalibrate`` and paste the printed dict
#: here when the engine's round program intentionally changes; any
#: UNintentional drift fails the fusion-budget rule.
EINSUM_BASELINE: Dict[Tuple[str, str], Dict[str, int]] = {
    # Every mode traces the same per-round program (the engine's whole
    # equivalence contract), so the counts agree: 20 = 8 training dots
    # (FFN fwd + bwd, counted once inside the local-step scan) + 6 eval
    # dots (iid + ood forward) + 6 einsum-mix tensordots (one per
    # parameter leaf).
    ("scanned", "stack"): {"pallas_call": 0, "dot_general": 20},
    ("scanned", "program"): {"pallas_call": 0, "dot_general": 20},
    ("chunked", "stack"): {"pallas_call": 0, "dot_general": 20},
    ("chunked", "program"): {"pallas_call": 0, "dot_general": 20},
    ("mesh", "stack"): {"pallas_call": 0, "dot_general": 20},
    ("mesh", "program"): {"pallas_call": 0, "dot_general": 20},
    ("unrolled", "stack"): {"pallas_call": 0, "dot_general": 20},
    ("unrolled", "program"): {"pallas_call": 0, "dot_general": 20},
}


def _n_leaves() -> int:
    return len(jax.tree.leaves(_setting()["params0"]))


def _scope(combo: Combo) -> str:
    """Counting scope per mode: the scanned family's round program is the
    outermost scan's body; the unrolled trace IS one round (its only
    scan is the local-epoch loop *inside* the round, which would exclude
    the mix), so it counts the whole program."""
    return "all" if combo.mode == "unrolled" else "scan_body"


def expected_budget(combo: Combo) -> Dict[str, int]:
    """``baseline − einsum mix budget + combo-impl mix budget`` — the
    model/eval/program equations cancel, leaving the per-impl mixing
    contract from the introspectable kernel metadata."""
    from repro.core.decentralized import mix_impl_budget

    base = EINSUM_BASELINE[(combo.mode, combo.kind)]
    s = _setting()
    ein = mix_impl_budget("einsum", _n_leaves())
    imp = mix_impl_budget(combo.impl, _n_leaves(),
                          mix_support=s["support"], robust=combo.robust)
    return {p: base[p] - ein[p] + imp[p]
            for p in ("pallas_call", "dot_general")}


def rules_for(combo: Combo) -> List[Rule]:
    """The full catalog, parameterized for one combo."""
    from repro.kernels.gossip_mix import mix_accum_upcasts

    donated = combo.mode in ("chunked", "mesh")
    upcasts = mix_accum_upcasts(
        combo.impl, combo.mix_in_float32,
        plane_low_precision=combo.param_dtype != "float32")
    return [
        FusionBudget.of(expected_budget(combo), scope=_scope(combo)),
        ConstantFootprint(max_total_bytes=MAX_TOTAL_CONST_BYTES,
                          max_const_bytes=MAX_CONST_BYTES),
        DtypeFlow(expect_kernel_upcasts=upcasts),
        Donation(expect=donated,
                 min_donated=_n_leaves() if donated else 1),
        HostSync(scope=_scope(combo)),
    ]


# ----------------------------------------------------------------------
# the ``serve`` preset: fleet serving tier trace-time contracts
# ----------------------------------------------------------------------
SERVE_N_NODES = 2   # fleet axis (vmapped over the parameter plane)
SERVE_SLOTS = 2     # decode slots per node
SERVE_CHUNK = 8     # prefill chunk (mixed steps); pure decode uses 1
SERVE_MAX_SEQ = 32


@dataclasses.dataclass(frozen=True)
class ServeCombo:
    """One serving-tier program (DESIGN.md §14).

    The fleet scheduler dispatches exactly two compiled shapes — the
    mixed (n, B, chunk) prefill step and the (n, B, 1) steady-state
    decode step — both through the same self-feeding kernel; the
    single-node program is the per-node-loop baseline's hot path.
    """

    program: str  # "fleet-prefill" | "fleet-decode" | "node-prefill"

    @property
    def name(self) -> str:
        return f"serve/{self.program}"


def serve_combos() -> List["ServeCombo"]:
    return [ServeCombo(p)
            for p in ("fleet-prefill", "fleet-decode", "node-prefill")]


@functools.lru_cache(maxsize=None)
def _serve_setting():
    """Tiny fleet (2 nodes × 2 slots) in the tests' config family."""
    from repro.configs.base import ModelConfig
    from repro.core.plane import PlaneLayout
    from repro.models.transformer import init_params
    from repro.serving.serve_step import make_cache

    cfg = ModelConfig(name="serve-lint", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab_size=64,
                      dtype="float32", param_dtype="float32")
    stacked = jax.vmap(lambda k: init_params(k, cfg))(
        jax.random.split(jax.random.key(0), SERVE_N_NODES))
    layout = PlaneLayout.from_tree(stacked)
    return cfg, stacked, layout, layout.pack(stacked), make_cache(
        cfg, SERVE_N_NODES, SERVE_SLOTS, SERVE_MAX_SEQ)


def _serve_traceable(combo: "ServeCombo"):
    from repro.serving.serve_step import (
        make_fleet_prefill_step,
        make_prefill_step,
    )

    cfg, stacked, layout, plane, cache = _serve_setting()
    n, b = SERVE_N_NODES, SERVE_SLOTS
    chunk = 1 if combo.program == "fleet-decode" else SERVE_CHUNK
    toks = jnp.ones((n, b, chunk), jnp.int32)
    feed = jnp.ones((n, b), jnp.int32)
    lens = jnp.full((n, b), chunk, jnp.int32)
    if combo.program == "node-prefill":
        one = jax.tree.map(lambda x: x[0], stacked)
        one_cache = jax.tree.map(lambda x: x[0], cache)
        return (make_prefill_step(cfg),
                (one, toks[0], feed[0], lens[0], one_cache), None)
    return (make_fleet_prefill_step(cfg, layout),
            (plane, toks, feed, lens, cache), None)


def serve_rules(combo: "ServeCombo") -> List[Rule]:
    """Serving contracts: no host round-trip inside the chunk scan (one
    dispatch must advance every node's slot batch), and the decode path
    is f32-native — no f64 anywhere, no kernel upcasts to declare."""
    return [
        HostSync(scope="scan_body"),
        DtypeFlow(expect_kernel_upcasts=None),
    ]


def run_combo(combo) -> Report:
    if isinstance(combo, ServeCombo):
        fn, args, jit_kwargs = _serve_traceable(combo)
        return analyze(fn, *args, rules=serve_rules(combo),
                       jit_kwargs=jit_kwargs, name=combo.name)
    fn, args, jit_kwargs = _traceable(combo)
    return analyze(fn, *args, rules=rules_for(combo),
                   jit_kwargs=jit_kwargs, name=combo.name)


def run_preset(preset: str = "engine-matrix",
               only: Optional[str] = None) -> List[Report]:
    combos = PRESETS[preset]()
    if only is not None:
        pat = re.compile(only)
        combos = [c for c in combos if pat.search(c.name)]
    return [run_combo(c) for c in combos]


def recalibrate() -> Dict[Tuple[str, str], Dict[str, int]]:
    """Measure the einsum baselines on the current engine — the literal
    to paste into :data:`EINSUM_BASELINE` after an intentional change."""
    from repro.analysis.rules import AnalysisContext

    out: Dict[Tuple[str, str], Dict[str, int]] = {}
    for mode in MODES:
        for kind in KINDS:
            combo = Combo(mode, "einsum", kind)
            fn, args, _ = _traceable(combo)
            ctx = AnalysisContext(jax.make_jaxpr(fn)(*args))
            rule = FusionBudget.of(
                {"pallas_call": 0, "dot_general": 0}, scope=_scope(combo))
            out[(mode, kind)] = rule.measure(ctx)
    return out


PRESETS = {
    "engine-matrix": engine_matrix_combos,
    "serve": serve_combos,
}
