"""Structural jaxpr traversal: equations, sub-jaxprs, and constants.

Everything here operates on the *equation graph* that ``jax.make_jaxpr``
returns — sub-jaxprs are pulled out of equation params (``scan`` /
``while`` / ``cond`` / ``pjit`` / ``custom_jvp_call`` / ``shard_map`` /
``pallas_call`` all stash theirs under different keys), never recovered
from the pretty-printed string.  String matching miscounts as soon as a
primitive name appears in a comment, a sub-jaxpr is printed twice, or
the printer elides a nested call; equation walking cannot.

Types are duck-checked (``eqns``/``invars`` for a raw ``Jaxpr``,
``jaxpr``/``consts`` for a ``ClosedJaxpr``) so the walker keeps working
across jax versions that move the classes between ``jax.core`` and
``jax.extend.core``.
"""
from __future__ import annotations

from collections import Counter
from typing import Any, Iterable, Iterator, Optional, Sequence, Tuple

__all__ = [
    "as_jaxpr",
    "sub_jaxprs",
    "iter_eqns",
    "count_primitives",
    "all_consts",
    "all_avals",
    "outermost_scan_body",
]

#: Path entries are the primitive names of the enclosing equations, e.g.
#: ``("pjit", "scan", "cond")`` for an equation inside an eval branch of
#: the round scan.
Path = Tuple[str, ...]


def _is_closed(obj: Any) -> bool:
    return hasattr(obj, "jaxpr") and hasattr(obj, "consts")


def _is_open(obj: Any) -> bool:
    return hasattr(obj, "eqns") and hasattr(obj, "invars")


def as_jaxpr(obj: Any):
    """The raw ``Jaxpr`` for a ``Jaxpr`` | ``ClosedJaxpr`` | anything with
    a ``.jaxpr`` attribute (e.g. ``jax.make_jaxpr`` output)."""
    if _is_closed(obj):
        return obj.jaxpr
    if _is_open(obj):
        return obj
    raise TypeError(f"not a jaxpr-like object: {type(obj).__name__}")


def sub_jaxprs(eqn) -> Iterator[Tuple[str, Any]]:
    """``(param_key, raw_jaxpr)`` for every sub-jaxpr in an equation's
    params — handles bare jaxprs, closed jaxprs, and tuples/lists of
    either (``cond`` branches)."""
    for key, val in eqn.params.items():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for item in vals:
            if _is_closed(item):
                yield key, item.jaxpr
            elif _is_open(item):
                yield key, item


def iter_eqns(jaxpr, path: Path = ()) -> Iterator[Tuple[Any, Path]]:
    """Pre-order walk over every equation, recursing into sub-jaxprs.

    Yields ``(eqn, path)`` where ``path`` names the enclosing equations'
    primitives — rules use it to scope counts (e.g. "outside pallas
    kernel bodies": ``"pallas_call" not in path``).
    """
    for eqn in as_jaxpr(jaxpr).eqns:
        yield eqn, path
        sub_path = path + (eqn.primitive.name,)
        for _, sub in sub_jaxprs(eqn):
            yield from iter_eqns(sub, sub_path)


def count_primitives(
    jaxpr,
    names: Optional[Sequence[str]] = None,
    exclude_within: Iterable[str] = (),
) -> Counter:
    """Per-primitive equation counts over the whole (recursive) jaxpr.

    ``names`` restricts the tally; ``exclude_within`` skips equations
    whose enclosing path contains any of the given primitives — e.g.
    ``exclude_within=("pallas_call",)`` counts XLA-level ``dot_general``
    GEMMs without the MACs inside Pallas kernel bodies.
    """
    excl = frozenset(exclude_within)
    keep = None if names is None else frozenset(names)
    counts: Counter = Counter()
    for eqn, path in iter_eqns(jaxpr):
        if excl and excl.intersection(path):
            continue
        name = eqn.primitive.name
        if keep is None or name in keep:
            counts[name] += 1
    return counts


def all_consts(closed) -> list:
    """Every constant closed over anywhere in the program — the top-level
    ``ClosedJaxpr.consts`` plus any consts attached to closed sub-jaxprs
    (``pjit`` bodies sometimes keep their own), deduplicated by identity.
    These are the arrays that get baked into the traced program — the
    constant-footprint rule's operand."""
    seen: dict = {}

    def visit_closed(cj) -> None:
        for const in cj.consts:
            seen.setdefault(id(const), const)
        visit_jaxpr(cj.jaxpr)

    def visit_jaxpr(jx) -> None:
        for eqn in jx.eqns:
            for val in eqn.params.values():
                items = val if isinstance(val, (tuple, list)) else (val,)
                for item in items:
                    if _is_closed(item):
                        visit_closed(item)
                    elif _is_open(item):
                        visit_jaxpr(item)

    if _is_closed(closed):
        visit_closed(closed)
    else:
        visit_jaxpr(closed)
    return list(seen.values())


def all_avals(jaxpr) -> Iterator[Tuple[Any, Path]]:
    """``(aval, path)`` for every variable the program touches: top-level
    inputs, every equation's inputs and outputs (literals included) —
    the dtype-flow rule's operand."""
    jx = as_jaxpr(jaxpr)
    for var in jx.invars + jx.constvars:
        yield var.aval, ()
    for eqn, path in iter_eqns(jx):
        for var in tuple(eqn.invars) + tuple(eqn.outvars):
            aval = getattr(var, "aval", None)
            if aval is not None:
                yield aval, path


def outermost_scan_body(jaxpr):
    """The body jaxpr of the first ``scan`` equation reached in pre-order
    that is not inside a Pallas kernel — the engine's scan-over-rounds in
    every scanned-family trace.  ``None`` when the program contains no
    scan (the unrolled mode)."""
    for eqn, path in iter_eqns(jaxpr):
        if eqn.primitive.name == "scan" and "pallas_call" not in path:
            return eqn.params["jaxpr"].jaxpr
    return None
