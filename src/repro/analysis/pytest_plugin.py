"""The ``jaxlint`` pytest fixture — the analyzer as a test utility.

Loaded by the repo-root ``conftest.py`` (``pytest_plugins``); suites use
it instead of string-matching jaxpr pretty-prints::

    def test_fused(jaxlint):
        assert jaxlint.pallas_calls(fn, *args) == 1

    def test_budget(jaxlint):
        rule = jaxlint.FusionBudget.of({"pallas_call": 1}, scope="all")
        jaxlint.check(fn, *args, rules=[rule])
"""
from __future__ import annotations

from typing import Optional, Sequence

import pytest

from repro.analysis.report import Report
from repro.analysis.rules import (
    ConstantFootprint,
    Donation,
    DtypeFlow,
    FusionBudget,
    HostSync,
    analyze,
)
from repro.analysis.walker import count_primitives


class Jaxlint:
    """Thin handle over :mod:`repro.analysis` for test suites."""

    FusionBudget = FusionBudget
    ConstantFootprint = ConstantFootprint
    DtypeFlow = DtypeFlow
    Donation = Donation
    HostSync = HostSync
    analyze = staticmethod(analyze)

    def count(self, fn, *args,
              names: Optional[Sequence[str]] = None,
              exclude_within: Sequence[str] = ("pallas_call",),
              **kwargs):
        """Per-primitive equation counts of ``fn(*args, **kwargs)``'s
        jaxpr (recursing into sub-jaxprs; kernel bodies excluded by
        default) — the eqn-walking replacement for
        ``str(jaxpr).count(...)``."""
        import jax

        if kwargs:
            closed = jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)
        else:
            closed = jax.make_jaxpr(fn)(*args)
        return count_primitives(closed, names=names,
                                exclude_within=exclude_within)

    def pallas_calls(self, fn, *args, **kwargs) -> int:
        """Number of ``pallas_call`` equations (kernel launches) in the
        traced program."""
        counts = self.count(fn, *args, names=("pallas_call",), **kwargs)
        return counts.get("pallas_call", 0)

    def check(self, fn, *args, rules, jit_kwargs=None, name=None,
              **kwargs) -> Report:
        """:func:`repro.analysis.analyze` + raise on any finding."""
        report = analyze(fn, *args, rules=rules, jit_kwargs=jit_kwargs,
                         name=name, **kwargs)
        return report.raise_if_failed()


@pytest.fixture(scope="session")
def jaxlint() -> Jaxlint:
    return Jaxlint()
