"""Findings, per-rule outcomes, and the aggregate analysis report."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

__all__ = ["Finding", "RuleOutcome", "Report", "AnalysisError"]


class AnalysisError(AssertionError):
    """Raised by :meth:`Report.raise_if_failed` — an ``AssertionError``
    subclass so pytest renders the full report on failure."""


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violation of one rule.

    ``rule``: the rule's registered name (e.g. ``"fusion-budget"``);
    ``message``: human-readable description of what was found where;
    ``path``: the jaxpr location (``/``-joined enclosing primitives),
    empty when the finding is program-global.
    """

    rule: str
    message: str
    path: str = ""

    def to_dict(self) -> Dict[str, str]:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        loc = f" [at {self.path}]" if self.path else ""
        return f"{self.rule}: {self.message}{loc}"


@dataclasses.dataclass
class RuleOutcome:
    """One rule's verdict on one program: its findings plus the measured
    quantities the rule based them on (counts, byte totals, donated-buffer
    tallies — whatever the rule reports), so a clean run still documents
    what was checked."""

    rule: str
    findings: List[Finding] = dataclasses.field(default_factory=list)
    measured: Dict[str, object] = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.findings

    def to_dict(self) -> Dict[str, object]:
        return {
            "rule": self.rule,
            "ok": self.ok,
            "findings": [f.to_dict() for f in self.findings],
            "measured": dict(self.measured),
        }


@dataclasses.dataclass
class Report:
    """All rule outcomes for one analyzed program."""

    name: str
    outcomes: List[RuleOutcome] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return all(o.ok for o in self.outcomes)

    @property
    def findings(self) -> List[Finding]:
        return [f for o in self.outcomes for f in o.findings]

    def outcome(self, rule: str) -> Optional[RuleOutcome]:
        for o in self.outcomes:
            if o.rule == rule:
                return o
        return None

    def failed_rules(self) -> List[str]:
        return [o.rule for o in self.outcomes if not o.ok]

    def raise_if_failed(self) -> "Report":
        if not self.ok:
            raise AnalysisError(str(self))
        return self

    def to_dict(self) -> Dict[str, object]:
        return {
            "name": self.name,
            "ok": self.ok,
            "rules": {o.rule: o.to_dict() for o in self.outcomes},
        }

    def __str__(self) -> str:
        lines = [f"jaxlint report for {self.name}: "
                 f"{'OK' if self.ok else 'FAILED'}"]
        for o in self.outcomes:
            status = "ok" if o.ok else f"{len(o.findings)} finding(s)"
            lines.append(f"  {o.rule}: {status}  {o.measured or ''}".rstrip())
            for f in o.findings:
                lines.append(f"    - {f}")
        return "\n".join(lines)
