"""The invariant-rule catalog and the :func:`analyze` entry point.

Each rule is a frozen dataclass (hashable, printable, declarative) with a
registered ``name`` and a ``check(ctx) -> List[Finding]`` method over an
:class:`AnalysisContext` — the traced ``ClosedJaxpr`` plus, for rules
that need it, the jit-lowered StableHLO text.  DESIGN.md §13 catalogs
what each rule guards and which PR introduced the contract.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.report import Finding, Report, RuleOutcome
from repro.analysis.walker import (
    all_avals,
    all_consts,
    count_primitives,
    iter_eqns,
    outermost_scan_body,
)

__all__ = [
    "AnalysisContext",
    "Rule",
    "FusionBudget",
    "ConstantFootprint",
    "DtypeFlow",
    "Donation",
    "HostSync",
    "analyze",
    "HOST_CALLBACK_PRIMS",
]

#: Primitives that synchronize with the host.  Any of these inside the
#: round scan body would serialize the whole R-round schedule on host
#: round-trips — the host-sync contract (PR 1's single-dispatch design).
HOST_CALLBACK_PRIMS: Tuple[str, ...] = (
    "io_callback",
    "debug_callback",
    "pure_callback",
    "outside_call",
)

_ALIASED_ARG_RE = re.compile(r"%arg(\d+)(?:(?!%arg).)*?tf\.aliasing_output",
                             re.DOTALL)
# Multi-device lowerings defer the input→output pairing to sharding
# propagation and mark donated inputs with ``jax.buffer_donor`` instead.
_BUFFER_DONOR_RE = re.compile(r"%arg(\d+)(?:(?!%arg).)*?jax\.buffer_donor",
                              re.DOTALL)


@dataclasses.dataclass
class AnalysisContext:
    """What a traced program exposes to the rules: its closed jaxpr and —
    when some rule declared ``needs_lowering`` — the StableHLO text of
    ``jax.jit(fn, **jit_kwargs).lower(*args)``."""

    closed_jaxpr: object
    lowered_text: Optional[str] = None
    name: str = "<fn>"

    def scoped(self, scope: str):
        """The sub-jaxpr a ``scope`` selects: ``"all"`` → the whole
        program; ``"scan_body"`` → the outermost scan's body (falling
        back to the whole program when no scan exists, so the same rule
        spec serves scanned and unrolled traces)."""
        if scope == "all":
            return self.closed_jaxpr
        if scope == "scan_body":
            body = outermost_scan_body(self.closed_jaxpr)
            return self.closed_jaxpr if body is None else body
        raise ValueError(f"unknown scope {scope!r}; have 'all', 'scan_body'")


@dataclasses.dataclass(frozen=True)
class Rule:
    """Base class: a named, parameterized invariant check."""

    name = "rule"
    needs_lowering = False

    def check(self, ctx: AnalysisContext) -> List[Finding]:
        raise NotImplementedError

    def _finding(self, message: str, path: str = "") -> Finding:
        return Finding(rule=self.name, message=message, path=path)


@dataclasses.dataclass(frozen=True)
class FusionBudget(Rule):
    """Exact trace-time equation counts — THE kernel-fusion contract.

    ``budget`` maps primitive names to the exact number of equations the
    scoped program must contain (e.g. ``{"pallas_call": 1}``: the whole
    mix is ONE fused kernel launch, PR 5/6).  Counts recurse into
    ``scan`` / ``pjit`` / ``cond`` sub-jaxprs but skip Pallas kernel
    bodies (``dot_general`` inside a kernel is the kernel's MAC, not an
    XLA GEMM).  Expected budgets come from introspectable metadata —
    ``repro.core.decentralized.mix_impl_budget`` /
    ``repro.kernels.gossip_mix.mix_eqn_budget`` — not hand-typed counts.
    """

    budget: Tuple[Tuple[str, int], ...] = ()
    scope: str = "scan_body"
    name = "fusion-budget"

    @staticmethod
    def of(budget: Dict[str, int], scope: str = "scan_body") -> "FusionBudget":
        """Build from a plain dict (the dataclass stores a sorted tuple so
        rule instances stay hashable)."""
        return FusionBudget(budget=tuple(sorted(budget.items())), scope=scope)

    def check(self, ctx: AnalysisContext) -> List[Finding]:
        expected = dict(self.budget)
        counts = count_primitives(ctx.scoped(self.scope),
                                  names=tuple(expected),
                                  exclude_within=("pallas_call",))
        findings = []
        for prim, want in sorted(expected.items()):
            got = counts.get(prim, 0)
            if got != want:
                findings.append(self._finding(
                    f"{prim}: expected exactly {want} equation(s) in "
                    f"scope {self.scope!r}, found {got}"))
        return findings

    def measure(self, ctx: AnalysisContext) -> Dict[str, object]:
        counts = count_primitives(ctx.scoped(self.scope),
                                  names=tuple(dict(self.budget)),
                                  exclude_within=("pallas_call",))
        return {p: counts.get(p, 0) for p in dict(self.budget)}


@dataclasses.dataclass(frozen=True)
class ConstantFootprint(Rule):
    """Bound the bytes of constants baked into the traced program.

    The scanned engine's whole design keeps per-round data (coefficient
    slabs, index schedules, banks) as *arguments*; anything large that
    shows up as a closed-over constant — an ``(R, n, n)`` stack captured
    by a closure, an accidentally materialized coefficient program — is
    a regression that silently multiplies compile memory and bakes data
    into the executable (PR 3's contract).  ``max_total_bytes`` caps the
    sum over all constants; ``max_const_bytes`` caps any single one.
    """

    max_total_bytes: int = 1 << 20
    max_const_bytes: Optional[int] = None
    name = "constant-footprint"

    def _const_bytes(self, const) -> int:
        arr = np.asarray(const)
        return int(arr.size) * int(arr.dtype.itemsize)

    def check(self, ctx: AnalysisContext) -> List[Finding]:
        consts = all_consts(ctx.closed_jaxpr)
        total = sum(self._const_bytes(c) for c in consts)
        findings = []
        if self.max_const_bytes is not None:
            for c in consts:
                nbytes = self._const_bytes(c)
                if nbytes > self.max_const_bytes:
                    arr = np.asarray(c)
                    findings.append(self._finding(
                        f"constant {arr.dtype}{list(arr.shape)} is "
                        f"{nbytes} B > per-constant cap "
                        f"{self.max_const_bytes} B — large data must be "
                        f"an argument, not baked into the trace"))
        if total > self.max_total_bytes:
            findings.append(self._finding(
                f"total constant footprint {total} B > cap "
                f"{self.max_total_bytes} B over {len(consts)} constant(s)"))
        return findings

    def measure(self, ctx: AnalysisContext) -> Dict[str, object]:
        consts = all_consts(ctx.closed_jaxpr)
        return {"n_consts": len(consts),
                "total_bytes": sum(self._const_bytes(c) for c in consts)}


@dataclasses.dataclass(frozen=True)
class DtypeFlow(Rule):
    """No forbidden dtypes anywhere; kernel upcasts only where declared.

    ``forbid`` dtypes (default: any f64 — one stray ``np.float64``
    doubles every downstream buffer) may not appear on any input,
    constant, or equation operand/output.  ``expect_kernel_upcasts``
    checks the low-precision-aggregation contract inside Pallas kernel
    bodies: ``True`` requires at least one small-float→f32
    ``convert_element_type`` (the declared f32 accumulation point,
    ``mix_in_float32=True``); ``False`` requires zero (the
    ``mix_in_float32=False`` path must stay low-precision end to end);
    ``None`` skips the check (no kernel / f32-native plane).  Declared
    expectations come from ``repro.kernels.gossip_mix.mix_accum_upcasts``.
    """

    forbid: Tuple[str, ...] = ("float64", "complex128", "int64")
    expect_kernel_upcasts: Optional[bool] = None
    name = "dtype-flow"

    def _forbidden(self, ctx: AnalysisContext) -> List[Finding]:
        findings, seen = [], set()
        for aval, path in all_avals(ctx.closed_jaxpr):
            dtype = getattr(aval, "dtype", None)
            if dtype is None:
                continue
            if str(dtype) in self.forbid:
                key = (str(dtype), path)
                if key not in seen:
                    seen.add(key)
                    shape = tuple(getattr(aval, "shape", ()))
                    findings.append(self._finding(
                        f"forbidden dtype {dtype} (shape {list(shape)}) "
                        f"in traced program", path="/".join(path)))
        for const in all_consts(ctx.closed_jaxpr):
            dtype = np.asarray(const).dtype
            if str(dtype) in self.forbid:
                findings.append(self._finding(
                    f"forbidden dtype {dtype} constant "
                    f"{list(np.asarray(const).shape)}"))
        return findings

    def _kernel_upcasts(self, ctx: AnalysisContext) -> int:
        small = {"bfloat16", "float16", "float8_e4m3fn", "float8_e5m2"}
        n = 0
        for eqn, path in iter_eqns(ctx.closed_jaxpr):
            if "pallas_call" not in path:
                continue
            if eqn.primitive.name != "convert_element_type":
                continue
            src = getattr(eqn.invars[0].aval, "dtype", None)
            dst = eqn.params.get("new_dtype")
            if src is not None and str(src) in small \
                    and str(dst) == "float32":
                n += 1
        return n

    def check(self, ctx: AnalysisContext) -> List[Finding]:
        findings = self._forbidden(ctx)
        if self.expect_kernel_upcasts is not None:
            ups = self._kernel_upcasts(ctx)
            if self.expect_kernel_upcasts and ups == 0:
                findings.append(self._finding(
                    "declared f32 accumulation (mix_in_float32=True) but "
                    "no small-float→f32 upcast found in any Pallas kernel "
                    "body — accumulation silently runs in low precision"))
            if not self.expect_kernel_upcasts and ups > 0:
                findings.append(self._finding(
                    f"low-precision path (mix_in_float32=False) upcasts "
                    f"to f32 at {ups} site(s) inside Pallas kernel bodies "
                    f"— must stay in the plane dtype"))
        return findings

    def measure(self, ctx: AnalysisContext) -> Dict[str, object]:
        return {"kernel_upcasts": self._kernel_upcasts(ctx)}


@dataclasses.dataclass(frozen=True)
class Donation(Rule):
    """Carry donation actually reaches the lowered program.

    The chunked and sharded engine modes (DESIGN.md §8) donate the
    ``(params, opt)`` carry so long schedules never double-allocate the
    model state — but ``donate_argnums`` silently vanishes if a wrapper
    re-jits without it.  This rule inspects the StableHLO lowering for
    donated-input attributes — ``tf.aliasing_output`` (single-device:
    the input→output pairing already resolved) or ``jax.buffer_donor``
    (multi-device: pairing deferred to sharding propagation):
    ``expect=True`` requires at least ``min_donated`` donated buffers;
    ``expect=False`` requires none (the one-shot scanned program takes
    no donation).  Lowering
    records donation intent on every backend, so the check runs on CPU
    CI too.
    """

    expect: bool = True
    min_donated: int = 1
    name = "donation"
    needs_lowering = True

    def _donated(self, ctx: AnalysisContext) -> List[int]:
        if ctx.lowered_text is None:
            raise ValueError("Donation rule needs the lowered program; "
                             "analyze() provides it when this rule is on")
        return sorted(
            {int(m.group(1))
             for m in _ALIASED_ARG_RE.finditer(ctx.lowered_text)}
            | {int(m.group(1))
               for m in _BUFFER_DONOR_RE.finditer(ctx.lowered_text)})

    def check(self, ctx: AnalysisContext) -> List[Finding]:
        donated = self._donated(ctx)
        if self.expect and len(donated) < self.min_donated:
            return [self._finding(
                f"expected ≥ {self.min_donated} donated input buffer(s) "
                f"(tf.aliasing_output in the lowering), found "
                f"{len(donated)} — the carry is not donated")]
        if not self.expect and donated:
            return [self._finding(
                f"expected no donated inputs, but {len(donated)} "
                f"buffer(s) carry tf.aliasing_output")]
        return []

    def measure(self, ctx: AnalysisContext) -> Dict[str, object]:
        return {"donated_buffers": len(self._donated(ctx))}


@dataclasses.dataclass(frozen=True)
class HostSync(Rule):
    """No host callbacks inside the scan body.

    ``io_callback`` / ``debug_callback`` / ``pure_callback`` equations
    inside the round scan would stall every round on a host round-trip,
    silently destroying the one-dispatch-per-run design (PR 1).  Scope
    ``"scan_body"`` checks the outermost scan (the whole program when no
    scan exists, so unrolled traces use the same spec).
    """

    forbid: Tuple[str, ...] = HOST_CALLBACK_PRIMS
    scope: str = "scan_body"
    name = "host-sync"

    def check(self, ctx: AnalysisContext) -> List[Finding]:
        findings = []
        for eqn, path in iter_eqns(ctx.scoped(self.scope)):
            if eqn.primitive.name in self.forbid:
                findings.append(self._finding(
                    f"host callback {eqn.primitive.name!r} inside scope "
                    f"{self.scope!r}", path="/".join(path)))
        return findings


def analyze(
    fn: Callable,
    *args,
    rules: Sequence[Rule],
    jit_kwargs: Optional[dict] = None,
    name: Optional[str] = None,
    **kwargs,
) -> Report:
    """Trace ``fn(*args, **kwargs)`` and run every rule against the jaxpr.

    ``jit_kwargs`` (e.g. ``{"donate_argnums": (0, 1)}``,
    ``{"static_argnames": (...)}``) are applied both to the
    ``jax.make_jaxpr`` trace and to the ``jax.jit(...).lower`` pass that
    runs when any rule ``needs_lowering`` — so the analyzed program is
    the one the engine would actually execute.  Returns a
    :class:`Report`; callers gate with ``report.raise_if_failed()`` or
    inspect per-rule ``outcomes``.
    """
    import jax

    jit_kwargs = dict(jit_kwargs or {})
    if kwargs:
        closed = jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)
    else:
        closed = jax.make_jaxpr(fn)(*args)
    lowered_text = None
    if any(r.needs_lowering for r in rules):
        lowered = jax.jit(fn, **jit_kwargs).lower(*args, **kwargs)
        lowered_text = lowered.as_text()
    fn_name = name or getattr(fn, "__name__", "<fn>")
    ctx = AnalysisContext(closed_jaxpr=closed, lowered_text=lowered_text,
                          name=fn_name)
    outcomes = []
    for rule in rules:
        findings = rule.check(ctx)
        measured = (rule.measure(ctx)
                    if hasattr(rule, "measure") else {})
        outcomes.append(RuleOutcome(rule=rule.name, findings=findings,
                                    measured=measured))
    return Report(name=fn_name, outcomes=outcomes)
