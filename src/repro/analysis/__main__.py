"""CLI: ``python -m repro.analysis --preset engine-matrix``.

Traces every combo in the preset, prints a one-line verdict per combo
(findings in full for failures), writes the machine-readable report to
``benchmarks/artifacts/ANALYSIS.json`` (``--out`` overrides), and exits
nonzero if any rule failed — CI gates on the exit code and uploads the
JSON artifact.

``--only REGEX`` restricts to matching combo names (e.g.
``--only 'mesh/.*program'``); ``--list`` prints combo names without
tracing; ``--recalibrate`` re-measures the pinned einsum baselines
(paste the printed dict into ``presets.EINSUM_BASELINE`` after an
*intentional* round-program change).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from repro.analysis.presets import PRESETS, recalibrate, run_preset


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jaxpr-level invariant checks (jaxlint) over the "
                    "sweep engine's program matrix")
    parser.add_argument("--preset", default="engine-matrix",
                        choices=sorted(PRESETS))
    parser.add_argument("--only", default=None, metavar="REGEX",
                        help="restrict to combos whose name matches")
    parser.add_argument("--list", action="store_true",
                        help="print combo names and exit")
    parser.add_argument("--out",
                        default="benchmarks/artifacts/ANALYSIS.json",
                        help="report path (default: %(default)s)")
    parser.add_argument("--recalibrate", action="store_true",
                        help="measure einsum baselines and print the "
                             "EINSUM_BASELINE literal")
    args = parser.parse_args(argv)

    if args.list:
        for combo in PRESETS[args.preset]():
            print(combo.name)
        return 0

    if args.recalibrate:
        print("EINSUM_BASELINE = {")
        for key, counts in sorted(recalibrate().items()):
            print(f"    {key!r}: {counts!r},")
        print("}")
        return 0

    reports = run_preset(args.preset, only=args.only)
    if not reports:
        print(f"no combos match --only {args.only!r}", file=sys.stderr)
        return 2
    for report in reports:
        if report.ok:
            print(f"ok    {report.name}")
        else:
            print(f"FAIL  {report.name}")
            for finding in report.findings:
                print(f"      - {finding}")

    ok = all(r.ok for r in reports)
    payload = {
        "preset": args.preset,
        "only": args.only,
        "n_combos": len(reports),
        "ok": ok,
        "combos": {r.name: r.to_dict() for r in reports},
    }
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    n_bad = sum(not r.ok for r in reports)
    print(f"{len(reports)} combo(s) analyzed, {n_bad} failing -> {out}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
