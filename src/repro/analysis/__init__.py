"""jaxpr-level static analysis ("jaxlint") — machine-checkable engine contracts.

Six PRs of engine work left a pile of *implicit* trace-time contracts:
one fused ``pallas_call`` per mix (DESIGN.md §11), O(n·dmax) — not O(n²)
— coefficient traffic on the edge-list path (§12), no ``(R, n, n)`` slab
constant-folded into the round scan (§7/§9's whole point), donated
carries in chunked/sharded modes (§8), and no host callbacks inside the
scan body.  This package makes them explicit: it walks
``jax.make_jaxpr`` output (recursing into ``scan`` / ``pjit`` /
``cond`` / ``pallas_call`` sub-jaxprs properly — no ``str()`` matching)
and checks a catalog of named rules (DESIGN.md §13).

Three entry points:

* **library** — :func:`analyze(fn, *args, rules=...) <analyze>` returns a
  :class:`Report` with per-rule findings;
* **pytest** — the ``jaxlint`` fixture (``repro.analysis.pytest_plugin``,
  loaded by the repo conftest) exposes the same API to test suites;
* **CLI** — ``python -m repro.analysis --preset engine-matrix`` traces the
  round/scan body of every (execution mode × mix_impl × coeff kind)
  combination, writes ``benchmarks/artifacts/ANALYSIS.json``, and exits
  nonzero on any violation.
"""
from repro.analysis.report import AnalysisError, Finding, Report, RuleOutcome
from repro.analysis.rules import (
    ConstantFootprint,
    Donation,
    DtypeFlow,
    FusionBudget,
    HostSync,
    Rule,
    analyze,
)
from repro.analysis.walker import (
    all_consts,
    count_primitives,
    iter_eqns,
    outermost_scan_body,
)

__all__ = [
    "AnalysisError",
    "Finding",
    "Report",
    "RuleOutcome",
    "Rule",
    "FusionBudget",
    "ConstantFootprint",
    "DtypeFlow",
    "Donation",
    "HostSync",
    "analyze",
    "iter_eqns",
    "count_primitives",
    "all_consts",
    "outermost_scan_body",
]
