"""Sharding rules: param/batch/cache pytrees → PartitionSpecs.

Mesh axes (DESIGN.md §5):
  * ``pod``   — multi-pod tier (hierarchical gossip);
  * ``node``  — gossip-topology nodes inside a pod (the paper's devices);
  * ``fsdp``  — FSDP shards within one node's model copy;
  * ``model`` — tensor parallel.

Every stacked-model leaf has layout ``(N_global_nodes, [L,] ...)`` — the
node axis shards over ``('pod', 'node')`` jointly, then per-tensor rules
place ``fsdp``/``model`` on the weight dims:

  attention heads / MoE experts / MLP hidden → ``model``
  d_model (largest remaining dim)            → ``fsdp``
  norms / small vectors                      → replicated

Rules are matched on the flattened path name (innermost dict keys), so
they apply uniformly to params AND to optimizer-moment trees that mirror
them.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


__all__ = [
    "param_specs",
    "batch_specs",
    "cache_specs",
    "opt_specs_like",
    "named_shardings",
    "NODE_AXES",
]

NODE_AXES = ("pod", "node")   # the stacked node axis shards over both tiers

# (regex over dotted path, spec for the *weight* dims after [node, L]).
# First match wins.  `None` entries mean "replicated on that dim".
_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    # --- embeddings / head -------------------------------------------------
    (r"\bembed$", ("model", "fsdp")),
    (r"\bhead$", ("fsdp", "model")),
    (r"\bfrontend_proj$", (None, "fsdp")),
    # --- attention ---------------------------------------------------------
    (r"attn\.wq$", ("fsdp", "model", None)),
    (r"attn\.wk$", ("fsdp", "model", None)),
    (r"attn\.wv$", ("fsdp", "model", None)),
    (r"attn\.wo$", ("model", None, "fsdp")),
    # --- MLA ----------------------------------------------------------------
    (r"attn\.w_dkv$", ("fsdp", None)),
    (r"attn\.w_kr$", ("fsdp", None)),
    (r"attn\.w_uk$", (None, "model", None)),
    (r"attn\.w_uv$", (None, "model", None)),
    (r"attn\.w_dq$", ("fsdp", None)),
    (r"attn\.w_uq$", (None, "model", None)),
    (r"attn\.w_o$", ("model", None, "fsdp")),
    # --- MoE ----------------------------------------------------------------
    (r"moe\.router$", ("fsdp", None)),
    (r"moe\.experts\.wg$", ("model", "fsdp", None)),
    (r"moe\.experts\.wi$", ("model", "fsdp", None)),
    (r"moe\.experts\.wo$", ("model", None, "fsdp")),
    (r"moe\.shared\.wg$", ("fsdp", "model")),
    (r"moe\.shared\.wi$", ("fsdp", "model")),
    (r"moe\.shared\.wo$", ("model", "fsdp")),
    # --- dense MLP ----------------------------------------------------------
    (r"mlp\.wg$", ("fsdp", "model")),
    (r"mlp\.wi$", ("fsdp", "model")),
    (r"mlp\.wo$", ("model", "fsdp")),
    # --- RWKV time/channel mix ----------------------------------------------
    (r"time_mix\.w[rkvg]$", ("fsdp", "model", None)),
    (r"time_mix\.wo$", ("model", None, "fsdp")),
    (r"time_mix\.lora_[ab]$", (None, None, None)),
    (r"time_mix\.decay_[ab]$", (None, None)),
    (r"channel_mix\.wk$", ("fsdp", "model")),
    (r"channel_mix\.wv$", ("model", "fsdp")),
    (r"channel_mix\.wr$", ("fsdp", "model")),
    # --- Mamba ----------------------------------------------------------------
    (r"mamba\.w_in$", ("fsdp", "model")),
    (r"mamba\.conv_w$", (None, "model")),
    (r"mamba\.w_bcdt$", ("model", None)),
    (r"mamba\.log_a$", ("model", None)),
    (r"mamba\.d_skip$", ("model",)),
    (r"mamba\.dt_bias$", ("model",)),
    (r"mamba\.w_out$", ("model", "fsdp")),
)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return ".".join(parts)


def _spec_for(path_s: str, leaf_shape, n_prefix_dims: int,
              node_axes, use_fsdp: bool, use_model: bool,
              axis_sizes=None) -> P:
    """Build a PartitionSpec: prefix dims (node axis, layer-stack axis) then
    the matched weight rule (truncated/padded to the leaf's actual rank).
    Axes whose mesh size does not divide the tensor dim are dropped
    (replicated) — e.g. kv_heads=2 cannot shard over model=16."""
    leaf_ndim = len(leaf_shape)
    axis_sizes = axis_sizes or {}

    def ok(axis, dim_idx):
        size = axis_sizes.get(axis)
        return size is None or leaf_shape[dim_idx] % size == 0

    for pattern, dims in _RULES:
        if re.search(pattern, path_s):
            weight_dims = leaf_ndim - n_prefix_dims
            rule = list(dims[:weight_dims])
            rule += [None] * (weight_dims - len(rule))
            rule = [
                d if d is not None
                and ((d == "model" and use_model) or (d == "fsdp" and use_fsdp))
                and ok(d, n_prefix_dims + i)
                else None
                for i, d in enumerate(rule)
            ]
            node_entry = _node_entry(node_axes)
            prefix = [node_entry] + [None] * (n_prefix_dims - 1)
            return P(*prefix, *rule)
    # default: replicate weight dims, shard the node axis
    return P(*([_node_entry(node_axes)] + [None] * (leaf_ndim - 1)))


def _node_entry(node_axes):
    """The stacked node dim shards over all node mesh axes jointly."""
    axes = tuple(a for a in node_axes if a is not None)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def param_specs(params: Any, node_axes=NODE_AXES, use_fsdp: bool = True,
                use_model: bool = True, axis_sizes: Optional[dict] = None) -> Any:
    """Spec tree for stacked params: leaves (N, [L,] weight dims...).

    Layer-stacked leaves (inside ``dense_layers``/``moe_layers``) have an
    extra L dim after the node axis — detected from the path.
    ``axis_sizes`` (mesh axis → size) enables divisibility checks.
    """
    node_axes = (node_axes,) if isinstance(node_axes, str) else tuple(node_axes)

    def fn(path, leaf):
        path_s = _path_str(path)
        stacked = "dense_layers" in path_s or "moe_layers" in path_s
        n_prefix = 2 if stacked else 1   # [node, L] vs [node]
        if leaf.ndim < n_prefix:
            return P()
        return _spec_for(path_s, leaf.shape, n_prefix, node_axes,
                         use_fsdp, use_model, axis_sizes)

    return jax.tree_util.tree_map_with_path(fn, params)


def opt_specs_like(opt_state: Any, p_specs: Any,
                   node_axes=NODE_AXES) -> Any:
    """Specs for a *stacked* optimizer state (vmapped over nodes):
    moment trees mirror params → reuse param specs; the per-node step
    vector shards over the node axis."""
    from repro.training.optimizer import AdamState, SGDState

    node_axes = (node_axes,) if isinstance(node_axes, str) else tuple(node_axes)
    step_spec = P(node_axes)
    if isinstance(opt_state, AdamState):
        return AdamState(step_spec, p_specs, p_specs)
    if isinstance(opt_state, SGDState):
        mom = p_specs if opt_state.momentum is not None else None
        return SGDState(step_spec, mom)
    raise TypeError(f"unknown optimizer state {type(opt_state)}")


def batch_specs(batch: Any, node_axes=NODE_AXES, data_axis: str = "fsdp") -> Any:
    """Batches: leaves (N_nodes, [micro,] local_batch, seq, ...) — node axis
    over (pod,node), per-node batch over fsdp."""
    node_axes = (node_axes,) if isinstance(node_axes, str) else tuple(node_axes)

    def fn(leaf):
        ndim = np.ndim(leaf)
        if ndim == 0:
            return P()
        rest = [None] * (ndim - 1)
        if ndim >= 2:
            rest[-2 if ndim >= 3 else 0] = None
        # batch dim right after node (and optional microbatch) dims:
        # (N, B, S...) → batch at index 1; (N, M, B, S...) → index 2.
        batch_idx = 1 if ndim <= 3 else 2
        spec = [None] * ndim
        spec[0] = node_axes
        if batch_idx < ndim:
            spec[batch_idx] = data_axis
        return P(*spec)

    return jax.tree.map(fn, batch)


def cache_specs(cache: Any, node_axes=NODE_AXES) -> Any:
    """Decode caches: leaves (N, L, B, T, heads/latent...) — node over
    (pod,node), decode batch over fsdp, head-like dim over model."""
    node_axes = (node_axes,) if isinstance(node_axes, str) else tuple(node_axes)

    def fn(path, leaf):
        path_s = _path_str(path)
        if "position" in path_s:
            return P(node_axes, "fsdp")
        ndim = leaf.ndim
        spec = [None] * ndim
        spec[0] = node_axes
        if ndim >= 3:
            spec[2] = "fsdp"          # (N, L, B, ...)
        if "k" == path_s.split(".")[-1] or path_s.endswith(".v") \
           or path_s.endswith("rwkv_state") or path_s.endswith("ssm_state") \
           or path_s.endswith("conv_state"):
            # heads / d_inner dim over model
            head_dim_idx = {"k": 4, "v": 4, "rwkv_state": 3,
                            "ssm_state": 3, "conv_state": 4}.get(
                                path_s.split(".")[-1], None)
            if head_dim_idx is not None and head_dim_idx < ndim:
                spec[head_dim_idx] = "model"
        return P(*spec)

    return jax.tree_util.tree_map_with_path(fn, cache)


def named_shardings(mesh: Mesh, specs: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )
