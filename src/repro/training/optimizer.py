"""Pure-JAX optimizers (no optax in this environment).

Minimal optax-style API: an :class:`Optimizer` bundles ``init(params)`` and
``update(grads, state, params)``; states are pytrees so they stack/shard
along the node axis exactly like params (the decentralized trainer vmaps
these across topology nodes).

Provided: SGD (+momentum), Adam, AdamW — the paper uses SGD(1e-2) for
MNIST/FMNIST and Adam(1e-3 / 1e-4) for TinyMem/CIFAR (Table 1).
Also: global-norm clipping and LR schedules (constant, cosine, warmup).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "Optimizer",
    "sgd",
    "adam",
    "adamw",
    "clip_by_global_norm",
    "constant_schedule",
    "cosine_schedule",
    "warmup_cosine_schedule",
    "apply_updates",
    "global_norm",
    "make_optimizer",
    "skip_nonfinite_updates",
    "NonfiniteGuardState",
]

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda x: x * scale, tree), norm


# ----------------------------------------------------------------------
# schedules
# ----------------------------------------------------------------------
def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, total_steps: int, final_frac: float = 0.1) -> Schedule:
    def fn(step):
        t = jnp.minimum(step, total_steps) / max(total_steps, 1)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1.0 - final_frac) * cos)

    return fn


def warmup_cosine_schedule(lr: float, warmup: int, total_steps: int,
                           final_frac: float = 0.1) -> Schedule:
    cos = cosine_schedule(lr, max(total_steps - warmup, 1), final_frac)

    def fn(step):
        warm = lr * (step + 1) / max(warmup, 1)
        return jnp.where(step < warmup, warm, cos(step - warmup))

    return fn


def _as_schedule(lr) -> Schedule:
    return lr if callable(lr) else constant_schedule(float(lr))


# ----------------------------------------------------------------------
# SGD
# ----------------------------------------------------------------------
class SGDState(NamedTuple):
    step: jnp.ndarray
    momentum: Optional[object]  # pytree like params, or None


def sgd(lr, momentum: float = 0.0, clip_norm: Optional[float] = None) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        mom = (
            jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
            if momentum > 0.0
            else None
        )
        return SGDState(jnp.zeros((), jnp.int32), mom)

    def update(grads, state: SGDState, params=None):
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        eta = sched(state.step)
        if momentum > 0.0:
            new_m = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32), state.momentum, grads
            )
            updates = jax.tree.map(lambda m: -eta * m, new_m)
            return updates, SGDState(state.step + 1, new_m)
        updates = jax.tree.map(lambda g: -eta * g.astype(jnp.float32), grads)
        return updates, SGDState(state.step + 1, None)

    return Optimizer(init, update)


# ----------------------------------------------------------------------
# Adam / AdamW
# ----------------------------------------------------------------------
class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: object
    nu: object


def _adam_core(lr, b1, b2, eps, weight_decay, clip_norm) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return AdamState(
            jnp.zeros((), jnp.int32),
            jax.tree.map(zeros, params),
            jax.tree.map(zeros, params),
        )

    def update(grads, state: AdamState, params=None):
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        eta = sched(state.step)
        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads
        )
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu,
            grads,
        )
        mu_hat_scale = 1.0 / (1.0 - b1 ** step.astype(jnp.float32))
        nu_hat_scale = 1.0 / (1.0 - b2 ** step.astype(jnp.float32))

        def upd(m, v, p):
            u = -eta * (m * mu_hat_scale) / (jnp.sqrt(v * nu_hat_scale) + eps)
            if weight_decay > 0.0 and p is not None:
                u = u - eta * weight_decay * p.astype(jnp.float32)
            return u

        if weight_decay > 0.0:
            if params is None:
                raise ValueError("adamw.update requires params for weight decay")
            updates = jax.tree.map(upd, mu, nu, params)
        else:
            updates = jax.tree.map(lambda m, v: upd(m, v, None), mu, nu)
        return updates, AdamState(step, mu, nu)

    return Optimizer(init, update)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         clip_norm: Optional[float] = None) -> Optimizer:
    return _adam_core(lr, b1, b2, eps, 0.0, clip_norm)


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, clip_norm: Optional[float] = None) -> Optimizer:
    return _adam_core(lr, b1, b2, eps, weight_decay, clip_norm)


# ----------------------------------------------------------------------
# nonfinite guard (DESIGN.md §16 — the local half of fault tolerance)
# ----------------------------------------------------------------------
class NonfiniteGuardState(NamedTuple):
    inner: object            # wrapped optimizer's state pytree
    skipped: jnp.ndarray     # () int32 — steps dropped for NaN/Inf grads


def skip_nonfinite_updates(opt: Optimizer) -> Optimizer:
    """Wrap ``opt`` so steps with any NaN/Inf gradient become identity.

    A single poisoned batch (label corruption, fp16 overflow, a Byzantine
    neighbor's garbage leaking into the loss) otherwise destroys the whole
    node: one NaN gradient NaNs the momentum/Adam moments and every later
    step.  The guard checks all gradient leaves for finiteness BEFORE the
    inner update; on a bad step the update is all-zeros and the inner state
    is carried through unchanged (step counter included, so LR schedules do
    not advance on skipped steps), while a carried ``skipped`` counter
    records the drop.  Grads are zero-substituted before the inner update
    runs so no transient NaN arithmetic can leak through the select.

    The wrapped optimizer is a drop-in :class:`Optimizer` — its state nests
    the inner state, so it vmaps/stacks/checkpoints along the node axis
    exactly like the unwrapped one.  Compose at construction time::

        engine = SweepEngine(skip_nonfinite_updates(sgd(1e-2)), ...)
    """

    def init(params):
        return NonfiniteGuardState(opt.init(params), jnp.zeros((), jnp.int32))

    def update(grads, state: NonfiniteGuardState, params=None):
        finite = jnp.all(jnp.stack(
            [jnp.all(jnp.isfinite(g)) for g in jax.tree.leaves(grads)]))
        safe = jax.tree.map(
            lambda g: jnp.where(finite, g, jnp.zeros_like(g)), grads)
        upd, new_inner = opt.update(safe, state.inner, params)
        sel = lambda n, o: jnp.where(finite, n, o)
        updates = jax.tree.map(lambda u: sel(u, jnp.zeros_like(u)), upd)
        inner = jax.tree.map(sel, new_inner, state.inner)
        skipped = jnp.where(finite, state.skipped, state.skipped + 1)
        return updates, NonfiniteGuardState(inner, skipped.astype(jnp.int32))

    return Optimizer(init, update)


def make_optimizer(name: str, lr, skip_nonfinite: bool = False,
                   **kwargs) -> Optimizer:
    """Config-system entry point."""
    table = {"sgd": sgd, "adam": adam, "adamw": adamw}
    if name not in table:
        raise KeyError(f"unknown optimizer {name!r}; have {sorted(table)}")
    opt = table[name](lr, **kwargs)
    return skip_nonfinite_updates(opt) if skip_nonfinite else opt
