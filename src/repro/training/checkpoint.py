"""Checkpointing: pytree ⇄ .npz with path-keyed flat entries.

Self-contained (no orbax in this environment): leaves are flattened with
their dotted tree paths as archive keys; restore rebuilds into a provided
pytree skeleton so dtypes/structure are validated on load.  Includes
step/metadata sidecar and atomic write (tmp + rename) — the behaviours a
production trainer actually relies on.
"""
from __future__ import annotations

import json
import os
import tempfile
import zipfile
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint", "latest_checkpoint"]


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(directory: str, step: int, params: Any,
                    opt_state: Any = None,
                    metadata: Optional[Dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    payload = {f"params/{k}": v for k, v in _flatten(params).items()}
    if opt_state is not None:
        payload.update({f"opt/{k}": v for k, v in _flatten(opt_state).items()})
    meta = dict(metadata or {}, step=step)
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, __meta__=json.dumps(meta), **payload)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


def _unflatten_into(skeleton: Any, flat: Dict[str, np.ndarray], prefix: str) -> Any:
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(skeleton)
    new_leaves = []
    for path, leaf in paths_leaves:
        key = prefix + "/" + "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != skeleton {np.shape(leaf)}"
            )
        want = getattr(leaf, "dtype", None)
        if want is not None and np.dtype(arr.dtype) != np.dtype(want):
            raise ValueError(
                f"{key}: checkpoint dtype {arr.dtype} != skeleton {np.dtype(want)}"
            )
        new_leaves.append(jnp.asarray(arr, dtype=want))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def _read_npz(path: str) -> Tuple[Dict[str, np.ndarray], Dict]:
    """Read + validate a checkpoint archive.

    A crash mid-write never leaves a bad file at the checkpoint path (the
    atomic tmp+rename in :func:`save_checkpoint` guarantees that), but disk
    corruption, partial copies, or a stray non-checkpoint ``.npz`` can.
    Both surface as ``ValueError`` naming the file, so resume logic can
    distinguish "bad checkpoint" from genuine tree-mismatch bugs.
    """
    try:
        with np.load(path, allow_pickle=False) as z:
            flat = {k: z[k] for k in z.files if k != "__meta__"}
            if "__meta__" not in z.files:
                raise ValueError(
                    f"{path}: no __meta__ entry — not a checkpoint archive")
            meta = json.loads(str(z["__meta__"]))
    except (zipfile.BadZipFile, zlib.error, EOFError) as e:
        raise ValueError(f"{path}: truncated or corrupt checkpoint ({e})")
    return flat, meta


def load_checkpoint(path: str, params_like: Any,
                    opt_like: Any = None) -> Tuple[Any, Any, Dict]:
    flat, meta = _read_npz(path)
    params = _unflatten_into(params_like, flat, "params")
    opt = _unflatten_into(opt_like, flat, "opt") if opt_like is not None else None
    return params, opt, meta


def latest_checkpoint(directory: str) -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    files = sorted(
        f for f in os.listdir(directory)
        if f.startswith("ckpt_") and f.endswith(".npz")
    )
    return os.path.join(directory, files[-1]) if files else None
