"""LM losses: standard and sequence-chunked cross-entropy.

The chunked variant never materializes the full (B, S, V) logits — it scans
over sequence chunks, projecting hidden→vocab and reducing the NLL chunk by
chunk.  For vocab=202k at train_4k this cuts peak logits memory by S/chunk
(the §Perf "fused unembed+CE" lever; cf. Liger/fused-CE kernels on GPU —
here expressed as an XLA-level scan, the TPU-idiomatic equivalent).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import softcap
from repro.models.transformer import ForwardOptions, forward

__all__ = ["lm_loss_fn", "softmax_xent"]


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 z_loss: float = 0.0) -> jnp.ndarray:
    """Mean next-token NLL; logits (B, S, V) f32, labels (B, S)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - picked
    if z_loss > 0.0:
        nll = nll + z_loss * jnp.square(logz)
    return jnp.mean(nll)


def _chunked_xent(params, cfg: ModelConfig, hidden: jnp.ndarray,
                  labels: jnp.ndarray, chunk: int) -> jnp.ndarray:
    """hidden (B, S, D) → mean NLL without full logits."""
    from repro.models.layers import norm_apply

    hidden = norm_apply(cfg.norm_kind, params["final_norm"], hidden, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    b, s, d = hidden.shape
    n_chunks = s // chunk
    h = hidden[:, : n_chunks * chunk].reshape(b, n_chunks, chunk, d)
    y = labels[:, : n_chunks * chunk].reshape(b, n_chunks, chunk)

    def body(acc, xs):
        hc, yc = xs                       # (B, chunk, D), (B, chunk)
        logits = (hc @ head).astype(jnp.float32)
        logits = softcap(logits, cfg.final_logit_softcap)
        logz = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(logz - picked), None

    total, _ = jax.lax.scan(
        body, jnp.zeros((), jnp.float32),
        (h.swapaxes(0, 1), y.swapaxes(0, 1)),
    )
    return total / (b * n_chunks * chunk)


def lm_loss_fn(cfg: ModelConfig, opts: Optional[ForwardOptions] = None,
               chunked_ce: int = 0):
    """→ ``loss(params, batch)``; batch: {tokens|embeddings, labels}."""
    opts = opts or ForwardOptions()

    def loss(params, batch) -> jnp.ndarray:
        inputs = {k: v for k, v in batch.items() if k in ("tokens", "embeddings")}
        labels = batch["labels"]
        if chunked_ce > 0:
            hidden, aux = forward(params, cfg, inputs, opts, return_hidden=True)
            return _chunked_xent(params, cfg, hidden, labels, chunked_ce) + aux
        logits, aux = forward(params, cfg, inputs, opts)
        return softmax_xent(logits, labels) + aux

    return loss
