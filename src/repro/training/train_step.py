"""Production train step: microbatched grad accumulation × per-node vmap ×
gossip aggregation — the single SPMD program that the dry-run lowers.

Layout of one step (per DESIGN.md §3/§5):

  batch  (N_nodes, micro, local_b, S)          # node → (pod,node), b → fsdp
  params (N_nodes, [L,] ...)                    # node → (pod,node), w → fsdp/model
    1. per node: scan microbatches, accumulate f32 grads   (LocalTrain inner)
    2. per node: optimizer update                           (Eq. 1)
    3. gossip: stacked params × mixing matrix               (Eq. 2)

The gossip contraction runs every ``gossip_every`` steps (the paper
aggregates once per round = once per E local epochs; in the production
trainer a "round" is a configurable number of optimizer steps).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core.mixing import mix_dense
from repro.models.transformer import ForwardOptions
from repro.training.losses import lm_loss_fn
from repro.training.optimizer import (Optimizer, apply_updates,
                                      skip_nonfinite_updates)

__all__ = ["make_train_step", "make_loss"]


def make_loss(cfg: ModelConfig, pcfg: ParallelConfig,
              opts: Optional[ForwardOptions] = None):
    opts = opts or ForwardOptions(remat=pcfg.remat)
    return lm_loss_fn(cfg, opts, chunked_ce=pcfg.chunked_ce)


def _cast_grads(grads, dtype):
    return jax.tree.map(lambda g: g.astype(dtype), grads)


def make_train_step(
    cfg: ModelConfig,
    pcfg: ParallelConfig,
    optimizer: Optimizer,
    opts: Optional[ForwardOptions] = None,
    gossip: bool = True,
    skip_nonfinite: bool = False,
) -> Callable:
    """Build ``train_step(params, opt_state, batch, coeffs) →
    (params, opt_state, loss)`` with stacked node axes everywhere.

    batch leaves: (N, micro, local_b, S[, ...]).
    coeffs: (N, N) row-stochastic global mixing matrix (hierarchical:
    block-diagonal intra-pod + inter-pod bridge entries).

    ``skip_nonfinite=True`` wraps the optimizer with
    :func:`repro.training.optimizer.skip_nonfinite_updates`, turning any
    step whose gradients contain NaN/Inf into an identity update with a
    carried per-node skip counter (DESIGN.md §16).  The opt state must
    then be created with the WRAPPED optimizer's ``init`` — i.e.
    ``skip_nonfinite_updates(optimizer).init`` — since the guard nests the
    inner state under :class:`NonfiniteGuardState`.
    """
    loss_fn = make_loss(cfg, pcfg, opts)
    if skip_nonfinite:
        optimizer = skip_nonfinite_updates(optimizer)

    def node_grads(params, node_batch):
        """Grad-accumulate over the microbatch axis for ONE node."""

        def micro_step(acc, mb):
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
            acc_g, acc_l = acc
            acc_g = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), acc_g, grads
            )
            return (acc_g, acc_l + loss), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (grads, loss_sum), _ = jax.lax.scan(
            micro_step, (zeros, jnp.zeros((), jnp.float32)), node_batch
        )
        m = pcfg.microbatch
        grads = jax.tree.map(lambda g: g / m, grads)
        return grads, loss_sum / m

    def train_step(stacked_params, stacked_opt, batch, coeffs):
        grads, losses = jax.vmap(node_grads)(stacked_params, batch)
        updates, new_opt = jax.vmap(optimizer.update)(
            grads, stacked_opt, stacked_params
        )
        new_params = jax.vmap(apply_updates)(stacked_params, updates)
        if gossip:
            new_params = mix_dense(new_params, coeffs)
        return new_params, new_opt, jnp.mean(losses)

    return train_step


def reshape_for_microbatch(batch, n_nodes: int, micro: int):
    """(global_b, S...) → (N, micro, local_b/micro, S...)."""

    def fn(leaf):
        g = leaf.shape[0]
        local = g // n_nodes
        mb = local // micro
        if local % micro:
            raise ValueError(
                f"local batch {local} not divisible by microbatch {micro}"
            )
        return leaf.reshape((n_nodes, micro, mb) + leaf.shape[1:])

    return jax.tree.map(fn, batch)
