"""musicgen-medium — decoder-only transformer over EnCodec audio tokens.

[arXiv:2306.05284] MusicGen (Copet et al., 2023), medium size:
48 layers, d_model=1536, 24 heads (GQA kv=24 ⇒ full MHA), d_ff=6144,
vocab=2048 (EnCodec codebook).  The EnCodec conv codec + text conditioner is
the modality frontend — STUBBED per the assignment: ``input_specs`` provides
precomputed frame embeddings; the decoder transformer here is real.
"""
from repro.configs.base import ModelConfig, ParallelConfig

ARCH_ID = "musicgen-medium"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="audio",
        source="arXiv:2306.05284 (MusicGen medium)",
        n_layers=48,
        d_model=1536,
        n_heads=24,
        n_kv_heads=24,
        d_ff=6144,
        vocab_size=2048,
        mlp_kind="gelu",          # MusicGen uses standard GELU FFN
        norm_kind="layernorm",
        rope_theta=10000.0,
        frontend="audio",
        frontend_dim=128,         # EnCodec latent frame dim (stub)
        max_seq_len=524_288,
    )


def parallel() -> ParallelConfig:
    # ~0.86B trunk params → 16 gossip nodes/pod, pure TP within node.
    return ParallelConfig(n_nodes=16, microbatch=2, remat=True)


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="audio",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab_size=128, mlp_kind="gelu", norm_kind="layernorm",
        frontend="audio", frontend_dim=32,
        dtype="float32", param_dtype="float32",
    )
