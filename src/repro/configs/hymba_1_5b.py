"""hymba-1.5b — hybrid-head architecture: parallel attention + Mamba heads.

[arXiv:2411.13676] Hymba (NVIDIA, 2024): 32 layers, d_model=1600,
25 heads (GQA kv=5), d_ff=5504, vocab=32001, ssm_state=16.  Each layer runs
attention heads and SSM (Mamba) heads *in parallel* on the same input and
fuses their (normalized) outputs — implemented in
``repro.models.transformer`` via ``hybrid_ssm=True`` (outputs averaged; the
paper's learnable per-path β is approximated by the 0.5/0.5 fuse — noted in
DESIGN.md).  Hymba uses sliding-window attention for most layers with a few
global layers; we model the published pattern as local/local/global.
"""
from repro.configs.base import ModelConfig, ParallelConfig

ARCH_ID = "hymba-1.5b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="hybrid",
        source="arXiv:2411.13676 (Hymba-1.5B)",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        d_ff=5504,
        vocab_size=32001,
        head_dim=64,
        mlp_kind="swiglu",
        attn_pattern=("local", "local", "global"),
        window_size=1024,
        hybrid_ssm=True,
        ssm_state_dim=16,
        ssm_expand=2,
        ssm_conv_dim=4,
        max_seq_len=524_288,      # SSM state + mostly-local attn ⇒ long ctx OK
    )


def parallel() -> ParallelConfig:
    return ParallelConfig(n_nodes=16, microbatch=2, remat=True)


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="hybrid",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=128, head_dim=32, attn_pattern=("local", "local", "global"),
        window_size=16, hybrid_ssm=True, ssm_state_dim=8, ssm_expand=2,
        dtype="float32", param_dtype="float32",
    )
