"""stablelm-1.6b — dense decoder.

[hf:stabilityai/stablelm-2-1_6b]: 24 layers, d_model=2048, 32 heads
(GQA kv=32 ⇒ MHA), d_ff=5632, vocab=100352.  RoPE (partial in the released
model; full here), SiLU-gated MLP, LayerNorm.
"""
from repro.configs.base import ModelConfig, ParallelConfig

ARCH_ID = "stablelm-1.6b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        source="hf:stabilityai/stablelm-2-1_6b",
        n_layers=24,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=5632,
        vocab_size=100352,
        mlp_kind="swiglu",
        norm_kind="layernorm",
        rope_theta=10000.0,
        max_seq_len=32_768,
    )


def parallel() -> ParallelConfig:
    return ParallelConfig(n_nodes=16, microbatch=2, remat=True)


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab_size=256, mlp_kind="swiglu", norm_kind="layernorm",
        dtype="float32", param_dtype="float32",
    )
