"""Model / run configuration system.

A single frozen :class:`ModelConfig` describes every architecture family in
the zoo (dense, MoE, SSM, hybrid, VLM, audio).  Family-specific fields are
simply unused by other families.  Every assigned-architecture file in
``repro/configs/`` instantiates one of these with the exact values from the
assignment (sources cited in each file) and also provides ``smoke()`` — the
reduced variant (≤2 layers, d_model ≤ 512, ≤4 experts) used by CPU tests.

``ParallelConfig`` carries the distribution plan consumed by
``repro/launch``: how the production mesh's ``data`` axis is split between
the gossip-topology node axis and FSDP, microbatching, remat, etc.  See
DESIGN.md §5 for the memory math that picks ``n_nodes`` per arch.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = ["ModelConfig", "ParallelConfig", "RunConfig", "SHAPES", "InputShape"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity ----------------------------------------------------------
    name: str = "model"
    family: str = "dense"          # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""               # citation for the config values

    # trunk -------------------------------------------------------------
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1024
    head_dim: Optional[int] = None          # default: d_model // n_heads
    mlp_kind: str = "swiglu"                # swiglu | gelu | geglu
    norm_kind: str = "rmsnorm"              # rmsnorm | layernorm
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    max_seq_len: int = 8192

    # attention variants --------------------------------------------------
    rope_theta: float = 10000.0
    attn_pattern: Tuple[str, ...] = ("global",)   # cycled over layers
    window_size: int = 4096                        # for "local" layers
    attn_logit_softcap: float = 0.0                # gemma2: 50.0
    final_logit_softcap: float = 0.0               # gemma2: 30.0
    qk_norm: bool = False

    # MLA (deepseek-v2) ---------------------------------------------------
    use_mla: bool = False
    kv_lora_rank: int = 512
    q_lora_rank: int = 0            # 0 = no q compression
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # MoE -----------------------------------------------------------------
    n_experts: int = 0              # 0 = dense MLP
    n_shared_experts: int = 0
    experts_per_token: int = 1
    moe_d_ff: Optional[int] = None  # per-expert hidden (default d_ff)
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01
    first_k_dense: int = 0          # deepseek: first layer(s) dense

    # SSM / RWKV ----------------------------------------------------------
    ssm_state_dim: int = 16
    ssm_conv_dim: int = 4
    ssm_expand: int = 2             # mamba d_inner = expand * d_model
    rwkv_head_dim: int = 64

    # hybrid (hymba) ------------------------------------------------------
    hybrid_ssm: bool = False        # parallel attn+SSM heads per layer

    # modality frontend stub ----------------------------------------------
    frontend: Optional[str] = None  # None | "audio" | "vision"
    frontend_dim: int = 0           # embedding dim provided by the stub

    # dtypes ----------------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"

    # -----------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def moe_d_ff_(self) -> int:
        return self.moe_d_ff if self.moe_d_ff is not None else self.d_ff

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def weight_dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """sub-quadratic decode: SSM/hybrid always; attention archs when a
        sliding-window pattern bounds (most of) the cache, or MLA compresses
        it (checked against HBM in launch/dryrun.py)."""
        return (
            self.family in ("ssm", "hybrid")
            or "local" in self.attn_pattern
            or self.use_mla
        )

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer attention kind by cycling ``attn_pattern``."""
        pat = self.attn_pattern
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS=6ND)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim_
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":  # rwkv6
            att = d * (self.n_heads * hd) * 4 + d * (self.n_heads * hd)  # r,k,v,g,o
            att += 6 * d * 32 * 2 + d * hd  # lora mixers + decay (approx)
            mlp = 2 * d * f + f * d  # rwkv channel-mix has k,r,v
        elif self.use_mla:
            att = d * self.kv_lora_rank + d * self.qk_rope_head_dim
            att += self.kv_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.v_head_dim)
            if self.q_lora_rank:
                att += d * self.q_lora_rank + self.q_lora_rank * self.n_heads * (
                    self.qk_nope_head_dim + self.qk_rope_head_dim)
            else:
                att += d * self.n_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
            att += self.n_heads * self.v_head_dim * d
            mlp = 0  # counted via moe below
        else:
            att = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
            mlp = (3 if self.mlp_kind in ("swiglu", "geglu") else 2) * d * f
        gates = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
        if self.is_moe:
            fe = self.moe_d_ff_
            moe = (self.n_experts + self.n_shared_experts) * gates * d * fe + d * self.n_experts
            dense_layers = self.first_k_dense
            moe_layers = self.n_layers - dense_layers
            body = moe_layers * (att + moe) + dense_layers * (att + gates * d * f)
        else:
            body = self.n_layers * (att + mlp)
        if self.hybrid_ssm:
            d_in = self.ssm_expand * d
            body += self.n_layers * (2 * d * d_in + d_in * d + d_in * self.ssm_state_dim * 2)
        return int(emb + body)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k + shared experts only)."""
        if not self.is_moe:
            return self.param_count()
        gates = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
        fe = self.moe_d_ff_
        inactive = (
            (self.n_layers - self.first_k_dense)
            * (self.n_experts - self.experts_per_token)
            * gates * self.d_model * fe
        )
        return self.param_count() - int(inactive)


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """How the production mesh's axes are used for this arch (DESIGN.md §5).

    The pod's ``data`` axis (16) is split ``node × fsdp``:
      * ``n_nodes``  — gossip-topology nodes in one pod (paper's devices),
      * ``16 // n_nodes`` — FSDP shards *within* each node's model copy.
    ``model`` (16) is tensor parallel.  Multi-pod adds the ``pod`` axis
    (hierarchical gossip tier).
    """

    n_nodes: int = 16
    tp_degree: int = 16             # tensor-parallel width (model axis)
    microbatch: int = 1             # grad-accumulation chunks per train step
    remat: bool = True              # checkpoint each layer in train fwd
    opt_dtype: str = "float32"      # adam moment dtype ("bfloat16" to halve)
    scan_layers: bool = True
    chunked_ce: int = 0             # >0: sequence-chunked cross-entropy width
    gossip_schedule: str = "dense"  # dense | sparse (circulant ppermute)
    steps_per_round: int = 1        # optimizer steps between gossips (Alg. 1
                                    # rounds amortize the gossip collective)
    moe_group_limit: int = 0        # device-limited routing (DeepSeek-V2
                                    # §2.1.3): token reaches ≤M expert groups

    @property
    def fsdp(self) -> int:
        return 256 // (self.n_nodes * self.tp_degree)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    parallel: ParallelConfig
    optimizer: str = "adamw"
    learning_rate: float = 3e-4
    rounds: int = 40
    local_epochs: int = 5
    topology: str = "ba"
    topology_kwargs: tuple = (("p", 2),)
    strategy: str = "degree"
    tau: float = 0.1
    seed: int = 0
