"""internvl2-1b — VLM: InternViT vision encoder + 0.9B LM trunk.

[arXiv:2404.16821] InternVL2-1B (Qwen2-0.5B LM trunk): 24 layers,
d_model=896, 14 heads (GQA kv=2), d_ff=4864, vocab=151655.  The InternViT
vision encoder + MLP projector is the modality frontend — STUBBED per the
assignment: ``input_specs`` provides precomputed patch embeddings
(frontend_dim=1024, InternViT-300M output width); the LM trunk is real.
"""
from repro.configs.base import ModelConfig, ParallelConfig

ARCH_ID = "internvl2-1b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="vlm",
        source="arXiv:2404.16821 (InternVL2-1B / Qwen2-0.5B trunk)",
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        d_ff=4864,
        vocab_size=151655,
        mlp_kind="swiglu",
        norm_kind="rmsnorm",
        rope_theta=1000000.0,
        frontend="vision",
        frontend_dim=1024,
        max_seq_len=32_768,
    )


def parallel() -> ParallelConfig:
    return ParallelConfig(n_nodes=16, microbatch=1, remat=True)


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="vlm",
        n_layers=2, d_model=112, n_heads=4, n_kv_heads=2, d_ff=224,
        vocab_size=256, frontend="vision", frontend_dim=64, head_dim=28,
        dtype="float32", param_dtype="float32",
    )
