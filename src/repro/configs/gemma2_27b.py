"""gemma2-27b — dense decoder with local/global alternation + softcaps.

[arXiv:2408.00118] Gemma-2 27B: 46 layers, d_model=4608, 32 heads
(GQA kv=16), d_ff=36864, vocab=256000, head_dim=128, alternating
sliding-window(4096)/global attention, attention-logit softcap 50,
final-logit softcap 30, RMSNorm, GeGLU.
"""
from repro.configs.base import ModelConfig, ParallelConfig

ARCH_ID = "gemma2-27b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        source="arXiv:2408.00118 (Gemma-2 27B)",
        n_layers=46,
        d_model=4608,
        n_heads=32,
        n_kv_heads=16,
        d_ff=36864,
        vocab_size=256000,
        head_dim=128,
        mlp_kind="geglu",
        norm_kind="rmsnorm",
        rope_theta=10000.0,
        attn_pattern=("local", "global"),
        window_size=4096,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        max_seq_len=524_288,   # long_500k via the sliding-window variant:
                               # local layers cache 4k; global layers are the
                               # gate — dryrun verifies the fit (DESIGN.md §4)
    )


def parallel() -> ParallelConfig:
    # 27B ⇒ 4 gossip nodes/pod (FSDP 4 × TP 16 = 64 chips per copy).
    return ParallelConfig(n_nodes=4, microbatch=8, remat=True)


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=256, head_dim=32, mlp_kind="geglu",
        attn_pattern=("local", "global"), window_size=16,
        attn_logit_softcap=50.0, final_logit_softcap=30.0,
        dtype="float32", param_dtype="float32",
    )
