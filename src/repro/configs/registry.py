"""Architecture registry: ``--arch <id>`` → (ModelConfig, ParallelConfig).

All 10 assigned architectures plus the paper's own models.  Import is lazy
so ``repro.configs`` stays cheap to import.
"""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig, ParallelConfig, SHAPES

__all__ = ["ARCHS", "get_config", "get_smoke_config", "get_parallel", "SHAPES"]

ARCHS = {
    "musicgen-medium": "repro.configs.musicgen_medium",
    "hymba-1.5b": "repro.configs.hymba_1_5b",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "starcoder2-7b": "repro.configs.starcoder2_7b",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
    "phi3-mini-3.8b": "repro.configs.phi3_mini_3_8b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "internvl2-1b": "repro.configs.internvl2_1b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "gemma2-27b": "repro.configs.gemma2_27b",
}


def _module(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(ARCHS)}")
    return importlib.import_module(ARCHS[arch])


def get_config(arch: str) -> ModelConfig:
    return _module(arch).config()


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke()


def get_parallel(arch: str) -> ParallelConfig:
    return _module(arch).parallel()
