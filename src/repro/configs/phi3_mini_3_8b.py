"""phi3-mini-3.8b — dense decoder, RoPE + SwiGLU.

[arXiv:2404.14219] Phi-3-mini: 32 layers, d_model=3072, 32 heads
(GQA kv=32 ⇒ MHA), d_ff=8192, vocab=32064.
"""
from repro.configs.base import ModelConfig, ParallelConfig

ARCH_ID = "phi3-mini-3.8b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        source="arXiv:2404.14219 (Phi-3-mini 3.8B)",
        n_layers=32,
        d_model=3072,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=32064,
        mlp_kind="swiglu",
        norm_kind="rmsnorm",
        rope_theta=10000.0,
        max_seq_len=131_072,
    )


def parallel() -> ParallelConfig:
    return ParallelConfig(n_nodes=16, microbatch=4, remat=True)


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab_size=256, mlp_kind="swiglu",
        dtype="float32", param_dtype="float32",
    )
