"""rwkv6-3b — "Finch": attention-free RNN with data-dependent decay.

[arXiv:2404.05892] RWKV-6 3B: 32 layers, d_model=2560, d_ff=8960,
vocab=65536.  Time-mix (matrix-valued state, per-channel data-dependent
decay via low-rank token-shift mixers) + channel-mix.  O(1) decode state →
the canonical ``long_500k`` architecture.
"""
from repro.configs.base import ModelConfig, ParallelConfig

ARCH_ID = "rwkv6-3b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="ssm",
        source="arXiv:2404.05892 (RWKV-6 Finch 3B)",
        n_layers=32,
        d_model=2560,
        n_heads=40,              # heads = d_model / rwkv_head_dim
        n_kv_heads=40,
        d_ff=8960,
        vocab_size=65536,
        rwkv_head_dim=64,
        norm_kind="layernorm",
        max_seq_len=1_048_576,   # state is O(1) in sequence length
    )


def parallel() -> ParallelConfig:
    return ParallelConfig(n_nodes=16, microbatch=2, remat=True)


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="ssm",
        n_layers=2, d_model=128, d_ff=256, vocab_size=128,
        n_heads=4, n_kv_heads=4, rwkv_head_dim=32, norm_kind="layernorm",
        dtype="float32", param_dtype="float32",
    )
