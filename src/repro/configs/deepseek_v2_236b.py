"""deepseek-v2-236b — MoE with Multi-head Latent Attention (MLA).

[arXiv:2405.04434] DeepSeek-V2: 60 layers, d_model=5120, 128 heads,
MLA kv_lora_rank=512 (q_lora_rank=1536), qk_nope=128, qk_rope=64, v=128;
MoE: 2 shared + 160 routed experts, top-6, per-expert d_ff=1536; first
layer dense (d_ff=12288); vocab=102400.  ≈236B total / ≈21B active.

The MLA latent cache (r=512 + rope 64 per token, layer) is ~18× smaller
than full MHA KV — this is what makes ``long_500k`` decode *fit* for a
236B model (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig, ParallelConfig

ARCH_ID = "deepseek-v2-236b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        source="arXiv:2405.04434 (DeepSeek-V2 236B)",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        d_ff=12288,            # the dense first layer's FFN width
        vocab_size=102400,
        mlp_kind="swiglu",
        norm_kind="rmsnorm",
        rope_theta=10000.0,
        use_mla=True,
        kv_lora_rank=512,
        q_lora_rank=1536,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        n_experts=160,
        n_shared_experts=2,
        experts_per_token=6,
        moe_d_ff=1536,
        capacity_factor=1.25,
        first_k_dense=1,
        max_seq_len=524_288,   # MLA latent cache keeps 500k viable
    )


def parallel() -> ParallelConfig:
    # 236B ⇒ ONE model copy per pod (FSDP 16 × TP 16 = 256 chips);
    # gossip topology lives on the pod axis (hierarchical tier).
    return ParallelConfig(n_nodes=1, microbatch=16, remat=True,
                          opt_dtype="bfloat16")


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="moe",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab_size=256, use_mla=True, kv_lora_rank=32, q_lora_rank=48,
        qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32,
        n_experts=4, n_shared_experts=1, experts_per_token=2,
        moe_d_ff=64, first_k_dense=1,
        dtype="float32", param_dtype="float32",
    )
