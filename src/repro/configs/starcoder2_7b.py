"""starcoder2-7b — dense code model, GQA + RoPE.

[arXiv:2402.19173] StarCoder2-7B: 32 layers, d_model=4608, 36 heads
(GQA kv=4), d_ff=18432, vocab=49152.  Non-gated GELU FFN (4×),
sliding-window 4096 in the released model — modeled here with the
local/global alternation it ships with (every layer windowed except the
final; we use alternating local/global to retain long-range paths).
"""
from repro.configs.base import ModelConfig, ParallelConfig

ARCH_ID = "starcoder2-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        source="arXiv:2402.19173 (StarCoder2-7B)",
        n_layers=32,
        d_model=4608,
        n_heads=36,
        n_kv_heads=4,
        d_ff=18432,
        vocab_size=49152,
        mlp_kind="gelu",
        norm_kind="layernorm",
        rope_theta=100000.0,
        attn_pattern=("local", "global"),
        window_size=4096,
        max_seq_len=524_288,   # local/global pattern bounds most of the cache
    )


def parallel() -> ParallelConfig:
    # 7.2B → 72 GB params+opt per node copy / 16 TP chips = 4.5 GB/chip.
    return ParallelConfig(n_nodes=16, microbatch=4, remat=True)


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=144, n_heads=4, n_kv_heads=2, d_ff=288,
        vocab_size=256, mlp_kind="gelu", norm_kind="layernorm",
        attn_pattern=("local", "global"), window_size=16, head_dim=36,
        dtype="float32", param_dtype="float32",
    )
