"""llama4-scout-17b-a16e — MoE decoder, 16 experts top-1 + shared expert.

[hf:meta-llama/Llama-4-Scout-17B-16E]: 48 layers, d_model=5120, 40 heads
(GQA kv=8), d_ff=8192 (per expert), vocab=202048, 16 routed experts top-1
plus one always-on shared expert (≈17B active / ≈109B total).  Early-fusion
multimodal in the release; the assignment exercises the language trunk.
"""
from repro.configs.base import ModelConfig, ParallelConfig

ARCH_ID = "llama4-scout-17b-a16e"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=202048,
        mlp_kind="swiglu",
        norm_kind="rmsnorm",
        rope_theta=500000.0,
        qk_norm=True,
        n_experts=16,
        n_shared_experts=1,
        experts_per_token=1,
        moe_d_ff=8192,
        capacity_factor=1.25,
        max_seq_len=131_072,
    )


def parallel() -> ParallelConfig:
    # ≈109B total → one copy per 128 chips: 2 gossip nodes/pod, FSDP=8.
    return ParallelConfig(n_nodes=2, microbatch=8, remat=True,
                          opt_dtype="bfloat16")


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="moe",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=256, n_experts=4, n_shared_experts=1, experts_per_token=1,
        moe_d_ff=256, qk_norm=True,
        dtype="float32", param_dtype="float32",
    )
