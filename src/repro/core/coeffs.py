"""Device-side coefficient programs — in-scan mixing-matrix generation.

The paper's contribution is ``GetAggrCoeffs``: per-round, per-node
aggregation coefficients.  The scanned/sharded engines (DESIGN.md §7/§8)
originally consumed them only as host-precomputed ``(E, R, n, n)`` stacks
— which dominate sweep memory (Fig-4 scale: E=96, R=500, n=32 is ~200 MB
of float32 coefficients per dispatch, vs ~0.4 MB of program state) and
make *reactive* strategies (recompute centrality on the per-round
surviving subgraph) impossible inside the scan.

A :class:`CoeffProgram` is the alternative: a jittable

    ``matrix(state, round_idx) -> (n, n) row-stochastic mixing matrix``

with compact per-experiment ``state`` (adjacency, nominal centrality
scores, data counts, τ, strategy id, PRNG seed, link-failure rate).  The
program is pure data-in/data-out, so it runs

* inside the round scan of ``repro.core.decentralized.make_scan_fn``
  (``coeff_fn=``) and all three ``repro.core.sweep.SweepEngine`` modes —
  scanned, sharded (state shards on the E axis), chunked;
* or *outside* the scan via :meth:`CoeffProgram.materialize`, which
  reproduces the legacy ``coeffs_stack`` slab bit-for-bit
  (``repro.core.decentralized.coeffs_stack`` now delegates here for every
  program-supported strategy).

**PRNG folding** (DESIGN.md §9): with ``base = key(seed)``, round r uses
``fold_in(fold_in(base, r), 0)`` for the Bernoulli edge mask
(``repro.core.dynamic.edge_mask``) and
``fold_in(fold_in(base, r·resample), 1)`` for the Random baseline's score
draw — so link churn varies per round even when Random resampling is
frozen, and every round's matrix is a pure function of (state, r).
Fold index 2 belongs to the node-level participation draw
(``repro.core.dynamic.ParticipationSpec``, DESIGN.md §15) so the three
in-scan randomness streams never collide.

**Centrality kernels** (pure jnp, fixed iteration counts so they trace):
degree is exact; eigenvector/PageRank run a fixed-length power method;
closeness counts hops via repeated masked matrix products
(Wasserman–Faust component scaling, networkx's default, so disconnected
survivors are well-defined).  Betweenness has no fixed-shape jnp
formulation (Brandes is data-dependent control flow over shortest-path
DAGs) — it stays host-side: reactive programs fall back to the NOMINAL
betweenness scores in state, documented here and in DESIGN.md §9.

Property tests against the networkx values cached on ``Topology`` live in
``tests/test_coeffs.py``; stack-vs-program bit-identity in
``tests/test_sweep_programs.py`` / ``tests/test_sweep_sharded.py``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dynamic import edge_mask
from repro.core.strategies import (
    AggregationStrategy,
    masked_normalize,
    masked_softmax,
    renormalize_rows,
    strategy_scores,
)
from repro.core.topology import Topology

__all__ = [
    "PROGRAM_KINDS",
    "CENTRALITY_KINDS",
    "CoeffProgram",
    "ProgramCoeffs",
    "program_for",
    "participation_renormalize",
    "quarantine_renormalize",
    "stack_states",
    "state_nbytes",
    "degree_centrality",
    "eigenvector_centrality",
    "pagerank_centrality",
    "closeness_centrality",
    "sparse_matvec",
    "eigenvector_centrality_sparse",
    "pagerank_centrality_sparse",
]

# lax.switch branch order — state["kind"] indexes into this tuple
PROGRAM_KINDS = ("unweighted", "weighted", "random", "fl", "degree",
                 "betweenness", "eigenvector", "pagerank", "closeness")
# kinds whose state carries nominal (host-computed) centrality scores
CENTRALITY_KINDS = ("degree", "betweenness", "eigenvector", "pagerank",
                    "closeness")


# ----------------------------------------------------------------------
# pure-jnp centrality kernels (fixed shapes / iteration counts)
# ----------------------------------------------------------------------
def degree_centrality(adj: jnp.ndarray) -> jnp.ndarray:
    """degree / (n-1) — the networkx normalization (scores in [0, 1])."""
    n = adj.shape[-1]
    return adj.sum(axis=-1) / max(n - 1, 1)


def eigenvector_centrality(adj: jnp.ndarray, iters: int = 200) -> jnp.ndarray:
    """Principal adjacency eigenvector via ``iters`` power-method steps,
    unit 2-norm, nonnegative (matches ``nx.eigenvector_centrality_numpy``
    up to power-method convergence).  Iterates on ``A + I`` — same
    eigenvectors, but the top eigenvalue is strictly dominant even on
    bipartite (sub)graphs where ``λ_min = -λ_max`` makes plain power
    iteration oscillate (networkx's iterative variant shifts the same
    way).  A zero adjacency (every edge dropped) keeps the uniform start
    vector instead of dividing by 0."""
    n = adj.shape[-1]
    x0 = jnp.full((n,), 1.0 / np.sqrt(n), adj.dtype)

    def step(x, _):
        y = adj @ x + x
        norm = jnp.sqrt((y * y).sum())
        return jnp.where(norm > 1e-12, y / jnp.maximum(norm, 1e-12), x), None

    x, _ = jax.lax.scan(step, x0, None, length=iters)
    return x


def pagerank_centrality(adj: jnp.ndarray, alpha: float = 0.85,
                        iters: int = 200) -> jnp.ndarray:
    """PageRank mass by fixed-length power iteration — networkx semantics:
    uniform personalization, dangling (isolated) nodes redistribute their
    mass uniformly.  α^200 ≈ 6e-15, far past nx's 1e-6 stop tolerance."""
    n = adj.shape[-1]
    deg = adj.sum(axis=-1)
    dangling = deg <= 0
    p = adj / jnp.where(dangling, 1.0, deg)[:, None]
    x0 = jnp.full((n,), 1.0 / n, adj.dtype)

    def step(x, _):
        dmass = jnp.where(dangling, x, 0.0).sum()
        return alpha * (x @ p + dmass / n) + (1.0 - alpha) / n, None

    x, _ = jax.lax.scan(step, x0, None, length=iters)
    return x


def closeness_centrality(adj: jnp.ndarray) -> jnp.ndarray:
    """Closeness via matrix-power hop counts: reachability after k hops is
    ``(I + A)^k > 0``; a node's distance to j is the first k that reaches
    it.  Wasserman–Faust component scaling (networkx default):
    ``cc(u) = ((r-1)/Σd) · ((r-1)/(n-1))`` with r = component size, so
    disconnected subgraphs (``drop_edges`` survivors) are well-defined and
    isolated nodes score 0."""
    n = adj.shape[-1]
    eye = jnp.eye(n, dtype=adj.dtype)
    hop = jnp.minimum(adj + eye, 1.0)

    def step(carry, k):
        reach, dist = carry
        new_reach = jnp.minimum(reach @ hop, 1.0)
        newly = (new_reach > 0) & (reach == 0)
        dist = dist + jnp.where(newly, k.astype(adj.dtype), 0.0)
        return (new_reach, dist), None

    (reach, dist), _ = jax.lax.scan(
        step, (eye, jnp.zeros((n, n), adj.dtype)),
        jnp.arange(1, max(n, 2), dtype=jnp.int32))
    r = reach.sum(axis=1)            # component size, including self
    sd = dist.sum(axis=1)            # Σ distances within the component
    return jnp.where(
        sd > 0,
        (r - 1.0) / jnp.maximum(sd, 1.0) * (r - 1.0) / max(n - 1, 1),
        0.0)


# ----------------------------------------------------------------------
# sparse (edge-list) centrality operands — per-edge instead of per-pair
# ----------------------------------------------------------------------
def sparse_matvec(nbr_idx: jnp.ndarray, nbr_val: jnp.ndarray,
                  x: jnp.ndarray) -> jnp.ndarray:
    """``(A @ x)[i] = Σ_d nbr_val[i, d] · x[nbr_idx[i, d]]`` for a matrix
    held as padded-ELL tables (``repro.core.topology.
    padded_neighbor_tables``; padding slots carry value 0).  O(n·dmax)
    work and state instead of the dense O(n²) — the operand the sparse
    power-iteration kernels below are built on."""
    return (nbr_val * jnp.take(x, nbr_idx, axis=0)).sum(axis=-1)


def eigenvector_centrality_sparse(nbr_idx: jnp.ndarray,
                                  nbr_val: jnp.ndarray,
                                  iters: int = 200) -> jnp.ndarray:
    """:func:`eigenvector_centrality` with the adjacency as padded-ELL
    edge tables: the same ``A + I``-shifted power method (same norm
    guard, same uniform start), each step a :func:`sparse_matvec` —
    200·|E| MACs instead of 200·n².  Property-tested against the cached
    networkx values in tests/test_coeffs.py."""
    n = nbr_idx.shape[0]
    x0 = jnp.full((n,), 1.0 / np.sqrt(n), nbr_val.dtype)

    def step(x, _):
        y = sparse_matvec(nbr_idx, nbr_val, x) + x
        norm = jnp.sqrt((y * y).sum())
        return jnp.where(norm > 1e-12, y / jnp.maximum(norm, 1e-12), x), None

    x, _ = jax.lax.scan(step, x0, None, length=iters)
    return x


def pagerank_centrality_sparse(nbr_idx: jnp.ndarray, nbr_val: jnp.ndarray,
                               alpha: float = 0.85,
                               iters: int = 200) -> jnp.ndarray:
    """:func:`pagerank_centrality` with the adjacency as padded-ELL edge
    tables.  The dense step's column combine ``(x @ P)[j] = Σ_i a_ij ·
    x_i / deg_i`` becomes, for a SYMMETRIC adjacency, a row gather over
    j's own neighbour list: ``Σ_d nbr_val[j, d] · (x / deg)[nbr_idx[j,
    d]]`` — a :func:`sparse_matvec` on the degree-normalized iterate.
    Dangling (isolated) nodes have no surviving edges, so they never
    appear in any table slot with nonzero value; their mass is
    redistributed through the same ``dmass`` term as the dense kernel.
    Matches networkx / the dense kernel on undirected graphs, including
    disconnected ``edge_mask`` survivors."""
    n = nbr_idx.shape[0]
    deg = nbr_val.sum(axis=-1)
    dangling = deg <= 0
    inv_deg = jnp.where(dangling, 0.0, 1.0 / jnp.where(dangling, 1.0, deg))
    x0 = jnp.full((n,), 1.0 / n, nbr_val.dtype)

    def step(x, _):
        dmass = jnp.where(dangling, x, 0.0).sum()
        y = sparse_matvec(nbr_idx, nbr_val, x * inv_deg)
        return alpha * (y + dmass / n) + (1.0 - alpha) / n, None

    x, _ = jax.lax.scan(step, x0, None, length=iters)
    return x


def _scaled_pagerank(adj: jnp.ndarray, alpha: float, iters: int) -> jnp.ndarray:
    """PageRank rescaled to [0, 1] — the strategies.py convention (mass is
    O(1/n); without rescaling τ=0.1 would flatten the softmax)."""
    pr = pagerank_centrality(adj, alpha=alpha, iters=iters)
    return pr / pr.max()


def _scaled_pagerank_sparse(nbr_idx: jnp.ndarray, nbr_val: jnp.ndarray,
                            alpha: float, iters: int) -> jnp.ndarray:
    """:func:`_scaled_pagerank` on padded-ELL edge tables."""
    pr = pagerank_centrality_sparse(nbr_idx, nbr_val, alpha=alpha,
                                    iters=iters)
    return pr / pr.max()


# ----------------------------------------------------------------------
# the coefficient program
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CoeffProgram:
    """Jittable per-round mixing-matrix generator (hashable → usable as a
    jit static argument and cache key).

    ``reactive=True`` recomputes centrality scores on the round's
    SURVIVING subgraph with the jnp kernels above; ``False`` restricts the
    nominal-score softmax to surviving support (which equals renormalizing
    the nominal matrix over surviving links — softmax restricted to a
    subset and renormalized IS the softmax over the subset).  Betweenness
    uses nominal scores in both modes (no fixed-shape jnp kernel).

    ``sparse=True`` (``program_for(..., sparse=True)``) switches the
    reactive degree/eigenvector/pagerank recomputation to the edge-list
    kernels (:func:`sparse_matvec` family): the state carries per-EDGE
    tables (``nbr_idx`` / ``nbr_val``, (n, dmax)) instead of feeding the
    per-pair (n, n) adjacency to the power iterations, and each round's
    per-edge survival is gathered from the SAME ``edge_mask`` draw — so
    the surviving support is bit-identical to the dense program and the
    power method costs O(iters·|E|) instead of O(iters·n²).  Closeness
    is inherently all-pairs (hop-distance matrix powers) and betweenness
    stays nominal, so both keep their dense/nominal path under
    ``sparse=True`` — documented in DESIGN.md §12.
    """

    n_nodes: int
    reactive: bool = False
    power_iters: int = 200
    pagerank_iters: int = 200
    pagerank_alpha: float = 0.85
    sparse: bool = False
    # static branch pruning: the sorted tuple of PROGRAM_KINDS indices this
    # program will ever be asked for (None → all nine).  Under the engine's
    # vmap-over-E the batched switch lowers to compute-all-branches +
    # select, so an unpruned reactive program pays the 200-iteration
    # power-method scans and the closeness matrix-power scan EVERY round
    # even when the grid never uses those kinds — the measured ~1.8×
    # program-vs-stack slowdown (BENCH_sweep.json `coeff_programs`).
    # Pruning is bit-identical for every kind it keeps.
    kinds: Optional[tuple] = None
    # static link-churn gate: False skips the per-round Bernoulli edge
    # mask entirely (bit-identical to p_fail = 0, which keeps every edge
    # exactly — see dynamic.edge_mask).  Grids with any p_fail > 0 must
    # keep True.
    link_failure: bool = True
    # betweenness has NO reactive jnp kernel (Brandes is data-dependent
    # control flow) — a reactive program asked for betweenness would
    # silently serve the nominal host-computed scores while every other
    # kind recomputes on the surviving subgraph.  validate_state_kinds
    # refuses that mixed semantics unless a caller opts into the nominal
    # fallback explicitly here (DESIGN.md §9).
    allow_nominal_betweenness: bool = False

    def __post_init__(self):
        if self.kinds is None:
            return
        kinds = tuple(sorted({int(k) for k in self.kinds}))
        if not kinds or kinds[0] < 0 or kinds[-1] >= len(PROGRAM_KINDS):
            raise ValueError(
                f"CoeffProgram.kinds must be non-empty indices into "
                f"PROGRAM_KINDS (0..{len(PROGRAM_KINDS) - 1}); got "
                f"{self.kinds!r}")
        object.__setattr__(self, "kinds", kinds)

    # ------------------------------------------------------------------
    def validate_state_kinds(self, state) -> None:
        """Host-side guard run before every materialize/engine dispatch:

        * pruned programs — a state whose ``kind`` is not among the
          traced branches would be silently remapped to the nearest kept
          branch by the compact switch — refuse instead;
        * reactive betweenness — there is no fixed-shape jnp betweenness
          kernel, so a reactive program would silently serve NOMINAL
          host-computed scores while every other kind recomputes on the
          surviving subgraph — refuse that mixed semantics unless
          ``allow_nominal_betweenness=True`` opts in (DESIGN.md §9).

        ``state`` may carry a leading experiment axis."""
        present = {int(k) for k in np.asarray(state["kind"]).ravel()}
        b_idx = PROGRAM_KINDS.index("betweenness")
        if (self.reactive and b_idx in present
                and not self.allow_nominal_betweenness):
            raise ValueError(
                "reactive CoeffProgram got a 'betweenness' state: "
                "betweenness has no fixed-shape jnp kernel, so the "
                "program would serve NOMINAL (host-computed) scores while "
                "every other kind recomputes on the surviving subgraph. "
                "Either use reactive=False, switch to a reactive "
                "centrality (degree/eigenvector/pagerank/closeness), or "
                "opt into the nominal fallback explicitly with "
                "CoeffProgram(allow_nominal_betweenness=True) / "
                "program_for(..., allow_nominal_betweenness=True) "
                "(DESIGN.md §9)")
        if self.kinds is None:
            return
        bad = sorted(present - set(self.kinds))
        if bad:
            raise ValueError(
                f"CoeffProgram pruned to kinds {self.kinds} "
                f"({[PROGRAM_KINDS[k] for k in self.kinds]}) got state "
                f"kind(s) {bad} ({[PROGRAM_KINDS[k] for k in bad]}); "
                f"rebuild the program with the union of the grid's kinds")

    # ------------------------------------------------------------------
    def matrix(self, state, round_idx) -> jnp.ndarray:
        """(n, n) row-stochastic mixing matrix for one round — pure jnp,
        safe inside jit/vmap/scan/shard_map.  ``state`` is one
        experiment's state (no leading axis); ``round_idx`` an int32
        scalar (absolute round, so chunked execution stays exact)."""
        n = self.n_nodes
        adj = state["adj"]
        r = jnp.asarray(round_idx, jnp.int32)
        base = jax.random.key(state["seed"])
        k_edges = jax.random.fold_in(jax.random.fold_in(base, r), 0)
        k_scores = jax.random.fold_in(
            jax.random.fold_in(base, r * state["resample"]), 1)

        if self.link_failure:
            em = edge_mask(k_edges, n, state["p_fail"], dtype=adj.dtype)
            adj_r = adj * em
        else:
            adj_r = adj
        mask = adj_r + jnp.eye(n, dtype=adj.dtype)
        tau = state["tau"]
        if self.sparse and self.reactive:
            # per-EDGE survival, gathered from the SAME edge-mask draw the
            # dense path multiplies in — surviving support is bit-identical
            nbr_idx = state["nbr_idx"]
            nbr_val = state["nbr_val"]
            if self.link_failure:
                nbr_val = nbr_val * em[jnp.arange(n)[:, None], nbr_idx]
        else:
            nbr_idx = nbr_val = None

        def soft(scores):
            return masked_softmax(scores, mask, tau, xp=jnp)

        def linear(w):
            return masked_normalize(w, mask, xp=jnp)

        def centrality(kernel, sparse_kernel=None):
            if not self.reactive:
                return state["scores"]
            if self.sparse and sparse_kernel is not None:
                return sparse_kernel(nbr_idx, nbr_val)
            return kernel(adj_r)

        # `kind` is per-experiment STATE so one compiled program serves a
        # mixed-strategy grid (fig4!): under the engine's vmap-over-E the
        # batched switch index lowers to compute-all-branches + select.
        # For reactive programs that dead-branch work is the 200-iteration
        # power methods + the closeness matrix-power scan per round —
        # measurably NOT noise (the ~1.8× program-vs-stack gap in
        # BENCH_sweep.json) — which is what the static `kinds` pruning
        # below removes: only the branches a grid actually uses are
        # traced, with `state["kind"]` remapped to the compact branch
        # index by position in the sorted static tuple.
        branches = (
            lambda: linear(jnp.ones((n,), adj.dtype)),         # unweighted
            lambda: linear(state["counts"]),                   # weighted
            lambda: soft(jax.random.uniform(k_scores, (n,))),  # random
            # fl deliberately ignores the edge mask: it models the
            # idealized fully-connected (server) baseline, which P2P link
            # churn does not touch — same semantics as the legacy host
            # path (dynamic_mixing_matrix(surv, fl) is also still 1/n)
            lambda: jnp.full((n, n), 1.0 / n, adj.dtype),      # fl
            lambda: soft(centrality(                           # degree
                degree_centrality,
                lambda i, v: v.sum(axis=-1) / max(n - 1, 1))),
            lambda: soft(state["scores"]),                     # betweenness
            lambda: soft(centrality(
                lambda a: eigenvector_centrality(a, self.power_iters),
                lambda i, v: eigenvector_centrality_sparse(
                    i, v, self.power_iters))),
            lambda: soft(centrality(
                lambda a: _scaled_pagerank(a, self.pagerank_alpha,
                                           self.pagerank_iters),
                lambda i, v: _scaled_pagerank_sparse(
                    i, v, self.pagerank_alpha, self.pagerank_iters))),
            # closeness is inherently all-pairs — dense even when sparse
            lambda: soft(centrality(closeness_centrality)),
        )
        if self.kinds is None:
            return jax.lax.switch(state["kind"], branches)
        if len(self.kinds) == 1:
            return branches[self.kinds[0]]()
        compact = jnp.searchsorted(jnp.asarray(self.kinds, jnp.int32),
                                   jnp.asarray(state["kind"], jnp.int32))
        return jax.lax.switch(compact, tuple(branches[k] for k in self.kinds))

    # ------------------------------------------------------------------
    def materialize(self, state, rounds: Optional[int] = None,
                    round_indices=None) -> np.ndarray:
        """(R, n, n) float32 stack: the program run OUTSIDE the training
        scan — the legacy slab representation.  Non-reactive link-free
        programs reproduce what ``coeffs_stack`` used to build; the
        in-scan path must match this bit-for-bit
        (tests/test_sweep_programs.py)."""
        if round_indices is None:
            if rounds is None:
                raise ValueError("materialize needs rounds or round_indices")
            round_indices = np.arange(int(rounds))
        self.validate_state_kinds(state)
        fn = _materialize_fn(self)
        state = jax.tree.map(jnp.asarray, state)
        return np.asarray(fn(state, jnp.asarray(round_indices, jnp.int32)))


@functools.lru_cache(maxsize=None)
def _materialize_fn(program: CoeffProgram):
    return jax.jit(jax.vmap(program.matrix, in_axes=(None, 0)))


# ----------------------------------------------------------------------
# state construction
# ----------------------------------------------------------------------
def program_for(
    topo: Topology,
    strategy: AggregationStrategy,
    data_counts: Optional[np.ndarray] = None,
    p_fail: float = 0.0,
    reactive: bool = False,
    resample_random: bool = True,
    **program_kwargs,
):
    """Build ``(program, state)`` for one topology × strategy cell.

    ``state`` is a dict of numpy leaves (stackable over experiments with
    :func:`stack_states`); nominal centrality scores come from
    ``strategies.strategy_scores`` → the networkx values cached on
    ``Topology`` — the *same* scores the numpy path softmaxes, so the two
    paths differ only in dtype (f64 host vs f32 device).

    Note ``p_fail`` has no effect on the ``"fl"`` baseline: FL models an
    idealized fully-connected overlay that P2P link churn does not touch
    (matching the legacy ``dynamic_mixing_matrix`` semantics) — its rows
    in a link-failure grid are churn-invariant by construction.
    """
    if strategy.kind not in PROGRAM_KINDS:
        raise KeyError(
            f"strategy {strategy.kind!r} has no coefficient program; "
            f"supported: {sorted(PROGRAM_KINDS)} "
            f"(others keep the host-side mixing_matrix path)")
    n = topo.n_nodes
    if strategy.kind == "weighted" and data_counts is None:
        raise ValueError("'weighted' strategy needs per-node data_counts")
    counts = (np.ones(n) if data_counts is None
              else np.asarray(data_counts, dtype=np.float64))
    if counts.shape != (n,):
        raise ValueError(f"data_counts shape {counts.shape} != ({n},)")
    scores = np.zeros(n)
    if strategy.kind in CENTRALITY_KINDS:
        scores = strategy_scores(topo, strategy)
    state = {
        "adj": np.asarray(topo.adjacency, np.float32),
        "scores": np.asarray(scores, np.float32),
        "counts": np.asarray(counts, np.float32),
        "tau": np.float32(strategy.tau),
        "kind": np.int32(PROGRAM_KINDS.index(strategy.kind)),
        "seed": np.uint32(strategy.seed),
        "p_fail": np.float32(p_fail),
        "resample": np.int32(bool(resample_random)),
    }
    program = CoeffProgram(n_nodes=n, reactive=bool(reactive),
                           **program_kwargs)
    if program.sparse:
        # per-edge operands for the sparse reactive centrality kernels:
        # nominal neighbour tables (self excluded — the adjacency
        # operand) with the nominal 0/1 edge values; per-round survival
        # multiplies onto nbr_val inside matrix()
        nbr_idx, nbr_mask = topo.neighbor_tables(include_self=False)
        state["nbr_idx"] = np.asarray(nbr_idx, np.int32)
        state["nbr_val"] = np.asarray(nbr_mask, np.float32)
    return program, state


def participation_renormalize(c: jnp.ndarray,
                              active: jnp.ndarray) -> jnp.ndarray:
    """Drop inactive *columns* from a row-stochastic mixing matrix and
    renormalize the surviving rows — the ``stale_mixing=False`` variant
    of partial participation (DESIGN.md §15), where an absent node's
    plane is excluded from its neighbours' averages instead of being
    served stale.

    Rows that lost no mass (none of their support columns were inactive)
    are returned BIT-IDENTICAL — the row-level ``changed`` gate skips the
    renormalizing divide — so an all-active round reproduces the
    synchronous matrix exactly.  Rows whose entire support went inactive
    fall back to self-weight 1 (:func:`strategies.renormalize_rows`);
    inactive rows' results are discarded by the round select anyway.
    """
    col = active.astype(c.dtype).reshape(
        (1,) * (c.ndim - 1) + active.shape)  # explicit: rank_promotion=raise
    masked = c * col
    changed = (masked != c).any(axis=-1, keepdims=True)
    return jnp.where(changed, renormalize_rows(masked, xp=jnp), c)


def quarantine_renormalize(c: jnp.ndarray,
                           quarantined: jnp.ndarray) -> jnp.ndarray:
    """Excise quarantined nodes' *columns* from a row-stochastic mixing
    matrix and renormalize the surviving rows — the coefficient half of
    the self-healing quarantine (DESIGN.md §16,
    ``repro.core.dynamic.FaultSpec``).

    Identical algebra to :func:`participation_renormalize` with
    ``active = ~quarantined`` (a quarantined neighbour's published plane
    is excluded from the averages, exactly like a dropped node under
    ``stale_mixing=False``), including the row-level ``changed`` gate: a
    round with nothing quarantined returns the matrix BIT-identical, so
    enabling the quarantine screen on a clean run cannot perturb it.
    Rows whose entire support is quarantined fall back to self-weight 1.
    """
    return participation_renormalize(c, jnp.logical_not(quarantined))


@dataclasses.dataclass
class ProgramCoeffs:
    """Drop-in replacement for the ``(E, R, n, n)`` slab in
    ``SweepEngine.run``: one shared program + per-experiment states with a
    leading E axis (sharded on E under a mesh, exactly like the slab)."""

    program: CoeffProgram
    states: Any

    @property
    def n_experiments(self) -> int:
        return jax.tree.leaves(self.states)[0].shape[0]


def stack_states(states: Sequence[dict]) -> dict:
    """[state] * E  →  state pytree with leading E axis."""
    return {k: np.stack([np.asarray(s[k]) for s in states])
            for k in states[0]}


def state_nbytes(state) -> int:
    """Host bytes of a state pytree — the memory-table number reported in
    EXPERIMENTS.md and BENCH_sweep.json (vs ``E·R·n²·4`` for a slab)."""
    return int(sum(np.asarray(x).nbytes for x in jax.tree.leaves(state)))
