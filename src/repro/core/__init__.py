"""Core: the paper's contribution — topology-aware decentralized learning.

Topology generators → aggregation strategies → mixing matrices →
(single-device | shard_map-collective) gossip → the Alg. 1 trainer →
knowledge-propagation metrics.
"""
from repro.core.topology import (
    Topology,
    barabasi_albert,
    watts_strogatz,
    stochastic_block,
    ring,
    fully_connected,
    build_topology,
)
from repro.core.strategies import AggregationStrategy, mixing_matrix, STRATEGIES
from repro.core.mixing import (
    mix_dense,
    mix_sparse,
    mix_sparse_host,
    sparse_offsets,
    circulant_decomposition,
    CirculantSchedule,
)
from repro.core.plane import LeafSlot, PlaneLayout
from repro.core.coeffs import (
    CoeffProgram,
    ProgramCoeffs,
    program_for,
    stack_states,
)
from repro.core.decentralized import (
    DecentralizedConfig,
    DecentralizedTrainer,
    coeffs_stack,
    stack_params,
    unstack_params,
)
from repro.core.sweep import SweepEngine, SweepResult
from repro.core.analytics import AnalyticsSpec, analytics_summary
from repro.core.propagation import (
    accuracy_auc,
    arrival_rounds,
    iid_ood_gap,
    propagation_summary,
)
