"""Flat parameter plane: a stacked node-model pytree as ONE (n, P) buffer.

The aggregation step (Eq. 2) contracts every leaf of the stacked pytree
against the same (n, n) mixing matrix.  Doing that leaf-by-leaf issues one
GEMM (or, worse, one kernel family) per leaf; the contraction itself does
not care about leaf boundaries.  :class:`PlaneLayout` erases them: it
records, once per tree structure, where each leaf's ``prod(trailing)``
columns live inside a contiguous ``(n, P)`` plane, so the whole mix
becomes a single ``C @ plane`` — one kernel launch regardless of how many
leaves the model has (DESIGN.md §11).

The layout is *static* metadata (shapes/dtypes/offsets — no arrays), built
from the pytree structure at trace time and therefore baked into the
compiled program: packing/unpacking trace to one concatenate / one slice
set per call, and the same layout is reused by every round of a scan and
every experiment of a vmapped sweep because it is part of the single
traced mix function.

``pack`` casts every leaf to one *plane dtype* (default: the widest leaf
dtype via ``jnp.result_type``; pass ``jnp.bfloat16`` to halve the plane's
HBM footprint) and ``unpack`` restores each leaf's own shape and dtype, so
mixed-precision models round-trip losslessly when the plane dtype covers
them and degrade only by the explicit storage cast when it does not.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["LeafSlot", "PlaneLayout"]


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """One leaf's column range inside the plane (static metadata)."""

    shape: Tuple[int, ...]   # trailing shape (node axis stripped)
    dtype: Any               # the leaf's own dtype (restored by unpack)
    offset: int              # first plane column
    size: int                # prod(shape), ≥ 1 (scalar-per-node leaves)


@dataclasses.dataclass(frozen=True)
class PlaneLayout:
    """Static packing plan for a stacked pytree with leading node axis n.

    Hashable/comparable (treedef + slot tuple), so it can key jit caches;
    contains no array data.
    """

    treedef: Any
    slots: Tuple[LeafSlot, ...]
    n_nodes: int

    @property
    def n_params(self) -> int:
        """P — plane columns (per-node parameter count over all leaves)."""
        return 0 if not self.slots else (self.slots[-1].offset
                                         + self.slots[-1].size)

    def plane_nbytes(self, dtype: Optional[Any] = None) -> int:
        """HBM bytes of one packed ``(n, P)`` plane (``dtype``: storage
        dtype, None → :attr:`widest_dtype`) — the unit of the streaming
        byte models in ``repro.kernels.gossip_mix.mix_modeled_hbm_bytes``
        (a fused mix reads and writes one plane: ``2 × plane_nbytes``)."""
        dtype = self.widest_dtype if dtype is None else jnp.dtype(dtype)
        return self.n_nodes * self.n_params * jnp.dtype(dtype).itemsize

    @property
    def widest_dtype(self):
        """Default plane dtype: ``jnp.result_type`` over the leaf dtypes —
        f32 as soon as any leaf is f32, bf16 for an all-bf16 tree."""
        return jnp.result_type(*[s.dtype for s in self.slots])

    # ------------------------------------------------------------------
    @classmethod
    def from_tree(cls, params) -> "PlaneLayout":
        """Layout for a stacked pytree (every leaf ``(n, ...)``).  Works on
        concrete arrays and on tracers — only shapes/dtypes are read."""
        leaves, treedef = jax.tree.flatten(params)
        if not leaves:
            raise ValueError("PlaneLayout.from_tree: empty pytree")
        n = leaves[0].shape[0]
        slots, offset = [], 0
        for leaf in leaves:
            if leaf.ndim < 1 or leaf.shape[0] != n:
                raise ValueError(
                    f"stacked pytree leaves must share the leading node "
                    f"axis; got shapes {[l.shape for l in leaves]}")
            size = int(np.prod(leaf.shape[1:], dtype=np.int64)) if \
                leaf.ndim > 1 else 1
            slots.append(LeafSlot(tuple(leaf.shape[1:]), jnp.dtype(leaf.dtype),
                                  offset, size))
            offset += size
        return cls(treedef, tuple(slots), n)

    # ------------------------------------------------------------------
    def _check_tree(self, params) -> list:
        """Trace-time structural guard: packing a tree this layout was
        not built from would silently mis-offset every column."""
        leaves, treedef = jax.tree.flatten(params)
        if treedef != self.treedef or any(
                tuple(l.shape) != (self.n_nodes,) + s.shape
                for l, s in zip(leaves, self.slots)):
            raise ValueError(
                f"PlaneLayout mismatch: layout was built for "
                f"{self.treedef} with leaf shapes "
                f"{[(self.n_nodes,) + s.shape for s in self.slots]}, got "
                f"{treedef} with {[tuple(l.shape) for l in leaves]}")
        return leaves

    def pack(self, params, dtype: Optional[Any] = None) -> jnp.ndarray:
        """Stacked pytree → ``(n, P)`` plane (one concatenate).

        ``dtype``: plane storage dtype; None → :attr:`widest_dtype`.
        """
        dtype = self.widest_dtype if dtype is None else jnp.dtype(dtype)
        leaves = self._check_tree(params)
        cols = [jnp.reshape(l, (self.n_nodes, -1)).astype(dtype)
                for l in leaves]
        return cols[0] if len(cols) == 1 else jnp.concatenate(cols, axis=1)

    def pack_row(self, params_one, dtype: Optional[Any] = None) -> jnp.ndarray:
        """SINGLE node's pytree (no leading node axis) → ``(P,)`` row.

        The serving-tier bridge: after a gossip round one node's freshly
        mixed params become its serving weights by writing this row into
        the fleet plane (``plane.at[i].set(row)``) — a data write, not a
        new traced program.
        """
        dtype = self.widest_dtype if dtype is None else jnp.dtype(dtype)
        leaves, treedef = jax.tree.flatten(params_one)
        if treedef != self.treedef or any(
                tuple(l.shape) != s.shape for l, s in zip(leaves, self.slots)):
            raise ValueError(
                f"PlaneLayout.pack_row: layout packs leaf shapes "
                f"{[s.shape for s in self.slots]}, got "
                f"{[tuple(l.shape) for l in leaves]}")
        cols = [jnp.reshape(l, (-1,)).astype(dtype) for l in leaves]
        return cols[0] if len(cols) == 1 else jnp.concatenate(cols)

    def unpack_row(self, row: jnp.ndarray):
        """``(P,)`` row → one node's pytree (inverse of :meth:`pack_row`)."""
        if row.shape[-1] != self.n_params:
            raise ValueError(
                f"PlaneLayout.unpack_row: row has {row.shape[-1]} columns, "
                f"layout packs {self.n_params}")
        leaves = [
            jnp.reshape(row[s.offset:s.offset + s.size], s.shape).astype(s.dtype)
            for s in self.slots
        ]
        return jax.tree.unflatten(self.treedef, leaves)

    def unpack(self, plane: jnp.ndarray):
        """``(n, P)`` plane → stacked pytree, each leaf back in its own
        shape and dtype (the inverse of :meth:`pack` up to the storage
        cast)."""
        if plane.shape[-1] != self.n_params:
            raise ValueError(
                f"PlaneLayout.unpack: plane has {plane.shape[-1]} columns, "
                f"layout packs {self.n_params}")
        leaves = [
            jnp.reshape(plane[:, s.offset:s.offset + s.size],
                        (self.n_nodes,) + s.shape).astype(s.dtype)
            for s in self.slots
        ]
        return jax.tree.unflatten(self.treedef, leaves)
