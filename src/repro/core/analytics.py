"""Streaming propagation analytics — online accumulators inside the round
scan (DESIGN.md §10).

The paper's headline quantities (per-node accuracy-AUC, IID/OOD gap, and
the round at which OOD knowledge *arrives* at each node — Figs. 2/5/6) were
previously computed host-side by ``repro.core.propagation`` from full
``(R, n)`` metric histories, which at sweep scale means materializing an
``(E, R, n)`` device→host slab per metric.  This module computes the same
numbers as **online accumulators threaded through the scan carry**:

* **streaming trapezoid AUC** — the running trapezoid sum
  ``Σ ½·(r_k − r_{k−1})·(a_k + a_{k−1})`` over the eval rounds the
  ``eval_every`` mask keeps, finalized to the span-normalized mean height
  exactly like :func:`repro.core.propagation.per_node_auc`;
* **arrival round at threshold** — the first eval round at which a node's
  accuracy reaches ``arrival_threshold`` (:data:`NO_ARRIVAL` if never),
  matching :func:`repro.core.propagation.arrival_rounds`;
* **IID/OOD gap** — derived from the two AUC accumulators at finalize.

The carry is O(n) per experiment (a handful of ``(n,)`` f32/i32 leaves —
see :meth:`AnalyticsSpec.init`), so ``SweepEngine.run(analytics=...,
keep_history=False)`` returns per-experiment per-node summaries in
O(E·n) memory without ever materializing ``(R, E, n)`` histories.  The
host-side ``propagation.py`` functions remain the *oracle* this path is
equivalence-tested against (tests/test_analytics.py, tests/test_golden.py,
tests/test_sweep_sharded.py — to 1e-6 in all three execution modes).

:class:`AnalyticsSpec` is a frozen (hashable) dataclass so it rides jit /
shard_map as a static argument, exactly like ``coeffs.CoeffProgram``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.propagation import NO_ARRIVAL, arrival_by_hop, hops_from

__all__ = ["AnalyticsSpec", "analytics_summary", "participation_summary",
           "quarantine_summary", "NO_ARRIVAL"]


@dataclasses.dataclass(frozen=True)
class AnalyticsSpec:
    """Static configuration of the in-scan analytics accumulators.

    ``arrival_threshold`` is the accuracy level that counts as "knowledge
    arrived" for the arrival-round metric (applied to both the IID and the
    OOD curve; the paper's propagation figures read the OOD one).
    """

    arrival_threshold: float = 0.5

    # ------------------------------------------------------------------
    # carry layout (DESIGN.md §10): O(n) per experiment
    # ------------------------------------------------------------------
    def init(self, n: int) -> Dict[str, jnp.ndarray]:
        """Fresh accumulator carry for one experiment with n nodes."""
        z = lambda shape, dt=jnp.float32: jnp.zeros(shape, dt)
        return {
            "count": z((), jnp.int32),        # eval observations so far
            "first_round": z(()),             # round of the first eval
            "prev_round": z(()),              # round of the latest eval
            "prev_iid": z((n,)),              # latest per-node accuracies
            "prev_ood": z((n,)),
            "iid_auc_sum": z((n,)),           # running trapezoid sums
            "ood_auc_sum": z((n,)),
            "iid_arrival": jnp.full((n,), NO_ARRIVAL, jnp.int32),
            "ood_arrival": jnp.full((n,), NO_ARRIVAL, jnp.int32),
        }

    def init_batch(self, n_experiments: int, n: int) -> Dict[str, jnp.ndarray]:
        """Carry stacked over the sweep engine's E axis (leaves (E, ...))."""
        one = self.init(n)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_experiments,) + x.shape), one)

    # ------------------------------------------------------------------
    def update(self, carry, round_idx, do_eval, iid, ood):
        """Fold one scan step's eval into the carry.

        ``round_idx`` is the ABSOLUTE round index (chunked execution
        slices absolute indices, so chunk boundaries cannot shift the
        stream); ``do_eval`` gates everything — skipped rounds (their
        iid/ood are zeros from the gated eval) leave the carry untouched.
        """
        r = jnp.asarray(round_idx, jnp.float32)
        r_i = jnp.asarray(round_idx, jnp.int32)
        seen = carry["count"] > 0
        # trapezoid increment needs a predecessor eval round
        w = jnp.where(do_eval & seen, 0.5 * (r - carry["prev_round"]), 0.0)
        sel = lambda new, old: jnp.where(do_eval, new, old)
        arrive = lambda arr, acc: jnp.where(
            do_eval & (arr == NO_ARRIVAL) & (acc >= self.arrival_threshold),
            r_i, arr)
        return {
            "count": carry["count"] + jnp.asarray(do_eval, jnp.int32),
            "first_round": jnp.where(do_eval & ~seen, r,
                                     carry["first_round"]),
            "prev_round": sel(r, carry["prev_round"]),
            "prev_iid": sel(iid, carry["prev_iid"]),
            "prev_ood": sel(ood, carry["prev_ood"]),
            "iid_auc_sum": carry["iid_auc_sum"] + w * (iid + carry["prev_iid"]),
            "ood_auc_sum": carry["ood_auc_sum"] + w * (ood + carry["prev_ood"]),
            "iid_arrival": arrive(carry["iid_arrival"], iid),
            "ood_arrival": arrive(carry["ood_arrival"], ood),
        }

    # ------------------------------------------------------------------
    def finalize(self, carry) -> Dict[str, jnp.ndarray]:
        """Carry → per-node summaries (the O(n) result the engine returns).

        AUC normalization mirrors ``propagation.per_node_auc``: trapezoid
        sum over the eval-round span, i.e. the mean height of the curve; a
        single eval round degenerates to that round's accuracy.
        """
        span = carry["prev_round"] - carry["first_round"]
        denom = jnp.where(span > 0, span, 1.0)
        multi = carry["count"] > 1
        iid_auc = jnp.where(multi, carry["iid_auc_sum"] / denom,
                            carry["prev_iid"])
        ood_auc = jnp.where(multi, carry["ood_auc_sum"] / denom,
                            carry["prev_ood"])
        return {
            "iid_auc": iid_auc,
            "ood_auc": ood_auc,
            "gap_pct": 100.0 * (ood_auc - iid_auc)
            / jnp.maximum(iid_auc, 1e-9),
            "iid_arrival": carry["iid_arrival"],
            "ood_arrival": carry["ood_arrival"],
            "final_iid_acc": carry["prev_iid"],
            "final_ood_acc": carry["prev_ood"],
        }


# ----------------------------------------------------------------------
# host-side digest (benchmark rows, BENCH_sweep.json analytics sections)
# ----------------------------------------------------------------------
def analytics_summary(
    stream: Dict[str, np.ndarray],
    adjacency: Optional[np.ndarray] = None,
    sources: Union[int, Sequence[int], None] = None,
) -> Dict[str, object]:
    """Digest ONE experiment's finalized per-node analytics into the
    figure-level quantities: topology-mean AUCs, the mean-based IID/OOD
    gap (matching ``propagation.iid_ood_gap``), arrival statistics, and —
    given the adjacency plus the OOD source node(s) — mean arrival round
    binned by (multi-source) hop distance.

    Nodes that never reach the threshold report under ``n_no_arrival``
    and are excluded from arrival means (``None`` marks an empty bin).
    """
    iid = float(np.mean(stream["iid_auc"]))
    ood = float(np.mean(stream["ood_auc"]))
    arr = np.asarray(stream["ood_arrival"])
    arrived = arr != NO_ARRIVAL
    out: Dict[str, object] = {
        "iid_auc": iid,
        "ood_auc": ood,
        "iid_ood_gap_pct": 100.0 * (ood - iid) / max(iid, 1e-9),
        "ood_arrival_mean": (float(arr[arrived].mean())
                             if arrived.any() else None),
        "n_no_arrival": int((~arrived).sum()),
    }
    if adjacency is not None and sources is not None:
        out["ood_arrival_by_hop"] = arrival_by_hop(
            arr, hops_from(adjacency, sources))
    return out


def participation_summary(
    part: Dict[str, np.ndarray],
    rounds: int,
    stream: Optional[Dict[str, np.ndarray]] = None,
) -> Dict[str, object]:
    """Digest ONE experiment's participation counters (one row of
    ``SweepResult.participation``, DESIGN.md §15) — realized activity
    rate, staleness statistics, time-skewed local-step totals — and,
    given that experiment's finalized analytics ``stream``, the
    staleness × arrival-round interaction the partial-participation
    preset reports: among nodes the OOD knowledge *reached*, how much
    later it arrives at the nodes that sat out more (Pearson correlation
    plus a median-split of mean arrival by mean staleness).

    Nodes that never arrive are excluded from the interaction (they
    already report under ``n_no_arrival``); degenerate spreads (all
    staleness equal, e.g. rate 1.0) report ``None`` correlations.
    """
    ra = np.asarray(part["rounds_active"], np.float64)
    ms = np.asarray(part["mean_staleness"], np.float64)
    out: Dict[str, object] = {
        "activity_rate": float(ra.mean() / max(rounds, 1)),
        "min_rounds_active": int(ra.min()),
        "mean_staleness": float(ms.mean()),
        "max_final_staleness": int(np.max(part["final_staleness"])),
        "local_steps_total": int(np.sum(part["local_steps"])),
    }
    if stream is None:
        return out
    arr = np.asarray(stream["ood_arrival"], np.float64)
    arrived = arr != NO_ARRIVAL
    out["n_no_arrival"] = int((~arrived).sum())
    corr = None
    if arrived.sum() >= 2:
        x, y = ms[arrived], arr[arrived]
        if x.std() > 0 and y.std() > 0:
            corr = float(np.corrcoef(x, y)[0, 1])
    out["staleness_arrival_corr"] = corr
    med = float(np.median(ms))
    lo = arrived & (ms <= med)
    hi = arrived & (ms > med)
    out["arrival_low_staleness"] = (float(arr[lo].mean())
                                    if lo.any() else None)
    out["arrival_high_staleness"] = (float(arr[hi].mean())
                                     if hi.any() else None)
    return out


def quarantine_summary(
    fault: Dict[str, np.ndarray],
    rounds: int,
) -> Dict[str, object]:
    """Digest ONE experiment's fault/quarantine counters (one row of
    ``SweepResult.fault``, DESIGN.md §16) into the robustness-preset
    quantities:

    * how much corruption actually landed (``n_faulty_nodes``,
      ``fault_round_rate`` — realized per-node-round fault fraction);
    * how the screen responded — mean/max rounds spent quarantined,
      **detection lag** (first quarantine round − first fault round,
      over nodes that were both faulted and caught; ``None`` when the
      quarantine screen is off or nothing was caught),
      ``n_undetected`` (faulted nodes the screen never flagged);
    * **false-positive rate** — the fraction of node-rounds spent
      quarantined among nodes that were NEVER faulty (``None`` when
      every node was faulted at least once).  Probation tails on
      genuinely-faulted nodes are deliberately not counted as false
      positives — holding a caught node out for ``probation`` rounds is
      the screen working as designed.
    """
    fr = np.asarray(fault["fault_rounds"], np.int64)
    rq = np.asarray(fault["rounds_quarantined"], np.int64)
    ff = np.asarray(fault["first_fault"], np.int64)
    fq = np.asarray(fault["first_quar"], np.int64)
    n = fr.shape[0]
    faulted = fr > 0
    out: Dict[str, object] = {
        "n_faulty_nodes": int(faulted.sum()),
        "fault_round_rate": float(fr.sum() / max(rounds * n, 1)),
        "rounds_quarantined_mean": float(rq.mean()),
        "rounds_quarantined_max": int(rq.max()),
    }
    caught = faulted & (fq >= 0) & (ff >= 0)
    out["detection_lag_mean"] = (float((fq - ff)[caught].mean())
                                 if caught.any() else None)
    out["n_undetected"] = int((faulted & (fq < 0)).sum())
    clean = ~faulted
    out["false_positive_rate"] = (
        float(rq[clean].sum() / max(rounds * int(clean.sum()), 1))
        if clean.any() else None)
    return out
