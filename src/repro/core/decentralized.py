"""Decentralized learning runtime — Algorithm 1 of the paper.

All n node-models are held as ONE stacked pytree (leaves ``(n, ...)``).
Each round:

  1. **LocalTrain** (Eq. 1): every node runs E epochs of minibatch SGD/Adam
     on its own data shard — ``vmap`` over the node axis, ``lax.scan`` over
     batches.
  2. **Aggregation** (Eq. 2): the stacked params are contracted against the
     strategy's row-stochastic mixing matrix (dense einsum on a single
     device; ``repro.core.gossip`` collectives under a mesh).

The trainer is model-agnostic: it takes a ``loss_fn(params, batch, rng)``
and an ``Optimizer``.  Evaluation after every round measures each node's
accuracy on the shared ``test_iid`` / ``test_ood`` sets — the accuracy-AUC
across rounds is the paper's knowledge-propagation metric.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mixing import mix_dense
from repro.core.strategies import AggregationStrategy, mixing_matrix
from repro.core.topology import Topology
from repro.training.optimizer import Optimizer, apply_updates

__all__ = [
    "DecentralizedConfig",
    "RoundMetrics",
    "DecentralizedTrainer",
    "stack_params",
    "unstack_params",
]


def stack_params(params_list) -> object:
    """[pytree] * n  →  stacked pytree with leading node axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *params_list)


def unstack_params(stacked, n: int):
    return [jax.tree.map(lambda x: x[i], stacked) for i in range(n)]


@dataclasses.dataclass(frozen=True)
class DecentralizedConfig:
    rounds: int = 40           # R in the paper
    local_epochs: int = 5      # E in the paper
    eval_every: int = 1
    resample_random_each_round: bool = True   # paper's Random baseline redraws
    mix_in_float32: bool = True


@dataclasses.dataclass
class RoundMetrics:
    round: int
    iid_acc: np.ndarray   # (n,) per-node accuracy on test_iid
    ood_acc: np.ndarray   # (n,) per-node accuracy on test_ood
    train_loss: np.ndarray  # (n,)


class DecentralizedTrainer:
    """Runs Alg. 1 over a topology with a pluggable aggregation strategy.

    Args:
      topology: the communication graph.
      strategy: aggregation strategy (mixing-matrix factory).
      optimizer: a ``repro.training.optimizer.Optimizer``.
      loss_fn: ``(params, batch) -> scalar loss``;  batch is whatever the
        data pipeline yields per node per step.
      eval_fn: ``(params, test_batch) -> accuracy`` scalar in [0, 1].
      config: round/epoch counts.
    """

    def __init__(
        self,
        topology: Topology,
        strategy: AggregationStrategy,
        optimizer: Optimizer,
        loss_fn: Callable,
        eval_fn: Callable,
        config: DecentralizedConfig = DecentralizedConfig(),
        data_counts: Optional[np.ndarray] = None,
        coeffs_fn: Optional[Callable[[int], np.ndarray]] = None,
    ):
        self.topology = topology
        self.strategy = strategy
        self.optimizer = optimizer
        self.loss_fn = loss_fn
        self.eval_fn = eval_fn
        self.config = config
        self.data_counts = data_counts
        self.coeffs_fn = coeffs_fn  # e.g. core.dynamic link-failure matrices
        self._train_round = jax.jit(self._train_round_impl)
        self._evaluate = jax.jit(self._evaluate_impl)

    # ------------------------------------------------------------------
    def coeffs_for_round(self, r: int) -> jnp.ndarray:
        """Mixing matrix for round r. Random redraws per round (seed mixes
        in the round index); all other strategies are static unless a
        ``coeffs_fn`` (e.g. time-varying topology) overrides."""
        if self.coeffs_fn is not None:
            return jnp.asarray(self.coeffs_fn(r))
        strat = self.strategy
        if strat.kind == "random" and self.config.resample_random_each_round:
            strat = dataclasses.replace(strat, seed=strat.seed * 100003 + r)
        return jnp.asarray(mixing_matrix(self.topology, strat, self.data_counts))

    # ------------------------------------------------------------------
    def _local_train_node(self, params, opt_state, batches):
        """E epochs over this node's batches: scan over (E*steps,) batches."""

        def step(carry, batch):
            p, s = carry
            loss, grads = jax.value_and_grad(self.loss_fn)(p, batch)
            updates, s = self.optimizer.update(grads, s, p)
            p = apply_updates(p, updates)
            return (p, s), loss

        e = self.config.local_epochs
        # repeat the epoch's batches E times along the scan axis
        rep = jax.tree.map(lambda x: jnp.concatenate([x] * e, axis=0), batches)
        (params, opt_state), losses = jax.lax.scan(step, (params, opt_state), rep)
        return params, opt_state, jnp.mean(losses)

    def _train_round_impl(self, stacked_params, stacked_opt, node_batches, coeffs):
        """One full round: vmapped LocalTrain then aggregation."""
        params, opt, losses = jax.vmap(self._local_train_node)(
            stacked_params, stacked_opt, node_batches
        )
        mixed = mix_dense(params, coeffs)
        return mixed, opt, losses

    def _evaluate_impl(self, stacked_params, test_iid, test_ood):
        iid = jax.vmap(lambda p: self.eval_fn(p, test_iid))(stacked_params)
        ood = jax.vmap(lambda p: self.eval_fn(p, test_ood))(stacked_params)
        return iid, ood

    # ------------------------------------------------------------------
    def run(
        self,
        stacked_params,
        node_batches_fn: Callable[[int], object],
        test_iid,
        test_ood,
    ) -> Tuple[object, List[RoundMetrics]]:
        """Train for R rounds.

        Args:
          stacked_params: pytree with leaves (n, ...).
          node_batches_fn: ``round -> pytree`` of per-node batch stacks with
            leaves (n, steps_per_epoch, batch, ...) — lets the pipeline
            reshuffle per round.
          test_iid / test_ood: shared global test batches.
        """
        n = self.topology.n_nodes
        stacked_opt = jax.vmap(self.optimizer.init)(stacked_params)
        history: List[RoundMetrics] = []

        for r in range(self.config.rounds):
            coeffs = self.coeffs_for_round(r)
            batches = node_batches_fn(r)
            stacked_params, stacked_opt, losses = self._train_round(
                stacked_params, stacked_opt, batches, coeffs
            )
            if (r + 1) % self.config.eval_every == 0 or r == self.config.rounds - 1:
                iid, ood = self._evaluate(stacked_params, test_iid, test_ood)
                history.append(
                    RoundMetrics(
                        round=r,
                        iid_acc=np.asarray(iid),
                        ood_acc=np.asarray(ood),
                        train_loss=np.asarray(losses),
                    )
                )
        return stacked_params, history
