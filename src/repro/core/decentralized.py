"""Decentralized learning runtime — Algorithm 1 of the paper.

All n node-models are held as ONE stacked pytree (leaves ``(n, ...)``).
Each round:

  1. **LocalTrain** (Eq. 1): every node runs E epochs of minibatch SGD/Adam
     on its own data shard — ``vmap`` over the node axis, ``lax.scan`` over
     batches.
  2. **Aggregation** (Eq. 2): the stacked params are contracted against the
     strategy's row-stochastic mixing matrix (dense einsum on a single
     device; ``repro.core.gossip`` collectives under a mesh).

Two execution modes (DESIGN.md §7):

* **scanned** (default): the whole R-round schedule is ONE jitted
  ``lax.scan``.  The per-round mixing matrices are precomputed host-side
  into an ``(R, n, n)`` stack (:func:`coeffs_stack`), so the Random
  baseline's per-round resampling and ``core.dynamic`` link-failure
  matrices become *data* consumed by the scan instead of host-side control
  flow.  Per-round batches are stacked along a leading round axis and
  evaluation runs inside the scan, so metrics come back as ``(R, n)``
  arrays with a single device dispatch for the whole run.
* **unrolled** (``DecentralizedConfig(unroll_eval=True)``): the legacy
  per-round Python loop — one dispatch per round, incremental history.
  Useful for streaming metrics while debugging, and for very long
  schedules where the stacked ``(R, ...)`` batch tensor would not fit in
  host memory.

Both modes produce identical histories — asserted in tests/test_sweep.py.
The vmap-over-experiments axis on top of the scanned mode lives in
``repro.core.sweep``.

The trainer is model-agnostic: it takes a ``loss_fn(params, batch)``
and an ``Optimizer``.  Evaluation after every round measures each node's
accuracy on the shared ``test_iid`` / ``test_ood`` sets — the accuracy-AUC
across rounds is the paper's knowledge-propagation metric.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mixing import mix_dense, mix_sparse, sparse_offsets
from repro.core.strategies import (
    AggregationStrategy,
    mixing_matrix,
    random_round_seed,
)
from repro.core.topology import Topology
from repro.training.optimizer import Optimizer, apply_updates

__all__ = [
    "DecentralizedConfig",
    "RoundMetrics",
    "DecentralizedTrainer",
    "stack_params",
    "unstack_params",
    "round_coeffs",
    "coeffs_stack",
    "make_local_train_fn",
    "make_round_fn",
    "make_participation_round_fn",
    "participation_carry_init",
    "make_fault_round_fn",
    "fault_carry_init",
    "make_mix_fn",
    "mix_impl_budget",
    "edges_schedule",
    "make_scan_fn",
    "eval_round_indices",
]


def stack_params(params_list) -> object:
    """[pytree] * n  →  stacked pytree with leading node axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *params_list)


def unstack_params(stacked, n: int):
    return [jax.tree.map(lambda x: x[i], stacked) for i in range(n)]


@dataclasses.dataclass(frozen=True)
class DecentralizedConfig:
    rounds: int = 40           # R in the paper
    local_epochs: int = 5      # E in the paper
    eval_every: int = 1
    resample_random_each_round: bool = True   # paper's Random baseline redraws
    # True (default): Eq. (2) accumulates in f32 whatever the param dtype
    # (bf16 aggregation in low precision loses exactly the small OOD
    # deltas the paper studies).  False: accumulate in the native param /
    # plane dtype — the low-precision-aggregation ablation.  Routed to
    # every mixing backend via make_round_fn → make_mix_fn.
    mix_in_float32: bool = True
    unroll_eval: bool = False  # True → legacy per-round Python loop
    # "einsum" | "pallas" (fused flat-plane kernel, kernels.gossip_mix:
    # one pallas_call per mix — DESIGN.md §11) | "sparse" (circulant
    # ring-offset schedule from the topology support; dense fallback for
    # supports that don't decompose compactly — see make_mix_fn) |
    # "edges" (padded edge-list segment kernel over the flat plane,
    # kernels.gossip_mix.mix_edges_pallas — O(n·dmax) table bytes per
    # plane tile instead of n², any support, no fallback — DESIGN.md §12)
    mix_impl: str = "einsum"
    # mix_impl="sparse" fallback slack: dense fallback when the non-self
    # ring-offset count exceeds max degree + sparse_slack (see
    # make_mix_fn / sparse_schedule).
    sparse_slack: int = 4
    # Robust aggregation (DESIGN.md §16): "mean" (default — the paper's
    # Eq. (2), untouched callables so degenerate configs stay
    # bit-identical) | "trimmed" (coordinate-wise trimmed mean over
    # neighbour rows, robust_trim extremes cut per side) | "median"
    # (coordinate-wise weighted median) | "norm_clip" (scale each
    # neighbour column so its row norm is at most robust_clip × the
    # receiver's own — a pure (n, n) coefficient transform composing
    # with every mix_impl).  "trimmed"/"median" sort per coordinate and
    # are served by mix_impl="einsum" (jnp reference) or "edges"
    # (Pallas kernel) only.
    robust: str = "mean"
    robust_trim: int = 1
    robust_clip: float = 1.0
    # True (default): the pipeline supplies E *distinct* epoch passes per
    # round (``NodeBatcher(local_epochs=E)``) and LocalTrain consumes them
    # as-is — the paper's Eq. (1).  False: legacy behavior — one epoch of
    # batches tiled E times, i.e. the identical batch order replayed every
    # local epoch (kept for the bit-exact equivalence tests).
    epoch_shuffle: bool = True


@dataclasses.dataclass
class RoundMetrics:
    round: int
    iid_acc: np.ndarray   # (n,) per-node accuracy on test_iid
    ood_acc: np.ndarray   # (n,) per-node accuracy on test_ood
    train_loss: np.ndarray  # (n,)


# ----------------------------------------------------------------------
# mixing-matrix schedules: per-round matrices as precomputed data
# ----------------------------------------------------------------------
def round_coeffs(
    topo: Topology,
    strategy: AggregationStrategy,
    round_idx: int,
    data_counts: Optional[np.ndarray] = None,
    coeffs_fn: Optional[Callable[[int], np.ndarray]] = None,
    resample_random: bool = True,
) -> np.ndarray:
    """Mixing matrix for one round.  Random redraws per round (seed mixed
    through :func:`repro.core.strategies.random_round_seed`); all other
    strategies are static unless a ``coeffs_fn`` (e.g. core.dynamic
    link-failure matrices) overrides.

    Program-supported strategies (``repro.core.coeffs.PROGRAM_KINDS``)
    route through the device-side coefficient program — float32, the same
    values the in-scan path generates — so unrolled, scanned, and
    program-driven runs consume identical matrices.  Other kinds
    (metropolis, ``register_strategy`` plugins) keep the host numpy path.
    """
    if coeffs_fn is not None:
        return np.asarray(coeffs_fn(round_idx))
    from repro.core.coeffs import PROGRAM_KINDS, program_for

    if strategy.kind in PROGRAM_KINDS:
        program, state = program_for(topo, strategy,
                                     data_counts=data_counts,
                                     resample_random=resample_random)
        return program.materialize(
            state, round_indices=np.array([round_idx]))[0]
    # host-path guard: unreachable while "random" is program-supported,
    # kept so the fallback stays round-correct if PROGRAM_KINDS shrinks
    if strategy.kind == "random" and resample_random:
        strategy = dataclasses.replace(
            strategy, seed=random_round_seed(strategy.seed, round_idx))
    return mixing_matrix(topo, strategy, data_counts)


def coeffs_stack(
    topo: Topology,
    strategy: AggregationStrategy,
    rounds: int,
    data_counts: Optional[np.ndarray] = None,
    coeffs_fn: Optional[Callable[[int], np.ndarray]] = None,
    resample_random: bool = True,
) -> np.ndarray:
    """(R, n, n) stack of per-round mixing matrices — the scanned trainer's
    data-not-control-flow representation of time-varying aggregation.

    For program-supported strategies this IS
    ``CoeffProgram.materialize(rounds)`` (DESIGN.md §9) — the legacy slab
    API survives as the materialized view of the coefficient program; the
    host numpy loop remains for ``coeffs_fn`` overrides and non-program
    strategies."""
    from repro.core.coeffs import PROGRAM_KINDS, program_for

    if coeffs_fn is None and strategy.kind in PROGRAM_KINDS:
        program, state = program_for(topo, strategy,
                                     data_counts=data_counts,
                                     resample_random=resample_random)
        return program.materialize(state, rounds)
    return np.stack([
        round_coeffs(topo, strategy, r, data_counts, coeffs_fn,
                     resample_random)
        for r in range(rounds)
    ])


# ----------------------------------------------------------------------
# round-step factories (shared by the trainer and repro.core.sweep)
# ----------------------------------------------------------------------
def make_mix_fn(mix_impl: str = "einsum",
                mix_support: Optional[np.ndarray] = None,
                sparse_slack: int = 4,
                mix_in_float32: bool = True,
                robust: str = "mean",
                robust_trim: int = 1,
                robust_clip: float = 1.0) -> Callable:
    """Aggregation backend: XLA einsum (default), the fused flat-plane
    Pallas kernel (``kernels.gossip_mix.mix_plane_pallas`` — the whole
    mix as ONE ``pallas_call``, DESIGN.md §11; interpret-mode on CPU,
    compiled on TPU/GPU), or the circulant ring-offset schedule
    (``mixing.mix_sparse``).

    ``"sparse"`` needs ``mix_support`` — the (n, n) neighbourhood mask
    (adjacency + self-loops) that fixes the static offset set.  When the
    non-self offset count exceeds ``max degree + sparse_slack`` the
    decomposition moves no fewer bytes than a dense all-gather, so this
    falls back to :func:`repro.core.mixing.mix_dense` (unstructured
    supports don't circulant-decompose compactly; rings/WS graphs do).

    ``"edges"`` also needs ``mix_support`` and fixes the padded-ELL
    neighbour tables at trace time instead
    (``repro.core.topology.padded_neighbor_tables`` with the diagonal
    forced in); per-round coefficients are gathered through the tables,
    so any support works — no structural fallback — and the mix runs as
    ONE Pallas segment kernel over the flat parameter plane
    (``kernels.gossip_mix.mix_edges_pallas``).  Like the circulant path,
    weight outside the tables would be silently dropped;
    ``SweepEngine.run`` validates coefficients against the support.

    ``mix_in_float32=False`` switches every backend's accumulation from
    f32 to the native param/plane dtype
    (``DecentralizedConfig.mix_in_float32`` — the low-precision
    aggregation ablation).

    ``robust`` (DESIGN.md §16) selects Byzantine-resilient aggregation:

    * ``"mean"`` (default) — Eq. (2) exactly; this function returns the
      SAME callables it always has, so every degenerate robustness
      config (fault rate 0.0) is bit-identical to the synchronous path.
    * ``"norm_clip"`` — a pure ``(n, n)`` coefficient transform
      (:func:`repro.core.mixing.norm_clip_coeffs`): each neighbour
      column is scaled so its published row norm is at most
      ``robust_clip`` × the receiver's own, then rows renormalize.
      Composes with EVERY ``mix_impl``.
    * ``"trimmed"`` / ``"median"`` — coordinate-wise trimmed mean
      (``robust_trim`` extremes cut per side) / weighted median over
      the padded-ELL neighbour tables.  Needs ``mix_support`` (tables
      fixed at trace time like ``"edges"``); served by
      ``mix_impl="einsum"`` (jnp reference,
      :func:`repro.core.mixing.mix_robust_tables`) or ``"edges"``
      (Pallas sort-network kernel,
      ``kernels.gossip_mix.mix_robust_pallas``) — the two are
      bit-identical (tests/test_robust_mix.py); other impls raise.
    """
    from repro.core.mixing import ROBUST_MODES

    if robust not in ROBUST_MODES:
        raise ValueError(f"unknown robust mode {robust!r}; "
                         f"have {ROBUST_MODES}")
    if robust in ("trimmed", "median"):
        if mix_impl not in ("einsum", "edges"):
            raise ValueError(
                f"robust={robust!r} has no mix_impl={mix_impl!r} path — "
                f"the per-coordinate sort runs over padded neighbour "
                f"tables; use mix_impl='einsum' (jnp reference) or "
                f"'edges' (Pallas kernel)")
        if mix_support is None:
            raise ValueError(
                f"robust={robust!r} needs mix_support (the (n, n) "
                f"neighbourhood mask, adjacency + self-loops) to fix "
                f"the padded-ELL neighbour tables at trace time")
        nbr_idx, nbr_mask = edges_schedule(mix_support)
        idx, msk = jnp.asarray(nbr_idx), jnp.asarray(nbr_mask)
        trim_k = int(robust_trim) if robust == "trimmed" else 0
        if mix_impl == "einsum":
            from repro.core.mixing import mix_robust_tables

            return lambda params, coeffs: mix_robust_tables(
                params, coeffs, idx, msk, robust, trim_k=trim_k,
                mix_in_float32=mix_in_float32)
        from repro.kernels.gossip_mix import mix_robust_pallas

        return lambda params, coeffs: mix_robust_pallas(
            params, coeffs, idx, msk, op=robust, trim_k=trim_k,
            mix_in_float32=mix_in_float32)
    if robust == "norm_clip":
        from repro.core.mixing import norm_clip_coeffs, plane_norms

        base = make_mix_fn(mix_impl, mix_support=mix_support,
                           sparse_slack=sparse_slack,
                           mix_in_float32=mix_in_float32)
        clip = float(robust_clip)

        def clipped_mix(params, coeffs):
            return base(params,
                        norm_clip_coeffs(coeffs, plane_norms(params), clip))

        return clipped_mix
    if mix_impl == "einsum":
        if mix_in_float32:
            return mix_dense
        return functools.partial(mix_dense, mix_in_float32=False)
    if mix_impl == "pallas":
        from repro.kernels.gossip_mix import mix_plane_pallas

        return functools.partial(mix_plane_pallas,
                                 mix_in_float32=mix_in_float32)
    if mix_impl == "sparse":
        if mix_support is None:
            raise ValueError(
                "mix_impl='sparse' needs mix_support (the (n, n) "
                "neighbourhood mask, adjacency + self-loops) to fix the "
                "ring-offset schedule at trace time")
        offsets, _ = sparse_schedule(mix_support, sparse_slack)
        if offsets is None:
            return make_mix_fn("einsum", mix_in_float32=mix_in_float32)
        return lambda params, coeffs: mix_sparse(
            params, coeffs, offsets, mix_in_float32=mix_in_float32)
    if mix_impl == "edges":
        if mix_support is None:
            raise ValueError(
                "mix_impl='edges' needs mix_support (the (n, n) "
                "neighbourhood mask, adjacency + self-loops) to fix the "
                "padded-ELL neighbour tables at trace time")
        from repro.kernels.gossip_mix import mix_edges_pallas

        nbr_idx, nbr_mask = edges_schedule(mix_support)
        idx, msk = jnp.asarray(nbr_idx), jnp.asarray(nbr_mask)
        return lambda params, coeffs: mix_edges_pallas(
            params, coeffs, idx, msk, mix_in_float32=mix_in_float32)
    raise KeyError(f"unknown mix_impl {mix_impl!r}; "
                   f"have 'einsum', 'pallas', 'sparse', 'edges'")


def mix_impl_budget(mix_impl: str, n_leaves: int = 1,
                    mix_support: Optional[np.ndarray] = None,
                    sparse_slack: int = 4,
                    robust: str = "mean") -> dict:
    """The trace-time equation budget a configured mix contributes to one
    round body — ``repro.kernels.gossip_mix.mix_eqn_budget`` with the
    circulant path's dense-fallback decision resolved exactly the way
    :func:`make_mix_fn` resolves it (offset count vs max degree + slack).
    This is the introspectable source of truth for ``repro.analysis``
    fusion-budget rules: when the fallback fires, the *einsum* budget is
    the contract, not the sparse one."""
    from repro.kernels.gossip_mix import mix_eqn_budget

    if mix_impl == "sparse" and mix_support is not None:
        offsets, _ = sparse_schedule(mix_support, sparse_slack)
        if offsets is None:
            return mix_eqn_budget("einsum", n_leaves, robust=robust)
    return mix_eqn_budget(mix_impl, n_leaves, robust=robust)


def sparse_schedule(mix_support, sparse_slack: int = 4):
    """``(offsets, covered)`` for a support mask, or ``(None, None)`` when
    the dense fallback applies (non-self offset count > max degree +
    slack).  ``covered`` is the (n, n) bool mask of positions the ring
    schedule can express — ``SweepEngine.run`` checks coefficients
    against it so off-schedule weight raises instead of being silently
    dropped by ``mix_sparse``."""
    support = np.asarray(mix_support)
    n = support.shape[0]
    offsets = sparse_offsets(support)
    off_diag = support * (1.0 - np.eye(n))
    max_degree = int(off_diag.sum(axis=1).max())
    nonzero_offsets = len(offsets) - (1 if 0 in offsets else 0)
    if nonzero_offsets > max_degree + sparse_slack:
        return None, None
    rows = np.arange(n)
    covered = np.zeros((n, n), bool)
    for k in offsets:
        covered[rows, (rows + k) % n] = True
    return offsets, covered


def edges_schedule(mix_support) -> Tuple[np.ndarray, np.ndarray]:
    """``(nbr_idx, nbr_mask)`` padded-ELL tables for a support mask with
    the diagonal forced in (every node keeps a self-slot, so row-
    stochastic matrices always have somewhere to put their self-weight).
    The edge-list analogue of :func:`sparse_schedule` — static trace-time
    metadata; the coverage mask for ``SweepEngine.run``'s off-support
    check is simply ``support ∪ diag`` (no structural fallback)."""
    support = np.asarray(mix_support)
    n = support.shape[0]
    from repro.core.topology import padded_neighbor_tables

    return padded_neighbor_tables(np.maximum(support, np.eye(n)))


def make_local_train_fn(loss_fn: Callable, optimizer: Optimizer,
                        local_epochs: int,
                        epoch_shuffle: bool = True) -> Callable:
    """LocalTrain (Eq. 1) for ONE node: E epochs over its batches as a
    ``lax.scan`` over the (E·steps,) batch axis.

    ``epoch_shuffle=True``: the incoming batches already carry all E
    epochs on the leading axis (each a distinct shuffle —
    ``NodeBatcher(local_epochs=E)``) and are consumed as-is.
    ``epoch_shuffle=False`` (legacy): one epoch of batches is tiled E
    times, replaying the identical order every epoch.
    """

    def local_train(params, opt_state, batches):
        def step(carry, batch):
            p, s = carry
            loss, grads = jax.value_and_grad(loss_fn)(p, batch)
            updates, s = optimizer.update(grads, s, p)
            p = apply_updates(p, updates)
            return (p, s), loss

        if epoch_shuffle:
            total = jax.tree.leaves(batches)[0].shape[0]
            if total % local_epochs:
                raise ValueError(
                    f"epoch_shuffle=True expects the pipeline to supply "
                    f"local_epochs={local_epochs} distinct epoch passes "
                    f"(NodeBatcher(local_epochs=...)), but the {total}-step "
                    f"batch axis is not divisible by {local_epochs}")
            rep = batches
        else:
            # legacy: repeat the epoch's batches E times along the scan axis
            rep = jax.tree.map(
                lambda x: jnp.concatenate([x] * local_epochs, axis=0),
                batches)
        (params, opt_state), losses = jax.lax.scan(
            step, (params, opt_state), rep)
        return params, opt_state, jnp.mean(losses)

    return local_train


def make_round_fn(loss_fn: Callable, optimizer: Optimizer, local_epochs: int,
                  mix_impl: str = "einsum",
                  epoch_shuffle: bool = True,
                  mix_support: Optional[np.ndarray] = None,
                  sparse_slack: int = 4,
                  mix_in_float32: bool = True,
                  robust: str = "mean",
                  robust_trim: int = 1,
                  robust_clip: float = 1.0) -> Callable:
    """One full round — vmapped LocalTrain then aggregation — as a pure
    function ``(stacked_params, stacked_opt, node_batches, coeffs) →
    (mixed_params, opt, losses)``.  ``mix_support`` is consulted by
    ``mix_impl='sparse'`` and ``'edges'`` (``sparse_slack`` by the former
    only); ``mix_in_float32``
    selects every backend's accumulation dtype (see
    :func:`make_mix_fn`)."""
    local_train = make_local_train_fn(loss_fn, optimizer, local_epochs,
                                      epoch_shuffle)
    mix = make_mix_fn(mix_impl, mix_support=mix_support,
                      sparse_slack=sparse_slack,
                      mix_in_float32=mix_in_float32,
                      robust=robust, robust_trim=robust_trim,
                      robust_clip=robust_clip)

    def round_fn(stacked_params, stacked_opt, node_batches, coeffs):
        params, opt, losses = jax.vmap(local_train)(
            stacked_params, stacked_opt, node_batches)
        return mix(params, coeffs), opt, losses

    return round_fn


def participation_carry_init(params, rate, pseed) -> dict:
    """Per-experiment participation carry (the traced half of
    :class:`repro.core.dynamic.ParticipationSpec`, DESIGN.md §15):

    * ``rate`` / ``pseed`` — the per-experiment activation rate and PRNG
      seed (carried, not static, so one compiled program serves a whole
      rate grid and both shard on the experiment axis);
    * ``pub`` — the *published* plane: each node's row as last seen by
      its neighbours.  A COPY of the initial stacked params (the engines
      donate the params argument, so aliasing it here would hand XLA the
      same buffer twice);
    * ``staleness`` — rounds since each node last participated (0 right
      after an active round);
    * ``staleness_sum`` — Σ over rounds of post-round staleness (host
      side divides by R for the mean);
    * ``rounds_active`` / ``local_steps`` — participation and
      time-skewed local-step counts per node.
    """
    n = jax.tree.leaves(params)[0].shape[0]
    zeros = jnp.zeros((n,), jnp.int32)
    return {
        "rate": jnp.asarray(rate, jnp.float32),
        "pseed": jnp.asarray(pseed, jnp.uint32),
        "pub": jax.tree.map(lambda x: jnp.asarray(x).copy(), params),
        "staleness": zeros,
        "staleness_sum": zeros,
        "rounds_active": zeros,
        "local_steps": zeros,
    }


def make_participation_round_fn(loss_fn: Callable, optimizer: Optimizer,
                                local_epochs: int,
                                participation,
                                mix_impl: str = "einsum",
                                epoch_shuffle: bool = True,
                                mix_support: Optional[np.ndarray] = None,
                                sparse_slack: int = 4,
                                mix_in_float32: bool = True,
                                robust: str = "mean",
                                robust_trim: int = 1,
                                robust_clip: float = 1.0) -> Callable:
    """Partial-participation round (DESIGN.md §15): ``(stacked_params,
    stacked_opt, pcarry, node_batches, coeffs, round_idx) → (params, opt,
    pcarry, losses)``.

    Per round: draw the active set from ``participation`` (a
    ``repro.core.dynamic.ParticipationSpec``), run LocalTrain on every
    node (the scan needs fixed shapes; inactive results are discarded by
    an elementwise select on the plane row), publish active nodes' fresh
    post-train rows into the stale plane ``pcarry["pub"]``, mix the
    published plane (so active nodes gossip against each neighbour's
    LAST published row — stale if that neighbour sat out), and select:
    active rows take the mixed result + fresh optimizer state, inactive
    rows keep params/opt/published row untouched.  Inactive losses
    report 0 (same convention as skipped evals).

    Because ``jnp.where`` with an all-true mask is elementwise-exact and
    ``rate=1.0`` activates every node exactly (see
    ``ParticipationSpec.active_mask``), a participation-1.0 run is
    BIT-IDENTICAL to :func:`make_round_fn`'s synchronous round under
    every mixing backend — the equivalence tests in
    tests/test_participation.py hold to ``==``, not allclose.
    """
    local_train = make_local_train_fn(loss_fn, optimizer, local_epochs,
                                      epoch_shuffle)
    mix = make_mix_fn(mix_impl, mix_support=mix_support,
                      sparse_slack=sparse_slack,
                      mix_in_float32=mix_in_float32,
                      robust=robust, robust_trim=robust_trim,
                      robust_clip=robust_clip)
    from repro.core.coeffs import participation_renormalize  # no cycle

    def select(active, new, old):
        # explicit reshape: rank-promoting broadcasts are disabled
        # repo-wide (jax_numpy_rank_promotion="raise")
        def sel(a, b):
            return jnp.where(
                active.reshape(active.shape + (1,) * (a.ndim - 1)), a, b)
        return jax.tree.map(sel, new, old)

    def round_fn(stacked_params, stacked_opt, pcarry, node_batches,
                 coeffs, round_idx):
        n = jax.tree.leaves(stacked_params)[0].shape[0]
        steps = jax.tree.leaves(node_batches)[0].shape[1]
        active = participation.active_mask(
            pcarry["rate"], pcarry["pseed"], round_idx, n)
        trained, opt_t, losses = jax.vmap(local_train)(
            stacked_params, stacked_opt, node_batches)
        pub = select(active, trained, pcarry["pub"])
        if not participation.stale_mixing:
            coeffs = participation_renormalize(coeffs, active)
        mixed = mix(pub, coeffs)
        params = select(active, mixed, stacked_params)
        opt = select(active, opt_t, stacked_opt)
        losses = jnp.where(active, losses, jnp.zeros((), losses.dtype))
        act = active.astype(jnp.int32)
        staleness = jnp.where(active, 0, pcarry["staleness"] + 1)
        pcarry = {
            **pcarry,
            "pub": pub,
            "staleness": staleness,
            "staleness_sum": pcarry["staleness_sum"] + staleness,
            "rounds_active": pcarry["rounds_active"] + act,
            "local_steps": pcarry["local_steps"] + act * steps,
        }
        return params, opt, pcarry, losses

    return round_fn


def fault_carry_init(params, rate, fseed) -> dict:
    """Per-experiment fault/quarantine carry (the traced half of
    :class:`repro.core.dynamic.FaultSpec`, DESIGN.md §16):

    * ``rate`` / ``fseed`` — the per-experiment fault rate and PRNG seed
      (carried, not static, so one compiled program serves a whole
      fault-rate grid and both shard on the experiment axis);
    * ``qtimer`` — probation countdown per node; a node is quarantined
      while ``qtimer > 0`` (re-flagging resets it to
      ``FaultSpec.probation``, healthy rounds decrement it);
    * ``norm_ema`` — EMA of each node's published row norm, the
      baseline for the spike screen.  0.0 means "not yet seeded";
      updated only on rounds the node passes the screen, so a
      quarantined node's garbage never drags its own baseline;
    * ``rounds_quarantined`` / ``fault_rounds`` /
      ``quar_fault_rounds`` — per-node counts of quarantined rounds,
      actually-faulty rounds, and rounds both at once (host side turns
      these into false-positive rates);
    * ``first_fault`` / ``first_quar`` — first round each node was
      faulty / quarantined (−1 sentinel = never); their difference is
      the detection lag.
    """
    n = jax.tree.leaves(params)[0].shape[0]
    zeros = jnp.zeros((n,), jnp.int32)
    return {
        "rate": jnp.asarray(rate, jnp.float32),
        "fseed": jnp.asarray(fseed, jnp.uint32),
        "qtimer": zeros,
        "norm_ema": jnp.zeros((n,), jnp.float32),
        "rounds_quarantined": zeros,
        "fault_rounds": zeros,
        "quar_fault_rounds": zeros,
        "first_fault": jnp.full((n,), -1, jnp.int32),
        "first_quar": jnp.full((n,), -1, jnp.int32),
    }


def make_fault_round_fn(loss_fn: Callable, optimizer: Optimizer,
                        local_epochs: int,
                        fault,
                        participation=None,
                        mix_impl: str = "einsum",
                        epoch_shuffle: bool = True,
                        mix_support: Optional[np.ndarray] = None,
                        sparse_slack: int = 4,
                        mix_in_float32: bool = True,
                        robust: str = "mean",
                        robust_trim: int = 1,
                        robust_clip: float = 1.0) -> Callable:
    """Byzantine-fault round (DESIGN.md §16).  Signature without
    participation: ``(stacked_params, stacked_opt, fcarry, node_batches,
    coeffs, round_idx) → (params, opt, fcarry, losses)``; with a
    ``ParticipationSpec`` the participation carry slots in before the
    fault carry on both sides.

    Per round: LocalTrain every node, publish (through the PR 9 stale
    plane when ``participation`` is set), then draw the faulty set from
    ``fault`` (a :class:`repro.core.dynamic.FaultSpec`, PRNG fold index
    3) and overwrite faulty nodes' PUBLISHED rows with
    ``FaultSpec.corrupt`` garbage — neighbours gossip against the
    corruption while the faulty node's own params follow local
    semantics (it keeps its honest locally-trained state, exactly like
    a node whose outbound link is compromised but whose replica is
    fine).  With ``fault.quarantine`` the in-scan health screen runs on
    the published plane: a row is flagged when it contains nonfinite
    values or its norm exceeds ``spike_ratio`` × that node's healthy
    EMA; flagged rows start a ``probation``-round quarantine during
    which their column is excised from the mixing matrix
    (:func:`repro.core.coeffs.quarantine_renormalize`), their plane row
    is zero-substituted (so ``0 × NaN`` cannot poison the dense
    contraction), and the quarantined node itself keeps training
    locally — self-healing: after probation it rejoins automatically.

    ``rate=0.0`` draws an exactly-empty faulty set (uniform < 0.0) and
    every select collapses bitwise, so a zero-fault run is
    BIT-IDENTICAL to :func:`make_round_fn` /
    :func:`make_participation_round_fn` under every mixing backend —
    tests/test_fault.py holds this to ``==``.

    Note: with ``robust="mean"`` and no quarantine, a NaN/Inf fault
    poisons every destination of the dense contraction (``0 × NaN =
    NaN``), not just graph neighbours — that IS the failure mode the
    robust aggregators and the quarantine screen exist to contain.
    """
    local_train = make_local_train_fn(loss_fn, optimizer, local_epochs,
                                      epoch_shuffle)
    mix = make_mix_fn(mix_impl, mix_support=mix_support,
                      sparse_slack=sparse_slack,
                      mix_in_float32=mix_in_float32,
                      robust=robust, robust_trim=robust_trim,
                      robust_clip=robust_clip)
    from repro.core.coeffs import (  # no cycle
        participation_renormalize,
        quarantine_renormalize,
    )
    from repro.core.mixing import plane_norms

    def select(mask, new, old):
        # explicit reshape: rank-promoting broadcasts are disabled
        # repo-wide (jax_numpy_rank_promotion="raise")
        def sel(a, b):
            return jnp.where(
                mask.reshape(mask.shape + (1,) * (a.ndim - 1)), a, b)
        return jax.tree.map(sel, new, old)

    def row_nonfinite(plane, n):
        cnt = jnp.zeros((n,), jnp.int32)
        for leaf in jax.tree.leaves(plane):
            flat = leaf.reshape((n, -1))
            cnt = cnt + jnp.sum(~jnp.isfinite(flat), axis=1,
                                dtype=jnp.int32)
        return cnt

    def round_fn(stacked_params, stacked_opt, *state_and_xs):
        if participation is not None:
            pcarry, fcarry, node_batches, coeffs, round_idx = state_and_xs
        else:
            pcarry = None
            fcarry, node_batches, coeffs, round_idx = state_and_xs
        n = jax.tree.leaves(stacked_params)[0].shape[0]
        trained, opt_t, losses = jax.vmap(local_train)(
            stacked_params, stacked_opt, node_batches)
        if participation is not None:
            steps = jax.tree.leaves(node_batches)[0].shape[1]
            active = participation.active_mask(
                pcarry["rate"], pcarry["pseed"], round_idx, n)
            pub = select(active, trained, pcarry["pub"])
            if not participation.stale_mixing:
                coeffs = participation_renormalize(coeffs, active)
        else:
            pub = trained
        faulty = fault.faulty_mask(fcarry["rate"], fcarry["fseed"],
                                   round_idx, n)
        # the corruption lands on the PUBLISHED plane (and persists in
        # pcarry["pub"] until the node republishes — garbage stays
        # visible to neighbours exactly as long as a stale row would)
        pub = select(faulty, fault.corrupt(pub, fcarry["fseed"], round_idx),
                     pub)
        fcarry = dict(fcarry)
        fint = faulty.astype(jnp.int32)
        r32 = jnp.asarray(round_idx, jnp.int32)
        fcarry["fault_rounds"] = fcarry["fault_rounds"] + fint
        fcarry["first_fault"] = jnp.where(
            (fcarry["first_fault"] < 0) & faulty, r32,
            fcarry["first_fault"])
        if fault.quarantine:
            norms = plane_norms(pub)
            ema = fcarry["norm_ema"]
            suspicious = ((row_nonfinite(pub, n) > 0)
                          | ~jnp.isfinite(norms)
                          | ((ema > 0.0) & (norms > fault.spike_ratio * ema)))
            qtimer = jnp.where(suspicious, fault.probation,
                               jnp.maximum(fcarry["qtimer"] - 1, 0))
            quarantined = qtimer > 0
            # EMA advances only on rounds the node passes the screen —
            # a quarantined node's garbage never drags its baseline
            healthy = jnp.where(
                ema > 0.0,
                fault.ema_beta * ema + (1.0 - fault.ema_beta) * norms,
                norms)
            qint = quarantined.astype(jnp.int32)
            fcarry["norm_ema"] = jnp.where(suspicious, ema, healthy)
            fcarry["qtimer"] = qtimer
            fcarry["rounds_quarantined"] = (
                fcarry["rounds_quarantined"] + qint)
            fcarry["quar_fault_rounds"] = (
                fcarry["quar_fault_rounds"] + qint * fint)
            fcarry["first_quar"] = jnp.where(
                (fcarry["first_quar"] < 0) & quarantined, r32,
                fcarry["first_quar"])
            coeffs = quarantine_renormalize(coeffs, quarantined)
            # zero-substitute quarantined rows BEFORE the contraction:
            # an excised column still participates in dense tensordot
            # and 0 × NaN = NaN would re-poison every destination
            pub_mix = select(quarantined,
                             jax.tree.map(jnp.zeros_like, pub), pub)
            keep_local = faulty | quarantined
        else:
            pub_mix = pub
            keep_local = faulty
        mixed = mix(pub_mix, coeffs)
        params = select(keep_local, trained, mixed)
        opt = opt_t
        if participation is not None:
            params = select(active, params, stacked_params)
            opt = select(active, opt_t, stacked_opt)
            losses = jnp.where(active, losses, jnp.zeros((), losses.dtype))
            act = active.astype(jnp.int32)
            staleness = jnp.where(active, 0, pcarry["staleness"] + 1)
            pcarry = {
                **pcarry,
                "pub": pub,
                "staleness": staleness,
                "staleness_sum": pcarry["staleness_sum"] + staleness,
                "rounds_active": pcarry["rounds_active"] + act,
                "local_steps": pcarry["local_steps"] + act * steps,
            }
            return params, opt, pcarry, fcarry, losses
        return params, opt, fcarry, losses

    return round_fn


def make_scan_fn(round_fn: Callable, evaluate: Callable,
                 make_batch: Optional[Callable] = None,
                 coeff_fn: Optional[Callable] = None,
                 analytics=None,
                 keep_history: bool = True,
                 participation=None,
                 fault=None) -> Callable:
    """Scan-over-rounds factory shared by ``DecentralizedTrainer`` (stacked
    batches) and ``repro.core.sweep`` (per-round index gather).

    ``round_fn``: :func:`make_round_fn` output; ``evaluate``:
    ``(stacked_params, test_iid, test_ood) → (iid, ood)``;  ``make_batch``
    maps the per-round scan slice to node batches (identity for
    pre-stacked batches, a bank gather for the sweep engine).

    ``coeff_fn`` switches the mixing-matrix source from *data* to
    *program* (DESIGN.md §9): when set, the ``coeffs`` argument carries
    absolute int32 round indices ``(R,)`` instead of an ``(R, n, n)``
    slab, and each scan step computes its matrix in-scan as
    ``coeff_fn(round_idx)`` — e.g. ``lambda r:
    CoeffProgram.matrix(state, r)`` — so per-round matrices (Random
    resampling, reactive link failure) never materialize on the host.

    ``analytics`` (a ``repro.core.analytics.AnalyticsSpec``) grows the
    scan carry by the streaming-analytics accumulators (DESIGN.md §10):
    every eval round is folded into O(n) online state (running trapezoid
    AUC, arrival rounds) instead of — or in addition to — the stacked
    ``(R, n)`` metric outputs.  The scan then consumes two extra inputs:
    ``round_idx`` (the ``(R,)`` ABSOLUTE round indices, so chunked
    execution cannot shift the stream) and ``analytics_carry`` (from
    ``AnalyticsSpec.init``, threaded back out for chunk chaining).
    ``keep_history=False`` (requires ``analytics``) drops the per-round
    ys entirely — the scan's memory footprint for metrics becomes O(n).

    ``participation`` (a ``repro.core.dynamic.ParticipationSpec``)
    switches ``round_fn`` to the extended
    :func:`make_participation_round_fn` signature and grows the carry by
    the participation state (``participation_carry`` ←
    :func:`participation_carry_init`, threaded back out for chunk
    chaining like the analytics carry); the scan then also consumes the
    ``round_idx`` absolute-round input (the active-set draw folds it).

    ``fault`` (a ``repro.core.dynamic.FaultSpec``) switches ``round_fn``
    to the :func:`make_fault_round_fn` signature and grows the carry by
    the fault/quarantine state (``fault_carry`` ←
    :func:`fault_carry_init`, threaded back out for chunk chaining);
    like participation, the fault draw folds the absolute round index
    so chunked execution cannot shift the corruption schedule.

    Returns ``scan_fn(params, opt, batch_xs, coeffs, eval_mask, test_iid,
    test_ood[, round_idx, analytics_carry, participation_carry,
    fault_carry])`` → ``(params, opt[, participation_carry]
    [, fault_carry][, analytics_carry][, losses, iid, ood])`` — the
    participation carry slots in before the fault carry, which slots in
    before the analytics carry; the per-round history tail is present
    unless ``keep_history=False``, and the
    no-analytics/no-participation/no-fault order is unchanged from the
    original ``(params, opt, losses, iid, ood)``.

    The carries come back out so callers can chain round-chunks (chunked
    mode donates them back in, keeping device accumulators bounded at one
    chunk).  ``eval_mask`` gates eval to the rounds ``eval_every`` keeps;
    skipped rounds report zeros (and leave the analytics carry untouched).
    Eval ALWAYS covers every node — an inactive node's frozen model is
    still a model the arrival analytics must see.
    """
    if make_batch is None:
        make_batch = lambda b: b
    if not keep_history and analytics is None:
        raise ValueError("keep_history=False without an analytics spec "
                         "would return no metrics at all")
    needs_rounds = (analytics is not None or participation is not None
                    or fault is not None)

    def scan_fn(params, opt, batch_xs, coeffs, eval_mask, test_iid,
                test_ood, round_idx=None, analytics_carry=None,
                participation_carry=None, fault_carry=None):
        n = jax.tree.leaves(params)[0].shape[0]

        def body(carry, xs):
            carry = list(carry)
            p, o = carry[0], carry[1]
            slot = 2
            pc = fc = None
            if participation is not None:
                pc = carry[slot]
                slot += 1
            if fault is not None:
                fc = carry[slot]
                slot += 1
            ac = carry[-1] if analytics is not None else None
            if needs_rounds:
                bx, c, do_eval, r_abs = xs
            else:
                bx, c, do_eval = xs
            if coeff_fn is not None:
                c = coeff_fn(c)  # c is this step's absolute round index
            if fault is not None:
                if participation is not None:
                    p, o, pc, fc, losses = round_fn(
                        p, o, pc, fc, make_batch(bx), c, r_abs)
                else:
                    p, o, fc, losses = round_fn(
                        p, o, fc, make_batch(bx), c, r_abs)
            elif participation is None:
                p, o, losses = round_fn(p, o, make_batch(bx), c)
            else:
                p, o, pc, losses = round_fn(p, o, pc, make_batch(bx), c,
                                            r_abs)
            iid, ood = jax.lax.cond(
                do_eval,
                lambda q: evaluate(q, test_iid, test_ood),
                lambda q: (jnp.zeros((n,)), jnp.zeros((n,))),
                p)
            out = [p, o]
            if participation is not None:
                out.append(pc)
            if fault is not None:
                out.append(fc)
            if analytics is not None:
                out.append(analytics.update(ac, r_abs, do_eval, iid, ood))
            ys = ((losses, iid, ood)
                  if (keep_history or analytics is None) else None)
            return tuple(out), ys

        carry0 = [params, opt]
        if participation is not None:
            carry0.append(participation_carry)
        if fault is not None:
            carry0.append(fault_carry)
        if analytics is not None:
            carry0.append(analytics_carry)
        xs = ((batch_xs, coeffs, eval_mask, round_idx) if needs_rounds
              else (batch_xs, coeffs, eval_mask))
        final, ys = jax.lax.scan(body, tuple(carry0), xs)
        out = list(final)
        if ys is not None:
            out.extend(ys)   # losses, iid, ood
        return tuple(out)

    return scan_fn


def eval_round_indices(rounds: int, eval_every: int) -> List[int]:
    """Rounds at which the legacy loop recorded metrics (kept identical so
    scanned histories line up bit-for-bit with unrolled ones)."""
    return [r for r in range(rounds)
            if (r + 1) % eval_every == 0 or r == rounds - 1]


class DecentralizedTrainer:
    """Runs Alg. 1 over a topology with a pluggable aggregation strategy.

    Args:
      topology: the communication graph.
      strategy: aggregation strategy (mixing-matrix factory).
      optimizer: a ``repro.training.optimizer.Optimizer``.
      loss_fn: ``(params, batch) -> scalar loss``;  batch is whatever the
        data pipeline yields per node per step.
      eval_fn: ``(params, test_batch) -> accuracy`` scalar in [0, 1].
      config: round/epoch counts + execution mode (scanned vs unrolled).
    """

    def __init__(
        self,
        topology: Topology,
        strategy: AggregationStrategy,
        optimizer: Optimizer,
        loss_fn: Callable,
        eval_fn: Callable,
        config: DecentralizedConfig = DecentralizedConfig(),
        data_counts: Optional[np.ndarray] = None,
        coeffs_fn: Optional[Callable[[int], np.ndarray]] = None,
    ):
        self.topology = topology
        self.strategy = strategy
        self.optimizer = optimizer
        self.loss_fn = loss_fn
        self.eval_fn = eval_fn
        self.config = config
        self.data_counts = data_counts
        self.coeffs_fn = coeffs_fn  # e.g. core.dynamic link-failure matrices
        mix_support = None
        if (config.mix_impl in ("sparse", "edges")
                or config.robust in ("trimmed", "median")):
            # support = neighbourhoods ∪ the strategy's actual round-0
            # support: kinds with off-neighbourhood weight (fl's dense
            # 1/n, register_strategy plugins, coeffs_fn overrides) would
            # otherwise have mass silently dropped by the static schedule
            # (sub-stochastic mixing).  Built-in supports never grow
            # across rounds; exotic coeffs_fn schedules that do should
            # use mix_impl="einsum".
            n = topology.n_nodes
            m0 = round_coeffs(topology, strategy, 0, data_counts,
                              coeffs_fn, config.resample_random_each_round)
            mix_support = np.maximum(
                topology.adjacency + np.eye(n),
                (np.abs(np.asarray(m0)) > 1e-12).astype(np.float64))
        self._round_fn = make_round_fn(
            loss_fn, optimizer, config.local_epochs, config.mix_impl,
            config.epoch_shuffle, mix_support=mix_support,
            sparse_slack=config.sparse_slack,
            mix_in_float32=config.mix_in_float32,
            robust=config.robust, robust_trim=config.robust_trim,
            robust_clip=config.robust_clip)
        self._train_round = jax.jit(self._round_fn)
        self._evaluate = jax.jit(self._evaluate_impl)
        self._scan_fn = make_scan_fn(self._round_fn, self._evaluate_impl)
        self._run_scan = jax.jit(self._run_scan_impl)

    # ------------------------------------------------------------------
    def coeffs_for_round(self, r: int) -> jnp.ndarray:
        """Mixing matrix for round r (see :func:`round_coeffs`)."""
        return jnp.asarray(round_coeffs(
            self.topology, self.strategy, r, self.data_counts,
            self.coeffs_fn, self.config.resample_random_each_round))

    def coeffs_stack(self, rounds: Optional[int] = None) -> np.ndarray:
        """(R, n, n) stack of this run's per-round mixing matrices."""
        return coeffs_stack(
            self.topology, self.strategy,
            self.config.rounds if rounds is None else rounds,
            self.data_counts, self.coeffs_fn,
            self.config.resample_random_each_round)

    # ------------------------------------------------------------------
    def _evaluate_impl(self, stacked_params, test_iid, test_ood):
        iid = jax.vmap(lambda p: self.eval_fn(p, test_iid))(stacked_params)
        ood = jax.vmap(lambda p: self.eval_fn(p, test_ood))(stacked_params)
        return iid, ood

    def _run_scan_impl(self, stacked_params, stacked_opt, batches, coeffs,
                       eval_mask, test_iid, test_ood):
        """All R rounds as one ``lax.scan`` (:func:`make_scan_fn`);
        batches/coeffs carry a leading (R,) axis; eval is folded into the
        scan body so metrics come back stacked as (R, n).  ``eval_mask``
        gates the eval forward passes to the rounds the history actually
        keeps (``eval_every``); skipped rounds report zeros and are
        dropped before building the history."""
        return self._scan_fn(stacked_params, stacked_opt, batches, coeffs,
                             eval_mask, test_iid, test_ood)

    # ------------------------------------------------------------------
    def run(
        self,
        stacked_params,
        node_batches_fn: Callable[[int], object],
        test_iid,
        test_ood,
    ) -> Tuple[object, List[RoundMetrics]]:
        """Train for R rounds.

        Args:
          stacked_params: pytree with leaves (n, ...).
          node_batches_fn: ``round -> pytree`` of per-node batch stacks with
            leaves (n, steps_per_epoch, batch, ...) — lets the pipeline
            reshuffle per round.
          test_iid / test_ood: shared global test batches.

        Scanned mode stacks all R rounds of batches on the leading axis
        (host memory ≈ R × one round of batches); set
        ``config.unroll_eval=True`` to stream rounds instead.
        """
        if self.config.unroll_eval:
            return self.run_unrolled(
                stacked_params, node_batches_fn, test_iid, test_ood)

        rounds = self.config.rounds
        coeffs = jnp.asarray(self.coeffs_stack())
        batches = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[node_batches_fn(r) for r in range(rounds)])
        eval_mask = np.zeros(rounds, bool)
        eval_mask[eval_round_indices(rounds, self.config.eval_every)] = True
        stacked_opt = jax.vmap(self.optimizer.init)(stacked_params)
        stacked_params, _, losses, iid, ood = self._run_scan(
            stacked_params, stacked_opt, batches, coeffs,
            jnp.asarray(eval_mask), test_iid, test_ood)
        losses, iid, ood = (np.asarray(losses), np.asarray(iid),
                            np.asarray(ood))
        history = [
            RoundMetrics(round=r, iid_acc=iid[r], ood_acc=ood[r],
                         train_loss=losses[r])
            for r in eval_round_indices(rounds, self.config.eval_every)
        ]
        return stacked_params, history

    def run_unrolled(
        self,
        stacked_params,
        node_batches_fn: Callable[[int], object],
        test_iid,
        test_ood,
    ) -> Tuple[object, List[RoundMetrics]]:
        """Legacy per-round Python loop (incremental history API)."""
        stacked_opt = jax.vmap(self.optimizer.init)(stacked_params)
        history: List[RoundMetrics] = []

        for r in range(self.config.rounds):
            coeffs = self.coeffs_for_round(r)
            batches = node_batches_fn(r)
            stacked_params, stacked_opt, losses = self._train_round(
                stacked_params, stacked_opt, batches, coeffs
            )
            if (r + 1) % self.config.eval_every == 0 or r == self.config.rounds - 1:
                iid, ood = self._evaluate(stacked_params, test_iid, test_ood)
                history.append(
                    RoundMetrics(
                        round=r,
                        iid_acc=np.asarray(iid),
                        ood_acc=np.asarray(ood),
                        train_loss=np.asarray(losses),
                    )
                )
        return stacked_params, history
