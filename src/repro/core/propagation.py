"""Knowledge-propagation metrics (paper §3/§5).

The paper's headline metric is **accuracy AUC**: for each node, the area
under the (round → test accuracy) curve over R rounds, averaged over all
nodes in a topology.  High OOD-AUC means the single OOD node's knowledge
reached the rest of the topology quickly.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.core.decentralized import RoundMetrics

__all__ = [
    "accuracy_auc",
    "per_node_auc",
    "mean_auc",
    "iid_ood_gap",
    "propagation_summary",
    "render_propagation_map",
    "hops_from",
    "UNREACHABLE",
]

#: ``hops_from`` sentinel for nodes with no path from the source (e.g.
#: components disconnected by ``core.dynamic`` link failures).  Consumers
#: label these ``"unreachable"`` and exclude them from hop statistics.
UNREACHABLE = -1


def _curves(history: Sequence[RoundMetrics], which: str) -> np.ndarray:
    """(rounds, n) matrix of per-node accuracies."""
    key = {"iid": "iid_acc", "ood": "ood_acc"}[which]
    return np.stack([getattr(m, key) for m in history])  # (R, n)


def per_node_auc(history: Sequence[RoundMetrics], which: str) -> np.ndarray:
    """Per-node accuracy-AUC, normalized to [0, 1] (trapezoid over rounds
    divided by the round span, i.e. mean height of the accuracy curve)."""
    acc = _curves(history, which)  # (R, n)
    if acc.shape[0] == 1:
        return acc[0]
    rounds = np.array([m.round for m in history], dtype=np.float64)
    auc = np.trapezoid(acc, x=rounds, axis=0)
    return auc / (rounds[-1] - rounds[0])


def accuracy_auc(history: Sequence[RoundMetrics], which: str) -> float:
    """Topology-mean accuracy AUC — the paper's bar-plot quantity."""
    return float(per_node_auc(history, which).mean())


def mean_auc(history: Sequence[RoundMetrics]) -> Dict[str, float]:
    return {
        "iid_auc": accuracy_auc(history, "iid"),
        "ood_auc": accuracy_auc(history, "ood"),
    }


def iid_ood_gap(history: Sequence[RoundMetrics]) -> float:
    """Percent difference between IID and OOD AUC (paper Fig. 2):
    lower (more negative) means OOD knowledge propagated worse."""
    iid = accuracy_auc(history, "iid")
    ood = accuracy_auc(history, "ood")
    return 100.0 * (ood - iid) / max(iid, 1e-9)


def hops_from(adjacency: np.ndarray, source: int) -> np.ndarray:
    """BFS hop distance of every node from the OOD source node; nodes with
    no path keep :data:`UNREACHABLE` (-1)."""
    n = adjacency.shape[0]
    dist = np.full(n, UNREACHABLE, dtype=np.int64)
    dist[source] = 0
    frontier = [source]
    d = 0
    while frontier:
        d += 1
        nxt = []
        for u in frontier:
            for v in np.nonzero(adjacency[u])[0]:
                if dist[v] < 0:
                    dist[v] = d
                    nxt.append(int(v))
        frontier = nxt
    return dist


def render_propagation_map(
    history: Sequence[RoundMetrics],
    adjacency: np.ndarray,
    ood_node: int,
    which: str = "ood",
) -> str:
    """Text rendering of the paper's Fig. 1 heatmap: final per-node
    accuracy grouped by hop distance from the OOD source (terminal-friendly
    stand-in for the graph plot)."""
    acc = _curves(history, which)[-1]
    hops = hops_from(adjacency, ood_node)
    lines = [f"final {which.upper()} accuracy by hop distance from node {ood_node}:"]
    blocks = " ▁▂▃▄▅▆▇█"

    def cells_for(nodes):
        return " ".join(
            f"{i}:{blocks[min(int(acc[i] * 8), 8)]}{acc[i]:.2f}" for i in nodes
        )

    for h in sorted(set(int(x) for x in hops) - {UNREACHABLE}):
        lines.append(f"  hop {h}: {cells_for(np.flatnonzero(hops == h))}")
    unreachable = np.flatnonzero(hops == UNREACHABLE)
    if unreachable.size:
        lines.append(f"  unreachable: {cells_for(unreachable)}")
    return "\n".join(lines)


def propagation_summary(
    history: Sequence[RoundMetrics],
    adjacency: np.ndarray,
    ood_node: int,
) -> Dict[str, object]:
    """Full report: AUCs, gap, and OOD accuracy binned by hop distance from
    the OOD node (quantifies the paper's 'knowledge hops between devices').

    Nodes the BFS cannot reach (link-failure runs that disconnect the
    graph) are reported under the ``"unreachable"`` key rather than a
    bogus hop ``-1`` bin, and are excluded from the hop-distance bins."""
    ood_final = _curves(history, "ood")[-1]  # (n,)
    hops = hops_from(adjacency, ood_node)
    by_hop: Dict[object, float] = {}
    for h in sorted(set(hops.tolist()) - {UNREACHABLE}):
        by_hop[int(h)] = float(ood_final[hops == h].mean())
    unreachable = hops == UNREACHABLE
    if unreachable.any():
        by_hop["unreachable"] = float(ood_final[unreachable].mean())
    return {
        **mean_auc(history),
        "iid_ood_gap_pct": iid_ood_gap(history),
        "final_ood_acc_by_hop": by_hop,
        "final_ood_acc_mean": float(ood_final.mean()),
    }
