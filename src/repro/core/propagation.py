"""Knowledge-propagation metrics (paper §3/§5) — host-side oracles.

The paper's headline metric is **accuracy AUC**: for each node, the area
under the (round → test accuracy) curve over R rounds, averaged over all
nodes in a topology.  High OOD-AUC means the OOD source's knowledge
reached the rest of the topology quickly.  ``arrival_rounds`` reads the
complementary quantity: the first round at which each node's accuracy
crosses a threshold — "rounds until the knowledge arrived", binned by hop
distance from the OOD source(s) in the figures.

These functions consume full ``Sequence[RoundMetrics]`` histories and run
in numpy on the host.  They are the ORACLE for the in-scan streaming
accumulators in ``repro.core.analytics`` (DESIGN.md §10), which compute
the same numbers as O(n) online state inside the round scan; the two
paths are equivalence-tested to 1e-6.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Union

import numpy as np

from repro.core.decentralized import RoundMetrics

__all__ = [
    "accuracy_auc",
    "per_node_auc",
    "mean_auc",
    "iid_ood_gap",
    "arrival_rounds",
    "arrival_by_hop",
    "propagation_summary",
    "render_propagation_map",
    "hops_from",
    "trapezoid",
    "UNREACHABLE",
    "NO_ARRIVAL",
]

#: ``hops_from`` sentinel for nodes with no path from any source (e.g.
#: components disconnected by ``core.dynamic`` link failures).  Consumers
#: label these ``"unreachable"`` and exclude them from hop statistics.
UNREACHABLE = -1

#: ``arrival_rounds`` sentinel for nodes whose accuracy never reaches the
#: threshold within the recorded history.
NO_ARRIVAL = -1

#: One or several OOD source nodes (multi-source scenarios place the
#: backdoor data on k nodes; hop fields and summaries take the min-over-
#: sources distance).
Sources = Union[int, Sequence[int], np.ndarray]


def trapezoid(y: np.ndarray, x: np.ndarray, axis: int = 0) -> np.ndarray:
    """``np.trapezoid`` with a pre-numpy-2.0 fallback.

    ``pyproject.toml`` declares ``numpy>=1.26`` but ``np.trapezoid`` only
    exists from numpy 2.0 (1.x spells it ``np.trapz``, which 2.x in turn
    deprecates) — dispatch at call time so both pins work and the fallback
    stays testable by deleting the attribute (tests/test_propagation.py).
    """
    fn = getattr(np, "trapezoid", None)
    if fn is None:  # numpy < 2.0
        fn = np.trapz
    return fn(y, x=x, axis=axis)


def _curves(history: Sequence[RoundMetrics], which: str) -> np.ndarray:
    """(rounds, n) matrix of per-node accuracies."""
    key = {"iid": "iid_acc", "ood": "ood_acc"}[which]
    return np.stack([getattr(m, key) for m in history])  # (R, n)


def per_node_auc(history: Sequence[RoundMetrics], which: str) -> np.ndarray:
    """Per-node accuracy-AUC, normalized to [0, 1] (trapezoid over rounds
    divided by the round span, i.e. mean height of the accuracy curve)."""
    acc = _curves(history, which)  # (R, n)
    if acc.shape[0] == 1:
        return acc[0]
    rounds = np.array([m.round for m in history], dtype=np.float64)
    auc = trapezoid(acc, x=rounds, axis=0)
    return auc / (rounds[-1] - rounds[0])


def accuracy_auc(history: Sequence[RoundMetrics], which: str) -> float:
    """Topology-mean accuracy AUC — the paper's bar-plot quantity."""
    return float(per_node_auc(history, which).mean())


def mean_auc(history: Sequence[RoundMetrics]) -> Dict[str, float]:
    return {
        "iid_auc": accuracy_auc(history, "iid"),
        "ood_auc": accuracy_auc(history, "ood"),
    }


def iid_ood_gap(history: Sequence[RoundMetrics]) -> float:
    """Percent difference between IID and OOD AUC (paper Fig. 2):
    lower (more negative) means OOD knowledge propagated worse."""
    iid = accuracy_auc(history, "iid")
    ood = accuracy_auc(history, "ood")
    return 100.0 * (ood - iid) / max(iid, 1e-9)


def arrival_rounds(
    history: Sequence[RoundMetrics],
    threshold: float = 0.5,
    which: str = "ood",
) -> np.ndarray:
    """First recorded round at which each node's accuracy reaches
    ``threshold`` — the "rounds until OOD knowledge arrived" quantity the
    paper plots against hop distance.  Nodes that never reach it keep
    :data:`NO_ARRIVAL` (-1).  Oracle for the streaming accumulator in
    ``repro.core.analytics``."""
    acc = _curves(history, which)  # (R, n)
    rounds = np.array([m.round for m in history], dtype=np.int64)
    hit = acc >= threshold
    first = np.argmax(hit, axis=0)  # first True (0 when none hit)
    return np.where(hit.any(axis=0), rounds[first], NO_ARRIVAL)


def arrival_by_hop(arrival: np.ndarray,
                   hops: np.ndarray) -> Dict[object, Optional[float]]:
    """Mean arrival round per hop-distance bin (single- or multi-source
    hop fields).  Nodes that never reached the threshold
    (:data:`NO_ARRIVAL`) are excluded from the means — ``None`` marks a
    bin with no arrivals — and BFS-unreachable nodes report under their
    own ``"unreachable"`` bin.  Shared by :func:`propagation_summary`
    and ``repro.core.analytics.analytics_summary`` so the host-oracle
    and streaming digests cannot drift apart."""
    arrival = np.asarray(arrival)
    hops = np.asarray(hops)
    arrived = arrival != NO_ARRIVAL
    out: Dict[object, Optional[float]] = {}
    for h in sorted(set(hops.tolist()) - {UNREACHABLE}):
        m = (hops == h) & arrived
        out[int(h)] = float(arrival[m].mean()) if m.any() else None
    unreachable = hops == UNREACHABLE
    if unreachable.any():
        m = unreachable & arrived
        out["unreachable"] = float(arrival[m].mean()) if m.any() else None
    return out


def _as_sources(source: Sources) -> np.ndarray:
    srcs = np.atleast_1d(np.asarray(source, dtype=np.int64))
    if srcs.ndim != 1 or srcs.size == 0:
        raise ValueError(f"need at least one source node, got {source!r}")
    return srcs


def hops_from(adjacency: np.ndarray, source: Sources) -> np.ndarray:
    """BFS hop distance of every node from the nearest OOD source.

    ``source`` may be a single node or a collection of nodes (multi-source
    OOD placement): seeding the BFS frontier with all sources yields the
    pointwise minimum over the single-source hop fields.  Nodes with no
    path from any source keep :data:`UNREACHABLE` (-1)."""
    n = adjacency.shape[0]
    dist = np.full(n, UNREACHABLE, dtype=np.int64)
    frontier = [int(s) for s in _as_sources(source)]
    for s in frontier:
        dist[s] = 0
    d = 0
    while frontier:
        d += 1
        nxt = []
        for u in frontier:
            for v in np.nonzero(adjacency[u])[0]:
                if dist[v] < 0:
                    dist[v] = d
                    nxt.append(int(v))
        frontier = nxt
    return dist


def render_propagation_map(
    history: Sequence[RoundMetrics],
    adjacency: np.ndarray,
    ood_node: Sources,
    which: str = "ood",
) -> str:
    """Text rendering of the paper's Fig. 1 heatmap: final per-node
    accuracy grouped by hop distance from the OOD source(s) (terminal-
    friendly stand-in for the graph plot)."""
    acc = _curves(history, which)[-1]
    hops = hops_from(adjacency, ood_node)
    srcs = _as_sources(ood_node)
    label = (f"node {int(srcs[0])}" if srcs.size == 1
             else "nodes " + ", ".join(str(int(s)) for s in srcs))
    lines = [f"final {which.upper()} accuracy by hop distance "
             f"from {label}:"]
    blocks = " ▁▂▃▄▅▆▇█"

    def cells_for(nodes):
        return " ".join(
            f"{i}:{blocks[min(int(acc[i] * 8), 8)]}{acc[i]:.2f}" for i in nodes
        )

    for h in sorted(set(int(x) for x in hops) - {UNREACHABLE}):
        lines.append(f"  hop {h}: {cells_for(np.flatnonzero(hops == h))}")
    unreachable = np.flatnonzero(hops == UNREACHABLE)
    if unreachable.size:
        lines.append(f"  unreachable: {cells_for(unreachable)}")
    return "\n".join(lines)


def propagation_summary(
    history: Sequence[RoundMetrics],
    adjacency: np.ndarray,
    ood_node: Sources,
    arrival_threshold: float = 0.5,
) -> Dict[str, object]:
    """Full report: AUCs, gap, arrival rounds, and OOD accuracy binned by
    hop distance from the OOD source(s) (quantifies the paper's 'knowledge
    hops between devices').  ``ood_node`` may be a single node or a
    collection (multi-source placement: hop bins use the min-over-sources
    distance).

    Nodes the BFS cannot reach (link-failure runs that disconnect the
    graph) are reported under the ``"unreachable"`` key rather than a
    bogus hop ``-1`` bin, and are excluded from the hop-distance bins;
    nodes that never cross ``arrival_threshold`` are excluded from
    arrival means (``None`` marks an all-excluded bin)."""
    ood_final = _curves(history, "ood")[-1]  # (n,)
    hops = hops_from(adjacency, ood_node)
    arrival = arrival_rounds(history, threshold=arrival_threshold)
    arrived = arrival != NO_ARRIVAL
    by_hop: Dict[object, float] = {}
    for h in sorted(set(hops.tolist()) - {UNREACHABLE}):
        by_hop[int(h)] = float(ood_final[hops == h].mean())
    unreachable = hops == UNREACHABLE
    if unreachable.any():
        by_hop["unreachable"] = float(ood_final[unreachable].mean())
    srcs = _as_sources(ood_node)
    return {
        **mean_auc(history),
        "iid_ood_gap_pct": iid_ood_gap(history),
        "final_ood_acc_by_hop": by_hop,
        "final_ood_acc_mean": float(ood_final.mean()),
        "ood_arrival_mean": (float(arrival[arrived].mean())
                             if arrived.any() else None),
        "ood_arrival_by_hop": arrival_by_hop(arrival, hops),
        "ood_sources": ([int(s) for s in srcs] if srcs.size > 1
                        else int(srcs[0])),
    }
