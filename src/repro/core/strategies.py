"""Aggregation strategies → row-stochastic mixing matrices.

The central design choice in Alg. 1 is ``GetAggrCoeffs(N_i, S)``: how device
i weights the models in its neighbourhood.  Every strategy here produces the
full ``(n, n)`` mixing matrix ``C`` with

* ``C[i, j] ≥ 0``,
* ``C[i, j] > 0  ⇒  j ∈ N_i = neighbors(i) ∪ {i}``  (except FL, which
  assumes a fully-connected topology — the paper's best-case baseline),
* ``Σ_j C[i, j] = 1``  (row-stochastic).

Baselines (paper §B.3): ``unweighted``, ``weighted``, ``random``, ``fl``.
Paper's contribution (§4): ``degree``, ``betweenness`` — topology-aware
coefficients ``C[i,j] = softmax_{j∈N_i}(R_j / τ)`` where ``R`` is each
node's centrality score.

Matrices are built host-side in numpy (graphs are metadata) and consumed by
``repro.core.mixing`` on device.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import numpy as np

from repro.core.topology import Topology

__all__ = [
    "AggregationStrategy",
    "mixing_matrix",
    "STRATEGIES",
    "register_strategy",
    "unweighted",
    "weighted",
    "random_coeffs",
    "fl",
    "degree",
    "betweenness",
    "eigenvector",
    "pagerank",
    "closeness",
    "metropolis_hastings",
    "TOPOLOGY_AWARE",
    "TOPOLOGY_UNAWARE",
    "validate_mixing_matrix",
]


@dataclasses.dataclass(frozen=True)
class AggregationStrategy:
    """A named strategy with its hyper-parameters.

    ``kind`` selects the coefficient rule; ``tau`` is the softmax temperature
    used by the softmax-scaled strategies (paper uses τ=0.1);  ``seed`` feeds
    the Random baseline.
    """

    kind: str = "unweighted"
    tau: float = 0.1
    seed: int = 0

    def matrix(self, topo: Topology, data_counts: Optional[np.ndarray] = None) -> np.ndarray:
        return mixing_matrix(topo, self, data_counts=data_counts)


def _neighborhood_mask(topo: Topology) -> np.ndarray:
    """(n, n) 0/1 mask of N_i per row: adjacency plus self-loop."""
    return topo.adjacency + np.eye(topo.n_nodes)


def _masked_softmax(scores: np.ndarray, mask: np.ndarray, tau: float) -> np.ndarray:
    """Row-wise softmax of per-*column* scores restricted to the row's mask.

    ``scores`` is an (n,) vector of per-node values R_j; row i's coefficients
    are softmax over {R_j / τ : j ∈ N_i}.  Numerically stabilized per row.
    """
    n = scores.shape[0]
    logits = np.broadcast_to(scores[None, :] / tau, (n, n)).copy()
    logits[mask == 0] = -np.inf
    logits -= logits.max(axis=1, keepdims=True)
    exp = np.exp(logits)
    exp[mask == 0] = 0.0
    return exp / exp.sum(axis=1, keepdims=True)


# ----------------------------------------------------------------------
# baseline strategies (§B.3)
# ----------------------------------------------------------------------
def unweighted(topo: Topology, strategy: AggregationStrategy,
               data_counts: Optional[np.ndarray] = None) -> np.ndarray:
    """C[i,j] = 1/|N_i| for j ∈ N_i."""
    mask = _neighborhood_mask(topo)
    return mask / mask.sum(axis=1, keepdims=True)


def weighted(topo: Topology, strategy: AggregationStrategy,
             data_counts: Optional[np.ndarray] = None) -> np.ndarray:
    """C[i,j] = |train_j| / Σ_{x∈N_i} |train_x|."""
    if data_counts is None:
        raise ValueError("'weighted' strategy needs per-node data_counts")
    counts = np.asarray(data_counts, dtype=np.float64)
    if counts.shape != (topo.n_nodes,):
        raise ValueError(f"data_counts shape {counts.shape} != ({topo.n_nodes},)")
    mask = _neighborhood_mask(topo)
    w = mask * counts[None, :]
    return w / w.sum(axis=1, keepdims=True)


def random_coeffs(topo: Topology, strategy: AggregationStrategy,
                  data_counts: Optional[np.ndarray] = None) -> np.ndarray:
    """Softmax(U(0,1)/τ) within each neighbourhood (fresh draw per call —
    the paper redraws each round; the trainer re-invokes per round)."""
    rng = np.random.default_rng(strategy.seed)
    scores = rng.uniform(size=topo.n_nodes)
    return _masked_softmax(scores, _neighborhood_mask(topo), strategy.tau)


def fl(topo: Topology, strategy: AggregationStrategy,
       data_counts: Optional[np.ndarray] = None) -> np.ndarray:
    """FedAvg best-case baseline: uniform over the whole topology."""
    n = topo.n_nodes
    return np.full((n, n), 1.0 / n)


# ----------------------------------------------------------------------
# topology-aware strategies (paper §4)
# ----------------------------------------------------------------------
def degree(topo: Topology, strategy: AggregationStrategy,
           data_counts: Optional[np.ndarray] = None) -> np.ndarray:
    """R_j = degree centrality of j (degree / (n-1), the networkx
    normalization — scores in [0,1] to match betweenness; with raw integer
    degrees τ=0.1 would be winner-take-all, contradicting the paper's
    Fig. 3 which shows soft coefficients); C[i,·] = softmax_{N_i}(R/τ)."""
    scores = topo.degree() / max(topo.n_nodes - 1, 1)
    return _masked_softmax(scores, _neighborhood_mask(topo), strategy.tau)


def betweenness(topo: Topology, strategy: AggregationStrategy,
                data_counts: Optional[np.ndarray] = None) -> np.ndarray:
    """R_j = betweenness centrality(j); C[i,·] = softmax_{N_i}(R/τ)."""
    return _masked_softmax(topo.betweenness(), _neighborhood_mask(topo), strategy.tau)


# ----------------------------------------------------------------------
# beyond-paper centrality strategies (paper §7 names these as future work)
# ----------------------------------------------------------------------
def eigenvector(topo: Topology, strategy: AggregationStrategy,
                data_counts: Optional[np.ndarray] = None) -> np.ndarray:
    """R_j = eigenvector centrality (global; weights neighbours by how
    central *their* neighbours are — a smoother global signal than
    betweenness)."""
    import networkx as nx

    ec = nx.eigenvector_centrality_numpy(topo.to_networkx())
    scores = np.array([ec[i] for i in range(topo.n_nodes)])
    return _masked_softmax(scores, _neighborhood_mask(topo), strategy.tau)


def pagerank(topo: Topology, strategy: AggregationStrategy,
             data_counts: Optional[np.ndarray] = None) -> np.ndarray:
    """R_j = PageRank (random-walk stationary mass — directly measures how
    often gossip 'visits' a node)."""
    import networkx as nx

    pr = nx.pagerank(topo.to_networkx())
    scores = np.array([pr[i] for i in range(topo.n_nodes)])
    # pagerank mass is O(1/n); rescale to [0,1] like the other metrics
    scores = scores / scores.max()
    return _masked_softmax(scores, _neighborhood_mask(topo), strategy.tau)


def closeness(topo: Topology, strategy: AggregationStrategy,
              data_counts: Optional[np.ndarray] = None) -> np.ndarray:
    """R_j = closeness centrality (inverse mean hop distance — how few hops
    knowledge needs from j to anyone)."""
    import networkx as nx

    cc = nx.closeness_centrality(topo.to_networkx())
    scores = np.array([cc[i] for i in range(topo.n_nodes)])
    return _masked_softmax(scores, _neighborhood_mask(topo), strategy.tau)


# ----------------------------------------------------------------------
# beyond-paper strategy (doubly-stochastic; classical gossip optimum)
# ----------------------------------------------------------------------
def metropolis_hastings(topo: Topology, strategy: AggregationStrategy,
                        data_counts: Optional[np.ndarray] = None) -> np.ndarray:
    """Metropolis–Hastings weights: C[i,j] = 1/(1+max(d_i,d_j)) for edges,
    self-weight = remainder.  Doubly-stochastic — included as a classical
    decentralized-SGD reference point the paper does not evaluate."""
    deg = topo.degree()
    n = topo.n_nodes
    c = np.zeros((n, n))
    for i in range(n):
        for j in topo.neighbors(i):
            c[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
        c[i, i] = 1.0 - c[i].sum()
    return c


STRATEGIES: Dict[str, Callable[..., np.ndarray]] = {
    "unweighted": unweighted,
    "weighted": weighted,
    "random": random_coeffs,
    "fl": fl,
    "degree": degree,
    "betweenness": betweenness,
    "metropolis": metropolis_hastings,
    "eigenvector": eigenvector,
    "pagerank": pagerank,
    "closeness": closeness,
}

TOPOLOGY_AWARE = frozenset({"degree", "betweenness", "eigenvector",
                            "pagerank", "closeness"})
TOPOLOGY_UNAWARE = frozenset({"unweighted", "weighted", "random", "fl"})


def register_strategy(name: str, fn: Callable[..., np.ndarray]) -> None:
    """Plugin point for additional centrality metrics (paper §7 future work)."""
    if name in STRATEGIES:
        raise KeyError(f"strategy {name!r} already registered")
    STRATEGIES[name] = fn


def mixing_matrix(
    topo: Topology,
    strategy: AggregationStrategy,
    data_counts: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Build + validate the (n, n) row-stochastic mixing matrix."""
    if strategy.kind not in STRATEGIES:
        raise KeyError(
            f"unknown strategy {strategy.kind!r}; have {sorted(STRATEGIES)}"
        )
    c = STRATEGIES[strategy.kind](topo, strategy, data_counts=data_counts)
    validate_mixing_matrix(c, topo, dense_ok=strategy.kind == "fl")
    return c


def validate_mixing_matrix(c: np.ndarray, topo: Topology, dense_ok: bool = False) -> None:
    n = topo.n_nodes
    if c.shape != (n, n):
        raise ValueError(f"mixing matrix shape {c.shape} != ({n},{n})")
    if np.any(c < -1e-12):
        raise ValueError("mixing matrix has negative entries")
    if not np.allclose(c.sum(axis=1), 1.0, atol=1e-9):
        raise ValueError("mixing matrix rows must sum to 1")
    if not dense_ok:
        mask = topo.adjacency + np.eye(n)
        if np.any((c > 1e-12) & (mask == 0)):
            raise ValueError("mixing matrix has weight outside neighbourhoods")
