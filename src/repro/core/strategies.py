"""Aggregation strategies → row-stochastic mixing matrices.

The central design choice in Alg. 1 is ``GetAggrCoeffs(N_i, S)``: how device
i weights the models in its neighbourhood.  Every strategy here produces the
full ``(n, n)`` mixing matrix ``C`` with

* ``C[i, j] ≥ 0``,
* ``C[i, j] > 0  ⇒  j ∈ N_i = neighbors(i) ∪ {i}``  (except FL, which
  assumes a fully-connected topology — the paper's best-case baseline),
* ``Σ_j C[i, j] = 1``  (row-stochastic).

Baselines (paper §B.3): ``unweighted``, ``weighted``, ``random``, ``fl``.
Paper's contribution (§4): ``degree``, ``betweenness`` — topology-aware
coefficients ``C[i,j] = softmax_{j∈N_i}(R_j / τ)`` where ``R`` is each
node's centrality score.

Matrices are built host-side in numpy (graphs are metadata) and consumed by
``repro.core.mixing`` on device.  The *rule* is split from the *arrays*:
:func:`strategy_scores` produces the per-node score vector R and
:func:`masked_softmax` applies the score→coefficient rule generically over
an array namespace, so the device-side coefficient programs
(``repro.core.coeffs``) share the exact same rule with ``xp=jnp``
(DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import numpy as np

from repro.core.topology import Topology

__all__ = [
    "AggregationStrategy",
    "mixing_matrix",
    "STRATEGIES",
    "register_strategy",
    "unweighted",
    "weighted",
    "random_coeffs",
    "fl",
    "degree",
    "betweenness",
    "eigenvector",
    "pagerank",
    "closeness",
    "metropolis_hastings",
    "TOPOLOGY_AWARE",
    "TOPOLOGY_UNAWARE",
    "validate_mixing_matrix",
    "masked_softmax",
    "masked_normalize",
    "renormalize_rows",
    "strategy_scores",
    "random_round_seed",
]


@dataclasses.dataclass(frozen=True)
class AggregationStrategy:
    """A named strategy with its hyper-parameters.

    ``kind`` selects the coefficient rule; ``tau`` is the softmax temperature
    used by the softmax-scaled strategies (paper uses τ=0.1);  ``seed`` feeds
    the Random baseline.
    """

    kind: str = "unweighted"
    tau: float = 0.1
    seed: int = 0

    def matrix(self, topo: Topology, data_counts: Optional[np.ndarray] = None,
               round_idx: Optional[int] = None) -> np.ndarray:
        """Mixing matrix; pass ``round_idx`` for round r's matrix.

        The per-round form DELEGATES to
        ``repro.core.decentralized.round_coeffs`` — the exact matrices the
        trainer/engine consume (program kinds via the device-side
        coefficient program, others via :func:`random_round_seed` seed
        mixing) — so a direct per-round call can neither silently repeat
        a round's Random draw nor diverge from what training used."""
        if round_idx is None:
            return mixing_matrix(topo, self, data_counts=data_counts)
        from repro.core.decentralized import round_coeffs  # call-time: no cycle

        return round_coeffs(topo, self, round_idx, data_counts=data_counts)


def random_round_seed(seed: int, round_idx: int) -> int:
    """Per-round seed mixing for the HOST-path Random draw.
    :func:`random_coeffs` itself is deterministic in ``strategy.seed``;
    a host caller that wants round r's draw mixes the seed through this
    helper first.  Note the engines' actual training stream for Random
    is the coefficient program's PRNG folding (``repro.core.coeffs``,
    DESIGN.md §9) — ``round_coeffs`` / ``matrix(round_idx=...)`` route
    there and keep this helper only as the fallback for non-program
    kinds."""
    return seed * 100003 + round_idx


def _neighborhood_mask(topo: Topology) -> np.ndarray:
    """(n, n) 0/1 mask of N_i per row: adjacency plus self-loop."""
    return topo.adjacency + np.eye(topo.n_nodes)


def masked_softmax(scores, mask, tau, xp=np):
    """Row-wise softmax of per-*column* scores restricted to the row's mask.

    ``scores`` is an (n,) vector of per-node values R_j; row i's coefficients
    are softmax over {R_j / τ : j ∈ N_i}.  Numerically stabilized per row.
    Written against the array namespace ``xp`` so the host path (numpy,
    float64) and the device-side coefficient programs (``xp=jnp``, float32,
    ``repro.core.coeffs``) share the exact same rule.
    """
    n = scores.shape[-1]
    logits = xp.where(mask > 0,
                      xp.broadcast_to(scores[None, :] / tau, (n, n)),
                      -xp.inf)
    logits = logits - logits.max(axis=1, keepdims=True)
    exp = xp.where(mask > 0, xp.exp(logits), 0.0)
    return exp / exp.sum(axis=1, keepdims=True)


def masked_normalize(weights, mask, xp=np):
    """Linear (non-softmax) coefficient rule: ``C[i, j] = w_j / Σ_{N_i} w``
    — Unweighted (w=1) and Weighted (w=|train_j|).  Shared between the
    numpy host path and the jnp coefficient programs like
    :func:`masked_softmax`; rows whose mask is empty are impossible here
    (every node keeps its self-loop)."""
    wm = mask * weights[None, :]
    return wm / wm.sum(axis=1, keepdims=True)


def renormalize_rows(c, fallback=None, xp=np):
    """Re-normalize the rows of a masked coefficient matrix.

    Rows with positive mass are divided by their sum; rows whose support
    was entirely masked away fall back to the matching row of
    ``fallback`` (identity — self-weight 1 — when omitted).  There is no
    epsilon: a row sum is either genuinely positive or the row takes the
    fallback, so near-zero sums cannot be silently inflated.  On the
    numpy host path an assert rejects sums in (0, 1e-9) outright — those
    indicate a masking bug upstream, not a row that lost its neighbours.

    Shared by :func:`repro.core.dynamic.dynamic_mixing_matrix` (link
    failure) and ``repro.core.coeffs.participation_renormalize`` (node
    dropout); written against the array namespace ``xp`` like
    :func:`masked_softmax` so both the numpy and traced-jnp paths apply
    the identical rule.
    """
    n = c.shape[-1]
    rowsum = c.sum(axis=-1, keepdims=True)
    if fallback is None:
        fallback = xp.eye(n, dtype=c.dtype)
        fallback = xp.broadcast_to(fallback, c.shape)
    if xp is np:
        tiny = (rowsum > 0) & (rowsum < 1e-9)
        assert not np.any(tiny), (
            f"renormalize_rows: row sums in (0, 1e-9) — masking bug? "
            f"rows={np.nonzero(tiny)[0].tolist()}")
    safe = xp.where(rowsum > 0, rowsum, xp.ones_like(rowsum))
    return xp.where(rowsum > 0, c / safe, fallback)


_masked_softmax = masked_softmax  # internal alias kept for readability below


# ----------------------------------------------------------------------
# baseline strategies (§B.3)
# ----------------------------------------------------------------------
def unweighted(topo: Topology, strategy: AggregationStrategy,
               data_counts: Optional[np.ndarray] = None) -> np.ndarray:
    """C[i,j] = 1/|N_i| for j ∈ N_i."""
    return masked_normalize(np.ones(topo.n_nodes), _neighborhood_mask(topo))


def weighted(topo: Topology, strategy: AggregationStrategy,
             data_counts: Optional[np.ndarray] = None) -> np.ndarray:
    """C[i,j] = |train_j| / Σ_{x∈N_i} |train_x|."""
    if data_counts is None:
        raise ValueError("'weighted' strategy needs per-node data_counts")
    counts = np.asarray(data_counts, dtype=np.float64)
    if counts.shape != (topo.n_nodes,):
        raise ValueError(f"data_counts shape {counts.shape} != ({topo.n_nodes},)")
    return masked_normalize(counts, _neighborhood_mask(topo))


def random_coeffs(topo: Topology, strategy: AggregationStrategy,
                  data_counts: Optional[np.ndarray] = None) -> np.ndarray:
    """Softmax(U(0,1)/τ) within each neighbourhood.

    The draw is FULLY determined by ``strategy.seed`` — calling this twice
    with the same strategy returns the same matrix.  The paper's per-round
    redraw comes from seed mixing (:func:`random_round_seed`), applied by
    ``round_coeffs`` / ``AggregationStrategy.matrix(round_idx=...)`` before
    this function runs — never from this function itself.
    """
    return _masked_softmax(strategy_scores(topo, strategy),
                           _neighborhood_mask(topo), strategy.tau)


def fl(topo: Topology, strategy: AggregationStrategy,
       data_counts: Optional[np.ndarray] = None) -> np.ndarray:
    """FedAvg best-case baseline: uniform over the whole topology."""
    n = topo.n_nodes
    return np.full((n, n), 1.0 / n)


# ----------------------------------------------------------------------
# per-node score vectors — the *data* half of the softmax-scaled rule,
# shared with the device-side coefficient programs (repro.core.coeffs
# loads these as nominal scores into CoeffProgram state)
# ----------------------------------------------------------------------
_SCORE_FNS: Dict[str, Callable[[Topology, "AggregationStrategy"], np.ndarray]] = {
    # degree / (n-1): networkx normalization — scores in [0,1] to match
    # betweenness; raw integer degrees at τ=0.1 would be winner-take-all,
    # contradicting the paper's Fig. 3 soft coefficients.
    "degree": lambda t, s: t.degree() / max(t.n_nodes - 1, 1),
    "betweenness": lambda t, s: t.betweenness(),
    "eigenvector": lambda t, s: t.eigenvector(),
    # pagerank mass is O(1/n); rescale to [0,1] like the other metrics
    "pagerank": lambda t, s: t.pagerank() / t.pagerank().max(),
    "closeness": lambda t, s: t.closeness(),
    "random": lambda t, s: np.random.default_rng(s.seed).uniform(
        size=t.n_nodes),
}


def strategy_scores(topo: Topology, strategy: AggregationStrategy) -> np.ndarray:
    """(n,) per-node scores R_j for the softmax-scaled strategies."""
    if strategy.kind not in _SCORE_FNS:
        raise KeyError(f"strategy {strategy.kind!r} has no score vector; "
                       f"softmax-scored kinds: {sorted(_SCORE_FNS)}")
    return np.asarray(_SCORE_FNS[strategy.kind](topo, strategy),
                      dtype=np.float64)


# ----------------------------------------------------------------------
# topology-aware strategies (paper §4)
# ----------------------------------------------------------------------
def degree(topo: Topology, strategy: AggregationStrategy,
           data_counts: Optional[np.ndarray] = None) -> np.ndarray:
    """R_j = degree centrality of j; C[i,·] = softmax_{N_i}(R/τ)."""
    return _masked_softmax(strategy_scores(topo, strategy),
                           _neighborhood_mask(topo), strategy.tau)


def betweenness(topo: Topology, strategy: AggregationStrategy,
                data_counts: Optional[np.ndarray] = None) -> np.ndarray:
    """R_j = betweenness centrality(j); C[i,·] = softmax_{N_i}(R/τ)."""
    return _masked_softmax(strategy_scores(topo, strategy),
                           _neighborhood_mask(topo), strategy.tau)


# ----------------------------------------------------------------------
# beyond-paper centrality strategies (paper §7 names these as future work)
# ----------------------------------------------------------------------
def eigenvector(topo: Topology, strategy: AggregationStrategy,
                data_counts: Optional[np.ndarray] = None) -> np.ndarray:
    """R_j = eigenvector centrality (global; weights neighbours by how
    central *their* neighbours are — a smoother global signal than
    betweenness)."""
    return _masked_softmax(strategy_scores(topo, strategy),
                           _neighborhood_mask(topo), strategy.tau)


def pagerank(topo: Topology, strategy: AggregationStrategy,
             data_counts: Optional[np.ndarray] = None) -> np.ndarray:
    """R_j = PageRank (random-walk stationary mass — directly measures how
    often gossip 'visits' a node)."""
    return _masked_softmax(strategy_scores(topo, strategy),
                           _neighborhood_mask(topo), strategy.tau)


def closeness(topo: Topology, strategy: AggregationStrategy,
              data_counts: Optional[np.ndarray] = None) -> np.ndarray:
    """R_j = closeness centrality (inverse mean hop distance — how few hops
    knowledge needs from j to anyone)."""
    return _masked_softmax(strategy_scores(topo, strategy),
                           _neighborhood_mask(topo), strategy.tau)


# ----------------------------------------------------------------------
# beyond-paper strategy (doubly-stochastic; classical gossip optimum)
# ----------------------------------------------------------------------
def metropolis_hastings(topo: Topology, strategy: AggregationStrategy,
                        data_counts: Optional[np.ndarray] = None) -> np.ndarray:
    """Metropolis–Hastings weights: C[i,j] = 1/(1+max(d_i,d_j)) for edges,
    self-weight = remainder.  Doubly-stochastic — included as a classical
    decentralized-SGD reference point the paper does not evaluate."""
    deg = topo.degree()
    n = topo.n_nodes
    c = np.zeros((n, n))
    for i in range(n):
        for j in topo.neighbors(i):
            c[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
        c[i, i] = 1.0 - c[i].sum()
    return c


STRATEGIES: Dict[str, Callable[..., np.ndarray]] = {
    "unweighted": unweighted,
    "weighted": weighted,
    "random": random_coeffs,
    "fl": fl,
    "degree": degree,
    "betweenness": betweenness,
    "metropolis": metropolis_hastings,
    "eigenvector": eigenvector,
    "pagerank": pagerank,
    "closeness": closeness,
}

TOPOLOGY_AWARE = frozenset({"degree", "betweenness", "eigenvector",
                            "pagerank", "closeness"})
TOPOLOGY_UNAWARE = frozenset({"unweighted", "weighted", "random", "fl"})


def register_strategy(name: str, fn: Callable[..., np.ndarray]) -> None:
    """Plugin point for additional centrality metrics (paper §7 future work)."""
    if name in STRATEGIES:
        raise KeyError(f"strategy {name!r} already registered")
    STRATEGIES[name] = fn


def mixing_matrix(
    topo: Topology,
    strategy: AggregationStrategy,
    data_counts: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Build + validate the (n, n) row-stochastic mixing matrix."""
    if strategy.kind not in STRATEGIES:
        raise KeyError(
            f"unknown strategy {strategy.kind!r}; have {sorted(STRATEGIES)}"
        )
    c = STRATEGIES[strategy.kind](topo, strategy, data_counts=data_counts)
    validate_mixing_matrix(c, topo, dense_ok=strategy.kind == "fl")
    return c


def validate_mixing_matrix(c: np.ndarray, topo: Topology, dense_ok: bool = False) -> None:
    n = topo.n_nodes
    if c.shape != (n, n):
        raise ValueError(f"mixing matrix shape {c.shape} != ({n},{n})")
    if np.any(c < -1e-12):
        raise ValueError("mixing matrix has negative entries")
    if not np.allclose(c.sum(axis=1), 1.0, atol=1e-9):
        raise ValueError("mixing matrix rows must sum to 1")
    if not dense_ok:
        mask = topo.adjacency + np.eye(n)
        if np.any((c > 1e-12) & (mask == 0)):
            raise ValueError("mixing matrix has weight outside neighbourhoods")
