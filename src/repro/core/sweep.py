"""Batched experiment-sweep engine: vmap over experiments × scan over rounds.

The paper's findings are all *sweeps* — over strategies (Fig. 4), OOD
placements (Fig. 5), topologies (Fig. 6), and seeds.  Every cell of such a
grid runs the same program shape (same n, R, model, batch geometry); only
the *data* differs: initial params, per-round mixing matrices, sample
indices, test batches.  This module exploits that: ONE jitted program —
``vmap`` over the experiment axis E of the ``lax.scan`` over rounds from
``repro.core.decentralized`` — evaluates a whole figure's grid in a single
device dispatch (DESIGN.md §7).

Inputs per experiment (leading axis E):

* ``params0``   — stacked initial node models, leaves ``(E, n, ...)``;
* ``coeffs``    — ``(E, R, n, n)`` per-round mixing matrices
  (:func:`repro.core.decentralized.coeffs_stack`; Random resampling and
  ``core.dynamic`` link-failure schedules are just different stacks);
* ``data_idx``  — ``(E,)`` row into the shared data bank;
* ``test_iid`` / ``test_ood`` — per-experiment test batches, leaves
  ``(E, b, ...)``.

Shared across experiments:

* ``bank``      — padded per-node sample bank, leaves ``(D, n, cap, ...)``
  (``NodeBatcher.sample_bank``); experiments sharing a data configuration
  (same seed/OOD placement) share a bank row, so memory scales with the
  number of *distinct* datasets D, not with E;
* ``indices``   — ``(D, R, n, S)`` per-round sample indices
  (``NodeBatcher.all_round_indices``) — batches are a per-round gather
  inside the scan, never materialized as an ``(E, R, ...)`` tensor.

Three execution modes of the same program family (DESIGN.md §7/§8), all
bit-for-bit identical (tests/test_sweep.py, tests/test_sweep_sharded.py):

* **scanned** (default): ``jit(vmap_E(scan_R(round)))`` on one device;
* **sharded-scanned** (``mesh=...``): the E axis is laid across a 1-D
  device mesh (``repro.launch.mesh.make_sweep_mesh``) with ``shard_map``
  — E is padded to a multiple of the mesh size with dummy experiments
  (copies of experiment 0, masked out of the returned result) and each
  device runs the identical per-experiment program on its slice, so
  sharding cannot change any real experiment's arithmetic;
* **unrolled** (``unroll_eval=True``): the legacy per-round Python loop,
  preserving the incremental history API (one dispatch per round,
  metrics available as they stream).

Orthogonally, ``chunk_rounds=c`` scans the round schedule in ``⌈R/c⌉``
chunks: the device-resident ``(R, n, S)`` index schedule, ``(R, n, n)``
coefficient slab, and ``(R, n)`` eval accumulators stay bounded at one
chunk while the host concatenates per-chunk metrics — the long-run mode.
The ``(params, opt)`` carry is donated back into each chunk (and into
the one-shot scans) on backends that support buffer donation, so the
scan never double-allocates the model/optimizer state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.decentralized import (
    DecentralizedConfig,
    RoundMetrics,
    eval_round_indices,
    make_round_fn,
    make_scan_fn,
)
from repro.training.optimizer import Optimizer

__all__ = ["SweepEngine", "SweepResult", "gather_round_batch",
           "pad_experiments", "donation_supported"]


def donation_supported() -> bool:
    """Buffer donation is a no-op (with a warning) on CPU; only donate
    where XLA actually reuses the buffers."""
    return jax.default_backend() in ("gpu", "tpu")


def pad_experiments(tree: Any, pad: int) -> Any:
    """Grow every leaf's leading E axis by ``pad`` dummy experiments —
    copies of experiment 0, so the padded program is numerically valid and
    the padding rows are simply dropped from the result.  Identity when
    ``pad == 0``."""
    if pad == 0:
        return tree
    return jax.tree.map(
        lambda x: jnp.concatenate(
            [jnp.asarray(x),
             jnp.broadcast_to(jnp.asarray(x)[:1],
                              (pad,) + tuple(np.shape(x)[1:]))], axis=0),
        tree)


def gather_round_batch(bank: Dict[str, jnp.ndarray], data_idx: jnp.ndarray,
                       idx_r: jnp.ndarray, batch_size: int):
    """One round of per-node batches for one experiment, gathered straight
    from the (D, n, cap, ...) bank.

    ``idx_r``: (n, S) sample indices (S = steps·batch) into each node's
    bank row.  Returns the exact pytree ``NodeBatcher.round_batches``
    yields — leaves (n, steps, batch, ...) — including the all-ones LM
    loss mask.
    """
    n, s = idx_r.shape
    steps = s // batch_size
    rows = jnp.arange(n)[:, None]

    def g(leaf: jnp.ndarray) -> jnp.ndarray:
        out = leaf[data_idx, rows, idx_r]  # (n, S, ...)
        return out.reshape((n, steps, batch_size) + leaf.shape[3:])

    batch = {k: g(v) for k, v in bank.items()}
    if "tokens" in batch:  # LM: trainer consumes an all-ones train mask
        seq = batch["tokens"].shape[-1]
        batch["mask"] = jnp.ones((n, steps, batch_size, seq - 1), jnp.float32)
    return batch


@dataclasses.dataclass
class SweepResult:
    """Stacked metrics for an E-experiment sweep.

    ``train_loss`` / ``iid_acc`` / ``ood_acc`` are ``(E, R, n)``;
    ``params`` is the final stacked pytree with leaves ``(E, n, ...)``.
    Accuracy rows are only populated at the rounds ``eval_every`` keeps
    (eval is gated inside the scan; skipped rounds are zeros).
    ``history(e)`` rebuilds the legacy per-experiment ``List[RoundMetrics]``
    (subsampled at ``eval_every`` exactly like ``DecentralizedTrainer.run``)
    for ``repro.core.propagation``.
    """

    train_loss: np.ndarray
    iid_acc: np.ndarray
    ood_acc: np.ndarray
    params: Any
    eval_every: int = 1

    @property
    def n_experiments(self) -> int:
        return self.train_loss.shape[0]

    @property
    def rounds(self) -> int:
        return self.train_loss.shape[1]

    def history(self, e: int) -> List[RoundMetrics]:
        return [
            RoundMetrics(round=r, iid_acc=self.iid_acc[e, r],
                         ood_acc=self.ood_acc[e, r],
                         train_loss=self.train_loss[e, r])
            for r in eval_round_indices(self.rounds, self.eval_every)
        ]

    def experiment_params(self, e: int):
        return jax.tree.map(lambda x: x[e], self.params)


class SweepEngine:
    """Compiles (strategy × seed × placement × topology) grids into one
    program: ``jit(vmap_E(scan_R(round)))``.

    Args:
      optimizer / loss_fn / eval_fn: exactly as ``DecentralizedTrainer``.
      config: round/epoch counts; ``mix_impl="pallas"`` routes aggregation
        through ``kernels.gossip_mix``; ``unroll_eval=True`` makes
        :meth:`run` default to the incremental per-round loop.
    """

    def __init__(
        self,
        optimizer: Optimizer,
        loss_fn: Callable,
        eval_fn: Callable,
        config: DecentralizedConfig = DecentralizedConfig(),
    ):
        self.optimizer = optimizer
        self.loss_fn = loss_fn
        self.eval_fn = eval_fn
        self.config = config
        self._round_fn = make_round_fn(
            loss_fn, optimizer, config.local_epochs, config.mix_impl,
            config.epoch_shuffle)
        self._run_jit = jax.jit(
            self._run_impl, static_argnames=("batch_size",))
        self._round_jit = jax.jit(
            self._one_round_impl, static_argnames=("batch_size", "do_eval"))
        self._chunk_jit: Optional[Callable] = None
        self._sharded_cache: Dict[Tuple[Any, int], Callable] = {}

    # ------------------------------------------------------------------
    def _eval(self, stacked_params, test_iid, test_ood):
        iid = jax.vmap(lambda p: self.eval_fn(p, test_iid))(stacked_params)
        ood = jax.vmap(lambda p: self.eval_fn(p, test_ood))(stacked_params)
        return iid, ood

    def _experiment_scan(self, bank, batch_size, eval_mask, params, opt,
                         coeffs_e, idx_e, data_idx, test_iid, test_ood):
        """All R rounds of ONE experiment (vmapped over E by the callers):
        :func:`repro.core.decentralized.make_scan_fn` with the per-round
        batch realized as an in-scan gather from the shared bank."""
        scan_fn = make_scan_fn(
            self._round_fn, self._eval,
            make_batch=lambda ix: gather_round_batch(
                bank, data_idx, ix, batch_size))
        return scan_fn(params, opt, idx_e, coeffs_e, eval_mask,
                       test_iid, test_ood)

    def _run_impl(self, params0, opt0, coeffs, indices, data_idx, eval_mask,
                  bank, test_iid, test_ood, *, batch_size):
        run_one = lambda p, o, c, ix, d, ti, to: self._experiment_scan(
            bank, batch_size, eval_mask, p, o, c, ix, d, ti, to)
        return jax.vmap(run_one)(
            params0, opt0, coeffs, indices, data_idx, test_iid, test_ood)

    def _one_round_impl(self, params, opt, coeffs_r, idx_r, data_idx, bank,
                        test_iid, test_ood, *, batch_size, do_eval):
        def one(p, o, c, ix, d, ti, to):
            batch = gather_round_batch(bank, d, ix, batch_size)
            p, o, losses = self._round_fn(p, o, batch, c)
            if do_eval:
                iid, ood = self._eval(p, ti, to)
            else:
                n = jax.tree.leaves(p)[0].shape[0]
                iid = ood = jnp.zeros((n,))
            return p, o, losses, iid, ood

        return jax.vmap(one)(
            params, opt, coeffs_r, idx_r, data_idx, test_iid, test_ood)

    # ------------------------------------------------------------------
    # sharded / chunked mode
    # ------------------------------------------------------------------
    def _make_sharded_fn(self, mesh, batch_size: int) -> Callable:
        """``jit(shard_map(vmap_E(scan_R(...))))`` over the mesh's single
        experiment axis.  Per-experiment inputs/outputs shard on E; the
        sample bank and eval mask are replicated (every experiment reads
        the full bank).  The (params, opt) carry is donated where the
        backend supports it."""
        key = (mesh, batch_size)
        if key in self._sharded_cache:
            return self._sharded_cache[key]
        from jax.sharding import PartitionSpec as P

        from repro.core.gossip import compat_shard_map

        exp, rep = P(mesh.axis_names[0]), P()

        def body(params, opt, coeffs, idx, data_idx, eval_mask, bank,
                 test_iid, test_ood):
            return self._run_impl(params, opt, coeffs, idx, data_idx,
                                  eval_mask, bank, test_iid, test_ood,
                                  batch_size=batch_size)

        mapped = compat_shard_map(
            body, mesh,
            in_specs=(exp, exp, exp, exp, exp, rep, rep, exp, exp),
            out_specs=(exp, exp, exp, exp, exp))
        fn = jax.jit(
            mapped,
            donate_argnums=(0, 1) if donation_supported() else ())
        self._sharded_cache[key] = fn
        return fn

    def _make_chunk_fn(self, batch_size: int) -> Callable:
        """Single-device chunk step: the scanned program with a donated
        (params, opt) carry, re-dispatched per round-chunk."""
        if self._chunk_jit is None:
            self._chunk_jit = jax.jit(
                self._run_impl, static_argnames=("batch_size",),
                donate_argnums=(0, 1) if donation_supported() else ())
        return lambda *args: self._chunk_jit(*args, batch_size=batch_size)

    def _run_sharded(self, params0, opt0, coeffs, idx, data_idx, eval_mask,
                     bank, test_iid, test_ood, batch_size, mesh,
                     chunk_rounds: Optional[int]) -> SweepResult:
        """Sharded and/or chunked execution.  Bit-identical to the scanned
        path: padding rows are dropped, each chunk resumes the exact scan
        carry, and per-shard programs are the same per-experiment math."""
        n_exp, rounds = coeffs.shape[:2]
        test_iid = jax.tree.map(jnp.asarray, test_iid)
        test_ood = jax.tree.map(jnp.asarray, test_ood)

        if mesh is not None:
            n_dev = int(np.prod(list(mesh.shape.values())))
            pad = (-n_exp) % n_dev
            params0, opt0, coeffs, idx, data_idx, test_iid, test_ood = (
                pad_experiments(t, pad)
                for t in (params0, opt0, coeffs, idx, data_idx,
                          test_iid, test_ood))
            from jax.sharding import NamedSharding, PartitionSpec as P

            exp_sh = NamedSharding(mesh, P(mesh.axis_names[0]))
            rep_sh = NamedSharding(mesh, P())
            put = lambda t, s: jax.tree.map(
                lambda x: jax.device_put(jnp.asarray(x), s), t)
            # device_put materializes fresh buffers laid out on the mesh,
            # so donating the carry never invalidates caller arrays.
            params0, opt0, coeffs, idx, data_idx, test_iid, test_ood = (
                put(t, exp_sh)
                for t in (params0, opt0, coeffs, idx, data_idx,
                          test_iid, test_ood))
            bank = put(bank, rep_sh)
            fn = self._make_sharded_fn(mesh, batch_size)
        else:
            if donation_supported():
                # chunk 0 would donate the caller's params0 — copy once
                params0 = jax.tree.map(
                    lambda x: jnp.asarray(x).copy(), params0)
            fn = self._make_chunk_fn(batch_size)

        chunk = chunk_rounds or rounds
        params, opt = params0, opt0
        losses, iids, oods = [], [], []
        for a in range(0, rounds, chunk):
            b = min(a + chunk, rounds)
            params, opt, l_c, iid_c, ood_c = fn(
                params, opt, coeffs[:, a:b], idx[:, a:b], data_idx,
                jnp.asarray(eval_mask[a:b]), bank, test_iid, test_ood)
            losses.append(np.asarray(l_c))
            iids.append(np.asarray(iid_c))
            oods.append(np.asarray(ood_c))

        out_params = jax.tree.map(lambda x: x[:n_exp], params)
        cat = lambda xs: np.concatenate(xs, axis=1)[:n_exp]
        return SweepResult(
            train_loss=cat(losses), iid_acc=cat(iids), ood_acc=cat(oods),
            params=out_params, eval_every=self.config.eval_every)

    # ------------------------------------------------------------------
    def run(
        self,
        params0,                      # pytree, leaves (E, n, ...)
        coeffs: np.ndarray,           # (E, R, n, n)
        bank,                         # pytree, leaves (D, n, cap, ...)
        indices: np.ndarray,          # (D, R, n, S)
        data_idx: np.ndarray,         # (E,) rows into bank/indices
        test_iid,                     # pytree, leaves (E, b, ...)
        test_ood,
        batch_size: int,
        unroll_eval: Optional[bool] = None,
        mesh=None,                    # 1-D jax Mesh → shard the E axis
        chunk_rounds: Optional[int] = None,  # scan R in ⌈R/c⌉ chunks
    ) -> SweepResult:
        """Run the whole grid.  ``unroll_eval`` overrides the config flag
        (None → use ``config.unroll_eval``).  ``mesh`` (from
        ``repro.launch.mesh.make_sweep_mesh``) shards the experiment axis
        across devices; ``chunk_rounds`` bounds device memory for long
        schedules.  All modes are bit-identical."""
        coeffs = jnp.asarray(coeffs, jnp.float32)
        data_idx = jnp.asarray(data_idx, jnp.int32)
        # (E, R, n, S): per-experiment index schedule, pre-gathered host-side
        # (tiny — int32; the sample bank itself stays (D, ...)-shaped).
        idx = jnp.asarray(np.asarray(indices, np.int32)[np.asarray(data_idx)])
        bank = jax.tree.map(jnp.asarray, bank)
        opt0 = jax.vmap(jax.vmap(self.optimizer.init))(params0)
        rounds = coeffs.shape[1]
        eval_mask = np.zeros(rounds, bool)
        eval_mask[eval_round_indices(rounds, self.config.eval_every)] = True

        unroll = (self.config.unroll_eval if unroll_eval is None
                  else unroll_eval)
        if unroll:
            if mesh is not None or chunk_rounds:
                raise ValueError(
                    "mesh/chunk_rounds are scanned-mode options; they "
                    "cannot combine with unroll_eval=True")
            return self._run_unrolled(
                params0, opt0, coeffs, idx, data_idx, eval_mask, bank,
                test_iid, test_ood, batch_size)

        if mesh is not None or chunk_rounds:
            return self._run_sharded(
                params0, opt0, coeffs, idx, data_idx, eval_mask, bank,
                test_iid, test_ood, batch_size, mesh, chunk_rounds)

        params, _, losses, iid, ood = self._run_jit(
            params0, opt0, coeffs, idx, data_idx, jnp.asarray(eval_mask),
            bank, test_iid, test_ood, batch_size=batch_size)
        return SweepResult(
            train_loss=np.asarray(losses), iid_acc=np.asarray(iid),
            ood_acc=np.asarray(ood), params=params,
            eval_every=self.config.eval_every)

    def _run_unrolled(self, params, opt, coeffs, idx, data_idx, eval_mask,
                      bank, test_iid, test_ood, batch_size) -> SweepResult:
        """Escape hatch: per-round dispatch, incremental metrics."""
        losses, iids, oods = [], [], []
        for r in range(coeffs.shape[1]):
            params, opt, l_r, iid_r, ood_r = self._round_jit(
                params, opt, coeffs[:, r], idx[:, r], data_idx, bank,
                test_iid, test_ood, batch_size=batch_size,
                do_eval=bool(eval_mask[r]))
            losses.append(np.asarray(l_r))
            iids.append(np.asarray(iid_r))
            oods.append(np.asarray(ood_r))
        return SweepResult(
            train_loss=np.stack(losses, axis=1),
            iid_acc=np.stack(iids, axis=1),
            ood_acc=np.stack(oods, axis=1),
            params=params, eval_every=self.config.eval_every)
