"""Batched experiment-sweep engine: vmap over experiments × scan over rounds.

The paper's findings are all *sweeps* — over strategies (Fig. 4), OOD
placements (Fig. 5), topologies (Fig. 6), and seeds.  Every cell of such a
grid runs the same program shape (same n, R, model, batch geometry); only
the *data* differs: initial params, per-round mixing matrices, sample
indices, test batches.  This module exploits that: ONE jitted program —
``vmap`` over the experiment axis E of the ``lax.scan`` over rounds from
``repro.core.decentralized`` — evaluates a whole figure's grid in a single
device dispatch (DESIGN.md §7).

Inputs per experiment (leading axis E):

* ``params0``   — stacked initial node models, leaves ``(E, n, ...)``;
* ``coeffs``    — EITHER an ``(E, R, n, n)`` stack of per-round mixing
  matrices (:func:`repro.core.decentralized.coeffs_stack`; Random
  resampling and ``core.dynamic`` link-failure schedules are just
  different stacks) OR a :class:`repro.core.coeffs.ProgramCoeffs` — a
  device-side coefficient program plus compact per-experiment state
  (leaves ``(E, ...)``, ~n² floats instead of R·n²), whose matrices are
  generated *inside* the scan (DESIGN.md §9; required for reactive
  link-failure strategies, bit-identical to the materialized stack for
  everything else);
* ``data_idx``  — ``(E,)`` row into the shared data bank;
* ``test_iid`` / ``test_ood`` — per-experiment test batches, leaves
  ``(E, b, ...)``.

Shared across experiments:

* ``bank``      — padded per-node sample bank, leaves ``(D, n, cap, ...)``
  (``NodeBatcher.sample_bank``); experiments sharing a data configuration
  (same seed/OOD placement) share a bank row, so memory scales with the
  number of *distinct* datasets D, not with E;
* ``indices``   — ``(D, R, n, S)`` per-round sample indices
  (``NodeBatcher.all_round_indices``) — batches are a per-round gather
  inside the scan, never materialized as an ``(E, R, ...)`` tensor.

Three execution modes of the same program family (DESIGN.md §7/§8), all
bit-for-bit identical (tests/test_sweep.py, tests/test_sweep_sharded.py):

* **scanned** (default): ``jit(vmap_E(scan_R(round)))`` on one device;
* **sharded-scanned** (``mesh=...``): the E axis is laid across a 1-D
  device mesh (``repro.launch.mesh.make_sweep_mesh``) with ``shard_map``
  — E is padded to a multiple of the mesh size with dummy experiments
  (copies of experiment 0, masked out of the returned result) and each
  device runs the identical per-experiment program on its slice, so
  sharding cannot change any real experiment's arithmetic;
* **unrolled** (``unroll_eval=True``): the legacy per-round Python loop,
  preserving the incremental history API (one dispatch per round,
  metrics available as they stream).

Orthogonally, ``chunk_rounds=c`` scans the round schedule in ``⌈R/c⌉``
chunks: the device-resident ``(R, n, S)`` index schedule, ``(R, n, n)``
coefficient slab, and ``(R, n)`` eval accumulators stay bounded at one
chunk while the host concatenates per-chunk metrics — the long-run mode.
The ``(params, opt)`` carry is donated back into each chunk (and into
the one-shot scans) on backends that support buffer donation, so the
scan never double-allocates the model/optimizer state.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.analytics import AnalyticsSpec
from repro.core.coeffs import CoeffProgram, ProgramCoeffs
from repro.core.decentralized import (
    DecentralizedConfig,
    RoundMetrics,
    eval_round_indices,
    fault_carry_init,
    make_fault_round_fn,
    make_participation_round_fn,
    make_round_fn,
    make_scan_fn,
    participation_carry_init,
)
from repro.core.dynamic import FaultSpec, ParticipationSpec
from repro.training.optimizer import Optimizer

__all__ = ["SweepEngine", "SweepResult", "gather_round_batch",
           "pad_experiments", "donation_supported",
           "DONATED_CARRY_ARGNUMS"]

#: The (params, opt) carry positions the chunked and sharded modes donate
#: (DESIGN.md §8) — introspectable metadata shared by the jit wrappers
#: below and the ``repro.analysis`` donation rule, so the analyzer checks
#: the same contract the engine declares.
DONATED_CARRY_ARGNUMS: Tuple[int, ...] = (0, 1)


def donation_supported() -> bool:
    """Buffer donation is a no-op (with a warning) on CPU; only donate
    where XLA actually reuses the buffers."""
    return jax.default_backend() in ("gpu", "tpu")


def pad_experiments(tree: Any, pad: int) -> Any:
    """Grow every leaf's leading E axis by ``pad`` dummy experiments —
    copies of experiment 0, so the padded program is numerically valid and
    the padding rows are simply dropped from the result.  Identity when
    ``pad == 0``."""
    if pad == 0:
        return tree
    return jax.tree.map(
        lambda x: jnp.concatenate(
            [jnp.asarray(x),
             jnp.broadcast_to(jnp.asarray(x)[:1],
                              (pad,) + tuple(np.shape(x)[1:]))], axis=0),
        tree)


def gather_round_batch(bank: Dict[str, jnp.ndarray], data_idx: jnp.ndarray,
                       idx_r: jnp.ndarray, batch_size: int):
    """One round of per-node batches for one experiment, gathered straight
    from the (D, n, cap, ...) bank.

    ``idx_r``: (n, S) sample indices (S = steps·batch) into each node's
    bank row.  Returns the exact pytree ``NodeBatcher.round_batches``
    yields — leaves (n, steps, batch, ...) — including the all-ones LM
    loss mask.
    """
    n, s = idx_r.shape
    steps = s // batch_size
    rows = jnp.arange(n)[:, None]

    def g(leaf: jnp.ndarray) -> jnp.ndarray:
        out = leaf[data_idx, rows, idx_r]  # (n, S, ...)
        return out.reshape((n, steps, batch_size) + leaf.shape[3:])

    batch = {k: g(v) for k, v in bank.items()}
    if "tokens" in batch:  # LM: trainer consumes an all-ones train mask
        seq = batch["tokens"].shape[-1]
        batch["mask"] = jnp.ones((n, steps, batch_size, seq - 1), jnp.float32)
    return batch


def _finalize_analytics(analytics: Optional[AnalyticsSpec], acarry,
                        n_exp: int) -> Optional[Dict[str, np.ndarray]]:
    """Vmapped ``AnalyticsSpec.finalize`` over the E axis, padding rows
    dropped — the ``SweepResult.analytics`` payload."""
    if analytics is None:
        return None
    out = jax.vmap(analytics.finalize)(acarry)
    return {k: np.asarray(v)[:n_exp] for k, v in out.items()}


def _finalize_participation(participation: Optional[ParticipationSpec],
                            pcarry, n_exp: int,
                            rounds: int) -> Optional[Dict[str, np.ndarray]]:
    """Host digest of the participation carry, padding rows dropped — the
    ``SweepResult.participation`` payload (all ``(E, n)``)."""
    if participation is None:
        return None
    return {
        "rounds_active": np.asarray(pcarry["rounds_active"])[:n_exp],
        "final_staleness": np.asarray(pcarry["staleness"])[:n_exp],
        "mean_staleness": (np.asarray(pcarry["staleness_sum"], np.float64)
                           [:n_exp] / max(rounds, 1)),
        "local_steps": np.asarray(pcarry["local_steps"])[:n_exp],
    }


def _finalize_fault(fault: Optional[FaultSpec], fcarry,
                    n_exp: int) -> Optional[Dict[str, np.ndarray]]:
    """Host digest of the fault/quarantine carry, padding rows dropped —
    the ``SweepResult.fault`` payload (all ``(E, n)``; consumed by
    ``repro.core.analytics.quarantine_summary``)."""
    if fault is None:
        return None
    return {k: np.asarray(fcarry[k])[:n_exp]
            for k in ("fault_rounds", "rounds_quarantined",
                      "quar_fault_rounds", "first_fault", "first_quar")}


def _split_engine_out(out, participation, analytics, fault=None):
    """Unpack a ``make_scan_fn`` output tuple — ``(params, opt[, pcarry]
    [, fcarry][, acarry][, losses, iid, ood])`` — into its six slots
    (missing ones come back ``None``/``{}``/history ``None``)."""
    params, opt = out[0], out[1]
    rest = list(out[2:])
    pcarry = rest.pop(0) if participation is not None else None
    fcarry = rest.pop(0) if fault is not None else None
    acarry = rest.pop(0) if analytics is not None else {}
    return (params, opt, pcarry, fcarry, acarry,
            (tuple(rest) if rest else None))


def _save_sweep_checkpoint(directory, rounds_done, params, opt, acarry,
                           pcarry, fcarry, losses, iids, oods,
                           keep_history) -> str:
    """Persist the FULL chunk-boundary scan state — model, optimizer,
    every carry, and the host-side history so far — as one atomic
    checkpoint (``repro.training.checkpoint.save_checkpoint``: tmp +
    rename, so a crash mid-write leaves the previous checkpoint intact).
    The state pytree rides the ``params`` slot; the variable-length
    history rides the ``opt_state`` slot (its round count is recorded in
    the metadata so restore can rebuild an exact skeleton)."""
    from repro.training.checkpoint import save_checkpoint

    state = {"params": params, "opt": opt, "acarry": acarry,
             "pcarry": pcarry, "fcarry": fcarry}
    hist = ({"losses": np.concatenate(losses, axis=1),
             "iids": np.concatenate(iids, axis=1),
             "oods": np.concatenate(oods, axis=1)}
            if keep_history and losses else None)
    return save_checkpoint(
        directory, rounds_done, state, hist,
        metadata={"rounds_done": int(rounds_done),
                  "keep_history": bool(keep_history)})


def _load_sweep_checkpoint(path, params, opt, acarry, pcarry, fcarry,
                           keep_history):
    """Inverse of :func:`_save_sweep_checkpoint` — restores into
    skeletons built from the CURRENT run's (post-padding) inputs, so a
    checkpoint from a differently-shaped run fails loudly with the
    offending tree path instead of resuming garbage."""
    import json
    import zipfile
    import zlib

    from repro.training.checkpoint import load_checkpoint

    try:
        with np.load(path, allow_pickle=False) as z:
            meta = json.loads(str(z["__meta__"]))
    except (zipfile.BadZipFile, zlib.error, EOFError) as e:
        raise ValueError(f"{path}: truncated or corrupt checkpoint ({e})")
    done = int(meta["rounds_done"])
    skel = {"params": params, "opt": opt, "acarry": acarry,
            "pcarry": pcarry, "fcarry": fcarry}
    if keep_history and done:
        e, n = np.shape(jax.tree.leaves(params)[0])[:2]
        h = np.zeros((e, done, n), np.float32)
        state, hist, meta = load_checkpoint(
            path, skel, {"losses": h, "iids": h, "oods": h})
        hist = {k: np.asarray(v) for k, v in hist.items()}
    else:
        state, _, meta = load_checkpoint(path, skel)
        hist = None
    return state, hist, meta


@dataclasses.dataclass
class SweepResult:
    """Stacked metrics for an E-experiment sweep.

    ``train_loss`` / ``iid_acc`` / ``ood_acc`` are ``(E, R, n)``;
    ``params`` is the final stacked pytree with leaves ``(E, n, ...)``.
    Accuracy rows are only populated at the rounds ``eval_every`` keeps
    (eval is gated inside the scan; skipped rounds are zeros).
    ``history(e)`` rebuilds the legacy per-experiment ``List[RoundMetrics]``
    (subsampled at ``eval_every`` exactly like ``DecentralizedTrainer.run``)
    for ``repro.core.propagation``.

    ``analytics`` (``SweepEngine.run(analytics=...)``) holds the finalized
    in-scan streaming summaries (DESIGN.md §10) — ``(E, n)`` arrays keyed
    ``iid_auc`` / ``ood_auc`` / ``gap_pct`` / ``iid_arrival`` /
    ``ood_arrival`` / ``final_iid_acc`` / ``final_ood_acc``.  With
    ``keep_history=False`` these are the ONLY metrics: the per-round
    arrays come back zero-length (``(E, 0, n)``, ``history(e) == []``),
    so a sweep's metric memory is O(E·n) instead of O(E·R·n).

    ``participation`` (``SweepEngine.run(participation=...)``) holds the
    per-node participation digest (DESIGN.md §15) — ``(E, n)`` arrays
    keyed ``rounds_active`` / ``final_staleness`` / ``mean_staleness``
    (Σ post-round staleness / R) / ``local_steps``.

    ``fault`` (``SweepEngine.run(fault=...)``) holds the per-node
    fault/quarantine digest (DESIGN.md §16) — ``(E, n)`` arrays keyed
    ``fault_rounds`` / ``rounds_quarantined`` / ``quar_fault_rounds`` /
    ``first_fault`` / ``first_quar`` (−1 = never), the inputs to
    ``repro.core.analytics.quarantine_summary``.
    """

    train_loss: np.ndarray
    iid_acc: np.ndarray
    ood_acc: np.ndarray
    params: Any
    eval_every: int = 1
    analytics: Optional[Dict[str, np.ndarray]] = None
    participation: Optional[Dict[str, np.ndarray]] = None
    fault: Optional[Dict[str, np.ndarray]] = None

    @property
    def n_experiments(self) -> int:
        return self.train_loss.shape[0]

    @property
    def rounds(self) -> int:
        return self.train_loss.shape[1]

    def history(self, e: int) -> List[RoundMetrics]:
        return [
            RoundMetrics(round=r, iid_acc=self.iid_acc[e, r],
                         ood_acc=self.ood_acc[e, r],
                         train_loss=self.train_loss[e, r])
            for r in eval_round_indices(self.rounds, self.eval_every)
        ]

    def experiment_params(self, e: int):
        return jax.tree.map(lambda x: x[e], self.params)


class SweepEngine:
    """Compiles (strategy × seed × placement × topology) grids into one
    program: ``jit(vmap_E(scan_R(round)))``.

    Args:
      optimizer / loss_fn / eval_fn: exactly as ``DecentralizedTrainer``.
      config: round/epoch counts; ``mix_impl="pallas"`` routes aggregation
        through ``kernels.gossip_mix``; ``unroll_eval=True`` makes
        :meth:`run` default to the incremental per-round loop.
      mix_support: required by ``mix_impl="sparse"`` and ``"edges"`` —
        the (n, n) union support mask fixing the static schedule (ring
        offsets / padded-ELL neighbour tables).  :meth:`run` validates
        every grid's coefficients against the schedule's coverage and
        raises rather than let off-schedule weight be silently dropped.
    """

    def __init__(
        self,
        optimizer: Optimizer,
        loss_fn: Callable,
        eval_fn: Callable,
        config: DecentralizedConfig = DecentralizedConfig(),
        mix_support: Optional[np.ndarray] = None,
    ):
        self.optimizer = optimizer
        self.loss_fn = loss_fn
        self.eval_fn = eval_fn
        self.config = config
        self._mix_support = mix_support
        self._round_fn = make_round_fn(
            loss_fn, optimizer, config.local_epochs, config.mix_impl,
            config.epoch_shuffle, mix_support=mix_support,
            sparse_slack=config.sparse_slack,
            mix_in_float32=config.mix_in_float32,
            robust=config.robust, robust_trim=config.robust_trim,
            robust_clip=config.robust_clip)
        self._run_jit = jax.jit(
            self._run_impl,
            static_argnames=("batch_size", "program", "analytics",
                             "keep_history", "participation", "fault"))
        self._round_jit = jax.jit(
            self._one_round_impl,
            static_argnames=("batch_size", "do_eval", "program",
                             "analytics", "participation", "fault"))
        self._chunk_jit: Dict[bool, Callable] = {}
        self._sharded_cache: Dict[Tuple[Any, ...], Callable] = {}
        self._part_round_fns: Dict[ParticipationSpec, Callable] = {}
        self._fault_round_fns: Dict[Tuple[Any, ...], Callable] = {}

    def _participation_round_fn(self, spec: ParticipationSpec) -> Callable:
        """Lazily-built (and cached — the fn's identity keys the jit
        traces) partial-participation round for this engine's config."""
        fn = self._part_round_fns.get(spec)
        if fn is None:
            fn = make_participation_round_fn(
                self.loss_fn, self.optimizer, self.config.local_epochs,
                spec, mix_impl=self.config.mix_impl,
                epoch_shuffle=self.config.epoch_shuffle,
                mix_support=self._mix_support,
                sparse_slack=self.config.sparse_slack,
                mix_in_float32=self.config.mix_in_float32,
                robust=self.config.robust,
                robust_trim=self.config.robust_trim,
                robust_clip=self.config.robust_clip)
            self._part_round_fns[spec] = fn
        return fn

    def _fault_round_fn(self, spec: FaultSpec,
                        participation: Optional[ParticipationSpec],
                        ) -> Callable:
        """Lazily-built (and cached) Byzantine-fault round — keyed on both
        specs since participation changes the round signature."""
        key = (spec, participation)
        fn = self._fault_round_fns.get(key)
        if fn is None:
            fn = make_fault_round_fn(
                self.loss_fn, self.optimizer, self.config.local_epochs,
                spec, participation=participation,
                mix_impl=self.config.mix_impl,
                epoch_shuffle=self.config.epoch_shuffle,
                mix_support=self._mix_support,
                sparse_slack=self.config.sparse_slack,
                mix_in_float32=self.config.mix_in_float32,
                robust=self.config.robust,
                robust_trim=self.config.robust_trim,
                robust_clip=self.config.robust_clip)
            self._fault_round_fns[key] = fn
        return fn

    # ------------------------------------------------------------------
    def _check_sparse_support(self, coeffs, program, states) -> None:
        """mix_impl='sparse' / 'edges' silently drop weight outside their
        static schedule (ring offsets / padded-ELL neighbour tables) —
        refuse grids whose coefficients the caller-supplied
        ``mix_support`` cannot express (sub-stochastic mixing would
        return quietly wrong results).  The circulant path's
        dense-fallback schedule covers everything, so no check applies
        there; the edge-list tables cover exactly ``support ∪ diag``."""
        from repro.core.coeffs import PROGRAM_KINDS
        from repro.core.decentralized import sparse_schedule

        if self._mix_support is None:
            return  # make_round_fn already raised in __init__
        if (self.config.mix_impl == "edges"
                or self.config.robust in ("trimmed", "median")):
            s = np.asarray(self._mix_support)
            covered = (s > 0) | np.eye(s.shape[0], dtype=bool)
        else:
            _, covered = sparse_schedule(self._mix_support,
                                         self.config.sparse_slack)
            if covered is None:
                return  # fell back to mix_dense
        if program is None:
            used = np.asarray(
                jnp.any(jnp.abs(coeffs) > 1e-12, axis=(0, 1)))
        else:
            adj = np.asarray(jax.tree.map(jnp.asarray, states)["adj"])
            n = adj.shape[-1]
            used = (np.abs(adj).max(axis=0) > 0) | np.eye(n, dtype=bool)
            if np.any(np.asarray(states["kind"])
                      == PROGRAM_KINDS.index("fl")):
                used = np.ones_like(used)  # fl's matrix is dense 1/n
        if np.any(used & ~covered):
            raise ValueError(
                f"mix_impl={self.config.mix_impl!r}: coefficients carry "
                "weight outside the mix_support schedule (ring offsets / "
                "neighbour tables), which the sparse mix would silently "
                "drop (sub-stochastic mixing); widen mix_support or use "
                "mix_impl='einsum'")

    # ------------------------------------------------------------------
    def _eval(self, stacked_params, test_iid, test_ood):
        iid = jax.vmap(lambda p: self.eval_fn(p, test_iid))(stacked_params)
        ood = jax.vmap(lambda p: self.eval_fn(p, test_ood))(stacked_params)
        return iid, ood

    def _experiment_scan(self, bank, batch_size, eval_mask, rounds_idx,
                         params, opt, coeffs_e, idx_e, data_idx, test_iid,
                         test_ood, acarry_e, pcarry_e, fcarry_e=None,
                         program=None, state_e=None, analytics=None,
                         keep_history=True, participation=None,
                         fault=None):
        """All R rounds of ONE experiment (vmapped over E by the callers):
        :func:`repro.core.decentralized.make_scan_fn` with the per-round
        batch realized as an in-scan gather from the shared bank.  With a
        ``program``, ``coeffs_e`` carries the (R,) absolute round indices
        and each step's matrix is computed in-scan from ``state_e``.  With
        an ``analytics`` spec, ``acarry_e`` is this experiment's streaming
        accumulator carry and ``rounds_idx`` the (R,) absolute indices;
        with a ``participation`` spec, ``pcarry_e`` its participation
        carry (stale plane + staleness counters, DESIGN.md §15); with a
        ``fault`` spec, ``fcarry_e`` its fault/quarantine carry
        (DESIGN.md §16)."""
        coeff_fn = (None if program is None
                    else (lambda r: program.matrix(state_e, r)))
        if fault is not None:
            round_fn = self._fault_round_fn(fault, participation)
        elif participation is not None:
            round_fn = self._participation_round_fn(participation)
        else:
            round_fn = self._round_fn
        scan_fn = make_scan_fn(
            round_fn, self._eval,
            make_batch=lambda ix: gather_round_batch(
                bank, data_idx, ix, batch_size),
            coeff_fn=coeff_fn, analytics=analytics,
            keep_history=keep_history, participation=participation,
            fault=fault)
        kwargs = {}
        if analytics is not None:
            kwargs.update(round_idx=rounds_idx, analytics_carry=acarry_e)
        if participation is not None:
            kwargs.update(round_idx=rounds_idx,
                          participation_carry=pcarry_e)
        if fault is not None:
            kwargs.update(round_idx=rounds_idx, fault_carry=fcarry_e)
        return scan_fn(params, opt, idx_e, coeffs_e, eval_mask,
                       test_iid, test_ood, **kwargs)

    def _run_impl(self, params0, opt0, coeffs, indices, data_idx, eval_mask,
                  rounds_idx, bank, test_iid, test_ood, states, acarry,
                  pcarry, fcarry={}, *, batch_size, program=None,
                  analytics=None, keep_history=True, participation=None,
                  fault=None):
        run_one = lambda p, o, c, ix, d, ti, to, st, ac, pc, fc: (
            self._experiment_scan(
                bank, batch_size, eval_mask, rounds_idx, p, o, c, ix, d,
                ti, to, ac, pc, fc, program, st, analytics, keep_history,
                participation, fault))
        return jax.vmap(run_one)(
            params0, opt0, coeffs, indices, data_idx, test_iid, test_ood,
            states, acarry, pcarry, fcarry)

    def _one_round_impl(self, params, opt, coeffs_r, idx_r, data_idx, bank,
                        test_iid, test_ood, states, acarry, pcarry, fcarry,
                        round_r, *, batch_size, do_eval, program=None,
                        analytics=None, participation=None, fault=None):
        def one(p, o, c, ix, d, ti, to, st, ac, pc, fc):
            if program is not None:
                c = program.matrix(st, c)  # c is this round's index
            batch = gather_round_batch(bank, d, ix, batch_size)
            if fault is not None:
                if participation is not None:
                    p, o, pc, fc, losses = self._fault_round_fn(
                        fault, participation)(p, o, pc, fc, batch, c,
                                              round_r)
                else:
                    p, o, fc, losses = self._fault_round_fn(
                        fault, None)(p, o, fc, batch, c, round_r)
            elif participation is None:
                p, o, losses = self._round_fn(p, o, batch, c)
            else:
                p, o, pc, losses = self._participation_round_fn(
                    participation)(p, o, pc, batch, c, round_r)
            if do_eval:
                iid, ood = self._eval(p, ti, to)
            else:
                n = jax.tree.leaves(p)[0].shape[0]
                iid = ood = jnp.zeros((n,))
            if analytics is not None and do_eval:
                ac = analytics.update(ac, round_r, True, iid, ood)
            return p, o, losses, iid, ood, ac, pc, fc

        return jax.vmap(one)(
            params, opt, coeffs_r, idx_r, data_idx, test_iid, test_ood,
            states, acarry, pcarry, fcarry)

    # ------------------------------------------------------------------
    # sharded / chunked mode
    # ------------------------------------------------------------------
    def _sharded_body(self, mesh, batch_size: int,
                      program: Optional[CoeffProgram],
                      analytics: Optional[AnalyticsSpec],
                      keep_history: bool,
                      participation: Optional[ParticipationSpec] = None,
                      fault: Optional[FaultSpec] = None,
                      ) -> Callable:
        """The un-jitted ``shard_map(vmap_E(scan_R(...)))`` program over
        the mesh's single experiment axis — shared by the executing
        wrapper below and by :meth:`traceable` for static analysis."""
        from jax.sharding import PartitionSpec as P

        from repro.core.gossip import compat_shard_map

        exp, rep = P(mesh.axis_names[0]), P()

        def body(params, opt, coeffs, idx, data_idx, eval_mask, rounds_idx,
                 bank, test_iid, test_ood, states, acarry, pcarry, fcarry):
            return self._run_impl(params, opt, coeffs, idx, data_idx,
                                  eval_mask, rounds_idx, bank, test_iid,
                                  test_ood, states, acarry, pcarry, fcarry,
                                  batch_size=batch_size, program=program,
                                  analytics=analytics,
                                  keep_history=keep_history,
                                  participation=participation,
                                  fault=fault)

        # outputs: (params, opt[, pcarry][, fcarry][, acarry][, losses,
        # iid, ood]) — all exp
        n_out = 2 + (1 if participation is not None else 0) \
            + (1 if fault is not None else 0) \
            + (1 if analytics is not None else 0) \
            + (3 if keep_history else 0)
        return compat_shard_map(
            body, mesh,
            in_specs=(exp, exp, exp, exp, exp, rep, rep, rep, exp, exp,
                      exp, exp, exp, exp),
            out_specs=(exp,) * n_out)

    def _make_sharded_fn(self, mesh, batch_size: int,
                         program: Optional[CoeffProgram],
                         analytics: Optional[AnalyticsSpec],
                         keep_history: bool, donate: bool,
                         participation: Optional[ParticipationSpec],
                         fault: Optional[FaultSpec] = None,
                         ) -> Callable:
        """``jit(shard_map(vmap_E(scan_R(...))))``.  Per-experiment
        inputs/outputs — including the coefficient-program states and the
        analytics/participation/fault carries — shard on E; the sample
        bank, eval mask, and absolute round indices are replicated (every
        experiment reads them whole).  The (params, opt) carry is donated
        when ``donate`` (``DONATED_CARRY_ARGNUMS``)."""
        key = (mesh, batch_size, program, analytics, keep_history, donate,
               participation, fault)
        if key in self._sharded_cache:
            return self._sharded_cache[key]
        fn = jax.jit(
            self._sharded_body(mesh, batch_size, program, analytics,
                               keep_history, participation, fault),
            donate_argnums=DONATED_CARRY_ARGNUMS if donate else ())
        self._sharded_cache[key] = fn
        return fn

    def _make_chunk_fn(self, batch_size: int,
                       program: Optional[CoeffProgram],
                       analytics: Optional[AnalyticsSpec],
                       keep_history: bool, donate: bool,
                       participation: Optional[ParticipationSpec],
                       fault: Optional[FaultSpec] = None,
                       ) -> Callable:
        """Single-device chunk step: the scanned program with a donated
        (params, opt) carry, re-dispatched per round-chunk."""
        if donate not in self._chunk_jit:
            self._chunk_jit[donate] = jax.jit(
                self._run_impl,
                static_argnames=("batch_size", "program", "analytics",
                                 "keep_history", "participation", "fault"),
                donate_argnums=DONATED_CARRY_ARGNUMS if donate else ())
        chunk_jit = self._chunk_jit[donate]
        return lambda *args: chunk_jit(
            *args, batch_size=batch_size, program=program,
            analytics=analytics, keep_history=keep_history,
            participation=participation, fault=fault)

    def _run_sharded(self, params0, opt0, coeffs, idx, data_idx, eval_mask,
                     bank, test_iid, test_ood, batch_size, mesh,
                     chunk_rounds: Optional[int], states, program,
                     acarry, analytics: Optional[AnalyticsSpec],
                     keep_history: bool, donate: bool, pcarry,
                     participation: Optional[ParticipationSpec],
                     fcarry={}, fault: Optional[FaultSpec] = None,
                     checkpoint_dir: Optional[str] = None,
                     resume: bool = False,
                     ) -> SweepResult:
        """Sharded and/or chunked execution.  Bit-identical to the scanned
        path: padding rows are dropped, each chunk resumes the exact scan
        carry — (params, opt) AND the analytics/participation/fault
        accumulators — round indices stay absolute in program, analytics,
        participation and fault mode, and per-shard programs are the same
        per-experiment math.

        ``checkpoint_dir`` makes the run crash-safe (DESIGN.md §16): the
        FULL scan state — params, optimizer, every carry, and the history
        accumulated so far — is persisted atomically
        (``repro.training.checkpoint``) at every chunk boundary, entirely
        outside the jitted scan.  ``resume=True`` restarts from
        ``latest_checkpoint`` and — because each chunk consumes absolute
        round indices and the carries resume exactly — reproduces the
        uninterrupted run bit-identically (tests/test_fault.py kills a
        sweep mid-run and proves it).  With no checkpoint on disk,
        ``resume=True`` degrades to a fresh start."""
        n_exp, rounds = coeffs.shape[:2]
        test_iid = jax.tree.map(jnp.asarray, test_iid)
        test_ood = jax.tree.map(jnp.asarray, test_ood)
        rounds_idx = jnp.arange(rounds, dtype=jnp.int32)

        if mesh is not None:
            n_dev = int(np.prod(list(mesh.shape.values())))
            pad = (-n_exp) % n_dev
            (params0, opt0, coeffs, idx, data_idx, test_iid, test_ood,
             states, acarry, pcarry, fcarry) = (
                pad_experiments(t, pad)
                for t in (params0, opt0, coeffs, idx, data_idx,
                          test_iid, test_ood, states, acarry, pcarry,
                          fcarry))
            from jax.sharding import NamedSharding, PartitionSpec as P

            exp_sh = NamedSharding(mesh, P(mesh.axis_names[0]))
            rep_sh = NamedSharding(mesh, P())
            put = lambda t, s: jax.tree.map(
                lambda x: jax.device_put(jnp.asarray(x), s), t)
            # device_put materializes fresh buffers laid out on the mesh,
            # so donating the carry never invalidates caller arrays.
            (params0, opt0, coeffs, idx, data_idx, test_iid, test_ood,
             states, acarry, pcarry, fcarry) = (
                put(t, exp_sh)
                for t in (params0, opt0, coeffs, idx, data_idx,
                          test_iid, test_ood, states, acarry, pcarry,
                          fcarry))
            bank = put(bank, rep_sh)
            rounds_idx = put(rounds_idx, rep_sh)
            fn = self._make_sharded_fn(mesh, batch_size, program,
                                       analytics, keep_history, donate,
                                       participation, fault)
            reput = lambda t: put(t, exp_sh)
        else:
            if donate:
                # chunk 0 would donate the caller's params0 — copy once
                params0 = jax.tree.map(
                    lambda x: jnp.asarray(x).copy(), params0)
            fn = self._make_chunk_fn(batch_size, program, analytics,
                                     keep_history, donate, participation,
                                     fault)
            reput = lambda t: jax.tree.map(jnp.asarray, t)

        chunk = chunk_rounds or rounds
        params, opt = params0, opt0
        losses, iids, oods = [], [], []
        start, chunks_done = 0, 0
        if checkpoint_dir is not None and resume:
            from repro.training.checkpoint import latest_checkpoint

            ck = latest_checkpoint(checkpoint_dir)
            if ck is not None:
                state, hist_np, meta = _load_sweep_checkpoint(
                    ck, params, opt, acarry, pcarry, fcarry, keep_history)
                params = reput(state["params"])
                opt = reput(state["opt"])
                if analytics is not None:
                    acarry = reput(state["acarry"])
                if participation is not None:
                    pcarry = reput(state["pcarry"])
                if fault is not None:
                    fcarry = reput(state["fcarry"])
                if keep_history and int(meta["rounds_done"]):
                    losses = [hist_np["losses"]]
                    iids = [hist_np["iids"]]
                    oods = [hist_np["oods"]]
                start = int(meta["rounds_done"])
        crash_after = int(os.environ.get(
            "REPRO_SWEEP_CRASH_AFTER_CHUNKS", "0"))
        for a in range(start, rounds, chunk):
            b = min(a + chunk, rounds)
            out = fn(
                params, opt, coeffs[:, a:b], idx[:, a:b], data_idx,
                jnp.asarray(eval_mask[a:b]), rounds_idx[a:b], bank,
                test_iid, test_ood, states, acarry, pcarry, fcarry)
            params, opt, pc_out, fc_out, ac_out, hist = _split_engine_out(
                out, participation, analytics, fault)
            if participation is not None:
                pcarry = pc_out
            if fault is not None:
                fcarry = fc_out
            if analytics is not None:
                acarry = ac_out
            if keep_history:
                l_c, iid_c, ood_c = hist
                losses.append(np.asarray(l_c))
                iids.append(np.asarray(iid_c))
                oods.append(np.asarray(ood_c))
            chunks_done += 1
            if checkpoint_dir is not None and b < rounds:
                _save_sweep_checkpoint(
                    checkpoint_dir, b, params, opt, acarry, pcarry,
                    fcarry, losses, iids, oods, keep_history)
                if crash_after and chunks_done >= crash_after:
                    # test hook: die WITHOUT cleanup, exactly like a
                    # preempted host (tests/test_fault.py kill-and-resume)
                    os._exit(17)

        out_params = jax.tree.map(lambda x: x[:n_exp], params)
        if keep_history:
            cat = lambda xs: np.concatenate(xs, axis=1)[:n_exp]
            l, i, o = cat(losses), cat(iids), cat(oods)
        else:
            n = jax.tree.leaves(out_params)[0].shape[1]
            l = i = o = np.zeros((n_exp, 0, n), np.float32)
        return SweepResult(
            train_loss=l, iid_acc=i, ood_acc=o, params=out_params,
            eval_every=self.config.eval_every,
            analytics=_finalize_analytics(analytics, acarry, n_exp),
            participation=_finalize_participation(
                participation, pcarry, n_exp, rounds),
            fault=_finalize_fault(fault, fcarry, n_exp))

    # ------------------------------------------------------------------
    def _prepare_inputs(self, params0, coeffs, bank, indices, data_idx,
                        analytics: Optional[AnalyticsSpec],
                        keep_history: bool,
                        participation: Optional[ParticipationSpec] = None,
                        participation_rates=None,
                        participation_seeds=None,
                        fault: Optional[FaultSpec] = None,
                        fault_rates=None,
                        fault_seeds=None):
        """Shared input normalization for :meth:`run` and
        :meth:`traceable` — program/stack resolution, support validation,
        index gathering, optimizer/analytics/participation/fault carry
        construction."""
        if fault is not None:
            # build (and cache) the fault round fn OUTSIDE any jit trace
            # (same trace-time-constant reasoning as participation below)
            self._fault_round_fn(fault, participation)
        elif participation is not None:
            # build (and cache) the participation round fn OUTSIDE any jit
            # trace: make_mix_fn bakes trace-time constants (e.g. the
            # padded-ELL neighbour tables) into the closure, which must
            # not be tracers of whichever program first used the fn
            self._participation_round_fn(participation)
        program: Optional[CoeffProgram] = None
        states: Any = {}
        if isinstance(coeffs, ProgramCoeffs):
            program = coeffs.program
            # a kind-pruned program silently remaps unlisted kinds — refuse
            program.validate_state_kinds(coeffs.states)
            states = jax.tree.map(jnp.asarray, coeffs.states)
            n_exp = coeffs.n_experiments
            rounds = int(np.asarray(indices).shape[1])
            # the scanned xs: absolute int32 round indices, (E, R) so the
            # existing chunk slicing / E-padding / E-sharding apply as-is
            coeffs = jnp.broadcast_to(
                jnp.arange(rounds, dtype=jnp.int32)[None], (n_exp, rounds))
        else:
            coeffs = jnp.asarray(coeffs, jnp.float32)
            rounds = coeffs.shape[1]
        if (self.config.mix_impl in ("sparse", "edges")
                or self.config.robust in ("trimmed", "median")):
            self._check_sparse_support(coeffs, program, states)
        if not keep_history and analytics is None:
            raise ValueError("keep_history=False without an analytics "
                             "spec would return no metrics at all")
        data_idx = jnp.asarray(data_idx, jnp.int32)
        # (E, R, n, S): per-experiment index schedule, pre-gathered host-side
        # (tiny — int32; the sample bank itself stays (D, ...)-shaped).
        idx = jnp.asarray(np.asarray(indices, np.int32)[np.asarray(data_idx)])
        bank = jax.tree.map(jnp.asarray, bank)
        opt0 = jax.vmap(jax.vmap(self.optimizer.init))(params0)
        eval_mask = np.zeros(rounds, bool)
        eval_mask[eval_round_indices(rounds, self.config.eval_every)] = True
        n_exp = jax.tree.leaves(params0)[0].shape[0]
        n_nodes = jax.tree.leaves(params0)[0].shape[1]
        acarry = (analytics.init_batch(n_exp, n_nodes)
                  if analytics is not None else {})
        if participation is None:
            if participation_rates is not None or \
                    participation_seeds is not None:
                raise ValueError("participation_rates/participation_seeds "
                                 "need a ParticipationSpec (participation=)")
            pcarry = {}
        else:
            rates = (np.ones(n_exp, np.float32)
                     if participation_rates is None
                     else np.broadcast_to(
                         np.asarray(participation_rates, np.float32),
                         (n_exp,)))
            seeds = (np.asarray(participation.seed + np.arange(n_exp),
                                np.uint32)
                     if participation_seeds is None
                     else np.broadcast_to(
                         np.asarray(participation_seeds, np.uint32),
                         (n_exp,)))
            pcarry = jax.vmap(participation_carry_init)(
                params0, jnp.asarray(rates), jnp.asarray(seeds))
        if fault is None:
            if fault_rates is not None or fault_seeds is not None:
                raise ValueError("fault_rates/fault_seeds need a "
                                 "FaultSpec (fault=)")
            fcarry = {}
        else:
            frates = (np.zeros(n_exp, np.float32)
                      if fault_rates is None
                      else np.broadcast_to(
                          np.asarray(fault_rates, np.float32), (n_exp,)))
            fseeds = (np.asarray(fault.seed + np.arange(n_exp), np.uint32)
                      if fault_seeds is None
                      else np.broadcast_to(
                          np.asarray(fault_seeds, np.uint32), (n_exp,)))
            fcarry = jax.vmap(fault_carry_init)(
                params0, jnp.asarray(frates), jnp.asarray(fseeds))
        return (params0, opt0, coeffs, idx, data_idx, eval_mask, bank,
                states, program, acarry, pcarry, fcarry, rounds, n_exp,
                n_nodes)

    def traceable(
        self,
        params0,
        coeffs,
        bank,
        indices: np.ndarray,
        data_idx: np.ndarray,
        test_iid,
        test_ood,
        batch_size: int,
        mode: str = "scanned",
        mesh=None,
        chunk_rounds: Optional[int] = None,
        analytics: Optional[AnalyticsSpec] = None,
        keep_history: bool = True,
        donate: Optional[bool] = None,
        participation: Optional[ParticipationSpec] = None,
        participation_rates=None,
        participation_seeds=None,
        fault: Optional[FaultSpec] = None,
        fault_rates=None,
        fault_seeds=None,
    ) -> Tuple[Callable, Tuple[Any, ...], Dict[str, Any]]:
        """``(fn, args, jit_kwargs)`` for static analysis — the exact
        program each execution mode runs, as a traceable closure plus
        concrete arguments, consumed by ``repro.analysis``
        (``jax.make_jaxpr(fn)(*args)`` /
        ``jax.jit(fn, **jit_kwargs).lower(*args)``).

        ``mode``: ``"scanned"`` (the one-shot jit), ``"chunked"`` (one
        donated round-chunk step — ``chunk_rounds`` bounds it),
        ``"mesh"`` (the shard_map program over ``mesh``), or
        ``"unrolled"`` (one per-round dispatch with eval).  ``donate``
        defaults to the run-time decision (:func:`donation_supported`);
        pass ``True`` to analyze donation intent on CPU, where run()
        skips it only because the backend ignores donation."""
        (params0, opt0, coeffs, idx, data_idx, eval_mask, bank, states,
         program, acarry, pcarry, fcarry, rounds, n_exp, n_nodes) = \
            self._prepare_inputs(
                params0, coeffs, bank, indices, data_idx, analytics,
                keep_history, participation, participation_rates,
                participation_seeds, fault, fault_rates, fault_seeds)
        donate = donation_supported() if donate is None else donate
        rounds_idx = jnp.arange(rounds, dtype=jnp.int32)
        eval_mask = jnp.asarray(eval_mask)
        test_iid = jax.tree.map(jnp.asarray, test_iid)
        test_ood = jax.tree.map(jnp.asarray, test_ood)

        if mode == "unrolled":
            fn = functools.partial(
                self._one_round_impl, batch_size=batch_size, do_eval=True,
                program=program, analytics=analytics,
                participation=participation, fault=fault)
            args = (params0, opt0, coeffs[:, 0], idx[:, 0], data_idx, bank,
                    test_iid, test_ood, states, acarry, pcarry, fcarry,
                    jnp.asarray(0, jnp.int32))
            return fn, args, {}

        if mode in ("scanned", "chunked"):
            fn = functools.partial(
                self._run_impl, batch_size=batch_size, program=program,
                analytics=analytics, keep_history=keep_history,
                participation=participation, fault=fault)
            c = rounds if mode == "scanned" else (chunk_rounds or rounds)
            args = (params0, opt0, coeffs[:, :c], idx[:, :c], data_idx,
                    eval_mask[:c], rounds_idx[:c], bank, test_iid,
                    test_ood, states, acarry, pcarry, fcarry)
            jit_kwargs = ({} if mode == "scanned" else
                          {"donate_argnums":
                           DONATED_CARRY_ARGNUMS if donate else ()})
            return fn, args, jit_kwargs

        if mode == "mesh":
            if mesh is None:
                from repro.launch.mesh import make_sweep_mesh

                mesh = make_sweep_mesh()
            n_dev = int(np.prod(list(mesh.shape.values())))
            pad = (-n_exp) % n_dev
            (params0, opt0, coeffs, idx, data_idx, test_iid, test_ood,
             states, acarry, pcarry, fcarry) = (
                pad_experiments(t, pad)
                for t in (params0, opt0, coeffs, idx, data_idx,
                          test_iid, test_ood, states, acarry, pcarry,
                          fcarry))
            fn = self._sharded_body(mesh, batch_size, program, analytics,
                                    keep_history, participation, fault)
            args = (params0, opt0, coeffs, idx, data_idx, eval_mask,
                    rounds_idx, bank, test_iid, test_ood, states, acarry,
                    pcarry, fcarry)
            return fn, args, {"donate_argnums":
                              DONATED_CARRY_ARGNUMS if donate else ()}

        raise KeyError(f"unknown mode {mode!r}; have 'scanned', "
                       f"'chunked', 'mesh', 'unrolled'")

    # ------------------------------------------------------------------
    def run(
        self,
        params0,                      # pytree, leaves (E, n, ...)
        coeffs,                       # (E, R, n, n) stack | ProgramCoeffs
        bank,                         # pytree, leaves (D, n, cap, ...)
        indices: np.ndarray,          # (D, R, n, S)
        data_idx: np.ndarray,         # (E,) rows into bank/indices
        test_iid,                     # pytree, leaves (E, b, ...)
        test_ood,
        batch_size: int,
        unroll_eval: Optional[bool] = None,
        mesh=None,                    # 1-D jax Mesh → shard the E axis
        chunk_rounds: Optional[int] = None,  # scan R in ⌈R/c⌉ chunks
        analytics: Optional[AnalyticsSpec] = None,
        keep_history: bool = True,
        donate: Optional[bool] = None,
        participation: Optional[ParticipationSpec] = None,
        participation_rates=None,   # (E,) or scalar; None → all 1.0
        participation_seeds=None,   # (E,) or scalar; None → seed+arange(E)
        fault: Optional[FaultSpec] = None,
        fault_rates=None,           # (E,) or scalar; None → all 0.0
        fault_seeds=None,           # (E,) or scalar; None → seed+arange(E)
        checkpoint_dir: Optional[str] = None,
        resume: bool = False,
    ) -> SweepResult:
        """Run the whole grid.  ``unroll_eval`` overrides the config flag
        (None → use ``config.unroll_eval``).  ``mesh`` (from
        ``repro.launch.mesh.make_sweep_mesh``) shards the experiment axis
        across devices; ``chunk_rounds`` bounds device memory for long
        schedules.  ``donate`` overrides carry donation in the
        chunked/sharded paths (None → :func:`donation_supported`, i.e.
        donate wherever XLA honors it).  All modes are bit-identical.

        ``coeffs`` may be a :class:`repro.core.coeffs.ProgramCoeffs`
        instead of an ``(E, R, n, n)`` stack: the per-round matrices are
        then generated device-side inside the scan (all three modes; the
        per-experiment program state shards on E like every other
        per-experiment input), the round count comes from the ``indices``
        schedule, and — for non-reactive programs — results are
        bit-identical to running the materialized stack.

        ``analytics`` (an :class:`repro.core.analytics.AnalyticsSpec`)
        threads the streaming-analytics accumulators through the scan
        (DESIGN.md §10) and populates ``SweepResult.analytics`` with
        per-experiment per-node summaries — identical values in every
        execution mode (the carry pads/shards on E and chunk boundaries
        resume it exactly).  ``keep_history=False`` (requires
        ``analytics``) drops the per-round ``(E, R, n)`` metric arrays
        entirely: the summaries are the only metrics, O(E·n) memory.

        ``participation`` (a ``repro.core.dynamic.ParticipationSpec``)
        switches every mode to partial-participation rounds (DESIGN.md
        §15): ``participation_rates`` gives the per-experiment activation
        rate (scalar broadcasts; None → 1.0, which is bit-identical to
        the synchronous path) and ``participation_seeds`` the per-
        experiment PRNG seeds (None → ``spec.seed + arange(E)``).  Rates
        and seeds are CARRIED data, not static, so one compiled program
        serves a whole rate grid.  ``SweepResult.participation`` holds
        the staleness digest.

        ``fault`` (a ``repro.core.dynamic.FaultSpec``) switches every
        mode to Byzantine-fault rounds (DESIGN.md §16):
        ``fault_rates``/``fault_seeds`` mirror the participation
        arguments (None → rate 0.0 — bit-identical to the fault-free
        path — and ``spec.seed + arange(E)``); both are CARRIED data, so
        one compiled program serves a whole fault-rate grid.
        ``SweepResult.fault`` holds the quarantine digest.

        ``checkpoint_dir`` (needs ``chunk_rounds``) persists the full
        scan state at every chunk boundary — atomic writes, outside the
        jitted scan; ``resume=True`` restarts from the latest checkpoint
        bit-identically (fresh start when none exists)."""
        (params0, opt0, coeffs, idx, data_idx, eval_mask, bank, states,
         program, acarry, pcarry, fcarry, rounds, n_exp, n_nodes) = \
            self._prepare_inputs(
                params0, coeffs, bank, indices, data_idx, analytics,
                keep_history, participation, participation_rates,
                participation_seeds, fault, fault_rates, fault_seeds)
        donate = donation_supported() if donate is None else donate

        if checkpoint_dir is not None and not chunk_rounds:
            raise ValueError(
                "checkpoint_dir needs chunk_rounds — checkpoints are "
                "written at chunk boundaries, outside the jitted scan")
        unroll = (self.config.unroll_eval if unroll_eval is None
                  else unroll_eval)
        if unroll:
            if mesh is not None or chunk_rounds:
                raise ValueError(
                    "mesh/chunk_rounds are scanned-mode options; they "
                    "cannot combine with unroll_eval=True")
            return self._run_unrolled(
                params0, opt0, coeffs, idx, data_idx, eval_mask, bank,
                test_iid, test_ood, batch_size, states, program,
                acarry, analytics, keep_history, pcarry, participation,
                fcarry, fault)

        if mesh is not None or chunk_rounds:
            return self._run_sharded(
                params0, opt0, coeffs, idx, data_idx, eval_mask, bank,
                test_iid, test_ood, batch_size, mesh, chunk_rounds,
                states, program, acarry, analytics, keep_history, donate,
                pcarry, participation, fcarry, fault, checkpoint_dir,
                resume)

        rounds_idx = jnp.arange(rounds, dtype=jnp.int32)
        out = self._run_jit(
            params0, opt0, coeffs, idx, data_idx, jnp.asarray(eval_mask),
            rounds_idx, bank, test_iid, test_ood, states, acarry, pcarry,
            fcarry, batch_size=batch_size, program=program,
            analytics=analytics, keep_history=keep_history,
            participation=participation, fault=fault)
        params, _, pc_out, fc_out, ac_out, hist = _split_engine_out(
            out, participation, analytics, fault)
        if participation is not None:
            pcarry = pc_out
        if fault is not None:
            fcarry = fc_out
        if analytics is not None:
            acarry = ac_out
        if hist is not None:
            losses, iid, ood = hist
        else:
            losses = iid = ood = np.zeros((n_exp, 0, n_nodes), np.float32)
        return SweepResult(
            train_loss=np.asarray(losses), iid_acc=np.asarray(iid),
            ood_acc=np.asarray(ood), params=params,
            eval_every=self.config.eval_every,
            analytics=_finalize_analytics(analytics, acarry, n_exp),
            participation=_finalize_participation(
                participation, pcarry, n_exp, rounds),
            fault=_finalize_fault(fault, fcarry, n_exp))

    def _run_unrolled(self, params, opt, coeffs, idx, data_idx, eval_mask,
                      bank, test_iid, test_ood, batch_size, states=None,
                      program=None, acarry=None, analytics=None,
                      keep_history=True, pcarry=None,
                      participation=None, fcarry=None,
                      fault=None) -> SweepResult:
        """Escape hatch: per-round dispatch, incremental metrics (the
        analytics carry is folded one eval round at a time)."""
        if states is None:
            states = {}
        if acarry is None:
            acarry = {}
        if pcarry is None:
            pcarry = {}
        if fcarry is None:
            fcarry = {}
        n_exp = jax.tree.leaves(params)[0].shape[0]
        n_nodes = jax.tree.leaves(params)[0].shape[1]
        rounds = coeffs.shape[1]
        losses, iids, oods = [], [], []
        for r in range(rounds):
            (params, opt, l_r, iid_r, ood_r, acarry, pcarry,
             fcarry) = self._round_jit(
                params, opt, coeffs[:, r], idx[:, r], data_idx, bank,
                test_iid, test_ood, states, acarry, pcarry, fcarry,
                jnp.asarray(r, jnp.int32), batch_size=batch_size,
                do_eval=bool(eval_mask[r]), program=program,
                analytics=analytics, participation=participation,
                fault=fault)
            if keep_history:
                losses.append(np.asarray(l_r))
                iids.append(np.asarray(iid_r))
                oods.append(np.asarray(ood_r))
        if keep_history:
            l = np.stack(losses, axis=1)
            i = np.stack(iids, axis=1)
            o = np.stack(oods, axis=1)
        else:
            l = i = o = np.zeros((n_exp, 0, n_nodes), np.float32)
        return SweepResult(
            train_loss=l, iid_acc=i, ood_acc=o,
            params=params, eval_every=self.config.eval_every,
            analytics=_finalize_analytics(analytics, acarry, n_exp),
            participation=_finalize_participation(
                participation, pcarry, n_exp, rounds),
            fault=_finalize_fault(fault, fcarry, n_exp))
