"""Distributed gossip: the paper's aggregation step as TPU collectives.

The stacked node-model pytree has leaves ``(n, ...)`` sharded so that the
node axis maps to the mesh ``data`` axis.  These functions run *inside*
``shard_map`` (they use ``axis_name`` collectives) and implement Eq. (2):

* :func:`gossip_dense`   — all_gather the node axis + local contraction
  (paper-faithful schedule; ICI bytes ∝ n · P).
* :func:`gossip_sparse`  — one ``ppermute`` per circulant offset with
  fused weighted accumulation (beyond-paper; ICI bytes ∝ #offsets · P).
* :func:`pod_gossip`     — hierarchical inter-pod mixing over the ``pod``
  mesh axis (the paper's WAN tier; see DESIGN.md §5).

All functions are correctness-tested against ``repro.core.mixing`` on a
multi-device CPU harness in tests/test_gossip_distributed.py.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.mixing import CirculantSchedule

__all__ = [
    "compat_shard_map",
    "gossip_dense",
    "gossip_sparse",
    "pod_gossip",
    "make_gossip_fn",
]


def compat_shard_map(fn, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking off, across jax versions
    (new: ``jax.shard_map(check_vma=False)``; old:
    ``jax.experimental.shard_map.shard_map(check_rep=False)``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map

    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


def _axis_size(axis_name: str) -> int:
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)  # older jax: count the axis members


def gossip_dense(params, coeffs_rows: jnp.ndarray, axis_name: str = "data"):
    """Dense gossip inside shard_map.

    Args:
      params: pytree, leaves (n_local, ...) — this shard's slice of the
        stacked node axis.
      coeffs_rows: (n_local, n) — this shard's *rows* of the mixing matrix
        (sharded over destinations, replicated over sources).
      axis_name: mesh axis carrying the node dimension.
    """

    def leaf_fn(leaf: jnp.ndarray) -> jnp.ndarray:
        full = jax.lax.all_gather(leaf, axis_name, axis=0, tiled=True)  # (n, ...)
        acc = jnp.tensordot(
            coeffs_rows.astype(jnp.float32), full.astype(jnp.float32), axes=(1, 0)
        )
        return acc.astype(leaf.dtype)

    return jax.tree.map(leaf_fn, params)


def _ring_perm(shift: int, size: int):
    """ppermute permutation: destination shard s receives from (s+shift)%size."""
    return [((s + shift) % size, s) for s in range(size)]


def _shard_roll(leaf: jnp.ndarray, k: int, n_local: int, axis_name: str) -> jnp.ndarray:
    """Distributed ``roll(leaf, -k, axis=0)`` over a node axis sharded in
    contiguous blocks of ``n_local`` along ``axis_name``.

    Destination node i needs source node (i+k) mod n.  A destination shard's
    block therefore spans at most two source shards, shifted by q and q+1
    where q, r = divmod(k, n_local): one ppermute each + slice-concat.
    """
    size = _axis_size(axis_name)
    q, r = divmod(k % (n_local * size), n_local)
    a = jax.lax.ppermute(leaf, axis_name, _ring_perm(q, size)) if q else leaf
    if r == 0:
        return a
    b = jax.lax.ppermute(leaf, axis_name, _ring_perm(q + 1, size))
    return jnp.concatenate([a[r:], b[:r]], axis=0)


def gossip_sparse(params, schedule: CirculantSchedule, weights_local: jnp.ndarray,
                  axis_name: str = "data"):
    """Sparse circulant gossip inside shard_map.

    Args:
      params: pytree, leaves (n_local, ...).
      schedule: host-side circulant decomposition (offsets are static).
      weights_local: (K, n_local) — this shard's slice of per-destination
        weights for each offset.
    """

    def leaf_fn(leaf: jnp.ndarray) -> jnp.ndarray:
        n_local = leaf.shape[0]
        extra = (1,) * (leaf.ndim - 1)
        acc = jnp.zeros(leaf.shape, jnp.float32)
        for idx, k in enumerate(schedule.offsets):
            wk = weights_local[idx].reshape((n_local,) + extra)
            shifted = _shard_roll(leaf, k, n_local, axis_name)
            acc = acc + wk * shifted.astype(jnp.float32)
        return acc.astype(leaf.dtype)

    return jax.tree.map(leaf_fn, params)


def pod_gossip(params, pod_coeffs: jnp.ndarray, axis_name: str = "pod"):
    """Hierarchical inter-pod mixing: each pod is one super-node.

    ``pod_coeffs`` is the (n_pods, n_pods) row-stochastic inter-pod matrix
    (e.g. topology-aware weights over the WAN graph of pods).  Every leaf is
    averaged *across pods at the same intra-pod position*:

        leaf'_p = Σ_q pod_coeffs[p, q] · leaf_q

    n_pods is small (2 here), so an all_gather over ``pod`` is optimal.
    """

    def leaf_fn(leaf: jnp.ndarray) -> jnp.ndarray:
        pods = jax.lax.all_gather(leaf, axis_name, axis=0)      # (n_pods, ...)
        me = jax.lax.axis_index(axis_name)
        w = pod_coeffs[me].astype(jnp.float32)                  # (n_pods,)
        acc = jnp.tensordot(w, pods.astype(jnp.float32), axes=(0, 0))
        return acc.astype(leaf.dtype)

    return jax.tree.map(leaf_fn, params)


def make_gossip_fn(
    mesh: Mesh,
    n_nodes: int,
    schedule: Optional[CirculantSchedule] = None,
    node_axis: str = "data",
    param_spec: P = P(),
):
    """Build a jit-able gossip function over a real mesh.

    Returns ``fn(stacked_params, coeffs) -> stacked_params`` where the node
    axis of every leaf is sharded over ``node_axis``.  If ``schedule`` is
    given, the sparse ppermute schedule is used (coeffs then must be the
    (K, n) circulant weights); otherwise the dense all_gather schedule
    (coeffs = (n, n) mixing matrix).
    """
    axis_size = mesh.shape[node_axis]
    if n_nodes % axis_size != 0:
        raise ValueError(f"n_nodes={n_nodes} not divisible by |{node_axis}|={axis_size}")

    # leaves: (n, ...) sharded (node_axis, *param_spec)
    leaf_spec = P(node_axis, *param_spec)

    if schedule is None:
        coeff_spec = P(node_axis, None)      # rows sharded over destinations

        def fn(params, coeffs):
            return gossip_dense(params, coeffs, node_axis)
    else:
        coeff_spec = P(None, node_axis)      # (K, n): shard destinations

        def fn(params, coeffs):
            return gossip_sparse(params, schedule, coeffs, node_axis)

    mapped = compat_shard_map(
        fn, mesh, in_specs=(leaf_spec, coeff_spec), out_specs=leaf_spec)
    return jax.jit(mapped)
