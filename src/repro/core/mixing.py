"""Apply a mixing matrix to a stacked model pytree.

All n node-models live in ONE pytree whose leaves carry a leading node axis
``(n, ...)`` — the TPU-native formulation of the paper's "n independent
models" (see DESIGN.md §3.1).  Eq. (2) of the paper,

    m_i^{t+1} = Σ_{j∈N_i} C[i,j] · m_j^{t+1/2},

is then a single contraction ``M' = C @ M`` applied leaf-wise.

Two schedules are provided:

* :func:`mix_dense` — paper-faithful: einsum against the dense (n, n)
  matrix.  Under pjit with the node axis sharded over mesh ``data``, XLA
  lowers this to an all-gather + local GEMM.
* :func:`mix_sparse` — beyond-paper: circulant decomposition of the sparse
  mixing matrix into ring offsets; inside ``shard_map`` each offset becomes
  one ``lax.ppermute`` with on-the-fly weighted accumulation, so ICI bytes
  scale with the number of distinct offsets (≈ max degree) instead of n.
  The offset SET is static (derived from the topology's neighbourhood
  support via :func:`sparse_offsets`) while the per-offset weights are
  gathered from the traced coefficients at each call — so one compiled
  schedule serves every round of a time-varying stack or in-scan
  coefficient program whose support stays within the nominal topology
  (link failure only shrinks support: dropped edges contribute weight 0).
  Reachable as ``DecentralizedConfig(mix_impl="sparse")``
  (``repro.core.decentralized.make_mix_fn``), which falls back to
  :func:`mix_dense` when the offset count exceeds max degree + slack —
  near-circulant graphs (rings, WS) win, unstructured support does not.

* :func:`mix_edges` — the general sparse schedule: padded-ELL edge-list
  tables (``repro.core.topology.padded_neighbor_tables``) are static
  trace-time data, per-edge coefficients are gathered from the live
  (n, n) matrix (:func:`edge_weights`), and each destination row
  accumulates its ≤ dmax neighbours — O(n·dmax·|leaf|) work instead of
  the dense O(n²·|leaf|), with no circulant-structure requirement.  This
  is ``DecentralizedConfig(mix_impl="edges")`` and the jnp reference of
  the Pallas segment kernel
  (``repro.kernels.gossip_mix.mix_edges_pallas``, DESIGN.md §12).

A further backend lives in ``repro.kernels.gossip_mix``: the fused
flat-plane Pallas kernel (``mix_impl="pallas"`` — the whole mix as ONE
``pallas_call`` over a packed ``(n, P)`` parameter plane, DESIGN.md §11).

All are pure functions of (params, coefficients) and agree to float
tolerance — property-tested in tests/test_mixing.py.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "mix_dense",
    "mix_sparse",
    "mix_sparse_host",
    "mix_edges",
    "edge_weights",
    "sparse_offsets",
    "circulant_decomposition",
    "CirculantSchedule",
    "mixing_collective_bytes",
    "ROBUST_MODES",
    "oddeven_sort_pairs",
    "robust_combine",
    "mix_robust_tables",
    "plane_norms",
    "norm_clip_coeffs",
]

#: robust-aggregation rules accepted by
#: ``repro.core.decentralized.make_mix_fn(robust=...)`` (DESIGN.md §16):
#: "mean" is the untouched weighted average; "trimmed"/"median" are the
#: coordinate-wise order statistics below; "norm_clip" is the
#: :func:`norm_clip_coeffs` coefficient transform.
ROBUST_MODES = ("mean", "trimmed", "median", "norm_clip")

# nonfinite sanitization bound for the robust sort keys: corrupted
# (NaN/±Inf) coordinates are clamped to ±_ROBUST_BIG so every comparison
# in the sort network is well-defined and a poisoned value behaves as a
# maximally extreme outlier (bounded influence).  Padding / zero-weight
# slots get _ROBUST_PAD, strictly beyond the clamp, so they sort past
# every real value.
_ROBUST_BIG = 1e30
_ROBUST_PAD = 2e30


def _leaf_mix(c: jnp.ndarray, leaf: jnp.ndarray,
              mix_in_float32: bool = True) -> jnp.ndarray:
    """out[i, ...] = Σ_j c[i, j] · leaf[j, ...], preserving leaf dtype.

    ``mix_in_float32=True`` (default) accumulates in f32 — aggregation of
    bf16 params in low precision loses knowledge exactly where the paper
    needs it (small OOD deltas).  False accumulates in the leaf dtype (the
    low-precision-aggregation ablation,
    ``DecentralizedConfig(mix_in_float32=False)``).
    """
    acc_dtype = jnp.float32 if mix_in_float32 else leaf.dtype
    acc = jnp.tensordot(c.astype(acc_dtype), leaf.astype(acc_dtype),
                        axes=(1, 0))
    return acc.astype(leaf.dtype)


def mix_dense(params, coeffs: jnp.ndarray, mix_in_float32: bool = True):
    """Dense gossip: every leaf contracted against the (n, n) matrix.

    Args:
      params: pytree with leaves of shape (n, ...).
      coeffs: (n, n) row-stochastic mixing matrix (device array or numpy).
      mix_in_float32: accumulation dtype — see :func:`_leaf_mix`.
    """
    c = jnp.asarray(coeffs)
    return jax.tree.map(lambda leaf: _leaf_mix(c, leaf, mix_in_float32),
                        params)


# ----------------------------------------------------------------------
# circulant (ring-offset) decomposition — sparse gossip schedule
# ----------------------------------------------------------------------
class CirculantSchedule:
    """Decomposition of an (n, n) mixing matrix into ring offsets.

    For each distinct offset ``k`` with any nonzero ``C[i, (i+k) % n]`` we
    store the per-destination coefficient vector ``w_k[i] = C[i, (i+k)%n]``.
    Then ``(C @ M)[i] = Σ_k w_k[i] · M[(i+k) % n]`` — i.e. a sum of weighted
    ring shifts, each of which is a single ``collective_permute`` on the ICI
    ring when the node axis is the mesh ``data`` axis.
    """

    def __init__(self, offsets: Sequence[int], weights: np.ndarray, n: int):
        self.offsets: Tuple[int, ...] = tuple(int(o) for o in offsets)
        self.weights = np.asarray(weights, dtype=np.float32)  # (K, n)
        self.n = n
        assert self.weights.shape == (len(self.offsets), n)

    def __len__(self) -> int:
        return len(self.offsets)

    def __repr__(self) -> str:
        return f"CirculantSchedule(n={self.n}, offsets={self.offsets})"


def circulant_decomposition(coeffs: np.ndarray) -> CirculantSchedule:
    """Exact decomposition of any (n, n) matrix into ring offsets.

    Every matrix decomposes into ≤ n offsets; sparse neighbourhood matrices
    on scale-free graphs typically use far fewer distinct offsets than n
    (BA n=16 p=2 → ~9 offsets vs 15 all-gather hops).  Offset 0 is the
    self-weight and costs no communication.
    """
    c = np.asarray(coeffs, dtype=np.float32)
    n = c.shape[0]
    offsets: List[int] = []
    weights: List[np.ndarray] = []
    for k in range(n):
        w = c[np.arange(n), (np.arange(n) + k) % n]
        if np.any(w != 0):
            offsets.append(k)
            weights.append(w)
    return CirculantSchedule(offsets, np.stack(weights), n)


def sparse_offsets(support: np.ndarray) -> Tuple[int, ...]:
    """Distinct ring offsets covering a 0/1 support mask (adjacency plus
    self-loops): offset k is needed iff any ``support[i, (i+k) % n] > 0``.
    Static metadata — compute once per topology, reuse for every round."""
    s = np.asarray(support)
    n = s.shape[0]
    rows = np.arange(n)
    return tuple(k for k in range(n)
                 if np.any(s[rows, (rows + k) % n] > 0))


def mix_sparse(params, coeffs: jnp.ndarray, offsets: Sequence[int],
               mix_in_float32: bool = True):
    """Circulant gossip with STATIC offsets and TRACED weights.

    ``offsets`` fixes the ring-shift schedule at trace time (it comes from
    the topology support, :func:`sparse_offsets`); the per-destination
    weights ``w_k[i] = coeffs[i, (i+k) % n]`` are gathered from the live
    (n, n) matrix, so per-round matrices (Random resampling, link
    failure, in-scan coefficient programs) reuse one compiled schedule.
    Requires ``offsets`` ⊇ the support of ``coeffs`` — entries outside
    the offset set are silently dropped (callers derive offsets from the
    nominal topology, whose support only ever shrinks under churn).
    Accumulates in f32 like :func:`mix_dense` (``mix_in_float32=False``
    accumulates in the leaf dtype, matching the other backends' ablation
    knob).
    """
    c = jnp.asarray(coeffs).astype(jnp.float32)
    n = c.shape[0]
    rows = jnp.arange(n)
    weights = [c[rows, (rows + k) % n] for k in offsets]

    def leaf_fn(leaf: jnp.ndarray) -> jnp.ndarray:
        acc_dtype = jnp.float32 if mix_in_float32 else leaf.dtype
        acc = jnp.zeros(leaf.shape, acc_dtype)
        extra = (1,) * (leaf.ndim - 1)
        for k, w in zip(offsets, weights):
            # destination i receives source (i+k) % n  ==  roll by -k
            shifted = jnp.roll(leaf, shift=-k, axis=0) if k else leaf
            acc = acc + (w.astype(acc_dtype).reshape((n,) + extra)
                         * shifted.astype(acc_dtype))
        return acc.astype(leaf.dtype)

    return jax.tree.map(leaf_fn, params)


# ----------------------------------------------------------------------
# padded edge-list (ELL) gossip — the general sparse schedule
# ----------------------------------------------------------------------
def edge_weights(coeffs: jnp.ndarray, nbr_idx: jnp.ndarray,
                 nbr_mask: jnp.ndarray) -> jnp.ndarray:
    """Per-edge coefficients ``w[i, d] = coeffs[i, nbr_idx[i, d]]``
    (masked): the (n, dmax) gather that turns a live (n, n) mixing matrix
    into the edge-list schedule's traced operand.  The tables come from
    ``repro.core.topology.padded_neighbor_tables`` and are STATIC; only
    this O(n·dmax) gather runs per round, so time-varying matrices (Random
    resampling, link failure, in-scan coefficient programs) reuse one
    compiled schedule.  Entries outside the table support are dropped —
    callers derive tables from the nominal topology, whose support only
    ever shrinks under churn (``SweepEngine.run`` validates this)."""
    c = jnp.asarray(coeffs)
    rows = jnp.arange(c.shape[0])[:, None]
    return c[rows, nbr_idx] * nbr_mask.astype(c.dtype)


def mix_edges(params, coeffs: jnp.ndarray, nbr_idx: jnp.ndarray,
              nbr_mask: jnp.ndarray, mix_in_float32: bool = True):
    """Edge-list gossip with STATIC padded-ELL tables and TRACED weights —
    the jnp reference of the Pallas segment kernel
    (``repro.kernels.gossip_mix.mix_edges_pallas``); property-tested equal
    to :func:`mix_dense` to 1e-6 in tests/test_mixing.py.

    ``(C @ M)[i] = Σ_d w[i, d] · M[nbr_idx[i, d]]`` — an O(n·dmax·|leaf|)
    gather-accumulate instead of the dense O(n²·|leaf|) contraction,
    which is what makes n ≥ 1024 topologies reachable (dmax ≈ max degree
    + 1 ≪ n on the paper's BA/WS graphs).  Accumulates in f32 like
    :func:`mix_dense` (``mix_in_float32=False`` accumulates in the leaf
    dtype — the shared low-precision-aggregation ablation knob).
    """
    idx = jnp.asarray(nbr_idx)
    w = edge_weights(jnp.asarray(coeffs).astype(jnp.float32), idx,
                     jnp.asarray(nbr_mask))

    def leaf_fn(leaf: jnp.ndarray) -> jnp.ndarray:
        acc_dtype = jnp.float32 if mix_in_float32 else leaf.dtype
        gathered = jnp.take(leaf.astype(acc_dtype), idx, axis=0)
        wk = w.astype(acc_dtype).reshape(w.shape + (1,) * (leaf.ndim - 1))
        return (wk * gathered).sum(axis=1).astype(leaf.dtype)

    return jax.tree.map(leaf_fn, params)


# ----------------------------------------------------------------------
# robust aggregation: coordinate-wise order statistics over neighbours
# ----------------------------------------------------------------------
def oddeven_sort_pairs(keys: jnp.ndarray, vals: jnp.ndarray):
    """Sort ``(keys, vals)`` ascending by ``keys`` along axis 0 with a
    fixed odd-even transposition network — ``d`` passes of vectorized
    compare-exchanges over a static length-``d`` leading axis.

    The network is stable (equal keys never swap), so its output depends
    only on the input, not on how many extra passes padding adds — which
    is what makes the ``dmax``-deep jnp reference and the ``d_pad``-deep
    Pallas kernel bit-identical.  Callers must pre-sanitize keys to
    finite values (NaN never satisfies ``lo > hi`` consistently and
    would oscillate forever); see :func:`robust_combine`.
    """
    d = keys.shape[0]
    for p in range(d):
        start = p % 2
        npairs = (d - start) // 2
        if npairs == 0:
            continue
        stop = start + 2 * npairs
        lo_k, hi_k = keys[start:stop:2], keys[start + 1:stop:2]
        lo_v, hi_v = vals[start:stop:2], vals[start + 1:stop:2]
        swap = lo_k > hi_k
        new_lo_k = jnp.where(swap, hi_k, lo_k)
        new_hi_k = jnp.where(swap, lo_k, hi_k)
        new_lo_v = jnp.where(swap, hi_v, lo_v)
        new_hi_v = jnp.where(swap, lo_v, hi_v)
        merged_k = jnp.stack([new_lo_k, new_hi_k], axis=1).reshape(
            (2 * npairs,) + keys.shape[1:])
        merged_v = jnp.stack([new_lo_v, new_hi_v], axis=1).reshape(
            (2 * npairs,) + vals.shape[1:])
        keys = jnp.concatenate([keys[:start], merged_k, keys[stop:]], axis=0)
        vals = jnp.concatenate([vals[:start], merged_v, vals[stop:]], axis=0)
    return keys, vals


def robust_combine(vals: jnp.ndarray, w: jnp.ndarray,
                   self_vals: jnp.ndarray, op: str,
                   trim_k: int = 1) -> jnp.ndarray:
    """Coordinate-wise robust aggregate of gathered neighbour rows.

    vals: (d, m, t) — slot d's value for destination row m, coordinate t
      (gathered from the padded-ELL tables; padding slots carry weight 0).
    w: (d, m) per-slot mixing weights — a slot participates iff w > 0.
    self_vals: (m, t) — each destination's own row (the fallback when
      every slot is trimmed away or the support is empty).
    op: ``"trimmed"`` — drop the ``trim_k`` smallest and largest values
      among the occupied slots, weighted mean of the survivors with the
      weight mass renormalized; ``"median"`` — unweighted coordinate-wise
      median of the occupied slots (weights only define occupancy).

    Nonfinite values are clamped to ±1e30 before sorting (bounded
    influence — a NaN plane behaves as an extreme outlier instead of
    poisoning the comparisons), and the whole computation is the SAME op
    sequence inside the Pallas kernel and the jnp reference, so the two
    are bit-identical (tests/test_robust_mix.py).

    This function is called from inside a Pallas kernel body, so it must
    stay jnp-only with static shapes (no host control flow on traced
    values, no cumsum primitives — the rank scan is an unrolled loop).
    """
    if op not in ("trimmed", "median"):
        raise ValueError(f"robust_combine op {op!r} not in "
                         f"('trimmed', 'median')")
    d = vals.shape[0]
    acc_dtype = vals.dtype
    wv = w[:, :, None]
    valid = wv > 0
    big = jnp.asarray(_ROBUST_BIG, acc_dtype)
    keys = jnp.clip(jnp.nan_to_num(vals, nan=_ROBUST_BIG, posinf=_ROBUST_BIG,
                                   neginf=-_ROBUST_BIG), -big, big)
    keys = jnp.where(valid, keys, jnp.asarray(_ROBUST_PAD, acc_dtype))
    w3 = jnp.where(valid, wv, jnp.zeros_like(wv)).astype(acc_dtype)
    w3 = jnp.broadcast_to(w3, keys.shape)
    keys, w3 = oddeven_sort_pairs(keys, w3)
    occupied = w3 > 0
    # unrolled rank scan (no jnp.cumsum — it has no Mosaic lowering)
    rank = jnp.zeros(keys.shape[1:], jnp.int32)
    ranks = []
    for i in range(d):
        rank = rank + occupied[i].astype(jnp.int32)
        ranks.append(rank)
    r_lo = jnp.stack(ranks, axis=0)          # 1-based rank among occupied
    cnt = rank                               # occupied slots per (m, t)
    if op == "median":
        lo = (cnt - 1) // 2
        hi = cnt // 2
        iota = jax.lax.broadcasted_iota(jnp.int32, keys.shape, 0)
        med = (jnp.sum(jnp.where(iota == lo[None], keys,
                                 jnp.zeros_like(keys)), axis=0)
               + jnp.sum(jnp.where(iota == hi[None], keys,
                                   jnp.zeros_like(keys)), axis=0))
        half = jnp.asarray(0.5, acc_dtype)
        return jnp.where(cnt > 0, half * med, self_vals)
    r_hi = cnt[None] - r_lo + occupied.astype(jnp.int32)
    keep = occupied & (r_lo > trim_k) & (r_hi > trim_k)
    wk = jnp.where(keep, w3, jnp.zeros_like(w3))
    mass = jnp.sum(wk, axis=0)
    num = jnp.sum(wk * keys, axis=0)
    safe = jnp.where(mass > 0, mass, jnp.ones_like(mass))
    return jnp.where(mass > 0, num / safe, self_vals)


def mix_robust_tables(params, coeffs: jnp.ndarray, nbr_idx: jnp.ndarray,
                      nbr_mask: jnp.ndarray, op: str, trim_k: int = 1,
                      mix_in_float32: bool = True):
    """Masked-sort REFERENCE of the robust edge-list gossip — Eq. (2)
    with the weighted mean replaced by :func:`robust_combine` over each
    destination's padded-ELL neighbour slots (self included; slots whose
    per-round weight is 0 — dropped links, quarantined columns, padding —
    are excluded from the order statistics).

    Same tables and traced-weights contract as :func:`mix_edges`; the
    Pallas counterpart is ``repro.kernels.gossip_mix.mix_robust_pallas``
    and the two are bit-identical (same op sequence, see
    :func:`robust_combine`).  O(n·dmax·|leaf|) memory for the gathered
    value tensor — fine at sweep scale (dmax ≪ n), not a kernel.
    """
    idx = jnp.asarray(nbr_idx)
    w = edge_weights(jnp.asarray(coeffs).astype(jnp.float32), idx,
                     jnp.asarray(nbr_mask))
    n = idx.shape[0]
    wt = w.T  # (dmax, n) — slot axis leading, like the kernel tables

    def leaf_fn(leaf: jnp.ndarray) -> jnp.ndarray:
        acc_dtype = jnp.float32 if mix_in_float32 else leaf.dtype
        flat = leaf.reshape(n, -1).astype(acc_dtype)
        vals = jnp.take(flat, idx.T, axis=0)          # (dmax, n, p)
        out = robust_combine(vals, wt.astype(acc_dtype), flat, op,
                             trim_k=trim_k)
        return out.astype(leaf.dtype).reshape(leaf.shape)

    return jax.tree.map(leaf_fn, params)


def plane_norms(params) -> jnp.ndarray:
    """(n,) f32 L2 norm of each node's full parameter row — the plane
    magnitude the ``norm_clip`` robust rule and the quarantine health
    screen compare against (DESIGN.md §16)."""
    leaves = jax.tree.leaves(params)
    n = leaves[0].shape[0]
    sq = jnp.zeros((n,), jnp.float32)
    for leaf in leaves:
        flat = leaf.reshape(n, -1).astype(jnp.float32)
        sq = sq + jnp.sum(flat * flat, axis=1)
    return jnp.sqrt(sq)


def norm_clip_coeffs(coeffs: jnp.ndarray, norms: jnp.ndarray,
                     clip_mult: float = 1.0) -> jnp.ndarray:
    """Row-norm clipping as a coefficient transform: neighbour j's weight
    in row i is scaled by ``min(1, clip_mult·‖x_i‖/‖x_j‖)`` — a
    neighbour whose plane is larger than the destination's own row can
    contribute at most a clipped fraction of its mass.  Neighbours with
    nonfinite norms are dropped outright (their scale is meaningless);
    self weights are never clipped; rows that were scaled are
    renormalized (fallback self-weight 1), rows left untouched are
    returned BIT-identical — so a round where nothing clips reproduces
    the plain mean exactly.

    Because this is a pure (n, n) → (n, n) transform, every mix backend
    (einsum/pallas/sparse/edges) reuses its existing kernel on the
    clipped matrix — ``make_mix_fn(robust="norm_clip")`` composes it in
    front of the selected impl.
    """
    from repro.core.strategies import renormalize_rows

    c = jnp.asarray(coeffs)
    n = c.shape[-1]
    norms = jnp.asarray(norms, jnp.float32)
    finite = jnp.isfinite(norms)
    denom = jnp.where(norms > 0, norms, jnp.ones_like(norms))
    ratio = (jnp.asarray(clip_mult, jnp.float32) * norms[:, None]
             / denom[None, :])
    # zero-norm neighbours pass unclipped (nothing to scale); nonfinite
    # destination norms disable clipping for that row (self is suspect —
    # the quarantine screen, not the clip rule, handles that case)
    factor = jnp.where(norms[None, :] > 0, jnp.minimum(ratio, 1.0), 1.0)
    factor = jnp.where(jnp.isfinite(factor), factor, 1.0)
    factor = jnp.where(finite[None, :], factor, 0.0)
    eye = jnp.eye(n, dtype=bool)
    factor = jnp.where(eye, 1.0, factor).astype(c.dtype)
    scaled = c * factor
    changed = (scaled != c).any(axis=-1, keepdims=True)
    return jnp.where(changed, renormalize_rows(scaled, xp=jnp), c)


def mix_sparse_host(params, schedule: CirculantSchedule):
    """Single-host reference of the circulant schedule (jnp.roll stands in
    for collective_permute).  The distributed version lives in
    ``repro.core.gossip.gossip_step_sparse`` inside shard_map."""

    def leaf_fn(leaf: jnp.ndarray) -> jnp.ndarray:
        acc = jnp.zeros(leaf.shape, jnp.float32)
        extra = (1,) * (leaf.ndim - 1)
        for k, w in zip(schedule.offsets, schedule.weights):
            wk = jnp.asarray(w).reshape((schedule.n,) + extra)
            # destination i receives source (i+k) % n  ==  roll by -k
            shifted = jnp.roll(leaf, shift=-k, axis=0) if k else leaf
            acc = acc + wk * shifted.astype(jnp.float32)
        return acc.astype(leaf.dtype)

    return jax.tree.map(leaf_fn, params)


def mixing_collective_bytes(
    n_nodes: int,
    param_bytes_per_node: int,
    schedule: CirculantSchedule | None = None,
) -> dict:
    """Napkin-math ICI bytes per node for the two gossip schedules.

    dense  : ring all-gather moves (n-1)/n of the full stacked params past
             every node → ≈ (n-1) · P bytes in, per node.
    sparse : one permute per non-zero offset (excluding 0) → K' · P bytes.
    """
    dense = (n_nodes - 1) * param_bytes_per_node
    out = {"dense_bytes_per_node": dense}
    if schedule is not None:
        nonzero = sum(1 for o in schedule.offsets if o != 0)
        out["sparse_bytes_per_node"] = nonzero * param_bytes_per_node
        out["sparse_offsets"] = nonzero
    return out
