"""Communication topologies for decentralized learning.

The paper studies three random-graph families (Appendix B.1):

* **Barabási–Albert (BA)** — scale-free, preferential attachment, parameter
  ``p`` (edges per new node).  Power-law degree distribution.
* **Stochastic Block (SB)** — ``c`` modular communities, intra-community edge
  probability ``p_in`` and inter-community probability ``p_out``.
* **Watts–Strogatz (WS)** — small-world ring lattice with ``k`` nearest
  neighbours and rewiring probability ``u``.

Topologies are *host-side metadata*: tiny graphs (n ≤ a few hundred) that
parameterize the mixing matrix.  They are represented as a frozen
:class:`Topology` carrying the adjacency matrix plus cached centrality
metrics.  All tensor compute stays in ``repro.core.mixing`` / ``gossip``.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import networkx as nx
import numpy as np

__all__ = [
    "Topology",
    "padded_neighbor_tables",
    "coo_edge_list",
    "barabasi_albert",
    "watts_strogatz",
    "stochastic_block",
    "ring",
    "star",
    "fully_connected",
    "from_adjacency",
    "TOPOLOGY_BUILDERS",
    "build_topology",
]


@dataclasses.dataclass(frozen=True)
class Topology:
    """An undirected communication graph ``G = (V, E)``.

    Attributes:
      adjacency: ``(n, n)`` symmetric 0/1 float array, zero diagonal.
      name: human-readable description (family + parameters).
      seed: the RNG seed used to generate it (-1 for deterministic graphs).
    """

    adjacency: np.ndarray
    name: str = "custom"
    seed: int = -1

    def __post_init__(self):
        a = np.asarray(self.adjacency, dtype=np.float64)
        if a.ndim != 2 or a.shape[0] != a.shape[1]:
            raise ValueError(f"adjacency must be square, got {a.shape}")
        if not np.allclose(a, a.T):
            raise ValueError("adjacency must be symmetric (undirected graph)")
        if np.any(np.diag(a) != 0):
            raise ValueError("adjacency must have zero diagonal")
        if not np.all((a == 0) | (a == 1)):
            raise ValueError("adjacency must be 0/1")
        object.__setattr__(self, "adjacency", a)
        object.__setattr__(self, "_metric_cache", {})

    # ------------------------------------------------------------------
    # basic properties
    # ------------------------------------------------------------------
    @property
    def n_nodes(self) -> int:
        return self.adjacency.shape[0]

    @property
    def n_edges(self) -> int:
        return int(self.adjacency.sum()) // 2

    def neighbors(self, i: int) -> np.ndarray:
        """Indices of i's neighbours (excluding i itself)."""
        return np.nonzero(self.adjacency[i])[0]

    def neighborhood(self, i: int) -> np.ndarray:
        """The paper's N_i = neighbours(i) ∪ {i}, sorted."""
        return np.sort(np.concatenate([self.neighbors(i), [i]]))

    def to_networkx(self) -> nx.Graph:
        return nx.from_numpy_array(self.adjacency)

    def is_connected(self) -> bool:
        return nx.is_connected(self.to_networkx())

    # ------------------------------------------------------------------
    # centrality metrics (cached — graphs are frozen)
    # ------------------------------------------------------------------
    def degree(self) -> np.ndarray:
        """Degree of each node (number of edges)."""
        return self.adjacency.sum(axis=1)

    def betweenness(self) -> np.ndarray:
        """Betweenness centrality (Freeman 1977), normalized as networkx."""
        cache = self._metric_cache
        if "betweenness" not in cache:
            bc = nx.betweenness_centrality(self.to_networkx(), normalized=True)
            cache["betweenness"] = np.array(
                [bc[i] for i in range(self.n_nodes)], dtype=np.float64
            )
        return cache["betweenness"]

    def eigenvector(self) -> np.ndarray:
        """Eigenvector centrality (principal adjacency eigenvector, unit
        2-norm, nonnegative) — the reference the jnp power-method kernel in
        ``repro.core.coeffs`` is property-tested against."""
        cache = self._metric_cache
        if "eigenvector" not in cache:
            ec = nx.eigenvector_centrality_numpy(self.to_networkx())
            cache["eigenvector"] = np.array(
                [ec[i] for i in range(self.n_nodes)], dtype=np.float64
            )
        return cache["eigenvector"]

    def pagerank(self) -> np.ndarray:
        """PageRank mass (α=0.85, uniform personalization, networkx
        semantics incl. dangling-node redistribution)."""
        cache = self._metric_cache
        if "pagerank" not in cache:
            pr = nx.pagerank(self.to_networkx())
            cache["pagerank"] = np.array(
                [pr[i] for i in range(self.n_nodes)], dtype=np.float64
            )
        return cache["pagerank"]

    def closeness(self) -> np.ndarray:
        """Closeness centrality (Wasserman–Faust component-scaled form —
        networkx's default — so disconnected graphs are well-defined)."""
        cache = self._metric_cache
        if "closeness" not in cache:
            cc = nx.closeness_centrality(self.to_networkx())
            cache["closeness"] = np.array(
                [cc[i] for i in range(self.n_nodes)], dtype=np.float64
            )
        return cache["closeness"]

    def modularity(self) -> float:
        """Greedy-community modularity (Clauset–Newman–Moore, as in paper)."""
        cache = self._metric_cache
        if "modularity" not in cache:
            g = self.to_networkx()
            communities = nx.community.greedy_modularity_communities(g)
            cache["modularity"] = float(nx.community.modularity(g, communities))
        return cache["modularity"]

    # ------------------------------------------------------------------
    # sparse edge-list views (cached — graphs are frozen)
    # ------------------------------------------------------------------
    def neighbor_tables(self,
                        include_self: bool = True
                        ) -> Tuple[np.ndarray, np.ndarray]:
        """Padded-ELL neighbour tables over this graph's support
        (:func:`padded_neighbor_tables`): ``(nbr_idx, nbr_mask)`` of shape
        ``(n, dmax)`` — the static operands of the edge-list mixing path
        (``mix_impl="edges"``) and of the sparse centrality kernels in
        ``repro.core.coeffs``.  ``include_self=True`` (default) lists
        ``N_i = neighbours(i) ∪ {i}``, matching the mixing-matrix support;
        ``False`` lists plain neighbours — the adjacency operand the
        centrality kernels consume."""
        cache = self._metric_cache
        key = ("neighbor_tables", bool(include_self))
        if key not in cache:
            support = self.adjacency
            if include_self:
                support = support + np.eye(self.n_nodes)
            cache[key] = padded_neighbor_tables(support)
        return cache[key]

    def edge_list(self) -> Tuple[np.ndarray, np.ndarray]:
        """COO directed edge list ``(src, dst)`` int32 arrays (both
        orientations of every undirected edge; no self-loops), sorted by
        destination then source — the flat companion of
        :meth:`neighbor_tables` for |E|-shaped per-edge state."""
        cache = self._metric_cache
        if "edge_list" not in cache:
            cache["edge_list"] = coo_edge_list(self.adjacency)
        return cache["edge_list"]

    def max_degree(self) -> int:
        return int(self.degree().max())

    def nodes_by_degree(self) -> np.ndarray:
        """Node indices sorted by degree, descending (ties → lower index)."""
        deg = self.degree()
        return np.argsort(-deg, kind="stable")

    def kth_highest_degree_node(self, k: int) -> int:
        """The paper places OOD data on the k-th highest degree node (1-based)."""
        order = self.nodes_by_degree()
        if not 1 <= k <= len(order):
            raise ValueError(f"k={k} out of range for n={len(order)}")
        return int(order[k - 1])


# ----------------------------------------------------------------------
# sparse edge-list derivations (host-side static scan data)
# ----------------------------------------------------------------------
def padded_neighbor_tables(
        support: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Padded-ELL neighbour tables for any 0/1 support mask.

    Row ``i`` lists the columns ``j`` with ``support[i, j] > 0`` (sorted),
    right-padded to the maximum row population ``dmax`` with the row's OWN
    index under mask 0 — padding gathers are always in-bounds and carry
    zero weight.  Returns ``(nbr_idx int32, nbr_mask float32)``, both
    ``(n, dmax)``.  Static metadata like :func:`repro.core.mixing.
    sparse_offsets`: derived once per topology/support, baked into the
    compiled program, reused for every round — per-round coefficients are
    *gathered through* the tables at trace time, so link failure (support
    can only shrink) and time-varying matrices reuse one compiled mix.
    A row with no support at all (isolated node under a self-loop-free
    mask) comes back all-padding: its mixed output is exactly zero, the
    same as the dense contraction with an all-zero coefficient row.
    """
    s = np.asarray(support) > 0
    if s.ndim != 2 or s.shape[0] != s.shape[1]:
        raise ValueError(f"support must be square, got {s.shape}")
    n = s.shape[0]
    dmax = max(int(s.sum(axis=1).max()) if n else 0, 1)
    nbr_idx = np.tile(np.arange(n, dtype=np.int32)[:, None], (1, dmax))
    nbr_mask = np.zeros((n, dmax), dtype=np.float32)
    for i in range(n):
        js = np.nonzero(s[i])[0]
        nbr_idx[i, :len(js)] = js
        nbr_mask[i, :len(js)] = 1.0
    return nbr_idx, nbr_mask


def coo_edge_list(adjacency: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """COO directed edge list for a 0/1 adjacency: ``(src, dst)`` int32
    arrays with one entry per orientation of every undirected edge,
    sorted by (dst, src) so per-destination segments are contiguous —
    the segment-sum ordering of the edge-list gossip kernel's framing."""
    a = np.asarray(adjacency) > 0
    dst, src = np.nonzero(a)  # row-major nonzero == sorted by (dst, src)
    return src.astype(np.int32), dst.astype(np.int32)


# ----------------------------------------------------------------------
# generators
# ----------------------------------------------------------------------
def _ensure_connected(g: nx.Graph, rng: np.random.Generator) -> nx.Graph:
    """Patch disconnected graphs by wiring components together (rare for
    the studied parameter ranges; SB with p_out=0.009 can disconnect)."""
    if nx.is_connected(g):
        return g
    comps = [sorted(c) for c in nx.connected_components(g)]
    for a, b in zip(comps[:-1], comps[1:]):
        u = int(rng.choice(a))
        v = int(rng.choice(b))
        g.add_edge(u, v)
    return g


def barabasi_albert(n: int, p: int, seed: int = 0) -> Topology:
    """BA scale-free graph: n nodes, each new node attaches with p edges."""
    g = nx.barabasi_albert_graph(n=n, m=p, seed=seed)
    return Topology(nx.to_numpy_array(g), name=f"ba_n{n}_p{p}", seed=seed)


def watts_strogatz(n: int, k: int = 4, u: float = 0.5, seed: int = 0) -> Topology:
    """WS small-world graph: ring of n nodes, k nearest neighbours,
    rewiring probability u.  Uses the connected variant as the paper's
    training requires knowledge to be able to reach every node."""
    g = nx.connected_watts_strogatz_graph(n=n, k=k, p=u, seed=seed)
    return Topology(nx.to_numpy_array(g), name=f"ws_n{n}_k{k}_u{u}", seed=seed)


def stochastic_block(
    n: int = 33,
    n_communities: int = 3,
    p_in: float = 0.5,
    p_out: float = 0.05,
    seed: int = 0,
) -> Topology:
    """SB modular graph: `n_communities` equal-ish blocks, intra-block edge
    probability p_in, inter-block probability p_out (paper: p_in=0.5,
    p_out ∈ {0.009, 0.05, 0.9})."""
    sizes = [n // n_communities] * n_communities
    for i in range(n - sum(sizes)):
        sizes[i] += 1
    probs = [
        [p_in if i == j else p_out for j in range(n_communities)]
        for i in range(n_communities)
    ]
    g = nx.stochastic_block_model(sizes, probs, seed=seed)
    g = nx.Graph(g)  # strip block metadata; simple graph
    g = _ensure_connected(g, np.random.default_rng(seed))
    return Topology(
        nx.to_numpy_array(g), name=f"sb_n{n}_c{n_communities}_pout{p_out}", seed=seed
    )


def ring(n: int) -> Topology:
    """Deterministic ring (useful for tests & ICI-embedding analysis)."""
    a = np.zeros((n, n))
    for i in range(n):
        a[i, (i + 1) % n] = a[(i + 1) % n, i] = 1.0
    return Topology(a, name=f"ring_n{n}")


def star(n: int) -> Topology:
    """Deterministic hub-and-spoke graph (node 0 = hub).  Maximal degree
    skew in two hops — the golden-run regression suite uses it as the
    sharpest deterministic contrast to the ring for hop-distance
    analytics (tests/regen_goldens.py)."""
    if n < 2:
        raise ValueError(f"star needs n >= 2, got {n}")
    a = np.zeros((n, n))
    a[0, 1:] = a[1:, 0] = 1.0
    return Topology(a, name=f"star_n{n}")


def fully_connected(n: int) -> Topology:
    """Complete graph — the FL baseline's implicit topology."""
    a = np.ones((n, n)) - np.eye(n)
    return Topology(a, name=f"full_n{n}")


def from_adjacency(adjacency: np.ndarray, name: str = "custom") -> Topology:
    return Topology(np.asarray(adjacency, dtype=np.float64), name=name)


TOPOLOGY_BUILDERS = {
    "ba": barabasi_albert,
    "ws": watts_strogatz,
    "sb": stochastic_block,
    "ring": ring,
    "star": star,
    "full": fully_connected,
}


def build_topology(kind: str, **kwargs) -> Topology:
    """Config-system entry point: ``build_topology('ba', n=33, p=2, seed=0)``."""
    if kind not in TOPOLOGY_BUILDERS:
        raise KeyError(f"unknown topology kind {kind!r}; have {sorted(TOPOLOGY_BUILDERS)}")
    return TOPOLOGY_BUILDERS[kind](**kwargs)


def paper_topology_suite(seed: int = 0) -> Sequence[Tuple[str, Topology]]:
    """The 12 (per-seed) topology settings studied in the paper's §5.3."""
    out = []
    for p in (1, 2, 3):
        out.append((f"ba_p{p}", barabasi_albert(33, p, seed)))
    for p_out in (0.009, 0.05, 0.9):
        out.append((f"sb_pout{p_out}", stochastic_block(33, 3, 0.5, p_out, seed)))
    for n in (8, 16, 33, 64):
        out.append((f"ba_n{n}", barabasi_albert(n, 2, seed)))
    for n in (8, 16, 33):
        out.append((f"ws_n{n}", watts_strogatz(n, 4, 0.5, seed)))
    return out
