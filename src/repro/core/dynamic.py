"""Time-varying topologies: unreliable links (beyond-paper robustness).

The paper motivates decentralized learning with "arbitrary and unstable
communication topologies" but evaluates static graphs only (§B.1 "we
assume the topology is static").  This module drops each edge i.i.d. with
probability ``p_fail`` per round and rebuilds the mixing matrix on the
surviving subgraph — modelling flaky WAN links — so strategy robustness
under churn can be measured (``benchmarks/ablations.py
run_link_failure``).

Centrality scores can be computed on the ORIGINAL graph (nodes know their
nominal position; cheap) or the SURVIVING graph per round (reactive;
requires per-round metric recomputation) — both provided.

Two executions of the same idea:

* **host** — :func:`drop_edges` / :func:`dynamic_mixing_matrix` /
  :func:`link_failure_schedule` build numpy matrices per round; the
  schedule pre-materializes a whole run as an ``(R, n, n)`` stack, so link
  churn is *data* the scanned trainer / sweep engine consume (DESIGN.md
  §7) rather than host-side control flow.
* **in-scan** — :func:`edge_mask` draws the same i.i.d. Bernoulli edge
  dropout as a pure-jnp symmetric keep-mask from a folded PRNG key, so
  the device-side coefficient programs (``repro.core.coeffs``,
  DESIGN.md §9) regenerate link churn *inside* the round scan; reactive
  strategies recompute centralities on the masked adjacency there.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.strategies import AggregationStrategy, mixing_matrix
from repro.core.topology import Topology

__all__ = ["drop_edges", "dynamic_mixing_matrix", "link_failure_schedule",
           "edge_mask"]


def edge_mask(key, n: int, p_fail, dtype=jnp.float32) -> jnp.ndarray:
    """(n, n) symmetric 0/1 keep-mask: each undirected edge survives with
    probability ``1 - p_fail`` — the in-scan form of :func:`drop_edges`.

    One uniform draw per upper-triangle entry, mirrored below, so the mask
    is symmetric by construction; multiply into the adjacency to get the
    surviving subgraph.  ``p_fail`` may be a traced scalar; ``p_fail=0``
    keeps every edge exactly (uniform draws live in [0, 1) ≥ 0), which is
    what makes static-topology coefficient programs bit-identical whether
    or not they route through this mask.
    """
    u = jax.random.uniform(key, (n, n))
    u = jnp.triu(u, k=1)
    u = u + u.T
    keep = u >= jnp.asarray(p_fail)
    # the diagonal draw is 0 and would be "dropped" for any p_fail > 0 —
    # irrelevant for adjacencies (zero diagonal) but keep the mask honest
    keep = keep | jnp.eye(n, dtype=bool)
    return keep.astype(dtype)


def drop_edges(topo: Topology, p_fail: float, rng: np.random.Generator,
               keep_connected_to_self: bool = True) -> Topology:
    """Remove each undirected edge with probability ``p_fail``.

    The result may be disconnected — that is the point (knowledge must
    survive partitions); every node always keeps its self-loop in the
    neighbourhood, so isolated nodes simply train locally that round.
    """
    a = topo.adjacency.copy()
    n = topo.n_nodes
    iu = np.triu_indices(n, k=1)
    mask = (a[iu] > 0) & (rng.random(len(iu[0])) < p_fail)
    a[iu[0][mask], iu[1][mask]] = 0.0
    a[iu[1][mask], iu[0][mask]] = 0.0
    return Topology(a, name=f"{topo.name}_drop{p_fail}", seed=topo.seed)


def dynamic_mixing_matrix(
    topo: Topology,
    strategy: AggregationStrategy,
    round_idx: int,
    p_fail: float,
    data_counts: Optional[np.ndarray] = None,
    reactive: bool = False,
) -> np.ndarray:
    """Mixing matrix for one round under link failure.

    reactive=False: centrality from the nominal graph, support restricted
    to surviving edges (renormalized).  reactive=True: centrality
    recomputed on the surviving subgraph.
    """
    rng = np.random.default_rng(
        (strategy.seed * 1_000_003 + round_idx) * 7919 + 17)
    surv = drop_edges(topo, p_fail, rng)
    if reactive or strategy.kind in ("unweighted", "weighted", "random", "fl"):
        return mixing_matrix(surv, strategy, data_counts=data_counts)
    # nominal centralities, surviving support
    full = mixing_matrix(topo, strategy, data_counts=data_counts)
    mask = surv.adjacency + np.eye(topo.n_nodes)
    c = full * mask
    rowsum = c.sum(axis=1, keepdims=True)
    # rows that lost all neighbours fall back to self-weight 1
    c = np.where(rowsum > 0, c / np.maximum(rowsum, 1e-12), np.eye(topo.n_nodes))
    return c


def link_failure_schedule(
    topo: Topology,
    strategy: AggregationStrategy,
    rounds: int,
    p_fail: float,
    data_counts: Optional[np.ndarray] = None,
    reactive: bool = False,
) -> np.ndarray:
    """(R, n, n) stack of per-round link-failure mixing matrices.

    Equals ``[dynamic_mixing_matrix(..., round_idx=r, ...) for r in
    range(R)]`` — the precomputed form the scanned trainer's
    ``coeffs_stack`` path and ``repro.core.sweep`` consume directly
    (equivalently, pass ``coeffs_fn=lambda r: dynamic_mixing_matrix(...)``
    to ``DecentralizedTrainer``; both produce identical runs, see
    tests/test_sweep.py).
    """
    return np.stack([
        dynamic_mixing_matrix(topo, strategy, r, p_fail,
                              data_counts=data_counts, reactive=reactive)
        for r in range(rounds)
    ])
