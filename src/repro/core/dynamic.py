"""Time-varying topologies: unreliable links (beyond-paper robustness).

The paper motivates decentralized learning with "arbitrary and unstable
communication topologies" but evaluates static graphs only (§B.1 "we
assume the topology is static").  This module drops each edge i.i.d. with
probability ``p_fail`` per round and rebuilds the mixing matrix on the
surviving subgraph — modelling flaky WAN links — so strategy robustness
under churn can be measured (``benchmarks/ablations.py
run_link_failure``).

Centrality scores can be computed on the ORIGINAL graph (nodes know their
nominal position; cheap) or the SURVIVING graph per round (reactive;
requires per-round metric recomputation) — both provided.

Two executions of the same idea:

* **host** — :func:`drop_edges` / :func:`dynamic_mixing_matrix` /
  :func:`link_failure_schedule` build numpy matrices per round; the
  schedule pre-materializes a whole run as an ``(R, n, n)`` stack, so link
  churn is *data* the scanned trainer / sweep engine consume (DESIGN.md
  §7) rather than host-side control flow.
* **in-scan** — :func:`edge_mask` draws the same i.i.d. Bernoulli edge
  dropout as a pure-jnp symmetric keep-mask from a folded PRNG key, so
  the device-side coefficient programs (``repro.core.coeffs``,
  DESIGN.md §9) regenerate link churn *inside* the round scan; reactive
  strategies recompute centralities on the masked adjacency there.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.strategies import (AggregationStrategy, mixing_matrix,
                                   renormalize_rows)
from repro.core.topology import Topology

__all__ = ["drop_edges", "dynamic_mixing_matrix", "link_failure_schedule",
           "edge_mask", "ParticipationSpec", "PARTICIPATION_MODES",
           "FaultSpec", "FAULT_MODES"]


def edge_mask(key, n: int, p_fail, dtype=jnp.float32) -> jnp.ndarray:
    """(n, n) symmetric 0/1 keep-mask: each undirected edge survives with
    probability ``1 - p_fail`` — the in-scan form of :func:`drop_edges`.

    One uniform draw per upper-triangle entry, mirrored below, so the mask
    is symmetric by construction; multiply into the adjacency to get the
    surviving subgraph.  ``p_fail`` may be a traced scalar; ``p_fail=0``
    keeps every edge exactly (uniform draws live in [0, 1) ≥ 0), which is
    what makes static-topology coefficient programs bit-identical whether
    or not they route through this mask.
    """
    u = jax.random.uniform(key, (n, n))
    u = jnp.triu(u, k=1)
    u = u + u.T
    keep = u >= jnp.asarray(p_fail)
    # the diagonal draw is 0 and would be "dropped" for any p_fail > 0 —
    # irrelevant for adjacencies (zero diagonal) but keep the mask honest
    keep = keep | jnp.eye(n, dtype=bool)
    return keep.astype(dtype)


PARTICIPATION_MODES = ("bernoulli", "duty")


@dataclasses.dataclass(frozen=True)
class ParticipationSpec:
    """Node-level partial participation: which nodes train+gossip a round.

    The static (hashable → jit-static) half of the participation
    machinery; the traced half — per-experiment ``rate``/``pseed`` plus
    the stale plane and staleness counters — lives in the participation
    carry built by ``repro.core.sweep.SweepEngine`` and threaded through
    the round scan (DESIGN.md §15).

    ``mode="bernoulli"`` draws each node active i.i.d. with probability
    ``rate`` per round, folded from the same PRNG-key convention as
    :func:`edge_mask` (``fold_in(fold_in(key(pseed), round), 2)`` — fold
    index 2; indices 0/1 belong to the edge mask and the Random-strategy
    resample in ``repro.core.coeffs``).  Because uniform draws live in
    [0, 1), ``rate=1.0`` activates every node *exactly*, which is what
    keeps participation-1.0 runs bit-identical to the synchronous engine.

    ``mode="duty"`` is a deterministic staggered duty cycle: node i is
    active in round r iff ``(r + i) % period < k`` with
    ``k = floor(rate·period + 0.5)`` — round-half-up so ``rate=1.0``
    gives ``k=period`` (always active) and ``rate=1/period`` gives
    ``k=1`` (exactly one active node per round) despite float32 rounding.

    ``stale_mixing=True`` (default): inactive nodes' rows of the plane
    are frozen and *published* stale to their neighbours — active nodes
    gossip against the last plane each neighbour ever published.
    ``stale_mixing=False``: inactive neighbours are dropped from the mix
    instead, and surviving rows are renormalized
    (``repro.core.coeffs.participation_renormalize``).
    """

    mode: str = "bernoulli"
    stale_mixing: bool = True
    period: int = 0
    seed: int = 0

    def __post_init__(self):
        if self.mode not in PARTICIPATION_MODES:
            raise ValueError(f"participation mode {self.mode!r} not in "
                             f"{PARTICIPATION_MODES}")
        if self.mode == "duty" and self.period < 1:
            raise ValueError("duty-cycle participation needs period >= 1")

    def active_mask(self, rate, pseed, round_idx, n: int) -> jnp.ndarray:
        """(n,) bool active mask for one round; ``rate``/``pseed``/
        ``round_idx`` may be traced scalars, ``n`` is static."""
        if self.mode == "bernoulli":
            key = jax.random.fold_in(jax.random.fold_in(
                jax.random.key(pseed), round_idx), 2)
            return jax.random.uniform(key, (n,)) < jnp.asarray(rate)
        # duty: static staggered schedule, independent of the PRNG stream
        period = jnp.asarray(self.period, jnp.int32)
        k = jnp.floor(jnp.asarray(rate) * self.period + 0.5).astype(jnp.int32)
        phase = (jnp.asarray(round_idx, jnp.int32) +
                 jnp.arange(n, dtype=jnp.int32)) % period
        return phase < k


FAULT_MODES = ("nan", "inf", "noise", "signflip", "zero")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Byzantine / corruption faults on the *published* parameter plane.

    The static (hashable → jit-static) half of the fault machinery,
    mirroring :class:`ParticipationSpec`: the corruption mode and the
    quarantine policy are compile-time configuration, while the
    per-experiment fault ``rate`` and ``fseed`` are traced values carried
    in the fault carry built by ``repro.core.sweep.SweepEngine`` — so one
    compiled program serves a whole fault-rate grid (DESIGN.md §16).

    Each round, each node is drawn faulty i.i.d. with probability
    ``rate`` from the shared folded-PRNG convention
    (``fold_in(fold_in(key(fseed), round), 3)`` — fold index 3; indices
    0/1/2 belong to the edge mask, the Random-strategy resample, and the
    participation draw).  Uniform draws live in [0, 1), so ``rate=0.0``
    marks no node faulty *exactly* — the bit-identity anchor for the
    fault-free control runs.

    A faulty node corrupts only what it PUBLISHES: its neighbours gossip
    against the garbage row while its own parameters follow local
    semantics (it keeps its honest locally-trained state that round).
    Corruption modes:

    * ``"nan"`` / ``"inf"`` — the published row is poisoned wholesale
      (overflowed local step / bit-rotted payload);
    * ``"noise"`` — Gaussian noise at ``noise_scale`` is added to every
      coordinate (per-leaf keys folded from the round key);
    * ``"signflip"`` — the row is replaced by ``-byz_scale ·`` itself,
      the classic amplified Byzantine attack;
    * ``"zero"`` — the row is zeroed (dropped payload).

    ``quarantine=True`` enables the in-scan self-healing screen: each
    round every node's published row is health-checked (any nonfinite
    coordinate, or plane norm exceeding ``spike_ratio ×`` a carried EMA
    of that node's past published norms).  Flagged nodes are quarantined
    for ``probation`` rounds — their columns are excised from the mixing
    matrix and surviving rows renormalized
    (``repro.core.coeffs.quarantine_renormalize``) — then released.  The
    screen is pure jnp (no callbacks), so it runs inside the scan in all
    four engine modes.
    """

    mode: str = "signflip"
    noise_scale: float = 1.0
    byz_scale: float = 3.0
    seed: int = 0
    quarantine: bool = False
    probation: int = 3
    spike_ratio: float = 10.0
    ema_beta: float = 0.9

    def __post_init__(self):
        if self.mode not in FAULT_MODES:
            raise ValueError(f"fault mode {self.mode!r} not in "
                             f"{FAULT_MODES}")
        if self.quarantine and self.probation < 1:
            raise ValueError("quarantine needs probation >= 1")

    def round_key(self, fseed, round_idx):
        """Fold-index-3 PRNG key for one round's fault draws."""
        return jax.random.fold_in(jax.random.fold_in(
            jax.random.key(fseed), round_idx), 3)

    def faulty_mask(self, rate, fseed, round_idx, n: int) -> jnp.ndarray:
        """(n,) bool faulty mask for one round; ``rate``/``fseed``/
        ``round_idx`` may be traced scalars, ``n`` is static."""
        key = self.round_key(fseed, round_idx)
        return jax.random.uniform(key, (n,)) < jnp.asarray(rate)

    def corrupt(self, stacked_params, fseed, round_idx):
        """Fully corrupted copy of a stacked (n, ...) parameter plane —
        the caller selects faulty rows out of it (``jnp.where`` on the
        mask), so clean rows never touch the corrupted values.  Noise
        keys are folded per-leaf from the round key so no two leaves
        share a draw."""
        key = self.round_key(fseed, round_idx)
        leaves, treedef = jax.tree.flatten(stacked_params)
        out = []
        for i, leaf in enumerate(leaves):
            if self.mode == "nan":
                bad = jnp.full_like(leaf, jnp.nan)
            elif self.mode == "inf":
                bad = jnp.full_like(leaf, jnp.inf)
            elif self.mode == "zero":
                bad = jnp.zeros_like(leaf)
            elif self.mode == "signflip":
                bad = jnp.asarray(-self.byz_scale, leaf.dtype) * leaf
            else:  # noise
                noise = jax.random.normal(jax.random.fold_in(key, i),
                                          leaf.shape, leaf.dtype)
                bad = leaf + jnp.asarray(self.noise_scale, leaf.dtype) * noise
            out.append(bad)
        return jax.tree.unflatten(treedef, out)


def drop_edges(topo: Topology, p_fail: float,
               rng: np.random.Generator) -> Topology:
    """Remove each undirected edge with probability ``p_fail``.

    The result may be disconnected — that is the point (knowledge must
    survive partitions); every node always keeps its self-loop in the
    neighbourhood, so isolated nodes simply train locally that round.
    Self-loops are not droppable here: :class:`Topology` requires a zero
    diagonal, and a node absent *including* its own contribution is
    node-level dropout — that is :class:`ParticipationSpec`'s job, not a
    link-failure draw.
    """
    a = topo.adjacency.copy()
    n = topo.n_nodes
    iu = np.triu_indices(n, k=1)
    mask = (a[iu] > 0) & (rng.random(len(iu[0])) < p_fail)
    a[iu[0][mask], iu[1][mask]] = 0.0
    a[iu[1][mask], iu[0][mask]] = 0.0
    return Topology(a, name=f"{topo.name}_drop{p_fail}", seed=topo.seed)


def dynamic_mixing_matrix(
    topo: Topology,
    strategy: AggregationStrategy,
    round_idx: int,
    p_fail: float,
    data_counts: Optional[np.ndarray] = None,
    reactive: bool = False,
) -> np.ndarray:
    """Mixing matrix for one round under link failure.

    reactive=False: centrality from the nominal graph, support restricted
    to surviving edges (renormalized).  reactive=True: centrality
    recomputed on the surviving subgraph.
    """
    rng = np.random.default_rng(
        (strategy.seed * 1_000_003 + round_idx) * 7919 + 17)
    surv = drop_edges(topo, p_fail, rng)
    if reactive or strategy.kind in ("unweighted", "weighted", "random", "fl"):
        return mixing_matrix(surv, strategy, data_counts=data_counts)
    # nominal centralities, surviving support
    full = mixing_matrix(topo, strategy, data_counts=data_counts)
    mask = surv.adjacency + np.eye(topo.n_nodes)
    # rows that lost all neighbours fall back to self-weight 1
    return renormalize_rows(full * mask)


def link_failure_schedule(
    topo: Topology,
    strategy: AggregationStrategy,
    rounds: int,
    p_fail: float,
    data_counts: Optional[np.ndarray] = None,
    reactive: bool = False,
) -> np.ndarray:
    """(R, n, n) stack of per-round link-failure mixing matrices.

    Equals ``[dynamic_mixing_matrix(..., round_idx=r, ...) for r in
    range(R)]`` — the precomputed form the scanned trainer's
    ``coeffs_stack`` path and ``repro.core.sweep`` consume directly
    (equivalently, pass ``coeffs_fn=lambda r: dynamic_mixing_matrix(...)``
    to ``DecentralizedTrainer``; both produce identical runs, see
    tests/test_sweep.py).
    """
    return np.stack([
        dynamic_mixing_matrix(topo, strategy, r, p_fail,
                              data_counts=data_counts, reactive=reactive)
        for r in range(rounds)
    ])
