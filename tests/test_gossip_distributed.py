"""shard_map gossip vs single-host reference, on 8 forced CPU devices.

Runs in a subprocess because XLA_FLAGS must be set before jax initializes
(and the main pytest process must keep seeing 1 device — per the
assignment, the device-count override is dry-run-only, never global).
"""
import os
import subprocess
import sys
import textwrap


SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import (barabasi_albert, mixing_matrix, AggregationStrategy,
                            stack_params, mix_dense, circulant_decomposition)
    from repro.core.gossip import compat_shard_map, make_gossip_fn, pod_gossip
    from repro.launch.mesh import compat_make_mesh
    from jax.sharding import PartitionSpec as P

    mesh = compat_make_mesh((8,), ("data",))
    t = barabasi_albert(16, 2, seed=0)
    for kind in ("unweighted", "degree"):
        c = mixing_matrix(t, AggregationStrategy(kind, tau=0.1))
        params = stack_params([
            {"w": jnp.arange(6.0).reshape(2, 3) + i, "b": jnp.ones(4) * i}
            for i in range(16)])
        ref = mix_dense(params, c)

        out = make_gossip_fn(mesh, 16)(params, jnp.asarray(c))
        np.testing.assert_allclose(out["w"], ref["w"], rtol=1e-5)
        np.testing.assert_allclose(out["b"], ref["b"], rtol=1e-5)

        sched = circulant_decomposition(c)
        outs = make_gossip_fn(mesh, 16, schedule=sched)(
            params, jnp.asarray(sched.weights))
        np.testing.assert_allclose(outs["w"], ref["w"], rtol=1e-5)

    # pod gossip: 2 pods × 4 data
    mesh2 = compat_make_mesh((2, 4), ("pod", "data"))
    leaf = jnp.arange(2 * 4 * 3.0).reshape(8, 3)
    pc = jnp.array([[0.75, 0.25], [0.25, 0.75]])
    fn = compat_shard_map(lambda x: pod_gossip({"x": x}, pc, "pod")["x"],
                          mesh2, in_specs=P(("pod", "data")),
                          out_specs=P(("pod", "data")))
    got = fn(leaf)
    full = leaf.reshape(2, 4, 3)
    want = jnp.einsum("pq,qnd->pnd", pc, full).reshape(8, 3)
    np.testing.assert_allclose(got, want, rtol=1e-5)
    print("DISTRIBUTED_GOSSIP_OK")
""")


def test_gossip_shard_map_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "DISTRIBUTED_GOSSIP_OK" in out.stdout, out.stderr[-3000:]
