"""Device-sharded sweep engine vs scanned vs unrolled, on 8 forced CPU
devices — the three execution modes must produce bit-identical results
(DESIGN.md §8), including eval_every > 1, mix_impl="pallas", a
link-failure coeffs stack, chunked rounds, E-to-mesh padding (E=3
experiments over 8 devices), in-scan coefficient programs (DESIGN.md
§9: program state sharded on E, reactive link-failure cell, program ==
materialized stack under shard_map), and in-scan streaming analytics
(DESIGN.md §10: carry sharded on E, summaries bit-identical across
scanned / chunked / mesh modes and equal to the host-side
``propagation.py`` oracles).

Runs in a subprocess because XLA_FLAGS must be set before jax initializes
(the main pytest process must keep seeing 1 device — the device-count
override is never global; see conftest.py).
"""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import numpy as np, jax, jax.numpy as jnp
    assert len(jax.devices()) == 8, jax.devices()

    from repro.core.decentralized import (
        DecentralizedConfig, coeffs_stack, stack_params)
    from repro.core.dynamic import link_failure_schedule
    from repro.core.strategies import AggregationStrategy
    from repro.core.sweep import SweepEngine
    from repro.core.topology import ring
    from repro.data.backdoor import backdoored_testset
    from repro.data.distribution import node_datasets
    from repro.data.pipeline import NodeBatcher, make_test_batch
    from repro.data.synthetic import make_dataset
    from repro.launch.mesh import make_sweep_mesh
    from repro.models.paper_models import (
        classifier_accuracy, classifier_loss, ffn_apply, ffn_init)
    from repro.training.optimizer import sgd

    N = 4
    train = make_dataset("mnist", 400, seed=0)
    test = make_dataset("mnist", 100, seed=9)
    loss_fn = classifier_loss(ffn_apply)
    acc_fn = classifier_accuracy(ffn_apply)
    cfg = DecentralizedConfig(rounds=4, local_epochs=2, eval_every=2)
    topo = ring(N)
    parts = node_datasets(train, N, ood_node=0, q=0.10, seed=0)
    nb = NodeBatcher(parts, batch_size=8, steps_per_epoch=2, seed=0,
                     local_epochs=2)
    tb = make_test_batch(test, 32, seed=0)
    ob = make_test_batch(backdoored_testset(test, seed=0), 32, seed=0)

    kinds = ["unweighted", "random", "degree"]   # E=3 → pads to 8 devices
    bank = {k: v[None] for k, v in nb.sample_bank().items()}
    indices = nb.all_round_indices(cfg.rounds)[None]
    data_idx = np.zeros(len(kinds), np.int32)
    coeffs = np.stack([
        coeffs_stack(topo, AggregationStrategy(k, seed=0), cfg.rounds,
                     nb.data_counts())
        for k in kinds])
    # experiment 2 runs a core.dynamic link-failure schedule instead
    coeffs[2] = link_failure_schedule(
        topo, AggregationStrategy("degree", tau=0.1, seed=1), cfg.rounds,
        p_fail=0.5)
    params0 = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[stack_params([ffn_init(jax.random.key(0))] * N)] * len(kinds))
    st = lambda t: {k: jnp.stack([jnp.asarray(t[k])] * len(kinds))
                    for k in t}
    mesh = make_sweep_mesh()   # all 8 virtual devices

    def check(r, ref, label):
        np.testing.assert_array_equal(r.train_loss, ref.train_loss)
        np.testing.assert_array_equal(r.iid_acc, ref.iid_acc)
        np.testing.assert_array_equal(r.ood_acc, ref.ood_acc)
        for a, b in zip(jax.tree.leaves(r.params),
                        jax.tree.leaves(ref.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print(label, "ok")

    for impl in ("einsum", "pallas"):
        c = dataclasses.replace(cfg, mix_impl=impl)
        engine = SweepEngine(sgd(1e-2), loss_fn, acc_fn, c)
        run = lambda **kw: engine.run(
            params0, coeffs, bank, indices, data_idx, st(tb), st(ob),
            batch_size=8, **kw)
        ref = run()
        check(run(unroll_eval=True), ref, impl + "/unrolled")
        check(run(mesh=mesh), ref, impl + "/sharded")
        check(run(mesh=mesh, chunk_rounds=3), ref, impl + "/sharded+chunk")

    # in-scan coefficient programs (DESIGN.md §9): per-experiment state
    # shards on E exactly like a slab; program == materialized stack
    # bit-for-bit under shard_map, incl. a reactive link-failure cell
    from repro.core.coeffs import ProgramCoeffs, program_for, stack_states

    ps = [program_for(topo, AggregationStrategy(k, tau=0.1, seed=e),
                      data_counts=nb.data_counts(), p_fail=pf,
                      reactive=True)
          for e, (k, pf) in enumerate(
              [("unweighted", 0.0), ("random", 0.0), ("degree", 0.5)])]
    pc = ProgramCoeffs(ps[0][0], stack_states([s for _, s in ps]))
    pstacks = np.stack([p.materialize(s, cfg.rounds) for p, s in ps])
    engine = SweepEngine(sgd(1e-2), loss_fn, acc_fn, cfg)
    run = lambda c, **kw: engine.run(
        params0, c, bank, indices, data_idx, st(tb), st(ob),
        batch_size=8, **kw)
    pref = run(pstacks, mesh=mesh)
    check(run(pc, mesh=mesh), pref, "programs/sharded")
    check(run(pc, mesh=mesh, chunk_rounds=3), pref,
          "programs/sharded+chunk")
    check(run(pc), pref, "programs/scanned-vs-sharded-stack")

    # in-scan streaming analytics (DESIGN.md §10): the accumulator carry
    # shards on E; summaries are BIT-identical across scanned / chunked /
    # mesh(8) / mesh(8)+chunk / unrolled and match the host oracles.
    from repro.core import propagation
    from repro.core.analytics import AnalyticsSpec

    spec = AnalyticsSpec(arrival_threshold=0.5)
    engine = SweepEngine(sgd(1e-2), loss_fn, acc_fn, cfg)
    runa = lambda **kw: engine.run(
        params0, coeffs, bank, indices, data_idx, st(tb), st(ob),
        batch_size=8, analytics=spec, **kw)
    ra = runa()
    for label, other in [
        ("chunked", runa(chunk_rounds=3)),
        ("sharded", runa(mesh=mesh)),
        ("sharded+chunk", runa(mesh=mesh, chunk_rounds=3)),
        ("unrolled", runa(unroll_eval=True)),
        ("sharded+no-history", runa(mesh=mesh, keep_history=False)),
    ]:
        for k in ra.analytics:
            np.testing.assert_array_equal(
                ra.analytics[k], other.analytics[k], err_msg=(label, k))
        print("analytics/" + label, "ok")
    # keep_history=False really drops the (E, R, n) history
    rn = runa(mesh=mesh, keep_history=False)
    assert rn.train_loss.shape[1] == 0 and rn.history(0) == []
    for e in range(len(kinds)):
        hist = ra.history(e)
        assert np.abs(ra.analytics["iid_auc"][e]
                      - propagation.per_node_auc(hist, "iid")).max() < 1e-6
        assert np.abs(ra.analytics["ood_auc"][e]
                      - propagation.per_node_auc(hist, "ood")).max() < 1e-6
        np.testing.assert_array_equal(
            ra.analytics["ood_arrival"][e],
            propagation.arrival_rounds(hist, 0.5))
    print("ANALYTICS_SHARDED_OK")

    # fused flat-plane aggregation (DESIGN.md §11): mix_impl="pallas" now
    # packs the stacked pytree and runs ONE pallas_call per mix — the
    # streaming-analytics summaries must stay bit-identical across
    # scanned / chunked / mesh(8) / mesh(8)+chunk with that kernel too.
    engine_p = SweepEngine(sgd(1e-2), loss_fn, acc_fn,
                           dataclasses.replace(cfg, mix_impl="pallas"))
    runp = lambda **kw: engine_p.run(
        params0, coeffs, bank, indices, data_idx, st(tb), st(ob),
        batch_size=8, analytics=spec, **kw)
    rp = runp()
    for label, other in [
        ("chunked", runp(chunk_rounds=3)),
        ("sharded", runp(mesh=mesh)),
        ("sharded+chunk", runp(mesh=mesh, chunk_rounds=3)),
    ]:
        for k in rp.analytics:
            np.testing.assert_array_equal(
                rp.analytics[k], other.analytics[k],
                err_msg=("pallas", label, k))
        print("analytics/pallas/" + label, "ok")
    print("PALLAS_PLANE_ANALYTICS_OK")
    print("SHARDED_SWEEP_OK")
""")


def test_sharded_sweep_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "ANALYTICS_SHARDED_OK" in out.stdout, (out.stdout[-2000:],
                                                  out.stderr[-3000:])
    assert "PALLAS_PLANE_ANALYTICS_OK" in out.stdout, (out.stdout[-2000:],
                                                       out.stderr[-3000:])
    assert "SHARDED_SWEEP_OK" in out.stdout, (out.stdout[-2000:],
                                              out.stderr[-3000:])
