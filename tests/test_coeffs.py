"""Device-side coefficient programs (repro.core.coeffs, DESIGN.md §9).

* jnp centrality kernels property-tested against the networkx values
  cached on ``Topology`` across random BA/WS/SB graphs — including
  disconnected subgraphs produced by ``core.dynamic.drop_edges``;
* the shared score→masked-softmax rule agrees between numpy and jnp;
* non-reactive programs reproduce the legacy host matrices;
* link-failure / reactive semantics (PRNG folding, p_fail edge cases).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis import given, settings, st  # optional dep; skips if absent

from repro.core.coeffs import (
    CENTRALITY_KINDS,
    PROGRAM_KINDS,
    closeness_centrality,
    degree_centrality,
    eigenvector_centrality,
    pagerank_centrality,
    program_for,
    stack_states,
    state_nbytes,
)
from repro.core.dynamic import drop_edges, edge_mask
from repro.core.strategies import (
    AggregationStrategy,
    masked_softmax,
    mixing_matrix,
    strategy_scores,
)
from repro.core.topology import (
    Topology,
    barabasi_albert,
    ring,
    stochastic_block,
    watts_strogatz,
)


def _graph(family: str, seed: int) -> Topology:
    if family == "ba":
        return barabasi_albert(14, 2, seed=seed)
    if family == "ws":
        return watts_strogatz(12, 4, 0.5, seed=seed)
    return stochastic_block(13, 3, 0.5, 0.05, seed=seed)


# ----------------------------------------------------------------------
# jnp kernels vs the networkx values cached on Topology
# ----------------------------------------------------------------------
def _check_kernels_match_networkx(topo: Topology):
    adj = jnp.asarray(topo.adjacency, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(degree_centrality(adj)),
        topo.degree() / (topo.n_nodes - 1), atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(eigenvector_centrality(adj, iters=500)),
        topo.eigenvector(), atol=2e-3)
    np.testing.assert_allclose(
        np.asarray(pagerank_centrality(adj)), topo.pagerank(), atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(closeness_centrality(adj)), topo.closeness(), atol=1e-5)


def _check_kernels_on_disconnected(surv: Topology):
    """degree / exact hop-count closeness / pagerank (dangling-node
    redistribution) match networkx even disconnected; eigenvector stays
    finite, nonnegative, unit-norm (nx's dense eig on disconnected graphs
    is ambiguous up to component choice, so only invariants hold)."""
    adj = jnp.asarray(surv.adjacency, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(degree_centrality(adj)),
        surv.degree() / (surv.n_nodes - 1), atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(closeness_centrality(adj)), surv.closeness(), atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(pagerank_centrality(adj)), surv.pagerank(), atol=1e-4)
    ev = np.asarray(eigenvector_centrality(adj, iters=300))
    assert np.all(np.isfinite(ev)) and np.all(ev >= -1e-7)
    assert np.isclose(np.linalg.norm(ev), 1.0, atol=1e-5)


@pytest.mark.parametrize("family", ["ba", "ws", "sb"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_kernels_match_networkx(family, seed):
    """Deterministic sweep (runs even without hypothesis — the @given
    variants below widen the seed space when it is installed)."""
    _check_kernels_match_networkx(_graph(family, seed))


@pytest.mark.parametrize("family", ["ba", "ws", "sb"])
@pytest.mark.parametrize("p_fail", [0.3, 0.7])
def test_kernels_on_disconnected_subgraphs(family, p_fail):
    surv = drop_edges(_graph(family, 0), p_fail,
                      np.random.default_rng(3))
    _check_kernels_on_disconnected(surv)


@given(family=st.sampled_from(["ba", "ws", "sb"]), seed=st.integers(0, 12))
@settings(max_examples=12, deadline=None)
def test_property_kernels_match_networkx(family, seed):
    """Connected random graphs: all four kernels within f32/power-method
    tolerance of the cached networkx references."""
    _check_kernels_match_networkx(_graph(family, seed))


@given(family=st.sampled_from(["ba", "ws", "sb"]), seed=st.integers(0, 12),
       p_fail=st.sampled_from([0.3, 0.6, 0.9]))
@settings(max_examples=12, deadline=None)
def test_property_kernels_on_disconnected_subgraphs(family, seed, p_fail):
    surv = drop_edges(_graph(family, seed), p_fail,
                      np.random.default_rng(seed * 7 + 1))
    _check_kernels_on_disconnected(surv)


def test_closeness_isolated_node_scores_zero():
    a = np.zeros((5, 5))
    a[0, 1] = a[1, 0] = a[1, 2] = a[2, 1] = 1.0  # path 0-1-2; 3,4 isolated
    cc = np.asarray(closeness_centrality(jnp.asarray(a, jnp.float32)))
    topo = Topology(a)
    np.testing.assert_allclose(cc, topo.closeness(), atol=1e-6)
    assert cc[3] == cc[4] == 0.0


def test_eigenvector_zero_adjacency_stays_uniform():
    ev = np.asarray(eigenvector_centrality(jnp.zeros((6, 6)), iters=50))
    np.testing.assert_allclose(ev, np.full(6, 1 / np.sqrt(6)), atol=1e-6)


# ----------------------------------------------------------------------
# shared masked-softmax rule: numpy path == jnp path
# ----------------------------------------------------------------------
@given(seed=st.integers(0, 20), tau=st.floats(0.05, 5.0))
@settings(max_examples=15, deadline=None)
def test_property_masked_softmax_numpy_vs_jnp(seed, tau):
    topo = barabasi_albert(10, 2, seed=seed)
    mask = topo.adjacency + np.eye(10)
    scores = np.random.default_rng(seed).uniform(size=10)
    host = masked_softmax(scores, mask, tau, xp=np)
    dev = np.asarray(masked_softmax(
        jnp.asarray(scores, jnp.float32), jnp.asarray(mask, jnp.float32),
        jnp.float32(tau), xp=jnp))
    np.testing.assert_allclose(host, dev, atol=1e-6)
    np.testing.assert_allclose(host.sum(1), 1.0, atol=1e-9)
    assert not ((dev > 1e-12) & (mask == 0)).any()


# ----------------------------------------------------------------------
# programs vs the legacy host matrices
# ----------------------------------------------------------------------
@pytest.mark.parametrize("kind", PROGRAM_KINDS)
def test_nonreactive_program_matches_host_matrix(kind):
    topo = barabasi_albert(12, 2, seed=0)
    strat = AggregationStrategy(kind, tau=0.1, seed=3)
    counts = np.arange(1.0, 13.0)
    program, state = program_for(topo, strat, data_counts=counts)
    stack = program.materialize(state, rounds=3)
    host = mixing_matrix(topo, strat, data_counts=counts)
    assert stack.shape == (3, 12, 12)
    np.testing.assert_allclose(stack.sum(axis=2), 1.0, atol=1e-6)
    if kind == "random":
        # same U(0,1)-softmax law, different PRNG (jax vs numpy): compare
        # support and resampling, not values
        assert not np.array_equal(stack[0], stack[1])
        mask = topo.adjacency + np.eye(12)
        assert not ((stack[0] > 1e-12) & (mask == 0)).any()
    else:
        np.testing.assert_allclose(stack[0], host, atol=5e-6)
        np.testing.assert_array_equal(stack[0], stack[2])  # static in r


def test_random_program_resample_flag():
    topo = ring(6)
    strat = AggregationStrategy("random", seed=5)
    program, state = program_for(topo, strat, resample_random=False)
    stack = program.materialize(state, rounds=3)
    np.testing.assert_array_equal(stack[0], stack[1])
    program, state = program_for(topo, strat, resample_random=True)
    stack = program.materialize(state, rounds=3)
    assert not np.array_equal(stack[0], stack[1])


def test_link_failure_varies_per_round_and_is_deterministic():
    topo = barabasi_albert(12, 2, seed=0)
    strat = AggregationStrategy("degree", tau=0.1, seed=7)
    program, state = program_for(topo, strat, p_fail=0.5, reactive=True)
    a = program.materialize(state, rounds=4)
    b = program.materialize(state, rounds=4)
    np.testing.assert_array_equal(a, b)          # pure function of (state, r)
    assert not np.array_equal(a[0], a[1])        # churn varies per round
    mask = topo.adjacency + np.eye(12)
    assert not ((a > 1e-12) & (mask[None] == 0)).any()  # support only shrinks
    np.testing.assert_allclose(a.sum(axis=2), 1.0, atol=1e-6)


def test_p_fail_one_collapses_to_local_training():
    topo = barabasi_albert(8, 2, seed=1)
    for kind in ("unweighted", "degree"):
        program, state = program_for(
            topo, AggregationStrategy(kind, tau=0.1, seed=0), p_fail=1.0,
            reactive=True)
        np.testing.assert_array_equal(
            program.materialize(state, rounds=1)[0],
            np.eye(8, dtype=np.float32))


def test_edge_mask_symmetric_and_p0_keeps_all():
    key = jax.random.key(0)
    m = np.asarray(edge_mask(key, 9, 0.5))
    np.testing.assert_array_equal(m, m.T)
    assert set(np.unique(m)) <= {0.0, 1.0}
    np.testing.assert_array_equal(np.asarray(edge_mask(key, 9, 0.0)),
                                  np.ones((9, 9)))


def test_reactive_degree_recomputes_on_survivor():
    """With every edge of a hub dropped, reactive degree must differ from
    the nominal-score restriction: p_fail churns both, but only reactive
    re-ranks neighbours by surviving degree."""
    topo = barabasi_albert(14, 2, seed=2)
    strat = AggregationStrategy("degree", tau=0.1, seed=11)
    _, s_nom = program_for(topo, strat, p_fail=0.6, reactive=False)
    p_rea, s_rea = program_for(topo, strat, p_fail=0.6, reactive=True)
    p_nom, _ = program_for(topo, strat, p_fail=0.6, reactive=False)
    nom = p_nom.materialize(s_nom, rounds=4)
    rea = p_rea.materialize(s_rea, rounds=4)
    assert not np.array_equal(nom, rea)


# ----------------------------------------------------------------------
# state construction / plumbing
# ----------------------------------------------------------------------
def test_program_for_validates_inputs():
    topo = ring(5)
    with pytest.raises(ValueError, match="data_counts"):
        program_for(topo, AggregationStrategy("weighted"))
    with pytest.raises(KeyError, match="no coefficient program"):
        program_for(topo, AggregationStrategy("metropolis"))
    with pytest.raises(ValueError, match="shape"):
        program_for(topo, AggregationStrategy("weighted"),
                    data_counts=np.ones(3))


def test_centrality_kinds_load_nominal_scores():
    topo = barabasi_albert(10, 2, seed=0)
    for kind in CENTRALITY_KINDS:
        strat = AggregationStrategy(kind, tau=0.1)
        _, state = program_for(topo, strat)
        np.testing.assert_allclose(
            state["scores"], strategy_scores(topo, strat), atol=1e-6)


def test_strategy_matrix_round_idx_matches_round_coeffs():
    """AggregationStrategy.matrix(round_idx=r) must return the SAME
    matrix the trainer/engine consume for round r (round_coeffs) — for
    Random that is the program's folded-PRNG draw, not a host redraw."""
    from repro.core.decentralized import round_coeffs

    topo = barabasi_albert(10, 2, seed=0)
    for kind in ("random", "degree"):
        strat = AggregationStrategy(kind, tau=0.1, seed=4)
        for r in (0, 3):
            np.testing.assert_array_equal(
                strat.matrix(topo, round_idx=r),
                round_coeffs(topo, strat, r))
    # Random still redraws across rounds through the delegation
    strat = AggregationStrategy("random", seed=4)
    assert not np.array_equal(strat.matrix(topo, round_idx=0),
                              strat.matrix(topo, round_idx=1))


def test_stack_states_and_nbytes():
    topo = ring(6)
    states = [program_for(topo, AggregationStrategy("degree", seed=s))[1]
              for s in (0, 1, 2)]
    stacked = stack_states(states)
    assert stacked["adj"].shape == (3, 6, 6)
    assert stacked["seed"].shape == (3,)
    # compact state: ~n² + O(n) floats per experiment, NOT R·n²
    assert state_nbytes(states[0]) < 6 * 6 * 4 + 3 * 6 * 4 + 64


# ----------------------------------------------------------------------
# sparse (edge-list) centrality kernels vs the dense kernels / networkx
# ----------------------------------------------------------------------
def _sparse_operands(topo: Topology):
    """Per-edge operands exactly as ``program_for(..., sparse=True)``
    builds them: padded neighbour tables WITHOUT the self loop, mask
    doubling as unit edge values."""
    nbr_idx, nbr_mask = topo.neighbor_tables(include_self=False)
    return jnp.asarray(nbr_idx), jnp.asarray(nbr_mask, jnp.float32)


def _check_sparse_kernels_match_networkx(topo: Topology):
    from repro.core.coeffs import (
        eigenvector_centrality_sparse,
        pagerank_centrality_sparse,
        sparse_matvec,
    )

    idx, val = _sparse_operands(topo)
    adj = jnp.asarray(topo.adjacency, jnp.float32)
    # per-edge mass recovers degree centrality
    np.testing.assert_allclose(
        np.asarray(val.sum(-1)) / (topo.n_nodes - 1),
        topo.degree() / (topo.n_nodes - 1), atol=1e-6)
    # sparse matvec IS the adjacency action
    x = jnp.asarray(np.random.default_rng(topo.seed or 0)
                    .normal(size=topo.n_nodes), jnp.float32)
    np.testing.assert_allclose(np.asarray(sparse_matvec(idx, val, x)),
                               np.asarray(adj @ x), rtol=1e-5, atol=1e-5)
    # power-method kernels vs the cached networkx references
    np.testing.assert_allclose(
        np.asarray(eigenvector_centrality_sparse(idx, val, iters=500)),
        topo.eigenvector(), atol=2e-3)
    np.testing.assert_allclose(
        np.asarray(pagerank_centrality_sparse(idx, val)),
        topo.pagerank(), atol=1e-4)
    # and bit-for-bit-level agreement with the dense jnp kernels (same
    # operator, same iteration count, same guards)
    np.testing.assert_allclose(
        np.asarray(eigenvector_centrality_sparse(idx, val, iters=200)),
        np.asarray(eigenvector_centrality(adj, iters=200)), atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(pagerank_centrality_sparse(idx, val)),
        np.asarray(pagerank_centrality(adj)), atol=1e-6)


@pytest.mark.parametrize("family", ["ba", "ws", "sb"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_sparse_kernels_match_networkx(family, seed):
    _check_sparse_kernels_match_networkx(_graph(family, seed))


@pytest.mark.parametrize("family", ["ba", "ws", "sb"])
@pytest.mark.parametrize("p_fail", [0.3, 0.7])
def test_sparse_kernels_on_disconnected_subgraphs(family, p_fail):
    """Edge-mask survivors (possibly disconnected, with dangling nodes):
    sparse pagerank matches networkx exactly like the dense kernel, and
    sparse eigenvector keeps the dense kernel's invariants."""
    from repro.core.coeffs import (
        eigenvector_centrality_sparse,
        pagerank_centrality_sparse,
    )

    surv = drop_edges(_graph(family, 0), p_fail, np.random.default_rng(3))
    idx, val = _sparse_operands(surv)
    adj = jnp.asarray(surv.adjacency, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(pagerank_centrality_sparse(idx, val)),
        surv.pagerank(), atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(pagerank_centrality_sparse(idx, val)),
        np.asarray(pagerank_centrality(adj)), atol=1e-6)
    ev = np.asarray(eigenvector_centrality_sparse(idx, val, iters=300))
    assert np.all(np.isfinite(ev)) and np.all(ev >= -1e-7)
    assert np.isclose(np.linalg.norm(ev), 1.0, atol=1e-5)
    np.testing.assert_allclose(
        ev, np.asarray(eigenvector_centrality(adj, iters=300)), atol=1e-6)


@given(family=st.sampled_from(["ba", "ws", "sb"]), seed=st.integers(0, 12))
@settings(max_examples=12, deadline=None)
def test_property_sparse_kernels_match_networkx(family, seed):
    _check_sparse_kernels_match_networkx(_graph(family, seed))


@pytest.mark.parametrize("kind", ["degree", "eigenvector", "pagerank",
                                  "closeness", "random"])
def test_sparse_program_matches_dense_program(kind):
    """The sparse=True reactive program must reproduce the dense reactive
    program's coefficient stack: identical edge_mask draw (same PRNG
    fold), per-edge survival gathered from the same (n, n) mask, same
    power-method trajectories — only the operand layout differs."""
    topo = barabasi_albert(12, 2, seed=0)
    strat = AggregationStrategy(kind, tau=0.1, seed=5)
    p_d, s_d = program_for(topo, strat, p_fail=0.3, reactive=True)
    p_s, s_s = program_for(topo, strat, p_fail=0.3, reactive=True,
                           sparse=True)
    assert p_s.sparse and not p_d.sparse
    assert "nbr_idx" in s_s and "nbr_val" in s_s
    dense = p_d.materialize(s_d, rounds=3)
    sparse = p_s.materialize(s_s, rounds=3)
    np.testing.assert_allclose(sparse, dense, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(sparse).sum(axis=2), 1.0,
                               atol=1e-6)


def test_sparse_program_state_stacks():
    """Per-edge operands ride the stacked state like every other leaf."""
    topo = barabasi_albert(10, 2, seed=1)
    states = [program_for(topo, AggregationStrategy("pagerank", tau=0.1,
                                                    seed=s),
                          p_fail=0.2, reactive=True, sparse=True)[1]
              for s in (0, 1)]
    stacked = stack_states(states)
    dmax = topo.max_degree()
    assert stacked["nbr_idx"].shape == (2, 10, dmax)
    assert stacked["nbr_val"].shape == (2, 10, dmax)


# ----------------------------------------------------------------------
# static branch pruning (CoeffProgram.kinds) + the link-failure gate
# ----------------------------------------------------------------------
def test_pruned_kinds_bit_identical_for_kept_kinds():
    """A program pruned to the grid's kinds must produce bit-identical
    matrices for every kind it keeps (the searchsorted remap only drops
    dead branches)."""
    import dataclasses

    topo = barabasi_albert(12, 2, seed=0)
    for kind in ("degree", "betweenness", "unweighted"):
        strat = AggregationStrategy(kind, tau=0.1, seed=3)
        # betweenness under reactive=True needs the explicit nominal
        # opt-in since the validate_state_kinds guard (DESIGN.md §9)
        program, state = program_for(topo, strat, p_fail=0.3, reactive=True,
                                     allow_nominal_betweenness=True)
        kept = (PROGRAM_KINDS.index(kind),)
        pruned = dataclasses.replace(program, kinds=kept)
        np.testing.assert_array_equal(
            np.asarray(program.materialize(state, rounds=3)),
            np.asarray(pruned.materialize(state, rounds=3)))


def test_pruned_kinds_union_covers_stacked_states():
    """The engine reuses ONE program across a stacked mixed-kind grid: a
    program pruned to the union of the stack's kinds must reproduce each
    state's full-program matrices bit-exactly."""
    import dataclasses

    topo = barabasi_albert(10, 2, seed=1)
    kinds = ("unweighted", "degree", "betweenness")
    programs_states = [
        program_for(topo, AggregationStrategy(k, tau=0.1, seed=5),
                    p_fail=0.3, reactive=True,
                    allow_nominal_betweenness=True)
        for k in kinds
    ]
    union = tuple(sorted(PROGRAM_KINDS.index(k) for k in kinds))
    pruned = dataclasses.replace(programs_states[0][0], kinds=union)
    for program, state in programs_states:
        pruned.validate_state_kinds(state)
        np.testing.assert_array_equal(
            np.asarray(program.materialize(state, rounds=2)),
            np.asarray(pruned.materialize(state, rounds=2)))


def test_pruned_kinds_validation():
    import dataclasses

    topo = ring(6)
    program, state = program_for(topo, AggregationStrategy("degree", tau=0.1))
    with pytest.raises(ValueError, match="non-empty"):
        dataclasses.replace(program, kinds=())
    with pytest.raises(ValueError, match="indices"):
        dataclasses.replace(program, kinds=(99,))
    other = dataclasses.replace(
        program, kinds=(PROGRAM_KINDS.index("unweighted"),))
    with pytest.raises(ValueError, match="rebuild the program"):
        other.validate_state_kinds(state)
    with pytest.raises(ValueError, match="rebuild the program"):
        other.materialize(state, rounds=1)


def test_link_failure_gate_bit_identical_to_p0():
    """link_failure=False must equal the p_fail=0 path bit-exactly (an
    all-ones edge mask keeps every edge and every softmax weight)."""
    import dataclasses

    topo = barabasi_albert(12, 2, seed=4)
    for kind, reactive in (("degree", True), ("betweenness", False),
                           ("unweighted", False)):
        strat = AggregationStrategy(kind, tau=0.1, seed=9)
        program, state = program_for(topo, strat, p_fail=0.0,
                                     reactive=reactive)
        gated = dataclasses.replace(program, link_failure=False)
        np.testing.assert_array_equal(
            np.asarray(program.materialize(state, rounds=3)),
            np.asarray(gated.materialize(state, rounds=3)))
