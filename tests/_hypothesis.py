"""Optional-hypothesis shim for the property-based tests.

``hypothesis`` is a dev-only dependency (pyproject.toml ``[dev]`` extra).
When it is installed, this module re-exports the real ``given`` /
``settings`` / ``strategies``.  When it is not, the decorators degrade to
stubs whose test bodies call ``pytest.importorskip("hypothesis")`` — so
the property tests skip cleanly (instead of failing collection) and the
rest of each test module still runs.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAS_HYPOTHESIS = True
except ImportError:  # degrade to skip-at-runtime stubs
    HAS_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def decorate(fn):
            def skip_without_hypothesis():
                pytest.importorskip("hypothesis")

            skip_without_hypothesis.__name__ = fn.__name__
            skip_without_hypothesis.__doc__ = fn.__doc__
            return skip_without_hypothesis

        return decorate

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _AnyStrategy:
        """Accepts any ``st.xxx(...)`` call made at decoration time."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()
