"""Beyond-paper extensions: new centrality strategies, dynamic topologies,
the serve driver, and the train driver (CLI-level integration)."""
import os
import subprocess
import sys

import jax

import numpy as np
import pytest

from repro.core.dynamic import drop_edges, dynamic_mixing_matrix
from repro.core.strategies import (
    TOPOLOGY_AWARE,
    AggregationStrategy,
    mixing_matrix,
    validate_mixing_matrix,
)
from repro.core.topology import barabasi_albert, ring


NEW_STRATEGIES = ("eigenvector", "pagerank", "closeness")


@pytest.mark.parametrize("kind", NEW_STRATEGIES)
def test_new_centralities_valid(kind):
    topo = barabasi_albert(16, 2, seed=0)
    c = mixing_matrix(topo, AggregationStrategy(kind, tau=0.1))
    validate_mixing_matrix(c, topo)
    assert kind in TOPOLOGY_AWARE


@pytest.mark.parametrize("kind", NEW_STRATEGIES)
def test_new_centralities_prefer_hub(kind):
    """All centrality metrics should give the BA hub more weight than a
    leaf, within any neighbourhood containing both."""
    topo = barabasi_albert(16, 1, seed=0)  # tree: clear hub/leaf split
    c = mixing_matrix(topo, AggregationStrategy(kind, tau=0.1))
    hub = topo.kth_highest_degree_node(1)
    deg = topo.degree()
    for i in topo.neighbors(hub):
        others = [j for j in topo.neighbors(i) if j != hub]
        for j in others:
            if deg[j] < deg[hub]:
                assert c[i, hub] > c[i, j]


class TestDynamicTopology:
    def test_drop_edges_monotone(self):
        topo = barabasi_albert(16, 2, seed=0)
        rng = np.random.default_rng(0)
        surv = drop_edges(topo, 0.5, rng)
        assert surv.n_edges < topo.n_edges
        # surviving edges are a subset
        assert np.all(surv.adjacency <= topo.adjacency)

    def test_drop_zero_identity(self):
        topo = ring(8)
        surv = drop_edges(topo, 0.0, np.random.default_rng(0))
        np.testing.assert_array_equal(surv.adjacency, topo.adjacency)

    @pytest.mark.parametrize("kind", ["unweighted", "degree"])
    def test_dynamic_matrix_row_stochastic(self, kind):
        topo = barabasi_albert(16, 2, seed=0)
        for r in range(5):
            c = dynamic_mixing_matrix(
                topo, AggregationStrategy(kind, tau=0.1), r, p_fail=0.5)
            assert np.allclose(c.sum(1), 1.0, atol=1e-9)
            assert (c >= -1e-12).all()

    def test_full_failure_is_local_training(self):
        topo = ring(6)
        c = dynamic_mixing_matrix(
            topo, AggregationStrategy("degree", tau=0.1), 0, p_fail=1.0)
        np.testing.assert_allclose(c, np.eye(6), atol=1e-9)


ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_cli(mod, *args, timeout=420):
    return subprocess.run(
        [sys.executable, "-m", mod, *args],
        env=dict(os.environ, PYTHONPATH="src"), cwd=ROOT,
        capture_output=True, text=True, timeout=timeout)


def test_train_driver_cli(tmp_path):
    out = _run_cli("repro.launch.train", "--arch", "internvl2-1b", "--smoke",
                   "--nodes", "2", "--rounds", "2", "--steps", "2",
                   "--batch", "2", "--seq", "16",
                   "--ckpt-dir", str(tmp_path), "--log", str(tmp_path / "log.jsonl"))
    assert out.returncode == 0, out.stderr[-2000:]
    assert "round    1" in out.stdout
    assert any(f.startswith("ckpt_") for f in os.listdir(tmp_path))


def test_serve_driver_cli():
    out = _run_cli("repro.launch.serve", "--arch", "stablelm-1.6b", "--smoke",
                   "--nodes", "2", "--batch", "1", "--prompt-len", "4",
                   "--new-tokens", "4")
    assert out.returncode == 0, out.stderr[-2000:]
    assert "served 2 nodes" in out.stdout


def test_dynamic_consensus_still_converges():
    """Gossip under 30% link failure must still drive consensus (in
    expectation the product of surviving mixing matrices is ergodic)."""
    topo = barabasi_albert(12, 2, seed=3)
    x = np.random.default_rng(0).normal(size=12)
    for r in range(300):
        c = dynamic_mixing_matrix(
            topo, AggregationStrategy("degree", tau=0.1), r, p_fail=0.3)
        x = c @ x
    assert np.std(x) < 1e-2


def test_dryrun_pcfg_override_spec():
    """input_specs honours a replanned ParallelConfig (the §Perf path)."""
    import dataclasses
    from repro.configs.registry import get_parallel
    from repro.launch.specs import input_specs

    p = dataclasses.replace(get_parallel("stablelm-1.6b"),
                            n_nodes=64, tp_degree=4, microbatch=1)
    spec = input_specs("stablelm-1.6b", "train_4k", pcfg=p)
    assert spec.n_global_nodes == 64
    leaf = jax.tree_util.tree_leaves(spec.abstract_args[2])[0]
    assert leaf.shape[0] == 64 and leaf.shape[1] * leaf.shape[2] == 4  # 256/64
