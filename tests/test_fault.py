"""Byzantine fault injection + self-healing quarantine + crash-safe
resume (DESIGN.md §16): fault rate 0.0 must collapse to the synchronous
engine bit-for-bit in every execution mode and mixing backend; at nonzero
rates the corruption draw, quarantine state machine, and fault digest
must agree exactly across scanned / chunked / unrolled; and a chunked
sweep killed mid-run must resume from its checkpoints bit-identically to
an uninterrupted one (8-device mesh subprocess at the bottom, like
tests/test_participation.py).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.analytics import quarantine_summary
from repro.core.coeffs import quarantine_renormalize
from repro.core.decentralized import (
    DecentralizedConfig,
    coeffs_stack,
    stack_params,
)
from repro.core.dynamic import FAULT_MODES, FaultSpec, ParticipationSpec
from repro.core.strategies import AggregationStrategy
from repro.core.sweep import SweepEngine
from repro.core.topology import ring
from repro.data.backdoor import backdoored_testset
from repro.data.distribution import node_datasets
from repro.data.pipeline import NodeBatcher, make_test_batch
from repro.data.synthetic import make_dataset
from repro.training.optimizer import sgd

N, ROUNDS, E = 4, 4, 3


@pytest.fixture(scope="module")
def grid():
    """E=3 experiments (unweighted / random / degree) on ring(4), shared
    data bank — the tests/test_participation.py setting."""
    train = make_dataset("mnist", 400, seed=0)
    test = make_dataset("mnist", 100, seed=9)
    from repro.models.paper_models import (
        classifier_accuracy, classifier_loss, ffn_apply, ffn_init)

    topo = ring(N)
    parts = node_datasets(train, N, ood_node=0, q=0.10, seed=0)
    nb = NodeBatcher(parts, batch_size=8, steps_per_epoch=2, seed=0,
                     local_epochs=2)
    tb = make_test_batch(test, 32, seed=0)
    ob = make_test_batch(backdoored_testset(test, seed=0), 32, seed=0)
    kinds = ["unweighted", "random", "degree"]
    bank = {k: v[None] for k, v in nb.sample_bank().items()}
    indices = nb.all_round_indices(ROUNDS)[None]
    data_idx = np.zeros(E, np.int32)
    coeffs = np.stack([
        coeffs_stack(topo, AggregationStrategy(k, seed=0), ROUNDS,
                     nb.data_counts())
        for k in kinds])
    params0 = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[stack_params([ffn_init(jax.random.key(0))] * N)] * E)
    st = lambda t: {k: jnp.stack([jnp.asarray(t[k])] * E) for k in t}
    return {
        "topo": topo,
        "loss_fn": classifier_loss(ffn_apply),
        "acc_fn": classifier_accuracy(ffn_apply),
        "args": (params0, coeffs, bank, indices, data_idx, st(tb), st(ob)),
        "params0": params0,
    }


def _engine(grid, mix_impl="einsum", robust="mean"):
    cfg = DecentralizedConfig(rounds=ROUNDS, local_epochs=2, eval_every=2,
                              mix_impl=mix_impl, robust=robust)
    support = None
    if mix_impl in ("sparse", "edges") or robust in ("trimmed", "median"):
        support = np.asarray(grid["topo"].adjacency) + np.eye(N)
    return SweepEngine(sgd(1e-2), grid["loss_fn"], grid["acc_fn"], cfg,
                       mix_support=support)


def _assert_results_equal(a, b):
    np.testing.assert_array_equal(a.train_loss, b.train_loss)
    np.testing.assert_array_equal(a.iid_acc, b.iid_acc)
    np.testing.assert_array_equal(a.ood_acc, b.ood_acc)
    for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ----------------------------------------------------------------------
# rate 0.0 == the synchronous engine, bit-for-bit (tentpole acceptance)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("mix_impl", ["einsum", "pallas", "edges"])
def test_rate0_bit_identical_to_synchronous(grid, mix_impl):
    """uniform(key) < 0.0 marks no node faulty, the corruption selects
    pick the clean branch everywhere, and the carry adds no arithmetic
    to the plane — so a rate-0.0 run must reproduce the no-fault program
    EXACTLY, per backend and per mode."""
    from repro.launch.mesh import make_sweep_mesh

    engine = _engine(grid, mix_impl)
    run = lambda **kw: engine.run(*grid["args"], batch_size=8, **kw)
    ref = run()
    spec = FaultSpec()
    for label, kw in [
        ("scanned", {}),
        ("chunked", {"chunk_rounds": 3}),
        ("mesh1", {"mesh": make_sweep_mesh(1)}),
        ("unrolled", {"unroll_eval": True}),
    ]:
        res = run(fault=spec, **kw)  # fault_rates default to 0.0
        _assert_results_equal(res, ref)
        f = res.fault
        assert f is not None, label
        np.testing.assert_array_equal(f["fault_rounds"],
                                      np.zeros((E, N), np.int32))
        np.testing.assert_array_equal(f["rounds_quarantined"],
                                      np.zeros((E, N), np.int32))
        np.testing.assert_array_equal(f["first_fault"],
                                      np.full((E, N), -1, np.int32))
        np.testing.assert_array_equal(f["first_quar"],
                                      np.full((E, N), -1, np.int32))


def test_rate0_with_quarantine_bit_identical(grid):
    """Quarantine screen armed at zero fault rate: the screen flags
    nothing (the norm EMA warms up on clean published norms, nonfinite
    counts stay zero) and the run reproduces the plain program exactly.
    A never-clipping norm_clip threshold is equally inert — every row of
    the clipped matrix is returned bit-identical."""
    ref = _engine(grid).run(*grid["args"], batch_size=8)
    res = _engine(grid).run(*grid["args"], batch_size=8,
                            fault=FaultSpec(quarantine=True))
    _assert_results_equal(res, ref)
    np.testing.assert_array_equal(res.fault["rounds_quarantined"],
                                  np.zeros((E, N), np.int32))
    cfg = DecentralizedConfig(rounds=ROUNDS, local_epochs=2, eval_every=2,
                              robust="norm_clip", robust_clip=1e6)
    loose_clip = SweepEngine(sgd(1e-2), grid["loss_fn"], grid["acc_fn"],
                             cfg).run(*grid["args"], batch_size=8,
                                      fault=FaultSpec(quarantine=True))
    _assert_results_equal(loose_clip, ref)


# ----------------------------------------------------------------------
# the corruption draw + modes
# ----------------------------------------------------------------------
def test_faulty_mask_rate_extremes_and_determinism():
    spec = FaultSpec()
    assert not np.asarray(spec.faulty_mask(0.0, 7, 3, 16)).any()
    assert np.asarray(spec.faulty_mask(1.0, 7, 3, 16)).all()
    a = np.asarray(spec.faulty_mask(0.5, 7, 3, 16))
    np.testing.assert_array_equal(a, np.asarray(spec.faulty_mask(0.5, 7, 3, 16)))
    assert not (a == np.asarray(spec.faulty_mask(0.5, 7, 4, 16))).all()
    # fold index 3 is disjoint from the participation draw (index 2)
    p = np.asarray(ParticipationSpec().active_mask(0.5, 7, 3, 16))
    assert not (a == p).all()


@pytest.mark.parametrize("mode", FAULT_MODES)
def test_corruption_modes(mode):
    spec = FaultSpec(mode=mode, noise_scale=0.5, byz_scale=3.0)
    p = {"w": jax.random.normal(jax.random.key(0), (6, 4, 3)) + 1.0,
         "b": jax.random.normal(jax.random.key(1), (6, 5))}
    bad = spec.corrupt(p, 0, 2)
    for k in p:
        b, o = np.asarray(bad[k]), np.asarray(p[k])
        if mode == "nan":
            assert np.isnan(b).all(), k
        elif mode == "inf":
            assert np.isinf(b).all(), k
        elif mode == "zero":
            np.testing.assert_array_equal(b, np.zeros_like(o))
        elif mode == "signflip":
            np.testing.assert_allclose(b, -3.0 * o, rtol=1e-6)
        else:  # noise: every coordinate perturbed, deterministically
            assert (b != o).all(), k
            np.testing.assert_array_equal(
                b, np.asarray(spec.corrupt(p, 0, 2)[k]))


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="mode"):
        FaultSpec(mode="gremlins")
    with pytest.raises(ValueError, match="probation"):
        FaultSpec(quarantine=True, probation=0)
    assert set(FAULT_MODES) == {"nan", "inf", "noise", "signflip", "zero"}


def test_fault_rates_require_spec(grid):
    engine = _engine(grid)
    with pytest.raises(ValueError, match="[Ff]ault"):
        engine.run(*grid["args"], batch_size=8,
                   fault_rates=np.ones(E, np.float32))


# ----------------------------------------------------------------------
# cross-mode equality at a genuinely nonzero rate
# ----------------------------------------------------------------------
def test_nonzero_rate_modes_bit_identical(grid):
    """rate grid [0, .4, .4] with noise faults + quarantine: scanned ==
    chunked (absolute round indices drive the draw) == unrolled,
    including every fault digest array."""
    engine = _engine(grid)
    spec = FaultSpec(mode="noise", quarantine=True, probation=2)
    rates = np.asarray([0.0, 0.4, 0.4], np.float32)
    run = lambda **kw: engine.run(*grid["args"], batch_size=8, fault=spec,
                                  fault_rates=rates, **kw)
    ref = run()
    for label, other in [("chunked", run(chunk_rounds=3)),
                         ("unrolled", run(unroll_eval=True))]:
        _assert_results_equal(other, ref)
        for k in ref.fault:
            np.testing.assert_array_equal(ref.fault[k], other.fault[k],
                                          err_msg=(label, k))
    # the draw actually lands faults at this rate
    assert (np.asarray(ref.fault["fault_rounds"])[1:] > 0).any()


def test_per_experiment_rates_ride_the_vmap_axis(grid):
    """One compiled program serves a fault-rate grid: the rate-0.0 row
    of a mixed [0, .5, .5] run equals the fault-free run bit-for-bit
    (rates are carried data, not static config)."""
    engine = _engine(grid)
    ref = engine.run(*grid["args"], batch_size=8)
    mixed = engine.run(*grid["args"], batch_size=8,
                       fault=FaultSpec(mode="signflip"),
                       fault_rates=np.asarray([0.0, 0.5, 0.5], np.float32))
    np.testing.assert_array_equal(mixed.train_loss[0], ref.train_loss[0])
    np.testing.assert_array_equal(mixed.iid_acc[0], ref.iid_acc[0])
    np.testing.assert_array_equal(
        mixed.fault["fault_rounds"][0], np.zeros(N, np.int32))


# ----------------------------------------------------------------------
# quarantine state machine + containment
# ----------------------------------------------------------------------
def test_nan_faults_detected_immediately_and_contained(grid):
    """NaN-poisoned published rows trip the nonfinite screen the same
    round they appear (first_quar == first_fault), quarantined columns
    are excised before mixing, and every node's parameters stay finite —
    while the same faults WITHOUT quarantine poison the plane."""
    engine = _engine(grid)
    rates = np.asarray([0.0, 0.5, 0.5], np.float32)
    res = engine.run(*grid["args"], batch_size=8,
                     fault=FaultSpec(mode="nan", quarantine=True,
                                     probation=2),
                     fault_rates=rates)
    f = res.fault
    faulted = np.asarray(f["fault_rounds"]) > 0
    assert faulted[1:].any()
    ff, fq = np.asarray(f["first_fault"]), np.asarray(f["first_quar"])
    np.testing.assert_array_equal(fq[faulted], ff[faulted])
    assert (np.asarray(f["quar_fault_rounds"])[faulted] > 0).all()
    for leaf in jax.tree.leaves(res.params):
        assert np.isfinite(np.asarray(leaf)).all()
    # control: same faults, no quarantine, plain mean → contagion
    loose = engine.run(*grid["args"], batch_size=8,
                       fault=FaultSpec(mode="nan"), fault_rates=rates)
    assert not all(np.isfinite(np.asarray(l)).all()
                   for l in jax.tree.leaves(loose.params))


def test_robust_aggregation_contains_nan_without_quarantine(grid):
    """The robust rules are the OTHER containment mechanism: trimmed /
    median keep every parameter finite under NaN faults with the screen
    off (the poisoned rows are outliers the order statistics drop).

    Containment is only guaranteed while each neighbourhood sees at most
    ``trim_k`` faulty rows — on ring(4) that means at most ONE faulty
    node per round.  The draw is deterministic (FaultSpec.seed + the
    default per-experiment fseeds), and rate 0.15 realizes 3 single-node
    fault rounds across the nonzero-rate experiments without ever
    drawing two at once."""
    rates = np.asarray([0.0, 0.15, 0.15], np.float32)
    for robust in ["trimmed", "median"]:
        res = _engine(grid, robust=robust).run(
            *grid["args"], batch_size=8, fault=FaultSpec(mode="nan"),
            fault_rates=rates)
        assert (np.asarray(res.fault["fault_rounds"])[1:] > 0).any()
        for leaf in jax.tree.leaves(res.params):
            assert np.isfinite(np.asarray(leaf)).all(), robust


def test_fault_and_participation_compose(grid):
    """Both carries thread the same scan: dropout (fold 2) and faults
    (fold 3) draw independently; rate-1.0 participation + rate-0.0
    faults still collapse to the synchronous run."""
    engine = _engine(grid)
    ref = engine.run(*grid["args"], batch_size=8)
    res = engine.run(*grid["args"], batch_size=8,
                     participation=ParticipationSpec(),
                     participation_rates=np.ones(E, np.float32),
                     fault=FaultSpec(quarantine=True))
    _assert_results_equal(res, ref)
    assert res.participation is not None and res.fault is not None
    # and a genuinely mixed run completes with both digests populated
    both = engine.run(*grid["args"], batch_size=8,
                      participation=ParticipationSpec(),
                      participation_rates=np.full(E, 0.6, np.float32),
                      fault=FaultSpec(mode="signflip", quarantine=True),
                      fault_rates=np.full(E, 0.3, np.float32))
    assert (np.asarray(both.participation["rounds_active"]) < ROUNDS).any()
    assert (np.asarray(both.fault["fault_rounds"]) > 0).any()


def test_quarantine_renormalize_matches_participation_semantics():
    c = jnp.asarray([[0.5, 0.25, 0.25], [0.3, 0.4, 0.3], [0.2, 0.3, 0.5]])
    none = jnp.zeros((3,), bool)
    np.testing.assert_array_equal(
        np.asarray(quarantine_renormalize(c, none)), np.asarray(c))
    out = np.asarray(quarantine_renormalize(c, jnp.asarray([False, True,
                                                            False])))
    np.testing.assert_allclose(out.sum(-1), np.ones(3), rtol=1e-6)
    np.testing.assert_array_equal(out[[0, 2], 1], np.zeros(2))


def test_quarantine_summary_digest():
    fault = {
        "fault_rounds": np.asarray([3, 0, 1, 0]),
        "rounds_quarantined": np.asarray([4, 2, 0, 0]),
        "quar_fault_rounds": np.asarray([3, 0, 0, 0]),
        "first_fault": np.asarray([2, -1, 5, -1]),
        "first_quar": np.asarray([3, 6, -1, -1]),
    }
    s = quarantine_summary(fault, rounds=10)
    assert s["n_faulty_nodes"] == 2
    assert s["fault_round_rate"] == pytest.approx(4 / 40)
    assert s["rounds_quarantined_max"] == 4
    assert s["detection_lag_mean"] == pytest.approx(1.0)  # node 0 only
    assert s["n_undetected"] == 1                         # node 2
    # node 1 (never faulty) spent 2/10 rounds quarantined; node 3 clean
    assert s["false_positive_rate"] == pytest.approx(2 / 20)
    # all-faulted edge case: FPR undefined
    all_bad = {k: np.asarray(v)[:1] for k, v in fault.items()}
    assert quarantine_summary(all_bad, rounds=10)["false_positive_rate"] is None


# ----------------------------------------------------------------------
# crash-safe checkpointing
# ----------------------------------------------------------------------
def test_checkpoint_dir_requires_chunking(grid):
    with pytest.raises(ValueError, match="chunk_rounds"):
        _engine(grid).run(*grid["args"], batch_size=8,
                          checkpoint_dir="/tmp/nope")


def test_resume_reproduces_uninterrupted_run(grid, tmp_path):
    """Chunked run with checkpointing == plain chunked run; dropping the
    later checkpoints and resuming reproduces the uninterrupted result
    (metrics, params, fault digest) bit-for-bit."""
    engine = _engine(grid)
    spec = FaultSpec(mode="noise", quarantine=True)
    rates = np.asarray([0.0, 0.4, 0.4], np.float32)
    run = lambda **kw: engine.run(*grid["args"], batch_size=8, fault=spec,
                                  fault_rates=rates, chunk_rounds=1, **kw)
    full = run()
    d = str(tmp_path / "ckpt")
    with_ckpt = run(checkpoint_dir=d)
    _assert_results_equal(with_ckpt, full)
    cks = sorted(os.listdir(d))
    assert len(cks) == ROUNDS - 1  # boundaries only, no final-round save
    for fn in cks[1:]:
        os.remove(os.path.join(d, fn))
    resumed = run(checkpoint_dir=d, resume=True)
    _assert_results_equal(resumed, full)
    for k in full.fault:
        np.testing.assert_array_equal(full.fault[k], resumed.fault[k],
                                      err_msg=k)
    # resume with an empty directory is a fresh start, not an error
    fresh = run(checkpoint_dir=str(tmp_path / "empty"), resume=True)
    _assert_results_equal(fresh, full)


# ----------------------------------------------------------------------
# kill-mid-sweep: the crash hook exits hard after 2 saved chunks; the
# resumed run must reproduce the uninterrupted analytics exactly.
# 8 virtual devices — the mesh path's device-put/reput is what a real
# crash recovery exercises (subprocess: XLA_FLAGS before jax init).
# ----------------------------------------------------------------------
_SETUP = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    assert len(jax.devices()) == 8, jax.devices()

    from repro.core.decentralized import (
        DecentralizedConfig, coeffs_stack, stack_params)
    from repro.core.dynamic import FaultSpec
    from repro.core.strategies import AggregationStrategy
    from repro.core.sweep import SweepEngine
    from repro.core.topology import ring
    from repro.data.backdoor import backdoored_testset
    from repro.data.distribution import node_datasets
    from repro.data.pipeline import NodeBatcher, make_test_batch
    from repro.data.synthetic import make_dataset
    from repro.launch.mesh import make_sweep_mesh
    from repro.models.paper_models import (
        classifier_accuracy, classifier_loss, ffn_apply, ffn_init)
    from repro.training.optimizer import sgd

    N, R, E = 4, 4, 3
    train = make_dataset("mnist", 400, seed=0)
    test = make_dataset("mnist", 100, seed=9)
    cfg = DecentralizedConfig(rounds=R, local_epochs=2, eval_every=2)
    topo = ring(N)
    parts = node_datasets(train, N, ood_node=0, q=0.10, seed=0)
    nb = NodeBatcher(parts, batch_size=8, steps_per_epoch=2, seed=0,
                     local_epochs=2)
    tb = make_test_batch(test, 32, seed=0)
    ob = make_test_batch(backdoored_testset(test, seed=0), 32, seed=0)
    kinds = ["unweighted", "random", "degree"]  # E=3 pads to 8 devices
    bank = {k: v[None] for k, v in nb.sample_bank().items()}
    indices = nb.all_round_indices(R)[None]
    data_idx = np.zeros(E, np.int32)
    coeffs = np.stack([
        coeffs_stack(topo, AggregationStrategy(k, seed=0), R,
                     nb.data_counts())
        for k in kinds])
    params0 = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[stack_params([ffn_init(jax.random.key(0))] * N)] * E)
    st = lambda t: {k: jnp.stack([jnp.asarray(t[k])] * E) for k in t}
    mesh = make_sweep_mesh()  # all 8 virtual devices
    engine = SweepEngine(sgd(1e-2), classifier_loss(ffn_apply),
                         classifier_accuracy(ffn_apply), cfg)
    spec = FaultSpec(mode="noise", quarantine=True)
    rates = np.asarray([0.0, 0.4, 0.4], np.float32)
    ckpt_dir = os.environ["FAULT_TEST_CKPT_DIR"]
    run = lambda **kw: engine.run(
        params0, coeffs, bank, indices, data_idx, st(tb), st(ob),
        batch_size=8, fault=spec, fault_rates=rates, mesh=mesh,
        chunk_rounds=1, **kw)
""")

_SCRIPT_KILL = _SETUP + textwrap.dedent("""
    print("starting doomed run", flush=True)
    run(checkpoint_dir=ckpt_dir)
    print("SHOULD NEVER GET HERE")
""")

_SCRIPT_RESUME = _SETUP + textwrap.dedent("""
    import jax
    saved = sorted(os.listdir(ckpt_dir))
    assert len(saved) == 2, saved   # killed after exactly 2 chunk saves
    resumed = run(checkpoint_dir=ckpt_dir, resume=True)
    full = run()
    np.testing.assert_array_equal(resumed.train_loss, full.train_loss)
    np.testing.assert_array_equal(resumed.iid_acc, full.iid_acc)
    np.testing.assert_array_equal(resumed.ood_acc, full.ood_acc)
    for a, b in zip(jax.tree.leaves(resumed.params),
                    jax.tree.leaves(full.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for k in full.fault:
        np.testing.assert_array_equal(resumed.fault[k], full.fault[k],
                                      err_msg=k)
    from repro.core.analytics import quarantine_summary
    for e in range(E):
        s = quarantine_summary({k: v[e] for k, v in resumed.fault.items()},
                               R)
        assert 0.0 <= s["fault_round_rate"] <= 1.0
    print("FAULT_RESUME_OK")
""")


def test_kill_and_resume_subprocess(tmp_path):
    repo = os.path.dirname(os.path.dirname(__file__))
    env = dict(os.environ, PYTHONPATH="src",
               FAULT_TEST_CKPT_DIR=str(tmp_path))
    killed = subprocess.run(
        [sys.executable, "-c", _SCRIPT_KILL],
        env=dict(env, REPRO_SWEEP_CRASH_AFTER_CHUNKS="2"),
        capture_output=True, text=True, timeout=600, cwd=repo)
    assert killed.returncode == 17, (killed.returncode,
                                     killed.stdout[-2000:],
                                     killed.stderr[-3000:])
    assert "SHOULD NEVER GET HERE" not in killed.stdout
    resumed = subprocess.run([sys.executable, "-c", _SCRIPT_RESUME],
                             env=env, capture_output=True, text=True,
                             timeout=600, cwd=repo)
    assert "FAULT_RESUME_OK" in resumed.stdout, (resumed.stdout[-2000:],
                                                 resumed.stderr[-3000:])
