"""Per-assigned-architecture smoke tests (deliverable f).

For every arch id: instantiate the REDUCED variant of the same family
(≤2 layers, d_model ≤ 512, ≤4 experts), run one forward and one train
step on CPU, assert output shapes and no NaNs; run one decode step for
decoder archs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_config, get_smoke_config
from repro.models.transformer import decode_step, forward, init_cache, init_params
from repro.training.losses import lm_loss_fn
from repro.training.optimizer import adamw, apply_updates

B, S = 2, 32


def _batch(cfg, key):
    ks = jax.random.split(key, 2)
    labels = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    if cfg.frontend is not None:
        return {
            "embeddings": jax.random.normal(ks[0], (B, S, cfg.frontend_dim)),
            "labels": labels,
        }
    return {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "labels": labels,
    }


@pytest.mark.parametrize("arch", sorted(ARCHS))
class TestArchSmoke:
    def test_smoke_config_is_reduced(self, arch):
        cfg = get_smoke_config(arch)
        full = get_config(arch)
        assert cfg.n_layers <= 2
        assert cfg.d_model <= 512
        assert cfg.n_experts <= 4
        assert cfg.family == full.family

    def test_forward_shapes_no_nan(self, arch):
        cfg = get_smoke_config(arch)
        params = init_params(jax.random.key(0), cfg)
        batch = _batch(cfg, jax.random.key(1))
        logits, aux = forward(params, cfg, {k: v for k, v in batch.items()
                                            if k != "labels"})
        assert logits.shape == (B, S, cfg.vocab_size)
        assert not bool(jnp.isnan(logits).any())
        assert not bool(jnp.isnan(aux))

    def test_one_train_step(self, arch):
        cfg = get_smoke_config(arch)
        params = init_params(jax.random.key(0), cfg)
        batch = _batch(cfg, jax.random.key(1))
        loss_fn = lm_loss_fn(cfg)
        opt = adamw(1e-3)
        state = opt.init(params)

        @jax.jit
        def step(p, s, b):
            loss, grads = jax.value_and_grad(loss_fn)(p, b)
            updates, s = opt.update(grads, s, p)
            return apply_updates(p, updates), s, loss

        p1, state, l1 = step(params, state, batch)
        p2, state, l2 = step(p1, state, batch)
        assert np.isfinite(float(l1)) and np.isfinite(float(l2))
        assert float(l2) < float(l1) + 1.0  # not diverging on repeat batch
        # params actually changed
        diff = sum(float(jnp.abs(a - b).sum())
                   for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p1)))
        assert diff > 0

    def test_one_decode_step(self, arch):
        cfg = get_smoke_config(arch)
        params = init_params(jax.random.key(0), cfg)
        cache = init_cache(cfg, B, 16)
        toks = jax.random.randint(jax.random.key(2), (B, 1), 0, cfg.vocab_size)
        logits, cache2 = decode_step(params, cfg, toks, cache)
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert not bool(jnp.isnan(logits).any())
        assert int(cache2["position"][0]) == 1


def test_full_configs_match_assignment():
    """Spot-check the exact assigned values."""
    c = get_config("deepseek-v2-236b")
    assert (c.n_layers, c.d_model, c.n_heads) == (60, 5120, 128)
    assert (c.n_experts, c.experts_per_token, c.n_shared_experts) == (160, 6, 2)
    assert c.kv_lora_rank == 512 and c.use_mla
    c = get_config("gemma2-27b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab_size) == (46, 4608, 36864, 256000)
    assert c.attn_logit_softcap == 50.0 and c.final_logit_softcap == 30.0
    c = get_config("llama4-scout-17b-a16e")
    assert (c.n_experts, c.experts_per_token) == (16, 1)
    assert c.vocab_size == 202048
    c = get_config("rwkv6-3b")
    assert c.family == "ssm" and c.d_model == 2560 and c.vocab_size == 65536
    c = get_config("hymba-1.5b")
    assert c.hybrid_ssm and c.ssm_state_dim == 16 and c.n_kv_heads == 5
    c = get_config("starcoder2-7b")
    assert (c.d_model, c.n_heads, c.n_kv_heads, c.d_ff) == (4608, 36, 4, 18432)
    c = get_config("musicgen-medium")
    assert c.frontend == "audio" and c.vocab_size == 2048 and c.n_layers == 48
    c = get_config("internvl2-1b")
    assert c.frontend == "vision" and c.n_kv_heads == 2
    c = get_config("stablelm-1.6b")
    assert (c.n_layers, c.d_model, c.vocab_size) == (24, 2048, 100352)
    c = get_config("phi3-mini-3.8b")
    assert (c.n_layers, c.d_model, c.d_ff) == (32, 3072, 8192)


def test_param_counts_close_to_published():
    published = {
        "stablelm-1.6b": 1.6e9, "phi3-mini-3.8b": 3.8e9, "starcoder2-7b": 7.2e9,
        "gemma2-27b": 27e9, "deepseek-v2-236b": 236e9, "rwkv6-3b": 3.1e9,
        "llama4-scout-17b-a16e": 109e9, "hymba-1.5b": 1.5e9,
    }
    for arch, target in published.items():
        got = get_config(arch).param_count()
        assert abs(got - target) / target < 0.25, (arch, got, target)
