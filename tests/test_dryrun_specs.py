"""Dry-run spec construction (no 512-device compile — structure only) and a
small end-to-end dry-run on 8 forced devices in a subprocess."""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS
from repro.launch.specs import LONG_CTX_OK, LONG_CTX_SKIP, applicable_shapes, input_specs


def test_every_arch_has_a_long_ctx_ruling():
    for arch in ARCHS:
        assert (arch in LONG_CTX_OK) != (arch in LONG_CTX_SKIP), arch


def test_applicable_shapes_counts():
    total = sum(len(applicable_shapes(a)) for a in ARCHS)
    skips = len(LONG_CTX_SKIP)
    assert total == len(ARCHS) * len(SHAPES) - skips == 34


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_spec_structure_matches_args(arch):
    """in_specs tree must prefix-match the abstract args (what jit needs)."""
    for shape in applicable_shapes(arch):
        spec = input_specs(arch, shape.name, multi_pod=False)
        assert len(spec.abstract_args) == len(spec.in_specs)
        if spec.kind == "train":
            params, opt, batch, coeffs = spec.abstract_args
            # batch shapes recombine to the global batch
            leaf = jax.tree.leaves(batch)[0]
            n, micro, mb = leaf.shape[:3]
            assert n * micro * mb == shape.global_batch
            assert leaf.shape[3] == shape.seq_len
        elif spec.kind == "decode":
            params, tokens, cache = spec.abstract_args
            assert tokens.shape[-1] == 1          # ONE new token
            assert int(jax.tree.leaves(cache)[0].shape[0]) == spec.n_global_nodes


SMALL_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.registry import get_smoke_config
    from repro.configs.base import ParallelConfig, InputShape
    from repro.launch.mesh import compat_make_mesh
    from repro.training.train_step import make_train_step
    from repro.training.optimizer import make_optimizer
    from repro.models.transformer import ForwardOptions, init_params
    from repro.sharding import param_specs, opt_specs_like

    mesh = compat_make_mesh((1, 2, 2, 2), ("pod", "node", "fsdp", "model"))
    cfg = get_smoke_config("stablelm-1.6b")
    pcfg = ParallelConfig(n_nodes=2, microbatch=2, remat=True)
    opt = make_optimizer("adamw", 1e-3)
    step = make_train_step(cfg, pcfg, opt, opts=ForwardOptions())
    n, b, s = 2, 4, 32
    p_abs = jax.eval_shape(jax.vmap(lambda k: init_params(k, cfg)),
                           jax.ShapeDtypeStruct((n, 2), jnp.uint32))
    o_abs = jax.eval_shape(jax.vmap(opt.init), p_abs)
    ax = {"model": 2, "fsdp": 2}
    ps = param_specs(p_abs, axis_sizes=ax)
    os_ = opt_specs_like(o_abs, ps)
    batch = {"tokens": jax.ShapeDtypeStruct((n, 2, b, s), jnp.int32),
             "labels": jax.ShapeDtypeStruct((n, 2, b, s), jnp.int32)}
    bs = {k: P(("pod", "node"), None, "fsdp", None) for k in batch}
    coeffs = jax.ShapeDtypeStruct((n, n), jnp.float32)
    sh = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    with mesh:
        compiled = jax.jit(step, in_shardings=(sh(ps), sh(os_), sh(bs), sh(P())),
                           out_shardings=(sh(ps), sh(os_), sh(P()))) \
            .lower(p_abs, o_abs, batch, coeffs).compile()
    txt = compiled.as_text()
    assert any(c in txt for c in ("all-reduce", "all-gather")), "no collectives?"
    print("SMALL_DRYRUN_OK")
""")


def test_small_dryrun_compiles_with_collectives():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SMALL_DRYRUN], env=env,
                         capture_output=True, text=True, timeout=420,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "SMALL_DRYRUN_OK" in out.stdout, out.stderr[-3000:]
