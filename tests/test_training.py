import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models.transformer import ForwardOptions, init_params
from repro.training.checkpoint import (
    latest_checkpoint,
    load_checkpoint,
    save_checkpoint,
)
from repro.training.losses import lm_loss_fn, softmax_xent
from repro.training.optimizer import (
    adam,
    adamw,
    apply_updates,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
    make_optimizer,
    sgd,
    warmup_cosine_schedule,
)
from repro.training.train_step import make_train_step, reshape_for_microbatch

CFG = ModelConfig(name="tiny", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                  d_ff=128, vocab_size=128, dtype="float32",
                  param_dtype="float32")


def _quad_problem():
    """min ||p - t||² — optimizers must converge on it."""
    t = jnp.array([1.0, -2.0, 3.0])

    def loss(p, batch=None):
        return jnp.sum(jnp.square(p - t))

    return t, loss


class TestOptimizers:
    @pytest.mark.parametrize("opt_fn", [
        lambda: sgd(0.1), lambda: sgd(0.05, momentum=0.9),
        lambda: adam(0.1), lambda: adamw(0.1, weight_decay=0.001),
    ])
    def test_converges_on_quadratic(self, opt_fn):
        t, loss = _quad_problem()
        opt = opt_fn()
        p = jnp.zeros(3)
        s = opt.init(p)
        for _ in range(200):
            g = jax.grad(loss)(p)
            u, s = opt.update(g, s, p)
            p = apply_updates(p, u)
        assert float(loss(p)) < 1e-2

    def test_clip_by_global_norm(self):
        tree = {"a": jnp.full((4,), 10.0)}
        clipped, norm = clip_by_global_norm(tree, 1.0)
        assert float(global_norm(clipped)) <= 1.0 + 1e-5
        assert float(norm) == pytest.approx(20.0)

    def test_schedules(self):
        cos = cosine_schedule(1.0, 100)
        assert float(cos(0)) == pytest.approx(1.0)
        assert float(cos(100)) == pytest.approx(0.1)
        wc = warmup_cosine_schedule(1.0, 10, 110)
        assert float(wc(0)) < float(wc(9))
        assert float(wc(9)) == pytest.approx(1.0)

    def test_make_optimizer_registry(self):
        assert make_optimizer("sgd", 0.1)
        with pytest.raises(KeyError):
            make_optimizer("lion", 0.1)


class TestLosses:
    def test_xent_uniform(self):
        logits = jnp.zeros((2, 8, 16))
        labels = jnp.zeros((2, 8), jnp.int32)
        assert float(softmax_xent(logits, labels)) == pytest.approx(np.log(16), rel=1e-5)

    def test_chunked_ce_matches_full(self):
        params = init_params(jax.random.key(0), CFG)
        toks = jax.random.randint(jax.random.key(1), (2, 32), 0, 128)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
        full = lm_loss_fn(CFG)(params, batch)
        chunked = lm_loss_fn(CFG, chunked_ce=8)(params, batch)
        assert float(full) == pytest.approx(float(chunked), rel=1e-4)


class TestTrainStep:
    def _setup(self, micro):
        pcfg = ParallelConfig(n_nodes=4, microbatch=micro, remat=False)
        opt = adamw(1e-3)
        step = make_train_step(CFG, pcfg, opt,
                               opts=ForwardOptions(remat=False))
        params = jax.vmap(lambda k: init_params(k, CFG))(
            jnp.stack([jax.random.key(0)] * 4))
        opt_state = jax.vmap(opt.init)(params)
        return step, params, opt_state

    def test_microbatch_equivalence(self):
        """grad accumulation over microbatches == one big batch."""
        toks = jax.random.randint(jax.random.key(5), (32, 16), 0, 128)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
        coeffs = jnp.eye(4)

        outs = {}
        for micro in (1, 2):
            step, params, opt_state = self._setup(micro)
            b = reshape_for_microbatch(batch, 4, micro)
            p, _, loss = jax.jit(step)(params, opt_state, b, coeffs)
            outs[micro] = (p, float(loss))
        p1, l1 = outs[1]
        p2, l2 = outs[2]
        assert l1 == pytest.approx(l2, rel=1e-4)
        for a, b_ in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=2e-3, atol=2e-4)

    def test_gossip_changes_params_toward_consensus(self):
        step, params, opt_state = self._setup(1)
        # perturb node 0 away from the others
        params = jax.tree.map(
            lambda x: x.at[0].add(jnp.ones_like(x[0])), params)
        toks = jax.random.randint(jax.random.key(5), (32, 16), 0, 128)
        batch = reshape_for_microbatch(
            {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}, 4, 1)
        full_avg = jnp.full((4, 4), 0.25)
        p, _, _ = jax.jit(step)(params, opt_state, batch, full_avg)
        # after full averaging all nodes identical
        leaf = jax.tree.leaves(p)[0]
        np.testing.assert_allclose(np.asarray(leaf[0]), np.asarray(leaf[1]),
                                   rtol=1e-5, atol=1e-6)


class TestCheckpoint:
    def test_roundtrip_with_opt(self, tmp_path):
        params = init_params(jax.random.key(0), CFG)
        opt = adamw(1e-3)
        state = opt.init(params)
        save_checkpoint(str(tmp_path), 3, params, state, metadata={"lr": 1e-3})
        path = latest_checkpoint(str(tmp_path))
        p2, s2, meta = load_checkpoint(path, params, state)
        assert meta["step"] == 3 and meta["lr"] == 1e-3
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_shape_mismatch_rejected(self, tmp_path):
        params = {"w": jnp.ones((3, 3))}
        save_checkpoint(str(tmp_path), 0, params)
        with pytest.raises(ValueError):
            load_checkpoint(latest_checkpoint(str(tmp_path)), {"w": jnp.ones((2, 2))})

    def test_latest_picks_max_step(self, tmp_path):
        params = {"w": jnp.ones(2)}
        save_checkpoint(str(tmp_path), 1, params)
        save_checkpoint(str(tmp_path), 12, params)
        assert "00000012" in latest_checkpoint(str(tmp_path))
