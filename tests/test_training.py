import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models.transformer import ForwardOptions, init_params
from repro.training.checkpoint import (
    latest_checkpoint,
    load_checkpoint,
    save_checkpoint,
)
from repro.training.losses import lm_loss_fn, softmax_xent
from repro.training.optimizer import (
    NonfiniteGuardState,
    adam,
    adamw,
    apply_updates,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
    make_optimizer,
    sgd,
    skip_nonfinite_updates,
    warmup_cosine_schedule,
)
from repro.training.train_step import make_train_step, reshape_for_microbatch

CFG = ModelConfig(name="tiny", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                  d_ff=128, vocab_size=128, dtype="float32",
                  param_dtype="float32")


def _quad_problem():
    """min ||p - t||² — optimizers must converge on it."""
    t = jnp.array([1.0, -2.0, 3.0])

    def loss(p, batch=None):
        return jnp.sum(jnp.square(p - t))

    return t, loss


class TestOptimizers:
    @pytest.mark.parametrize("opt_fn", [
        lambda: sgd(0.1), lambda: sgd(0.05, momentum=0.9),
        lambda: adam(0.1), lambda: adamw(0.1, weight_decay=0.001),
    ])
    def test_converges_on_quadratic(self, opt_fn):
        t, loss = _quad_problem()
        opt = opt_fn()
        p = jnp.zeros(3)
        s = opt.init(p)
        for _ in range(200):
            g = jax.grad(loss)(p)
            u, s = opt.update(g, s, p)
            p = apply_updates(p, u)
        assert float(loss(p)) < 1e-2

    def test_clip_by_global_norm(self):
        tree = {"a": jnp.full((4,), 10.0)}
        clipped, norm = clip_by_global_norm(tree, 1.0)
        assert float(global_norm(clipped)) <= 1.0 + 1e-5
        assert float(norm) == pytest.approx(20.0)

    def test_schedules(self):
        cos = cosine_schedule(1.0, 100)
        assert float(cos(0)) == pytest.approx(1.0)
        assert float(cos(100)) == pytest.approx(0.1)
        wc = warmup_cosine_schedule(1.0, 10, 110)
        assert float(wc(0)) < float(wc(9))
        assert float(wc(9)) == pytest.approx(1.0)

    def test_make_optimizer_registry(self):
        assert make_optimizer("sgd", 0.1)
        with pytest.raises(KeyError):
            make_optimizer("lion", 0.1)


class TestNonfiniteGuard:
    """skip_nonfinite_updates (DESIGN.md §16): the local half of fault
    tolerance — one poisoned batch must not destroy the node."""

    def test_clean_steps_bit_identical_to_unwrapped(self):
        opt, raw = skip_nonfinite_updates(adam(1e-2)), adam(1e-2)
        p = {"w": jnp.ones((3, 2)), "b": jnp.zeros(2)}
        g = {"w": jnp.full((3, 2), 0.1), "b": jnp.full(2, -0.2)}
        s, rs = opt.init(p), raw.init(p)
        for _ in range(3):
            u, s = jax.jit(opt.update)(g, s, p)
            ru, rs = jax.jit(raw.update)(g, rs, p)
            for a, b in zip(jax.tree.leaves(u), jax.tree.leaves(ru)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert int(s.skipped) == 0

    @pytest.mark.parametrize("poison", [jnp.nan, jnp.inf, -jnp.inf])
    def test_poisoned_step_is_identity(self, poison):
        opt = skip_nonfinite_updates(sgd(0.1, momentum=0.9))
        p = {"w": jnp.ones((4,))}
        s = opt.init(p)
        u1, s1 = opt.update({"w": jnp.full(4, 0.3)}, s, p)
        bad = {"w": jnp.asarray([0.1, poison, 0.2, 0.3])}
        u2, s2 = opt.update(bad, s1, p)
        np.testing.assert_array_equal(np.asarray(u2["w"]), np.zeros(4))
        assert int(s2.skipped) == 1
        # inner state untouched: momentum AND step (LR schedule frozen)
        np.testing.assert_array_equal(np.asarray(s2.inner.momentum["w"]),
                                      np.asarray(s1.inner.momentum["w"]))
        assert int(s2.inner.step) == int(s1.inner.step)
        # recovery: the next clean step proceeds normally
        u3, s3 = opt.update({"w": jnp.full(4, 0.3)}, s2, p)
        assert np.isfinite(np.asarray(u3["w"])).all()
        assert (np.asarray(u3["w"]) != 0).all()
        assert int(s3.skipped) == 1

    def test_poisoned_batch_through_train_step(self):
        """End-to-end: a label-poisoned batch NaNs the gradients of one
        node; with the guard that node's params and opt state come back
        bit-identical and only its skip counter advances — without it the
        node is destroyed."""
        pcfg = ParallelConfig(n_nodes=4, microbatch=1, remat=False)
        opt = skip_nonfinite_updates(adamw(1e-3))
        # gossip=False: the dense contraction would smear node 2's NaN
        # params into every row (0·NaN) — containing THAT is the robust
        # aggregators' job (tests/test_robust_mix.py), not the guard's
        step = make_train_step(CFG, pcfg, adamw(1e-3),
                               opts=ForwardOptions(remat=False),
                               gossip=False, skip_nonfinite=True)
        params = jax.vmap(lambda k: init_params(k, CFG))(
            jnp.stack([jax.random.key(0)] * 4))
        opt_state = jax.vmap(opt.init)(params)
        toks = jax.random.randint(jax.random.key(5), (32, 16), 0, 128)
        batch = reshape_for_microbatch(
            {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}, 4, 1)
        # poison node 2's embedding so its grads (and loss) go nonfinite
        poisoned = jax.tree.map(lambda x: x, params)
        leaves, treedef = jax.tree_util.tree_flatten(poisoned)
        leaves = [l.at[2].set(jnp.nan) for l in leaves]
        poisoned = jax.tree_util.tree_unflatten(treedef, leaves)
        p2, s2, _ = jax.jit(step)(poisoned, opt_state, batch, jnp.eye(4))
        skipped = np.asarray(s2.skipped)
        np.testing.assert_array_equal(skipped, [0, 0, 1, 0])
        for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(poisoned)):
            # node 2: identity update (params carried through unchanged
            # modulo the NaNs it already had); others: genuine updates
            aa, bb = np.asarray(a), np.asarray(b)
            np.testing.assert_array_equal(aa[2], bb[2])
            assert (aa[[0, 1, 3]] != bb[[0, 1, 3]]).any()

    def test_wrapped_state_structure(self):
        opt = make_optimizer("sgd", 0.1, skip_nonfinite=True)
        s = opt.init({"w": jnp.ones(2)})
        assert isinstance(s, NonfiniteGuardState)
        assert int(s.skipped) == 0


class TestLosses:
    def test_xent_uniform(self):
        logits = jnp.zeros((2, 8, 16))
        labels = jnp.zeros((2, 8), jnp.int32)
        assert float(softmax_xent(logits, labels)) == pytest.approx(np.log(16), rel=1e-5)

    def test_chunked_ce_matches_full(self):
        params = init_params(jax.random.key(0), CFG)
        toks = jax.random.randint(jax.random.key(1), (2, 32), 0, 128)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
        full = lm_loss_fn(CFG)(params, batch)
        chunked = lm_loss_fn(CFG, chunked_ce=8)(params, batch)
        assert float(full) == pytest.approx(float(chunked), rel=1e-4)


class TestTrainStep:
    def _setup(self, micro):
        pcfg = ParallelConfig(n_nodes=4, microbatch=micro, remat=False)
        opt = adamw(1e-3)
        step = make_train_step(CFG, pcfg, opt,
                               opts=ForwardOptions(remat=False))
        params = jax.vmap(lambda k: init_params(k, CFG))(
            jnp.stack([jax.random.key(0)] * 4))
        opt_state = jax.vmap(opt.init)(params)
        return step, params, opt_state

    def test_microbatch_equivalence(self):
        """grad accumulation over microbatches == one big batch."""
        toks = jax.random.randint(jax.random.key(5), (32, 16), 0, 128)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
        coeffs = jnp.eye(4)

        outs = {}
        for micro in (1, 2):
            step, params, opt_state = self._setup(micro)
            b = reshape_for_microbatch(batch, 4, micro)
            p, _, loss = jax.jit(step)(params, opt_state, b, coeffs)
            outs[micro] = (p, float(loss))
        p1, l1 = outs[1]
        p2, l2 = outs[2]
        assert l1 == pytest.approx(l2, rel=1e-4)
        for a, b_ in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=2e-3, atol=2e-4)

    def test_gossip_changes_params_toward_consensus(self):
        step, params, opt_state = self._setup(1)
        # perturb node 0 away from the others
        params = jax.tree.map(
            lambda x: x.at[0].add(jnp.ones_like(x[0])), params)
        toks = jax.random.randint(jax.random.key(5), (32, 16), 0, 128)
        batch = reshape_for_microbatch(
            {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}, 4, 1)
        full_avg = jnp.full((4, 4), 0.25)
        p, _, _ = jax.jit(step)(params, opt_state, batch, full_avg)
        # after full averaging all nodes identical
        leaf = jax.tree.leaves(p)[0]
        np.testing.assert_allclose(np.asarray(leaf[0]), np.asarray(leaf[1]),
                                   rtol=1e-5, atol=1e-6)


class TestCheckpoint:
    def test_roundtrip_with_opt(self, tmp_path):
        params = init_params(jax.random.key(0), CFG)
        opt = adamw(1e-3)
        state = opt.init(params)
        save_checkpoint(str(tmp_path), 3, params, state, metadata={"lr": 1e-3})
        path = latest_checkpoint(str(tmp_path))
        p2, s2, meta = load_checkpoint(path, params, state)
        assert meta["step"] == 3 and meta["lr"] == 1e-3
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_shape_mismatch_rejected(self, tmp_path):
        params = {"w": jnp.ones((3, 3))}
        save_checkpoint(str(tmp_path), 0, params)
        with pytest.raises(ValueError):
            load_checkpoint(latest_checkpoint(str(tmp_path)), {"w": jnp.ones((2, 2))})

    def test_latest_picks_max_step(self, tmp_path):
        params = {"w": jnp.ones(2)}
        save_checkpoint(str(tmp_path), 1, params)
        save_checkpoint(str(tmp_path), 12, params)
        assert "00000012" in latest_checkpoint(str(tmp_path))

    def test_missing_key_names_tree_path(self, tmp_path):
        save_checkpoint(str(tmp_path), 0, {"w": jnp.ones(2)})
        with pytest.raises(KeyError, match="params/extra"):
            load_checkpoint(latest_checkpoint(str(tmp_path)),
                            {"w": jnp.ones(2), "extra": jnp.ones(3)})

    def test_dtype_mismatch_names_tree_path(self, tmp_path):
        save_checkpoint(str(tmp_path), 0, {"w": jnp.ones((3, 3), jnp.float32)})
        with pytest.raises(ValueError, match=r"params/w.*dtype"):
            load_checkpoint(latest_checkpoint(str(tmp_path)),
                            {"w": jnp.ones((3, 3), jnp.int32)})

    def test_truncated_file_detected(self, tmp_path):
        """A partially-copied / disk-corrupted archive must fail as a
        ValueError naming the file — not leak zipfile internals or, far
        worse, resume from garbage."""
        params = {"w": jnp.arange(64, dtype=jnp.float32)}
        path = save_checkpoint(str(tmp_path), 0, params)
        raw = open(path, "rb").read()
        with open(path, "wb") as f:
            f.write(raw[: len(raw) // 2])
        with pytest.raises(ValueError, match="truncated or corrupt"):
            load_checkpoint(path, params)

    def test_non_checkpoint_npz_rejected(self, tmp_path):
        """A stray .npz without the __meta__ sidecar is not a checkpoint."""
        path = str(tmp_path / "ckpt_00000000.npz")
        np.savez(path, **{"params/w": np.ones(2, np.float32)})
        with pytest.raises(ValueError, match="__meta__"):
            load_checkpoint(path, {"w": jnp.ones(2)})

    def test_crash_mid_write_leaves_previous_checkpoint(self, tmp_path):
        """The atomic tmp+rename contract: a checkpoint path either holds
        the complete old file or the complete new one.  Simulate the
        crash window by writing the tmp file and never renaming."""
        params = {"w": jnp.ones(4)}
        path = save_checkpoint(str(tmp_path), 0, params)
        (tmp_path / "garbage.tmp").write_bytes(b"half a checkpoint")
        assert latest_checkpoint(str(tmp_path)) == path  # .tmp ignored
        p, _, meta = load_checkpoint(path, params)
        assert meta["step"] == 0
