"""Decode-path correctness: token-by-token cached decode must reproduce the
full-sequence forward logits (the serving invariant), per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models.transformer import decode_step, forward, init_cache, init_params
from repro.serving.serve_step import greedy_generate, make_cache, make_serve_step

DENSE = ModelConfig(name="dense", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                    d_ff=128, vocab_size=64, dtype="float32", param_dtype="float32")
LOCAL = ModelConfig(name="local", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                    d_ff=128, vocab_size=64, attn_pattern=("local", "global"),
                    window_size=8, dtype="float32", param_dtype="float32")
MLA = ModelConfig(name="mla", family="moe", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=4, d_ff=128, vocab_size=64, use_mla=True,
                  kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
                  v_head_dim=16, n_experts=4, experts_per_token=2,
                  moe_d_ff=64, capacity_factor=8.0,  # high cap: dropless
                  dtype="float32", param_dtype="float32")
SSM = ModelConfig(name="ssm", family="ssm", n_layers=2, d_model=64, d_ff=128,
                  vocab_size=64, rwkv_head_dim=32, norm_kind="layernorm",
                  dtype="float32", param_dtype="float32")
HYBRID = ModelConfig(name="hy", family="hybrid", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
                     hybrid_ssm=True, ssm_state_dim=8,
                     dtype="float32", param_dtype="float32")


def _decode_all(cfg, params, toks, max_seq):
    b, s = toks.shape
    cache = init_cache(cfg, b, max_seq)
    outs = []
    step = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c))
    for i in range(s):
        logits, cache = step(params, toks[:, i : i + 1], cache)
        outs.append(logits[:, 0])
    return jnp.stack(outs, axis=1)


@pytest.mark.parametrize("cfg", [DENSE, LOCAL, MLA, SSM, HYBRID],
                         ids=lambda c: c.name)
def test_decode_matches_forward(cfg):
    params = init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab_size)
    full, _ = forward(params, cfg, {"tokens": toks})
    inc = _decode_all(cfg, params, toks, 16)
    np.testing.assert_allclose(np.asarray(inc), np.asarray(full),
                               rtol=3e-3, atol=3e-3)


def test_local_ring_buffer_wraps():
    """Decoding past the window must still work (ring-buffer cache) and
    match a full forward whose local mask hides old positions anyway."""
    cfg = LOCAL
    params = init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (1, 20), 0, cfg.vocab_size)
    full, _ = forward(params, cfg, {"tokens": toks})
    # cache length = window for all-local? pattern has global too → max_seq
    inc = _decode_all(cfg, params, toks, 24)
    np.testing.assert_allclose(np.asarray(inc), np.asarray(full),
                               rtol=3e-3, atol=3e-3)


def test_greedy_generate_deterministic():
    params = init_params(jax.random.key(0), DENSE)
    prompt = jax.random.randint(jax.random.key(1), (2, 4), 0, 64)
    out1 = greedy_generate(DENSE, params, prompt, n_new=6)
    out2 = greedy_generate(DENSE, params, prompt, n_new=6)
    assert out1.shape == (2, 10)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    np.testing.assert_array_equal(np.asarray(out1[:, :4]), np.asarray(prompt))


def test_stacked_serve_step():
    """The node-stacked serving path: each node's model serves its own
    requests (the paper's per-device inference)."""
    n = 3
    params = jax.vmap(lambda k: init_params(k, DENSE))(
        jax.random.split(jax.random.key(0), n))
    serve = jax.jit(make_serve_step(DENSE))
    cache = make_cache(DENSE, n, batch_per_node=2, max_seq=8)
    toks = jax.random.randint(jax.random.key(1), (n, 2, 1), 0, 64)
    logits, cache = serve(params, toks, cache)
    assert logits.shape == (n, 2, 1, 64)
    # different node params ⇒ different logits
    assert not np.allclose(np.asarray(logits[0]), np.asarray(logits[1]))
    assert (np.asarray(cache["position"]) == 1).all()


# ----------------------------------------------------------------------
# chunked prefill kernel (make_prefill_step): bit-equality + self-feed
# ----------------------------------------------------------------------
@pytest.mark.parametrize("cfg", [DENSE, SSM], ids=lambda c: c.name)
def test_chunked_prefill_bit_equals_decode_loop(cfg):
    """One fused (B, C) prefill call must be BIT-identical — logits and
    every cache leaf — to C sequential decode_step dispatches (same math,
    one trace)."""
    from repro.serving.serve_step import make_prefill_step

    params = init_params(jax.random.key(0), cfg)
    b, c, max_seq = 2, 6, 16
    toks = jax.random.randint(jax.random.key(1), (b, c), 0, cfg.vocab_size)

    ref_cache = init_cache(cfg, b, max_seq)
    step = jax.jit(lambda p, t, ca: decode_step(p, cfg, t, ca))
    ref_logits = None
    for i in range(c):
        ref_logits, ref_cache = step(params, toks[:, i : i + 1], ref_cache)

    prefill = jax.jit(make_prefill_step(cfg))
    full = jnp.full((b,), c, jnp.int32)
    last, sampled, cache = prefill(params, toks, full, full,
                                   init_cache(cfg, b, max_seq))
    np.testing.assert_array_equal(np.asarray(last),
                                  np.asarray(ref_logits[:, 0]))
    np.testing.assert_array_equal(np.asarray(sampled[:, -1]),
                                  np.asarray(jnp.argmax(ref_logits[:, 0], -1)))
    for k in cache:
        np.testing.assert_array_equal(np.asarray(cache[k]),
                                      np.asarray(ref_cache[k]), err_msg=k)


def test_chunked_prefill_freezes_masked_slots():
    """lens[b] = 0 lanes must pass every cache leaf through untouched
    (bit-exact) while other lanes advance — the invariant that lets one
    call serve slots in different lifecycle phases."""
    from repro.serving.serve_step import make_prefill_step

    cfg = DENSE
    params = init_params(jax.random.key(0), cfg)
    b, c, max_seq = 3, 5, 12
    cache0 = init_cache(cfg, b, max_seq)
    # advance all lanes a little first so the frozen state is nontrivial
    warm = jax.random.randint(jax.random.key(2), (b, 2), 0, cfg.vocab_size)
    for i in range(2):
        _, cache0 = decode_step(params, cfg, warm[:, i : i + 1], cache0)

    prefill = jax.jit(make_prefill_step(cfg))
    toks = jax.random.randint(jax.random.key(3), (b, c), 0, cfg.vocab_size)
    feed = jnp.asarray([c, 0, 3], jnp.int32)
    lens = jnp.asarray([c, 0, 3], jnp.int32)
    _, _, cache = prefill(params, toks, feed, lens, cache0)
    for k in cache:
        axis = 0 if k == "position" else 1
        frozen = jnp.take(cache[k], jnp.asarray([1]), axis=axis)
        orig = jnp.take(cache0[k], jnp.asarray([1]), axis=axis)
        np.testing.assert_array_equal(np.asarray(frozen), np.asarray(orig),
                                      err_msg=k)
    assert int(cache["position"][0]) == 2 + c
    assert int(cache["position"][1]) == 2
    assert int(cache["position"][2]) == 2 + 3


def test_prefill_self_feed_matches_greedy():
    """A lane that exhausts its planned tokens self-feeds its greedy
    sample: prompt + in-chunk generation must equal greedy_generate."""
    from repro.serving.serve_step import make_prefill_step

    cfg = DENSE
    params = init_params(jax.random.key(0), cfg)
    prompt = jax.random.randint(jax.random.key(4), (1, 4), 0, cfg.vocab_size)
    n_new = 5
    ref = np.asarray(greedy_generate(cfg, params, prompt, n_new))[0, 4:]

    c = 4 + n_new - 1  # prompt feeds 4, then 4 more self-fed steps
    toks = jnp.zeros((1, c), jnp.int32).at[0, :4].set(prompt[0])
    prefill = jax.jit(make_prefill_step(cfg))
    _, sampled, _ = prefill(params, toks, jnp.asarray([4], jnp.int32),
                            jnp.asarray([c], jnp.int32),
                            init_cache(cfg, 1, 16))
    np.testing.assert_array_equal(np.asarray(sampled[0, 3:3 + n_new]), ref)
