"""Decode-path correctness: token-by-token cached decode must reproduce the
full-sequence forward logits (the serving invariant), per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models.transformer import decode_step, forward, init_cache, init_params
from repro.serving.serve_step import greedy_generate, make_cache, make_serve_step

DENSE = ModelConfig(name="dense", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                    d_ff=128, vocab_size=64, dtype="float32", param_dtype="float32")
LOCAL = ModelConfig(name="local", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                    d_ff=128, vocab_size=64, attn_pattern=("local", "global"),
                    window_size=8, dtype="float32", param_dtype="float32")
MLA = ModelConfig(name="mla", family="moe", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=4, d_ff=128, vocab_size=64, use_mla=True,
                  kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
                  v_head_dim=16, n_experts=4, experts_per_token=2,
                  moe_d_ff=64, capacity_factor=8.0,  # high cap: dropless
                  dtype="float32", param_dtype="float32")
SSM = ModelConfig(name="ssm", family="ssm", n_layers=2, d_model=64, d_ff=128,
                  vocab_size=64, rwkv_head_dim=32, norm_kind="layernorm",
                  dtype="float32", param_dtype="float32")
HYBRID = ModelConfig(name="hy", family="hybrid", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
                     hybrid_ssm=True, ssm_state_dim=8,
                     dtype="float32", param_dtype="float32")


def _decode_all(cfg, params, toks, max_seq):
    b, s = toks.shape
    cache = init_cache(cfg, b, max_seq)
    outs = []
    step = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c))
    for i in range(s):
        logits, cache = step(params, toks[:, i : i + 1], cache)
        outs.append(logits[:, 0])
    return jnp.stack(outs, axis=1)


@pytest.mark.parametrize("cfg", [DENSE, LOCAL, MLA, SSM, HYBRID],
                         ids=lambda c: c.name)
def test_decode_matches_forward(cfg):
    params = init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab_size)
    full, _ = forward(params, cfg, {"tokens": toks})
    inc = _decode_all(cfg, params, toks, 16)
    np.testing.assert_allclose(np.asarray(inc), np.asarray(full),
                               rtol=3e-3, atol=3e-3)


def test_local_ring_buffer_wraps():
    """Decoding past the window must still work (ring-buffer cache) and
    match a full forward whose local mask hides old positions anyway."""
    cfg = LOCAL
    params = init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (1, 20), 0, cfg.vocab_size)
    full, _ = forward(params, cfg, {"tokens": toks})
    # cache length = window for all-local? pattern has global too → max_seq
    inc = _decode_all(cfg, params, toks, 24)
    np.testing.assert_allclose(np.asarray(inc), np.asarray(full),
                               rtol=3e-3, atol=3e-3)


def test_greedy_generate_deterministic():
    params = init_params(jax.random.key(0), DENSE)
    prompt = jax.random.randint(jax.random.key(1), (2, 4), 0, 64)
    out1 = greedy_generate(DENSE, params, prompt, n_new=6)
    out2 = greedy_generate(DENSE, params, prompt, n_new=6)
    assert out1.shape == (2, 10)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    np.testing.assert_array_equal(np.asarray(out1[:, :4]), np.asarray(prompt))


def test_stacked_serve_step():
    """The node-stacked serving path: each node's model serves its own
    requests (the paper's per-device inference)."""
    n = 3
    params = jax.vmap(lambda k: init_params(k, DENSE))(
        jax.random.split(jax.random.key(0), n))
    serve = jax.jit(make_serve_step(DENSE))
    cache = make_cache(DENSE, n, batch_per_node=2, max_seq=8)
    toks = jax.random.randint(jax.random.key(1), (n, 2, 1), 0, 64)
    logits, cache = serve(params, toks, cache)
    assert logits.shape == (n, 2, 1, 64)
    # different node params ⇒ different logits
    assert not np.allclose(np.asarray(logits[0]), np.asarray(logits[1]))
    assert (np.asarray(cache["position"]) == 1).all()
