"""Per-kernel allclose vs the pure-jnp oracles, swept over shapes/dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypothesis import given, settings, st  # optional dep; skips if absent

from repro.core.topology import barabasi_albert, padded_neighbor_tables, ring
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gossip_mix import (
    gossip_edges_pallas,
    gossip_mix_pallas,
    gossip_plane_pallas,
    mix_dense_pallas,
    mix_edges_pallas,
    mix_eqn_budget,
    mix_modeled_hbm_bytes,
    mix_plane_pallas,
)
from repro.kernels.ref import flash_attention_ref, gossip_mix_ref, rwkv_scan_ref
from repro.kernels.ssm_scan import rwkv_scan_pallas


class TestGossipPlane:
    """Fused flat-plane mix: out = C @ plane in ONE pallas_call."""

    @pytest.mark.parametrize("n,p,bt", [
        (4, 100, 256), (8, 512, 256), (5, 129, 128), (16, 3000, 1024),
        (3, 1, 128), (9, 1025, 512),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_allclose(self, n, p, bt, dtype):
        plane = (jax.random.normal(jax.random.key(0), (n, p)) * 2).astype(dtype)
        c = jax.nn.softmax(jax.random.normal(jax.random.key(1), (n, n)), axis=1)
        out = gossip_plane_pallas(plane, c, bt=bt)
        ref = (c @ plane.astype(jnp.float32)).astype(dtype)
        tol = 1e-6 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=tol, atol=tol)

    def test_one_pallas_call_regardless_of_leaf_count(self, jaxlint):
        """THE fusion contract: a 4-leaf ragged pytree mixes in exactly
        one kernel launch, where the legacy path issued one per leaf
        (each itself vmapped over n destination rows) — asserted as the
        named fusion-budget rule over the introspectable per-impl
        metadata, on the real equation graph (no jaxpr str() matching)."""
        n = 6
        ks = jax.random.split(jax.random.key(0), 4)
        params = {
            "w": jax.random.normal(ks[0], (n, 4, 6)),
            "b": jax.random.normal(ks[1], (n, 5)),
            "deep": {"u": jax.random.normal(ks[2], (n, 3, 2))},
            "scalar": jax.random.normal(ks[3], (n,)),
        }
        c = jax.nn.softmax(jax.random.normal(jax.random.key(9), (n, n)), axis=1)
        jaxlint.check(
            mix_plane_pallas, params, c,
            rules=[jaxlint.FusionBudget.of(mix_eqn_budget("pallas"),
                                           scope="all")])
        # the legacy per-leaf path: one launch per leaf, for contrast
        assert jaxlint.pallas_calls(mix_dense_pallas, params, c) == 4

    def test_non_lane_multiple_bt_is_clamped(self):
        """A caller-supplied bt that is not a 128 multiple must still
        produce a correct (TPU-lowerable) tiling — bt is clamped up to a
        lane multiple internally."""
        n, p = 4, 5000
        plane = jax.random.normal(jax.random.key(2), (n, p))
        c = jax.nn.softmax(jax.random.normal(jax.random.key(3), (n, n)), axis=1)
        out = gossip_plane_pallas(plane, c, bt=1000)
        np.testing.assert_allclose(np.asarray(out), np.asarray(c @ plane),
                                   rtol=1e-6, atol=1e-6)

    def test_row_stochastic_fixed_point(self):
        """Constant params across nodes are a fixed point of any
        row-stochastic matrix — the invariance consensus relies on."""
        n = 8
        one = jax.random.normal(jax.random.key(3), (40,))
        params = {"w": jnp.broadcast_to(one, (n, 40))}
        c = jax.nn.softmax(jax.random.normal(jax.random.key(4), (n, n)), axis=1)
        out = mix_plane_pallas(params, c)
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   np.asarray(params["w"]),
                                   rtol=1e-6, atol=1e-6)

    def test_bf16_accumulation_knob(self):
        """mix_in_float32=False accumulates in the plane dtype: on a bf16
        plane it matches a bf16-native oracle, and differs from the f32
        accumulation path."""
        n, p = 8, 400
        plane = (jax.random.normal(jax.random.key(5), (n, p)) * 2
                 ).astype(jnp.bfloat16)
        c = jax.nn.softmax(jax.random.normal(jax.random.key(6), (n, n)), axis=1)
        low = gossip_plane_pallas(plane, c, mix_in_float32=False)
        oracle = jnp.dot(c.astype(jnp.bfloat16), plane,
                         preferred_element_type=jnp.bfloat16)
        np.testing.assert_array_equal(np.asarray(low, np.float32),
                                      np.asarray(oracle, np.float32))
        hi = gossip_plane_pallas(plane, c, mix_in_float32=True)
        assert np.any(np.asarray(hi, np.float32)
                      != np.asarray(low, np.float32))

    def test_vmap_over_experiments(self):
        """The sweep engine vmaps the mix over E — batching must equal
        per-experiment calls."""
        n, p = 4, 260
        planes = jax.random.normal(jax.random.key(7), (3, n, p))
        cs = jax.nn.softmax(jax.random.normal(jax.random.key(8), (3, n, n)),
                            axis=-1)
        out = jax.vmap(lambda pl_, c_: gossip_plane_pallas(pl_, c_, bt=128))(
            planes, cs)
        for e in range(3):
            np.testing.assert_allclose(
                np.asarray(out[e]), np.asarray(cs[e] @ planes[e]),
                rtol=1e-6, atol=1e-6)

    def test_modeled_bytes_fused_dominates_rows(self):
        """The honest bytes model: the fused kernel stream moves strictly
        fewer HBM bytes than the legacy per-row fan-out at every studied
        scale; counting the pack/unpack copies too (6·n·P) it still wins
        whenever n·(n+1) > 6·n, i.e. for every paper topology (n ≥ 8).
        The legacy wrapper is ~n·(K+1)·|P| as the module docstring now
        states."""
        for n in (4, 16, 33, 64):
            for p_floats in (10_000, 1_000_000):
                rows = mix_modeled_hbm_bytes("pallas_rows", n, p_floats,
                                             n_leaves=6)
                plane = mix_modeled_hbm_bytes("pallas_plane", n, p_floats)
                e2e = mix_modeled_hbm_bytes("pallas_plane_e2e", n, p_floats)
                assert plane < e2e and plane < rows
                if n >= 8:
                    assert e2e < rows
                # legacy model ≈ n·(n+1)·P·4: within the weight-vector term
                assert abs(rows - n * (n + 1) * p_floats * 4) <= 6 * n * n * 4
                # fused kernel stream ≈ 2·n·P·4 + coeff refetches
                assert plane >= 2 * n * p_floats * 4
                assert plane - 2 * n * p_floats * 4 <= \
                    -(-p_floats // 2048) * n * n * 4


def _edge_inputs(n, p, dtype=jnp.float32, seed=0, topo=None):
    """Random plane + row-stochastic coeffs on a sparse support, plus the
    padded-ELL tables and per-edge weights for that support."""
    from repro.core.mixing import edge_weights

    topo = barabasi_albert(n, p=2, seed=seed) if topo is None else topo
    support = np.asarray(topo.adjacency) + np.eye(n)
    rng = np.random.default_rng(seed)
    c = rng.random((n, n)).astype(np.float32) * (support > 0)
    c /= c.sum(1, keepdims=True)
    plane = (jax.random.normal(jax.random.key(seed), (n, p)) * 2).astype(dtype)
    idx, msk = padded_neighbor_tables(support)
    w = edge_weights(jnp.asarray(c), jnp.asarray(idx), jnp.asarray(msk))
    return plane, jnp.asarray(c), jnp.asarray(idx), jnp.asarray(msk), w


class TestGossipEdges:
    """Edge-list gather/accumulate mix: out = C @ plane where C's support
    is a padded-ELL neighbour table — O(n·dmax·bt) weight traffic per
    tile instead of O(n²)."""

    @pytest.mark.parametrize("n,p,bt", [
        (8, 100, 256), (16, 512, 256), (13, 129, 128), (32, 3000, 1024),
        (9, 1, 128),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_allclose(self, n, p, bt, dtype):
        plane, c, idx, _, w = _edge_inputs(n, p, dtype)
        out = gossip_edges_pallas(plane, w, idx, bt=bt)
        ref = (c @ plane.astype(jnp.float32)).astype(dtype)
        tol = 1e-6 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=tol, atol=tol)

    def test_ring_small_dmax(self):
        """dmax=3 on a ring — the degenerate small-degree case the padded
        table layout is built for."""
        n, p = 24, 700
        plane, c, idx, _, w = _edge_inputs(n, p, topo=ring(n))
        assert idx.shape[1] == 3
        out = gossip_edges_pallas(plane, w, idx)
        np.testing.assert_allclose(np.asarray(out), np.asarray(c @ plane),
                                   rtol=1e-6, atol=1e-6)

    def test_one_pallas_call_on_ragged_pytree(self, jaxlint):
        """Same fusion contract as the dense plane kernel: the whole
        multi-leaf mix is ONE pallas_call — the named fusion-budget rule
        over the edges-impl metadata."""
        n = 8
        ks = jax.random.split(jax.random.key(0), 3)
        params = {
            "w": jax.random.normal(ks[0], (n, 4, 6)),
            "b": jax.random.normal(ks[1], (n, 5)),
            "scalar": jax.random.normal(ks[2], (n,)),
        }
        _, c, idx, msk, _ = _edge_inputs(n, 8)
        jaxlint.check(
            mix_edges_pallas, params, c, idx, msk,
            rules=[jaxlint.FusionBudget.of(mix_eqn_budget("edges"),
                                           scope="all")])

    def test_mix_edges_pallas_matches_host(self):
        """Tree-level wrapper round-trips leaf shapes/dtypes and matches
        the jnp reference path."""
        from repro.core.mixing import mix_edges

        n = 12
        ks = jax.random.split(jax.random.key(1), 2)
        params = {"w": jax.random.normal(ks[0], (n, 7, 3)),
                  "b": jax.random.normal(ks[1], (n,))}
        _, c, idx, msk, _ = _edge_inputs(n, 8, seed=3)
        out = mix_edges_pallas(params, c, idx, msk)
        ref = mix_edges(params, c, idx, msk)
        for k in params:
            assert out[k].shape == params[k].shape
            assert out[k].dtype == params[k].dtype
            np.testing.assert_allclose(np.asarray(out[k]),
                                       np.asarray(ref[k]),
                                       rtol=1e-6, atol=1e-6)

    def test_bf16_accumulation_knob(self):
        """mix_in_float32=False accumulates in the plane dtype and
        differs from the f32-accumulation path on a bf16 plane."""
        n, p = 16, 400
        plane, _, idx, _, w = _edge_inputs(n, p, jnp.bfloat16, seed=2)
        hi = gossip_edges_pallas(plane, w, idx, mix_in_float32=True)
        lo = gossip_edges_pallas(plane, w, idx, mix_in_float32=False)
        assert np.any(np.asarray(hi, np.float32) != np.asarray(lo, np.float32))
        np.testing.assert_allclose(np.asarray(hi, np.float32),
                                   np.asarray(lo, np.float32),
                                   rtol=3e-2, atol=3e-2)

    def test_vmap_over_experiments(self):
        """Sweep engines vmap the mix over E with shared tables."""
        n, p = 8, 260
        _, _, idx, msk, _ = _edge_inputs(n, p)
        from repro.core.mixing import edge_weights

        rng = np.random.default_rng(5)
        support = np.asarray(
            barabasi_albert(n, p=2, seed=0).adjacency) + np.eye(n)
        cs = rng.random((3, n, n)).astype(np.float32) * (support > 0)
        cs /= cs.sum(-1, keepdims=True)
        planes = jax.random.normal(jax.random.key(7), (3, n, p))
        ws = jax.vmap(lambda c: edge_weights(c, idx, msk))(jnp.asarray(cs))
        out = jax.vmap(lambda pl_, w_: gossip_edges_pallas(pl_, w_, idx))(
            planes, ws)
        for e in range(3):
            np.testing.assert_allclose(
                np.asarray(out[e]), np.asarray(cs[e] @ planes[e]),
                rtol=1e-5, atol=1e-5)

    def test_modeled_bytes_edges_beats_plane_at_scale(self):
        """The point of the sparse path: at n ≥ 256 with bounded degree
        the edge-list stream moves strictly fewer modeled HBM bytes than
        the dense fused plane (whose n² coefficient refetch dominates)."""
        for n, dmax in ((256, 20), (1024, 20), (1024, 6)):
            for p_floats in (10_000, 1_000_000):
                plane = mix_modeled_hbm_bytes("pallas_plane", n, p_floats)
                edges = mix_modeled_hbm_bytes("edges", n, p_floats,
                                              max_neighbors=dmax)
                assert edges < plane
        # at toy scale (n=8) the dense refetch is negligible: no win
        tiny_plane = mix_modeled_hbm_bytes("pallas_plane", 8, 10_000)
        tiny_edges = mix_modeled_hbm_bytes("edges", 8, 10_000,
                                           max_neighbors=7)
        assert tiny_edges >= tiny_plane

    def test_modeled_bytes_sparse_series(self):
        """K-offset circulant model: (K+1) plane streams + offset table.
        Fewer offsets → fewer bytes, and a ring (K=3) undercuts the dense
        einsum at n=1024 with a modest plane (the n² coefficient read
        dominates there) — but never the fused plane kernel on
        plane-heavy shapes, which only streams the plane twice."""
        ring3 = mix_modeled_hbm_bytes("sparse", 1024, 100, n_offsets=3)
        ring9 = mix_modeled_hbm_bytes("sparse", 1024, 100, n_offsets=9)
        einsum = mix_modeled_hbm_bytes("einsum", 1024, 100)
        assert ring3 < ring9 < einsum
        plane = mix_modeled_hbm_bytes("pallas_plane", 256, 10_000)
        assert mix_modeled_hbm_bytes("sparse", 256, 10_000,
                                     n_offsets=3) > plane

    def test_modeled_bytes_require_sparsity_kwargs(self):
        with pytest.raises(ValueError, match="max_neighbors"):
            mix_modeled_hbm_bytes("edges", 64, 1000)
        with pytest.raises(ValueError, match="n_offsets"):
            mix_modeled_hbm_bytes("sparse", 64, 1000)
        with pytest.raises(KeyError):
            mix_modeled_hbm_bytes("segment", 64, 1000)


@given(n=st.integers(8, 24), seed=st.integers(0, 10))
@settings(max_examples=10, deadline=None)
def test_property_edges_matches_dense_kernel(n, seed):
    """Edges kernel == dense plane kernel to 1e-6 on random BA supports
    and random row-stochastic coefficients."""
    plane, c, idx, _, w = _edge_inputs(n, 130, seed=seed)
    e = gossip_edges_pallas(plane, w, idx)
    d = gossip_plane_pallas(plane, c)
    np.testing.assert_allclose(np.asarray(e), np.asarray(d),
                               rtol=1e-6, atol=1e-6)


class TestGossipMix:
    @pytest.mark.parametrize("k,m,n", [(2, 8, 8), (4, 100, 130), (7, 256, 512),
                                       (3, 1, 700), (5, 513, 129)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_allclose(self, k, m, n, dtype):
        blocks = (jax.random.normal(jax.random.key(0), (k, m, n)) * 2).astype(dtype)
        w = jax.nn.softmax(jax.random.normal(jax.random.key(1), (k,)))
        out = gossip_mix_pallas(blocks, w)
        ref = gossip_mix_ref(blocks, w)
        tol = 1e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=tol, atol=tol)

    def test_row_stochastic_identity(self):
        """Σw=1 with identical blocks must reproduce the block exactly-ish."""
        blocks = jnp.broadcast_to(
            jax.random.normal(jax.random.key(2), (64, 64)), (5, 64, 64))
        w = jnp.full((5,), 0.2)
        out = gossip_mix_pallas(blocks, w)
        np.testing.assert_allclose(np.asarray(out), np.asarray(blocks[0]),
                                   rtol=1e-5, atol=1e-6)


class TestFlashAttention:
    @pytest.mark.parametrize("b,s,h,kv,hd", [
        (1, 128, 4, 2, 32), (2, 100, 4, 4, 32), (1, 256, 8, 2, 64),
        (1, 64, 6, 1, 16),
    ])
    def test_causal(self, b, s, h, kv, hd):
        q, k, v = (jax.random.normal(jax.random.key(i), shape)
                   for i, shape in enumerate(
                       [(b, s, h, hd), (b, s, kv, hd), (b, s, kv, hd)]))
        out = flash_attention_pallas(q, k, v, bq=64, bkv=64)
        ref = flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)

    @pytest.mark.parametrize("window", [16, 64])
    def test_sliding_window(self, window):
        q, k, v = (jax.random.normal(jax.random.key(i), (1, 128, 4, 32))
                   for i in range(3))
        out = flash_attention_pallas(q, k, v, window=window, bq=32, bkv=32)
        ref = flash_attention_ref(q, k, v, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)

    def test_softcap(self):
        q, k, v = (jax.random.normal(jax.random.key(i), (1, 64, 2, 32)) * 3
                   for i in range(3))
        out = flash_attention_pallas(q, k, v, logit_softcap=20.0, bq=32, bkv=32)
        ref = flash_attention_ref(q, k, v, logit_softcap=20.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)

    def test_bf16(self):
        q, k, v = (jax.random.normal(jax.random.key(i), (1, 128, 2, 32))
                   .astype(jnp.bfloat16) for i in range(3))
        out = flash_attention_pallas(q, k, v, bq=64, bkv=64)
        ref = flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=3e-2, atol=3e-2)


class TestRwkvScan:
    def _inputs(self, b, s, h, hd, seed=0):
        ks = jax.random.split(jax.random.key(seed), 6)
        r, k, v = (jax.random.normal(ks[i], (b, s, h, hd)) * 0.5 for i in range(3))
        w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, s, h, hd)) * 0.5 - 2))
        u = jax.random.normal(ks[4], (h, hd)) * 0.3
        st = jax.random.normal(ks[5], (b, h, hd, hd)) * 0.1
        return r, k, v, w, u, st

    @pytest.mark.parametrize("b,s,h,hd,chunk", [
        (1, 64, 2, 16, 16), (2, 100, 2, 32, 32), (1, 128, 4, 32, 64),
        (1, 37, 1, 16, 32),
    ])
    def test_allclose(self, b, s, h, hd, chunk):
        r, k, v, w, u, st = self._inputs(b, s, h, hd)
        y1, s1 = rwkv_scan_pallas(r, k, v, w, u, st, chunk=chunk)
        y2, s2 = rwkv_scan_ref(r, k, v, w, u, st)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   rtol=2e-3, atol=2e-3)

    def test_state_threading_matches_two_calls(self):
        """scan(x₁∥x₂) == scan(x₂ | state=scan(x₁))  — cache semantics."""
        r, k, v, w, u, st = self._inputs(1, 64, 2, 16)
        y_full, s_full = rwkv_scan_pallas(r, k, v, w, u, st, chunk=16)
        y1, s1 = rwkv_scan_pallas(*(x[:, :32] for x in (r, k, v, w)), u, st, chunk=16)
        y2, s2 = rwkv_scan_pallas(*(x[:, 32:] for x in (r, k, v, w)), u, s1, chunk=16)
        np.testing.assert_allclose(np.asarray(y_full[:, 32:]), np.asarray(y2),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2),
                                   rtol=2e-3, atol=2e-3)


class TestMlaAttention:
    def _inputs(self, b, s, h, r, dr, seed=0):
        ks = jax.random.split(jax.random.key(seed), 4)
        return (jax.random.normal(ks[0], (b, s, h, r)) * 0.3,
                jax.random.normal(ks[1], (b, s, h, dr)) * 0.3,
                jax.random.normal(ks[2], (b, s, r)) * 0.3,
                jax.random.normal(ks[3], (b, s, dr)) * 0.3)

    @pytest.mark.parametrize("b,s,h,r,dr,blk", [
        (1, 128, 4, 32, 16, 64), (2, 100, 2, 64, 16, 32),
        (1, 64, 8, 16, 8, 64),
    ])
    def test_allclose(self, b, s, h, r, dr, blk):
        from repro.kernels.mla_attention import mla_attention_pallas
        from repro.kernels.ref import mla_attention_ref

        ql, qr, ck, kr = self._inputs(b, s, h, r, dr)
        out = mla_attention_pallas(ql, qr, ck, kr, bq=blk, bkv=blk)
        ref = mla_attention_ref(ql, qr, ck, kr)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)

    def test_model_path_agreement(self):
        """deepseek-smoke forward: pallas MLA path == einsum path."""
        from repro.configs.registry import get_smoke_config
        from repro.models.transformer import ForwardOptions, forward, init_params

        cfg = get_smoke_config("deepseek-v2-236b")
        p = init_params(jax.random.key(0), cfg)
        toks = jax.random.randint(jax.random.key(1), (2, 32), 0, cfg.vocab_size)
        l1, _ = forward(p, cfg, {"tokens": toks},
                        ForwardOptions(attn_impl="einsum"))
        l2, _ = forward(p, cfg, {"tokens": toks},
                        ForwardOptions(attn_impl="pallas"))
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=5e-3, atol=5e-3)
