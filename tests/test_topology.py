import numpy as np
import pytest
from tests._hypothesis import given, settings, st  # optional dep; skips if absent

from repro.core.topology import (
    Topology,
    barabasi_albert,
    build_topology,
    fully_connected,
    ring,
    stochastic_block,
    watts_strogatz,
)


class TestGenerators:
    @pytest.mark.parametrize("p", [1, 2, 3])
    def test_ba(self, p):
        t = barabasi_albert(33, p, seed=0)
        assert t.n_nodes == 33
        assert t.is_connected()
        # preferential attachment: p edges per new node
        assert t.n_edges == (33 - p) * p

    @pytest.mark.parametrize("n", [8, 16, 33])
    def test_ws(self, n):
        t = watts_strogatz(n, k=4, u=0.5, seed=1)
        assert t.n_nodes == n
        assert t.is_connected()

    @pytest.mark.parametrize("p_out", [0.009, 0.05, 0.9])
    def test_sb(self, p_out):
        t = stochastic_block(33, 3, 0.5, p_out, seed=2)
        assert t.n_nodes == 33
        assert t.is_connected()  # patched if sampled disconnected

    def test_sb_modularity_ordering(self):
        """Paper Fig 7: lower p_out ⇒ higher modularity."""
        mods = [
            stochastic_block(33, 3, 0.5, p, seed=0).modularity()
            for p in (0.009, 0.05, 0.9)
        ]
        assert mods[0] > mods[1] > mods[2]

    def test_ring_and_full(self):
        r = ring(8)
        assert (r.degree() == 2).all()
        f = fully_connected(8)
        assert (f.degree() == 7).all()

    def test_build_topology(self):
        t = build_topology("ba", n=16, p=2, seed=0)
        assert t.n_nodes == 16
        with pytest.raises(KeyError):
            build_topology("nope")


class TestMetrics:
    def test_degree_matches_adjacency(self):
        t = barabasi_albert(33, 2, seed=0)
        assert np.array_equal(t.degree(), t.adjacency.sum(0))

    def test_betweenness_range_and_hub(self):
        t = barabasi_albert(33, 1, seed=0)  # tree: hubs have high betweenness
        bc = t.betweenness()
        assert bc.min() >= 0 and bc.max() <= 1
        # the max-degree node of a BA tree should rank high in betweenness
        hub = t.kth_highest_degree_node(1)
        assert bc[hub] >= np.percentile(bc, 75)

    def test_kth_highest_degree(self):
        t = barabasi_albert(33, 2, seed=0)
        order = [t.kth_highest_degree_node(k) for k in (1, 2, 3, 4)]
        degs = t.degree()[order]
        assert (np.diff(degs) <= 0).all()
        assert len(set(order)) == 4

    def test_neighborhood_includes_self(self):
        t = ring(6)
        nb = t.neighborhood(0)
        assert 0 in nb and len(nb) == 3


class TestValidation:
    def test_rejects_asymmetric(self):
        a = np.zeros((3, 3))
        a[0, 1] = 1
        with pytest.raises(ValueError):
            Topology(a)

    def test_rejects_self_loop(self):
        a = np.eye(3)
        with pytest.raises(ValueError):
            Topology(a)


@given(n=st.integers(4, 24), p=st.integers(1, 3), seed=st.integers(0, 10))
@settings(max_examples=20, deadline=None)
def test_ba_always_connected(n, p, seed):
    if p >= n:
        return
    t = barabasi_albert(n, p, seed)
    assert t.is_connected()
    assert (t.degree() >= 1).all()
