"""Continuous-batching scheduler: slot packing, eviction, and agreement
with straight greedy generation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models.transformer import init_params
from repro.serving.scheduler import FleetScheduler, NodeScheduler, Request
from repro.serving.serve_step import greedy_generate

CFG = ModelConfig(name="sched", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab_size=64,
                  dtype="float32", param_dtype="float32")


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.key(0), CFG)


def test_matches_greedy_generate(params):
    """A scheduled request must produce the same tokens as the plain
    greedy generator (same model, same prompt)."""
    prompt = [3, 17, 42, 5]
    n_new = 6
    ref = greedy_generate(CFG, params, jnp.asarray([prompt], jnp.int32), n_new)
    want = np.asarray(ref)[0, len(prompt):].tolist()

    sched = NodeScheduler(CFG, params, n_slots=2, max_seq=32)
    req = Request(rid=0, prompt=prompt, max_new=n_new)
    sched.submit(req)
    sched.run_until_drained()
    assert req.done
    assert req.output == want


def test_slot_reuse_more_requests_than_slots(params):
    sched = NodeScheduler(CFG, params, n_slots=2, max_seq=32)
    reqs = [Request(rid=i, prompt=[i + 1, i + 2], max_new=3) for i in range(5)]
    for r in reqs:
        sched.submit(r)
    steps = sched.run_until_drained()
    assert all(r.done for r in reqs)
    assert all(len(r.output) == 3 for r in reqs)
    # with 2 slots and 5 requests the work must have been time-multiplexed
    assert steps >= 3 * 3  # ≥ ceil(5/2) waves × (2 prompt + 3 gen − overlap)


def test_interleaved_isolation(params):
    """Requests sharing a batch must not contaminate each other: the same
    prompt yields the same output whether run alone or packed with another
    request."""
    alone = Request(rid=0, prompt=[7, 8, 9], max_new=4)
    s1 = NodeScheduler(CFG, params, n_slots=1, max_seq=32)
    s1.submit(alone)
    s1.run_until_drained()

    packed = Request(rid=1, prompt=[7, 8, 9], max_new=4)
    other = Request(rid=2, prompt=[40, 41], max_new=6)
    s2 = NodeScheduler(CFG, params, n_slots=2, max_seq=32)
    s2.submit(packed)
    s2.submit(other)
    s2.run_until_drained()
    assert packed.output == alone.output


def test_eos_eviction(params):
    """A request whose sampled token equals eos stops early."""
    sched = NodeScheduler(CFG, params, n_slots=1, max_seq=32)
    probe = Request(rid=0, prompt=[1, 2], max_new=8)
    sched.submit(probe)
    sched.run_until_drained()
    eos_tok = probe.output[1]  # force eos at (first occurrence of) this token
    expected_len = probe.output.index(eos_tok) + 1
    req = Request(rid=1, prompt=[1, 2], max_new=8, eos=eos_tok)
    sched2 = NodeScheduler(CFG, params, n_slots=1, max_seq=32)
    sched2.submit(req)
    sched2.run_until_drained()
    assert req.done and len(req.output) == expected_len < 8


def test_fleet_round_robin(params):
    n = 3
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape).copy(), params)
    fleet = FleetScheduler(CFG, stacked, n_nodes=n, n_slots=2, max_seq=32)
    reqs = [Request(rid=i, prompt=[i + 1], max_new=2) for i in range(6)]
    nodes = [fleet.submit(r) for r in reqs]
    assert nodes == [0, 1, 2, 0, 1, 2]
    fleet.run_until_drained()
    assert all(r.done for r in reqs)
