"""Continuous-batching scheduler: slot packing, eviction, and agreement
with straight greedy generation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models.transformer import init_params
from repro.serving.scheduler import FleetScheduler, NodeScheduler, Request
from repro.serving.serve_step import greedy_generate

CFG = ModelConfig(name="sched", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab_size=64,
                  dtype="float32", param_dtype="float32")


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.key(0), CFG)


def test_matches_greedy_generate(params):
    """A scheduled request must produce the same tokens as the plain
    greedy generator (same model, same prompt)."""
    prompt = [3, 17, 42, 5]
    n_new = 6
    ref = greedy_generate(CFG, params, jnp.asarray([prompt], jnp.int32), n_new)
    want = np.asarray(ref)[0, len(prompt):].tolist()

    sched = NodeScheduler(CFG, params, n_slots=2, max_seq=32)
    req = Request(rid=0, prompt=prompt, max_new=n_new)
    sched.submit(req)
    sched.run_until_drained()
    assert req.done
    assert req.output == want


def test_slot_reuse_more_requests_than_slots(params):
    sched = NodeScheduler(CFG, params, n_slots=2, max_seq=32)
    reqs = [Request(rid=i, prompt=[i + 1, i + 2], max_new=3) for i in range(5)]
    for r in reqs:
        sched.submit(r)
    steps = sched.run_until_drained()
    assert all(r.done for r in reqs)
    assert all(len(r.output) == 3 for r in reqs)
    # with 2 slots and 5 requests the work must have been time-multiplexed:
    # at least one dispatch per admission wave (the self-feeding chunk can
    # absorb a 2-token prompt + 3 generated tokens in a single call)
    assert steps >= -(-len(reqs) // 2)  # ≥ ceil(5/2) waves


def test_interleaved_isolation(params):
    """Requests sharing a batch must not contaminate each other: the same
    prompt yields the same output whether run alone or packed with another
    request."""
    alone = Request(rid=0, prompt=[7, 8, 9], max_new=4)
    s1 = NodeScheduler(CFG, params, n_slots=1, max_seq=32)
    s1.submit(alone)
    s1.run_until_drained()

    packed = Request(rid=1, prompt=[7, 8, 9], max_new=4)
    other = Request(rid=2, prompt=[40, 41], max_new=6)
    s2 = NodeScheduler(CFG, params, n_slots=2, max_seq=32)
    s2.submit(packed)
    s2.submit(other)
    s2.run_until_drained()
    assert packed.output == alone.output


def test_eos_eviction(params):
    """A request whose sampled token equals eos stops early."""
    sched = NodeScheduler(CFG, params, n_slots=1, max_seq=32)
    probe = Request(rid=0, prompt=[1, 2], max_new=8)
    sched.submit(probe)
    sched.run_until_drained()
    eos_tok = probe.output[1]  # force eos at (first occurrence of) this token
    expected_len = probe.output.index(eos_tok) + 1
    req = Request(rid=1, prompt=[1, 2], max_new=8, eos=eos_tok)
    sched2 = NodeScheduler(CFG, params, n_slots=1, max_seq=32)
    sched2.submit(req)
    sched2.run_until_drained()
    assert req.done and len(req.output) == expected_len < 8


def test_fleet_round_robin(params):
    n = 3
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape).copy(), params)
    fleet = FleetScheduler(CFG, stacked, n_nodes=n, n_slots=2, max_seq=32)
    reqs = [Request(rid=i, prompt=[i + 1], max_new=2) for i in range(6)]
    nodes = [fleet.submit(r) for r in reqs]
    assert nodes == [0, 1, 2, 0, 1, 2]
    fleet.run_until_drained()
    assert all(r.done for r in reqs)


# ----------------------------------------------------------------------
# chunked prefill + self-feeding decode vs the legacy replay reference
# ----------------------------------------------------------------------
def _mixed_workload(seed=0, n=8):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(1, 64, size=int(rng.integers(1, 18))).tolist(),
                    max_new=int(rng.integers(1, 12)))
            for i in range(n)]


def test_chunked_matches_legacy_replay(params):
    """The chunked/self-feeding path must emit token-for-token the same
    outputs as the legacy token-by-token replay, across prompts shorter
    and longer than the chunk — in far fewer dispatches."""
    chunked = NodeScheduler(CFG, params, n_slots=2, max_seq=48,
                            prefill_chunk=8)
    legacy = NodeScheduler(CFG, params, n_slots=2, max_seq=48,
                           prefill_chunk=None)
    a, b = _mixed_workload(3), _mixed_workload(3)
    for r in a:
        chunked.submit(r)
    for r in b:
        legacy.submit(r)
    steps_c = chunked.run_until_drained()
    steps_l = legacy.run_until_drained()
    assert [r.output for r in a] == [r.output for r in b]
    assert steps_c < steps_l  # the point of chunking


def test_queue_draining_mixed_prompt_lengths(params):
    """Prompts straddling the chunk boundary drain together; every
    request completes with exactly its generation budget."""
    sched = NodeScheduler(CFG, params, n_slots=3, max_seq=64,
                          prefill_chunk=8)
    lens = [1, 7, 8, 9, 16, 17]
    reqs = [Request(rid=i, prompt=list(range(1, l + 1)), max_new=5)
            for i, l in enumerate(lens)]
    for r in reqs:
        sched.submit(r)
    sched.run_until_drained()
    assert all(r.done and len(r.output) == 5 for r in reqs)


def test_max_length_eviction_matches_legacy(params):
    """A tight max_seq truncates generation at the same token count on
    both paths (the cache-headroom cap mirrors the legacy over-length
    eviction)."""
    for max_seq in (10, 16):
        chunked = NodeScheduler(CFG, params, n_slots=2, max_seq=max_seq,
                                prefill_chunk=8)
        legacy = NodeScheduler(CFG, params, n_slots=2, max_seq=max_seq,
                               prefill_chunk=None)
        mk = lambda: [Request(rid=i, prompt=[2 + i] * p, max_new=50)
                      for i, p in enumerate([3, 8, 14, 20])]
        a, b = mk(), mk()
        for r in a:
            chunked.submit(r)
        for r in b:
            legacy.submit(r)
        chunked.run_until_drained()
        legacy.run_until_drained()
        assert all(r.done for r in a + b)
        assert [r.output for r in a] == [r.output for r in b]


def test_eos_mid_chunk_truncates(params):
    """An EOS sampled mid-chunk by a self-feeding lane must cut the
    output exactly where the legacy one-token-per-step path stops."""
    probe = Request(rid=0, prompt=[1, 2], max_new=10)
    s = NodeScheduler(CFG, params, n_slots=1, max_seq=32, prefill_chunk=8)
    s.submit(probe)
    s.run_until_drained()
    eos_tok = probe.output[3]  # guaranteed to be sampled mid-chunk
    expected = probe.output[: probe.output.index(eos_tok) + 1]
    for chunk in (8, None):
        req = Request(rid=1, prompt=[1, 2], max_new=10, eos=eos_tok)
        s2 = NodeScheduler(CFG, params, n_slots=1, max_seq=32,
                           prefill_chunk=chunk)
        s2.submit(req)
        s2.run_until_drained()
        assert req.done and req.output == expected


# ----------------------------------------------------------------------
# fleet-vmapped path: equivalence with the loop + no-re-jit model swap
# ----------------------------------------------------------------------
def _stacked(n, seed=0):
    return jax.vmap(lambda k: init_params(k, CFG))(
        jax.random.split(jax.random.key(seed), n))


def test_fleet_vmapped_matches_loop():
    n = 3
    stacked = _stacked(n)
    vm = FleetScheduler(CFG, stacked, n_nodes=n, n_slots=2, max_seq=48,
                        prefill_chunk=8, vmapped=True)
    lp = FleetScheduler(CFG, stacked, n_nodes=n, n_slots=2, max_seq=48,
                        prefill_chunk=8, vmapped=False)
    a, b = _mixed_workload(5, n=9), _mixed_workload(5, n=9)
    for r in a:
        vm.submit(r)
    for r in b:
        lp.submit(r)
    vm.run_until_drained()
    lp.run_until_drained()
    assert [r.output for r in a] == [r.output for r in b]


def test_swap_node_no_rejit():
    """Installing a node's post-gossip params is a plane row write: the
    fleet step's trace counters must stay frozen across the swap, and the
    swapped node must actually serve the NEW model."""
    n = 2
    vm = FleetScheduler(CFG, _stacked(n), n_nodes=n, n_slots=2, max_seq=48,
                        prefill_chunk=8, vmapped=True)

    def probe_outputs():
        reqs = [Request(rid=i, prompt=[3, 17, 42, 5], max_new=6)
                for i in range(n)]
        for i, r in enumerate(reqs):
            vm.submit(r, node=i)
        vm.run_until_drained()
        return [r.output for r in reqs]

    before = probe_outputs()
    traces = (vm.decode_traces, vm.prefill_traces)
    new_params = init_params(jax.random.key(777), CFG)
    vm.swap_node(0, new_params)
    after = probe_outputs()
    assert (vm.decode_traces, vm.prefill_traces) == traces  # no re-jit
    assert after[0] != before[0]       # node 0 serves the new model
    assert after[1] == before[1]       # node 1 untouched

    # the swapped node agrees with a fresh single-node scheduler
    ref = Request(rid=9, prompt=[3, 17, 42, 5], max_new=6)
    solo = NodeScheduler(CFG, new_params, n_slots=2, max_seq=48,
                         prefill_chunk=8)
    solo.submit(ref)
    solo.run_until_drained()
    assert after[0] == ref.output
