"""repro.analysis (jaxlint): walker mechanics, each rule positive +
negative, and the four canonical regression fixtures — every fixture
runs with the FULL rule catalog active and must trip exactly its own
rule (a checker that fires on healthy programs is as useless as one
that misses sick ones)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (
    AnalysisError,
    ConstantFootprint,
    Donation,
    DtypeFlow,
    FusionBudget,
    HostSync,
    Report,
    analyze,
    count_primitives,
    outermost_scan_body,
)
from repro.analysis.walker import iter_eqns, sub_jaxprs

R, N, D = 12, 8, 5


# ----------------------------------------------------------------------
# a healthy toy "round scan": one dot per round, nothing baked in
# ----------------------------------------------------------------------
def _toy_scan(state, coeffs):
    """state (n, d), coeffs (R, n, n): R rounds of state ← C_r @ state."""

    def body(carry, coeff):
        new = coeff @ carry
        return new, jnp.sum(new)

    return jax.lax.scan(body, state, coeffs)


def _toy_args():
    rng = np.random.default_rng(0)
    state = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
    coeffs = jnp.asarray(rng.normal(size=(R, N, N)), jnp.float32)
    return state, coeffs


def _catalog(expect_donated: bool = False):
    """The full rule catalog, sized for the healthy toy scan."""
    return [
        FusionBudget.of({"dot_general": 1, "pallas_call": 0},
                        scope="scan_body"),
        ConstantFootprint(max_total_bytes=1024),
        DtypeFlow(),
        Donation(expect=expect_donated),
        HostSync(),
    ]


class TestWalker:
    def test_iter_eqns_recurses_with_paths(self):
        closed = jax.make_jaxpr(_toy_scan)(*_toy_args())
        prims = {e.primitive.name for e, _ in iter_eqns(closed)}
        assert "scan" in prims and "dot_general" in prims
        # the dot lives INSIDE the scan body: its path says so
        paths = [p for e, p in iter_eqns(closed)
                 if e.primitive.name == "dot_general"]
        assert paths and all("scan" in p for p in paths)

    def test_count_primitives_exclude_within(self):
        closed = jax.make_jaxpr(_toy_scan)(*_toy_args())
        assert count_primitives(closed)["dot_general"] == 1
        assert count_primitives(
            closed, exclude_within=("scan",)).get("dot_general", 0) == 0

    def test_sub_jaxprs_yields_cond_branches(self):
        def f(x, flag):
            return jax.lax.cond(flag, lambda v: v + 1.0,
                                lambda v: v * 2.0, x)

        closed = jax.make_jaxpr(f)(jnp.zeros(3), True)
        cond_eqn = next(e for e, _ in iter_eqns(closed)
                        if e.primitive.name == "cond")
        assert len(list(sub_jaxprs(cond_eqn))) == 2

    def test_outermost_scan_body(self):
        closed = jax.make_jaxpr(_toy_scan)(*_toy_args())
        body = outermost_scan_body(closed)
        assert body is not None
        assert count_primitives(body)["dot_general"] == 1
        no_scan = jax.make_jaxpr(lambda x: x @ x.T)(jnp.ones((3, 3)))
        assert outermost_scan_body(no_scan) is None

    def test_counts_recurse_into_pjit(self):
        inner = jax.jit(lambda x: x @ x.T)
        closed = jax.make_jaxpr(lambda x: inner(x) + 1.0)(jnp.ones((3, 3)))
        assert count_primitives(closed)["dot_general"] == 1


class TestReport:
    def test_clean_report(self):
        report = analyze(_toy_scan, *_toy_args(), rules=_catalog())
        assert isinstance(report, Report) and report.ok
        assert report.failed_rules() == []
        assert report.raise_if_failed() is report
        d = report.to_dict()
        assert d["ok"] and set(d["rules"]) == {
            "fusion-budget", "constant-footprint", "dtype-flow",
            "donation", "host-sync"}
        # clean outcomes still document what was measured
        assert d["rules"]["fusion-budget"]["measured"]["dot_general"] == 1

    def test_raise_carries_findings_text(self):
        bad = FusionBudget.of({"dot_general": 7}, scope="scan_body")
        report = analyze(_toy_scan, *_toy_args(), rules=[bad])
        assert not report.ok
        with pytest.raises(AnalysisError, match="expected exactly 7"):
            report.raise_if_failed()


# ----------------------------------------------------------------------
# the four canonical regressions — full catalog on, exactly one rule trips
# ----------------------------------------------------------------------
def _assert_only_trips(report: Report, rule_name: str):
    assert report.failed_rules() == [rule_name], str(report)


class TestNegativeFixtures:
    def test_materialized_stack_closure_trips_constant_footprint(self):
        """The leak the scanned engine exists to avoid: an (R, n, n)
        coefficient slab captured by closure becomes a 3 KiB trace
        constant instead of an argument."""
        state, coeffs = _toy_args()

        def leaky(s):
            def body(carry, r):
                return coeffs[r] @ carry, jnp.sum(carry)

            return jax.lax.scan(body, s, jnp.arange(R))

        report = analyze(leaky, state, rules=_catalog())
        _assert_only_trips(report, "constant-footprint")
        assert report.outcome("constant-footprint").measured[
            "total_bytes"] >= R * N * N * 4

    def test_f64_literal_trips_dtype_flow(self):
        """One stray float64 under x64 poisons the whole round dtype."""
        state, coeffs = _toy_args()

        with jax.experimental.enable_x64():
            def f64_scan(s, cs):
                def body(carry, coeff):
                    new = (coeff @ carry
                           + jnp.asarray(1e-3, jnp.float64))
                    return new.astype(jnp.float32), jnp.sum(carry)

                return jax.lax.scan(body, s, cs)

            report = analyze(f64_scan, state, coeffs, rules=_catalog())
        _assert_only_trips(report, "dtype-flow")

    def test_undonated_carry_trips_donation(self):
        """The chunked-mode contract: analyzing with expect=True but
        jitting without donate_argnums must fail — and threading the
        engine's DONATED_CARRY_ARGNUMS through must pass."""
        from repro.core.sweep import DONATED_CARRY_ARGNUMS

        state, coeffs = _toy_args()
        report = analyze(_toy_scan, state, coeffs,
                         rules=_catalog(expect_donated=True),
                         jit_kwargs={})
        _assert_only_trips(report, "donation")

        donated = analyze(
            _toy_scan, state, coeffs, rules=_catalog(expect_donated=True),
            jit_kwargs={"donate_argnums": DONATED_CARRY_ARGNUMS[:1]})
        assert donated.ok, str(donated)
        assert donated.outcome("donation").measured["donated_buffers"] >= 1

    def test_debug_callback_in_round_body_trips_host_sync(self):
        """jax.debug.print inside the scan body = one host round-trip
        per round — the single-dispatch design's cardinal sin."""
        state, coeffs = _toy_args()

        def chatty(s, cs):
            def body(carry, coeff):
                new = coeff @ carry
                jax.debug.print("round sum {}", jnp.sum(new))
                return new, jnp.sum(new)

            return jax.lax.scan(body, s, cs)

        report = analyze(chatty, state, coeffs, rules=_catalog())
        _assert_only_trips(report, "host-sync")
        finding = report.outcome("host-sync").findings[0]
        assert "debug_callback" in finding.message


# ----------------------------------------------------------------------
# per-rule specifics not covered by the fixtures
# ----------------------------------------------------------------------
class TestRules:
    def test_fusion_budget_exact_not_at_most(self):
        rule = FusionBudget.of({"dot_general": 0}, scope="scan_body")
        report = analyze(_toy_scan, *_toy_args(), rules=[rule])
        assert not report.ok  # 1 ≠ 0: exact, both directions

    def test_constant_footprint_per_const_cap(self):
        big = jnp.ones((256,), jnp.float32)  # 1 KiB single const

        def f(x):
            return x + big

        rule = ConstantFootprint(max_total_bytes=1 << 20,
                                 max_const_bytes=512)
        report = analyze(f, jnp.zeros((256,)), rules=[rule])
        assert report.failed_rules() == ["constant-footprint"]
        assert "per-constant cap" in report.findings[0].message

    def test_dtype_flow_kernel_upcast_knob(self):
        """mix_in_float32 routes to an in-kernel bf16→f32 upcast the
        analyzer can see — and its absence on the low-precision path."""
        from repro.kernels.gossip_mix import gossip_plane_pallas

        plane = jnp.ones((4, 256), jnp.bfloat16)
        c = jnp.full((4, 4), 0.25, jnp.float32)
        hi = lambda p_, c_: gossip_plane_pallas(p_, c_,
                                                mix_in_float32=True)
        lo = lambda p_, c_: gossip_plane_pallas(p_, c_,
                                                mix_in_float32=False)
        assert analyze(hi, plane, c,
                       rules=[DtypeFlow(expect_kernel_upcasts=True)]).ok
        assert analyze(lo, plane, c,
                       rules=[DtypeFlow(expect_kernel_upcasts=False)]).ok
        assert not analyze(hi, plane, c,
                           rules=[DtypeFlow(
                               expect_kernel_upcasts=False)]).ok
        assert not analyze(lo, plane, c,
                           rules=[DtypeFlow(
                               expect_kernel_upcasts=True)]).ok

    def test_host_sync_scope_all(self):
        def noisy(x):
            jax.debug.print("x {}", x)
            return x * 2.0

        report = analyze(noisy, jnp.ones(3),
                         rules=[HostSync(scope="all")])
        assert report.failed_rules() == ["host-sync"]


# ----------------------------------------------------------------------
# budget metadata (kernels / core)
# ----------------------------------------------------------------------
class TestBudgetMetadata:
    def test_mix_eqn_budget_values(self):
        from repro.kernels.gossip_mix import mix_eqn_budget

        assert mix_eqn_budget("einsum", 6) == {"pallas_call": 0,
                                               "dot_general": 6}
        assert mix_eqn_budget("pallas") == {"pallas_call": 1,
                                            "dot_general": 0}
        assert mix_eqn_budget("edges") == {"pallas_call": 1,
                                           "dot_general": 0}
        assert mix_eqn_budget("sparse") == {"pallas_call": 0,
                                            "dot_general": 0}
        with pytest.raises(KeyError):
            mix_eqn_budget("segment")

    def test_mix_impl_budget_sparse_fallback(self):
        """On a support that doesn't circulant-decompose, the sparse
        impl falls back to dense einsum — and its declared budget must
        say so."""
        from repro.core.decentralized import mix_impl_budget
        from repro.core.topology import barabasi_albert, ring

        n = 16
        ring_support = np.asarray(ring(n).adjacency) + np.eye(n)
        ba_support = (np.asarray(barabasi_albert(n, p=5, seed=0).adjacency)
                      + np.eye(n))
        assert mix_impl_budget("sparse", 3, mix_support=ring_support) == {
            "pallas_call": 0, "dot_general": 0}
        assert mix_impl_budget("sparse", 3, mix_support=ba_support,
                               sparse_slack=0) == {
            "pallas_call": 0, "dot_general": 3}


# ----------------------------------------------------------------------
# engine-matrix preset + CLI (one-cell smokes; full matrix runs in CI)
# ----------------------------------------------------------------------
class TestPreset:
    def test_engine_matrix_lists_49_combos(self):
        from repro.analysis.presets import engine_matrix_combos

        combos = engine_matrix_combos()
        assert len(combos) == 49
        assert len({c.name for c in combos}) == 49
        # the partial-participation cells: every mode on einsum + one
        # kernel backend, sharing the synchronous einsum budgets
        part = [c for c in combos if c.participation]
        assert {(c.mode, c.impl) for c in part} == {
            ("scanned", "einsum"), ("chunked", "einsum"),
            ("mesh", "einsum"), ("unrolled", "einsum"),
            ("scanned", "pallas")}
        # the fault cells: quarantined fault injection through every
        # mode (same einsum budgets), plus a fault × trimmed composition
        fault = [c for c in combos if c.fault]
        assert {c.mode for c in fault} == set(
            ("scanned", "chunked", "mesh", "unrolled"))
        assert any(c.robust == "trimmed" for c in fault)
        # the robust cells cover both order-statistic backends plus the
        # coefficient-transform rule
        assert {(c.impl, c.robust) for c in combos
                if c.robust != "mean"} == {
            ("einsum", "trimmed"), ("einsum", "norm_clip"),
            ("edges", "median")}

    @pytest.mark.parametrize("mode,impl", [
        ("scanned", "pallas"), ("unrolled", "einsum")])
    def test_combo_reports_clean(self, mode, impl):
        from repro.analysis.presets import Combo, run_combo

        report = run_combo(Combo(mode, impl, "stack"))
        assert report.ok, str(report)

    def test_cli_writes_artifact_and_exits_zero(self, tmp_path):
        from repro.analysis.__main__ import main

        out = tmp_path / "ANALYSIS.json"
        code = main(["--only", "^scanned/sparse/stack$",
                     "--out", str(out)])
        assert code == 0
        import json

        payload = json.loads(out.read_text())
        assert payload["ok"] and payload["n_combos"] == 1
        combo = payload["combos"]["scanned/sparse/stack"]
        assert combo["rules"]["fusion-budget"]["ok"]

    def test_cli_only_no_match_is_an_error(self, tmp_path):
        from repro.analysis.__main__ import main

        assert main(["--only", "no-such-combo",
                     "--out", str(tmp_path / "x.json")]) == 2


class TestJaxlintFixture:
    def test_count_walks_equations(self, jaxlint):
        counts = jaxlint.count(_toy_scan, *_toy_args())
        assert counts["dot_general"] == 1 and counts["scan"] == 1

    def test_check_raises_on_violation(self, jaxlint):
        with pytest.raises(AnalysisError):
            jaxlint.check(
                _toy_scan, *_toy_args(),
                rules=[jaxlint.FusionBudget.of({"pallas_call": 3},
                                               scope="all")])
