"""Partial-participation gossip (DESIGN.md §15): the node-level active-set
round must collapse to the synchronous engine bit-for-bit at rate 1.0 —
in every execution mode and every mixing backend — and at partial rates
the staleness counters, stale-plane selects, and time-skewed local-step
counts must agree exactly across scanned / chunked / unrolled (the
8-device mesh lives in the subprocess test at the bottom, like
tests/test_sweep_sharded.py).
"""
import inspect
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.coeffs import participation_renormalize
from repro.core.decentralized import (
    DecentralizedConfig,
    coeffs_stack,
    stack_params,
)
from repro.core.dynamic import PARTICIPATION_MODES, ParticipationSpec
from repro.core.strategies import AggregationStrategy, renormalize_rows
from repro.core.sweep import SweepEngine
from repro.core.topology import ring
from repro.data.backdoor import backdoored_testset
from repro.data.distribution import node_datasets
from repro.data.pipeline import NodeBatcher, make_test_batch
from repro.data.synthetic import make_dataset
from repro.training.optimizer import sgd

N, ROUNDS, E = 4, 4, 3


@pytest.fixture(scope="module")
def grid():
    """E=3 experiments (unweighted / random / degree) on ring(4), shared
    data bank — the test_sweep_sharded.py setting at 1 device."""
    train = make_dataset("mnist", 400, seed=0)
    test = make_dataset("mnist", 100, seed=9)
    from repro.models.paper_models import (
        classifier_accuracy, classifier_loss, ffn_apply, ffn_init)

    topo = ring(N)
    parts = node_datasets(train, N, ood_node=0, q=0.10, seed=0)
    nb = NodeBatcher(parts, batch_size=8, steps_per_epoch=2, seed=0,
                     local_epochs=2)
    tb = make_test_batch(test, 32, seed=0)
    ob = make_test_batch(backdoored_testset(test, seed=0), 32, seed=0)
    kinds = ["unweighted", "random", "degree"]
    bank = {k: v[None] for k, v in nb.sample_bank().items()}
    indices = nb.all_round_indices(ROUNDS)[None]
    data_idx = np.zeros(E, np.int32)
    coeffs = np.stack([
        coeffs_stack(topo, AggregationStrategy(k, seed=0), ROUNDS,
                     nb.data_counts())
        for k in kinds])
    params0 = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[stack_params([ffn_init(jax.random.key(0))] * N)] * E)
    st = lambda t: {k: jnp.stack([jnp.asarray(t[k])] * E) for k in t}
    return {
        "topo": topo,
        "loss_fn": classifier_loss(ffn_apply),
        "acc_fn": classifier_accuracy(ffn_apply),
        "args": (params0, coeffs, bank, indices, data_idx, st(tb), st(ob)),
        "params0": params0,
    }


def _engine(grid, mix_impl="einsum"):
    cfg = DecentralizedConfig(rounds=ROUNDS, local_epochs=2, eval_every=2,
                              mix_impl=mix_impl)
    support = None
    if mix_impl in ("sparse", "edges"):
        support = np.asarray(grid["topo"].adjacency) + np.eye(N)
    return SweepEngine(sgd(1e-2), grid["loss_fn"], grid["acc_fn"], cfg,
                       mix_support=support)


def _assert_results_equal(a, b):
    np.testing.assert_array_equal(a.train_loss, b.train_loss)
    np.testing.assert_array_equal(a.iid_acc, b.iid_acc)
    np.testing.assert_array_equal(a.ood_acc, b.ood_acc)
    for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ----------------------------------------------------------------------
# rate 1.0 == the synchronous engine, bit-for-bit (tentpole acceptance)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("mix_impl", ["einsum", "pallas", "edges"])
def test_rate1_bit_identical_to_synchronous(grid, mix_impl):
    """uniform(key) < 1.0 activates every node every round, the stale-
    plane selects pick the fresh branch everywhere, and the carry adds no
    arithmetic to the plane — so a rate-1.0 run must reproduce the
    no-participation program EXACTLY, per backend and per mode."""
    from repro.launch.mesh import make_sweep_mesh

    engine = _engine(grid, mix_impl)
    run = lambda **kw: engine.run(*grid["args"], batch_size=8, **kw)
    ref = run()
    spec = ParticipationSpec()
    for label, kw in [
        ("scanned", {}),
        ("chunked", {"chunk_rounds": 3}),
        ("mesh1", {"mesh": make_sweep_mesh(1)}),
        ("unrolled", {"unroll_eval": True}),
    ]:
        res = run(participation=spec,
                  participation_rates=np.ones(E, np.float32), **kw)
        _assert_results_equal(res, ref)
        part = res.participation
        assert part is not None, label
        np.testing.assert_array_equal(part["rounds_active"],
                                      np.full((E, N), ROUNDS))
        np.testing.assert_array_equal(part["final_staleness"],
                                      np.zeros((E, N), np.int32))
        np.testing.assert_array_equal(part["mean_staleness"],
                                      np.zeros((E, N)))
        steps = part["local_steps"]
        assert (steps == steps[0, 0]).all() and steps[0, 0] % ROUNDS == 0


def test_duty_cycle_rate1_bit_identical(grid):
    """The static duty-cycle schedule at rate 1.0 (k == period) is the
    all-active schedule — synchronous bit-identity holds there too."""
    engine = _engine(grid)
    ref = engine.run(*grid["args"], batch_size=8)
    res = engine.run(*grid["args"], batch_size=8,
                     participation=ParticipationSpec(mode="duty", period=3),
                     participation_rates=np.ones(E, np.float32))
    _assert_results_equal(res, ref)


# ----------------------------------------------------------------------
# degenerate active sets
# ----------------------------------------------------------------------
def test_zero_active_rounds_freeze_everything(grid):
    """rate 0.0: nobody ever publishes or mixes — params stay at their
    init, losses report zero, staleness increments everywhere, and the
    time-skewed local-step counts stay zero."""
    engine = _engine(grid)
    res = engine.run(*grid["args"], batch_size=8,
                     participation=ParticipationSpec(),
                     participation_rates=np.zeros(E, np.float32))
    for a, b in zip(jax.tree.leaves(res.params),
                    jax.tree.leaves(grid["params0"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(res.train_loss,
                                  np.zeros_like(res.train_loss))
    part = res.participation
    np.testing.assert_array_equal(part["rounds_active"], np.zeros((E, N)))
    np.testing.assert_array_equal(part["local_steps"], np.zeros((E, N)))
    np.testing.assert_array_equal(part["final_staleness"],
                                  np.full((E, N), ROUNDS))
    # Σ_{r=1..R} r / R
    np.testing.assert_allclose(part["mean_staleness"],
                               np.full((E, N), (ROUNDS + 1) / 2))


def test_duty_cycle_exactly_one_active(grid):
    """period=N at rate 1/N staggers the phases so EXACTLY one node is
    active each round — each node trains exactly R/period times."""
    engine = _engine(grid)
    res = engine.run(*grid["args"], batch_size=8,
                     participation=ParticipationSpec(mode="duty", period=N),
                     participation_rates=np.full(E, 1.0 / N, np.float32))
    part = res.participation
    # R == N == period here: every node active exactly once
    np.testing.assert_array_equal(part["rounds_active"],
                                  np.ones((E, N), np.int32))
    assert int(part["rounds_active"].sum()) == E * ROUNDS
    # per-round losses: exactly one nonzero row per (experiment, round)
    active_rows = (np.asarray(res.train_loss) != 0).sum(axis=2)
    np.testing.assert_array_equal(active_rows,
                                  np.ones((E, ROUNDS), np.int32))


def test_duty_mask_schedule():
    """The (r + i) % period phase stagger, directly."""
    spec = ParticipationSpec(mode="duty", period=4)
    masks = np.stack([
        np.asarray(spec.active_mask(0.25, 0, r, 4)) for r in range(4)])
    # one active node per round, rotating
    np.testing.assert_array_equal(masks.sum(axis=1), np.ones(4))
    np.testing.assert_array_equal(masks.sum(axis=0), np.ones(4))
    full = np.stack([
        np.asarray(spec.active_mask(1.0, 0, r, 4)) for r in range(4)])
    assert full.all()


def test_participation_spec_validation():
    with pytest.raises(ValueError, match="period"):
        ParticipationSpec(mode="duty", period=0)
    with pytest.raises(ValueError, match="mode"):
        ParticipationSpec(mode="nope")
    assert set(PARTICIPATION_MODES) == {"bernoulli", "duty"}


# ----------------------------------------------------------------------
# cross-mode equality at a genuinely partial rate
# ----------------------------------------------------------------------
def test_partial_rate_modes_bit_identical(grid):
    """rate 0.5: scanned == chunked (absolute round indices drive the
    active-set draw, so chunk boundaries cannot shift it) == unrolled,
    including every participation digest array."""
    engine = _engine(grid)
    spec = ParticipationSpec()
    run = lambda **kw: engine.run(
        *grid["args"], batch_size=8, participation=spec,
        participation_rates=np.full(E, 0.5, np.float32), **kw)
    ref = run()
    for label, other in [("chunked", run(chunk_rounds=3)),
                         ("unrolled", run(unroll_eval=True))]:
        _assert_results_equal(other, ref)
        for k in ref.participation:
            np.testing.assert_array_equal(
                ref.participation[k], other.participation[k],
                err_msg=(label, k))
    # the draw actually drops nodes at this rate
    assert (np.asarray(ref.participation["rounds_active"]) < ROUNDS).any()


def test_per_experiment_rates_ride_the_vmap_axis(grid):
    """One compiled program serves a rate grid: the rate-1.0 row of a
    mixed [1.0, 0.5, 0.0] run equals the all-ones run's row bit-for-bit
    (rates are carried data, not static config)."""
    engine = _engine(grid)
    spec = ParticipationSpec()
    run = lambda rates: engine.run(
        *grid["args"], batch_size=8, participation=spec,
        participation_rates=np.asarray(rates, np.float32))
    mixed = run([1.0, 0.5, 0.0])
    ones = run([1.0, 1.0, 1.0])
    np.testing.assert_array_equal(mixed.train_loss[0], ones.train_loss[0])
    np.testing.assert_array_equal(
        mixed.participation["rounds_active"][0],
        np.full(N, ROUNDS))
    np.testing.assert_array_equal(
        mixed.participation["rounds_active"][2], np.zeros(N))


def test_drop_mode_rate1_bit_identical(grid):
    """stale_mixing=False (drop inactive columns + renormalize) keeps
    the all-active round bit-identical: the row-level `changed` gate in
    participation_renormalize skips the divide when no mass was lost."""
    engine = _engine(grid)
    ref = engine.run(*grid["args"], batch_size=8)
    res = engine.run(*grid["args"], batch_size=8,
                     participation=ParticipationSpec(stale_mixing=False),
                     participation_rates=np.ones(E, np.float32))
    _assert_results_equal(res, ref)


def test_analytics_and_participation_compose(grid):
    """Both carries thread the same scan; the staleness × arrival digest
    (analytics.participation_summary) reads them together."""
    from repro.core.analytics import AnalyticsSpec, participation_summary

    engine = _engine(grid)
    res = engine.run(*grid["args"], batch_size=8,
                     analytics=AnalyticsSpec(arrival_threshold=0.5),
                     participation=ParticipationSpec(),
                     participation_rates=np.full(E, 0.6, np.float32))
    assert res.analytics is not None and res.participation is not None
    for e in range(E):
        part = {k: v[e] for k, v in res.participation.items()}
        stream = {k: v[e] for k, v in res.analytics.items()}
        s = participation_summary(part, ROUNDS, stream)
        assert 0.0 <= s["activity_rate"] <= 1.0
        assert s["local_steps_total"] == int(part["local_steps"].sum())
        assert "staleness_arrival_corr" in s
        assert "arrival_low_staleness" in s


def test_rates_require_spec(grid):
    engine = _engine(grid)
    with pytest.raises(ValueError, match="participation"):
        engine.run(*grid["args"], batch_size=8,
                   participation_rates=np.ones(E, np.float32))


# ----------------------------------------------------------------------
# the shared row-normalize helper + drop-mode renormalization
# ----------------------------------------------------------------------
def test_renormalize_rows_healthy_rows_divide_exact_rowsum():
    rng = np.random.default_rng(0)
    # healthy rows divide by their EXACT row sum (the old
    # np.maximum(rowsum, 1e-12) epsilon was dead there by construction)
    d = rng.uniform(0.5, 2.0, size=(4, 4))
    np.testing.assert_array_equal(renormalize_rows(d),
                                  d / d.sum(axis=-1, keepdims=True))
    # rows already summing to exactly 1.0 come back bit-identical
    c = np.array([[0.5, 0.25, 0.25], [1.0, 0.0, 0.0], [0.0, 0.5, 0.5]])
    np.testing.assert_array_equal(renormalize_rows(c), c)


def test_renormalize_rows_zero_row_falls_back_to_self():
    c = np.array([[0.5, 0.5, 0.0],
                  [0.0, 0.0, 0.0],
                  [0.0, 0.2, 0.8]])
    out = renormalize_rows(c)
    np.testing.assert_array_equal(out[1], np.array([0.0, 1.0, 0.0]))
    np.testing.assert_array_equal(out[0], c[0])


def test_renormalize_rows_asserts_on_subnormal_rowsum():
    c = np.zeros((2, 2))
    c[0, 0] = 1e-12  # positive but far below any honest coefficient
    with pytest.raises(AssertionError, match="masking bug"):
        renormalize_rows(c)


def test_renormalize_rows_jnp_path_no_assert():
    c = jnp.zeros((2, 2)).at[0, 0].set(1e-12)
    out = renormalize_rows(c, xp=jnp)  # traced path cannot assert
    assert np.isfinite(np.asarray(out)).all()


def test_participation_renormalize_semantics():
    rng = np.random.default_rng(1)
    c = rng.uniform(0.0, 1.0, size=(2, 4, 4)).astype(np.float32)
    c *= rng.uniform(size=(2, 4, 4)) > 0.4  # sparsify
    c[..., np.arange(4), np.arange(4)] += 0.2  # self mass
    c /= c.sum(axis=-1, keepdims=True)
    c = jnp.asarray(c)
    all_on = jnp.ones((4,), bool)
    np.testing.assert_array_equal(
        np.asarray(participation_renormalize(c, all_on)), np.asarray(c))
    active = jnp.asarray([True, False, True, True])
    out = np.asarray(participation_renormalize(c, active))
    np.testing.assert_allclose(out.sum(axis=-1), np.ones((2, 4)),
                               rtol=1e-6)
    # the dropped column is zeroed everywhere EXCEPT rows whose entire
    # support went inactive — those fall back to self-weight 1 (and the
    # inactive node's own row is discarded by the round select anyway)
    masked = np.asarray(c) * np.asarray(active, np.float32)[None, None, :]
    fallback = masked.sum(axis=-1) == 0
    np.testing.assert_array_equal(out[..., 1][~fallback],
                                  np.zeros_like(out[..., 1][~fallback]))
    np.testing.assert_array_equal(
        out[fallback], np.broadcast_to(np.eye(4, dtype=np.float32)[1],
                                       out[fallback].shape))
    # rows with no support on the dropped column are returned bit-exact
    untouched = np.asarray(c)[..., 1] == 0
    np.testing.assert_array_equal(out[untouched], np.asarray(c)[untouched])


# ----------------------------------------------------------------------
# satellite regressions: drop_edges dead param, reactive betweenness
# ----------------------------------------------------------------------
def test_drop_edges_dead_param_removed():
    """`keep_connected_to_self` was dead (Topology rejects nonzero
    diagonals, so a self-loop-preserving variant is unrepresentable);
    node-level dropout is ParticipationSpec's job now.  The parameter is
    gone — passing it must fail loudly instead of silently no-opping."""
    from repro.core.dynamic import drop_edges

    assert "keep_connected_to_self" not in inspect.signature(
        drop_edges).parameters
    with pytest.raises(TypeError):
        drop_edges(ring(4), 0.5, np.random.default_rng(0),
                   keep_connected_to_self=True)


def test_reactive_betweenness_rejected_with_opt_in():
    from repro.core.coeffs import program_for

    topo = ring(6)
    strat = AggregationStrategy("betweenness", tau=0.1, seed=0)
    program, state = program_for(topo, strat, p_fail=0.3, reactive=True)
    with pytest.raises(ValueError, match="betweenness"):
        program.validate_state_kinds(state)
    ok, state_ok = program_for(topo, strat, p_fail=0.3, reactive=True,
                               allow_nominal_betweenness=True)
    ok.validate_state_kinds(state_ok)  # explicit opt-in passes
    nominal, state_n = program_for(topo, strat, p_fail=0.3, reactive=False)
    nominal.validate_state_kinds(state_n)  # non-reactive never gated


# ----------------------------------------------------------------------
# 8-device mesh: participation shards on E bit-identically (subprocess —
# XLA_FLAGS must be set before jax initializes; see conftest.py)
# ----------------------------------------------------------------------
SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    assert len(jax.devices()) == 8, jax.devices()

    from repro.core.decentralized import (
        DecentralizedConfig, coeffs_stack, stack_params)
    from repro.core.dynamic import ParticipationSpec
    from repro.core.strategies import AggregationStrategy
    from repro.core.sweep import SweepEngine
    from repro.core.topology import ring
    from repro.data.backdoor import backdoored_testset
    from repro.data.distribution import node_datasets
    from repro.data.pipeline import NodeBatcher, make_test_batch
    from repro.data.synthetic import make_dataset
    from repro.launch.mesh import make_sweep_mesh
    from repro.models.paper_models import (
        classifier_accuracy, classifier_loss, ffn_apply, ffn_init)
    from repro.training.optimizer import sgd

    N, R, E = 4, 4, 3
    train = make_dataset("mnist", 400, seed=0)
    test = make_dataset("mnist", 100, seed=9)
    cfg = DecentralizedConfig(rounds=R, local_epochs=2, eval_every=2)
    topo = ring(N)
    parts = node_datasets(train, N, ood_node=0, q=0.10, seed=0)
    nb = NodeBatcher(parts, batch_size=8, steps_per_epoch=2, seed=0,
                     local_epochs=2)
    tb = make_test_batch(test, 32, seed=0)
    ob = make_test_batch(backdoored_testset(test, seed=0), 32, seed=0)
    kinds = ["unweighted", "random", "degree"]  # E=3 pads to 8 devices
    bank = {k: v[None] for k, v in nb.sample_bank().items()}
    indices = nb.all_round_indices(R)[None]
    data_idx = np.zeros(E, np.int32)
    coeffs = np.stack([
        coeffs_stack(topo, AggregationStrategy(k, seed=0), R,
                     nb.data_counts())
        for k in kinds])
    params0 = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[stack_params([ffn_init(jax.random.key(0))] * N)] * E)
    st = lambda t: {k: jnp.stack([jnp.asarray(t[k])] * E) for k in t}
    mesh = make_sweep_mesh()  # all 8 virtual devices
    engine = SweepEngine(sgd(1e-2), classifier_loss(ffn_apply),
                         classifier_accuracy(ffn_apply), cfg)
    run = lambda **kw: engine.run(
        params0, coeffs, bank, indices, data_idx, st(tb), st(ob),
        batch_size=8, **kw)

    def check(r, ref, label):
        np.testing.assert_array_equal(r.train_loss, ref.train_loss)
        np.testing.assert_array_equal(r.iid_acc, ref.iid_acc)
        np.testing.assert_array_equal(r.ood_acc, ref.ood_acc)
        for a, b in zip(jax.tree.leaves(r.params),
                        jax.tree.leaves(ref.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        if ref.participation is not None:
            for k in ref.participation:
                np.testing.assert_array_equal(
                    r.participation[k], ref.participation[k],
                    err_msg=(label, k))
        print(label, "ok")

    # rate 1.0 sharded over 8 devices == the synchronous scanned run
    sync = run()
    spec = ParticipationSpec()
    ones = np.ones(E, np.float32)
    check(run(participation=spec, participation_rates=ones, mesh=mesh),
          sync, "mesh8/rate1-vs-sync")

    # a genuine rate grid: scanned == mesh(8) == mesh(8)+chunk, incl.
    # the participation digest (carry shards on E; padding rows dropped)
    rates = np.asarray([1.0, 0.6, 0.3], np.float32)
    ref = run(participation=spec, participation_rates=rates)
    check(run(participation=spec, participation_rates=rates, mesh=mesh),
          ref, "mesh8/rate-grid")
    check(run(participation=spec, participation_rates=rates, mesh=mesh,
              chunk_rounds=3),
          ref, "mesh8/rate-grid+chunk")
    # the grid's rate-1.0 row is the synchronous row, even sharded
    np.testing.assert_array_equal(ref.train_loss[0], sync.train_loss[0])
    print("PARTICIPATION_SHARDED_OK")
""")


def test_participation_sharded_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "PARTICIPATION_SHARDED_OK" in out.stdout, (out.stdout[-2000:],
                                                      out.stderr[-3000:])
