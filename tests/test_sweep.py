"""Sweep-engine equivalence: the scanned / vmapped paths must reproduce
the legacy per-round loop exactly (same histories, same final params),
including per-round Random resampling and dynamic link-failure schedules.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.decentralized import (
    DecentralizedConfig,
    DecentralizedTrainer,
    coeffs_stack,
    eval_round_indices,
    stack_params,
)
from repro.core.dynamic import dynamic_mixing_matrix, link_failure_schedule
from repro.core.strategies import AggregationStrategy
from repro.core.sweep import SweepEngine, gather_round_batch
from repro.core.topology import ring
from repro.data.distribution import node_datasets
from repro.data.pipeline import NodeBatcher, make_test_batch
from repro.data.synthetic import make_dataset
from repro.training.optimizer import sgd

N, ROUNDS = 4, 5
# epoch_shuffle=False: these equivalence tests drive hand-built one-epoch
# batch stacks, i.e. the legacy replay-E-times behavior the flag preserves.
CFG = DecentralizedConfig(rounds=ROUNDS, local_epochs=2, eval_every=2,
                          epoch_shuffle=False)


# ----------------------------------------------------------------------
# tiny MLP regression setting (fast; exercises multi-leaf pytrees)
# ----------------------------------------------------------------------
def _loss_fn(p, batch):
    h = jnp.tanh(batch["x"] @ p["w1"] + p["b1"][None])
    pred = h @ p["w2"] + p["b2"][None]
    return jnp.mean((pred - batch["y"]) ** 2)


def _eval_fn(p, tb):
    h = jnp.tanh(tb["x"] @ p["w1"] + p["b1"][None])
    pred = h @ p["w2"] + p["b2"][None]
    return jnp.mean((jnp.abs(pred - tb["y"]) < 0.5).astype(jnp.float32))


def _mlp_init(seed):
    r = np.random.default_rng(seed)
    return {
        "w1": jnp.asarray(r.normal(size=(5, 8)) * 0.3, jnp.float32),
        "b1": jnp.zeros((8,), jnp.float32),
        "w2": jnp.asarray(r.normal(size=(8, 2)) * 0.3, jnp.float32),
        "b2": jnp.zeros((2,), jnp.float32),
    }


def _mlp_batches_fn(r):
    g = np.random.default_rng(100 + r)
    return {
        "x": jnp.asarray(g.normal(size=(N, 3, 8, 5)), jnp.float32),
        "y": jnp.asarray(g.normal(size=(N, 3, 8, 2)), jnp.float32),
    }


def _mlp_tests():
    g = np.random.default_rng(7)
    mk = lambda: {
        "x": jnp.asarray(g.normal(size=(16, 5)), jnp.float32),
        "y": jnp.asarray(g.normal(size=(16, 2)), jnp.float32),
    }
    return mk(), mk()


def _assert_hist_equal(h1, h2):
    assert [m.round for m in h1] == [m.round for m in h2]
    for a, b in zip(h1, h2):
        np.testing.assert_array_equal(a.iid_acc, b.iid_acc)
        np.testing.assert_array_equal(a.ood_acc, b.ood_acc)
        np.testing.assert_array_equal(a.train_loss, b.train_loss)


def _assert_trees_equal(t1, t2):
    for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _run_mlp(strategy, cfg, coeffs_fn=None):
    trainer = DecentralizedTrainer(
        ring(N), strategy, sgd(1e-2), _loss_fn, _eval_fn, cfg,
        coeffs_fn=coeffs_fn)
    params = stack_params([_mlp_init(0)] * N)
    tb, ob = _mlp_tests()
    return trainer.run(params, _mlp_batches_fn, tb, ob)


@pytest.mark.parametrize("kind", ["unweighted", "random"])
def test_scan_matches_unrolled_bitexact(kind):
    """The single-scan path == the legacy loop, incl. the Random
    baseline's per-round mixing-matrix resampling."""
    strat = AggregationStrategy(kind, seed=3)
    p_scan, h_scan = _run_mlp(strat, CFG)
    p_unr, h_unr = _run_mlp(strat, dataclasses.replace(CFG, unroll_eval=True))
    _assert_hist_equal(h_scan, h_unr)
    _assert_trees_equal(p_scan, p_unr)


def test_scan_matches_unrolled_dynamic_link_failure():
    """A core.dynamic drop_edges coefficient schedule is pure data to the
    scanned path and host control flow to the unrolled one — same run."""
    topo = ring(N)
    strat = AggregationStrategy("degree", tau=0.1, seed=1)
    fn = lambda r: dynamic_mixing_matrix(topo, strat, r, p_fail=0.5)
    p_scan, h_scan = _run_mlp(strat, CFG, coeffs_fn=fn)
    p_unr, h_unr = _run_mlp(
        strat, dataclasses.replace(CFG, unroll_eval=True), coeffs_fn=fn)
    _assert_hist_equal(h_scan, h_unr)
    _assert_trees_equal(p_scan, p_unr)


def test_link_failure_schedule_is_the_coeffs_stack():
    topo = ring(N)
    strat = AggregationStrategy("degree", tau=0.1, seed=1)
    sched = link_failure_schedule(topo, strat, ROUNDS, p_fail=0.5)
    assert sched.shape == (ROUNDS, N, N)
    stack = coeffs_stack(
        topo, strat, ROUNDS,
        coeffs_fn=lambda r: dynamic_mixing_matrix(topo, strat, r, 0.5))
    np.testing.assert_array_equal(sched, stack)


def test_coeffs_stack_random_resamples_per_round():
    stack = coeffs_stack(ring(N), AggregationStrategy("random", seed=0),
                         ROUNDS)
    assert stack.shape == (ROUNDS, N, N)
    assert not np.array_equal(stack[0], stack[1])
    # coeffs_stack materializes the float32 device-side coefficient
    # program (core/coeffs.py) — rows are stochastic to f32 precision
    np.testing.assert_allclose(stack.sum(axis=2), 1.0, atol=1e-6)


def test_eval_round_indices_matches_legacy_rule():
    assert eval_round_indices(5, 2) == [1, 3, 4]
    assert eval_round_indices(4, 1) == [0, 1, 2, 3]
    assert eval_round_indices(6, 10) == [5]


# ----------------------------------------------------------------------
# NodeBatcher bank/indices == materialized round batches
# ----------------------------------------------------------------------
def test_bank_gather_reproduces_round_batches():
    train = make_dataset("mnist", 600, seed=0)
    parts = node_datasets(train, N, ood_node=1, q=0.10, seed=0)
    nb = NodeBatcher(parts, batch_size=8, steps_per_epoch=3, seed=0)
    bank = jax.tree.map(
        lambda x: jnp.asarray(x)[None], nb.sample_bank())  # D=1
    for r in (0, 2):
        want = nb.round_batches(r)
        got = gather_round_batch(
            bank, jnp.asarray(0), jnp.asarray(nb.round_indices(r)),
            batch_size=8)
        assert set(got) == set(want)
        for k in want:
            np.testing.assert_array_equal(np.asarray(got[k]), want[k])


# ----------------------------------------------------------------------
# vmapped grid == per-experiment legacy runs (real data pipeline)
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def mnist_setting():
    train = make_dataset("mnist", 600, seed=0)
    test = make_dataset("mnist", 120, seed=9)
    from repro.data.backdoor import backdoored_testset
    from repro.models.paper_models import (
        classifier_accuracy, classifier_loss, ffn_apply, ffn_init)

    loss_fn = classifier_loss(ffn_apply)
    acc_fn = classifier_accuracy(ffn_apply)
    configs = {}
    for seed in (0, 1):
        parts = node_datasets(train, N, ood_node=0, q=0.10, seed=seed)
        nb = NodeBatcher(parts, batch_size=8, steps_per_epoch=2, seed=seed)
        tb = make_test_batch(test, 48, seed=seed)
        ob = make_test_batch(backdoored_testset(test, seed=seed), 48,
                             seed=seed)
        configs[seed] = (nb, tb, ob)
    return loss_fn, acc_fn, ffn_init, configs


def test_sweep_grid_matches_legacy_per_experiment(mnist_setting):
    """Strategies × seeds through ONE compiled program == N independent
    legacy DecentralizedTrainer.run calls, bit-for-bit."""
    loss_fn, acc_fn, init, configs = mnist_setting
    topo = ring(N)
    cfg = DecentralizedConfig(rounds=3, local_epochs=1, eval_every=2)
    cells = [("unweighted", 0), ("random", 0), ("degree", 1), ("fl", 1)]

    seeds = sorted(configs)
    raw = [configs[s][0].sample_bank() for s in seeds]
    cap = max(b["x"].shape[1] for b in raw)
    pad = lambda a: np.pad(
        a, [(0, 0), (0, cap - a.shape[1])] + [(0, 0)] * (a.ndim - 2))
    bank = {k: np.stack([pad(b[k]) for b in raw]) for k in raw[0]}
    indices = np.stack(
        [configs[s][0].all_round_indices(cfg.rounds) for s in seeds])
    data_idx = np.array([seeds.index(s) for _, s in cells])
    coeffs = np.stack([
        coeffs_stack(topo, AggregationStrategy(k, seed=s), cfg.rounds,
                     configs[s][0].data_counts())
        for k, s in cells])
    params0 = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[stack_params([init(jax.random.key(s))] * N) for _, s in cells])
    stack_tests = lambda which: {
        k: jnp.stack([jnp.asarray(configs[s][which][k]) for _, s in cells])
        for k in configs[0][which]}

    engine = SweepEngine(sgd(1e-2), loss_fn, acc_fn, cfg)
    res = engine.run(params0, coeffs, bank, indices, data_idx,
                     stack_tests(1), stack_tests(2), batch_size=8)
    res_unrolled = engine.run(params0, coeffs, bank, indices, data_idx,
                              stack_tests(1), stack_tests(2), batch_size=8,
                              unroll_eval=True)
    np.testing.assert_array_equal(res.train_loss, res_unrolled.train_loss)
    np.testing.assert_array_equal(res.iid_acc, res_unrolled.iid_acc)
    _assert_trees_equal(res.params, res_unrolled.params)

    for e, (kind, seed) in enumerate(cells):
        nb, tb, ob = configs[seed]
        trainer = DecentralizedTrainer(
            topo, AggregationStrategy(kind, seed=seed), sgd(1e-2),
            loss_fn, acc_fn, cfg, data_counts=nb.data_counts())
        fp, hist = trainer.run(
            stack_params([init(jax.random.key(seed))] * N),
            lambda r: jax.tree.map(jnp.asarray, nb.round_batches(r)),
            jax.tree.map(jnp.asarray, tb), jax.tree.map(jnp.asarray, ob))
        _assert_hist_equal(hist, res.history(e))
        _assert_trees_equal(fp, res.experiment_params(e))


# ----------------------------------------------------------------------
# chunked-rounds + (single-device) sharded modes == scanned, bit-for-bit
# ----------------------------------------------------------------------
def _mnist_grid(mnist_setting, cfg):
    """Assemble the 4-cell grid of test_sweep_grid... as engine inputs."""
    loss_fn, acc_fn, init, configs = mnist_setting
    topo = ring(N)
    cells = [("unweighted", 0), ("random", 0), ("degree", 1), ("fl", 1)]
    seeds = sorted(configs)
    raw = [configs[s][0].sample_bank() for s in seeds]
    cap = max(b["x"].shape[1] for b in raw)
    pad = lambda a: np.pad(
        a, [(0, 0), (0, cap - a.shape[1])] + [(0, 0)] * (a.ndim - 2))
    bank = {k: np.stack([pad(b[k]) for b in raw]) for k in raw[0]}
    indices = np.stack(
        [configs[s][0].all_round_indices(cfg.rounds) for s in seeds])
    data_idx = np.array([seeds.index(s) for _, s in cells])
    coeffs = np.stack([
        coeffs_stack(topo, AggregationStrategy(k, seed=s), cfg.rounds,
                     configs[s][0].data_counts())
        for k, s in cells])
    params0 = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[stack_params([init(jax.random.key(s))] * N) for _, s in cells])
    stack_tests = lambda which: {
        k: jnp.stack([jnp.asarray(configs[s][which][k]) for _, s in cells])
        for k in configs[0][which]}
    engine = SweepEngine(sgd(1e-2), loss_fn, acc_fn, cfg)
    args = (params0, coeffs, bank, indices, data_idx,
            stack_tests(1), stack_tests(2))
    return engine, args


def _assert_results_equal(a, b):
    np.testing.assert_array_equal(a.train_loss, b.train_loss)
    np.testing.assert_array_equal(a.iid_acc, b.iid_acc)
    np.testing.assert_array_equal(a.ood_acc, b.ood_acc)
    _assert_trees_equal(a.params, b.params)


def test_chunked_rounds_matches_scanned_bitexact(mnist_setting):
    """chunk_rounds=2 over R=3 (a full chunk + a remainder chunk) resumes
    the exact scan carry — metrics and params bit-identical."""
    cfg = DecentralizedConfig(rounds=3, local_epochs=1, eval_every=2)
    engine, args = _mnist_grid(mnist_setting, cfg)
    res = engine.run(*args, batch_size=8)
    res_chunked = engine.run(*args, batch_size=8, chunk_rounds=2)
    _assert_results_equal(res_chunked, res)


def test_sharded_single_device_mesh_matches_scanned(mnist_setting):
    """mesh=make_sweep_mesh(1) exercises the full shard_map machinery on
    the 1 CPU device the main pytest process sees (the 8-device version
    lives in tests/test_sweep_sharded.py, subprocess)."""
    from repro.launch.mesh import make_sweep_mesh

    cfg = DecentralizedConfig(rounds=3, local_epochs=1, eval_every=2)
    engine, args = _mnist_grid(mnist_setting, cfg)
    res = engine.run(*args, batch_size=8)
    res_sharded = engine.run(*args, batch_size=8, mesh=make_sweep_mesh(1))
    _assert_results_equal(res_sharded, res)
    res_both = engine.run(*args, batch_size=8, mesh=make_sweep_mesh(1),
                          chunk_rounds=2)
    _assert_results_equal(res_both, res)


def test_unroll_rejects_shard_and_chunk(mnist_setting):
    from repro.launch.mesh import make_sweep_mesh

    cfg = DecentralizedConfig(rounds=3, local_epochs=1, eval_every=2)
    engine, args = _mnist_grid(mnist_setting, cfg)
    with pytest.raises(ValueError):
        engine.run(*args, batch_size=8, unroll_eval=True, chunk_rounds=2)
    with pytest.raises(ValueError):
        engine.run(*args, batch_size=8, unroll_eval=True,
                   mesh=make_sweep_mesh(1))


def test_epoch_shuffle_distinct_passes():
    """epoch_shuffle=True + NodeBatcher(local_epochs=E) trains on E
    *different* batch orders; the legacy flag replays one order E times —
    the two runs genuinely diverge."""
    train = make_dataset("mnist", 400, seed=0)
    parts = node_datasets(train, N, ood_node=0, q=0.10, seed=0)
    from repro.models.paper_models import (
        classifier_accuracy, classifier_loss, ffn_apply, ffn_init)

    tb = make_test_batch(make_dataset("mnist", 80, seed=9), 32)
    run = lambda nb, cfg: DecentralizedTrainer(
        ring(N), AggregationStrategy("unweighted"), sgd(1e-2),
        classifier_loss(ffn_apply), classifier_accuracy(ffn_apply),
        cfg).run(
            stack_params([ffn_init(jax.random.key(0))] * N),
            lambda r: jax.tree.map(jnp.asarray, nb.round_batches(r)),
            jax.tree.map(jnp.asarray, tb), jax.tree.map(jnp.asarray, tb))

    nb_e = NodeBatcher(parts, batch_size=8, steps_per_epoch=2, seed=0,
                       local_epochs=2)
    cfg_e = DecentralizedConfig(rounds=2, local_epochs=2, eval_every=1)
    p_shuf, _ = run(nb_e, cfg_e)

    nb_l = NodeBatcher(parts, batch_size=8, steps_per_epoch=2, seed=0)
    cfg_l = dataclasses.replace(cfg_e, epoch_shuffle=False)
    p_legacy, _ = run(nb_l, cfg_l)

    leaves = zip(jax.tree.leaves(p_shuf), jax.tree.leaves(p_legacy))
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in leaves)


def test_epoch_shuffle_rejects_indivisible_batch_axis():
    """A 3-step batch axis cannot be local_epochs=2 distinct passes."""
    from repro.core.decentralized import make_local_train_fn

    fn = make_local_train_fn(_loss_fn, sgd(1e-2), local_epochs=2,
                             epoch_shuffle=True)
    params = _mlp_init(0)
    opt = sgd(1e-2).init(params)
    batches = _mlp_batches_fn(0)
    one_node = jax.tree.map(lambda x: x[0], batches)  # (3, 8, ...)
    with pytest.raises(ValueError, match="not divisible"):
        fn(params, opt, one_node)


# ----------------------------------------------------------------------
# pallas aggregation routing
# ----------------------------------------------------------------------
def test_pallas_mix_impl_matches_einsum():
    """mix_impl='pallas' routes Eq. (2) through kernels/gossip_mix; the
    fused-MAC accumulation matches the einsum to f32 rounding."""
    strat = AggregationStrategy("degree", tau=0.1)
    cfg = DecentralizedConfig(rounds=2, local_epochs=1, eval_every=1)
    p_e, h_e = _run_mlp(strat, cfg)
    p_p, h_p = _run_mlp(strat, dataclasses.replace(cfg, mix_impl="pallas"))
    for a, b in zip(jax.tree.leaves(p_e), jax.tree.leaves(p_p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
    for ma, mb in zip(h_e, h_p):
        np.testing.assert_allclose(ma.train_loss, mb.train_loss,
                                   rtol=1e-5, atol=1e-6)
