"""Validate the analytic roofline model against XLA cost_analysis on a
LOOP-FREE lowering (no scan, micro=1, 2 layers) where HLO flop counting is
exact — the methodology contract of benchmarks/roofline.py."""
import os
import subprocess
import sys
import textwrap


from benchmarks.roofline import attention_flops, cache_bytes, full_table, resolve_plan
from repro.configs.base import ModelConfig, SHAPES


class TestAnalyticPieces:
    def test_attention_flops_causal_scaling(self):
        cfg = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          d_ff=128, vocab_size=64)
        f1 = attention_flops(cfg, batch=1, seq=128)
        f2 = attention_flops(cfg, batch=1, seq=256)
        assert 3.5 < f2 / f1 < 4.5  # quadratic in seq

    def test_window_caps_context(self):
        full = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                           d_ff=128, vocab_size=64)
        local = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                            d_ff=128, vocab_size=64,
                            attn_pattern=("local",), window_size=64)
        assert attention_flops(local, 1, 4096) < attention_flops(full, 1, 4096) / 10

    def test_mla_cache_much_smaller_than_mha(self):
        from repro.configs.registry import get_config, get_parallel

        ds = get_config("deepseek-v2-236b")
        plan = resolve_plan(ds, get_parallel("deepseek-v2-236b"),
                            SHAPES["decode_32k"], False)
        mla = cache_bytes(ds, SHAPES["decode_32k"], plan)["total"]
        # equivalent MHA cache
        import dataclasses

        mha = dataclasses.replace(ds, use_mla=False)
        full = cache_bytes(mha, SHAPES["decode_32k"], plan)["total"]
        assert full / mla > 10  # the MLA selling point

    def test_all_pairs_fit_hbm(self):
        rows = [r for r in full_table(False) if "skipped" not in r]
        bad = [(r["arch"], r["shape"]) for r in rows if not r["fits_hbm"]]
        assert not bad, f"pairs exceeding 90% HBM: {bad}"

    def test_every_pair_has_positive_terms(self):
        for r in full_table(False):
            if "skipped" in r:
                continue
            assert r["t_compute_s"] > 0
            assert r["t_memory_s"] > 0
            assert r["useful_flops_ratio"] <= 1.5


VALIDATE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from repro.configs.base import ModelConfig, ParallelConfig, InputShape
    from repro.models.transformer import ForwardOptions
    from repro.training.train_step import make_train_step
    from repro.training.optimizer import make_optimizer
    from benchmarks import roofline

    # loop-free micro config: no scan, micro=1, einsum attention
    cfg = ModelConfig(name="v", n_layers=2, d_model=256, n_heads=8,
                      n_kv_heads=8, d_ff=1024, vocab_size=4096,
                      dtype="float32", param_dtype="float32")
    pcfg = ParallelConfig(n_nodes=8, microbatch=1, remat=False,
                          scan_layers=False)
    opt = make_optimizer("adamw", 1e-3)
    step = make_train_step(cfg, pcfg, opt,
                           opts=ForwardOptions(remat=False, use_scan=False))
    n, b, s = 8, 2, 128
    from repro.models.transformer import init_params
    p_abs = jax.eval_shape(
        jax.vmap(lambda k: init_params(k, cfg)),
        jax.ShapeDtypeStruct((n, 2), jnp.uint32))
    opt_abs = jax.eval_shape(jax.vmap(opt.init), p_abs)
    batch = {
        "tokens": jax.ShapeDtypeStruct((n, 1, b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((n, 1, b, s), jnp.int32),
    }
    coeffs = jax.ShapeDtypeStruct((n, n), jnp.float32)
    compiled = jax.jit(step).lower(p_abs, opt_abs, batch, coeffs).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jax returns [dict]
        ca = ca[0]
    hlo_flops = float(ca["flops"])

    shape = InputShape("v", s, n * b, "train")
    plan = roofline.Plan(n_global=n, fsdp=1, model=1, pods=1, micro=1,
                         local_batch=b)
    fl = roofline.step_flops(cfg, shape, plan)
    ratio = fl["total"] / hlo_flops
    print(f"ANALYTIC/HLO={ratio:.3f}")
    assert 0.5 < ratio < 2.0, ratio
    print("ROOFLINE_VALIDATION_OK")
""")


def test_analytic_flops_vs_hlo_loopfree():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", VALIDATE], env=env,
                         capture_output=True, text=True, timeout=420,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "ROOFLINE_VALIDATION_OK" in out.stdout, \
        out.stdout[-1000:] + out.stderr[-2000:]
